"""Kernel-vs-oracle correctness: the CORE L1 signal.

* FA-2 Pallas kernel ~= exact attention (bf16 tolerance).
* H-FA Pallas kernel == bit-exact numpy integer emulation.
* hypothesis sweeps over shapes/seeds (session guide requirement).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fa2, hfa, ref


def bf(x):
    return np.asarray(jnp.asarray(np.asarray(x, np.float32), jnp.bfloat16), np.float32)


def rand_case(seed, b, n, d):
    rng = np.random.default_rng(seed)
    return (bf(rng.standard_normal((b, d))),
            bf(rng.standard_normal((n, d))),
            bf(rng.standard_normal((n, d))))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1, 3, 8]),
       st.sampled_from([64, 128]), st.sampled_from([8, 16, 32]))
def test_fa2_kernel_matches_exact(seed, b, n, d):
    q, k, v = rand_case(seed, b, n, d)
    out = np.asarray(fa2.fa2_attention(q, k, v), np.float32)
    want = ref.exact_attention(q, k, v)
    assert np.max(np.abs(out - want)) < 2e-2, "fa2 kernel deviates from exact"


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 4]),
       st.sampled_from([64, 128]), st.sampled_from([8, 16]))
def test_hfa_kernel_bit_exact_vs_numpy_spec(seed, b, n, d):
    q, k, v = rand_case(seed, b, n, d)
    out = np.asarray(hfa.hfa_attention(q, k, v), np.float32)
    want = ref.hfa_attention_int(q, k, v)
    assert np.array_equal(out, want), "H-FA kernel must be bit-exact vs the spec"


def test_hfa_kernel_with_mask_matches_per_row_reference():
    rng = np.random.default_rng(5)
    q, k, v = rand_case(7, 4, 128, 16)
    mask = rng.random((4, 128)) > 0.4
    out = np.asarray(hfa.hfa_attention(q, k, v, jnp.asarray(mask)), np.float32)
    for b in range(4):
        want = ref.hfa_attention_int(q[b:b + 1], k[mask[b]], v[mask[b]])
        assert np.array_equal(out[b], want[0]), f"row {b}"


def test_fa2_kernel_with_causal_mask():
    q, k, v = rand_case(11, 8, 8, 8)  # self-attention: B == N
    causal = np.tril(np.ones((8, 8), bool))
    out = np.asarray(fa2.fa2_attention(q, k, v, jnp.asarray(causal), block_k=8), np.float32)
    for b in range(8):
        want = ref.exact_attention(q[b:b + 1], k[:b + 1], v[:b + 1])
        assert np.max(np.abs(out[b] - want[0])) < 2e-2, f"row {b}"


def test_mha_wrappers_shapes():
    rng = np.random.default_rng(3)
    q = bf(rng.standard_normal((2, 64, 16)))
    k = bf(rng.standard_normal((2, 64, 16)))
    v = bf(rng.standard_normal((2, 64, 16)))
    causal = jnp.asarray(np.tril(np.ones((64, 64), bool)))
    o1 = hfa.hfa_attention_mha(q, k, v, causal)
    o2 = fa2.fa2_attention_mha(q, k, v, causal)
    assert o1.shape == (2, 64, 16)
    assert o2.shape == (2, 64, 16)


def test_block_k_must_divide_n():
    q, k, v = rand_case(1, 2, 100, 8)
    with pytest.raises(ValueError):
        hfa.hfa_attention(q, k, v, block_k=64)


def test_hfa_blocked_merge_against_monolithic():
    # Eq. 16 merging: blocked result stays close to the single-FAU result
    q, k, v = rand_case(13, 2, 128, 16)
    mono = ref.hfa_attention_int(q, k, v)
    blocked = ref.hfa_attention_int_blocked(q, k, v, 4)
    # both approximate the same value; bounded deviation
    assert np.max(np.abs(mono - blocked)) < 0.5


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

"""L2 model tests: shapes, attention-impl consistency, ablations, tasks."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model, tasks
from compile.kernels import ref


@pytest.fixture(scope="module")
def tiny():
    cfg = model.ModelConfig("tiny", vocab=64, d_model=32, n_head=2, n_layer=1, seq_len=64)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_forward_shapes(tiny):
    cfg, params = tiny
    toks = jnp.zeros((3, cfg.seq_len), jnp.int32)
    logits = model.forward(params, cfg, toks, "exact")
    assert logits.shape == (3, cfg.seq_len, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_fa2_model_close_to_exact(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(4, 60, size=(2, cfg.seq_len)), jnp.int32)
    le = model.forward(params, cfg, toks, "exact")
    lf = model.forward(params, cfg, toks, "fa2")
    # bf16 attention inside an f32 model: logits stay close
    assert float(jnp.max(jnp.abs(le - lf))) < 0.15


def test_hfa_model_runs_and_deviates_boundedly(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(4, 60, size=(1, cfg.seq_len)), jnp.int32)
    le = model.forward(params, cfg, toks, "exact")
    lh = model.forward(params, cfg, toks, "hfa")
    diff = float(jnp.max(jnp.abs(le - lh)))
    assert 0.0 < diff < 5.0, f"H-FA logit deviation {diff}"


def test_save_load_roundtrip(tmp_path, tiny):
    cfg, params = tiny
    model.save_params(params, cfg, str(tmp_path))
    loaded, cfg2 = model.load_params(str(tmp_path))
    assert cfg2 == cfg
    for k in params:
        assert np.array_equal(np.asarray(params[k]), np.asarray(loaded[k])), k


def test_emu_config_ablation_ordering():
    # attention-level sanity: Mitchell is the dominant error source
    rng = np.random.default_rng(2)
    q = rng.standard_normal((4, 16)).astype(np.float32)
    k = rng.standard_normal((64, 16)).astype(np.float32)
    v = rng.standard_normal((64, 16)).astype(np.float32)
    ex = ref.exact_attention(q, k, v)

    def err(cfg):
        return float(np.sqrt(((ref.hfa_attention_emu(q, k, v, cfg) - ex) ** 2).mean()))

    e_all = err(ref.EmuConfig())
    e_nom = err(ref.EmuConfig(mitchell=False))
    e_noq = err(ref.EmuConfig(quant=False))
    e_nop = err(ref.EmuConfig(pwl=False))
    assert e_nom < 0.2 * e_all
    assert abs(e_noq - e_all) < 0.5 * e_all
    assert abs(e_nop - e_all) < 0.5 * e_all


def test_task_generators_produce_valid_instances():
    rng = np.random.default_rng(0)
    for fam, var in tasks.all_task_ids():
        for _ in range(20):
            t = tasks.gen_task(rng, fam, var)
            assert len(t.options) == 4
            assert len(set(t.options)) == 4
            assert 0 <= t.answer < 4
            assert all(0 <= tok < tasks.VOCAB for tok in t.prompt)
            assert t.prompt[-1] == tasks.ATOK


def test_corpus_shape_and_vocab():
    rng = np.random.default_rng(1)
    c = tasks.make_corpus(rng, 8, 65)
    assert c.shape == (8, 65)
    assert c.min() >= 0 and c.max() < tasks.VOCAB


def test_eval_file_roundtrip(tmp_path):
    rng = np.random.default_rng(2)
    insts = [tasks.gen_task(rng, "assoc", 2) for _ in range(5)]
    p = str(tmp_path / "assoc_2.txt")
    tasks.write_eval_file(p, insts)
    lines = [l for l in open(p) if not l.startswith("#")]
    assert len(lines) == 5
    pr, op, ans = lines[0].strip().split("|")
    assert [int(x) for x in pr.split()] == insts[0].prompt
    assert int(ans) == insts[0].answer


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

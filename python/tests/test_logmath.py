"""Unit + property tests for the bit-exact LNS primitives (hypothesis
sweeps per the session guide: shapes/dtypes + invariants)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import logmath as lm


def test_pwl_tables_match_baked_constants():
    c0, c1 = lm.pwl_tables()
    assert c0.tolist() == [16384, 15024, 13777, 12634, 11585, 10624, 9742, 8933]
    assert c1.tolist() == [85, 78, 71, 66, 60, 55, 51, 46]


@given(st.integers(0, 127))
def test_pwl_approximates_pow2(f):
    y = int(lm.pwl_pow2_neg_frac_q14(np.int32(f), xp=np))
    exact = 2.0 ** (-f / 128.0) * (1 << 14)
    assert abs(y - exact) < 30  # < 1.5e-3 relative in Q14


@given(st.integers(0, 0xFFFF))
@settings(max_examples=300)
def test_log_conversion_roundtrip_error_bounded(bits):
    s, l = lm.bf16_bits_to_log_q7(np.int32(bits), xp=np)
    val = lm.bf16_bits_to_f32(np.int32(bits), xp=np)
    if not np.isfinite(val) or val == 0 or (int(bits) & 0x7F80) == 0:
        return
    # Mitchell conversion error <= 0.086 in log2
    err = abs(float(l) / 128.0 - np.log2(abs(float(val))))
    assert err <= 0.09, (bits, err)
    assert int(s) == (bits >> 15)


@given(st.floats(-40.0, 5.0, allow_nan=False))
def test_quant_clamps_and_is_monotone_grid(x):
    q = int(lm.quant_diff_q7(np.float32(x), xp=np))
    assert -2772 <= q <= 0  # floor(-15 * log2e * 128) = -2771.x
    # floor property: q <= x*log2e*128 < q+1 within clamp range
    xc = min(max(x, -15.0), 0.0)
    t = np.float32(xc) * lm.LOG2E_F32 * 128
    assert q <= t + 1e-3


@given(
    st.integers(-5000, 5000), st.integers(-5000, 5000),
    st.integers(0, 1), st.integers(0, 1),
)
@settings(max_examples=500)
def test_lns_add_commutes_for_same_sign(a, b, sa, sb):
    s1, l1 = lm.lns_add(np.int32(sa), np.int32(a), np.int32(sb), np.int32(b), xp=np)
    s2, l2 = lm.lns_add(np.int32(sb), np.int32(b), np.int32(sa), np.int32(a), xp=np)
    assert int(l1) == int(l2)
    if a != b:  # sign ties break toward the second operand
        assert int(s1) == int(s2)


@given(st.integers(-5000, 5000), st.integers(0, 1))
def test_lns_add_zero_identity(a, sa):
    s, l = lm.lns_add(np.int32(sa), np.int32(a), np.int32(0), np.int32(lm.LOG_ZERO), xp=np)
    assert (int(s), int(l)) == (sa, a)
    s, l = lm.lns_add(np.int32(0), np.int32(lm.LOG_ZERO), np.int32(sa), np.int32(a), xp=np)
    assert (int(s), int(l)) == (sa, a)


@given(st.integers(-3000, 3000), st.integers(-3000, 3000))
@settings(max_examples=300)
def test_lns_add_same_sign_upper_bounds(a, b):
    # positive + positive: max(A,B) <= result <= max(A,B) + 1.0 (Q7: +128)
    _, l = lm.lns_add(np.int32(0), np.int32(a), np.int32(0), np.int32(b), xp=np)
    assert max(a, b) <= int(l) <= max(a, b) + 128


@given(st.floats(1e-30, 1e30, allow_nan=False, allow_infinity=False))
@settings(max_examples=300)
def test_back_conversion_accuracy(v):
    q7 = int(np.floor(np.log2(v) * 128))
    if not -(126 << 7) <= q7 <= (127 << 7):
        return
    bits = lm.log_q7_to_bf16_bits(np.int32(0), np.int32(q7), xp=np)
    out = float(lm.bf16_bits_to_f32(bits.astype(np.int32), xp=np))
    # Eq. 22 error: within a factor of 2^(0.086 + 1/128)
    ratio = out / v
    assert 0.9 < ratio < 1.1 or abs(np.log2(ratio)) < 0.1


def test_sentinel_roundtrip():
    bits = lm.log_q7_to_bf16_bits(np.int32(1), np.int32(lm.LOG_ZERO), xp=np)
    assert int(bits) == 0x8000  # signed zero


def test_f32_bf16_rne():
    cases = np.array([1.0, 1.0 + 1 / 256, 1.0 + 3 / 512, -2.5, 0.0], np.float32)
    bits = lm.f32_to_bf16_bits(cases, xp=np)
    back = lm.bf16_bits_to_f32(bits, xp=np)
    assert back[0] == 1.0
    assert back[1] == 1.0          # tie to even
    assert back[2] == 1.0 + 1 / 128  # round up
    assert back[3] == -2.5
    assert back[4] == 0.0


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

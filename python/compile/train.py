"""Build-time training of the tiny LMs on the synthetic task corpus.

Runs once under ``make artifacts`` (skipped when weights already exist).
Training uses the exact-softmax attention path in f32 — the paper likewise
evaluates H-FA on models trained without it ("without applying any
fine-tuning or re-training", Section VI-A).  A hand-rolled Adam avoids an
optax dependency.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import tasks
from .model import ModelConfig, forward, init_params, save_params

TRAIN_STEPS = {"s0": 1000, "s1": 1400, "s2": 1400}
BATCH = 32
LR = 3e-3
WARMUP = 30


def loss_fn(params, cfg, batch):
    """Next-token CE, up-weighted at answer positions.

    Most tokens in a task document are unpredictable random symbols whose
    loss is irreducible; the learnable signal lives at the position right
    after the ``A`` marker.  Weighting answer positions 20x concentrates
    the gradient there (the 1x elsewhere keeps general LM behaviour).
    """
    inputs, targets = batch[:, :-1], batch[:, 1:]
    logits = forward(params, cfg, inputs, attn_impl="exact")
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets != tasks.PAD).astype(jnp.float32)
    answer_pos = (inputs == tasks.ATOK).astype(jnp.float32)
    w = mask * (1.0 + 19.0 * answer_pos)
    return (nll * w).sum() / w.sum()


def adam_update(params, grads, mstate, vstate, step, lr):
    b1, b2, eps = 0.9, 0.999, 1e-8
    out_p, out_m, out_v = {}, {}, {}
    for k in params:
        m = b1 * mstate[k] + (1 - b1) * grads[k]
        v = b2 * vstate[k] + (1 - b2) * grads[k] ** 2
        mhat = m / (1 - b1 ** step)
        vhat = v / (1 - b2 ** step)
        out_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        out_m[k], out_v[k] = m, v
    return out_p, out_m, out_v


def train_model(cfg: ModelConfig, seed: int = 0, verbose: bool = True) -> dict:
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    mstate = {k: jnp.zeros_like(v) for k, v in params.items()}
    vstate = {k: jnp.zeros_like(v) for k, v in params.items()}
    steps = TRAIN_STEPS.get(cfg.name, 800)

    corpus = tasks.make_corpus(rng, num_seqs=steps * BATCH // 4,
                               seq_len=cfg.seq_len + 1)

    @jax.jit
    def step_fn(params, mstate, vstate, batch, step, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
        params, mstate, vstate = adam_update(params, grads, mstate, vstate, step, lr)
        return params, mstate, vstate, loss

    t0 = time.time()
    for step in range(1, steps + 1):
        idx = rng.integers(0, corpus.shape[0], size=BATCH)
        batch = jnp.asarray(corpus[idx])
        lr = LR * min(1.0, step / WARMUP) * (0.5 * (1 + np.cos(np.pi * step / steps)))
        params, mstate, vstate, loss = step_fn(
            params, mstate, vstate, batch, jnp.float32(step), jnp.float32(lr))
        if verbose and (step % 100 == 0 or step == 1):
            print(f"[train {cfg.name}] step {step:4d}/{steps} "
                  f"loss {float(loss):.4f} ({time.time()-t0:.1f}s)")
    return params


def train_and_save(cfg: ModelConfig, out_dir: str, seed: int = 0) -> dict:
    params = train_model(cfg, seed=seed)
    save_params(params, cfg, out_dir)
    return params

"""FA-2 baseline Pallas kernel: all-float FlashAttention-2 (Alg. 2).

This is the comparison design of the paper's evaluation ('FA-2'): the same
streaming recurrence and tiling as the H-FA kernel, but with every
operation — exponentials, vector-wide multiplications, the final division —
kept in floating point.  Matches the rust ``attention::fa2`` golden model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # python float: avoid captured-constant error in pallas


def _fa2_kernel(q_ref, k_ref, v_ref, mask_ref,
                o_ref, m_ref, l_ref, acc_ref,
                *, scale: float, num_blocks: int):
    """One grid step of the FA-2 recurrence over a KV tile (tile-level
    online softmax — mathematically identical to the per-key loop)."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    valid = mask_ref[...]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(scores, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                       # rescale factor
    p = jnp.exp(scores - m_new[:, None])                  # (B, blk)
    p = jnp.where(valid, p, 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_new = acc_prev * alpha[:, None] + p @ v

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(j == num_blocks - 1)
    def _finalize():
        o_ref[...] = (acc_new / l_new[:, None]).astype(jnp.bfloat16)


@functools.partial(jax.jit, static_argnames=("scale", "block_k"))
def fa2_attention(q, k, v, mask=None, *, scale: float | None = None,
                  block_k: int = 64):
    """FA-2 attention for one head.  q: (B, d), k/v: (N, d), bf16 in/out."""
    b, d = q.shape
    n = k.shape[0]
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    if n % block_k != 0:
        raise ValueError(f"N={n} not divisible by block_k={block_k}")
    num_blocks = n // block_k
    if mask is None:
        mask = jnp.ones((b, n), dtype=jnp.bool_)

    kernel = functools.partial(_fa2_kernel, scale=scale, num_blocks=num_blocks)
    out_shapes = (
        jax.ShapeDtypeStruct((b, d), jnp.bfloat16),
        jax.ShapeDtypeStruct((b,), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.float32),
        jax.ShapeDtypeStruct((b, d), jnp.float32),
    )
    o, _, _, _ = pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((b, d), lambda j: (0, 0)),
            pl.BlockSpec((block_k, d), lambda j: (j, 0)),
            pl.BlockSpec((block_k, d), lambda j: (j, 0)),
            pl.BlockSpec((b, block_k), lambda j: (0, j)),
        ],
        out_specs=(
            pl.BlockSpec((b, d), lambda j: (0, 0)),
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((b, d), lambda j: (0, 0)),
        ),
        out_shape=out_shapes,
        interpret=True,
    )(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
      v.astype(jnp.bfloat16), mask)
    return o


def fa2_attention_mha(q, k, v, mask=None, *, scale: float | None = None,
                      block_k: int = 64):
    """Multi-head wrapper: q/k/v (H, T, d); mask (T, T) shared across heads."""
    f = functools.partial(fa2_attention, scale=scale, block_k=block_k)
    if mask is None:
        return jax.vmap(lambda a, b_, c: f(a, b_, c))(q, k, v)
    return jax.vmap(lambda a, b_, c: f(a, b_, c, mask))(q, k, v)

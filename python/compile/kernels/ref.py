"""Reference oracles for attention.

Tiers:

* :func:`exact_attention`   — textbook softmax attention in f32/f64 (the
  ground truth every other implementation is measured against).
* :func:`fa2_attention`     — FlashAttention-2 streaming recurrence (Alg. 2
  of the paper) in f32; numerically equal to exact attention up to float
  associativity.
* :func:`hfa_attention_int` — the **bit-exact** integer emulation of the
  H-FA hardware datapath (Q9.7 LNS accumulation, Mitchell, PWL), the same
  arithmetic the Pallas kernel and the rust ``attention::hfa`` model use.
* :func:`hfa_attention_emu` — an f64 *functional* emulation with one switch
  per approximation source (quant / mitchell / pwl), used for the Table III
  error-attribution study and the Fig. 5 Mitchell-input histogram.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import logmath as lm


# --------------------------------------------------------------------------
# Tier 0: exact attention
# --------------------------------------------------------------------------

def exact_attention(q: np.ndarray, k: np.ndarray, v: np.ndarray,
                    scale: float | None = None, dtype=np.float64) -> np.ndarray:
    """softmax(q k^T * scale) v.  q: (B, d), k/v: (N, d).  Returns (B, d)."""
    q = q.astype(dtype)
    k = k.astype(dtype)
    v = v.astype(dtype)
    if scale is None:
        scale = 1.0 / np.sqrt(q.shape[-1])
    s = (q @ k.T) * dtype(scale)
    s = s - s.max(axis=-1, keepdims=True)
    p = np.exp(s)
    p = p / p.sum(axis=-1, keepdims=True)
    return p @ v


# --------------------------------------------------------------------------
# Tier 1: FlashAttention-2 recurrence (Alg. 2), f32
# --------------------------------------------------------------------------

def fa2_attention(q: np.ndarray, k: np.ndarray, v: np.ndarray,
                  scale: float | None = None) -> np.ndarray:
    """Streaming FA-2 (delayed softmax division), one key per step, f32."""
    q = q.astype(np.float32)
    k = k.astype(np.float32)
    v = v.astype(np.float32)
    bq, d = q.shape
    n = k.shape[0]
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    scale = np.float32(scale)

    m = np.full(bq, -np.inf, dtype=np.float32)
    ell = np.zeros(bq, dtype=np.float32)
    o = np.zeros((bq, d), dtype=np.float32)
    for i in range(n):
        s = (q @ k[i]) * scale                       # (B,)
        m_new = np.maximum(m, s)
        alpha = np.exp(m - m_new)                     # rescale factor
        alpha[np.isnan(alpha)] = 0.0                  # -inf - -inf warmup
        beta = np.exp(s - m_new)
        ell = ell * alpha + beta
        o = o * alpha[:, None] + beta[:, None] * v[None, i]
        m = m_new
    return o / ell[:, None]


# --------------------------------------------------------------------------
# Tier 2: bit-exact H-FA integer emulation
# --------------------------------------------------------------------------

def _to_bf16_bits(x: np.ndarray) -> np.ndarray:
    return lm.f32_to_bf16_bits(np.ascontiguousarray(x, dtype=np.float32), xp=np)


def _finalize_log_triplet(s_o: np.ndarray, log_o: np.ndarray) -> np.ndarray:
    """LogDiv (Eq. 15) + log->bf16 conversion (Eq. 22) on an LNS triplet."""
    s_attn = s_o[:, 1:] ^ s_o[:, :1]
    log_attn = log_o[:, 1:] - log_o[:, :1]
    log_attn = np.where(log_o[:, 1:] <= lm.LOG_ZERO // 2,
                        np.int32(lm.LOG_ZERO), log_attn).astype(np.int32)
    bits = lm.log_q7_to_bf16_bits(s_attn, log_attn, xp=np)
    return lm.bf16_bits_to_f32(bits, xp=np)


def _hfa_partial_state(q, k, v, scale):
    """Inner loop of Alg. 2 without the final division — one KV block."""
    q = q.astype(np.float32)
    k = k.astype(np.float32)
    v = v.astype(np.float32)
    bq, d = q.shape
    n = k.shape[0]
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    scale = np.float32(scale)
    ones = np.ones((n, 1), dtype=np.float32)
    v_ext_bits = _to_bf16_bits(np.concatenate([ones, v], axis=1))   # (N, d+1)
    sv, logv = lm.bf16_bits_to_log_q7(v_ext_bits, xp=np)
    m = np.full(bq, -np.inf, dtype=np.float32)
    s_o = np.zeros((bq, d + 1), dtype=np.int32)
    log_o = np.full((bq, d + 1), lm.LOG_ZERO, dtype=np.int32)
    for i in range(n):
        s = (q @ k[i]) * scale                          # (B,) f32 scores
        m_new = np.maximum(m, s)
        dm_q = lm.quant_diff_q7(m - m_new, xp=np)       # (B,)
        ds_q = lm.quant_diff_q7(s - m_new, xp=np)       # (B,)
        a = lm.shift_log(log_o, dm_q[:, None], xp=np)   # (B, d+1)
        b = lm.shift_log(logv[None, i, :], ds_q[:, None], xp=np)
        s_o, log_o = lm.lns_add(s_o, a,
                                np.broadcast_to(sv[i], (bq, d + 1)), b, xp=np)
        m = m_new
    return m, s_o, log_o


def hfa_attention_int(q: np.ndarray, k: np.ndarray, v: np.ndarray,
                      scale: float | None = None) -> np.ndarray:
    """Bit-exact Q9.7 LNS emulation of the H-FA FAU (Eqs. 14, 15, 17-19).

    Score path: f32 (q k^T * scale), running max in f32.
    Accumulation path: integer LNS on d+1 lanes (lane 0 is the ell
    sum-of-exponentials with V-element 1).  Returns f32 (bf16-valued).
    """
    _, s_o, log_o = _hfa_partial_state(q, k, v, scale)
    return _finalize_log_triplet(s_o, log_o)


def hfa_merge_int(state_a, state_b):
    """ACC-block merge (Eq. 16) of two partial (m, s, log) triplets, LNS."""
    m_a, s_a, log_a = state_a
    m_b, s_b, log_b = state_b
    m_n = np.maximum(m_a, m_b)
    da = lm.quant_diff_q7(m_a - m_n, xp=np)
    db = lm.quant_diff_q7(m_b - m_n, xp=np)
    a = lm.shift_log(log_a, da[:, None], xp=np)
    b = lm.shift_log(log_b, db[:, None], xp=np)
    s_n, log_n = lm.lns_add(s_a, a, s_b, b, xp=np)
    return m_n, s_n, log_n


def hfa_attention_int_blocked(q, k, v, num_blocks: int,
                              scale: float | None = None) -> np.ndarray:
    """2D parallel H-FA (Fig. 2): split KV into blocks, merge with Eq. 16."""
    n = k.shape[0]
    assert n % num_blocks == 0
    step = n // num_blocks
    states = [
        _hfa_partial_state(q, k[b * step:(b + 1) * step],
                           v[b * step:(b + 1) * step], scale)
        for b in range(num_blocks)
    ]
    acc = states[0]
    for st in states[1:]:
        acc = hfa_merge_int(acc, st)
    return _finalize_log_triplet(acc[1], acc[2])


# --------------------------------------------------------------------------
# Tier 3: functional f64 emulation with per-approximation switches
# --------------------------------------------------------------------------

@dataclass
class EmuConfig:
    """Ablation switches for the three H-FA error sources (Table III)."""
    quant: bool = True      # (a) Q9.7 fixed-point quantization + [-15,0] clamp
    mitchell: bool = True   # (b) log2(1 +- x) ~= +-x  (Eqs. 17, 18, 22)
    pwl: bool = True        # (c) 8-segment PWL for 2^-f  (Eq. 19)
    collect_mitchell: list | None = field(default=None)


def _q(x: np.ndarray, cfg: EmuConfig) -> np.ndarray:
    """Score-difference quantization (natural-log units -> log2 units)."""
    if cfg.quant:
        x = np.where(np.isnan(x), lm.CLAMP_LO, x)
        x = np.clip(x, lm.CLAMP_LO, 0.0)
        t = x.astype(np.float32) * lm.LOG2E_F32
        return np.floor(t.astype(np.float64) * lm.FRAC_ONE) / lm.FRAC_ONE
    x = np.where(np.isnan(x), -np.inf, x)
    return x.astype(np.float64) * np.float64(lm.LOG2E_F32)


def _log2_value(v_bits: np.ndarray, cfg: EmuConfig):
    """float -> log domain for the value vector (Eq. 18), f64 functional."""
    sign = ((v_bits >> 15) & 1).astype(np.int32)
    e = (v_bits >> 7) & 0xFF
    mant = (v_bits & 0x7F).astype(np.float64) / lm.FRAC_ONE
    is_zero = e == 0
    if cfg.mitchell:
        if cfg.collect_mitchell is not None:
            cfg.collect_mitchell.append(mant[~is_zero].ravel().copy())
        logv = (e - lm.BF16_BIAS).astype(np.float64) + mant
    else:
        logv = (e - lm.BF16_BIAS).astype(np.float64) + np.log2(1.0 + mant)
    logv = np.where(is_zero, -np.inf, logv)
    return sign, logv


def _pow2_neg(dist: np.ndarray, cfg: EmuConfig) -> np.ndarray:
    """2^-dist for dist >= 0, optionally via the 8-segment PWL (Eq. 19)."""
    dist = np.where(np.isfinite(dist), dist, 1e9)
    if not cfg.pwl:
        return np.power(2.0, -np.minimum(dist, 1000.0))
    p = np.floor(dist)
    f = dist - p
    j = np.minimum((f * 8).astype(np.int64), 7)
    y0 = np.power(2.0, -(j / 8.0))
    y1 = np.power(2.0, -((j + 1) / 8.0))
    y = y0 + (y1 - y0) * (f * 8.0 - j)
    return y * np.power(2.0, -np.minimum(p, 1000.0))


def _lns_add_f(sa, a, sb, b, cfg: EmuConfig):
    """Functional signed LNS add with switchable Mitchell/PWL."""
    d = np.abs(a - b)
    d = np.where(np.isnan(d), np.inf, d)
    x = _pow2_neg(d, cfg)
    mx = np.maximum(a, b)
    same = sa == sb
    if cfg.mitchell:
        if cfg.collect_mitchell is not None:
            finite = np.isfinite(d)
            cfg.collect_mitchell.append(x[finite].ravel().copy())
        delta = np.where(same, x, -x)
    else:
        delta = np.log2(np.maximum(np.where(same, 1.0 + x, 1.0 - x), 1e-300))
    l = mx + delta
    s = np.where(a > b, sa, sb)
    a_zero = np.isneginf(a)
    b_zero = np.isneginf(b)
    l = np.where(a_zero, b, np.where(b_zero, a, l))
    s = np.where(a_zero, sb, np.where(b_zero, sa, s))
    l = np.where(a_zero & b_zero, -np.inf, l)
    return s.astype(np.int32), l


def hfa_attention_emu(q, k, v, cfg: EmuConfig | None = None,
                      scale: float | None = None) -> np.ndarray:
    """f64 functional H-FA with ablation switches.  Returns (B, d) f64."""
    if cfg is None:
        cfg = EmuConfig()
    q = q.astype(np.float32)
    k = k.astype(np.float32)
    v = v.astype(np.float32)
    bq, d = q.shape
    n = k.shape[0]
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    scale = np.float32(scale)

    ones = np.ones((n, 1), dtype=np.float32)
    v_ext_bits = _to_bf16_bits(np.concatenate([ones, v], axis=1))
    sv, logv = _log2_value(v_ext_bits, cfg)

    m = np.full(bq, -np.inf, dtype=np.float32)
    s_o = np.zeros((bq, d + 1), dtype=np.int32)
    log_o = np.full((bq, d + 1), -np.inf, dtype=np.float64)

    for i in range(n):
        s = (q @ k[i]) * scale
        m_new = np.maximum(m, s)
        dm = _q((m - m_new).astype(np.float64), cfg)
        ds = _q((s - m_new).astype(np.float64), cfg)
        a = log_o + dm[:, None]
        b = logv[None, i, :] + ds[:, None]
        s_o, log_o = _lns_add_f(s_o, a,
                                np.broadcast_to(sv[i], (bq, d + 1)), b, cfg)
        m = m_new

    s_attn = s_o[:, 1:] ^ s_o[:, :1]
    log_attn = log_o[:, 1:] - log_o[:, :1]
    if cfg.mitchell:
        # Eq. 22: 2^(I+F) ~= 2^I (1+F) — the hardware back-conversion
        i_part = np.floor(log_attn)
        f_part = log_attn - i_part
        mag = np.power(2.0, i_part) * (1.0 + f_part)
    else:
        mag = np.power(2.0, log_attn)
    mag = np.where(np.isneginf(log_attn) | np.isnan(log_attn), 0.0, mag)
    return np.where(s_attn == 1, -mag, mag)

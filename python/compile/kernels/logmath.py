"""Bit-accurate emulation primitives for the H-FA fixed-point LNS datapath.

This file is the *executable specification* of the hardware arithmetic
described in Sections IV-V of the paper.  The rust crate
(`rust/src/arith/`) implements the same operations bit-for-bit; golden
vectors dumped by ``python/compile/goldens.py`` pin the two sides
together.

Number formats
--------------
* All logarithmic quantities are **Q9.7** fixed point stored in int32
  (value x 128): 9 integer bits (incl. sign) and 7 fraction bits, the
  format the paper derives from BFloat16 (8 exponent + 7 mantissa bits,
  plus one sign-extension bit).
* ``LOG_ZERO`` is the -inf sentinel for the logarithm of 0.
* PWL coefficients for 2^-f are Q14, derived from a closed-form f64
  expression so that python and rust compute identical tables.

Every function exists in two flavours:
* a jnp flavour (vectorised, traceable -> usable inside Pallas kernels
  under ``interpret=True``), and
* the same code also runs eagerly on numpy arrays for tests.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Format constants (mirrored in rust/src/arith/fix.rs — keep in sync)
# --------------------------------------------------------------------------

FRAC_BITS = 7                     # Q9.7: 7 fractional bits
FRAC_ONE = 1 << FRAC_BITS         # 128
FRAC_MASK = FRAC_ONE - 1          # 0x7f
BF16_BIAS = 127
LOG_ZERO = -(1 << 24)             # -inf sentinel, far below any reachable Q9.7
CLAMP_LO = -15.0                  # paper: score differences constrained to [-15, 0]
LOG2E_F32 = np.float32(1.4426950408889634)
PWL_SEGMENTS = 8
PWL_SEG_BITS = 3                  # log2(PWL_SEGMENTS)
PWL_IN_BITS = FRAC_BITS - PWL_SEG_BITS   # 4 low bits index within a segment
PWL_COEF_BITS = 14                # Q14 coefficients
MAX_SHIFT = 24                    # beyond this the Q7 result underflows to 0


def _round_half_away(x: float) -> int:
    """floor(x + 0.5) — identical in python and rust (no banker's rounding)."""
    return int(np.floor(x + 0.5))


def pwl_tables() -> tuple[np.ndarray, np.ndarray]:
    """Closed-form endpoint-interpolated PWL fit of 2^-x on [0,1), 8 segments.

    Returns (C0, C1) int32 arrays of length 8 in Q14 such that for a Q7
    fractional input f (0..127), with segment j = f >> 4 and u = f & 15:

        2^{-f/128} * 2^14  ~=  C0[j] - C1[j] * u
    """
    c0 = np.zeros(PWL_SEGMENTS, dtype=np.int64)
    c1 = np.zeros(PWL_SEGMENTS, dtype=np.int64)
    for j in range(PWL_SEGMENTS):
        y0 = 2.0 ** (-(j / 8.0))
        y1 = 2.0 ** (-((j + 1) / 8.0))
        c0[j] = _round_half_away(y0 * (1 << PWL_COEF_BITS))
        c1[j] = _round_half_away((y0 - y1) * (1 << PWL_COEF_BITS) / 16.0)
    return c0.astype(np.int32), c1.astype(np.int32)


PWL_C0, PWL_C1 = pwl_tables()


# --------------------------------------------------------------------------
# Primitive ops. `xp` is the array module: np for eager tests, jnp inside
# traced code. All integer work happens in int32.
# --------------------------------------------------------------------------

def pwl_pow2_neg_frac_q14(f, xp=jnp, tables=None):
    """Q14 approximation of 2^{-f/128} for f in [0, 128) (int32).

    ``tables`` lets Pallas kernels pass the (C0, C1) coefficient LUTs as
    kernel inputs (array constants cannot be captured in a pallas trace).
    """
    if tables is not None:
        c0, c1 = tables
    else:
        c0 = xp.asarray(PWL_C0, dtype=xp.int32)
        c1 = xp.asarray(PWL_C1, dtype=xp.int32)
    j = (f >> PWL_IN_BITS).astype(xp.int32)
    u = (f & ((1 << PWL_IN_BITS) - 1)).astype(xp.int32)
    return c0[j] - c1[j] * u


def bf16_bits_to_log_q7(bits, xp=jnp):
    """(sign, Q9.7 log2|v|) of a BFloat16 given its raw uint16 bits (Eq. 18).

    Mitchell: log2(2^{E-b}(1+M)) ~= (E-b) + M, computed implicitly by
    reinterpreting E.M as fixed point.  E == 0 (zero/subnormal) maps to the
    LOG_ZERO sentinel.
    """
    b = bits.astype(xp.int32)
    sign = (b >> 15) & 1
    exp_mant = b & 0x7FFF                      # E.M as Q8.7, biased
    logq = exp_mant - (BF16_BIAS << FRAC_BITS)  # subtract bias from integer part
    is_zero = (b & 0x7F80) == 0                # E == 0
    logq = xp.where(is_zero, xp.int32(LOG_ZERO), logq)
    return sign.astype(xp.int32), logq.astype(xp.int32)


def log_q7_to_bf16_bits(sign, logq, xp=jnp):
    """Inverse of the above (Eq. 22): Q9.7 log -> BFloat16 bits.

    I = floor(logq), F = frac(logq); bits = (s, I + bias, F).  Exponent
    underflow saturates to +-0, overflow saturates to the max finite value.
    """
    i_part = logq >> FRAC_BITS                 # arithmetic shift (floor)
    f_part = logq & FRAC_MASK
    ebits = i_part + BF16_BIAS
    underflow = (ebits <= 0) | (logq <= xp.int32(LOG_ZERO // 2))
    overflow = ebits >= 255
    bits = (sign << 15) | (ebits << FRAC_BITS) | f_part
    max_finite = (sign << 15) | (254 << FRAC_BITS) | FRAC_MASK
    bits = xp.where(overflow, max_finite, bits)
    bits = xp.where(underflow, sign << 15, bits)
    return bits.astype(xp.uint16) if xp is np else bits.astype(jnp.uint16)


def quant_diff_q7(dz, xp=jnp):
    """quant[(dz) * log2 e] for a (non-positive) f32 score difference.

    Clamp to [-15, 0] first (paper Section IV-B), multiply by log2(e) in
    f32, truncate (floor) to Q9.7.  NaN inputs (from -inf - -inf at warmup)
    are treated as the clamp floor.
    """
    dz = dz.astype(xp.float32)
    dz = xp.where(xp.isnan(dz), xp.float32(CLAMP_LO), dz)
    dz = xp.clip(dz, CLAMP_LO, 0.0)
    t = dz * LOG2E_F32
    return xp.floor(t * FRAC_ONE).astype(xp.int32)


def lns_add(sa, a, sb, b, xp=jnp, tables=None):
    """Signed LNS addition (Eq. 14/17): (sa,A) (+) (sb,B) -> (s, L).

    L = max(A,B) +- (PWL(2^-f) >> p) with Mitchell's log2(1 +- x) ~= +-x.
    Sign: A > B -> sa, else sb (Eq. 14d).  LOG_ZERO short-circuits.
    """
    a = a.astype(xp.int32)
    b = b.astype(xp.int32)
    a_is_zero = a <= xp.int32(LOG_ZERO // 2)
    b_is_zero = b <= xp.int32(LOG_ZERO // 2)

    d = xp.abs(a - b)
    p = d >> FRAC_BITS
    f = d & FRAC_MASK
    y_q14 = pwl_pow2_neg_frac_q14(f, xp=xp, tables=tables)
    shift = xp.minimum(p + (PWL_COEF_BITS - FRAC_BITS), MAX_SHIFT).astype(xp.int32)
    r_q7 = y_q14 >> shift

    mx = xp.maximum(a, b)
    same = (sa == sb)
    l_add = mx + r_q7
    l_sub = mx - r_q7
    l = xp.where(same, l_add, l_sub)
    s = xp.where(a > b, sa, sb).astype(xp.int32)

    # sentinel handling
    l = xp.where(a_is_zero, b, xp.where(b_is_zero, a, l))
    s = xp.where(a_is_zero, sb, xp.where(b_is_zero, sa, s))
    both = a_is_zero & b_is_zero
    l = xp.where(both, xp.int32(LOG_ZERO), l)
    s = xp.where(both, 0, s)
    return s.astype(xp.int32), l.astype(xp.int32)


def shift_log(logq, dq, xp=jnp):
    """logq + dq with LOG_ZERO propagation (multiply by 2^{dq} in LNS)."""
    out = logq + dq
    return xp.where(logq <= xp.int32(LOG_ZERO // 2), xp.int32(LOG_ZERO), out).astype(xp.int32)


def f32_to_bf16_bits(x, xp=jnp):
    """Round-to-nearest-even f32 -> bf16 raw bits (uint16-valued int32)."""
    xi = (
        x.view(np.uint32).astype(np.int64)
        if xp is np
        else jnp.asarray(jnp.float32(x)).view(jnp.uint32).astype(jnp.int64)
    )
    rounded = (xi + 0x7FFF + ((xi >> 16) & 1)) >> 16
    return rounded.astype(np.int32) if xp is np else rounded.astype(jnp.int32)


def bf16_bits_to_f32(bits, xp=jnp):
    """bf16 raw bits -> f32 value."""
    if xp is np:
        return (bits.astype(np.uint32) << 16).view(np.float32)
    return (bits.astype(jnp.uint32) << 16).view(jnp.float32)

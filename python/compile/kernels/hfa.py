"""H-FA Pallas kernel: hybrid float/log-domain FlashAttention-2 (the paper's
core contribution, Sections IV-V).

Score path (Q K^T, running max, score differences) in float; the fused
accumulation of the sum-of-exponentials and the output vector in Q9.7
fixed-point LNS with Mitchell's approximation and an 8-segment PWL for
2^-f — the same bit-exact arithmetic as ``logmath.py`` / ``ref.py`` /
``rust/src/arith``.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's FAU
streams one key per cycle from an SRAM KV buffer; here the Pallas grid
iterates over KV tiles (the BlockSpec expresses the HBM->VMEM schedule) and
an in-kernel ``fori_loop`` reproduces the per-key recurrence exactly.  The
triplet (m, sign, log|O|) is carried across grid steps in accumulator refs.
Always lowered with ``interpret=True`` — real-TPU Mosaic custom-calls are
not executable on the CPU PJRT plugin.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import logmath as lm

NEG_INF = -1e30  # python float: avoid captured-constant error in pallas


def _hfa_kernel(q_ref, k_ref, v_ref, mask_ref, c0_ref, c1_ref,
                o_ref, m_ref, sgn_ref, log_ref,
                *, scale: float, num_blocks: int, block_k: int):
    """One grid step: stream one KV tile through the log-domain FAU."""
    j = pl.program_id(0)
    tables = (c0_ref[...], c1_ref[...])   # PWL coefficient LUTs (Eq. 19)

    # ---- init accumulators at the first KV tile -------------------------
    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        sgn_ref[...] = jnp.zeros_like(sgn_ref)
        log_ref[...] = jnp.full_like(log_ref, lm.LOG_ZERO)

    q = q_ref[...].astype(jnp.float32)                    # (B, d)
    k = k_ref[...].astype(jnp.float32)                    # (blk, d)
    v = v_ref[...]                                        # (blk, d) bf16
    valid = mask_ref[...]                                 # (B, blk) bool

    # float score path (dot-product unit of the FAU)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (B, blk)
    scores = jnp.where(valid, scores, NEG_INF)

    # value vector + prepended 1-lane (ell), converted to LNS once per tile
    ones = jnp.ones((v.shape[0], 1), dtype=v.dtype)
    v_ext = jnp.concatenate([ones, v], axis=1)            # (blk, d+1)
    v_bits = jax.lax.bitcast_convert_type(v_ext, jnp.uint16)
    sv_t, logv_t = lm.bf16_bits_to_log_q7(v_bits, xp=jnp)  # (blk, d+1)

    def body(i, carry):
        m, sgn, log_o = carry
        s = jax.lax.dynamic_index_in_dim(scores, i, axis=1, keepdims=False)
        msk = jax.lax.dynamic_index_in_dim(valid, i, axis=1, keepdims=False)
        sv = jax.lax.dynamic_index_in_dim(sv_t, i, axis=0, keepdims=False)
        logv = jax.lax.dynamic_index_in_dim(logv_t, i, axis=0, keepdims=False)

        m_new = jnp.where(msk, jnp.maximum(m, s), m)
        dm_q = lm.quant_diff_q7(m - m_new, xp=jnp)         # (B,)
        ds_q = lm.quant_diff_q7(s - m_new, xp=jnp)         # (B,)
        a = lm.shift_log(log_o, dm_q[:, None], xp=jnp)     # (B, d+1)
        b = lm.shift_log(logv[None, :], ds_q[:, None], xp=jnp)
        b = jnp.where(msk[:, None], b, jnp.int32(lm.LOG_ZERO))
        sv_b = jnp.broadcast_to(sv[None, :], sgn.shape)
        sgn_n, log_n = lm.lns_add(sgn, a, sv_b, b, xp=jnp, tables=tables)
        return m_new, sgn_n, log_n

    carry = (m_ref[...], sgn_ref[...], log_ref[...])
    m, sgn, log_o = jax.lax.fori_loop(0, block_k, body, carry)
    m_ref[...] = m
    sgn_ref[...] = sgn
    log_ref[...] = log_o

    # ---- LogDiv + back-conversion at the last KV tile (Eqs. 15, 22) -----
    @pl.when(j == num_blocks - 1)
    def _finalize():
        s_attn = sgn[:, 1:] ^ sgn[:, :1]
        log_attn = log_o[:, 1:] - log_o[:, :1]
        log_attn = jnp.where(log_o[:, 1:] <= jnp.int32(lm.LOG_ZERO // 2),
                             jnp.int32(lm.LOG_ZERO), log_attn)
        bits = lm.log_q7_to_bf16_bits(s_attn, log_attn, xp=jnp)
        o_ref[...] = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)


@functools.partial(jax.jit, static_argnames=("scale", "block_k"))
def hfa_attention(q, k, v, mask=None, *, scale: float | None = None,
                  block_k: int = 64):
    """H-FA attention for one head.  q: (B, d), k/v: (N, d), bf16 in/out.

    ``mask``: optional (B, N) bool, True = attend.  ``block_k`` is the KV
    tile streamed per grid step (the FAU's KV sub-block depth).
    """
    b, d = q.shape
    n = k.shape[0]
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    if n % block_k != 0:
        raise ValueError(f"N={n} not divisible by block_k={block_k}")
    num_blocks = n // block_k
    if mask is None:
        mask = jnp.ones((b, n), dtype=jnp.bool_)

    kernel = functools.partial(_hfa_kernel, scale=scale,
                               num_blocks=num_blocks, block_k=block_k)
    out_shapes = (
        jax.ShapeDtypeStruct((b, d), jnp.bfloat16),       # attention out
        jax.ShapeDtypeStruct((b,), jnp.float32),          # m carry
        jax.ShapeDtypeStruct((b, d + 1), jnp.int32),      # sign carry
        jax.ShapeDtypeStruct((b, d + 1), jnp.int32),      # log|O| carry
    )
    grid = (num_blocks,)
    o, _, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda j: (0, 0)),
            pl.BlockSpec((block_k, d), lambda j: (j, 0)),
            pl.BlockSpec((block_k, d), lambda j: (j, 0)),
            pl.BlockSpec((b, block_k), lambda j: (0, j)),
            pl.BlockSpec((lm.PWL_SEGMENTS,), lambda j: (0,)),
            pl.BlockSpec((lm.PWL_SEGMENTS,), lambda j: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((b, d), lambda j: (0, 0)),
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((b, d + 1), lambda j: (0, 0)),
            pl.BlockSpec((b, d + 1), lambda j: (0, 0)),
        ),
        out_shape=out_shapes,
        interpret=True,
    )(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
      v.astype(jnp.bfloat16), mask,
      jnp.asarray(lm.PWL_C0, jnp.int32), jnp.asarray(lm.PWL_C1, jnp.int32))
    return o


def hfa_attention_mha(q, k, v, mask=None, *, scale: float | None = None,
                      block_k: int = 64):
    """Multi-head wrapper: q/k/v (H, T, d); mask (T, T) shared across heads."""
    f = functools.partial(hfa_attention, scale=scale, block_k=block_k)
    if mask is None:
        return jax.vmap(lambda a, b_, c: f(a, b_, c))(q, k, v)
    return jax.vmap(lambda a, b_, c: f(a, b_, c, mask))(q, k, v)

"""L2: tiny GPT-style transformer whose attention layer calls the L1 kernels.

The model is deliberately small (the paper's accuracy study uses pretrained
LLMs; here the LM is trained from scratch at artifact-build time — see
DESIGN.md §Substitutions) but structurally standard: token+position
embeddings, pre-LN blocks with multi-head causal self-attention and a GELU
MLP, weight-tied LM head.

``attn_impl`` selects the attention kernel:
  * ``exact`` — f32 softmax attention (training / oracle path),
  * ``fa2``   — the all-float FlashAttention-2 Pallas kernel (BF16),
  * ``hfa``   — the hybrid float/log-domain H-FA Pallas kernel (BF16).
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fa2 as fa2_kernel
from .kernels import hfa as hfa_kernel


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 64
    d_model: int = 64
    n_head: int = 2
    n_layer: int = 2
    seq_len: int = 128

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head

    def to_file(self, path: str) -> None:
        with open(path, "w") as f:
            for k, v in asdict(self).items():
                f.write(f"{k}={v}\n")


# The three model sizes of the Table-II study (DESIGN.md §6).
SIZES = {
    "s0": ModelConfig("s0", d_model=32, n_head=1, n_layer=1),
    "s1": ModelConfig("s1", d_model=64, n_head=2, n_layer=2),
    "s2": ModelConfig("s2", d_model=128, n_head=2, n_layer=2),
}


def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 4 + 8 * cfg.n_layer)
    d, h = cfg.d_model, 4 * cfg.d_model
    std = 0.02
    p = {
        "tok_emb": jax.random.normal(ks[0], (cfg.vocab, d)) * std,
        "pos_emb": jax.random.normal(ks[1], (cfg.seq_len, d)) * std,
        "lnf_g": jnp.ones(d), "lnf_b": jnp.zeros(d),
    }
    for l in range(cfg.n_layer):
        b = 4 + 8 * l
        p[f"l{l}.ln1_g"] = jnp.ones(d)
        p[f"l{l}.ln1_b"] = jnp.zeros(d)
        p[f"l{l}.wq"] = jax.random.normal(ks[b + 0], (d, d)) * std
        p[f"l{l}.wk"] = jax.random.normal(ks[b + 1], (d, d)) * std
        p[f"l{l}.wv"] = jax.random.normal(ks[b + 2], (d, d)) * std
        p[f"l{l}.wo"] = jax.random.normal(ks[b + 3], (d, d)) * std / np.sqrt(2 * cfg.n_layer)
        p[f"l{l}.ln2_g"] = jnp.ones(d)
        p[f"l{l}.ln2_b"] = jnp.zeros(d)
        p[f"l{l}.w1"] = jax.random.normal(ks[b + 4], (d, h)) * std
        p[f"l{l}.b1"] = jnp.zeros(h)
        p[f"l{l}.w2"] = jax.random.normal(ks[b + 5], (h, d)) * std / np.sqrt(2 * cfg.n_layer)
        p[f"l{l}.b2"] = jnp.zeros(d)
    return {k: v.astype(jnp.float32) for k, v in p.items()}


def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(x, p, l, cfg: ModelConfig, attn_impl: str):
    """Multi-head causal self-attention.  x: (T, D) -> (T, D)."""
    t, d = x.shape
    h, dh = cfg.n_head, cfg.d_head
    q = (x @ p[f"l{l}.wq"]).reshape(t, h, dh).transpose(1, 0, 2)  # (H,T,dh)
    k = (x @ p[f"l{l}.wk"]).reshape(t, h, dh).transpose(1, 0, 2)
    v = (x @ p[f"l{l}.wv"]).reshape(t, h, dh).transpose(1, 0, 2)
    causal = jnp.tril(jnp.ones((t, t), dtype=jnp.bool_))

    if attn_impl == "exact":
        s = jnp.einsum("hqd,hkd->hqk", q, k) / np.sqrt(dh)
        s = jnp.where(causal[None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("hqk,hkd->hqd", w, v)
    elif attn_impl == "fa2":
        o = fa2_kernel.fa2_attention_mha(q, k, v, causal).astype(jnp.float32)
    elif attn_impl == "hfa":
        o = hfa_kernel.hfa_attention_mha(q, k, v, causal).astype(jnp.float32)
    else:
        raise ValueError(f"unknown attn_impl {attn_impl!r}")
    return o.transpose(1, 0, 2).reshape(t, d) @ p[f"l{l}.wo"]


def forward_single(params, cfg: ModelConfig, tokens, attn_impl="exact"):
    """tokens: (T,) int32 -> logits (T, V) f32."""
    t = tokens.shape[0]
    x = params["tok_emb"][tokens] + params["pos_emb"][:t]
    for l in range(cfg.n_layer):
        a = _attention(_layer_norm(x, params[f"l{l}.ln1_g"], params[f"l{l}.ln1_b"]),
                       params, l, cfg, attn_impl)
        x = x + a
        hdn = _layer_norm(x, params[f"l{l}.ln2_g"], params[f"l{l}.ln2_b"])
        hdn = jax.nn.gelu(hdn @ params[f"l{l}.w1"] + params[f"l{l}.b1"])
        x = x + hdn @ params[f"l{l}.w2"] + params[f"l{l}.b2"]
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["tok_emb"].T


def forward(params, cfg: ModelConfig, tokens, attn_impl="exact"):
    """tokens: (B, T) int32 -> logits (B, T, V) f32."""
    return jax.vmap(lambda tk: forward_single(params, cfg, tk, attn_impl))(tokens)


# --------------------------------------------------------------------------
# Weight (de)serialization — flat f32 .bin + line-based manifest, read by
# rust/src/model/weights.rs
# --------------------------------------------------------------------------

def save_params(params: dict, cfg: ModelConfig, out_dir: str) -> None:
    import os
    os.makedirs(out_dir, exist_ok=True)
    names = sorted(params.keys())
    offset = 0
    chunks = []
    with open(f"{out_dir}/manifest.txt", "w") as mf:
        mf.write("# name|shape(comma-sep)|offset(floats)|count\n")
        for n in names:
            a = np.asarray(params[n], dtype="<f4")
            shape = ",".join(map(str, a.shape))
            mf.write(f"{n}|{shape}|{offset}|{a.size}\n")
            chunks.append(a.ravel())
            offset += a.size
    np.concatenate(chunks).tofile(f"{out_dir}/weights.bin")
    cfg.to_file(f"{out_dir}/config.txt")


def load_params(out_dir: str) -> tuple[dict, ModelConfig]:
    cfg_kv = {}
    with open(f"{out_dir}/config.txt") as f:
        for line in f:
            k, v = line.strip().split("=")
            cfg_kv[k] = v if k == "name" else int(v)
    cfg = ModelConfig(**cfg_kv)
    flat = np.fromfile(f"{out_dir}/weights.bin", dtype="<f4")
    params = {}
    with open(f"{out_dir}/manifest.txt") as f:
        for line in f:
            if line.startswith("#"):
                continue
            n, shape, off, cnt = line.strip().split("|")
            shape = tuple(int(s) for s in shape.split(",") if s)
            params[n] = jnp.asarray(flat[int(off):int(off) + int(cnt)].reshape(shape))
    return params, cfg

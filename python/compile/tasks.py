"""Synthetic benchmark task families for the LLM-accuracy study.

Substitute for the paper's MMLU / GPQA / SWAG / GSM8K / XCOPA evaluation
(Section VI-A): the sandbox has no HuggingFace weights or network, so we
train a tiny LM from scratch on a mixture of five procedurally generated
task families and evaluate it exactly the way lm-evaluation-harness scores
multiple-choice tasks — the correct continuation must out-rank three
distractor options in the model's logits.

Families (each with 4 difficulty variants -> the 20-task "Table I" grid):

* ``copy_last``  — recall the most recent symbol of a list.
* ``induction``  — induction-head pattern: ``... a b ... a -> b``.
* ``assoc``      — key/value recall from an association list.
* ``maxsym``     — report the largest symbol of a list (symbols ordered).
* ``modsum``     — sum a list of digits mod 10 (tiny GSM8K stand-in).

Token map (vocab = 64): 0 PAD, 1 SEP, 2 Q, 3 A, 4..53 symbols, 54..63 digits.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

VOCAB = 64
PAD, SEP, QTOK, ATOK = 0, 1, 2, 3
SYM_BASE, NUM_SYMS = 4, 50
DIG_BASE, NUM_DIGS = 54, 10

FAMILIES = ("copy_last", "induction", "assoc", "maxsym", "modsum")
# 4 difficulty variants per family (the per-variant int is the "length" knob)
VARIANTS = {
    "copy_last": (4, 8, 12, 16),
    "induction": (6, 10, 14, 18),
    "assoc": (2, 3, 4, 5),
    "maxsym": (4, 6, 8, 10),
    "modsum": (2, 3, 4, 5),
}


@dataclass
class TaskInstance:
    prompt: list[int]      # token ids, ends right before the answer position
    options: list[int]     # 4 candidate answer tokens (options[answer] correct)
    answer: int            # index into options


def _symbols(rng: np.random.Generator, n: int, replace=True) -> np.ndarray:
    return SYM_BASE + rng.choice(NUM_SYMS, size=n, replace=replace)


def _distract(rng: np.random.Generator, correct: int, pool_base: int,
              pool_n: int) -> TaskInstance | tuple[list[int], int]:
    """Build a 4-way option set around ``correct`` from the given pool."""
    opts = {correct}
    while len(opts) < 4:
        opts.add(int(pool_base + rng.integers(pool_n)))
    opts = list(opts)
    rng.shuffle(opts)
    return opts, opts.index(correct)


def gen_copy_last(rng, k: int) -> TaskInstance:
    xs = _symbols(rng, k)
    correct = int(xs[-1])
    opts, ans = _distract(rng, correct, SYM_BASE, NUM_SYMS)
    return TaskInstance([QTOK, *map(int, xs), ATOK], opts, ans)


def gen_induction(rng, g: int) -> TaskInstance:
    """``.. a b ..filler.. a`` -> b.  g = total pattern length."""
    a, b = map(int, _symbols(rng, 2, replace=False))
    filler = [t for t in map(int, _symbols(rng, g)) if t not in (a, b)]
    pos = int(rng.integers(0, max(len(filler) - 1, 1)))
    seq = filler[:pos] + [a, b] + filler[pos:] + [a]
    opts, ans = _distract(rng, b, SYM_BASE, NUM_SYMS)
    return TaskInstance([QTOK, *seq, ATOK], opts, ans)


def gen_assoc(rng, npairs: int) -> TaskInstance:
    keys = _symbols(rng, npairs, replace=False)
    vals = _symbols(rng, npairs)
    i = int(rng.integers(npairs))
    prompt = [QTOK]
    for kk, vv in zip(keys, vals):
        prompt += [int(kk), int(vv)]
    prompt += [QTOK, int(keys[i]), ATOK]
    opts, ans = _distract(rng, int(vals[i]), SYM_BASE, NUM_SYMS)
    return TaskInstance(prompt, opts, ans)


def gen_maxsym(rng, k: int) -> TaskInstance:
    xs = _symbols(rng, k, replace=False)
    correct = int(xs.max())
    opts, ans = _distract(rng, correct, SYM_BASE, NUM_SYMS)
    return TaskInstance([QTOK, *map(int, xs), ATOK], opts, ans)


def gen_modsum(rng, k: int) -> TaskInstance:
    ds = rng.integers(0, 10, size=k)
    correct = int(DIG_BASE + ds.sum() % 10)
    prompt = [QTOK, *(int(DIG_BASE + d) for d in ds), ATOK]
    opts, ans = _distract(rng, correct, DIG_BASE, NUM_DIGS)
    return TaskInstance(prompt, opts, ans)


GENERATORS = {
    "copy_last": gen_copy_last,
    "induction": gen_induction,
    "assoc": gen_assoc,
    "maxsym": gen_maxsym,
    "modsum": gen_modsum,
}


def gen_task(rng, family: str, variant: int) -> TaskInstance:
    return GENERATORS[family](rng, variant)


def all_task_ids() -> list[tuple[str, int]]:
    """The 20 (family, variant) pairs of the Table-I grid."""
    return [(fam, var) for fam in FAMILIES for var in VARIANTS[fam]]


# --------------------------------------------------------------------------
# Training corpus: packed documents of prompt+answer from all families
# --------------------------------------------------------------------------

def make_corpus(rng, num_seqs: int, seq_len: int) -> np.ndarray:
    """(num_seqs, seq_len) int32 of SEP-packed task documents."""
    out = np.full((num_seqs, seq_len), PAD, dtype=np.int32)
    ids = all_task_ids()
    for r in range(num_seqs):
        buf: list[int] = []
        while len(buf) < seq_len:
            fam, var = ids[rng.integers(len(ids))]
            t = gen_task(rng, fam, var)
            buf += t.prompt + [t.options[t.answer], SEP]
        out[r] = buf[:seq_len]
    return out


# --------------------------------------------------------------------------
# Eval-file serialization (read by rust/src/evalsuite)
# --------------------------------------------------------------------------

def write_eval_file(path: str, tasks: list[TaskInstance]) -> None:
    with open(path, "w") as f:
        f.write("# prompt tokens|4 option tokens|answer index\n")
        for t in tasks:
            f.write(" ".join(map(str, t.prompt)) + "|"
                    + " ".join(map(str, t.options)) + f"|{t.answer}\n")


def gen_eval_files(out_dir: str, num_per_task: int = 100,
                   seed: int = 12345) -> list[str]:
    import os
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for fam, var in all_task_ids():
        rng = np.random.default_rng(seed + hash((fam, var)) % 100000)
        tasks = [gen_task(rng, fam, var) for _ in range(num_per_task)]
        p = f"{out_dir}/{fam}_{var}.txt"
        write_eval_file(p, tasks)
        paths.append(p)
    return paths

"""AOT artifact builder: python runs ONCE here, never on the request path.

Emits into ``artifacts/``:
  * ``hlo/``      — HLO **text** modules (kernel-only attention + full tiny-LM
    forwards with weights baked as constants) loadable by the rust PJRT
    runtime.  Text, not serialized protos: jax >= 0.5 emits 64-bit
    instruction ids that xla_extension 0.5.1 rejects; the text parser
    reassigns ids (see /opt/xla-example/README.md).
  * ``models/<size>/`` — trained weights (flat f32 bin + manifest) for the
    rust-native inference engine.
  * ``eval/``     — synthetic benchmark task files (Table I/II substitutes).
  * ``golden/``   — golden vectors pinning rust arithmetic to the python spec.
  * ``.stamp``    — build marker for make.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import goldens, model, tasks, train
from .kernels import fa2 as fa2_kernel
from .kernels import hfa as hfa_kernel


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True: the tiny-LM weights are baked into the
    # module; the default printer elides them as `constant({...})` which
    # the rust-side HLO text parser cannot reconstruct.
    return comp.as_hlo_text(True)


def write_hlo(path: str, fn, *specs) -> None:
    t0 = time.time()
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] {path}  ({len(text)/1e3:.0f} kB, {time.time()-t0:.1f}s)")


def build_attention_kernels(hlo_dir: str) -> None:
    """Standalone attention executables for the serving path.

    Shapes follow the paper's accelerator configuration: N = 1024 keys
    (four 256-row KV sub-blocks), head dims 32/64; plus a small d=32
    variant for quick tests.  B is the query batch the coordinator forms.
    """
    configs = [
        ("fa2", 32, 256, 8), ("hfa", 32, 256, 8),
        ("fa2", 64, 1024, 16), ("hfa", 64, 1024, 16),
        ("fa2", 128, 1024, 16), ("hfa", 128, 1024, 16),
    ]
    for kind, d, n, b in configs:
        kfn = fa2_kernel.fa2_attention if kind == "fa2" else hfa_kernel.hfa_attention
        fn = lambda q, k, v, _kfn=kfn: (_kfn(q, k, v),)
        sq = jax.ShapeDtypeStruct((b, d), jnp.bfloat16)
        skv = jax.ShapeDtypeStruct((n, d), jnp.bfloat16)
        write_hlo(f"{hlo_dir}/attn_{kind}_d{d}_n{n}_b{b}.hlo.txt", fn, sq, skv, skv)


def build_model_hlos(hlo_dir: str, sizes: list[str], models_dir: str) -> None:
    """Full-model forwards with baked weights, one per (size, attn_impl)."""
    for size in sizes:
        params, cfg = model.load_params(f"{models_dir}/{size}")
        impls = ["fa2", "hfa", "exact"] if size == "s1" else ["fa2", "hfa"]
        for impl in impls:
            fn = (lambda toks, _p=params, _c=cfg, _i=impl:
                  (model.forward(_p, _c, toks, attn_impl=_i),))
            spec = jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32)
            write_hlo(f"{hlo_dir}/model_{size}_{impl}.hlo.txt", fn, spec)


def main() -> None:
    ap = argparse.ArgumentParser(description="H-FA AOT artifact builder")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="s0,s1,s2")
    ap.add_argument("--skip-train", action="store_true",
                    help="fail instead of training if weights are missing")
    ap.add_argument("--only", default="",
                    help="comma-set of phases: train,eval,golden,kernels,models")
    args = ap.parse_args()

    out = args.out_dir
    sizes = [s for s in args.sizes.split(",") if s]
    phases = set(args.only.split(",")) if args.only else {
        "train", "eval", "golden", "kernels", "models"}
    os.makedirs(f"{out}/hlo", exist_ok=True)
    os.makedirs(f"{out}/models", exist_ok=True)

    if "train" in phases:
        for size in sizes:
            mdir = f"{out}/models/{size}"
            if os.path.exists(f"{mdir}/weights.bin"):
                print(f"[aot] {mdir} exists — skipping training")
                continue
            if args.skip_train:
                raise SystemExit(f"missing weights for {size} and --skip-train given")
            print(f"[aot] training {size} ...")
            train.train_and_save(model.SIZES[size], mdir, seed=0)

    if "eval" in phases:
        paths = tasks.gen_eval_files(f"{out}/eval", num_per_task=100)
        print(f"[aot] wrote {len(paths)} eval task files")

    if "golden" in phases:
        goldens.dump_all(f"{out}/golden")

    if "kernels" in phases:
        build_attention_kernels(f"{out}/hlo")

    if "models" in phases:
        build_model_hlos(f"{out}/hlo", sizes, f"{out}/models")

    with open(f"{out}/.stamp", "w") as f:
        f.write(str(time.time()) + "\n")
    print("[aot] done")


if __name__ == "__main__":
    main()

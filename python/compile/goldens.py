"""Golden-vector dump: pins the rust arithmetic to the python spec.

Every primitive of the H-FA datapath gets a table of (input -> expected
output) pairs generated from the bit-exact python emulation; the rust test
suite (rust/tests/golden_replay.rs) replays them and asserts bit equality.
Whole-attention cases additionally record the f32 score matrix so the rust
LNS pipeline can be checked bit-exactly independent of dot-product
association order (see DESIGN.md §3).
"""

from __future__ import annotations

import os

import numpy as np

from .kernels import logmath as lm
from .kernels import ref


def _f32_bits(x: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(x, dtype=np.float32).view(np.uint32)


def dump_pwl(path: str) -> None:
    with open(path, "w") as f:
        f.write("# c0_q14 c1_q14 (8 segments of 2^-f PWL)\n")
        for c0, c1 in zip(lm.PWL_C0, lm.PWL_C1):
            f.write(f"{c0} {c1}\n")


def dump_log_conv(path: str, rng) -> None:
    """bf16 bits -> (sign, q7 log) including edge cases."""
    edge = [0x0000, 0x8000, 0x3F80, 0xBF80, 0x0080, 0x7F7F, 0xFF7F,
            0x0001, 0x4000, 0x3400, 0x7F80 - 1]
    rand = rng.integers(0, 1 << 16, size=2000).tolist()
    bits = np.array(edge + rand, dtype=np.int64).astype(np.int32)
    s, l = lm.bf16_bits_to_log_q7(bits, xp=np)
    with open(path, "w") as f:
        f.write("# bf16_bits sign log_q7\n")
        for b, ss, ll in zip(bits, s, l):
            f.write(f"{int(b) & 0xFFFF} {ss} {ll}\n")


def dump_back_conv(path: str, rng) -> None:
    """(sign, q7 log) -> bf16 bits, sweeping the reachable log range."""
    logs = np.concatenate([
        np.array([lm.LOG_ZERO, -(127 << 7), -(127 << 7) + 1, 0, 1, -1,
                  (128 << 7) - 1, (130 << 7), -(130 << 7)], dtype=np.int64),
        rng.integers(-(140 << 7), 130 << 7, size=2000),
    ]).astype(np.int32)
    signs = rng.integers(0, 2, size=logs.size).astype(np.int32)
    bits = lm.log_q7_to_bf16_bits(signs, logs, xp=np)
    with open(path, "w") as f:
        f.write("# sign log_q7 bf16_bits\n")
        for s, l, b in zip(signs, logs, bits):
            f.write(f"{s} {l} {int(b)}\n")


def dump_quant(path: str, rng) -> None:
    """f32 score difference -> q7 (clamp [-15,0], x log2e, floor)."""
    edge = np.array([0.0, -0.0, -1e-8, -1.0, -14.999, -15.0, -16.0, -1e30,
                     -np.inf, np.nan, 0.5, 3.0], dtype=np.float32)
    rand = (-rng.random(size=2000) * 20).astype(np.float32)
    x = np.concatenate([edge, rand])
    q = lm.quant_diff_q7(x, xp=np)
    with open(path, "w") as f:
        f.write("# f32_bits q7\n")
        for xb, qq in zip(_f32_bits(x), q):
            f.write(f"{int(xb)} {qq}\n")


def dump_lns_add(path: str, rng) -> None:
    n = 4000
    a = rng.integers(-(40 << 7), 40 << 7, size=n).astype(np.int32)
    b = rng.integers(-(40 << 7), 40 << 7, size=n).astype(np.int32)
    # inject sentinels and exact ties
    a[:50] = lm.LOG_ZERO
    b[25:75] = lm.LOG_ZERO
    b[100:150] = a[100:150]
    sa = rng.integers(0, 2, size=n).astype(np.int32)
    sb = rng.integers(0, 2, size=n).astype(np.int32)
    s, l = lm.lns_add(sa, a, sb, b, xp=np)
    with open(path, "w") as f:
        f.write("# sa a sb b -> s l\n")
        for row in zip(sa, a, sb, b, s, l):
            f.write(" ".join(map(str, map(int, row))) + "\n")


def dump_attn_case(path: str, rng, b: int, n: int, d: int,
                   num_blocks: int = 1) -> None:
    """Whole-attention golden: inputs, scores, and expected output bits."""
    import jax.numpy as jnp
    bf = lambda x: np.asarray(jnp.asarray(x, jnp.bfloat16), np.float32)
    q = bf(rng.standard_normal((b, d)))
    k = bf(rng.standard_normal((n, d)))
    v = bf(rng.standard_normal((n, d)))
    scale = np.float32(1.0 / np.sqrt(d))
    scores = np.stack([(q.astype(np.float32) @ k[i]) * scale
                       for i in range(n)], axis=1)      # (B, N)
    if num_blocks == 1:
        out = ref.hfa_attention_int(q, k, v)
    else:
        out = ref.hfa_attention_int_blocked(q, k, v, num_blocks)
    out_bits = lm.f32_to_bf16_bits(out, xp=np)
    fa2 = ref.fa2_attention(q, k, v)
    with open(path, "w") as f:
        f.write(f"{b} {n} {d} {num_blocks}\n")
        for name, arr in [("q", _f32_bits(q)), ("k", _f32_bits(k)),
                          ("v", _f32_bits(v)), ("scores", _f32_bits(scores)),
                          ("out_bf16", out_bits.astype(np.int64)),
                          ("fa2_f32", _f32_bits(fa2.astype(np.float32)))]:
            f.write(name + ": " + " ".join(map(str, arr.ravel().tolist())) + "\n")


def dump_all(out_dir: str, seed: int = 7) -> None:
    os.makedirs(out_dir, exist_ok=True)
    rng = np.random.default_rng(seed)
    dump_pwl(f"{out_dir}/pwl_table.txt")
    dump_log_conv(f"{out_dir}/log_conv.txt", rng)
    dump_back_conv(f"{out_dir}/back_conv.txt", rng)
    dump_quant(f"{out_dir}/quant.txt", rng)
    dump_lns_add(f"{out_dir}/lns_add.txt", rng)
    dump_attn_case(f"{out_dir}/attn_case_small.txt", rng, b=2, n=16, d=8)
    dump_attn_case(f"{out_dir}/attn_case_mid.txt", rng, b=4, n=64, d=32)
    dump_attn_case(f"{out_dir}/attn_case_blocked.txt", rng, b=2, n=64, d=16,
                   num_blocks=4)
    print(f"[goldens] wrote golden vectors to {out_dir}")

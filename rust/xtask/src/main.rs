//! Repo automation (cargo-xtask pattern).  `cargo run -p xtask -- lint`
//! runs the concurrency-invariant linter over `rust/src`.
//!
//! The linter enforces the project's concurrency rules at the source
//! level — cheap, deterministic, and independent of any nightly tooling
//! (loom / Miri / TSan cover the *dynamic* side; this covers the rules a
//! dynamic tool cannot see):
//!
//! * `facade` — all synchronization primitives are imported through
//!   `crate::sync`; `std::sync` / `std::thread` appear nowhere else
//!   (including test code).  This is what makes the loom suite
//!   model-check the exact shipped implementations rather than a copy.
//! * `no-unwrap` — no `.unwrap()` / `.expect(` in non-test coordinator
//!   code (the serve loop, the continuous scheduler's slot table, the
//!   KV store).  A panicking worker strands its batch and a panicking
//!   scheduler strands every queue; every serve-path failure must flow
//!   through `ServeError` / poison-recovery instead.
//! * `ordering-comment` — every `Ordering::` use site in non-test code
//!   carries an `// ordering: <Ord> — rationale` comment on the same
//!   line or within the 4 preceding lines.  Keeps the release/acquire
//!   audit (EXPERIMENTS.md §Verification) from rotting.
//! * `lock-order` — coordinator locks are acquired in the documented
//!   order KvStore → Metrics → queues (`coordinator/protocol.rs` module
//!   docs), never reversed.  Tracked textually per scope via live
//!   `let`-bound guards.
//!
//! Suppress a single finding with a trailing `// lint:allow(<rule>)`
//! on the offending line.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(),
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint");
            ExitCode::FAILURE
        }
    }
}

/// One reported rule violation.
struct Finding {
    file: PathBuf,
    line: usize,
    rule: &'static str,
    msg: String,
}

fn lint() -> ExitCode {
    let manifest = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    let src = Path::new(&manifest).join("..").join("src");
    let mut files = Vec::new();
    collect_rs(&src, &mut files);
    files.sort();

    let mut findings = Vec::new();
    for f in &files {
        match std::fs::read_to_string(f) {
            Ok(text) => lint_file(f, &text, &mut findings),
            Err(e) => findings.push(Finding {
                file: f.clone(),
                line: 0,
                rule: "io",
                msg: format!("unreadable: {e}"),
            }),
        }
    }

    if findings.is_empty() {
        println!("lint: OK ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        let mut out = String::new();
        for f in &findings {
            let _ = writeln!(out, "{}:{}: [{}] {}", f.file.display(), f.line, f.rule, f.msg);
        }
        eprint!("{out}");
        eprintln!("lint: {} finding(s) in {} files", findings.len(), files.len());
        ExitCode::FAILURE
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// A source line split into its code text (string-literal bodies and
/// comments blanked out, byte positions preserved) and its comment text.
struct Line {
    code: String,
    comment: String,
}

/// Split `text` into per-line code/comment views with a small scanner
/// that understands line comments, nested block comments, string
/// literals (incl. raw strings), char literals, and lifetimes.
fn split_lines(text: &str) -> Vec<Line> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        Str,
        RawStr(usize), // number of #s
        Block(usize),  // nesting depth
    }
    let mut st = St::Code;
    let mut lines = Vec::new();
    for raw in text.lines() {
        let b: Vec<char> = raw.chars().collect();
        let mut code = String::with_capacity(b.len());
        let mut comment = String::new();
        let mut i = 0;
        while i < b.len() {
            match st {
                St::Code => {
                    let c = b[i];
                    if c == '/' && i + 1 < b.len() && b[i + 1] == '/' {
                        // line comment runs to EOL
                        comment.push_str(&raw[raw.char_indices().nth(i).map(|(o, _)| o).unwrap_or(0)..]);
                        while i < b.len() {
                            code.push(' ');
                            i += 1;
                        }
                    } else if c == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                        st = St::Block(1);
                        code.push(' ');
                        code.push(' ');
                        i += 2;
                    } else if c == '"' {
                        st = St::Str;
                        code.push('"');
                        i += 1;
                    } else if c == 'r'
                        && i + 1 < b.len()
                        && (b[i + 1] == '"' || b[i + 1] == '#')
                        && !prev_ident(&b, i)
                    {
                        // raw string r"..." / r#"..."#
                        let mut hashes = 0;
                        let mut j = i + 1;
                        while j < b.len() && b[j] == '#' {
                            hashes += 1;
                            j += 1;
                        }
                        if j < b.len() && b[j] == '"' {
                            st = St::RawStr(hashes);
                            for _ in i..=j {
                                code.push(' ');
                            }
                            i = j + 1;
                        } else {
                            code.push(c);
                            i += 1;
                        }
                    } else if c == '\'' {
                        // char literal vs lifetime: a char closes within
                        // a couple of chars, a lifetime never closes
                        let close = if i + 1 < b.len() && b[i + 1] == '\\' {
                            (i + 2..b.len().min(i + 8)).find(|&j| b[j] == '\'')
                        } else if i + 2 < b.len() && b[i + 2] == '\'' {
                            Some(i + 2)
                        } else {
                            None
                        };
                        if let Some(j) = close {
                            for _ in i..=j {
                                code.push(' ');
                            }
                            i = j + 1;
                        } else {
                            code.push('\''); // lifetime tick
                            i += 1;
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
                St::Str => {
                    let c = b[i];
                    if c == '\\' && i + 1 < b.len() {
                        code.push(' ');
                        code.push(' ');
                        i += 2;
                    } else if c == '"' {
                        st = St::Code;
                        code.push('"');
                        i += 1;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                St::RawStr(h) => {
                    if b[i] == '"' && (i + 1..=i + h).all(|j| j < b.len() && b[j] == '#') {
                        st = St::Code;
                        for _ in 0..=h {
                            code.push(' ');
                        }
                        i += 1 + h;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                St::Block(d) => {
                    if b[i] == '*' && i + 1 < b.len() && b[i + 1] == '/' {
                        st = if d == 1 { St::Code } else { St::Block(d - 1) };
                        code.push(' ');
                        code.push(' ');
                        i += 2;
                    } else if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                        st = St::Block(d + 1);
                        code.push(' ');
                        code.push(' ');
                        i += 2;
                    } else {
                        comment.push(b[i]);
                        code.push(' ');
                        i += 1;
                    }
                }
            }
        }
        // St::Str / St::RawStr / St::Block legitimately span lines in
        // Rust; keep the state for the next line.
        lines.push(Line { code, comment });
    }
    lines
}

fn prev_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

/// Lock classes in their documented acquisition order.
const LOCK_ORDER: [&str; 3] = ["KvStore", "Metrics", "queue"];

/// A live `let`-bound lock guard inside the current scope.
struct Guard {
    name: String,
    rank: usize,
    depth: i32,
    line: usize,
}

fn lint_file(path: &Path, text: &str, findings: &mut Vec<Finding>) {
    let rel = path.to_string_lossy().replace('\\', "/");
    let is_facade = rel.ends_with("/sync.rs") || rel.ends_with("src/sync.rs");
    let in_coordinator = rel.contains("/coordinator/");
    let raw_lines: Vec<&str> = text.lines().collect();
    let lines = split_lines(text);

    // Repo convention: the `#[cfg(test)] mod tests` block is the last
    // item of a file, so everything from its attribute on is test code.
    let test_start = lines
        .iter()
        .position(|l| l.code.trim() == "#[cfg(test)]")
        .unwrap_or(lines.len());

    let allowed = |idx: usize, rule: &str| -> bool {
        raw_lines
            .get(idx)
            .is_some_and(|r| r.contains(&format!("lint:allow({rule})")))
    };

    let mut depth: i32 = 0;
    let mut guards: Vec<Guard> = Vec::new();

    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        let in_test = idx >= test_start;
        let lineno = idx + 1;

        // facade: no std::sync / std::thread outside the facade module
        if !is_facade
            && (code.contains("std::sync") || code.contains("std::thread"))
            && !allowed(idx, "facade")
        {
            findings.push(Finding {
                file: path.into(),
                line: lineno,
                rule: "facade",
                msg: "import concurrency primitives through crate::sync, not std".into(),
            });
        }

        // no-unwrap: coordinator non-test code must not panic on Results
        if in_coordinator
            && !in_test
            && (code.contains(".unwrap()") || code.contains(".expect("))
            && !allowed(idx, "no-unwrap")
        {
            findings.push(Finding {
                file: path.into(),
                line: lineno,
                rule: "no-unwrap",
                msg: "serve paths must not panic; return ServeError or recover".into(),
            });
        }

        // ordering-comment: every atomic ordering site is documented.
        // The `// ordering:` marker may sit above the site separated by
        // at most 4 code lines; comment-only lines are free, so
        // multi-line rationales and multi-line statements both work.
        if !in_test && code.contains("Ordering::") && !allowed(idx, "ordering-comment") {
            let mut near = line.comment.contains("ordering:");
            let mut budget: i32 = 4;
            let mut j = idx;
            while !near && j > 0 && budget >= 0 {
                j -= 1;
                if lines[j].comment.contains("ordering:") {
                    near = true;
                    break;
                }
                let comment_only = lines[j].code.trim().is_empty() && !lines[j].comment.is_empty();
                if !comment_only {
                    budget -= 1;
                }
            }
            if !near {
                findings.push(Finding {
                    file: path.into(),
                    line: lineno,
                    rule: "ordering-comment",
                    msg: "atomic access without an `// ordering: <Ord> — why` comment nearby"
                        .into(),
                });
            }
        }

        // lock-order: textual live-guard tracking (coordinator only)
        if in_coordinator && !in_test {
            if code.contains(".lock()") && !allowed(idx, "lock-order") {
                let rank = classify_lock(&rel, code);
                if let Some(held) = guards.iter().find(|g| g.rank > rank) {
                    findings.push(Finding {
                        file: path.into(),
                        line: lineno,
                        rule: "lock-order",
                        msg: format!(
                            "acquires {} while holding {} (line {}); order is {}",
                            LOCK_ORDER[rank],
                            LOCK_ORDER[held.rank],
                            held.line,
                            LOCK_ORDER.join(" -> ")
                        ),
                    });
                }
                // only `let`-bound guards outlive the statement
                if let Some(name) = let_binding(code) {
                    guards.push(Guard { name, rank, depth, line: lineno });
                }
            }
            // explicit early release
            for g in 0..guards.len() {
                if code.contains(&format!("drop({})", guards[g].name)) {
                    guards.remove(g);
                    break;
                }
            }
        }

        for c in code.chars() {
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    guards.retain(|g| g.depth < depth + 1);
                }
                _ => {}
            }
        }
    }
}

/// Rank a `.lock()` call site in the documented order
/// KvStore(0) -> Metrics(1) -> queues/other(2).
fn classify_lock(rel_path: &str, code: &str) -> usize {
    if rel_path.ends_with("kvstore.rs") {
        0
    } else if rel_path.ends_with("metrics.rs") || code.contains("latencies") || code.contains("metrics.") {
        1
    } else {
        2
    }
}

/// `let [mut] <name> = ....lock()...` -> the bound guard name.
fn let_binding(code: &str) -> Option<String> {
    let t = code.trim_start();
    let rest = t.strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let name: String = rest
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_src(rel: &str, src: &str) -> Vec<String> {
        let mut f = Vec::new();
        lint_file(Path::new(rel), src, &mut f);
        f.into_iter().map(|x| format!("{}:{}", x.rule, x.line)).collect()
    }

    #[test]
    fn facade_rule_flags_std_sync_and_thread() {
        let hits = lint_src(
            "src/runtime/pool.rs",
            "use std::sync::Mutex;\nuse crate::sync::Arc;\nfn f() { std::thread::spawn(|| {}); }\n",
        );
        assert_eq!(hits, vec!["facade:1", "facade:3"]);
    }

    #[test]
    fn facade_rule_ignores_comments_strings_and_the_facade_itself() {
        assert!(lint_src("src/a.rs", "// std::sync is banned\nlet s = \"std::thread\";\n").is_empty());
        assert!(lint_src("src/sync.rs", "pub use std::sync::Arc;\n").is_empty());
    }

    #[test]
    fn no_unwrap_rule_is_coordinator_and_non_test_only() {
        let src = "fn f() { x.unwrap(); }\n#[cfg(test)]\nmod tests { fn g() { y.unwrap(); } }\n";
        assert_eq!(lint_src("src/coordinator/server.rs", src), vec!["no-unwrap:1"]);
        assert!(lint_src("src/attention/kernel.rs", src).is_empty());
    }

    #[test]
    fn ordering_comment_window_is_same_line_or_four_code_lines_above() {
        let ok = "// ordering: Relaxed — counter\nx.load(Ordering::Relaxed);\n";
        assert!(lint_src("src/a.rs", ok).is_empty());
        let far = "// ordering: Relaxed\n\n\n\n\n\nx.load(Ordering::Relaxed);\n";
        assert_eq!(lint_src("src/a.rs", far), vec!["ordering-comment:7"]);
        let inline = "x.store(1, Ordering::SeqCst); // ordering: SeqCst — gate\n";
        assert!(lint_src("src/a.rs", inline).is_empty());
        // comment-only lines don't consume the window: a multi-line
        // rationale block followed by a multi-line statement still passes
        let block = "// ordering: Relaxed — stats\n// line two of the why\n// line three\nm\n    .counter\n    .fetch_add(1, Ordering::Relaxed);\n";
        assert!(lint_src("src/a.rs", block).is_empty(), "{:?}", lint_src("src/a.rs", block));
        let undocumented = "fn f() {\n    x.load(Ordering::Relaxed);\n}\n";
        assert_eq!(lint_src("src/a.rs", undocumented), vec!["ordering-comment:2"]);
    }

    #[test]
    fn lock_order_flags_reversed_acquisition() {
        // holding a queue guard, then locking the KvStore: reversed
        let src = "fn f(&self) {\n    let q = self.inner.lock();\n    let k = kv.inner.lock();\n}\n";
        let hits = lint_src("src/coordinator/server.rs", src);
        assert!(hits.is_empty(), "same-file ranks are both queue: {hits:?}");
        let src_kv = "fn f(&self) {\n    let q = queue.lock();\n    let m = metrics.latencies_us.lock();\n}\n";
        assert_eq!(lint_src("src/coordinator/server.rs", src_kv), vec!["lock-order:3"]);
    }

    #[test]
    fn lock_order_guard_dies_at_scope_end_and_on_drop() {
        let scoped =
            "fn f(&self) {\n    {\n        let q = queue.lock();\n    }\n    let m = latencies.lock();\n}\n";
        assert!(lint_src("src/coordinator/server.rs", scoped).is_empty());
        let dropped =
            "fn f(&self) {\n    let q = queue.lock();\n    drop(q);\n    let m = latencies.lock();\n}\n";
        assert!(lint_src("src/coordinator/server.rs", dropped).is_empty());
    }

    #[test]
    fn scheduler_slot_table_is_covered_by_coordinator_rules() {
        // the continuous scheduler (coordinator/scheduler.rs) is serve
        // path: the coordinator-scoped rules must bind to it exactly as
        // they do to server.rs — no-unwrap on non-test code, documented
        // atomic orderings, and the KvStore -> Metrics -> queue order
        let rel = "src/coordinator/scheduler.rs";
        assert_eq!(
            lint_src(rel, "fn admit(&mut self) { self.slots.get(\"s\").unwrap(); }\n"),
            vec!["no-unwrap:1"]
        );
        assert_eq!(
            lint_src(rel, "fn hit(&self) { self.metrics.slot_hits.fetch_add(1, Ordering::Relaxed); }\n"),
            vec!["ordering-comment:1"]
        );
        assert_eq!(
            lint_src(
                rel,
                "fn f(&self) {\n    let q = queue.lock();\n    let m = metrics.latencies_us.lock();\n}\n"
            ),
            vec!["lock-order:3"]
        );
        // the scheduler's own #[cfg(test)] module keeps the usual exemption
        let test_src = "#[cfg(test)]\nmod tests { fn g() { sched().dispatch().unwrap(); } }\n";
        assert!(lint_src(rel, test_src).is_empty());
    }

    #[test]
    fn streaming_ingress_is_covered_by_coordinator_rules() {
        // the framed-socket front end (coordinator/ingress/) is serve
        // path: every file under it must bind to the coordinator-scoped
        // rules exactly like server.rs — no-unwrap on non-test code,
        // documented atomic orderings, and the lock order — and the
        // facade rule must hold even in its test code
        for rel in [
            "src/coordinator/ingress/mod.rs",
            "src/coordinator/ingress/conn.rs",
            "src/coordinator/ingress/stream.rs",
            "src/coordinator/ingress/frame.rs",
        ] {
            assert_eq!(
                lint_src(rel, "fn f(out: &WriteQueue<Frame>) { out.push(f, stall).unwrap(); }\n"),
                vec!["no-unwrap:1"],
                "{rel}"
            );
            assert_eq!(
                lint_src(rel, "fn f(&self) { self.dead.store(true, Ordering::Relaxed); }\n"),
                vec!["ordering-comment:1"],
                "{rel}"
            );
            assert_eq!(
                lint_src(
                    rel,
                    "fn f(&self) {\n    let q = queue.lock();\n    let m = metrics.latencies_us.lock();\n}\n"
                ),
                vec!["lock-order:3"],
                "{rel}"
            );
            // std::net is deliberately NOT facaded (loom has no sockets;
            // the ingress tick-polls its reads instead), but std::sync /
            // std::thread stay banned — even inside ingress test code
            assert!(lint_src(rel, "use std::net::TcpStream;\n").is_empty(), "{rel}");
            let test_src = "#[cfg(test)]\nmod tests { use std::sync::Mutex; }\n";
            assert_eq!(lint_src(rel, test_src), vec!["facade:2"], "{rel}");
        }
    }

    #[test]
    fn prefix_sharing_layer_is_covered_by_coordinator_rules() {
        // the prefix-index + refcount registry lives *inside* the
        // KvStore inner mutex (no second lock to order), so the store
        // keeps rank 0: taking it while a Metrics guard is live must
        // flag, and the publish path must therefore stay atomics-only
        let rel = "src/coordinator/kvstore.rs";
        assert_eq!(
            lint_src(
                rel,
                "fn f(&self) {\n    let m = metrics.latencies_us.lock();\n    let g = self.inner.lock();\n}\n"
            ),
            vec!["lock-order:3"]
        );
        // registry bookkeeping is serve path: no-unwrap + documented
        // orderings bind exactly as in server.rs
        assert_eq!(
            lint_src(rel, "fn f(&self) { self.inner.lock().chunk_refs.get(&p).unwrap(); }\n"),
            vec!["no-unwrap:1"]
        );
        assert_eq!(
            lint_src(rel, "fn f(&self, m: &Metrics) { m.kv_dedup_hits.fetch_add(1, Ordering::Relaxed); }\n"),
            vec!["ordering-comment:1"]
        );
        // chunk hashing lives in attention/prepared.rs — outside the
        // coordinator-scoped rules, but the facade ban still binds
        assert_eq!(
            lint_src("src/attention/prepared.rs", "use std::sync::Arc;\n"),
            vec!["facade:1"]
        );
        assert!(lint_src("src/attention/prepared.rs", "use crate::sync::Arc;\n").is_empty());
    }

    #[test]
    fn lint_allow_suppresses_a_single_line() {
        let src = "use std::sync::Mutex; // lint:allow(facade)\n";
        assert!(lint_src("src/a.rs", src).is_empty());
    }

    #[test]
    fn lifetimes_do_not_open_char_literals() {
        // if '\'' handling is wrong, the rest of the file becomes a
        // string and the std::thread below goes unseen
        let src = "fn f<'a>(x: &'a str) {}\nuse std::thread;\n";
        assert_eq!(lint_src("src/a.rs", src), vec!["facade:2"]);
    }
}

//! Regression: the chunked prepared-KV layout performs O(appended rows)
//! bytes of copying per decode step — never O(resident rows).  Counted
//! end-to-end with the process-wide `kv_copy_bytes` counter (the memory
//! -traffic companion of `value_conversion_count`): from-scratch builds
//! copy each row exactly once, clones move no row data, and a
//! copy-on-write append touches only the partially-filled tail chunk
//! plus the new rows, independent of how many filled chunks precede it.
//!
//! Kept as the sole test in this binary so the process-wide byte counter
//! sees no concurrent traffic from unrelated tests.

use hfa::attention::prepared::{kv_copy_bytes, row_bytes, PreparedKv};
use hfa::sync::Arc;
use hfa::coordinator::KvStore;
use hfa::proptest::Rng;
use hfa::Mat;

fn rand_kv(rng: &mut Rng, n: usize, d: usize) -> (Mat, Mat) {
    (
        Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
        Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
    )
}

#[test]
fn append_copy_traffic_tracks_appended_rows_not_resident() {
    // pin the pool before its first use: the process-wide counter must
    // see the same pool shape in every environment (local, CI, sanitizer
    // lanes) rather than a machine-sized one — set here, not via ambient
    // env, so the pin can't be forgotten by a new lane
    std::env::set_var("HFA_POOL_THREADS", "1");
    const D: usize = 8;
    let rb = row_bytes(D, D) as u64;
    let mut rng = Rng::new(20_260_728);

    // --- from-scratch build: each row copied exactly once ------------------
    let (k, v) = rand_kv(&mut rng, 96, D);
    let before = kv_copy_bytes();
    let kv = PreparedKv::with_block_rows(k.clone(), v.clone(), 16);
    assert_eq!(kv_copy_bytes() - before, 96 * rb, "build copies each row once");

    // --- clones move no row data ------------------------------------------
    let before = kv_copy_bytes();
    let shared = Arc::new(kv);
    let _arc_clone = shared.clone();
    let _table_clone = PreparedKv::clone(&shared);
    assert_eq!(kv_copy_bytes() - before, 0, "Arc/chunk-table clones copy no rows");

    // --- copy-on-write append at a chunk boundary: new rows only ----------
    // 96 rows = 6 full chunks of 16; the tail is full, so the append
    // opens a fresh (unshared) chunk and copies nothing resident
    let (k1, v1) = rand_kv(&mut rng, 1, D);
    let before = kv_copy_bytes();
    let grown = shared.appended(&k1, &v1);
    assert_eq!(kv_copy_bytes() - before, rb, "boundary append copies only the new row");

    // --- mid-chunk CoW append: tail rows + new rows, nothing else ---------
    // `grown` shares its 1-row tail with nobody yet; share it and append
    let grown = Arc::new(grown);
    let held = grown.clone(); // simulates an in-flight reader generation
    let (k2, v2) = rand_kv(&mut rng, 2, D);
    let before = kv_copy_bytes();
    let grown2 = grown.appended(&k2, &v2);
    assert_eq!(
        kv_copy_bytes() - before,
        3 * rb,
        "mid-chunk append copies the 1-row shared tail plus the 2 new rows"
    );
    assert_eq!(held.n(), 97, "snapshot generation untouched");
    assert_eq!(grown2.n(), 99);

    // --- per-token cost is independent of resident length -----------------
    // same tail phase (5 rows into a 16-row chunk), 10x the resident rows
    let (kb, vb) = rand_kv(&mut rng, 165, D); // 10 full chunks + 5
    let (ks, vs) = rand_kv(&mut rng, 21, D); //   1 full chunk + 5
    let big = Arc::new(PreparedKv::with_block_rows(kb, vb, 16));
    let small = Arc::new(PreparedKv::with_block_rows(ks, vs, 16));
    let (ka, va) = rand_kv(&mut rng, 1, D);
    let before = kv_copy_bytes();
    let _gb = big.appended(&ka, &va);
    let cost_big = kv_copy_bytes() - before;
    let before = kv_copy_bytes();
    let _gs = small.appended(&ka, &va);
    let cost_small = kv_copy_bytes() - before;
    assert_eq!(cost_big, cost_small, "append cost must not scale with resident rows");
    assert_eq!(cost_big, 5 * rb + rb, "5-row shared tail + 1 new row");

    // --- full serving path: KvStore decode loop ---------------------------
    // DEFAULT_BLOCK_ROWS chunks: a 520-row session (2 full chunks + 8-row
    // tail) and an 8-row session (tail only) pay the *same* per-token
    // copy cost — the monolithic layout this replaces paid 520 rows vs 8
    let (kl, vl) = rand_kv(&mut rng, 520, D);
    let long_store = KvStore::new(600, D, 1);
    long_store.put("s", kl, vl).unwrap();
    let (ksh, vsh) = rand_kv(&mut rng, 8, D);
    let short_store = KvStore::new(600, D, 1);
    short_store.put("s", ksh, vsh).unwrap();
    let (ka, va) = rand_kv(&mut rng, 1, D);
    let before = kv_copy_bytes();
    long_store.append("s", ka.clone(), va.clone()).unwrap();
    let cost_long = kv_copy_bytes() - before;
    let before = kv_copy_bytes();
    short_store.append("s", ka, va).unwrap();
    let cost_short = kv_copy_bytes() - before;
    assert_eq!(
        cost_long, cost_short,
        "store-level append traffic must be independent of the resident prefix"
    );
    assert_eq!(cost_long, 8 * rb + rb, "8-row shared tail + 1 new row");

    // --- decode-loop total: sum of tails, bounded by the chunk capacity ---
    let before = kv_copy_bytes();
    let steps = 12u64;
    for _ in 0..steps {
        let (k1, v1) = rand_kv(&mut rng, 1, D);
        long_store.append("s", k1, v1).unwrap();
    }
    let total = kv_copy_bytes() - before;
    // tail sizes 9..=20 rows; each step copies (tail + 1) rows
    let expect: u64 = (9..9 + steps).map(|t| (t + 1) * rb).sum();
    assert_eq!(total, expect, "decode-loop traffic = sum of (tail + appended) rows");
    assert_eq!(long_store.get("s").unwrap().prepared().n(), 533);

    // --- fork + shared-tail CoW: exact accounting under sharing -----------
    // "s" is 533 rows = 2 full chunks + a 21-row tail; forking moves no
    // row data, and the child's first append CoWs exactly the shared
    // tail (21 rows) plus the new row — the full prefix chunks stay
    // aliased, so the byte-budget charge is the child's delta only
    let before = kv_copy_bytes();
    long_store.fork("s", "f").unwrap();
    assert_eq!(kv_copy_bytes() - before, 0, "fork copies no rows");
    assert_eq!(long_store.shared_bytes(), 533 * rb as usize, "every chunk aliased");
    let (k1, v1) = rand_kv(&mut rng, 1, D);
    let before = kv_copy_bytes();
    let used_before = long_store.used_bytes();
    long_store.append("f", k1, v1).unwrap();
    assert_eq!(
        kv_copy_bytes() - before,
        21 * rb + rb,
        "forked append copies the 21-row shared tail + 1 new row"
    );
    assert_eq!(
        long_store.used_bytes() - used_before,
        22 * rb as usize,
        "only the child's diverged tail chunk is newly charged"
    );
    assert_eq!(long_store.get("s").unwrap().prepared().n(), 533, "parent untouched");
    assert_eq!(long_store.get("f").unwrap().prepared().n(), 534);
    assert_eq!(long_store.shared_bytes(), 512 * rb as usize, "full prefix still aliased");
}

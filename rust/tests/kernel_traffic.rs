//! Exact K/V stream-traffic pin for the query-tiled kernel: per-query
//! streaming (`qt = 1`, the seed behaviour) reads every resident row
//! once **per query**; a `QT`-tile reads it once **per tile** — a
//! `QT`-fold reduction, measured by the process-wide
//! `kernel::kv_stream_bytes` counter.
//!
//! Sole test in this binary: the counter is process-wide, so it can
//! only be pinned where no other test runs concurrently (same
//! convention as `append_traffic.rs` for the write-traffic counter).

use hfa::attention::kernel;
use hfa::attention::prepared::PreparedKv;
use hfa::proptest::Rng;
use hfa::Mat;

#[test]
fn tile_streams_each_kv_row_once_per_tile_not_per_query() {
    // pin the pool before its first use: the process-wide counter must
    // see the same pool shape in every environment (local, CI, sanitizer
    // lanes) rather than a machine-sized one — set here, not via ambient
    // env, so the pin can't be forgotten by a new lane
    std::env::set_var("HFA_POOL_THREADS", "1");
    let (b, n, d) = (16usize, 64usize, 8usize);
    let qt = kernel::DEFAULT_QUERY_TILE; // 8: b/qt = 2 tiles exactly
    let mut rng = Rng::new(20_260_728);
    let k = Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16();
    let v = Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16();
    // chunk capacity 16: the count-driven blocks below cross chunk
    // boundaries or align with them — traffic must not depend on that
    let kv = PreparedKv::with_block_rows(k, v, 16);
    let q = Mat::from_vec(b, d, rng.normal_vec(b * d)).round_bf16();
    let rsb = kernel::row_stream_bytes(d, d);

    // qt = 1: per-query streaming — B x N rows per call
    let s0 = kernel::kv_stream_bytes();
    let _ = kv.attention_tiled(&q, 1, None, 1);
    let per_query = kernel::kv_stream_bytes() - s0;
    assert_eq!(per_query, (b * n) as u64 * rsb, "qt=1 must stream B x N rows");

    // qt = QT: once per tile — ceil(B/QT) x N rows per call
    let s1 = kernel::kv_stream_bytes();
    let _ = kv.attention_tiled(&q, 1, None, qt);
    let tiled = kernel::kv_stream_bytes() - s1;
    assert_eq!(tiled, (b.div_ceil(qt) * n) as u64 * rsb, "qt={qt} must stream per tile");
    assert_eq!(per_query, qt as u64 * tiled, "traffic must drop exactly QT-fold");

    // the two-axis grid partitions the same plane: splitting the KV
    // axis into blocks moves no extra bytes
    let s2 = kernel::kv_stream_bytes();
    let _ = kv.attention_tiled(&q, 4, None, qt);
    assert_eq!(kernel::kv_stream_bytes() - s2, tiled, "blocked grid total traffic");

    // ragged everything: 5 queries (one short tile) x 3 ragged blocks
    // still covers each (tile, row) pair exactly once
    let q5 = Mat::from_vec(5, d, rng.normal_vec(5 * d)).round_bf16();
    let s3 = kernel::kv_stream_bytes();
    let _ = kv.attention_tiled(&q5, 3, None, 4);
    let ragged = kernel::kv_stream_bytes() - s3;
    assert_eq!(ragged, (5usize.div_ceil(4) * n) as u64 * rsb, "ragged tile/block traffic");

    // masked calls stay exact: rows [0, 10) are masked for every query
    // in the (single) tile, so they are never streamed at all
    let q4 = Mat::from_vec(4, d, rng.normal_vec(4 * d)).round_bf16();
    let mut mask = vec![true; 4 * n];
    for bi in 0..4 {
        for i in 0..10 {
            mask[bi * n + i] = false;
        }
    }
    let s4 = kernel::kv_stream_bytes();
    let _ = kv.full().partial_states(&q4, None, Some(&mask));
    assert_eq!(
        kernel::kv_stream_bytes() - s4,
        (n - 10) as u64 * rsb,
        "fully-masked rows must not be charged"
    );
}

//! Native tiny-LM engine vs the PJRT full-model artifacts + the accuracy
//! study machinery (Tables I/II/III substitutes).

use hfa::evalsuite::score::{evaluate_file, mean_logit_error};
use hfa::model::{AttnSelect, Transformer};

fn model_dir(size: &str) -> Option<std::path::PathBuf> {
    let d = hfa::artifacts_dir().join("models").join(size);
    if d.join("weights.bin").is_file() {
        Some(d)
    } else {
        eprintln!("WARNING: {} missing — run `make artifacts`", d.display());
        None
    }
}

#[test]
fn native_forward_matches_pjrt_exact_model() {
    let Some(dir) = model_dir("s1") else { return };
    let model = Transformer::load(&dir).expect("load s1");
    let reg = match hfa::runtime::ArtifactRegistry::open(&hfa::artifacts_dir()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("WARNING: {e}");
            return;
        }
    };
    let exe = reg.model("s1", "exact").expect("model_s1_exact artifact");

    let tokens: Vec<i32> = (0..128).map(|i| ((i * 7) % 60 + 4) as i32).collect();
    let native = model.forward(&tokens, AttnSelect::Exact, &mut None).unwrap();
    let pjrt = exe.run_model(&tokens).unwrap();
    assert_eq!(pjrt.len(), native.rows * native.cols);

    let mut worst = 0.0f32;
    for (a, b) in native.data.iter().zip(&pjrt) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst < 2e-2, "native vs PJRT logits diverge: max |d| = {worst}");
}

#[test]
fn hfa_attention_barely_moves_accuracy() {
    // the paper's core claim (Tables I/II): swapping FA-2 for H-FA does
    // not collapse task accuracy
    let Some(dir) = model_dir("s1") else { return };
    let model = Transformer::load(&dir).expect("load s1");
    let eval = hfa::artifacts_dir().join("eval");
    let file = eval.join("copy_last_4.txt");
    if !file.is_file() {
        eprintln!("WARNING: eval tasks missing");
        return;
    }
    let fa2 = evaluate_file(&model, &file, AttnSelect::Fa2, 40, &mut None).unwrap();
    let hfa_acc = evaluate_file(&model, &file, AttnSelect::Hfa, 40, &mut None).unwrap();
    assert!(fa2.pct() > 60.0, "model should have learned copy_last_4: {}", fa2.pct());
    let delta = (fa2.pct() - hfa_acc.pct()).abs();
    assert!(delta <= 15.0, "H-FA degraded accuracy too much: {} vs {}", hfa_acc.pct(), fa2.pct());
}

#[test]
fn mitchell_dominates_logit_error_in_model() {
    // Table III: disabling Mitchell removes most of the logit error
    let Some(dir) = model_dir("s0") else { return };
    let model = Transformer::load(&dir).expect("load s0");
    let file = hfa::artifacts_dir().join("eval").join("assoc_2.txt");
    if !file.is_file() {
        return;
    }
    let e_all = mean_logit_error(&model, &file, AttnSelect::HfaEmu(
        hfa::attention::hfa::EmuConfig::all_on()), 6).unwrap();
    let e_nomit = mean_logit_error(&model, &file, AttnSelect::HfaEmu(
        hfa::attention::hfa::EmuConfig { mitchell: false, ..Default::default() }), 6).unwrap();
    assert!(e_nomit < 0.5 * e_all, "mitchell should dominate: all={e_all}, no-mit={e_nomit}");
}

#[test]
fn mitchell_histogram_concentrates_low() {
    // Fig. 5: the mass of Mitchell inputs concentrates at small x
    let Some(dir) = model_dir("s0") else { return };
    let model = Transformer::load(&dir).expect("load s0");
    let file = hfa::artifacts_dir().join("eval").join("maxsym_4.txt");
    if !file.is_file() {
        return;
    }
    let mut hist = hfa::arith::mitchell::MitchellHistogram::new(64);
    let _ = evaluate_file(&model, &file, AttnSelect::Hfa, 10, &mut Some(&mut hist)).unwrap();
    assert!(hist.total > 5_000, "too few recorded inputs: {}", hist.total);
    // the distribution skews low (the paper's Fig. 5 shows the same shape
    // on LLM traffic; our tiny-LM values give a milder skew — recorded in
    // EXPERIMENTS.md)
    assert!(hist.mass_below(0.1) > 2.0 * 0.1, "mass below 0.1 = {}", hist.mass_below(0.1));
    assert!(hist.mass_below(0.5) > 0.5, "mass below 0.5 = {}", hist.mass_below(0.5));
}

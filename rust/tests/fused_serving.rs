//! Cross-session super-batch serving, end to end: a dispatch spanning
//! many sessions must be invisible to every caller — outputs
//! bit-identical to serving each session alone (the golden blocked
//! model, which single-session serving is pinned against elsewhere),
//! appends barriering only their own session, pins released per session
//! and the KV byte accounting returning to baseline once the traffic
//! drains.

use std::sync::Arc;

use hfa::attention::prepared::row_bytes;
use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{KvStore, Server, SimBackend};
use hfa::hw::Arith;
use hfa::proptest::Rng;
use hfa::Mat;

const D: usize = 8;
const SEQ: usize = 32;
const KV_BLOCKS: usize = 4;

fn accel_cfg() -> AcceleratorConfig {
    AcceleratorConfig {
        head_dim: D,
        seq_len: SEQ,
        kv_blocks: KV_BLOCKS,
        parallel_queries: 1,
        freq_mhz: 500.0,
    }
}

/// Golden single-session serving result: the blocked H-FA model over the
/// session's exact KV prefix (what `Server` is pinned to produce for a
/// lone session by `coordinator::server::tests`).
fn golden(q: &[f32], k: &Mat, v: &Mat, rows: usize) -> Vec<f32> {
    hfa::attention::hfa::attention_blocked(
        &Mat::from_vec(1, D, q.to_vec()).round_bf16(),
        &k.rows_slice(0, rows).round_bf16(),
        &v.rows_slice(0, rows).round_bf16(),
        KV_BLOCKS,
        None,
        &mut None,
    )
    .row(0)
    .to_vec()
}

// The acceptance pin: queries on several sessions landing inside one
// forming window must ship as ONE dispatch (where the single-session
// batcher needed one per session), and every output must still be
// bit-identical to isolated serving.
#[test]
fn super_batch_spanning_sessions_is_one_dispatch_and_bit_identical() {
    const SESSIONS: usize = 8;
    let coord = CoordinatorConfig {
        max_batch: 8,
        max_total_batch: 64,
        batch_window_us: 200_000, // generous: all submits land well inside
        workers: 1,
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };
    let kv = Arc::new(KvStore::new(SEQ, D, SESSIONS));
    let mut rng = Rng::new(41);
    let mut kvs = Vec::new();
    for s in 0..SESSIONS {
        let k = Mat::from_vec(SEQ, D, rng.normal_vec(SEQ * D));
        let v = Mat::from_vec(SEQ, D, rng.normal_vec(SEQ * D));
        kv.put(&format!("sess-{s}"), k.clone(), v.clone()).unwrap();
        kvs.push((k, v));
    }
    let srv =
        Server::start(&coord, kv, vec![SimBackend::factory(Arith::Hfa, accel_cfg())]).unwrap();

    // one query per session, submitted back to back — the fan-out
    // regime where the single-session batcher degenerated to N
    // batch-size-1 dispatches
    let queries: Vec<Vec<f32>> = (0..SESSIONS).map(|_| rng.normal_vec(D)).collect();
    let rxs: Vec<_> = queries
        .iter()
        .enumerate()
        .map(|(s, q)| srv.submit(&format!("sess-{s}"), q.clone()).unwrap())
        .collect();
    for (s, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert!(resp.ok(), "session {s}: {:?}", resp.output);
        assert_eq!(
            resp.output.unwrap(),
            golden(&queries[s], &kvs[s].0, &kvs[s].1, SEQ),
            "session {s}: fused dispatch diverged from isolated serving"
        );
        assert_eq!(resp.batch_size, SESSIONS, "response must report the fused batch size");
    }
    let snap = srv.metrics.snapshot();
    assert_eq!(snap.completed, SESSIONS as u64);
    assert_eq!(
        snap.batches, 1,
        "{SESSIONS} one-query sessions must fuse into a single dispatch: {snap:?}"
    );
    assert_eq!(snap.mean_sessions, SESSIONS as f64);
    assert_eq!(snap.mean_batch, SESSIONS as f64);
    srv.shutdown();
}

// Many-session soak: 64 sessions running interleaved decode loops
// (append one row, then attend) over the fused path.  Every attend must
// be bit-identical to the golden model over that session's exact prefix,
// appends must only ever grow their own session, and when the traffic
// drains the store must hold zero pins and exactly the resident bytes
// the sessions' final lengths account for (no leak across super-batches).
#[test]
fn many_session_decode_soak_stays_exact_and_leaks_nothing() {
    const SESSIONS: usize = 64;
    const PREFILL: usize = 8;
    const STEPS: usize = 4;
    let coord = CoordinatorConfig {
        max_batch: 8,
        max_total_batch: 256,
        batch_window_us: 3_000,
        workers: 3,
        queue_depth: 512,
        ..CoordinatorConfig::default()
    };
    let kv = Arc::new(KvStore::new(SEQ, D, SESSIONS));
    let mut rng = Rng::new(2027);
    let mut mats = Vec::new();
    for s in 0..SESSIONS {
        let n = PREFILL + STEPS;
        let k = Mat::from_vec(n, D, rng.normal_vec(n * D));
        let v = Mat::from_vec(n, D, rng.normal_vec(n * D));
        kv.put(&format!("sess-{s}"), k.rows_slice(0, PREFILL), v.rows_slice(0, PREFILL))
            .unwrap();
        mats.push((k, v));
    }
    let factories = (0..coord.workers)
        .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg()))
        .collect();
    let srv = Server::start(&coord, kv.clone(), factories).unwrap();

    for step in 0..STEPS {
        let at = PREFILL + step;
        // decode writes for every session, then the barrier acks; each
        // session's next attend is only submitted after its own ack, so
        // per-session ordering is the client-enforced decode protocol
        let acks: Vec<_> = (0..SESSIONS)
            .map(|s| {
                let (k, v) = &mats[s];
                srv.submit_append(
                    &format!("sess-{s}"),
                    k.rows_slice(at, at + 1),
                    v.rows_slice(at, at + 1),
                )
                .unwrap()
            })
            .collect();
        for (s, ack) in acks.into_iter().enumerate() {
            let a = ack.recv().unwrap();
            assert!(a.ok(), "step {step} session {s} append: {:?}", a.output);
        }
        // one attend per session, submitted back to back so the window
        // fuses them across sessions
        let queries: Vec<Vec<f32>> = (0..SESSIONS).map(|_| rng.normal_vec(D)).collect();
        let rxs: Vec<_> = (0..SESSIONS)
            .map(|s| srv.submit(&format!("sess-{s}"), queries[s].clone()).unwrap())
            .collect();
        for (s, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert!(resp.ok(), "step {step} session {s}: {:?}", resp.output);
            let (k, v) = &mats[s];
            assert_eq!(
                resp.output.unwrap(),
                golden(&queries[s], k, v, at + 1),
                "step {step} session {s}: fused decode attend diverged from golden \
                 over {} rows",
                at + 1
            );
        }
    }

    // the fused path must actually have fused: strictly fewer dispatches
    // than requests, more than one session per dispatch on average
    let snap = srv.metrics.snapshot();
    assert_eq!(snap.completed, (SESSIONS * STEPS) as u64);
    assert_eq!(snap.appends, (SESSIONS * STEPS) as u64);
    assert_eq!(snap.failed, 0);
    assert!(
        snap.mean_sessions > 1.0,
        "soak never exercised cross-session fusion: {snap:?}"
    );

    // no leak across super-batches: every ingress pin released, byte
    // accounting equal to exactly the sessions' final resident lengths
    assert_eq!(kv.pinned_sessions(), 0, "drained server must hold no pins");
    assert_eq!(kv.resident(), SESSIONS);
    let expect_bytes = SESSIONS * (PREFILL + STEPS) * row_bytes(D, D);
    assert_eq!(kv.used_bytes(), expect_bytes, "byte accounting drifted over the soak");
    srv.shutdown();
    assert_eq!(kv.pinned_sessions(), 0, "shutdown must not re-pin anything");
}

// Append barriers must order within their own session only: a session
// with a pending query closed by its append must see pre-append KV for
// the query, while an unrelated session fused into neighbouring
// dispatches is untouched.
#[test]
fn append_barriers_order_within_their_session_only() {
    let coord = CoordinatorConfig {
        max_batch: 8,
        max_total_batch: 64,
        batch_window_us: 100_000,
        workers: 1,
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };
    let kv = Arc::new(KvStore::new(SEQ, D, 4));
    let mut rng = Rng::new(97);
    let n = 12;
    let (ka, va) = (
        Mat::from_vec(n, D, rng.normal_vec(n * D)),
        Mat::from_vec(n, D, rng.normal_vec(n * D)),
    );
    let (kb, vb) = (
        Mat::from_vec(SEQ, D, rng.normal_vec(SEQ * D)),
        Mat::from_vec(SEQ, D, rng.normal_vec(SEQ * D)),
    );
    kv.put("a", ka.rows_slice(0, n - 1), va.rows_slice(0, n - 1)).unwrap();
    kv.put("b", kb.clone(), vb.clone()).unwrap();
    let factories = vec![SimBackend::factory(Arith::Hfa, accel_cfg())];
    let srv = Server::start(&coord, kv, factories).unwrap();

    // session a: query then append — the append closes the pair into one
    // dispatch, query served against the pre-append prefix; session b's
    // query rides the window independently
    let qa = rng.normal_vec(D);
    let qb = rng.normal_vec(D);
    let rx_a = srv.submit("a", qa.clone()).unwrap();
    let rx_b = srv.submit("b", qb.clone()).unwrap();
    let rx_app =
        srv.submit_append("a", ka.rows_slice(n - 1, n), va.rows_slice(n - 1, n)).unwrap();
    let ra = rx_a.recv().unwrap();
    let rapp = rx_app.recv().unwrap();
    let rb = rx_b.recv().unwrap();
    assert!(ra.ok() && rapp.ok() && rb.ok());
    assert_eq!(
        ra.output.unwrap(),
        golden(&qa, &ka, &va, n - 1),
        "query closed by its session's append must see pre-append KV"
    );
    assert_eq!(rb.output.unwrap(), golden(&qb, &kb, &vb, SEQ), "other session untouched");
    // post-ack query sees the grown KV
    let qa2 = rng.normal_vec(D);
    let ra2 = srv.call("a", qa2.clone()).unwrap();
    assert!(ra2.ok());
    assert_eq!(ra2.output.unwrap(), golden(&qa2, &ka, &va, n));
    srv.shutdown();
}

//! Incremental decode vs full forward: feeding tokens one at a time
//! through `Transformer::decoder` (append-only per-head KV caches) must
//! reproduce the full-sequence `forward` logits **bit-exactly** at every
//! position, for every supported attention implementation.  This is the
//! model-level pin of the append-only decode path: causal row `t` attends
//! exactly the `t+1` cached rows, and every per-row op is row-independent.

use std::io::Write;
use std::path::{Path, PathBuf};

use hfa::model::{AttnSelect, Transformer};
use hfa::proptest::Rng;

const VOCAB: usize = 24;
const D: usize = 16;
const HEADS: usize = 2;
const LAYERS: usize = 2;
const SEQ: usize = 16;
const DFF: usize = 32;

/// Write a random-but-deterministic tiny model in the `weights.bin` +
/// `manifest.txt` + `config.txt` format `Weights::load` expects.
fn write_tiny_model(dir: &Path, rng: &mut Rng) {
    std::fs::create_dir_all(dir).unwrap();
    let mut flat: Vec<f32> = Vec::new();
    let mut manifest = String::from("# tiny decode-parity model\n");
    let mut tensor = |name: &str, shape: &[usize], data: Vec<f32>| {
        let count: usize = shape.iter().product();
        assert_eq!(data.len(), count, "{name}");
        let dims: Vec<String> = shape.iter().map(|s| s.to_string()).collect();
        manifest.push_str(&format!(
            "{name}|{}|{}|{count}\n",
            dims.join(","),
            flat.len()
        ));
        flat.extend_from_slice(&data);
    };

    let small = |rng: &mut Rng, n: usize| -> Vec<f32> {
        rng.normal_vec(n).into_iter().map(|x| 0.3 * x).collect()
    };
    let near_one = |rng: &mut Rng, n: usize| -> Vec<f32> {
        rng.normal_vec(n).into_iter().map(|x| 1.0 + 0.1 * x).collect()
    };
    let tiny = |rng: &mut Rng, n: usize| -> Vec<f32> {
        rng.normal_vec(n).into_iter().map(|x| 0.02 * x).collect()
    };

    tensor("tok_emb", &[VOCAB, D], small(rng, VOCAB * D));
    tensor("pos_emb", &[SEQ, D], small(rng, SEQ * D));
    for l in 0..LAYERS {
        tensor(&format!("l{l}.ln1_g"), &[D], near_one(rng, D));
        tensor(&format!("l{l}.ln1_b"), &[D], tiny(rng, D));
        tensor(&format!("l{l}.wq"), &[D, D], small(rng, D * D));
        tensor(&format!("l{l}.wk"), &[D, D], small(rng, D * D));
        tensor(&format!("l{l}.wv"), &[D, D], small(rng, D * D));
        tensor(&format!("l{l}.wo"), &[D, D], small(rng, D * D));
        tensor(&format!("l{l}.ln2_g"), &[D], near_one(rng, D));
        tensor(&format!("l{l}.ln2_b"), &[D], tiny(rng, D));
        tensor(&format!("l{l}.w1"), &[D, DFF], small(rng, D * DFF));
        tensor(&format!("l{l}.b1"), &[DFF], tiny(rng, DFF));
        tensor(&format!("l{l}.w2"), &[DFF, D], small(rng, DFF * D));
        tensor(&format!("l{l}.b2"), &[D], tiny(rng, D));
    }
    tensor("lnf_g", &[D], near_one(rng, D));
    tensor("lnf_b", &[D], tiny(rng, D));

    let bytes: Vec<u8> = flat.iter().flat_map(|f| f.to_le_bytes()).collect();
    std::fs::write(dir.join("weights.bin"), bytes).unwrap();
    std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
    let mut cfg = std::fs::File::create(dir.join("config.txt")).unwrap();
    writeln!(
        cfg,
        "name=tiny\nvocab={VOCAB}\nd_model={D}\nn_head={HEADS}\nn_layer={LAYERS}\nseq_len={SEQ}"
    )
    .unwrap();
}

/// Per-test model directory (tests run concurrently in one process, so
/// each gets its own files even though the contents are identical).
fn tiny_model_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("hfa_decode_parity_{}_{tag}", std::process::id()));
    let mut rng = Rng::new(20_260_728);
    write_tiny_model(&dir, &mut rng);
    dir
}

#[test]
fn decode_steps_bit_identical_to_full_forward() {
    let dir = tiny_model_dir("parity");
    let model = Transformer::load(&dir).expect("load tiny model");
    let tokens: Vec<i32> = (0..12).map(|i| ((i * 5 + 3) % VOCAB) as i32).collect();

    for attn in [AttnSelect::Exact, AttnSelect::Fa2, AttnSelect::Hfa] {
        let full = model.forward(&tokens, attn, &mut None).unwrap();
        assert_eq!((full.rows, full.cols), (tokens.len(), VOCAB));
        let mut dec = model.decoder(attn).unwrap();
        for (t, &tok) in tokens.iter().enumerate() {
            assert_eq!(dec.pos(), t);
            let step = dec.step(tok).unwrap();
            assert_eq!((step.rows, step.cols), (1, VOCAB));
            assert_eq!(
                step.row(0),
                full.row(t),
                "{attn:?}: decode step {t} diverged from full forward"
            );
        }
    }
}

#[test]
fn decoder_rejects_bad_inputs() {
    let dir = tiny_model_dir("rejects");
    let model = Transformer::load(&dir).expect("load tiny model");
    assert!(
        model.decoder(AttnSelect::HfaEmu(hfa::attention::hfa::EmuConfig::all_on())).is_err(),
        "emu ablations have no decode path"
    );
    let mut dec = model.decoder(AttnSelect::Exact).unwrap();
    assert!(dec.step(-1).is_err(), "negative token");
    assert!(dec.step(VOCAB as i32).is_err(), "token out of vocab");
    for i in 0..SEQ {
        dec.step((i % VOCAB) as i32).unwrap();
    }
    assert!(dec.step(0).is_err(), "decode past seq_len must fail");
}

//! Regression: the append-only decode path pays V linear->log conversion
//! **proportional to the appended rows only** — never the resident
//! prefix, never per batch.  Counted end-to-end with the process-wide
//! `value_conversion_count` through `PreparedKv::append`,
//! `KvStore::append` and a full server decode loop (prefill once, then
//! append+attend steps).
//!
//! Kept as the sole test in this binary so the process-wide conversion
//! counter sees no concurrent traffic from unrelated tests.

use std::sync::Arc;

use hfa::attention::hfa::value_conversion_count;
use hfa::attention::prepared::PreparedKv;
use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{KvStore, Server, SimBackend};
use hfa::hw::Arith;
use hfa::proptest::Rng;
use hfa::Mat;

#[test]
fn append_conversion_work_tracks_new_rows_only() {
    const N: usize = 64; // session capacity
    const D: usize = 8;
    const PREFILL: usize = 48;
    const STEPS: usize = 12;
    let mut rng = Rng::new(7_777);
    let k = Mat::from_vec(N, D, rng.normal_vec(N * D));
    let v = Mat::from_vec(N, D, rng.normal_vec(N * D));

    // --- PreparedKv level -------------------------------------------------
    let before = value_conversion_count();
    let mut kv = PreparedKv::new(
        k.rows_slice(0, 8).round_bf16(),
        v.rows_slice(0, 8).round_bf16(),
    );
    assert_eq!(value_conversion_count() - before, 8, "prefill converts its own rows once");
    let before = value_conversion_count();
    kv.append(&k.rows_slice(8, 9).round_bf16(), &v.rows_slice(8, 9).round_bf16());
    assert_eq!(value_conversion_count() - before, 1, "1-row append converts 1 row");
    let before = value_conversion_count();
    kv.append(&k.rows_slice(9, 14).round_bf16(), &v.rows_slice(9, 14).round_bf16());
    assert_eq!(value_conversion_count() - before, 5, "5-row append converts 5 rows");

    // --- KvStore level (copy-on-write Arc swap) ---------------------------
    let store = KvStore::new(N, D, 2);
    let before = value_conversion_count();
    store.put("s", k.rows_slice(0, PREFILL), v.rows_slice(0, PREFILL)).unwrap();
    assert_eq!(value_conversion_count() - before, PREFILL as u64);
    let snapshot = store.get("s").unwrap(); // hold the old Arc across appends
    let before = value_conversion_count();
    store.append("s", k.rows_slice(PREFILL, PREFILL + 1), v.rows_slice(PREFILL, PREFILL + 1))
        .unwrap();
    store.append("s", k.rows_slice(PREFILL + 1, PREFILL + 4), v.rows_slice(PREFILL + 1, PREFILL + 4))
        .unwrap();
    assert_eq!(
        value_conversion_count() - before,
        4,
        "store appends must convert only the appended rows (resident: {})",
        snapshot.prepared().n()
    );
    drop(snapshot);

    // --- full serving decode loop -----------------------------------------
    let accel_cfg = AcceleratorConfig {
        head_dim: D,
        seq_len: N,
        kv_blocks: 4,
        parallel_queries: 1,
        freq_mhz: 500.0,
    };
    let coord_cfg = CoordinatorConfig {
        max_batch: 4,
        max_total_batch: 256,
        batch_window_us: 100,
        workers: 2,
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };
    let kv_store = Arc::new(KvStore::new(N, D, 2));
    let before_prefill = value_conversion_count();
    kv_store.put("dec", k.rows_slice(0, PREFILL), v.rows_slice(0, PREFILL)).unwrap();
    assert_eq!(value_conversion_count() - before_prefill, PREFILL as u64);

    let factories = (0..coord_cfg.workers)
        .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg.clone()))
        .collect();
    let server = Server::start(&coord_cfg, kv_store.clone(), factories).unwrap();

    let before_decode = value_conversion_count();
    for step in 0..STEPS {
        let at = PREFILL + step;
        let ack = server
            .append("dec", k.rows_slice(at, at + 1), v.rows_slice(at, at + 1))
            .unwrap();
        assert!(ack.ok(), "step {step}: {:?}", ack.output);
        let resp = server.call("dec", rng.normal_vec(D)).unwrap();
        assert!(resp.ok(), "step {step}: {:?}", resp.output);
    }
    assert_eq!(
        value_conversion_count() - before_decode,
        STEPS as u64,
        "a {STEPS}-step decode loop over a {PREFILL}-row prefill must convert \
         exactly {STEPS} rows — attends must not reconvert, appends must not \
         touch resident rows"
    );
    assert_eq!(kv_store.get("dec").unwrap().prepared().n(), PREFILL + STEPS);
    server.shutdown();
}

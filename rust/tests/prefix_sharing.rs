//! Cross-session KV prefix sharing, end to end: S sessions sharing a
//! P-row prefix store each full prefix chunk exactly once (exact
//! `used_bytes` equation), LNS conversion cost is proportional to
//! *unique* rows rather than S×P, forked and dedup-admitted sessions
//! decode bitwise-identically to independently-put sessions across a
//! join/leave/evict soak, and no eviction ever frees a chunk another
//! resident session still references.
//!
//! Kept as the sole test in this binary: the conversion and copy
//! counters are process-wide, so concurrent unrelated tests would break
//! the exact equations.

use std::sync::Arc;

use hfa::attention::hfa::value_conversion_count;
use hfa::attention::prepared::row_bytes;
use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{KvStore, Server, SimBackend};
use hfa::hw::Arith;
use hfa::proptest::Rng;
use hfa::Mat;

const D: usize = 8;
/// Two full DEFAULT_BLOCK_ROWS (256) chunks.
const PREFIX: usize = 512;
/// Per-session private suffix rows at put time.
const TAIL: usize = 8;
const ROWS: usize = PREFIX + TAIL;
const STEPS: usize = 4;
const SEQ: usize = 600;
const SESSIONS: usize = 5;
const KV_BLOCKS: usize = 4;

fn accel_cfg() -> AcceleratorConfig {
    AcceleratorConfig {
        head_dim: D,
        seq_len: SEQ,
        kv_blocks: KV_BLOCKS,
        parallel_queries: 1,
        freq_mhz: 500.0,
    }
}

/// Golden single-session result over the session's exact KV prefix.
fn golden(q: &[f32], k: &Mat, v: &Mat, rows: usize) -> Vec<f32> {
    hfa::attention::hfa::attention_blocked(
        &Mat::from_vec(1, D, q.to_vec()).round_bf16(),
        &k.rows_slice(0, rows).round_bf16(),
        &v.rows_slice(0, rows).round_bf16(),
        KV_BLOCKS,
        None,
        &mut None,
    )
    .row(0)
    .to_vec()
}

/// A session's full K or V trajectory: `PREFIX` rows shared by every
/// session, then `TAIL + STEPS` rows drawn per-session.
fn session_mat(prefix: &Mat, rng: &mut Rng) -> Mat {
    let n = ROWS + STEPS;
    let mut m = Mat::zeros(n, D);
    m.data[..PREFIX * D].copy_from_slice(&prefix.data);
    let suffix = rng.normal_vec((TAIL + STEPS) * D);
    m.data[PREFIX * D..].copy_from_slice(&suffix);
    m
}

#[test]
fn prefix_sharing_stores_once_and_decodes_bit_identically() {
    // deterministic pool shape for the process-wide counters (same
    // rationale as tests/append_traffic.rs)
    std::env::set_var("HFA_POOL_THREADS", "1");
    let rb = row_bytes(D, D);
    let mut rng = Rng::new(20_260_808);
    let kp = Mat::from_vec(PREFIX, D, rng.normal_vec(PREFIX * D));
    let vp = Mat::from_vec(PREFIX, D, rng.normal_vec(PREFIX * D));
    let mats: Vec<(Mat, Mat)> =
        (0..SESSIONS).map(|_| (session_mat(&kp, &mut rng), session_mat(&vp, &mut rng))).collect();

    // --- (a) S puts of a shared P-row prefix: stored once, converted once --
    let kv = Arc::new(KvStore::new(SEQ, D, SESSIONS + 2));
    let conv0 = value_conversion_count();
    for (s, (k, v)) in mats.iter().enumerate() {
        kv.put(&format!("sess-{s}"), k.rows_slice(0, ROWS), v.rows_slice(0, ROWS)).unwrap();
    }
    assert_eq!(
        value_conversion_count() - conv0,
        (ROWS + (SESSIONS - 1) * TAIL) as u64,
        "LNS conversion must be proportional to unique rows, not S x P"
    );
    assert_eq!(
        kv.used_bytes(),
        ROWS * rb + (SESSIONS - 1) * TAIL * rb,
        "the prefix chunks are charged exactly once fleet-wide"
    );
    assert_eq!(kv.shared_bytes(), PREFIX * rb);
    for s in 0..SESSIONS {
        assert_eq!(kv.session_resident_bytes(&format!("sess-{s}")), Some(ROWS * rb));
    }

    // --- (b) fork + dedup decode soak: bitwise-equal to independent puts --
    // "beam" forks from sess-0; "indep" re-puts sess-0's exact prefill
    // (its full chunks dedup to the same Arcs).  Both then run the same
    // decode trajectory as sess-0 would and must match the golden model
    // (and each other) bit for bit, while unrelated sessions join and
    // leave around them.
    let coord = CoordinatorConfig {
        max_batch: 8,
        max_total_batch: 64,
        batch_window_us: 3_000,
        workers: 2,
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };
    let factories =
        (0..coord.workers).map(|_| SimBackend::factory(Arith::Hfa, accel_cfg())).collect();
    let srv = Server::start(&coord, kv.clone(), factories).unwrap();
    srv.fork("sess-0", "beam").unwrap();
    let (k0, v0) = &mats[0];
    let conv0 = value_conversion_count();
    kv.put("indep", k0.rows_slice(0, ROWS), v0.rows_slice(0, ROWS)).unwrap();
    assert_eq!(
        value_conversion_count() - conv0,
        TAIL as u64,
        "a dedup-admitted put re-converts only its non-full tail"
    );
    for step in 0..STEPS {
        let at = ROWS + step;
        for who in ["beam", "indep"] {
            let r = srv
                .append(who, k0.rows_slice(at, at + 1), v0.rows_slice(at, at + 1))
                .unwrap();
            assert!(r.ok(), "step {step} {who} append: {:?}", r.output);
        }
        let q = rng.normal_vec(D);
        let beam = srv.call("beam", q.clone()).unwrap().output.unwrap();
        let indep = srv.call("indep", q.clone()).unwrap().output.unwrap();
        assert_eq!(beam, indep, "step {step}: forked vs independently-put decode diverged");
        assert_eq!(beam, golden(&q, k0, v0, at + 1), "step {step}: diverged from golden");
        // churn: a sibling leaves (freeing only its private tail — the
        // prefix is still referenced by everyone else) and rejoins via
        // the dedup path
        let churn = format!("sess-{}", 1 + (step % (SESSIONS - 1)));
        let used = kv.used_bytes();
        srv.cancel(&churn, true);
        assert_eq!(used - kv.used_bytes(), TAIL * rb, "churn evict freed a shared chunk");
        let s = 1 + (step % (SESSIONS - 1));
        let (ks, vs) = &mats[s];
        kv.put(&churn, ks.rows_slice(0, ROWS), vs.rows_slice(0, ROWS)).unwrap();
        assert_eq!(kv.used_bytes(), used, "rejoin via dedup restored the exact accounting");
    }

    // --- (c) evicting the parent frees only its unshared bytes ------------
    // sess-0's prefix chunks are shared with every session; its 8-row
    // put-time tail was CoW-diverged by beam's first append, so evicting
    // it frees exactly that private tail chunk.
    let before = kv.used_bytes();
    assert_eq!(kv.evict("sess-0"), Some(TAIL * rb), "parent eviction freed shared bytes");
    assert_eq!(before - kv.used_bytes(), TAIL * rb);
    // the orphaned child still serves, still bit-identical
    let q = rng.normal_vec(D);
    let beam = srv.call("beam", q.clone()).unwrap().output.unwrap();
    assert_eq!(beam, golden(&q, k0, v0, ROWS + STEPS), "child diverged after parent eviction");

    // drain: no pin leaks, and tearing every session down returns the
    // registry to empty (nothing freed early, nothing leaked)
    assert_eq!(kv.pinned_sessions(), 0, "drained serving must hold no pins");
    srv.shutdown();
    assert_eq!(kv.pinned_sessions(), 0, "shutdown must not re-pin anything");
    for s in (0..SESSIONS).map(|s| format!("sess-{s}")).chain(["beam".into(), "indep".into()]) {
        kv.evict(&s);
    }
    assert_eq!(kv.resident(), 0);
    assert_eq!(kv.used_bytes(), 0);
    assert_eq!(kv.shared_bytes(), 0);
    assert_eq!(kv.registered_chunks(), 0, "eviction leaked or double-freed chunks");
    assert_eq!(kv.indexed_prefixes(), 0, "prefix index entries must die with their chunks");
}

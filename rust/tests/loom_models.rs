//! Loom model checks for the coordinator's hand-rolled protocols
//! (`hfa::coordinator::protocol`).
//!
//! Build and run with:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_models -- --test-threads=1
//! ```
//!
//! Under `--cfg loom` the whole crate's `hfa::sync` facade resolves its
//! Mutex/Condvar/atomics to loom's instrumented types, so these models
//! exhaustively explore every bounded-preemption interleaving of the
//! *shipped* protocol code — not a simplified replica.  Each model
//! pins one liveness or safety property the serving stack depends on;
//! a missed-wakeup, lost-item, leaked-pin or cap-overrun interleaving
//! fails the lane deterministically.
//!
//! Preemption bound 3 (the loom paper's sweet spot: virtually all real
//! bugs need <= 2 preemptions) keeps each model in the seconds range.

#![cfg(loom)]

use std::time::{Duration, Instant};

use hfa::coordinator::protocol::{
    release, try_admit, BatchKind, BatchQueue, CancelRegistry, IterGate, IterToken, PinGuard,
};
use hfa::coordinator::KvStore;
use hfa::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use hfa::sync::Arc;
use hfa::Mat;

/// Run `f` under loom with the suite's preemption bound.
fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    let mut b = loom::model::Builder::new();
    b.preemption_bound = Some(3);
    b.check(f);
}

/// Protocol 1 — BatchQueue park/wake/shutdown.
///
/// Two workers block in `pop`, a bounded producer blocks in `push` when
/// the queue is full, and `close` ends the stream.  The property is
/// liveness: no interleaving leaves a worker parked forever after the
/// producer closed (a missed `notify` would deadlock the model and fail
/// the check), and every pushed item is popped exactly once.
#[test]
fn batch_queue_park_wake_shutdown() {
    model(|| {
        let q: Arc<BatchQueue<u8>> = Arc::new(BatchQueue::new(1, 2));
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                loom::thread::spawn(move || {
                    let mut got = 0u8;
                    while q.pop().is_some() {
                        got += 1;
                    }
                    got
                })
            })
            .collect();
        // cap 1 with two items: the second push parks the producer until
        // a worker frees the slot
        q.push(1).expect("workers alive");
        q.push(2).expect("workers alive");
        q.close();
        let total: u8 = workers.into_iter().map(|h| h.join().expect("worker model panicked")).sum();
        assert_eq!(total, 2, "each item popped exactly once, none lost");
    });
}

/// Protocol 2 — WorkerExit live-count and stranded-item handoff.
///
/// Workers race their exits against the producer's push.  The safety
/// property is conservation: an accepted item (push returned `Ok`) is
/// handed back in the last exiter's residue — no interleaving strands
/// it silently in a dead queue — and once every worker is gone, push
/// refuses the item instead of hanging its caller.
#[test]
fn worker_exit_hands_back_stranded_items() {
    model(|| {
        let q: Arc<BatchQueue<u8>> = Arc::new(BatchQueue::new(4, 2));
        // both workers die without ever popping (failed init, panicked
        // backend), racing the producer's push in every order the
        // preemption bound allows
        let crashers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                loom::thread::spawn(move || q.worker_exited().len())
            })
            .collect();
        let accepted = q.push(9).is_ok();
        let residue: usize =
            crashers.into_iter().map(|h| h.join().expect("crasher model panicked")).sum();
        if accepted {
            assert_eq!(residue, 1, "accepted item is handed back by the last exiter, never lost");
        } else {
            assert_eq!(residue, 0, "refused item stays with the caller");
        }
        assert_eq!(q.push(8), Err(8), "push to a dead pool is refused, not hung");
    });
}

/// Protocol 3 — PinGuard release-before-reply ordering.
///
/// A worker serves a pinned session: it releases the pin, then publishes
/// the reply (Release store).  The client observes the reply (Acquire
/// load) and must find the session already evictable — the serving
/// invariant that a caller holding its response never blocks eviction.
/// The second half models the panic path: a guard dropped with an
/// unreleased pin still unpins on drop.
#[test]
fn pin_guard_releases_before_reply() {
    model(|| {
        let kv = Arc::new(KvStore::new(2, 1, 4));
        kv.put("s", Mat::zeros(2, 1), Mat::zeros(2, 1)).expect("put in model");
        assert!(kv.pin("s"));
        let replied = Arc::new(AtomicBool::new(false));

        let worker = {
            let (kv, replied) = (kv.clone(), replied.clone());
            loom::thread::spawn(move || {
                let mut guard = PinGuard::new(&kv, "s".into(), 1);
                guard.release_one();
                // ordering: Release — publishes the unpin above to the
                // client's Acquire load of the reply flag
                replied.store(true, Ordering::Release);
            })
        };

        // ordering: Acquire — pairs with the worker's Release store; once
        // the reply is visible, so is everything before it (the unpin)
        if replied.load(Ordering::Acquire) {
            assert_eq!(kv.pinned_sessions(), 0, "reply visible implies pin released");
        }
        worker.join().expect("worker model panicked");

        // panic analogue: a guard dropped with its pin unreleased
        assert!(kv.pin("s"));
        drop(PinGuard::new(&kv, "s".into(), 1));
        assert_eq!(kv.pinned_sessions(), 0, "drop path releases the remainder");
    });
}

/// Protocol 4 — CancelRegistry mark-vs-serve race.
///
/// A cancel for session `s` at instant `t0` races a worker's
/// `cancelled_since(s, t0)` check for a request that arrived at `t0`.
/// Either outcome of the race is legal (served before the cancel landed,
/// or shed), but the mark must be durable — after the race the registry
/// always sheds `t0` traffic — and must never leak onto traffic
/// submitted after the cancel instant (the resubmit path).
#[test]
fn cancel_mark_vs_serve_race() {
    model(|| {
        let reg = Arc::new(CancelRegistry::default());
        let t0 = Instant::now();

        let canceller = {
            let reg = reg.clone();
            loom::thread::spawn(move || reg.cancel_at("s", t0))
        };
        let worker = {
            let reg = reg.clone();
            loom::thread::spawn(move || reg.cancelled_since("s", t0))
        };
        let _served_or_shed: bool = worker.join().expect("worker model panicked");
        canceller.join().expect("canceller model panicked");

        assert!(reg.cancelled_since("s", t0), "the mark is durable after the race");
        assert!(
            !reg.cancelled_since("s", t0 + Duration::from_nanos(1)),
            "a resubmit after the cancel instant is never shed"
        );
    });
}

/// Protocol 5 — admission gate increment/rollback under contention.
///
/// Two admitters race `try_admit` at cap 1 with no interleaved release:
/// at most one may win (the increment-then-check gate's whole point —
/// a check-then-increment gate admits both), a loser's rollback leaves
/// no residue, and the gauge balances to zero after the winners release.
#[test]
fn admission_gate_bounds_and_rolls_back() {
    model(|| {
        let gauge = Arc::new(AtomicU64::new(0));
        let admitters: Vec<_> = (0..2)
            .map(|_| {
                let gauge = gauge.clone();
                loom::thread::spawn(move || try_admit(&gauge, 1))
            })
            .collect();
        let admitted = admitters
            .into_iter()
            .map(|h| h.join().expect("admitter model panicked"))
            .filter(|&ok| ok)
            .count();
        assert_eq!(admitted, 1, "cap 1: exactly one racing admitter wins");
        // ordering: SeqCst — post-join read of the gate's total order
        assert_eq!(gauge.load(Ordering::SeqCst), 1, "the loser's rollback left no residue");
        release(&gauge);
        // ordering: SeqCst — see above
        assert_eq!(gauge.load(Ordering::SeqCst), 0, "gauge balances once the winner releases");
    });
}

/// Protocol 6 — IterGate lane claim/finish race.
///
/// The continuous scheduler keeps at most one dispatch per lane in
/// flight, and workers retire dispatches by dropping an [`IterToken`]
/// (finish-then-nudge).  Two racing claimers of the same lane must
/// never both win (a double claim would put two decode iterations in
/// flight at once and break the iteration protocol); the other lane is
/// independent and stays claimable throughout; a winner's token drop —
/// racing a fresh claim — always reopens the lane and fires its nudge
/// exactly once per retirement.
#[test]
fn iter_gate_single_claim_per_lane_and_token_reopens() {
    model(|| {
        let gate = Arc::new(IterGate::new());
        let nudges = Arc::new(AtomicU64::new(0));
        let holders = Arc::new(AtomicU64::new(0));
        // two workers race to claim the decode lane and, on winning,
        // hold it (holders must never exceed one), then retire their
        // dispatch via the token drop
        let claimers: Vec<_> = (0..2)
            .map(|_| {
                let gate = gate.clone();
                let nudges = nudges.clone();
                let holders = holders.clone();
                loom::thread::spawn(move || {
                    if gate.claim(BatchKind::Decode) {
                        // ordering: SeqCst — the holders probe must join
                        // the claim/finish total order to witness a
                        // double claim
                        let was = holders.fetch_add(1, Ordering::SeqCst);
                        assert_eq!(was, 0, "two dispatches in flight on one lane");
                        // ordering: SeqCst — released before the token
                        // drop reopens the lane for the other claimer
                        holders.fetch_sub(1, Ordering::SeqCst);
                        let n = nudges.clone();
                        drop(IterToken::new(
                            gate,
                            BatchKind::Decode,
                            // ordering: SeqCst — joins the lane's total
                            // order; the count must match retirements
                            Some(Box::new(move || {
                                n.fetch_add(1, Ordering::SeqCst);
                            })),
                        ));
                        true
                    } else {
                        false
                    }
                })
            })
            .collect();
        // the prefill lane is independent: claimable no matter where the
        // decode racers are, and its ungated Formed kind always claims
        assert!(gate.claim(BatchKind::Prefill), "lanes are independent");
        assert!(gate.claim(BatchKind::Formed), "Formed is ungated");
        assert!(!gate.inflight(BatchKind::Formed));
        let wins = claimers
            .into_iter()
            .map(|h| h.join().expect("claimer model panicked"))
            .filter(|&won| won)
            .count();
        assert!(wins >= 1, "an uncontended or winning claim must succeed");
        // ordering: SeqCst — post-join read of the lane's total order
        assert_eq!(
            nudges.load(Ordering::SeqCst),
            wins as u64,
            "each retirement fires its nudge exactly once"
        );
        assert!(!gate.inflight(BatchKind::Decode), "every token drop reopened the lane");
        assert!(gate.claim(BatchKind::Decode), "the lane is claimable again after retirement");
        gate.finish(BatchKind::Decode);
    });
}

//! Seeded chaos soak: the serving stack under injected backend faults,
//! panics, cancellations, deadline expiry and drain.
//!
//! The invariants proved here are the robustness contract of ISSUE 6:
//! * every accepted request receives **exactly one** terminal response
//!   (no hangs, no double delivery — the metrics tallies balance),
//! * no session pin leaks (`pinned_sessions() == 0` after drain),
//! * the KV store's `used_bytes` is exactly the bytes of the sessions
//!   still resident, which in turn match the *acknowledged* appends —
//!   a failed append must not grow a session, a cancelled+evicted
//!   session must free its bytes.
//!
//! All fault decisions are content-keyed off a fixed seed
//! (`coordinator::chaos`), so a failure here reproduces exactly.

use std::sync::Arc;
use std::time::Duration;

use hfa::attention::prepared::row_bytes;
use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{ChaosBackend, ChaosConfig, KvStore, ServeError, Server, SimBackend};
use hfa::hw::Arith;
use hfa::proptest::Rng;
use hfa::Mat;

const D: usize = 8;
const SEQ: usize = 32;
const SESSIONS: usize = 64;
const PREFILL: usize = 16;
const ROUNDS: usize = 3;

fn accel() -> AcceleratorConfig {
    AcceleratorConfig { head_dim: D, seq_len: SEQ, kv_blocks: 4, parallel_queries: 1, freq_mhz: 500.0 }
}

fn session_name(s: usize) -> String {
    format!("s{s:02}")
}

fn chaos_factories(workers: usize, chaos: &ChaosConfig) -> Vec<hfa::coordinator::BackendFactory> {
    (0..workers)
        .map(|_| ChaosBackend::wrap_factory(chaos.clone(), SimBackend::factory(Arith::Hfa, accel())))
        .collect()
}

#[test]
fn seeded_soak_reaches_a_consistent_terminal_state() {
    let coord = CoordinatorConfig {
        max_batch: 4,
        max_total_batch: 64,
        batch_window_us: 2_000,
        workers: 3,
        queue_depth: 512,
        // generous live-traffic deadline so only the deliberately
        // pre-expired submits time out, even on slow CI machines
        request_timeout_us: 30_000_000,
        max_pending_requests: 4096,
        max_retries: 3,
        retry_backoff_us: 50,
        worker_respawn_budget: 32,
        ..CoordinatorConfig::default()
    };
    let chaos = ChaosConfig {
        seed: 0xC4A05,
        panic_rate: 0.01,
        fault_rate: 0.15,
        transient_ratio: 0.5,
        transient_failures: 1,
        // a little per-dispatch latency keeps a backlog queued, so the
        // mid-flight cancels below reliably find requests to shed
        latency: Duration::from_millis(2),
    };
    // budget holds every session at full length: the only evictions in
    // this soak are the deliberate cancel+evict ones
    let kv = Arc::new(KvStore::new(SEQ, D, SESSIONS));
    let mut rng = Rng::new(0xC4A05);
    for s in 0..SESSIONS {
        kv.put(
            &session_name(s),
            Mat::from_vec(PREFILL, D, rng.normal_vec(PREFILL * D)),
            Mat::from_vec(PREFILL, D, rng.normal_vec(PREFILL * D)),
        )
        .unwrap();
    }
    let srv = Server::start(&coord, kv.clone(), chaos_factories(coord.workers, &chaos)).unwrap();

    // traffic: per round, every session attends once and every fourth
    // session appends one decode row; all reply handles are held so no
    // request is implicitly cancelled
    enum Kind {
        Query,
        Append,
        Expired,
    }
    let mut pending: Vec<(usize, Kind, hfa::coordinator::ResponseHandle)> = Vec::new();
    for _round in 0..ROUNDS {
        for s in 0..SESSIONS {
            let name = session_name(s);
            let rx = srv.submit(&name, rng.normal_vec(D)).expect("submit within bounds");
            pending.push((s, Kind::Query, rx));
            if s % 4 == 1 {
                let rx = srv
                    .submit_append(
                        &name,
                        Mat::from_vec(1, D, rng.normal_vec(D)),
                        Mat::from_vec(1, D, rng.normal_vec(D)),
                    )
                    .expect("append submit within bounds");
                pending.push((s, Kind::Append, rx));
            }
        }
    }
    // a few requests arrive already expired: they must be shed, not served
    for s in 0..4 {
        let rx = srv
            .submit_with_deadline(&session_name(s), rng.normal_vec(D), std::time::Instant::now())
            .expect("expired submit is still admitted");
        pending.push((s, Kind::Expired, rx));
    }
    // cancel the last four sessions mid-flight and evict their KV: their
    // queued requests fail and their bytes come back
    for s in SESSIONS - 4..SESSIONS {
        srv.cancel(&session_name(s), true);
    }

    // every request: exactly one terminal response, within a bound
    let submitted = pending.len();
    let mut acked_appends = vec![0usize; SESSIONS];
    let mut terminal = 0usize;
    for (s, kind, rx) in &pending {
        let resp = rx
            .recv_timeout(Duration::from_secs(60))
            .unwrap_or_else(|e| panic!("session {s}: no terminal response: {e}"));
        terminal += 1;
        match kind {
            Kind::Append => {
                if resp.ok() {
                    acked_appends[*s] += 1;
                }
            }
            Kind::Expired => {
                assert_eq!(
                    resp.output,
                    Err(ServeError::TimedOut),
                    "pre-expired request must be shed as TimedOut"
                );
            }
            Kind::Query => {
                // chaos may fail it (permanent faults stay failed by
                // design); what matters is the response is explicit
                if let Err(e) = &resp.output {
                    assert!(
                        !matches!(e, ServeError::TimedOut),
                        "live query must not time out, got {e}"
                    );
                }
            }
        }
    }
    assert_eq!(terminal, submitted, "every request gets exactly one terminal response");

    // drain: admissions close, in-flight work is already done, teardown
    // is clean
    let metrics = Arc::clone(&srv.metrics);
    let report = srv.drain(Duration::from_secs(30));
    assert!(report.clean, "drain must complete cleanly: {report}");
    assert_eq!(
        report.force_failed, 0,
        "nothing was in flight at drain, so nothing may be force-failed: {report}"
    );
    assert_eq!(
        report.served, 0,
        "every terminal response landed before the drain began: {report}"
    );

    // invariant: no leaked pins
    assert_eq!(kv.pinned_sessions(), 0, "no session pin may leak through the chaos");

    // invariant: exact byte accounting.  Resident sessions hold exactly
    // PREFILL + acknowledged appends rows; evicted sessions hold none.
    let mut expected_bytes = 0usize;
    for s in 0..SESSIONS {
        let name = session_name(s);
        match kv.get(&name) {
            Some(entry) => {
                let rows = entry.prepared().n();
                assert_eq!(
                    rows,
                    PREFILL + acked_appends[s],
                    "session {name}: resident rows must equal prefill + acked appends"
                );
                expected_bytes += rows * row_bytes(D, D);
            }
            None => {
                assert!(
                    s >= SESSIONS - 4,
                    "session {name} vanished without a cancel+evict"
                );
            }
        }
    }
    assert_eq!(kv.used_bytes(), expected_bytes, "used_bytes must match resident rows exactly");

    // invariant: the terminal tallies balance — every accepted request
    // is exactly one of completed / append-acked / failed, and nothing
    // was delivered into a dropped channel
    let snap = metrics.snapshot();
    assert_eq!(snap.accepted, submitted as u64);
    assert_eq!(
        snap.completed + snap.appends + snap.failed,
        snap.accepted,
        "terminal outcomes must balance accepted requests: {snap:?}"
    );
    assert_eq!(snap.delivery_lost, 0, "all receivers were held: {snap:?}");
    assert_eq!(snap.inflight, 0);
    assert!(snap.timed_out >= 4, "the pre-expired submits must be shed: {snap:?}");
    assert!(snap.cancelled > 0, "the cancelled sessions had queued requests: {snap:?}");
    // the seeded fault plan injects both kinds of faults at these rates
    assert!(snap.failed > snap.timed_out + snap.cancelled, "chaos must fail some queries: {snap:?}");
    assert!(snap.retries > 0, "transient faults must trigger retries: {snap:?}");
}

#[test]
fn transient_faults_recover_through_server_retries() {
    // every dispatch entry faults transiently exactly once: with retries
    // enabled every query must still succeed, and the retry counter
    // must show the loop earned those successes
    let coord = CoordinatorConfig {
        max_batch: 4,
        max_total_batch: 64,
        batch_window_us: 1_000,
        workers: 2,
        queue_depth: 64,
        max_retries: 2,
        retry_backoff_us: 10,
        ..CoordinatorConfig::default()
    };
    let chaos = ChaosConfig {
        seed: 7,
        fault_rate: 1.0,
        transient_ratio: 1.0,
        transient_failures: 1,
        ..ChaosConfig::default()
    };
    let kv = Arc::new(KvStore::new(SEQ, D, 4));
    let mut rng = Rng::new(77);
    kv.put(
        "sess",
        Mat::from_vec(SEQ, D, rng.normal_vec(SEQ * D)),
        Mat::from_vec(SEQ, D, rng.normal_vec(SEQ * D)),
    )
    .unwrap();
    let srv = Server::start(&coord, kv, chaos_factories(coord.workers, &chaos)).unwrap();
    for i in 0..16 {
        let resp = srv.call("sess", rng.normal_vec(D)).unwrap();
        assert!(resp.ok(), "query {i} must recover through retry: {:?}", resp.output);
    }
    let snap = srv.metrics.snapshot();
    assert_eq!(snap.completed, 16);
    assert_eq!(snap.failed, 0, "transient faults must never surface with retries on");
    assert!(snap.retries >= 16, "every query faulted once before recovering: {snap:?}");
    srv.shutdown();
}

#[test]
fn chaos_outputs_match_the_faultless_backend_bit_for_bit() {
    // robustness must not buy accuracy drift: answers served through an
    // active chaos wrapper (transient faults + retries) are bit-identical
    // to the plain SimBackend's
    let coord = CoordinatorConfig {
        max_batch: 4,
        max_total_batch: 64,
        batch_window_us: 500,
        workers: 1,
        queue_depth: 64,
        max_retries: 2,
        retry_backoff_us: 10,
        ..CoordinatorConfig::default()
    };
    let chaos = ChaosConfig {
        seed: 13,
        fault_rate: 1.0,
        transient_ratio: 1.0,
        transient_failures: 1,
        ..ChaosConfig::default()
    };
    let mut rng = Rng::new(13);
    let k = Mat::from_vec(SEQ, D, rng.normal_vec(SEQ * D));
    let v = Mat::from_vec(SEQ, D, rng.normal_vec(SEQ * D));
    let queries: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(D)).collect();

    let serve = |factories: Vec<hfa::coordinator::BackendFactory>| -> Vec<Vec<f32>> {
        let kv = Arc::new(KvStore::new(SEQ, D, 4));
        kv.put("sess", k.clone(), v.clone()).unwrap();
        let srv = Server::start(&coord, kv, factories).unwrap();
        let outs = queries
            .iter()
            .map(|q| {
                let r = srv.call("sess", q.clone()).unwrap();
                r.output.unwrap_or_else(|e| panic!("query must serve: {e}"))
            })
            .collect();
        srv.shutdown();
        outs
    };

    let chaotic = serve(chaos_factories(1, &chaos));
    let plain = serve(vec![SimBackend::factory(Arith::Hfa, accel())]);
    assert_eq!(chaotic, plain, "fault injection must never perturb served outputs");
}

#[test]
fn panic_heavy_chaos_fails_explicitly_once_the_respawn_budget_is_spent() {
    // panic_rate 1.0: every dispatch kills its backend.  With a budget
    // of one respawn, callers get explicit backend errors while the
    // watchdog lasts and an explicit shutdown error after — never a hang.
    let coord = CoordinatorConfig {
        max_batch: 1,
        max_total_batch: 64,
        batch_window_us: 100,
        workers: 1,
        queue_depth: 16,
        worker_respawn_budget: 1,
        ..CoordinatorConfig::default()
    };
    let chaos = ChaosConfig { seed: 3, panic_rate: 1.0, ..ChaosConfig::default() };
    let kv = Arc::new(KvStore::new(SEQ, D, 4));
    let mut rng = Rng::new(3);
    kv.put(
        "sess",
        Mat::from_vec(SEQ, D, rng.normal_vec(SEQ * D)),
        Mat::from_vec(SEQ, D, rng.normal_vec(SEQ * D)),
    )
    .unwrap();
    let srv = Server::start(&coord, kv.clone(), chaos_factories(1, &chaos)).unwrap();
    for i in 0..2 {
        let resp = srv.call("sess", rng.normal_vec(D)).unwrap();
        assert!(!resp.ok(), "dispatch {i} must fail");
        assert!(
            resp.output.unwrap_err().to_string().contains("panicked"),
            "dispatch {i}: caller must learn of the crash"
        );
    }
    std::thread::sleep(Duration::from_millis(200)); // let the final unwind land
    assert_eq!(srv.metrics.snapshot().worker_respawns, 1);
    let resp = srv.call("sess", rng.normal_vec(D)).unwrap();
    assert!(
        matches!(resp.output, Err(ServeError::Shutdown(_))),
        "past the budget the pool is gone: {:?}",
        resp.output
    );
    assert_eq!(kv.pinned_sessions(), 0, "panic paths must not leak pins");
    srv.shutdown();
}

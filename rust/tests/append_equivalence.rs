//! Tier-1 pin of the append-only decode path: a `PreparedKv` grown by
//! prefill + appends must be **bitwise identical** to `PreparedKv::new`
//! over the full matrices — raw BF16 planes, resident LNS lanes, stored
//! block partition, and every attention entry point — across ragged
//! tails and varied append sizes.  Same property through the `KvStore`
//! swap-in path.

use std::sync::Arc;

use hfa::attention::prepared::{fixed_block_ranges, PreparedKv};
use hfa::coordinator::KvStore;
use hfa::proptest::Rng;
use hfa::Mat;

fn bits(data: &[f32]) -> Vec<u32> {
    data.iter().map(|x| x.to_bits()).collect()
}

fn assert_prepared_identical(grown: &PreparedKv, full: &PreparedKv, ctx: &str) {
    assert_eq!(grown.n(), full.n(), "{ctx}: row count");
    assert_eq!(grown.d(), full.d(), "{ctx}: key dim");
    assert_eq!(grown.dv(), full.dv(), "{ctx}: value dim");
    assert_eq!(bits(&grown.k_mat().data), bits(&full.k_mat().data), "{ctx}: K plane");
    assert_eq!(bits(&grown.v_mat().data), bits(&full.v_mat().data), "{ctx}: V plane");
    assert_eq!(grown.v_lns_mat(), full.v_lns_mat(), "{ctx}: LNS lanes");
    assert_eq!(grown.block_rows(), full.block_rows(), "{ctx}: block capacity");
    assert_eq!(grown.blocks(), full.blocks(), "{ctx}: block partition");
    assert_eq!(
        grown.blocks(),
        fixed_block_ranges(grown.n(), grown.block_rows()),
        "{ctx}: partition must match the from-scratch formula"
    );
    // the chunk table is the partition: per-chunk planes must agree too
    assert_eq!(grown.chunks().len(), full.chunks().len(), "{ctx}: chunk count");
    for (ci, (g, f)) in grown.chunks().iter().zip(full.chunks()).enumerate() {
        assert_eq!(g.rows(), f.rows(), "{ctx}: chunk {ci} rows");
        assert_eq!(bits(&g.k().data), bits(&f.k().data), "{ctx}: chunk {ci} K");
        assert_eq!(bits(&g.v().data), bits(&f.v().data), "{ctx}: chunk {ci} V");
        assert_eq!(g.v_lns(), f.v_lns(), "{ctx}: chunk {ci} lanes");
    }
}

#[test]
fn prefill_plus_appends_bit_identical_to_full_build() {
    let mut rng = Rng::new(20_260_701);
    // (total rows, prefill, append chunk sizes, stored block capacity):
    // covers single-row decode steps, multi-row chunks, tails that stay
    // ragged, tails that exactly fill, and a zero-row prefill
    let cases: &[(usize, usize, &[usize], usize)] = &[
        (9, 4, &[1, 1, 1, 1, 1], 4),
        (21, 4, &[1, 3, 8, 5], 8),
        (16, 8, &[8], 8),
        (13, 1, &[2, 2, 2, 2, 2, 2], 256),
        (7, 0, &[3, 4], 2),
        (33, 32, &[1], 16),
    ];
    for &(total, prefill, chunks, br) in cases {
        assert_eq!(prefill + chunks.iter().sum::<usize>(), total, "bad case spec");
        let d = 8;
        let k = Mat::from_vec(total, d, rng.normal_vec(total * d)).round_bf16();
        let v = Mat::from_vec(total, d, rng.normal_vec(total * d)).round_bf16();
        let ctx = format!("total={total} prefill={prefill} chunks={chunks:?} br={br}");

        let full = PreparedKv::with_block_rows(k.clone(), v.clone(), br);
        let mut grown = PreparedKv::with_block_rows(
            k.rows_slice(0, prefill),
            v.rows_slice(0, prefill),
            br,
        );
        let mut at = prefill;
        for &step in chunks {
            grown.append(&k.rows_slice(at, at + step), &v.rows_slice(at, at + step));
            at += step;
            // the partition must stay canonical after *every* append
            assert_eq!(grown.blocks(), fixed_block_ranges(at, br), "{ctx} at={at}");
        }
        assert_prepared_identical(&grown, &full, &ctx);

        // every attention entry point agrees bit-for-bit
        let q = Mat::from_vec(3, d, rng.normal_vec(3 * d)).round_bf16();
        assert_eq!(
            bits(&grown.attention(&q, None, None).data),
            bits(&full.attention(&q, None, None).data),
            "{ctx}: full attention"
        );
        assert_eq!(
            bits(&grown.attention_blocked(&q, 3, None).data),
            bits(&full.attention_blocked(&q, 3, None).data),
            "{ctx}: count-blocked attention"
        );
        assert_eq!(
            bits(&grown.attention_resident_blocks(&q, None).data),
            bits(&full.attention_resident_blocks(&q, None).data),
            "{ctx}: resident-block attention"
        );
    }
}

#[test]
fn kvstore_append_path_bit_identical_to_full_put() {
    let mut rng = Rng::new(77_001);
    let (n, d) = (40usize, 8usize);
    let k = Mat::from_vec(n, d, rng.normal_vec(n * d));
    let v = Mat::from_vec(n, d, rng.normal_vec(n * d));

    let grown_store = KvStore::new(64, d, 2);
    grown_store.put("s", k.rows_slice(0, 25), v.rows_slice(0, 25)).unwrap();
    let mut at = 25;
    for step in [1usize, 1, 6, 7] {
        grown_store
            .append("s", k.rows_slice(at, at + step), v.rows_slice(at, at + step))
            .unwrap();
        at += step;
    }
    assert_eq!(at, n);

    let full_store = KvStore::new(64, d, 2);
    full_store.put("s", k.clone(), v.clone()).unwrap();

    let grown = grown_store.get("s").unwrap();
    let full = full_store.get("s").unwrap();
    assert_prepared_identical(grown.prepared().as_ref(), full.prepared().as_ref(), "kvstore");

    // and the prepared sets drive attention identically
    let q = Mat::from_vec(2, d, rng.normal_vec(2 * d)).round_bf16();
    assert_eq!(
        bits(&grown.prepared().attention_blocked(&q, 4, None).data),
        bits(&full.prepared().attention_blocked(&q, 4, None).data),
    );
}

#[test]
fn appended_snapshot_isolation_under_sharing() {
    // the store-style copy-on-write: growing a shared Arc'd PreparedKv
    // must not disturb readers of the old snapshot
    let mut rng = Rng::new(4_242);
    let d = 4;
    let k = Mat::from_vec(6, d, rng.normal_vec(6 * d)).round_bf16();
    let v = Mat::from_vec(6, d, rng.normal_vec(6 * d)).round_bf16();
    let base = Arc::new(PreparedKv::new(k.clone(), v.clone()));
    let q = Mat::from_vec(1, d, rng.normal_vec(d)).round_bf16();
    let before = base.attention(&q, None, None);

    let k2 = Mat::from_vec(2, d, rng.normal_vec(2 * d)).round_bf16();
    let v2 = Mat::from_vec(2, d, rng.normal_vec(2 * d)).round_bf16();
    let grown = base.appended(&k2, &v2);

    assert_eq!(base.n(), 6);
    assert_eq!(grown.n(), 8);
    assert_eq!(bits(&base.attention(&q, None, None).data), bits(&before.data));

    let mut full_k = k.clone();
    full_k.append_rows(&k2);
    let mut full_v = v.clone();
    full_v.append_rows(&v2);
    let full = PreparedKv::new(full_k, full_v);
    assert_prepared_identical(&grown, &full, "shared-append");
}

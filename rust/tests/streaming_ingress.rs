//! Streaming ingress end-to-end over real sockets: bit-exactness of the
//! streamed path against in-process solo serving, a seeded
//! chaos-scripted soak of concurrent misbehaving connections
//! ([`ConnChaos`]), and drain-with-in-flight-stream semantics.
//!
//! The invariants proved here extend the robustness contract of the
//! chaos soak (`tests/chaos_serving.rs`) across the wire:
//! * streamed token outputs are **bit-identical** to `append`+`call`
//!   against an in-process server,
//! * every behaving stream sees every token and **exactly one**
//!   terminal frame, under concurrent disconnects and torn frames,
//! * a mid-stream disconnect cancels the stream and evicts its session
//!   (no KV pin or byte leaks — `used_bytes` is exact after drain),
//! * drain lets an in-flight stream finish its terminal frames.
//!
//! All client misbehavior is drawn from a fixed [`ConnChaos`] seed, so
//! a failure here replays exactly.  (The slow-consumer *shed* policy is
//! proved deterministically at the write-queue layer in
//! `coordinator::ingress::stream`'s unit tests, where a stall does not
//! race socket buffering.)

use std::sync::Arc;
use std::time::Duration;

use hfa::attention::prepared::row_bytes;
use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{
    ChaosBackend, ChaosConfig, Client, ConnChaos, ConnFate, Ingress, KvStore, Server, SimBackend,
    StreamEvent, StreamStep,
};
use hfa::hw::Arith;
use hfa::proptest::Rng;
use hfa::Mat;

const D: usize = 8;
const SEQ: usize = 32;
const PREFILL: usize = 2;
const STEPS: usize = 8;

fn accel() -> AcceleratorConfig {
    AcceleratorConfig { head_dim: D, seq_len: SEQ, kv_blocks: 4, parallel_queries: 1, freq_mhz: 500.0 }
}

fn coord(workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        queue_depth: 512,
        max_pending_requests: 4096,
        request_timeout_us: 30_000_000,
        ingress_max_connections: 64,
        ingress_max_requests: 1024,
        ingress_write_queue: 8,
        // generous: this suite's deliberate stalls are short pauses that
        // must be *tolerated*; the shed policy itself is unit-tested at
        // the write-queue layer where it cannot race socket buffering
        ingress_stall_budget_us: 30_000_000,
        ..CoordinatorConfig::default()
    }
}

/// An ingress over plain Sim backends (optionally slowed per dispatch,
/// so streams stay in flight long enough to disconnect mid-way).
fn bind(c: &CoordinatorConfig, sessions: usize, latency: Duration) -> (Ingress, Arc<KvStore>) {
    let kv = Arc::new(KvStore::new(SEQ, D, sessions));
    let factories: Vec<hfa::coordinator::BackendFactory> = (0..c.workers)
        .map(|_| {
            if latency.is_zero() {
                SimBackend::factory(Arith::Hfa, accel())
            } else {
                ChaosBackend::wrap_factory(
                    ChaosConfig { latency, ..ChaosConfig::default() },
                    SimBackend::factory(Arith::Hfa, accel()),
                )
            }
        })
        .collect();
    let srv = Server::start(c, kv.clone(), factories).expect("server starts");
    (Ingress::bind("127.0.0.1:0", srv, c).expect("ingress binds"), kv)
}

fn prefill(rng: &mut Rng) -> (Mat, Mat) {
    (
        Mat::from_vec(PREFILL, D, rng.normal_vec(PREFILL * D)),
        Mat::from_vec(PREFILL, D, rng.normal_vec(PREFILL * D)),
    )
}

fn plan(rng: &mut Rng, steps: usize) -> Vec<StreamStep> {
    (0..steps)
        .map(|_| StreamStep {
            k: Mat::from_vec(1, D, rng.normal_vec(D)),
            v: Mat::from_vec(1, D, rng.normal_vec(D)),
            q: rng.normal_vec(D),
        })
        .collect()
}

// The headline accuracy contract of the ISSUE: outputs streamed over
// the socket are bit-identical to the same decode loop served solo by
// an in-process server — framing, threading and backpressure must never
// perturb a single mantissa bit.
#[test]
fn streamed_tokens_match_in_process_solo_serving_bit_for_bit() {
    let c = coord(2);
    let mut rng = Rng::new(0x51B);
    let (k0, v0) = prefill(&mut rng);
    let steps = plan(&mut rng, STEPS);

    // solo path: in-process append + call, one step at a time
    let kv = Arc::new(KvStore::new(SEQ, D, 4));
    kv.put("solo", k0.clone(), v0.clone()).unwrap();
    let srv = Server::start(
        &c,
        kv,
        (0..c.workers).map(|_| SimBackend::factory(Arith::Hfa, accel())).collect(),
    )
    .unwrap();
    let mut solo = Vec::new();
    for s in &steps {
        assert!(srv.append("solo", s.k.clone(), s.v.clone()).unwrap().ok());
        solo.push(srv.call("solo", s.q.clone()).unwrap().output.unwrap());
    }
    srv.shutdown();

    // streamed path: the same loop over the wire
    let (ing, _kv) = bind(&c, 4, Duration::ZERO);
    let mut cl = Client::connect(&ing.local_addr()).unwrap();
    cl.put("wire", k0, v0).unwrap();
    let events = cl.stream("wire", steps).unwrap();
    let streamed: Vec<Vec<f32>> = events
        .iter()
        .filter_map(|e| match e {
            StreamEvent::Token { out, .. } => Some(out.clone()),
            _ => None,
        })
        .collect();
    assert_eq!(*events.last().unwrap(), StreamEvent::End { steps: STEPS as u32 });
    cl.goodbye().unwrap();
    let report = ing.drain(Duration::from_secs(10));
    assert!(report.clean(), "{report}");

    assert_eq!(streamed.len(), solo.len());
    for (i, (a, b)) in streamed.iter().zip(&solo).enumerate() {
        let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ab, bb, "step {i}: streamed output must be bit-identical to solo");
    }
}

// What each scripted connection of the soak observed, for the
// exactly-one-terminal and byte-accounting checks after drain.
struct Verdict {
    fate: ConnFate,
    tokens: usize,
    ends: usize,
    errors: usize,
}

// The soak: 40 concurrent connections, each scripted by its seeded
// [`ConnFate`] — behave, disconnect mid-stream, pause mid-read (within
// the stall budget), or send a torn frame.  Afterwards: every behaving
// stream got every token and exactly one terminal, every mid-stream
// disconnect was detected and its session evicted, and the KV store's
// byte accounting is exact.
#[test]
fn seeded_connection_chaos_soak_keeps_terminals_and_bytes_exact() {
    const CONNS: usize = 40;
    let chaos = ConnChaos {
        seed: 0x50AC,
        disconnect_rate: 0.25,
        stall_rate: 0.25,
        torn_rate: 0.15,
        max_step: 4,
    };
    // slow each dispatch so streams are still in flight when their
    // clients disconnect (production paces delivery: a token arrives
    // only after its compute, so a disconnect after n tokens lands with
    // >= 2 steps still to serve)
    let c = coord(3);
    let (ing, kv) = bind(&c, CONNS, Duration::from_millis(25));
    let addr = ing.local_addr();
    let metrics = ing.metrics();

    let workers: Vec<_> = (0..CONNS)
        .map(|i| {
            let fate = chaos.fate(&format!("s{i:02}"));
            std::thread::spawn(move || -> Verdict {
                let mut v = Verdict { fate, tokens: 0, ends: 0, errors: 0 };
                let mut rng = Rng::new(0x50AC ^ ((i as u64) << 8));
                let sess = format!("s{i:02}");
                let mut cl = Client::connect(&addr).expect("connect");
                if fate == ConnFate::TornFrame {
                    // a length prefix promising 100 bytes, then 4, then FIN
                    use std::io::Write;
                    let mut torn = 100u32.to_le_bytes().to_vec();
                    torn.extend_from_slice(&[0x03, 0, 0, 0]);
                    let mut sock = cl.socket();
                    sock.write_all(&torn).expect("torn write");
                    return v; // drop disconnects
                }
                let (k0, v0) = prefill(&mut rng);
                cl.put(&sess, k0, v0).expect("put");
                cl.start_stream(&sess, plan(&mut rng, STEPS)).expect("stream");
                loop {
                    match fate {
                        ConnFate::DisconnectAfter(n) if v.tokens == n as usize => return v,
                        ConnFate::StallBefore(n) if v.tokens == n as usize => {
                            // a recoverable pause: well within the budget
                            std::thread::sleep(Duration::from_millis(150));
                        }
                        _ => {}
                    }
                    match cl.next_event().expect("event") {
                        StreamEvent::Token { .. } => v.tokens += 1,
                        StreamEvent::End { steps } => {
                            assert_eq!(steps as usize, STEPS, "{sess}");
                            v.ends += 1;
                            break;
                        }
                        StreamEvent::Failed { detail, .. } => {
                            panic!("{sess}: unexpected stream failure: {detail}");
                        }
                    }
                }
                cl.goodbye().expect("goodbye");
                v
            })
        })
        .collect();
    let verdicts: Vec<Verdict> =
        workers.into_iter().map(|h| h.join().expect("soak client panicked")).collect();

    // the seed must actually exercise every band (documented, not drawn
    // at runtime: the fates are pure functions of seed + key)
    let count = |f: fn(&ConnFate) -> bool| verdicts.iter().filter(|v| f(&v.fate)).count();
    let healthy = count(|f| matches!(f, ConnFate::Healthy));
    let paused = count(|f| matches!(f, ConnFate::StallBefore(_)));
    let dropped = count(|f| matches!(f, ConnFate::DisconnectAfter(_)));
    let torn = count(|f| matches!(f, ConnFate::TornFrame));
    assert!(healthy > 0 && paused > 0 && dropped > 0 && torn > 0, "seed must hit every band");

    // exactly one terminal per behaving stream, every token delivered
    for v in &verdicts {
        match v.fate {
            ConnFate::Healthy | ConnFate::StallBefore(_) => {
                assert_eq!((v.tokens, v.ends, v.errors), (STEPS, 1, 0), "fate {:?}", v.fate);
            }
            ConnFate::DisconnectAfter(n) => assert_eq!(v.tokens, n as usize),
            ConnFate::TornFrame => assert_eq!((v.tokens, v.ends), (0, 0)),
        }
    }

    let report = ing.drain(Duration::from_secs(60));
    assert!(report.clean(), "soak teardown must be graceful: {report}");

    // byte accounting: behaving sessions hold prefill + every appended
    // step; disconnected sessions were evicted; torn ones never existed
    assert_eq!(kv.pinned_sessions(), 0, "no pin may leak");
    let mut expected = 0usize;
    for (i, v) in verdicts.iter().enumerate() {
        let sess = format!("s{i:02}");
        match v.fate {
            ConnFate::Healthy | ConnFate::StallBefore(_) => {
                let entry = kv.get(&sess).unwrap_or_else(|| panic!("{sess} must stay resident"));
                assert_eq!(entry.prepared().n(), PREFILL + STEPS, "{sess}");
                expected += (PREFILL + STEPS) * row_bytes(D, D);
            }
            ConnFate::DisconnectAfter(_) => {
                assert!(kv.get(&sess).is_none(), "{sess}: disconnect must evict the session");
            }
            ConnFate::TornFrame => assert!(kv.get(&sess).is_none(), "{sess}"),
        }
    }
    assert_eq!(kv.used_bytes(), expected, "used_bytes must match resident rows exactly");

    // the wire-level tallies agree with the script
    let snap = metrics.snapshot();
    assert_eq!(snap.conns_accepted, CONNS as u64, "{snap:?}");
    assert_eq!(snap.streams_opened, (healthy + paused + dropped) as u64, "{snap:?}");
    assert!(
        snap.disconnects >= (dropped + torn) as u64,
        "every drop and torn frame is a detected disconnect: {snap:?}"
    );
    assert_eq!(snap.slow_consumer_shed, 0, "pauses stay within the budget: {snap:?}");
    assert!(
        snap.sessions_evicted >= dropped as u64,
        "each mid-stream disconnect evicts its session: {snap:?}"
    );
    // behaving streams account for an exact floor; disconnected streams
    // may have queued a few more tokens before their shed step
    assert!(
        snap.stream_tokens >= ((healthy + paused) * STEPS) as u64,
        "behaving streams alone account for {} tokens: {snap:?}",
        (healthy + paused) * STEPS
    );
    assert!(snap.first_token_p99_us > 0.0, "first-token span must be sampled: {snap:?}");
    assert!(snap.inter_token_p99_us > 0.0, "inter-token span must be sampled: {snap:?}");
}

// Drain with a stream in flight: the stream finishes, its terminal End
// lands on the wire, the connection is told Bye — nothing is torn down
// under the client.
#[test]
fn drain_lets_an_in_flight_stream_finish_its_terminal_frames() {
    let c = coord(2);
    let (ing, _kv) = bind(&c, 4, Duration::from_millis(20));
    let addr = ing.local_addr();
    let client = std::thread::spawn(move || {
        let mut rng = Rng::new(0xD12A);
        let mut cl = Client::connect(&addr).expect("connect");
        let (k0, v0) = prefill(&mut rng);
        cl.put("live", k0, v0).expect("put");
        let events = cl.stream("live", plan(&mut rng, STEPS)).expect("stream");
        let tokens = events.iter().filter(|e| matches!(e, StreamEvent::Token { .. })).count();
        assert_eq!(tokens, STEPS, "drain must let every token land");
        assert_eq!(*events.last().unwrap(), StreamEvent::End { steps: STEPS as u32 });
        // the draining server closes the conversation explicitly
        assert!(cl.goodbye().is_ok());
    });
    // let the stream get in flight, then drain around it
    std::thread::sleep(Duration::from_millis(60));
    let report = ing.drain(Duration::from_secs(30));
    client.join().expect("client panicked");
    assert!(report.clean(), "in-flight stream must finish gracefully: {report}");
    assert_eq!(report.forced_conns, 0, "{report}");
    assert!(report.server.clean, "{report}");
}

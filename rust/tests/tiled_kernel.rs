//! Bit-exactness of the query-tiled, two-axis-parallel kernel: for
//! every query-tile height, KV-block count and chunk capacity — ragged
//! or not, batch 1 or batch >> tile — the grid-scheduled path must
//! produce byte-identical outputs to the seed per-row datapath
//! (`HfaState::step` one query at a time, sequential block walk,
//! in-order Eq. 16 merges).  The references below are written straight
//! from the public primitives, independent of the kernel under test.

use hfa::attention::hfa::{value_to_lns, HfaState};
use hfa::attention::merge::merge_hfa;
use hfa::attention::prepared::{kv_block_ranges, PreparedKv};
use hfa::proptest::Rng;
use hfa::tensor::dot_f32;
use hfa::Mat;

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

fn rand_case(rng: &mut Rng, b: usize, n: usize, d: usize) -> (Mat, Mat, Mat) {
    (
        Mat::from_vec(b, d, rng.normal_vec(b * d)).round_bf16(),
        Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
        Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
    )
}

/// Seed blocked reference: per query, walk each count-driven block
/// serially (per-row `step`), then merge the per-block partials in
/// block order — exactly the pre-kernel algorithm.
fn seed_blocked_attention(q: &Mat, k: &Mat, v: &Mat, num_blocks: usize) -> Mat {
    let n = k.rows;
    let scale = 1.0 / (q.cols as f32).sqrt();
    let v_lns: Vec<_> = (0..n).map(|i| value_to_lns(v.row(i), &mut None)).collect();
    let mut out = Mat::zeros(q.rows, v.cols);
    for bi in 0..q.rows {
        let mut acc: Option<HfaState> = None;
        for (lo, hi) in kv_block_ranges(n, num_blocks) {
            let mut st = HfaState::new(v.cols);
            for i in lo..hi {
                let s = dot_f32(q.row(bi), k.row(i)) * scale;
                st.step(s, &v_lns[i], &mut None);
            }
            acc = Some(match acc {
                None => st,
                Some(prev) => merge_hfa(&prev, &st, &mut None),
            });
        }
        let st = acc.unwrap_or_else(|| HfaState::new(v.cols));
        out.row_mut(bi).copy_from_slice(&st.finalize());
    }
    out
}

/// Seed masked reference over one KV range: per query, per row, skip
/// masked pairs (mask is `(B, hi-lo)` relative to the range).
fn seed_masked_states(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    lo: usize,
    hi: usize,
    mask: Option<&[bool]>,
) -> Vec<HfaState> {
    let span = hi - lo;
    let scale = 1.0 / (q.cols as f32).sqrt();
    let v_lns: Vec<_> = (lo..hi).map(|i| value_to_lns(v.row(i), &mut None)).collect();
    (0..q.rows)
        .map(|bi| {
            let mut st = HfaState::new(v.cols);
            for i in 0..span {
                if mask.map(|m| !m[bi * span + i]).unwrap_or(false) {
                    continue;
                }
                let s = dot_f32(q.row(bi), k.row(lo + i)) * scale;
                st.step(s, &v_lns[i], &mut None);
            }
            st
        })
        .collect()
}

fn assert_states_eq(got: &[HfaState], want: &[HfaState], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: state count");
    for (qi, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.m.to_bits(), w.m.to_bits(), "{ctx}: query {qi} running max");
        assert_eq!(g.acc, w.acc, "{ctx}: query {qi} accumulator lanes");
    }
}

#[test]
fn tiled_grid_bit_identical_to_seed_per_row_path() {
    // sweep (B, N, d) x chunk capacity x block count x tile height,
    // covering B=1 (decode), B < tile, B/N not divisible by anything,
    // N < block count, and tiles above the clamp
    let mut rng = Rng::new(20_260_728);
    let cases: &[(usize, usize, usize)] = &[
        (1, 8, 4),   // decode step, tiny KV
        (1, 37, 8),  // decode step, ragged N
        (2, 16, 8),
        (5, 33, 8),  // nothing divides anything
        (8, 64, 16), // even geometry
        (3, 1, 4),   // single KV row
        (17, 40, 8), // B not divisible by any tile below
    ];
    for &(b, n, d) in cases {
        let (q, k, v) = rand_case(&mut rng, b, n, d);
        for &br in &[5usize, 16, 256] {
            let kv = PreparedKv::with_block_rows(k.clone(), v.clone(), br);
            for &p in &[1usize, 2, 4, 7] {
                let seed = seed_blocked_attention(&q, &k, &v, p);
                for &qt in &[1usize, 2, 3, 8, 64] {
                    let got = kv.attention_tiled(&q, p, None, qt);
                    assert_eq!(
                        bits(&got),
                        bits(&seed),
                        "b={b} n={n} d={d} br={br} p={p} qt={qt}"
                    );
                }
                // the default-tile entry point is the same grid
                assert_eq!(
                    bits(&kv.attention_blocked(&q, p, None)),
                    bits(&seed),
                    "b={b} n={n} d={d} br={br} p={p} default tile"
                );
            }
            // unblocked full path == p=1 reference
            let seed1 = seed_blocked_attention(&q, &k, &v, 1);
            assert_eq!(
                bits(&kv.attention(&q, None, None)),
                bits(&seed1),
                "b={b} n={n} d={d} br={br} full"
            );
        }
    }
}

#[test]
fn dense_golden_path_rides_the_same_grid_bit_identically() {
    // hfa::attention_blocked (dense borrowed planes) now grid-schedules
    // too; it must still match the seed merge chain exactly
    let mut rng = Rng::new(31_337);
    for &(b, n, d, p) in &[(1usize, 24usize, 8usize, 4usize), (9, 33, 8, 3), (4, 7, 4, 8)] {
        let (q, k, v) = rand_case(&mut rng, b, n, d);
        let seed = seed_blocked_attention(&q, &k, &v, p);
        let got = hfa::attention::hfa::attention_blocked(&q, &k, &v, p, None, &mut None);
        assert_eq!(bits(&got), bits(&seed), "dense b={b} n={n} d={d} p={p}");
    }
}

#[test]
fn masked_tiled_kernel_bit_exact_across_chunk_crossing_ranges() {
    // chunk capacity 8 on n=37: the ranges below start/end mid-chunk and
    // cross one or more chunk boundaries.  Random masks must match the
    // seed skip-semantics bitwise, and an all-true mask must be
    // indistinguishable from no mask at all (the hoisted mask rows must
    // not perturb the unmasked fast path).
    let mut rng = Rng::new(77_003);
    let (b, n, d) = (5usize, 37usize, 8usize);
    let (q, k, v) = rand_case(&mut rng, b, n, d);
    let kv = PreparedKv::with_block_rows(k.clone(), v.clone(), 8);
    for &(lo, hi) in &[(0usize, 37usize), (4, 12), (7, 25), (30, 37)] {
        let span = hi - lo;
        let ctx = format!("range [{lo}, {hi})");
        let view = kv.view(lo, hi);

        let mask: Vec<bool> = (0..b * span).map(|_| rng.below(3) != 0).collect();
        let got = view.partial_states(&q, None, Some(&mask));
        let want = seed_masked_states(&q, &k, &v, lo, hi, Some(&mask));
        assert_states_eq(&got, &want, &format!("{ctx} random mask"));

        let all_true = vec![true; b * span];
        let with_mask = view.partial_states(&q, None, Some(&all_true));
        let without = view.partial_states(&q, None, None);
        assert_states_eq(&with_mask, &without, &format!("{ctx} all-true vs none"));
        let unmasked_seed = seed_masked_states(&q, &k, &v, lo, hi, None);
        assert_states_eq(&without, &unmasked_seed, &format!("{ctx} unmasked"));
    }
}

#[test]
fn batch_one_grid_equals_batch_one_sequential() {
    // the decode configuration the grid exists for: one query, many
    // resident blocks — the parallel schedule must not change a bit
    let mut rng = Rng::new(8_086);
    let (q, k, v) = rand_case(&mut rng, 1, 256, 16);
    let kv = PreparedKv::with_block_rows(k.clone(), v.clone(), 32); // 8 resident chunks
    let seed8 = seed_blocked_attention(&q, &k, &v, 8);
    assert_eq!(bits(&kv.attention_blocked(&q, 8, None)), bits(&seed8));
    // the stored (append-stable) partition has 8 chunks of 32 rows: the
    // count-driven 8-way split lands on the same boundaries here
    assert_eq!(bits(&kv.attention_resident_blocks(&q, None)), bits(&seed8));
}

//! End-to-end PJRT round-trips: the AOT HLO artifacts must reproduce the
//! rust golden models' numerics (the three layers compose).

use hfa::attention::{exact, hfa as hfa_golden};
use hfa::proptest::Rng;
use hfa::runtime::{ArtifactRegistry, AttnKernelSpec};
use hfa::Mat;

fn registry() -> Option<ArtifactRegistry> {
    match ArtifactRegistry::open(&hfa::artifacts_dir()) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("WARNING: skipping runtime tests: {e}");
            None
        }
    }
}

#[test]
fn hfa_kernel_artifact_matches_rust_golden_model() {
    let Some(reg) = registry() else { return };
    let spec = AttnKernelSpec { kind: "hfa".into(), head_dim: 32, seq_len: 256, batch: 8 };
    let exe = reg.attention_kernel(&spec).expect("kernel artifact");

    let mut rng = Rng::new(101);
    let q = Mat::from_vec(8, 32, rng.normal_vec(8 * 32)).round_bf16();
    let k = Mat::from_vec(256, 32, rng.normal_vec(256 * 32)).round_bf16();
    let v = Mat::from_vec(256, 32, rng.normal_vec(256 * 32)).round_bf16();

    let got = exe.run_attention(&q, &k, &v).expect("execute");
    let golden = hfa_golden::attention(&q, &k, &v, None, None, &mut None);

    // the HLO kernel computes scores with XLA's dot (different f32
    // association than the sequential rust dot) -> tolerance, not bits
    let rel = got.rel_rms(&golden);
    assert!(rel < 0.05, "PJRT H-FA vs rust golden rel rms {rel}");
}

#[test]
fn fa2_kernel_artifact_matches_exact_attention() {
    let Some(reg) = registry() else { return };
    let spec = AttnKernelSpec { kind: "fa2".into(), head_dim: 32, seq_len: 256, batch: 8 };
    let exe = reg.attention_kernel(&spec).expect("kernel artifact");

    let mut rng = Rng::new(103);
    let q = Mat::from_vec(8, 32, rng.normal_vec(8 * 32)).round_bf16();
    let k = Mat::from_vec(256, 32, rng.normal_vec(256 * 32)).round_bf16();
    let v = Mat::from_vec(256, 32, rng.normal_vec(256 * 32)).round_bf16();

    let got = exe.run_attention(&q, &k, &v).expect("execute");
    let reference = exact::attention(&q, &k, &v, None, None);
    let rel = got.rel_rms(&reference);
    assert!(rel < 0.02, "PJRT FA-2 vs exact rel rms {rel}");
}

#[test]
fn hfa_and_fa2_artifacts_differ_but_track() {
    // sanity: the two kernels are genuinely different computations yet
    // approximate the same attention
    let Some(reg) = registry() else { return };
    let s_h = AttnKernelSpec { kind: "hfa".into(), head_dim: 32, seq_len: 256, batch: 8 };
    let s_f = AttnKernelSpec { kind: "fa2".into(), head_dim: 32, seq_len: 256, batch: 8 };
    let (eh, ef) = (reg.attention_kernel(&s_h).unwrap(), reg.attention_kernel(&s_f).unwrap());

    let mut rng = Rng::new(107);
    let q = Mat::from_vec(8, 32, rng.normal_vec(8 * 32)).round_bf16();
    let k = Mat::from_vec(256, 32, rng.normal_vec(256 * 32)).round_bf16();
    let v = Mat::from_vec(256, 32, rng.normal_vec(256 * 32)).round_bf16();
    let oh = eh.run_attention(&q, &k, &v).unwrap();
    let of = ef.run_attention(&q, &k, &v).unwrap();
    assert_ne!(oh.data, of.data, "H-FA must differ bit-wise from FA-2");
    // near-uniform random attention over N=256 keys puts outputs near 0,
    // so relative error is uninformative — bound the absolute deviation
    // (the H-FA approximation floor on this workload)
    assert!(oh.max_abs_diff(&of) < 0.5, "absolute deviation {}", oh.max_abs_diff(&of));
}

#[test]
fn registry_lists_expected_artifacts() {
    let Some(reg) = registry() else { return };
    let kernels = reg.list_attention_kernels().unwrap();
    assert!(kernels.len() >= 6, "expected >= 6 attention kernels, got {}", kernels.len());
    let models = reg.list_models().unwrap();
    assert!(
        models.iter().any(|(s, i)| s == "s1" && i == "hfa"),
        "model_s1_hfa missing from {models:?}"
    );
}

#[test]
fn model_artifact_runs_and_is_finite() {
    let Some(reg) = registry() else { return };
    let exe = match reg.model("s1", "exact") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("WARNING: {e}");
            return;
        }
    };
    let tokens: Vec<i32> = (0..128).map(|i| (i % 60) + 4).collect();
    let logits = exe.run_model(&tokens).expect("model fwd");
    assert_eq!(logits.len(), 128 * 64);
    assert!(logits.iter().all(|x| x.is_finite()));
    // logits should not be constant
    let (mn, mx) = logits.iter().fold((f32::MAX, f32::MIN), |(a, b), &x| (a.min(x), b.max(x)));
    assert!(mx - mn > 0.5, "degenerate logits: range {}", mx - mn);
}

//! Regression: through the coordinator, the value matrix is linear->log
//! converted exactly once per session (at `KvStore::put`), never per
//! batch.  This pins the paper's "KV preloaded in local buffers"
//! assumption end-to-end: `SimBackend` adopts the store's prepared KV by
//! Arc identity, and `Accelerator::compute_batch` runs entirely on the
//! resident lanes.
//!
//! Kept as the sole test in this binary so the process-wide conversion
//! counter sees no concurrent traffic from unrelated tests.

use std::sync::Arc;

use hfa::attention::hfa::value_conversion_count;
use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{KvStore, Server, SimBackend};
use hfa::hw::Arith;
use hfa::proptest::Rng;
use hfa::Mat;

#[test]
fn value_to_lns_runs_once_per_session_not_per_batch() {
    const N: usize = 64;
    const D: usize = 8;
    let accel_cfg = AcceleratorConfig {
        head_dim: D,
        seq_len: N,
        kv_blocks: 4,
        parallel_queries: 1,
        freq_mhz: 500.0,
    };
    let coord_cfg = CoordinatorConfig {
        max_batch: 4,
        max_total_batch: 256,
        batch_window_us: 100,
        workers: 2,
        queue_depth: 128,
        ..CoordinatorConfig::default()
    };

    let kv = Arc::new(KvStore::new(N, D, 4));
    let mut rng = Rng::new(42);

    let before_put = value_conversion_count();
    kv.put("sess", Mat::from_vec(N, D, rng.normal_vec(N * D)),
           Mat::from_vec(N, D, rng.normal_vec(N * D))).unwrap();
    let after_put = value_conversion_count();
    assert_eq!(
        after_put - before_put,
        N as u64,
        "put() must convert each of the {N} value rows exactly once"
    );

    let factories = (0..coord_cfg.workers)
        .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg.clone()))
        .collect();
    let server = Server::start(&coord_cfg, kv.clone(), factories).unwrap();

    // several waves of batches against the resident session — with both
    // workers serving, every one must run on the prepared lanes
    for wave in 0..5 {
        let rxs: Vec<_> =
            (0..16).map(|_| server.submit("sess", rng.normal_vec(D)).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.ok(), "wave {wave}: {:?}", r.output);
        }
    }
    let after_serving = value_conversion_count();
    assert_eq!(
        after_serving, after_put,
        "serving must not reconvert V: {} extra row conversions after {} batches",
        after_serving - after_put,
        server.metrics.snapshot().batches
    );

    // replacing the session pays the conversion again — once
    kv.put("sess", Mat::from_vec(N, D, rng.normal_vec(N * D)),
           Mat::from_vec(N, D, rng.normal_vec(N * D))).unwrap();
    assert_eq!(value_conversion_count() - after_serving, N as u64);

    server.shutdown();
}

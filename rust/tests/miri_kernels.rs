//! Miri lane: undefined-behavior check of the crate's bit-twiddling
//! arithmetic, the prepared-KV chunk-view slicing, and the worker
//! pool's one `unsafe` block (the lifetime-erasure transmute in
//! `run_scoped`).
//!
//! Run with:
//!
//! ```text
//! HFA_POOL_THREADS=0 MIRIFLAGS=-Zmiri-disable-isolation \
//!     cargo +nightly miri test --test miri_kernels
//! ```
//!
//! `HFA_POOL_THREADS=0` keeps the global pool from spawning detached
//! workers (Miri rejects threads still alive at process exit); the
//! zero-worker pool still routes every fan-out through `run_scoped`'s
//! transmute + caller-drain path, so the unsafe code is exercised, just
//! serially.  Shapes are deliberately tiny — Miri runs ~100x slower
//! than native.

use hfa::arith::bf16::Bf16;
use hfa::arith::lns::{lns_add, Lns};
use hfa::attention::prepared::PreparedKv;
use hfa::proptest::Rng;
use hfa::runtime::WorkerPool;
use hfa::Mat;

/// Pin the pool to zero workers for every test in this binary,
/// whichever runs first (also set by the CI lane's environment).
fn serial_pool() {
    std::env::set_var("HFA_POOL_THREADS", "0");
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn bf16_bit_manipulation_is_defined() {
    serial_pool();
    // sweep a structured set of bit patterns through the f32 <-> bf16
    // round-trips and field extractors Miri checks for UB
    for hi in [0x0000u16, 0x0001, 0x0080, 0x3f80, 0x7f7f, 0x7f80, 0x8000, 0xbf80, 0xff80] {
        let b = Bf16::from_bits(hi);
        assert_eq!(b.bits(), hi);
        let f = b.to_f32();
        if !b.is_nan() {
            assert_eq!(Bf16::from_f32(f).bits(), hi, "bits 0x{hi:04x} round-trip");
        }
        let _ = (b.sign(), b.exponent(), b.mantissa(), b.is_zero_or_subnormal());
    }
    let mut rng = Rng::new(11);
    for x in rng.normal_vec(64) {
        let b = Bf16::from_f32(x);
        assert_eq!(Bf16::from_f32(b.to_f32()).bits(), b.bits(), "bf16 values are fixed points");
    }
}

#[test]
fn lns_conversion_and_add_are_defined() {
    serial_pool();
    let mut rng = Rng::new(23);
    for x in rng.normal_vec(48) {
        let l = Lns::from_bf16(Bf16::from_f32(x));
        let _ = l.to_bf16();
        let _ = l.to_f64();
        assert_eq!(lns_add(l, Lns::ZERO), l, "zero is the additive identity");
        // exercise the PWL table walk (Eq. 19) across sign/magnitude
        // combinations; bit-exact values are pinned by the tier-1 suite,
        // Miri only vets the integer manipulation for UB
        let _ = lns_add(l, l.neg());
        let _ = lns_add(l, l.scaled(-3));
    }
}

#[test]
fn chunk_views_match_dense_planes_across_append() {
    serial_pool();
    let mut rng = Rng::new(5);
    let (n, d) = (7, 3);
    let k = Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16();
    let v = Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16();
    // chunk capacity 4 rows: row 4 starts chunk 1, views straddle the seam
    let mut kv = PreparedKv::with_block_rows(k.clone(), v.clone(), 4);
    for (lo, hi) in [(0, n), (2, 6), (3, 4), (4, 4)] {
        assert_eq!(bits(&kv.k_rows(lo, hi)), bits(&k.rows_slice(lo, hi)), "K view [{lo},{hi})");
        assert_eq!(bits(&kv.v_rows(lo, hi)), bits(&v.rows_slice(lo, hi)), "V view [{lo},{hi})");
    }
    for r in 0..n {
        assert_eq!(kv.k_row(r), k.row(r), "chunk-resolved K row {r}");
        let (signs, logs) = (kv.v_row_signs(r), kv.v_row_logs(r));
        assert_eq!(signs.len(), d + 1, "sign lane width row {r}");
        assert_eq!(logs.len(), d + 1, "log lane width row {r}");
    }
    // append crosses a chunk boundary (7 + 3 rows, capacity 4): the
    // copy-on-write tail-chunk clone and fresh-chunk alloc both slice
    let ka = Mat::from_vec(3, d, rng.normal_vec(3 * d)).round_bf16();
    let va = Mat::from_vec(3, d, rng.normal_vec(3 * d)).round_bf16();
    let grown = kv.appended(&ka, &va);
    kv.append(&ka, &va);
    assert_eq!(kv.n(), n + 3);
    assert_eq!(bits(&kv.k_mat()), bits(&grown.k_mat()), "in-place == copy-on-write");
    assert_eq!(kv.k_row(n + 2), ka.row(2), "appended rows resolve through the chunk table");
}

#[test]
fn tiled_attention_matches_blocked_serially() {
    serial_pool();
    let mut rng = Rng::new(41);
    let (b, n, d) = (3, 6, 2);
    let q = Mat::from_vec(b, d, rng.normal_vec(b * d)).round_bf16();
    let k = Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16();
    let v = Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16();
    let kv = PreparedKv::with_block_rows(k, v, 4);
    let reference = kv.attention(&q, None, None);
    let blocked = kv.attention_blocked(&q, 2, None);
    let tiled = kv.attention_tiled(&q, 2, None, 2);
    assert_eq!(bits(&reference), bits(&blocked), "blocked == dense, serial pool");
    assert_eq!(bits(&blocked), bits(&tiled), "tile height never changes bits");
}

#[test]
fn zero_worker_pool_transmute_is_sound() {
    serial_pool();
    // WorkerPool::new(0): no threads, but run_scoped still erases the
    // job lifetimes through its unsafe transmute and drains on the
    // caller — the exact code path Miri must vet for stacked-borrows UB
    let pool = WorkerPool::new(0);
    let mut out = vec![0usize; 12];
    {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(4)
            .enumerate()
            .map(|(c, chunk)| {
                Box::new(move || {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        *slot = c * 4 + j + 1;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
    }
    assert!(out.iter().enumerate().all(|(i, &x)| x == i + 1), "every borrowed slot written");
}

//! Coordinator end-to-end under concurrency, failure injection and
//! backpressure.

use std::sync::Arc;

use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{KvStore, Server, SimBackend};
use hfa::hw::Arith;
use hfa::proptest::Rng;
use hfa::Mat;

fn boot(workers: usize, queue_depth: usize, window_us: u64) -> Server {
    let accel = AcceleratorConfig {
        head_dim: 8, seq_len: 32, kv_blocks: 2, parallel_queries: 1, freq_mhz: 500.0,
    };
    let coord = CoordinatorConfig { max_batch: 8, max_total_batch: 256, batch_window_us: window_us, workers, queue_depth, ..CoordinatorConfig::default() };
    let kv = Arc::new(KvStore::new(32, 8, 8));
    let mut rng = Rng::new(77);
    kv.put("a", Mat::from_vec(32, 8, rng.normal_vec(256)),
           Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
    kv.put("b", Mat::from_vec(32, 8, rng.normal_vec(256)),
           Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
    let factories = (0..workers).map(|_| SimBackend::factory(Arith::Hfa, accel.clone())).collect();
    Server::start(&coord, kv, factories).unwrap()
}

#[test]
fn concurrent_clients_all_complete() {
    let srv = Arc::new(boot(3, 512, 100));
    let mut handles = Vec::new();
    for t in 0..4 {
        let srv = srv.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + t);
            let mut ok = 0;
            for _ in 0..50 {
                let session = if rng.bool() { "a" } else { "b" };
                match srv.call(session, rng.normal_vec(8)) {
                    Ok(r) if r.ok() => ok += 1,
                    _ => {}
                }
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 200, "all concurrent requests must succeed");
    let snap = srv.metrics.snapshot();
    assert_eq!(snap.completed, 200);
    assert_eq!(snap.failed, 0);
}

#[test]
fn mixed_good_and_bad_sessions() {
    let srv = boot(2, 128, 50);
    let mut rng = Rng::new(9);
    let mut good = 0;
    let mut bad = 0;
    for i in 0..40 {
        let session = if i % 3 == 0 { "missing" } else { "a" };
        let r = srv.call(session, rng.normal_vec(8)).unwrap();
        if r.ok() { good += 1 } else { bad += 1 }
    }
    assert_eq!(good + bad, 40);
    assert!(bad >= 13, "missing-session requests must fail cleanly");
    srv.shutdown();
}

#[test]
fn tiny_queue_exerts_backpressure() {
    let srv = boot(1, 2, 5_000); // long window, tiny queue
    let mut rng = Rng::new(5);
    let mut rejected = 0;
    let mut receivers = Vec::new();
    for _ in 0..64 {
        match srv.submit("a", rng.normal_vec(8)) {
            Ok(rx) => receivers.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "expected ingress rejections with queue depth 2");
    for rx in receivers {
        let _ = rx.recv(); // drain accepted ones
    }
    srv.shutdown();
}

#[test]
fn decode_loop_under_concurrent_traffic_stays_exact() {
    // one client runs an autoregressive decode loop (append one row,
    // then attend) on session "dec" while another hammers session "a";
    // every decode-step output must be bit-exact vs the golden blocked
    // model over the exact KV prefix the step saw.
    let srv = Arc::new(boot(3, 512, 100));
    let mut rng = Rng::new(2_026);
    let n_total = 32usize;
    let prefill = 20usize;
    let k = Mat::from_vec(n_total, 8, rng.normal_vec(n_total * 8));
    let v = Mat::from_vec(n_total, 8, rng.normal_vec(n_total * 8));
    srv.kv.put("dec", k.rows_slice(0, prefill), v.rows_slice(0, prefill)).unwrap();

    let background = {
        let srv = srv.clone();
        std::thread::spawn(move || {
            let mut rng = Rng::new(5_050);
            let mut ok = 0;
            for _ in 0..60 {
                if let Ok(r) = srv.call("a", rng.normal_vec(8)) {
                    if r.ok() {
                        ok += 1;
                    }
                }
            }
            ok
        })
    };

    let (kb, vb) = (k.round_bf16(), v.round_bf16());
    for step in 0..(n_total - prefill) {
        let at = prefill + step;
        let ack = srv.append("dec", k.rows_slice(at, at + 1), v.rows_slice(at, at + 1)).unwrap();
        assert!(ack.ok(), "step {step}: {:?}", ack.output);
        let q = rng.normal_vec(8);
        let resp = srv.call("dec", q.clone()).unwrap();
        assert!(resp.ok(), "step {step}: {:?}", resp.output);
        let golden = hfa::attention::hfa::attention_blocked(
            &Mat::from_vec(1, 8, q).round_bf16(),
            &kb.rows_slice(0, at + 1),
            &vb.rows_slice(0, at + 1),
            2, // boot() configures 2 KV blocks
            None,
            &mut None,
        );
        assert_eq!(
            resp.output.unwrap(),
            golden.row(0).to_vec(),
            "step {step}: decode attend diverged from golden over {} rows",
            at + 1
        );
    }
    // capacity guard: the session is now full (32 rows)
    let overflow = srv.append("dec", Mat::zeros(1, 8), Mat::zeros(1, 8)).unwrap();
    assert!(!overflow.ok(), "append past capacity must fail cleanly");

    let ok = background.join().unwrap();
    assert_eq!(ok, 60, "background session must be unaffected by decode traffic");
}

#[test]
fn graceful_shutdown_completes_inflight() {
    let srv = boot(2, 256, 2_000);
    let mut rng = Rng::new(3);
    let rxs: Vec<_> = (0..16).map(|_| srv.submit("a", rng.normal_vec(8)).unwrap()).collect();
    srv.shutdown(); // must drain the batcher, not drop requests
    let mut done = 0;
    for rx in rxs {
        if let Ok(r) = rx.recv() {
            assert!(r.ok());
            done += 1;
        }
    }
    assert_eq!(done, 16, "in-flight requests must complete on shutdown");
}

//! Coordinator end-to-end under concurrency, failure injection and
//! backpressure.

use std::sync::Arc;

use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{KvStore, Server, SimBackend};
use hfa::hw::Arith;
use hfa::proptest::Rng;
use hfa::Mat;

fn boot(workers: usize, queue_depth: usize, window_us: u64) -> Server {
    let accel = AcceleratorConfig {
        head_dim: 8, seq_len: 32, kv_blocks: 2, parallel_queries: 1, freq_mhz: 500.0,
    };
    let coord = CoordinatorConfig { max_batch: 8, batch_window_us: window_us, workers, queue_depth };
    let kv = Arc::new(KvStore::new(32, 8, 8));
    let mut rng = Rng::new(77);
    kv.put("a", Mat::from_vec(32, 8, rng.normal_vec(256)),
           Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
    kv.put("b", Mat::from_vec(32, 8, rng.normal_vec(256)),
           Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
    let factories = (0..workers).map(|_| SimBackend::factory(Arith::Hfa, accel.clone())).collect();
    Server::start(&coord, kv, factories).unwrap()
}

#[test]
fn concurrent_clients_all_complete() {
    let srv = Arc::new(boot(3, 512, 100));
    let mut handles = Vec::new();
    for t in 0..4 {
        let srv = srv.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + t);
            let mut ok = 0;
            for _ in 0..50 {
                let session = if rng.bool() { "a" } else { "b" };
                match srv.call(session, rng.normal_vec(8)) {
                    Ok(r) if r.ok() => ok += 1,
                    _ => {}
                }
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 200, "all concurrent requests must succeed");
    let snap = srv.metrics.snapshot();
    assert_eq!(snap.completed, 200);
    assert_eq!(snap.failed, 0);
}

#[test]
fn mixed_good_and_bad_sessions() {
    let srv = boot(2, 128, 50);
    let mut rng = Rng::new(9);
    let mut good = 0;
    let mut bad = 0;
    for i in 0..40 {
        let session = if i % 3 == 0 { "missing" } else { "a" };
        let r = srv.call(session, rng.normal_vec(8)).unwrap();
        if r.ok() { good += 1 } else { bad += 1 }
    }
    assert_eq!(good + bad, 40);
    assert!(bad >= 13, "missing-session requests must fail cleanly");
    srv.shutdown();
}

#[test]
fn tiny_queue_exerts_backpressure() {
    let srv = boot(1, 2, 5_000); // long window, tiny queue
    let mut rng = Rng::new(5);
    let mut rejected = 0;
    let mut receivers = Vec::new();
    for _ in 0..64 {
        match srv.submit("a", rng.normal_vec(8)) {
            Ok(rx) => receivers.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "expected ingress rejections with queue depth 2");
    for rx in receivers {
        let _ = rx.recv(); // drain accepted ones
    }
    srv.shutdown();
}

#[test]
fn graceful_shutdown_completes_inflight() {
    let srv = boot(2, 256, 2_000);
    let mut rng = Rng::new(3);
    let rxs: Vec<_> = (0..16).map(|_| srv.submit("a", rng.normal_vec(8)).unwrap()).collect();
    srv.shutdown(); // must drain the batcher, not drop requests
    let mut done = 0;
    for rx in rxs {
        if let Ok(r) = rx.recv() {
            assert!(r.ok());
            done += 1;
        }
    }
    assert_eq!(done, 16, "in-flight requests must complete on shutdown");
}

//! Bit-exactness of the prepared-KV execution engine: the pooled,
//! conversion-amortized serving path must produce byte-identical outputs
//! to the serial seed datapath across random shapes and masks, and the
//! blocked path must handle ragged (non-divisible) KV partitions.

use hfa::attention::hfa as hfa_mod;
use hfa::attention::hfa::{value_to_lns, HfaState};
use hfa::attention::merge::merge_hfa;
use hfa::attention::prepared::{kv_block_ranges, PreparedKv};
use hfa::proptest::Rng;
use hfa::tensor::dot_f32;
use hfa::Mat;

/// The seed algorithm, written out serially from the public primitives:
/// per-call V->LNS conversion, one query at a time, no pooling.
fn serial_seed_attention(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    scale: Option<f32>,
    mask: Option<&[bool]>,
) -> Mat {
    let n = k.rows;
    let scale = scale.unwrap_or(1.0 / (q.cols as f32).sqrt());
    let v_lns: Vec<_> = (0..n).map(|i| value_to_lns(v.row(i), &mut None)).collect();
    let mut out = Mat::zeros(q.rows, v.cols);
    for bi in 0..q.rows {
        let mut st = HfaState::new(v.cols);
        for i in 0..n {
            if mask.map(|m| !m[bi * n + i]).unwrap_or(false) {
                continue;
            }
            let s = dot_f32(q.row(bi), k.row(i)) * scale;
            st.step(s, &v_lns[i], &mut None);
        }
        out.row_mut(bi).copy_from_slice(&st.finalize());
    }
    out
}

fn rand_case(rng: &mut Rng, b: usize, n: usize, d: usize) -> (Mat, Mat, Mat) {
    (
        Mat::from_vec(b, d, rng.normal_vec(b * d)).round_bf16(),
        Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
        Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16(),
    )
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn prepared_path_bit_identical_to_serial_seed_across_shapes() {
    let mut rng = Rng::new(20_240_728);
    for &(b, n, d) in &[
        (1usize, 7usize, 4usize),
        (2, 16, 8),
        (5, 33, 8),
        (8, 64, 16),
        (17, 100, 8),
        (3, 1, 4),
    ] {
        let (q, k, v) = rand_case(&mut rng, b, n, d);
        let seed = serial_seed_attention(&q, &k, &v, None, None);
        // module entry point (pool fan-out + convert-once)
        let fast = hfa_mod::attention(&q, &k, &v, None, None, &mut None);
        assert_eq!(bits(&fast), bits(&seed), "attention b={b} n={n} d={d}");
        // explicit PreparedKv reuse: same bits on repeated calls
        let kv = PreparedKv::new(k.clone(), v.clone());
        for _ in 0..2 {
            assert_eq!(bits(&kv.attention(&q, None, None)), bits(&seed), "prepared reuse");
        }
    }
}

#[test]
fn prepared_path_bit_identical_under_random_masks() {
    let mut rng = Rng::new(424_242);
    for trial in 0..8 {
        let (b, n, d) = (4usize, 24usize, 8usize);
        let (q, k, v) = rand_case(&mut rng, b, n, d);
        let mask: Vec<bool> = (0..b * n).map(|_| rng.below(4) != 0).collect();
        let seed = serial_seed_attention(&q, &k, &v, None, Some(&mask));
        let fast = hfa_mod::attention(&q, &k, &v, None, Some(&mask), &mut None);
        assert_eq!(bits(&fast), bits(&seed), "masked trial {trial}");
        let kv = PreparedKv::new(k.clone(), v.clone());
        assert_eq!(bits(&kv.attention(&q, None, Some(&mask))), bits(&seed));
    }
}

#[test]
fn pooled_fanout_matches_single_query_calls() {
    // the pool chunks a batch across threads; each row must equal the
    // b=1 (serial) computation of the same query
    let mut rng = Rng::new(7_777);
    let (q, k, v) = rand_case(&mut rng, 23, 48, 8);
    let batch = hfa_mod::attention(&q, &k, &v, None, None, &mut None);
    for bi in 0..q.rows {
        let q1 = q.rows_slice(bi, bi + 1);
        let one = hfa_mod::attention(&q1, &k, &v, None, None, &mut None);
        assert_eq!(bits(&batch.rows_slice(bi, bi + 1)), bits(&one), "row {bi}");
    }
}

#[test]
fn blocked_handles_ragged_tail_without_panicking() {
    // seed asserted k.rows % num_blocks == 0; now the tail block is short
    let mut rng = Rng::new(11_003);
    for &(n, p) in &[(10usize, 4usize), (100, 3), (7, 8), (33, 2), (64, 4)] {
        let (q, k, v) = rand_case(&mut rng, 3, n, 8);
        let got = hfa_mod::attention_blocked(&q, &k, &v, p, None, &mut None);

        // reference: explicit partial states over the same ranges + merge
        let mut acc: Option<Vec<HfaState>> = None;
        for (lo, hi) in kv_block_ranges(n, p) {
            let kb = k.rows_slice(lo, hi);
            let vb = v.rows_slice(lo, hi);
            let st = hfa_mod::partial_states(&q, &kb, &vb, None, None, &mut None);
            acc = Some(match acc {
                None => st,
                Some(prev) => prev
                    .into_iter()
                    .zip(st)
                    .map(|(a, b)| merge_hfa(&a, &b, &mut None))
                    .collect(),
            });
        }
        let states = acc.unwrap();
        let mut reference = Mat::zeros(q.rows, v.cols);
        for (bi, st) in states.iter().enumerate() {
            reference.row_mut(bi).copy_from_slice(&st.finalize());
        }
        assert_eq!(bits(&got), bits(&reference), "n={n} p={p}");
    }
}

#[test]
fn blocked_divisible_case_unchanged_vs_unblocked_merge_error() {
    // the divisible case keeps the seed partition: p=1 blocked == plain
    let mut rng = Rng::new(5_005);
    let (q, k, v) = rand_case(&mut rng, 2, 32, 8);
    let plain = hfa_mod::attention(&q, &k, &v, None, None, &mut None);
    let blocked1 = hfa_mod::attention_blocked(&q, &k, &v, 1, None, &mut None);
    assert_eq!(bits(&plain), bits(&blocked1));
}

#[test]
fn from_scores_replay_matches_prepared_lanes() {
    // attention_from_scores now reads resident SoA lanes; replaying the
    // scores the dot product would produce must equal the full pipeline
    let mut rng = Rng::new(909);
    let (q, k, v) = rand_case(&mut rng, 3, 20, 8);
    let scale = 1.0 / (8f32).sqrt();
    let mut scores = Mat::zeros(q.rows, k.rows);
    for bi in 0..q.rows {
        for i in 0..k.rows {
            scores.set(bi, i, dot_f32(q.row(bi), k.row(i)) * scale);
        }
    }
    let replay = hfa_mod::attention_from_scores(&scores, &v);
    let full = hfa_mod::attention(&q, &k, &v, None, None, &mut None);
    assert_eq!(bits(&replay), bits(&full));
}

//! Continuous (iteration-level) batching, end to end: a resident decode
//! session must cost ONE batcher admission for its whole token stream
//! (not one per token), a long prefill must never stall resident
//! sessions' decode cadence, sessions joining and leaving the running
//! batch must leave every output bit-identical to solo serving, and
//! cancellation must retire a session's slot and free its KV bytes
//! before its queued requests drain.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{Backend, BackendFactory, KvEntry, KvStore, Server, SimBackend};
use hfa::hw::Arith;
use hfa::proptest::Rng;
use hfa::Mat;

const D: usize = 8;
const SEQ: usize = 32;
const KV_BLOCKS: usize = 4;

fn accel_cfg() -> AcceleratorConfig {
    AcceleratorConfig {
        head_dim: D,
        seq_len: SEQ,
        kv_blocks: KV_BLOCKS,
        parallel_queries: 1,
        freq_mhz: 500.0,
    }
}

/// Golden single-session serving result: the blocked H-FA model over the
/// session's exact KV prefix (what `Server` is pinned to produce for a
/// lone session by `coordinator::server::tests`).
fn golden(q: &[f32], k: &Mat, v: &Mat, rows: usize) -> Vec<f32> {
    hfa::attention::hfa::attention_blocked(
        &Mat::from_vec(1, D, q.to_vec()).round_bf16(),
        &k.rows_slice(0, rows).round_bf16(),
        &v.rows_slice(0, rows).round_bf16(),
        KV_BLOCKS,
        None,
        &mut None,
    )
    .row(0)
    .to_vec()
}

// The acceptance pin: an N-token decode loop must cost exactly ONE
// batcher admission (the join), with every subsequent append/query
// routed straight into the resident slot and served by per-iteration
// decode dispatches — and every output bit-identical to the golden
// single-session model.
#[test]
fn decode_loop_costs_one_admission_not_one_per_token() {
    const PREFILL: usize = 8;
    const STEPS: usize = 8;
    let coord = CoordinatorConfig {
        max_batch: 8,
        max_total_batch: 64,
        batch_window_us: 500,
        workers: 1,
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };
    let kv = Arc::new(KvStore::new(SEQ, D, 4));
    let mut rng = Rng::new(71);
    let n = PREFILL + STEPS;
    let k = Mat::from_vec(n, D, rng.normal_vec(n * D));
    let v = Mat::from_vec(n, D, rng.normal_vec(n * D));
    kv.put("sess", k.rows_slice(0, PREFILL), v.rows_slice(0, PREFILL)).unwrap();
    let srv = Server::start(
        &coord,
        kv.clone(),
        vec![SimBackend::factory(Arith::Hfa, accel_cfg())],
    )
    .unwrap();

    // the client-serialized decode loop: append row t, await the ack,
    // attend, await the output — the protocol every decode client runs
    for step in 0..STEPS {
        let at = PREFILL + step;
        let ack = srv
            .append("sess", k.rows_slice(at, at + 1), v.rows_slice(at, at + 1))
            .unwrap();
        assert!(ack.ok(), "step {step} append: {:?}", ack.output);
        let q = rng.normal_vec(D);
        let resp = srv.call("sess", q.clone()).unwrap();
        assert!(resp.ok(), "step {step}: {:?}", resp.output);
        assert_eq!(
            resp.output.unwrap(),
            golden(&q, &k, &v, at + 1),
            "step {step}: continuous decode diverged from golden over {} rows",
            at + 1
        );
    }

    let snap = srv.metrics.snapshot();
    // ONE admission for the whole stream: only the first append (the
    // join) went through the window/barrier batcher
    assert_eq!(
        snap.batcher_admissions, 1,
        "an N-token decode must cost one admission, not N: {snap:?}"
    );
    // everything after the join bypassed the batcher: (STEPS-1) appends
    // + STEPS queries routed straight into the resident slot
    assert_eq!(snap.slot_hits, (2 * STEPS - 1) as u64, "{snap:?}");
    assert_eq!(snap.prefill_iters, 1, "{snap:?}");
    // client serialization means each routed request is its own decode
    // iteration (one request per dispatch)
    assert_eq!(snap.decode_iters, (2 * STEPS - 1) as u64, "{snap:?}");
    assert_eq!(snap.completed, STEPS as u64);
    assert_eq!(snap.appends, STEPS as u64);
    assert_eq!(snap.failed, 0);
    // the latency spans recorded something sensible in each stage
    assert!(snap.queue_wait_p99_us > 0.0, "no queue-wait samples: {snap:?}");
    assert!(snap.prefill_p99_us > 0.0, "no prefill samples: {snap:?}");
    assert!(snap.decode_gap_p99_us > 0.0, "no decode-gap samples: {snap:?}");
    assert_eq!(kv.pinned_sessions(), 0, "resident slots must hold no idle pins");
    srv.shutdown();
}

/// Backend that (while `armed`) parks any dispatch touching a session
/// with >= `min_rows` resident rows until released — a deterministic
/// stand-in for a long compute, so tests can prove what keeps flowing
/// (or stays deferred) while a lane is occupied, with no sleeps and no
/// timing races.
struct GatedBackend {
    inner: Box<dyn Backend>,
    armed: Arc<AtomicBool>,
    entered: Arc<AtomicBool>,
    release: Arc<AtomicBool>,
    min_rows: usize,
}

impl GatedBackend {
    fn wrap_factory(
        inner: BackendFactory,
        armed: Arc<AtomicBool>,
        entered: Arc<AtomicBool>,
        release: Arc<AtomicBool>,
        min_rows: usize,
    ) -> BackendFactory {
        Box::new(move || {
            let be = inner()?;
            Ok(Box::new(GatedBackend {
                inner: be,
                armed: armed.clone(),
                entered: entered.clone(),
                release: release.clone(),
                min_rows,
            }) as Box<dyn Backend>)
        })
    }
}

impl Backend for GatedBackend {
    fn head_dim(&self) -> usize {
        self.inner.head_dim()
    }

    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn compute_plan(&mut self, plan: &[(&KvEntry, &Mat)]) -> Result<Vec<Mat>> {
        if self.armed.load(Ordering::SeqCst)
            && plan.iter().any(|(kv, _)| kv.prepared().n() >= self.min_rows)
        {
            self.entered.store(true, Ordering::SeqCst);
            while !self.release.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        self.inner.compute_plan(plan)
    }

    fn name(&self) -> String {
        format!("gated({})", self.inner.name())
    }
}

// Decode-cadence starvation: while a long prefill occupies its lane (a
// worker parked inside the big session's first compute), a resident
// session's decode steps must keep completing through the independent
// decode lane — the whole point of scheduling prefill separately.
#[test]
fn long_prefill_does_not_stall_resident_decode_cadence() {
    const PREFILL: usize = 8;
    const STEPS_DURING: usize = 4;
    let coord = CoordinatorConfig {
        max_batch: 8,
        max_total_batch: 64,
        batch_window_us: 1_000,
        workers: 2, // one parks in the prefill, the other serves decode
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };
    let kv = Arc::new(KvStore::new(SEQ, D, 4));
    let mut rng = Rng::new(503);
    let n = PREFILL + STEPS_DURING;
    let (kr, vr) = (
        Mat::from_vec(n, D, rng.normal_vec(n * D)),
        Mat::from_vec(n, D, rng.normal_vec(n * D)),
    );
    let (kb, vb) = (
        Mat::from_vec(SEQ, D, rng.normal_vec(SEQ * D)),
        Mat::from_vec(SEQ, D, rng.normal_vec(SEQ * D)),
    );
    kv.put("res", kr.rows_slice(0, PREFILL), vr.rows_slice(0, PREFILL)).unwrap();
    kv.put("big", kb.clone(), vb.clone()).unwrap();
    let armed = Arc::new(AtomicBool::new(true)); // park from the start
    let entered = Arc::new(AtomicBool::new(false));
    let release = Arc::new(AtomicBool::new(false));
    let factories = (0..coord.workers)
        .map(|_| {
            GatedBackend::wrap_factory(
                SimBackend::factory(Arith::Hfa, accel_cfg()),
                armed.clone(),
                entered.clone(),
                release.clone(),
                SEQ,
            )
        })
        .collect();
    let srv = Server::start(&coord, kv, factories).unwrap();

    // make "res" resident: its first query forms a group, closes at the
    // window and admits (an 8-row dispatch, which passes the gate)
    let q0 = rng.normal_vec(D);
    let r0 = srv.call("res", q0.clone()).unwrap();
    assert!(r0.ok(), "{:?}", r0.output);
    assert_eq!(r0.output.unwrap(), golden(&q0, &kr, &vr, PREFILL));

    // the big session's first traffic: one query over its full SEQ-row
    // KV — admitted as a prefill whose compute parks on the gate
    let big_q = rng.normal_vec(D);
    let big_rx = srv.submit("big", big_q.clone()).unwrap();
    let t0 = std::time::Instant::now();
    while !entered.load(Ordering::SeqCst) {
        assert!(t0.elapsed() < Duration::from_secs(10), "prefill never reached a worker");
        std::thread::sleep(Duration::from_millis(1));
    }
    let decode_iters_before = srv.metrics.snapshot().decode_iters;

    // with the prefill lane parked, the resident session's decode loop
    // must keep its cadence through the decode lane.  recv_timeout so a
    // starved decode fails the test with a message instead of hanging.
    for step in 0..STEPS_DURING {
        let at = PREFILL + step;
        let ack = srv
            .submit_append("res", kr.rows_slice(at, at + 1), vr.rows_slice(at, at + 1))
            .unwrap();
        let a = ack
            .recv_timeout(Duration::from_secs(5))
            .expect("decode append stalled behind the in-flight prefill");
        assert!(a.ok(), "step {step} append: {:?}", a.output);
        let q = rng.normal_vec(D);
        let rx = srv.submit("res", q.clone()).unwrap();
        let resp = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("decode query stalled behind the in-flight prefill");
        assert!(resp.ok(), "step {step}: {:?}", resp.output);
        assert_eq!(
            resp.output.unwrap(),
            golden(&q, &kr, &vr, at + 1),
            "step {step}: decode under concurrent prefill diverged from golden"
        );
    }
    let decode_iters_during = srv.metrics.snapshot().decode_iters - decode_iters_before;
    assert!(
        decode_iters_during >= (2 * STEPS_DURING) as u64,
        "decode iterations must advance while the prefill lane is parked \
         (got {decode_iters_during})"
    );
    assert!(
        big_rx.try_recv().is_err(),
        "the gated prefill cannot have completed yet"
    );

    // release the prefill; its output must be untouched by everything
    // that decoded around it
    release.store(true, Ordering::SeqCst);
    let big = big_rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert!(big.ok(), "{:?}", big.output);
    assert_eq!(
        big.output.unwrap(),
        golden(&big_q, &kb, &vb, SEQ),
        "prefill served around live decode traffic diverged from golden"
    );
    srv.shutdown();
}

// Join/leave soak: sessions enter the running batch at staggered steps,
// decode together, and two of them leave (cancel + evict) mid-soak.
// Every output must stay bit-identical to solo serving, each join must
// cost exactly one admission, and a leave must not disturb survivors.
#[test]
fn join_leave_soak_stays_bit_identical_one_admission_per_join() {
    const SESSIONS: usize = 5;
    const STEPS: usize = 8;
    const PREFILL: usize = 6;
    const LEAVE_AFTER: usize = 5; // sessions 0 and 1 leave after this step
    let coord = CoordinatorConfig {
        max_batch: 8,
        max_total_batch: 256,
        batch_window_us: 2_000,
        workers: 2,
        queue_depth: 256,
        ..CoordinatorConfig::default()
    };
    let kv = Arc::new(KvStore::new(SEQ, D, SESSIONS));
    let mut rng = Rng::new(6007);
    let mats: Vec<(Mat, Mat)> = (0..SESSIONS)
        .map(|_| {
            let n = PREFILL + STEPS;
            (
                Mat::from_vec(n, D, rng.normal_vec(n * D)),
                Mat::from_vec(n, D, rng.normal_vec(n * D)),
            )
        })
        .collect();
    let factories = (0..coord.workers)
        .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg()))
        .collect();
    let srv = Server::start(&coord, kv.clone(), factories).unwrap();

    let mut joins = 0u64;
    let mut routed = 0u64; // expected slot_hits
    let mut queries_total = 0u64;
    let mut appends_total = 0u64;
    let active = |s: usize, step: usize| -> bool {
        s <= step && !(s < 2 && step > LEAVE_AFTER)
    };
    for step in 0..STEPS {
        // join: session `step` puts its prefill and sends its first
        // append (the admission); already-resident sessions decode
        let mut acks = Vec::new();
        for s in 0..SESSIONS {
            if !active(s, step) {
                continue;
            }
            let (k, v) = &mats[s];
            let at = PREFILL + (step - s);
            if s == step {
                kv.put(&format!("sess-{s}"), k.rows_slice(0, PREFILL), v.rows_slice(0, PREFILL))
                    .unwrap();
                joins += 1;
            } else {
                routed += 1;
            }
            appends_total += 1;
            acks.push((
                s,
                srv.submit_append(
                    &format!("sess-{s}"),
                    k.rows_slice(at, at + 1),
                    v.rows_slice(at, at + 1),
                )
                .unwrap(),
            ));
        }
        for (s, ack) in acks {
            let a = ack.recv().unwrap();
            assert!(a.ok(), "step {step} session {s} append: {:?}", a.output);
        }
        // interleaved attends across the whole running batch — decode
        // iterations may fuse several sessions into one ragged dispatch
        let mut rxs = Vec::new();
        for s in 0..SESSIONS {
            if !active(s, step) {
                continue;
            }
            let q = rng.normal_vec(D);
            routed += 1;
            queries_total += 1;
            rxs.push((s, q.clone(), srv.submit(&format!("sess-{s}"), q).unwrap()));
        }
        for (s, q, rx) in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.ok(), "step {step} session {s}: {:?}", resp.output);
            let (k, v) = &mats[s];
            let rows = PREFILL + (step - s) + 1;
            assert_eq!(
                resp.output.unwrap(),
                golden(&q, k, v, rows),
                "step {step} session {s}: join/leave soak diverged from golden over {rows} rows"
            );
        }
        if step == LEAVE_AFTER {
            // sessions 0 and 1 leave: slots retire at the iteration
            // boundary, KV bytes freed immediately
            for s in 0..2 {
                srv.cancel(&format!("sess-{s}"), true);
                assert!(!kv.contains(&format!("sess-{s}")), "evicted KV must be gone");
            }
        }
    }

    let snap = srv.metrics.snapshot();
    assert_eq!(snap.batcher_admissions, joins, "one admission per join, none per token: {snap:?}");
    assert_eq!(snap.slot_hits, routed, "{snap:?}");
    assert_eq!(snap.completed, queries_total);
    assert_eq!(snap.appends, appends_total);
    assert_eq!(snap.failed, 0, "soak must not shed anything: {snap:?}");
    assert_eq!(kv.pinned_sessions(), 0, "drained server must hold no pins");
    srv.shutdown();
}

// Cancellation with eviction: the session's KV bytes are freed
// synchronously (before its queued requests have drained), the queued
// requests fail with Cancelled, and the slot is retired — a rejoin is a
// fresh admission, not a hit on a stale slot.
#[test]
fn cancel_evicts_kv_and_retires_slot_before_queued_requests_drain() {
    const ROWS: usize = 16;
    let coord = CoordinatorConfig {
        max_batch: 8,
        max_total_batch: 64,
        batch_window_us: 500_000, // long window: queued queries sit forming
        workers: 1,
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };
    let kv = Arc::new(KvStore::new(SEQ, D, 4));
    let mut rng = Rng::new(911);
    let (k, v) = (
        Mat::from_vec(ROWS + 2, D, rng.normal_vec((ROWS + 2) * D)),
        Mat::from_vec(ROWS + 2, D, rng.normal_vec((ROWS + 2) * D)),
    );
    kv.put("s", k.rows_slice(0, ROWS), v.rows_slice(0, ROWS)).unwrap();
    let srv = Server::start(
        &coord,
        kv.clone(),
        vec![SimBackend::factory(Arith::Hfa, accel_cfg())],
    )
    .unwrap();

    // two queries parked in the forming window (the window is huge, so
    // they cannot dispatch before the cancel lands)
    let rx1 = srv.submit("s", rng.normal_vec(D)).unwrap();
    let rx2 = srv.submit("s", rng.normal_vec(D)).unwrap();
    assert!(kv.used_bytes() > 0);
    srv.cancel("s", true);
    // eviction is synchronous with the cancel call: bytes are gone
    // before the queued requests have received their terminal errors
    assert_eq!(kv.used_bytes(), 0, "cancel(evict_kv=true) must free bytes immediately");
    assert!(!kv.contains("s"));
    for rx in [rx1, rx2] {
        let resp = rx.recv().unwrap();
        let err = resp.output.unwrap_err();
        assert!(
            matches!(err, hfa::coordinator::ServeError::Cancelled),
            "queued request must drain as Cancelled, got {err:?}"
        );
    }
    assert_eq!(kv.pinned_sessions(), 0, "cancelled requests must release their pins");

    // rejoin: the slot was retired, so fresh traffic is a NEW admission
    // (and serves correctly against re-put KV)
    kv.put("s", k.rows_slice(0, ROWS), v.rows_slice(0, ROWS)).unwrap();
    let ack = srv
        .append("s", k.rows_slice(ROWS, ROWS + 1), v.rows_slice(ROWS, ROWS + 1))
        .unwrap();
    assert!(ack.ok(), "{:?}", ack.output);
    let q = rng.normal_vec(D);
    let resp = srv.call("s", q.clone()).unwrap();
    assert!(resp.ok(), "{:?}", resp.output);
    assert_eq!(resp.output.unwrap(), golden(&q, &k, &v, ROWS + 1));
    let snap = srv.metrics.snapshot();
    assert_eq!(
        snap.batcher_admissions, 1,
        "the rejoin after retire must be the only admission (the first \
         two queries were cancelled while still forming): {snap:?}"
    );
    assert_eq!(snap.shed, 2);
    assert_eq!(snap.cancelled, 2);
    srv.shutdown();
}

// Prefill token budget, end to end through the config knob: four
// sessions' first traffic arriving together must split across separate
// prefill admissions when each group alone reaches the budget.
#[test]
fn prefill_token_budget_splits_joins_across_admissions() {
    const SESSIONS: usize = 4;
    const JOIN_ROWS: usize = 4;
    let coord = CoordinatorConfig {
        max_batch: 8,
        max_total_batch: 64,
        batch_window_us: 1_000,
        workers: 1,
        queue_depth: 64,
        max_batch_prefill_tokens: JOIN_ROWS, // one join's rows fill the budget
        ..CoordinatorConfig::default()
    };
    let kv = Arc::new(KvStore::new(SEQ, D, SESSIONS));
    let mut rng = Rng::new(313);
    let mats: Vec<(Mat, Mat)> = (0..SESSIONS)
        .map(|_| {
            (
                Mat::from_vec(JOIN_ROWS, D, rng.normal_vec(JOIN_ROWS * D)),
                Mat::from_vec(JOIN_ROWS, D, rng.normal_vec(JOIN_ROWS * D)),
            )
        })
        .collect();
    let srv = Server::start(
        &coord,
        kv.clone(),
        vec![SimBackend::factory(Arith::Hfa, accel_cfg())],
    )
    .unwrap();

    // every session joins by appending its first rows into an empty
    // store — each append is a JOIN_ROWS-token group, so the budget
    // admits them one prefill dispatch at a time
    let acks: Vec<_> = (0..SESSIONS)
        .map(|s| {
            let (k, v) = &mats[s];
            srv.submit_append(&format!("sess-{s}"), k.clone(), v.clone()).unwrap()
        })
        .collect();
    for (s, ack) in acks.into_iter().enumerate() {
        let a = ack.recv().unwrap();
        assert!(a.ok(), "session {s} join append: {:?}", a.output);
    }
    let qs: Vec<Vec<f32>> = (0..SESSIONS).map(|_| rng.normal_vec(D)).collect();
    for (s, q) in qs.iter().enumerate() {
        let resp = srv.call(&format!("sess-{s}"), q.clone()).unwrap();
        assert!(resp.ok(), "session {s}: {:?}", resp.output);
        let (k, v) = &mats[s];
        assert_eq!(resp.output.unwrap(), golden(q, k, v, JOIN_ROWS));
    }
    let snap = srv.metrics.snapshot();
    assert_eq!(snap.batcher_admissions, SESSIONS as u64, "{snap:?}");
    assert_eq!(
        snap.prefill_iters, SESSIONS as u64,
        "a {JOIN_ROWS}-token budget must admit the {SESSIONS} joins one \
         prefill dispatch each: {snap:?}"
    );
    srv.shutdown();
}

// Deadline enforcement for parked admissions: a join deferred by the
// total-token budget against a persistently busy running batch never
// reaches a dispatch-side shed point, so the scheduler's own deadline
// sweep must fail it as TimedOut at its deadline and release its pin —
// not park it (and hang its caller) until the running batch drains.
// (Regression: the waiting queue used to be swept only on a Cancel.)
#[test]
fn token_budget_deferred_request_times_out_instead_of_hanging() {
    const BUSY_ROWS: usize = 12;
    const NEW_ROWS: usize = 9;
    let coord = CoordinatorConfig {
        max_batch: 8,
        max_total_batch: 64,
        batch_window_us: 500,
        workers: 1,
        queue_depth: 64,
        // busy (12 resident) + new (9 resident + 1 query) cannot coexist
        max_batch_total_tokens: 16,
        ..CoordinatorConfig::default()
    };
    let kv = Arc::new(KvStore::new(SEQ, D, 4));
    let mut rng = Rng::new(4242);
    let (kb, vb) = (
        Mat::from_vec(BUSY_ROWS, D, rng.normal_vec(BUSY_ROWS * D)),
        Mat::from_vec(BUSY_ROWS, D, rng.normal_vec(BUSY_ROWS * D)),
    );
    kv.put("busy", kb.clone(), vb.clone()).unwrap();
    kv.put(
        "new",
        Mat::from_vec(NEW_ROWS, D, rng.normal_vec(NEW_ROWS * D)),
        Mat::from_vec(NEW_ROWS, D, rng.normal_vec(NEW_ROWS * D)),
    )
    .unwrap();
    let armed = Arc::new(AtomicBool::new(false)); // let the admission serve
    let entered = Arc::new(AtomicBool::new(false));
    let release = Arc::new(AtomicBool::new(false));
    let srv = Server::start(
        &coord,
        kv.clone(),
        vec![GatedBackend::wrap_factory(
            SimBackend::factory(Arith::Hfa, accel_cfg()),
            armed.clone(),
            entered.clone(),
            release.clone(),
            BUSY_ROWS,
        )],
    )
    .unwrap();

    // make "busy" resident (its admission serves normally, unarmed)
    let q0 = rng.normal_vec(D);
    let r0 = srv.call("busy", q0.clone()).unwrap();
    assert!(r0.ok(), "{:?}", r0.output);
    assert_eq!(r0.output.unwrap(), golden(&q0, &kb, &vb, BUSY_ROWS));

    // park the running batch: busy's next decode step holds the lone
    // worker (and the decode lane) until released, so its slot stays
    // mid-flight — never idle, never retirable
    armed.store(true, Ordering::SeqCst);
    let q1 = rng.normal_vec(D);
    let busy_rx = srv
        .submit_with_deadline("busy", q1.clone(), std::time::Instant::now() + Duration::from_secs(60))
        .unwrap();
    let t0 = std::time::Instant::now();
    while !entered.load(Ordering::SeqCst) {
        assert!(t0.elapsed() < Duration::from_secs(10), "decode never reached the worker");
        std::thread::sleep(Duration::from_millis(1));
    }

    // the join that cannot fit: 12 + (9 + 1) > 16 and nothing is idle to
    // retire — admission defers.  Its deadline must still be enforced.
    let new_rx = srv
        .submit_with_deadline(
            "new",
            rng.normal_vec(D),
            std::time::Instant::now() + Duration::from_millis(300),
        )
        .unwrap();
    let resp = new_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("deferred join must be shed at its deadline, not parked forever");
    let err = resp.output.unwrap_err();
    assert!(
        matches!(err, hfa::coordinator::ServeError::TimedOut),
        "deferred join must time out, got {err:?}"
    );
    assert!(
        busy_rx.try_recv().is_err(),
        "the running batch is still parked: the shed came from the waiting queue"
    );
    let snap = srv.metrics.snapshot();
    assert_eq!(snap.timed_out, 1, "{snap:?}");
    assert_eq!(snap.shed, 1, "{snap:?}");

    // unpark; the resident session's decode is untouched by the shed
    release.store(true, Ordering::SeqCst);
    let busy = busy_rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert!(busy.ok(), "{:?}", busy.output);
    assert_eq!(busy.output.unwrap(), golden(&q1, &kb, &vb, BUSY_ROWS));
    assert_eq!(kv.pinned_sessions(), 0, "shed + served requests released every pin");
    srv.shutdown();
}

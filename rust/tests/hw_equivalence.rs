//! RTL-equivalence of the hardware model: the cycle-simulated accelerator
//! must compute exactly what the golden algorithm models compute, and its
//! timing must satisfy the paper's published latency points.

use hfa::attention::exact;
use hfa::attention::hfa as hfa_golden;
use hfa::config::AcceleratorConfig;
use hfa::hw::pipeline::LatencyModel;
use hfa::hw::{Accelerator, Arith};
use hfa::proptest::{check, Rng};
use hfa::Mat;

fn cfg(d: usize, n: usize, p: usize) -> AcceleratorConfig {
    AcceleratorConfig { head_dim: d, seq_len: n, kv_blocks: p, parallel_queries: 1, freq_mhz: 500.0 }
}

#[test]
fn paper_latency_points() {
    assert_eq!(LatencyModel::for_head_dim(32).total(), 19);
    assert_eq!(LatencyModel::for_head_dim(64).total(), 20);
    assert_eq!(LatencyModel::for_head_dim(128).total(), 21);
}

#[test]
fn property_hfa_accelerator_bit_equals_golden_blocked() {
    check(
        "accelerator == golden",
        2027,
        10,
        |rng: &mut Rng| {
            let d = [8usize, 16, 32][rng.below(3) as usize];
            let p = [1usize, 2, 4][rng.below(3) as usize];
            let n = 32 * p;
            let k = Mat::from_vec(n, d, rng.normal_vec(n * d));
            let v = Mat::from_vec(n, d, rng.normal_vec(n * d));
            let q = Mat::from_vec(2, d, rng.normal_vec(2 * d));
            (d, n, p, k, v, q)
        },
        |(d, n, p, k, v, q)| {
            let mut acc = Accelerator::new(Arith::Hfa, cfg(*d, *n, *p));
            acc.load_kv(k.clone(), v.clone()).map_err(|e| e.to_string())?;
            let (out, _) = acc.compute_batch(q).map_err(|e| e.to_string())?;
            let golden = hfa_golden::attention_blocked(
                &q.round_bf16(), &k.round_bf16(), &v.round_bf16(), *p, None, &mut None);
            if out.data == golden.data {
                Ok(())
            } else {
                Err(format!("bit mismatch, max|d|={}", out.max_abs_diff(&golden)))
            }
        },
    );
}

#[test]
fn property_fa2_accelerator_tracks_exact() {
    check(
        "fa2 accelerator ~= exact",
        31,
        10,
        |rng: &mut Rng| {
            let d = 16usize;
            let n = 64;
            (
                Mat::from_vec(n, d, rng.normal_vec(n * d)),
                Mat::from_vec(n, d, rng.normal_vec(n * d)),
                Mat::from_vec(3, d, rng.normal_vec(3 * d)),
            )
        },
        |(k, v, q)| {
            let mut acc = Accelerator::new(Arith::Fa2, cfg(16, 64, 4));
            acc.load_kv(k.clone(), v.clone()).map_err(|e| e.to_string())?;
            let (out, _) = acc.compute_batch(q).map_err(|e| e.to_string())?;
            let reference = exact::attention(
                &q.round_bf16(), &k.round_bf16(), &v.round_bf16(), None, None);
            let rel = out.rel_rms(&reference);
            if rel < 0.03 { Ok(()) } else { Err(format!("rel {rel}")) }
        },
    );
}

#[test]
fn more_blocks_never_slower() {
    let lat = LatencyModel::for_head_dim(64);
    let mut prev = u64::MAX;
    for p in [1usize, 2, 4, 8, 16] {
        let c = hfa::hw::pipeline::simulate(64, 1024, p, 1, 1, lat).cycles;
        assert!(c <= prev, "p={p} got slower: {c} > {prev}");
        prev = c;
    }
}

//! Cross-language bit-exactness: replay the golden vectors dumped by
//! `python/compile/goldens.py` and assert the rust arithmetic matches the
//! python spec bit-for-bit (DESIGN.md §3).
//!
//! Requires `make artifacts` (skips, loudly, if artifacts are missing).

use hfa::arith::bf16::Bf16;
use hfa::arith::fix::quant_diff_q7;
use hfa::arith::lns::{lns_add, Lns};
use hfa::arith::pwl;
use hfa::golden::{parse_attn_case, parse_rows};
use hfa::Mat;

fn golden_dir() -> Option<std::path::PathBuf> {
    let dir = hfa::artifacts_dir().join("golden");
    if dir.is_dir() {
        Some(dir)
    } else {
        eprintln!("WARNING: {} missing — run `make artifacts` first", dir.display());
        None
    }
}

#[test]
fn pwl_tables_bit_identical() {
    let Some(dir) = golden_dir() else { return };
    let rows = parse_rows(&dir.join("pwl_table.txt")).unwrap();
    assert_eq!(rows.len(), pwl::SEGMENTS);
    for (j, row) in rows.iter().enumerate() {
        assert_eq!(row[0] as i32, pwl::PWL_C0[j], "C0[{j}]");
        assert_eq!(row[1] as i32, pwl::PWL_C1[j], "C1[{j}]");
    }
}

#[test]
fn bf16_to_log_conversion_bit_identical() {
    let Some(dir) = golden_dir() else { return };
    let rows = parse_rows(&dir.join("log_conv.txt")).unwrap();
    assert!(rows.len() > 1000);
    for row in rows {
        let (bits, sign, logq) = (row[0] as u16, row[1] as i32, row[2] as i32);
        let l = Lns::from_bf16(Bf16::from_bits(bits));
        assert_eq!((l.sign, l.log), (sign, logq), "bits {bits:#06x}");
    }
}

#[test]
fn log_to_bf16_conversion_bit_identical() {
    let Some(dir) = golden_dir() else { return };
    let rows = parse_rows(&dir.join("back_conv.txt")).unwrap();
    for row in rows {
        let (sign, logq, bits) = (row[0] as i32, row[1] as i32, row[2] as u16);
        let got = Lns { sign, log: logq }.to_bf16().bits();
        assert_eq!(got, bits, "sign {sign} log {logq}");
    }
}

#[test]
fn quantizer_bit_identical() {
    let Some(dir) = golden_dir() else { return };
    let rows = parse_rows(&dir.join("quant.txt")).unwrap();
    for row in rows {
        let x = f32::from_bits(row[0] as u32);
        assert_eq!(quant_diff_q7(x), row[1] as i32, "x={x}");
    }
}

#[test]
fn lns_add_bit_identical() {
    let Some(dir) = golden_dir() else { return };
    let rows = parse_rows(&dir.join("lns_add.txt")).unwrap();
    assert!(rows.len() > 3000);
    for row in rows {
        let a = Lns { sign: row[0] as i32, log: row[1] as i32 };
        let b = Lns { sign: row[2] as i32, log: row[3] as i32 };
        let r = lns_add(a, b);
        assert_eq!(
            (r.sign, r.log),
            (row[4] as i32, row[5] as i32),
            "lns_add({a:?}, {b:?})"
        );
    }
}

fn run_attn_case(name: &str) {
    let Some(dir) = golden_dir() else { return };
    let case = parse_attn_case(&dir.join(name)).unwrap();
    let v = Mat::from_vec(case.n, case.d, case.v.clone());

    // 1) LNS pipeline from python's own scores: must be bit-exact
    let scores = Mat::from_vec(case.b, case.n, case.scores.clone());
    if case.num_blocks == 1 {
        let out = hfa::attention::hfa::attention_from_scores(&scores, &v);
        for (i, &expect_bits) in case.out_bf16.iter().enumerate() {
            let got = Bf16::from_f32(out.data[i]).bits();
            assert_eq!(got, expect_bits, "{name}: lane {i} from-scores mismatch");
        }
    }

    // 2) full pipeline recomputing scores in rust: tolerance-level match
    //    (f32 dot association order differs from numpy BLAS)
    let q = Mat::from_vec(case.b, case.d, case.q.clone());
    let k = Mat::from_vec(case.n, case.d, case.k.clone());
    let out = if case.num_blocks == 1 {
        hfa::attention::hfa::attention(&q, &k, &v, None, None, &mut None)
    } else {
        hfa::attention::hfa::attention_blocked(&q, &k, &v, case.num_blocks, None, &mut None)
    };
    let expect: Vec<f32> = case
        .out_bf16
        .iter()
        .map(|&b| Bf16::from_bits(b).to_f32())
        .collect();
    let expect = Mat::from_vec(case.b, case.d, expect);
    let rel = out.rel_rms(&expect);
    assert!(rel < 0.06, "{name}: full-pipeline rel rms {rel}");

    // 3) rust FA-2 vs python FA-2 reference
    let fa2 = hfa::attention::fa2::attention(&q, &k, &v, None, None);
    let fa2_ref = Mat::from_vec(case.b, case.d, case.fa2_f32.clone());
    assert!(fa2.max_abs_diff(&fa2_ref) < 1e-3, "{name}: fa2 mismatch");
}

#[test]
fn attention_case_small_replays() {
    run_attn_case("attn_case_small.txt");
}

#[test]
fn attention_case_mid_replays() {
    run_attn_case("attn_case_mid.txt");
}

#[test]
fn attention_case_blocked_replays() {
    run_attn_case("attn_case_blocked.txt");
}

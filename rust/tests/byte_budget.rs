//! Byte-budget eviction and in-flight pinning through the serving stack.
//!
//! * A query queued in the batcher pins its session: LRU pressure from
//!   other sessions can no longer evict it into a spurious "unknown
//!   session" failure (the pre-pinning race).
//! * Admission-control failures (byte budget exhausted by pinned
//!   sessions, capacity overflow) surface as explicit error responses
//!   through `Server::submit_append`, not as silent drops.

use std::sync::Arc;

use hfa::attention::prepared::row_bytes;
use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{KvStore, Server, SimBackend};
use hfa::hw::Arith;
use hfa::proptest::Rng;
use hfa::Mat;

const D: usize = 8;

fn accel_cfg(seq_len: usize) -> AcceleratorConfig {
    AcceleratorConfig {
        head_dim: D,
        seq_len,
        kv_blocks: 2,
        parallel_queries: 1,
        freq_mhz: 500.0,
    }
}

fn full_session(rng: &mut Rng, n: usize) -> (Mat, Mat) {
    (
        Mat::from_vec(n, D, rng.normal_vec(n * D)),
        Mat::from_vec(n, D, rng.normal_vec(n * D)),
    )
}

#[test]
fn queued_queries_pin_their_session_against_eviction() {
    // regression for the eviction-vs-in-flight race: the query sits in
    // the batcher for the whole forming window while enough puts arrive
    // to evict its session twice over
    let coord = CoordinatorConfig {
        max_batch: 8,
        max_total_batch: 256,
        batch_window_us: 300_000, // long window: the query stays queued
        workers: 1,
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };
    let kv = Arc::new(KvStore::new(32, D, 2)); // budget: two full sessions
    let mut rng = Rng::new(404);
    let (k, v) = full_session(&mut rng, 32);
    kv.put("victim", k, v).unwrap();
    let factories = vec![SimBackend::factory(Arith::Hfa, accel_cfg(32))];
    let srv = Server::start(&coord, kv.clone(), factories).unwrap();

    let rx = srv.submit("victim", rng.normal_vec(D)).unwrap();
    // two more full sessions: without the pin, LRU would evict "victim"
    let (k2, v2) = full_session(&mut rng, 32);
    kv.put("b", k2, v2).unwrap();
    let (k3, v3) = full_session(&mut rng, 32);
    kv.put("c", k3, v3).unwrap();
    assert!(kv.contains("victim"), "pinned session was evicted under pressure");
    assert!(kv.evictions() >= 1, "the pressure must have evicted an unpinned session");

    let resp = rx.recv().unwrap();
    assert!(resp.ok(), "queued query hit the race: {:?}", resp.output);

    // delivery released the pin: enough new pressure now evicts it
    for name in ["d", "e"] {
        let (kx, vx) = full_session(&mut rng, 32);
        kv.put(name, kx, vx).unwrap();
    }
    assert!(!kv.contains("victim"), "delivered session must be evictable again");
    srv.shutdown();
}

#[test]
fn append_admission_errors_surface_through_server() {
    let coord = CoordinatorConfig {
        max_batch: 4,
        max_total_batch: 256,
        batch_window_us: 100,
        workers: 1,
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };
    // budget: exactly 16 rows of prepared KV
    let kv = Arc::new(KvStore::with_byte_budget(16, D, 16 * row_bytes(D, D)));
    let mut rng = Rng::new(505);
    let (k, v) = full_session(&mut rng, 8);
    kv.put("dec", k, v).unwrap();
    let (k2, v2) = full_session(&mut rng, 8);
    kv.put("other", k2, v2).unwrap();
    // "other" has in-flight work elsewhere: it cannot be the victim
    assert!(kv.pin("other"));

    let factories = vec![SimBackend::factory(Arith::Hfa, accel_cfg(16))];
    let srv = Server::start(&coord, kv.clone(), factories).unwrap();

    // growing "dec" needs a victim, but the only candidate is pinned:
    // the admission error must come back as an error acknowledgement
    let (k1, v1) = full_session(&mut rng, 1);
    let ack = srv.append("dec", k1.clone(), v1.clone()).unwrap();
    assert!(!ack.ok(), "over-budget append must fail, not silently evict a pinned session");
    let msg = ack.output.unwrap_err().to_string();
    assert!(msg.contains("pinned") || msg.contains("budget"), "unexpected error: {msg}");
    assert!(kv.contains("other"), "pinned session must survive");
    assert_eq!(kv.get("dec").unwrap().prepared().n(), 8, "failed append must not apply");

    // releasing the pin lets the same append evict and land
    kv.unpin("other");
    let ack = srv.append("dec", k1, v1).unwrap();
    assert!(ack.ok(), "{:?}", ack.output);
    assert_eq!(kv.get("dec").unwrap().prepared().n(), 9);
    assert!(!kv.contains("other"), "unpinned LRU session becomes the victim");

    // a query for the evicted session still fails cleanly (explicit
    // error, not a hang) — admission control never strands a caller
    let resp = srv.call("other", rng.normal_vec(D)).unwrap();
    assert!(!resp.ok());
    assert!(resp.output.unwrap_err().to_string().contains("unknown session"));
    srv.shutdown();
}

#[test]
fn byte_budget_serves_many_short_sessions_concurrently() {
    // the count-based store held `capacity` sessions regardless of size;
    // the byte budget packs four half-length decode prefills into the
    // space of two full sessions and serves them all
    let coord = CoordinatorConfig {
        max_batch: 4,
        max_total_batch: 256,
        batch_window_us: 100,
        workers: 2,
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };
    let kv = Arc::new(KvStore::new(32, D, 2));
    let mut rng = Rng::new(606);
    for s in 0..4 {
        let (k, v) = full_session(&mut rng, 16);
        kv.put(&format!("s{s}"), k, v).unwrap();
    }
    assert_eq!(kv.resident(), 4, "four half sessions fit in two full sessions' bytes");
    assert_eq!(kv.evictions(), 0);

    let factories = (0..coord.workers)
        .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg(32)))
        .collect();
    let srv = Server::start(&coord, kv.clone(), factories).unwrap();
    for s in 0..4 {
        let resp = srv.call(&format!("s{s}"), rng.normal_vec(D)).unwrap();
        assert!(resp.ok(), "session s{s}: {:?}", resp.output);
    }
    assert_eq!(srv.metrics.snapshot().completed, 4);
    srv.shutdown();
}

//! Figs. 6 & 7: area and power of the H-FA vs FA-2 accelerators at 28 nm,
//! 500 MHz, 4 parallel KV blocks, head dims 32/64/128, datapath + KV SRAM
//! — plus the Fig. 6-style per-block breakdown at d=32.

use hfa::benchlib::Table;
use hfa::config::AcceleratorConfig;
use hfa::hw::cost::{compare, report::breakdown_table, Arith};

fn main() {
    // ---- Fig. 7 -----------------------------------------------------------
    let mut t = Table::new(
        "Fig. 7 analog — area (mm^2) and power (mW) at 28 nm / 500 MHz, 4 KV blocks",
        &["d", "FA-2 dp", "FA-2 sram", "H-FA dp", "H-FA sram",
          "area savings %", "FA-2 mW", "H-FA mW", "power savings %"],
    );
    let mut a_savings = Vec::new();
    let mut p_savings = Vec::new();
    for d in [32usize, 64, 128] {
        let cfg = AcceleratorConfig {
            head_dim: d,
            seq_len: 1024,
            kv_blocks: 4,
            parallel_queries: 1,
            freq_mhz: 500.0,
        };
        let (fa2, hfa_r, area_s, power_s) = compare(&cfg, 64);
        a_savings.push(area_s);
        p_savings.push(power_s);
        t.row(&[
            d.to_string(),
            format!("{:.3}", fa2.datapath_area_mm2),
            format!("{:.3}", fa2.sram_area_mm2),
            format!("{:.3}", hfa_r.datapath_area_mm2),
            format!("{:.3}", hfa_r.sram_area_mm2),
            format!("{area_s:.1}"),
            format!("{:.0}", fa2.total_power_mw()),
            format!("{:.0}", hfa_r.total_power_mw()),
            format!("{power_s:.1}"),
        ]);
    }
    t.emit("fig7_area_power");
    println!(
        "mean area savings {:.1}% (paper: 26.5%), mean power savings {:.1}% (paper: 23.4%)",
        a_savings.iter().sum::<f64>() / a_savings.len() as f64,
        p_savings.iter().sum::<f64>() / p_savings.len() as f64
    );

    // ---- Fig. 6 breakdown at d=32, p=4 -------------------------------------
    let mut b = Table::new(
        "Fig. 6 analog — datapath area breakdown at d=32, 4 KV blocks (mm^2)",
        &["block", "FA-2", "H-FA"],
    );
    let fa2_rows = breakdown_table(Arith::Fa2, 32, 4);
    let hfa_rows = breakdown_table(Arith::Hfa, 32, 4);
    for (i, (name, area)) in fa2_rows.iter().enumerate() {
        let hname = &hfa_rows[i].0;
        let label = if name == hname { name.clone() } else { format!("{name} / {hname}") };
        b.row(&[label, format!("{area:.4}"), format!("{:.4}", hfa_rows[i].1)]);
    }
    let fa2_total: f64 = fa2_rows.iter().map(|r| r.1).sum();
    let hfa_total: f64 = hfa_rows.iter().map(|r| r.1).sum();
    b.row(&[
        "TOTAL datapath".into(),
        format!("{fa2_total:.4}"),
        format!("{hfa_total:.4}"),
    ]);
    b.emit("fig6_breakdown");
    println!(
        "datapath-only savings at d=32: {:.1}% (paper Fig. 6: 36.1%)",
        100.0 * (1.0 - hfa_total / fa2_total)
    );
}

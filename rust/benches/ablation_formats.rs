//! Ablation of the paper's number-format design choices: fraction bits of
//! the fixed-point log format (paper: 7, matching the BF16 mantissa) and
//! the PWL segment count for 2^-f (paper: 8).  Sweeps attention output
//! error vs a per-lane hardware-cost proxy, justifying the chosen point.

use hfa::attention::exact;
use hfa::benchlib::Table;
use hfa::proptest::Rng;
use hfa::Mat;

/// Functional H-FA with parameterized fraction bits + PWL segments
/// (f64 carrier; mirrors attention_emu with all approximations on).
fn hfa_param(q: &Mat, k: &Mat, v: &Mat, frac_bits: u32, segments: usize) -> Mat {
    let (b, d) = (q.rows, q.cols);
    let n = k.rows;
    let scale = 1.0 / (d as f32).sqrt();
    let grid = (1u64 << frac_bits) as f64;
    let quant = |x: f64| (x.clamp(-15.0, 0.0) * std::f64::consts::LOG2_E * grid).floor() / grid;
    let pwl = |dist: f64| {
        let p = dist.floor();
        let f = dist - p;
        let j = ((f * segments as f64) as usize).min(segments - 1);
        let y0 = 2f64.powf(-(j as f64) / segments as f64);
        let y1 = 2f64.powf(-((j + 1) as f64) / segments as f64);
        (y0 + (y1 - y0) * (f * segments as f64 - j as f64)) * 2f64.powf(-p.min(60.0))
    };
    let logv: Vec<Vec<(i32, f64)>> = (0..n)
        .map(|i| {
            let mut row = vec![(0i32, 0.0f64)];
            for &x in v.row(i) {
                let bf = hfa::Bf16::from_f32(x);
                if bf.is_zero_or_subnormal() {
                    row.push((bf.sign() as i32, f64::NEG_INFINITY));
                } else {
                    // Mitchell float->log at the chosen grid
                    let m = (bf.mantissa() as f64 / 128.0 * grid).floor() / grid;
                    row.push((bf.sign() as i32, bf.exponent() as f64 - 127.0 + m));
                }
            }
            row
        })
        .collect();
    let mut out = Mat::zeros(b, d);
    for bi in 0..b {
        let mut m = f32::NEG_INFINITY;
        let mut sg = vec![0i32; d + 1];
        let mut lg = vec![f64::NEG_INFINITY; d + 1];
        for i in 0..n {
            let s = hfa::tensor::dot_f32(q.row(bi), k.row(i)) * scale;
            let m_new = m.max(s);
            let dm = quant((m - m_new) as f64);
            let ds = quant((s - m_new) as f64);
            for l in 0..=d {
                let a = lg[l] + dm;
                let (sv, vlg) = logv[i][l];
                let bb = vlg + ds;
                if a == f64::NEG_INFINITY && bb == f64::NEG_INFINITY {
                    continue;
                }
                if a == f64::NEG_INFINITY {
                    sg[l] = sv;
                    lg[l] = bb;
                    continue;
                }
                if bb == f64::NEG_INFINITY {
                    lg[l] = a;
                    continue;
                }
                let dist = (a - bb).abs();
                let r = (pwl(dist) * grid).floor() / grid; // truncate to grid
                let mx = a.max(bb);
                lg[l] = if sg[l] == sv { mx + r } else { mx - r };
                sg[l] = if a > bb { sg[l] } else { sv };
            }
            m = m_new;
        }
        for j in 0..d {
            let la = lg[j + 1] - lg[0];
            let mag = if la.is_finite() {
                let ip = la.floor();
                2f64.powf(ip) * (1.0 + (la - ip)) // Eq. 22 back-conversion
            } else {
                0.0
            };
            out.set(bi, j, if sg[j + 1] ^ sg[0] == 1 { -mag as f32 } else { mag as f32 });
        }
    }
    out
}

fn main() {
    let (b, n, d) = (4usize, 128usize, 32usize);
    let mut rng = Rng::new(314);
    let q = Mat::from_vec(b, d, rng.normal_vec(b * d)).round_bf16();
    let k = Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16();
    let v = Mat::from_vec(n, d, rng.normal_vec(n * d)).round_bf16();
    let reference = exact::attention(&q, &k, &v, None, None);

    let mut t = Table::new(
        "Design-choice ablation — log-format fraction bits x PWL segments \
         (error vs per-lane cost proxy; paper picks 7 bits / 8 segments)",
        &["frac bits", "PWL segs", "rel RMS err", "lane adder bits", "LUT entries"],
    );
    for &fb in &[4u32, 5, 6, 7, 8, 10] {
        for &seg in &[2usize, 4, 8, 16] {
            let out = hfa_param(&q, &k, &v, fb, seg);
            let err = out.rel_rms(&reference);
            t.row(&[
                fb.to_string(),
                seg.to_string(),
                format!("{err:.4}"),
                (9 + fb).to_string(),
                seg.to_string(),
            ]);
        }
    }
    t.emit("ablation_formats");
    println!(
        "observation: error saturates at the Mitchell floor by ~7 fraction bits / 8 segments —\n\
         finer formats pay area without accuracy (the paper's 16-bit Q9.7 + 8-segment choice)."
    );
}

//! End-to-end serving throughput/latency through the coordinator:
//! simulated-accelerator backends (H-FA vs FA-2) and, when artifacts are
//! present, the PJRT-compiled H-FA kernel backend.  Also reports the raw
//! accelerator compute-batch wall time (coordinator overhead = difference),
//! a decode-loop scenario (prefill once, then N append+attend steps)
//! comparing the append-only path against rebuilding the session per step,
//! a continuous-decode scenario (S resident sessions streaming one token
//! per round through the slot-table scheduler — tokens/s plus the
//! server-side inter-token p99),
//! a streaming-ingress scenario (S loopback socket clients, one token
//! frame per decode step through the framed front end — end-to-end
//! tokens/s plus first-token / inter-token delivery p99),
//! and the query-tiled kernel microbench (EXPERIMENTS.md §Tiling): exact
//! K/V stream traffic per tile height plus the batch-1 two-axis decode
//! grid.
//!
//! Every scenario also lands as a row in `BENCH_attention.json`
//! (`target/bench_results/`, schema `{bench, shape, ns_per_step,
//! kv_bytes_copied}`) so the perf trajectory is machine-readable; the
//! bench validates its own output so CI's tiny-shape smoke run fails if
//! the writer regresses.  Shapes honour `HFA_BENCH_N` / `HFA_BENCH_D`
//! (defaults 1024 / 64) so that smoke run stays cheap.

use std::sync::Arc;
use std::time::{Duration, Instant};

use hfa::attention::kernel;
use hfa::attention::PreparedKv;
use hfa::benchlib::{bench, validate_json, write_bench_json, BenchRow, Table};
use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{KvStore, PjrtBackend, Server, SimBackend};
use hfa::hw::{Accelerator, Arith};
use hfa::proptest::Rng;
use hfa::runtime::AttnKernelSpec;
use hfa::Mat;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn drive(server: &Server, total: usize, d: usize, rng: &mut Rng) -> (f64, f64, f64) {
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for _ in 0..total {
        loop {
            match server.submit("bench", rng.normal_vec(d)) {
                Ok(rx) => {
                    pending.push(rx);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_micros(50)), // backpressure
            }
        }
    }
    for rx in pending {
        let r = rx.recv().expect("response");
        assert!(r.ok(), "{:?}", r.output);
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics.snapshot();
    (total as f64 / wall, snap.p50_us, snap.p99_us)
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(2024);
    let d = env_usize("HFA_BENCH_D", 64);
    let n = env_usize("HFA_BENCH_N", 1024);
    let accel_cfg = AcceleratorConfig {
        head_dim: d,
        seq_len: n,
        kv_blocks: 4,
        parallel_queries: 1,
        freq_mhz: 500.0,
    };
    let coord_cfg = CoordinatorConfig {
        max_batch: 16,
        max_total_batch: 256,
        batch_window_us: 150,
        workers: 2,
        queue_depth: 256,
        ..CoordinatorConfig::default()
    };
    let total: usize = env_usize("HFA_BENCH_REQS", 256);
    let mut json_rows: Vec<BenchRow> = Vec::new();

    let k = Mat::from_vec(n, d, rng.normal_vec(n * d));
    let v = Mat::from_vec(n, d, rng.normal_vec(n * d));

    let mut t = Table::new(
        &format!("E2E serving — coordinator + backend, N={n}, d={d}, 4 KV blocks"),
        &["backend", "requests", "QPS", "p50 us", "p99 us", "mean batch"],
    );

    for (name, slug, arith) in
        [("sim H-FA", "e2e_sim_hfa", Arith::Hfa), ("sim FA-2", "e2e_sim_fa2", Arith::Fa2)]
    {
        let kv = Arc::new(KvStore::new(n, d, 4));
        kv.put("bench", k.clone(), v.clone())?;
        let factories = (0..coord_cfg.workers)
            .map(|_| SimBackend::factory(arith, accel_cfg.clone()))
            .collect();
        let server = Server::start(&coord_cfg, kv, factories)?;
        let (qps, p50, p99) = drive(&server, total, d, &mut rng);
        let snap = server.metrics.snapshot();
        t.row(&[
            name.into(),
            total.to_string(),
            format!("{qps:.0}"),
            format!("{p50:.0}"),
            format!("{p99:.0}"),
            format!("{:.1}", snap.mean_batch),
        ]);
        json_rows.push(BenchRow {
            bench: slug.into(),
            shape: format!("N{n}_d{d}_p4"),
            ns_per_step: 1e9 / qps.max(1e-9),
            kv_bytes_copied: 0,
        });
        server.shutdown();
    }

    // PJRT backend (needs artifacts)
    let spec = AttnKernelSpec { kind: "hfa".into(), head_dim: d, seq_len: n, batch: 16 };
    let artifacts = hfa::artifacts_dir();
    if artifacts.join("hlo").join(spec.file_name()).is_file() {
        let kv = Arc::new(KvStore::new(n, d, 4));
        kv.put("bench", k.clone(), v.clone())?;
        let factories = vec![
            PjrtBackend::factory(artifacts.clone(), spec.clone()),
            PjrtBackend::factory(artifacts.clone(), spec),
        ];
        let server = Server::start(&coord_cfg, kv, factories)?;
        let (qps, p50, p99) = drive(&server, total, d, &mut rng);
        let snap = server.metrics.snapshot();
        t.row(&[
            "pjrt H-FA kernel".into(),
            total.to_string(),
            format!("{qps:.0}"),
            format!("{p50:.0}"),
            format!("{p99:.0}"),
            format!("{:.1}", snap.mean_batch),
        ]);
        json_rows.push(BenchRow {
            bench: "e2e_pjrt_hfa".into(),
            shape: format!("N{n}_d{d}"),
            ns_per_step: 1e9 / qps.max(1e-9),
            kv_bytes_copied: 0,
        });
        server.shutdown();
    } else {
        eprintln!("(skipping PJRT backend row: artifacts missing)");
    }
    t.emit("e2e_throughput");

    // Session fan-out (EXPERIMENTS.md §Fused-batching): S sessions with
    // ONE in-flight query each — the worst-case regime for the old
    // single-session batcher, which shipped S batch-size-1 dispatches
    // per round.  The two-level batcher fuses each round into
    // ~ceil(S / max_total_batch) super-batch dispatches; the
    // "dispatches" and "sessions/dispatch" columns are exact structural
    // counts from the metrics, machine-independent.
    let fan_sessions = env_usize("HFA_BENCH_SESSIONS", 64);
    let fan_rounds = env_usize("HFA_BENCH_FANOUT_ROUNDS", 8);
    let prefill = (n / 4).max(1);
    let mut ft = Table::new(
        &format!(
            "Session fan-out — {fan_sessions} sessions x 1 query/round, \
             prefill {prefill} of N={n}, d={d}"
        ),
        &["sessions", "rounds", "QPS", "dispatches", "sessions/dispatch", "p99 us"],
    );
    {
        let fan_coord = CoordinatorConfig {
            max_batch: 16,
            max_total_batch: 1024,
            batch_window_us: 500,
            workers: 2,
            queue_depth: fan_sessions.max(256),
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(n, d, fan_sessions));
        for s in 0..fan_sessions {
            kv.put(&format!("fan-{s}"), k.rows_slice(0, prefill), v.rows_slice(0, prefill))?;
        }
        let factories = (0..fan_coord.workers)
            .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg.clone()))
            .collect();
        let server = Server::start(&fan_coord, kv, factories)?;
        let t0 = Instant::now();
        for _ in 0..fan_rounds {
            let rxs: Vec<_> = (0..fan_sessions)
                .map(|s| loop {
                    match server.submit(&format!("fan-{s}"), rng.normal_vec(d)) {
                        Ok(rx) => break rx,
                        Err(_) => std::thread::sleep(Duration::from_micros(50)),
                    }
                })
                .collect();
            for rx in rxs {
                let r = rx.recv().expect("response");
                assert!(r.ok(), "{:?}", r.output);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let total_q = (fan_sessions * fan_rounds) as f64;
        let qps = total_q / wall;
        let snap = server.metrics.snapshot();
        ft.row(&[
            fan_sessions.to_string(),
            fan_rounds.to_string(),
            format!("{qps:.0}"),
            snap.batches.to_string(),
            format!("{:.1}", snap.mean_sessions),
            format!("{:.0}", snap.p99_us),
        ]);
        // the structural dispatch count lives in the markdown/CSV table
        // above; the JSON row keeps the schema honest (kv_bytes_copied
        // is a byte counter — this scenario copies nothing)
        json_rows.push(BenchRow {
            bench: format!("fanout_s{fan_sessions}"),
            shape: format!("S{fan_sessions}_N{n}_d{d}_prefill{prefill}"),
            ns_per_step: 1e9 / qps.max(1e-9),
            kv_bytes_copied: 0,
        });
        server.shutdown();
    }
    ft.emit("session_fanout");

    // raw accelerator batch compute (no coordinator) for overhead attribution
    let mut accel = Accelerator::new(Arith::Hfa, accel_cfg.clone());
    accel.load_kv(k.clone(), v.clone())?;
    let q = Mat::from_vec(16, d, rng.normal_vec(16 * d));
    let stats = bench(2, 20, Duration::from_secs(10), || {
        let _ = accel.compute_batch(&q).unwrap();
    });
    println!(
        "raw sim-accelerator compute_batch(16 queries): mean {:.2} ms (functional model wall time; modelled silicon time: {:.1} us)",
        stats.mean_ms(),
        accel.compute_batch(&q)?.1.time_us(500.0)
    );

    // KV-preparation amortization (EXPERIMENTS.md §Perf): per-call
    // conversion (the seed serving behaviour) vs prepared-KV reuse
    let kb = k.round_bf16();
    let vb = v.round_bf16();
    let per_call = bench(2, 20, Duration::from_secs(10), || {
        let _ = hfa::attention::hfa::attention(&q, &kb, &vb, None, None, &mut None);
    });
    let prepared = PreparedKv::new(kb.clone(), vb.clone());
    let reused = bench(2, 20, Duration::from_secs(10), || {
        let _ = prepared.attention(&q, None, None);
    });
    println!(
        "attention(16 queries, N={n}, d={d}): per-call V->LNS {:.2} ms, prepared-KV reuse {:.2} ms ({:.2}x)",
        per_call.mean_ms(),
        reused.mean_ms(),
        per_call.mean_ns / reused.mean_ns.max(1.0)
    );

    // Query-tiled kernel microbench (EXPERIMENTS.md §Tiling): exact K/V
    // stream traffic per call at qt=1 (the seed's per-query streaming)
    // vs the default tile — the ~QT-fold reduction — plus the batch-1
    // two-axis grid across resident-block counts (decode-step
    // parallelism ∝ blocks even with a single query).
    let qt_default = kernel::DEFAULT_QUERY_TILE;
    let bq = 16usize;
    let qm = Mat::from_vec(bq, d, rng.normal_vec(bq * d)).round_bf16();
    let mut kt = Table::new(
        &format!("Tiled kernel — N={n}, d={d} (stream traffic exact, from kv_stream_bytes)"),
        &["config", "ns/call", "KV rows streamed/call", "stream KiB/call"],
    );
    for qt in [1usize, qt_default] {
        let s0 = kernel::kv_stream_bytes();
        let _ = prepared.attention_tiled(&qm, 1, None, qt);
        let per_call_bytes = kernel::kv_stream_bytes() - s0;
        let st = bench(2, 20, Duration::from_secs(5), || {
            let _ = prepared.attention_tiled(&qm, 1, None, qt);
        });
        kt.row(&[
            format!("B={bq} qt={qt}"),
            format!("{:.0}", st.mean_ns),
            (per_call_bytes / kernel::row_stream_bytes(d, d)).to_string(),
            format!("{:.1}", per_call_bytes as f64 / 1024.0),
        ]);
        json_rows.push(BenchRow {
            bench: format!("kernel_stream_qt{qt}"),
            shape: format!("B{bq}_N{n}_d{d}_p1"),
            ns_per_step: st.mean_ns,
            kv_bytes_copied: per_call_bytes,
        });
    }
    let q1 = Mat::from_vec(1, d, rng.normal_vec(d)).round_bf16();
    for p in [1usize, 8] {
        let st = bench(2, 50, Duration::from_secs(5), || {
            let _ = prepared.attention_tiled(&q1, p, None, qt_default);
        });
        kt.row(&[
            format!("B=1 grid p={p}"),
            format!("{:.0}", st.mean_ns),
            "-".into(),
            "-".into(),
        ]);
        json_rows.push(BenchRow {
            bench: format!("decode_b1_grid_p{p}"),
            shape: format!("B1_N{n}_d{d}_p{p}"),
            ns_per_step: st.mean_ns,
            kv_bytes_copied: 0,
        });
    }
    kt.emit("tiled_kernel");

    // decode loop (EXPERIMENTS.md §Decode): prefill once, then STEPS x
    // (one-row KV write + one attend).  "append" uses Server::append
    // (convert only the new row); "re-put" rebuilds the whole session per
    // step — the only option before the append path existed.
    let steps: usize = env_usize("HFA_BENCH_DECODE_STEPS", 64).min(n / 2);
    let prefill = n - steps;
    // NOTE on fairness: both arms time the full step (KV write + attend)
    // via wall clock, which is symmetric; per-request latency percentiles
    // are NOT comparable across arms (the re-put arm's write bypasses the
    // server and its metrics), so the table reports steps/s only.
    let mut dt = Table::new(
        &format!("Decode loop — prefill once, then append+attend per token, N={n}, d={d}"),
        &[
            "KV write path",
            "prefill",
            "steps",
            "steps/s",
            "step mean us",
            "V rows converted",
            "KV MiB copied",
        ],
    );
    for (name, slug, use_append) in [
        ("chunked append", "decode_append", true),
        ("full re-put (seed)", "decode_reput", false),
    ] {
        let kv = Arc::new(KvStore::new(n, d, 4));
        kv.put("dec", k.rows_slice(0, prefill), v.rows_slice(0, prefill))?;
        let factories = (0..coord_cfg.workers)
            .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg.clone()))
            .collect();
        let server = Server::start(&coord_cfg, kv.clone(), factories)?;
        let conv0 = hfa::attention::hfa::value_conversion_count();
        let copy0 = hfa::attention::prepared::kv_copy_bytes();
        let t0 = Instant::now();
        for s in 0..steps {
            let at = prefill + s;
            if use_append {
                let ack = server.append(
                    "dec",
                    k.rows_slice(at, at + 1),
                    v.rows_slice(at, at + 1),
                )?;
                assert!(ack.ok(), "{:?}", ack.output);
            } else {
                kv.put("dec", k.rows_slice(0, at + 1), v.rows_slice(0, at + 1))?;
            }
            let r = server.call("dec", rng.normal_vec(d))?;
            assert!(r.ok(), "{:?}", r.output);
        }
        let wall = t0.elapsed().as_secs_f64();
        let converted = hfa::attention::hfa::value_conversion_count() - conv0;
        let copied = hfa::attention::prepared::kv_copy_bytes() - copy0;
        dt.row(&[
            name.into(),
            prefill.to_string(),
            steps.to_string(),
            format!("{:.0}", steps as f64 / wall),
            format!("{:.0}", wall / steps as f64 * 1e6),
            converted.to_string(),
            format!("{:.2}", copied as f64 / (1024.0 * 1024.0)),
        ]);
        json_rows.push(BenchRow {
            bench: slug.into(),
            shape: format!("B1_N{n}_d{d}_prefill{prefill}_steps{steps}"),
            ns_per_step: wall / steps as f64 * 1e9,
            kv_bytes_copied: copied,
        });
        server.shutdown();
    }
    dt.emit("decode_loop");

    // Continuous batching (EXPERIMENTS.md §Continuous-batching): S resident
    // decode sessions each streaming one token per round (append ack, then
    // attend), scheduled from the slot table — after the first round no
    // request round-trips through the window batcher, so "admissions" stays
    // at the S joins while "slot hits" grows with every decoded token.
    // tokens/s counts decoded tokens (one append per session per round);
    // inter-token p99 is the server-side decode-gap reservoir, measured
    // between consecutive decode dispatches of the same session.
    let cont_steps = env_usize("HFA_BENCH_CONT_STEPS", 32).min(n / 2);
    let cont_prefill = (n / 4).max(1).min(n - cont_steps);
    let mut ct = Table::new(
        &format!(
            "Continuous decode — S resident sessions x 1 token/round, \
             prefill {cont_prefill} of N={n}, d={d}"
        ),
        &["sessions", "steps", "tokens/s", "inter-token p99 us", "admissions", "slot hits"],
    );
    for sessions in [1usize, 16, 64] {
        let cont_coord = CoordinatorConfig {
            max_batch: 16,
            max_total_batch: 1024,
            batch_window_us: 200,
            workers: 2,
            queue_depth: (2 * sessions).max(256),
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(n, d, sessions));
        for s in 0..sessions {
            kv.put(
                &format!("cont-{s}"),
                k.rows_slice(0, cont_prefill),
                v.rows_slice(0, cont_prefill),
            )?;
        }
        let factories = (0..cont_coord.workers)
            .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg.clone()))
            .collect();
        let server = Server::start(&cont_coord, kv, factories)?;
        let t0 = Instant::now();
        for step in 0..cont_steps {
            let at = cont_prefill + step;
            // one appended token per session (the first round's appends are
            // the S admissions; later rounds hit the resident slots)...
            let acks: Vec<_> = (0..sessions)
                .map(|s| loop {
                    match server.submit_append(
                        &format!("cont-{s}"),
                        k.rows_slice(at, at + 1),
                        v.rows_slice(at, at + 1),
                    ) {
                        Ok(rx) => break rx,
                        Err(_) => std::thread::sleep(Duration::from_micros(50)),
                    }
                })
                .collect();
            for rx in acks {
                let r = rx.recv().expect("append ack");
                assert!(r.ok(), "{:?}", r.output);
            }
            // ...then one ragged multi-session decode grid over the slots
            let rxs: Vec<_> = (0..sessions)
                .map(|s| loop {
                    match server.submit(&format!("cont-{s}"), rng.normal_vec(d)) {
                        Ok(rx) => break rx,
                        Err(_) => std::thread::sleep(Duration::from_micros(50)),
                    }
                })
                .collect();
            for rx in rxs {
                let r = rx.recv().expect("decode response");
                assert!(r.ok(), "{:?}", r.output);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let tokens_per_s = (sessions * cont_steps) as f64 / wall;
        let snap = server.metrics.snapshot();
        ct.row(&[
            sessions.to_string(),
            cont_steps.to_string(),
            format!("{tokens_per_s:.0}"),
            format!("{:.0}", snap.decode_gap_p99_us),
            snap.batcher_admissions.to_string(),
            snap.slot_hits.to_string(),
        ]);
        json_rows.push(BenchRow {
            bench: format!("continuous_decode_s{sessions}"),
            shape: format!("S{sessions}_N{n}_d{d}_prefill{cont_prefill}_steps{cont_steps}"),
            ns_per_step: 1e9 / tokens_per_s.max(1e-9),
            kv_bytes_copied: 0,
        });
        server.shutdown();
    }
    ct.emit("continuous_decode");

    // Streaming ingress (EXPERIMENTS.md §Streaming): S loopback clients,
    // each prefilling a session over the wire and streaming one token
    // frame per decode step through the framed-socket front end.
    // tokens/s is end-to-end (framing + write queue + TCP included);
    // first-token / inter-token p99 are the client-visible delivery
    // spans sampled as each frame enters the write queue.  Shed and
    // disconnect counts must be zero here — a behaving client is never
    // shed — and the drain must come back clean, so the bench doubles
    // as a load smoke.
    let stream_steps = env_usize("HFA_BENCH_STREAM_STEPS", 16).min(n / 2);
    let stream_prefill = (n / 4).max(1).min(n - stream_steps);
    let mut gt = Table::new(
        &format!(
            "Streaming ingress — S loopback clients x {stream_steps} streamed tokens, \
             prefill {stream_prefill} of N={n}, d={d}"
        ),
        &[
            "sessions",
            "steps",
            "tokens/s",
            "first-token p99 us",
            "inter-token p99 us",
            "shed",
            "disconnects",
        ],
    );
    for sessions in [1usize, 16, 64] {
        use hfa::coordinator::{Client, Ingress, StreamEvent, StreamStep};
        let stream_coord = CoordinatorConfig {
            max_batch: 16,
            max_total_batch: 1024,
            batch_window_us: 200,
            workers: 2,
            queue_depth: (2 * sessions).max(256),
            ingress_max_connections: (2 * sessions).max(64),
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(n, d, sessions));
        let factories = (0..stream_coord.workers)
            .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg.clone()))
            .collect();
        let server = Server::start(&stream_coord, kv, factories)?;
        let ing = Ingress::bind("127.0.0.1:0", server, &stream_coord)?;
        let addr = ing.local_addr();
        let metrics = ing.metrics();
        let t0 = Instant::now();
        let clients: Vec<_> = (0..sessions)
            .map(|s| {
                let (k, v) = (k.clone(), v.clone());
                std::thread::spawn(move || -> anyhow::Result<()> {
                    let mut rng = Rng::new(0x57E0 ^ ((s as u64) << 8));
                    let mut cl = Client::connect(&addr)?;
                    let sess = format!("stream-{s}");
                    cl.put(
                        &sess,
                        k.rows_slice(0, stream_prefill),
                        v.rows_slice(0, stream_prefill),
                    )?;
                    let plan: Vec<StreamStep> = (0..stream_steps)
                        .map(|t| {
                            let at = stream_prefill + t;
                            StreamStep {
                                k: k.rows_slice(at, at + 1),
                                v: v.rows_slice(at, at + 1),
                                q: rng.normal_vec(k.cols),
                            }
                        })
                        .collect();
                    let events = cl.stream(&sess, plan)?;
                    let tokens =
                        events.iter().filter(|e| matches!(e, StreamEvent::Token { .. })).count();
                    anyhow::ensure!(tokens == stream_steps, "{sess}: {tokens}/{stream_steps}");
                    anyhow::ensure!(
                        matches!(events.last(), Some(StreamEvent::End { .. })),
                        "{sess}: missing terminal End: {:?}",
                        events.last()
                    );
                    cl.goodbye()?;
                    Ok(())
                })
            })
            .collect();
        for c in clients {
            c.join().map_err(|_| anyhow::anyhow!("stream client panicked"))??;
        }
        let wall = t0.elapsed().as_secs_f64();
        let tokens_per_s = (sessions * stream_steps) as f64 / wall;
        let snap = metrics.snapshot();
        gt.row(&[
            sessions.to_string(),
            stream_steps.to_string(),
            format!("{tokens_per_s:.0}"),
            format!("{:.0}", snap.first_token_p99_us),
            format!("{:.0}", snap.inter_token_p99_us),
            snap.slow_consumer_shed.to_string(),
            snap.disconnects.to_string(),
        ]);
        // the latency spans and shed tallies ride in the shape string —
        // the row schema is fixed at 4 keys
        json_rows.push(BenchRow {
            bench: format!("streaming_s{sessions}"),
            shape: format!(
                "S{sessions}_N{n}_d{d}_prefill{stream_prefill}_steps{stream_steps}_ftp99us{:.0}_itp99us{:.0}_shed{}",
                snap.first_token_p99_us, snap.inter_token_p99_us, snap.slow_consumer_shed
            ),
            ns_per_step: 1e9 / tokens_per_s.max(1e-9),
            kv_bytes_copied: 0,
        });
        let report = ing.drain(Duration::from_secs(30));
        anyhow::ensure!(report.clean(), "streaming bench drain must be clean: {report}");
    }
    gt.emit("streaming_ingress");

    // Prefix-sharing fleet (EXPERIMENTS.md §Prefix-sharing): S sessions
    // admit the same P-row prefix plus an 8-row private tail through
    // the radix cache, then each forks one beam child — the shared-
    // system-prompt fleet the prefix index exists for.  bytes/session
    // and dedup-hit counts are exact structural numbers from the store
    // and the metrics gauges (machine-independent); ns/step times the
    // put+fork admissions, whose dedup path skips conversion for every
    // full prefix chunk.  The geometry is independent of HFA_BENCH_N:
    // sharing happens at DEFAULT_BLOCK_ROWS granularity, so the prefix
    // must span full chunks even in the CI smoke shape.
    let pfx_sessions = env_usize("HFA_BENCH_PREFIX_SESSIONS", 32).max(2);
    let pfx_prefix = env_usize(
        "HFA_BENCH_PREFIX_ROWS",
        2 * hfa::attention::prepared::DEFAULT_BLOCK_ROWS,
    );
    let pfx_tail = 8usize;
    let pfx_rows = pfx_prefix + pfx_tail;
    let mut pt = Table::new(
        &format!(
            "Prefix-sharing fleet — {pfx_sessions} sessions x ({pfx_prefix}-row shared \
             prefix + {pfx_tail}-row tail) + 1 fork each, d={d}"
        ),
        &[
            "resident sessions",
            "bytes/session solo",
            "bytes/session fleet",
            "shared KiB",
            "dedup hits",
            "us/admission",
        ],
    );
    {
        let rb = hfa::attention::prepared::row_bytes(d, d);
        let kv = Arc::new(KvStore::new(pfx_rows, d, 2 * pfx_sessions));
        let metrics = Arc::new(hfa::coordinator::Metrics::new());
        kv.attach_metrics(Arc::clone(&metrics));
        let kp = rng.normal_vec(pfx_prefix * d);
        let vp = rng.normal_vec(pfx_prefix * d);
        let mats: Vec<(Mat, Mat)> = (0..pfx_sessions)
            .map(|_| {
                let mut kd = kp.clone();
                let mut vd = vp.clone();
                kd.extend(rng.normal_vec(pfx_tail * d));
                vd.extend(rng.normal_vec(pfx_tail * d));
                (Mat::from_vec(pfx_rows, d, kd), Mat::from_vec(pfx_rows, d, vd))
            })
            .collect();
        let copy0 = hfa::attention::prepared::kv_copy_bytes();
        let t0 = Instant::now();
        for (s, (km, vm)) in mats.iter().enumerate() {
            kv.put(&format!("pfx-{s}"), km.clone(), vm.clone())?;
        }
        for s in 0..pfx_sessions {
            kv.fork(&format!("pfx-{s}"), &format!("beam-{s}"))?;
        }
        let admissions = 2 * pfx_sessions;
        let wall = t0.elapsed().as_secs_f64();
        let copied = hfa::attention::prepared::kv_copy_bytes() - copy0;
        // the exact fleet equation the test suite pins, re-asserted here
        // so a perf run can never report numbers from a broken cache
        anyhow::ensure!(
            kv.used_bytes() == pfx_rows * rb + (pfx_sessions - 1) * pfx_tail * rb,
            "prefix fleet bytes drifted: {} used",
            kv.used_bytes()
        );
        let snap = metrics.snapshot();
        anyhow::ensure!(
            snap.kv_resident_sessions == admissions as u64 && snap.kv_dedup_hits > 0,
            "sharing gauges missing: {snap:?}"
        );
        let solo = pfx_rows * rb;
        pt.row(&[
            admissions.to_string(),
            solo.to_string(),
            snap.kv_mean_session_bytes.to_string(),
            format!("{:.1}", snap.kv_shared_bytes as f64 / 1024.0),
            snap.kv_dedup_hits.to_string(),
            format!("{:.1}", wall / admissions as f64 * 1e6),
        ]);
        // bytes-per-session + dedup hits ride in the shape string (the
        // row schema is fixed at 4 keys); kv_bytes_copied is the real
        // copy traffic of the whole fleet admission — proportional to
        // unique rows, not sessions x rows
        json_rows.push(BenchRow {
            bench: format!("prefix_fleet_s{pfx_sessions}"),
            shape: format!(
                "S{pfx_sessions}_P{pfx_prefix}_d{d}_tail{pfx_tail}_bps{}_solo{solo}_dedup{}",
                snap.kv_mean_session_bytes, snap.kv_dedup_hits
            ),
            ns_per_step: wall / admissions as f64 * 1e9,
            kv_bytes_copied: copied,
        });
    }
    pt.emit("prefix_fleet");

    // machine-readable trajectory file, self-validated so CI's smoke run
    // catches a writer regression
    let path = write_bench_json("BENCH_attention.json", &json_rows)?;
    let written = std::fs::read_to_string(&path)?;
    validate_json(&written).map_err(|e| anyhow::anyhow!("BENCH_attention.json invalid: {e}"))?;
    println!("(perf json: {} — {} rows, validated)", path.display(), json_rows.len());
    Ok(())
}

//! End-to-end serving throughput/latency through the coordinator:
//! simulated-accelerator backends (H-FA vs FA-2) and, when artifacts are
//! present, the PJRT-compiled H-FA kernel backend.  Also reports the raw
//! accelerator compute-batch wall time (coordinator overhead = difference)
//! and a decode-loop scenario (prefill once, then N append+attend steps)
//! comparing the append-only path against rebuilding the session per step.

use std::sync::Arc;
use std::time::{Duration, Instant};

use hfa::benchlib::{bench, Table};
use hfa::config::{AcceleratorConfig, CoordinatorConfig};
use hfa::coordinator::{KvStore, PjrtBackend, Server, SimBackend};
use hfa::hw::{Accelerator, Arith};
use hfa::proptest::Rng;
use hfa::runtime::AttnKernelSpec;
use hfa::Mat;

const D: usize = 64;
const N: usize = 1024;

fn drive(server: &Server, total: usize, rng: &mut Rng) -> (f64, f64, f64) {
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for _ in 0..total {
        loop {
            match server.submit("bench", rng.normal_vec(D)) {
                Ok(rx) => {
                    pending.push(rx);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_micros(50)), // backpressure
            }
        }
    }
    for rx in pending {
        let r = rx.recv().expect("response");
        assert!(r.ok(), "{:?}", r.output);
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics.snapshot();
    (total as f64 / wall, snap.p50_us, snap.p99_us)
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(2024);
    let accel_cfg = AcceleratorConfig {
        head_dim: D,
        seq_len: N,
        kv_blocks: 4,
        parallel_queries: 1,
        freq_mhz: 500.0,
    };
    let coord_cfg = CoordinatorConfig {
        max_batch: 16,
        batch_window_us: 150,
        workers: 2,
        queue_depth: 256,
    };
    let total: usize =
        std::env::var("HFA_BENCH_REQS").ok().and_then(|s| s.parse().ok()).unwrap_or(256);

    let k = Mat::from_vec(N, D, rng.normal_vec(N * D));
    let v = Mat::from_vec(N, D, rng.normal_vec(N * D));

    let mut t = Table::new(
        "E2E serving — coordinator + backend, N=1024, d=64, 4 KV blocks",
        &["backend", "requests", "QPS", "p50 us", "p99 us", "mean batch"],
    );

    for (name, arith) in [("sim H-FA", Arith::Hfa), ("sim FA-2", Arith::Fa2)] {
        let kv = Arc::new(KvStore::new(N, D, 4));
        kv.put("bench", k.clone(), v.clone())?;
        let factories = (0..coord_cfg.workers)
            .map(|_| SimBackend::factory(arith, accel_cfg.clone()))
            .collect();
        let server = Server::start(&coord_cfg, kv, factories)?;
        let (qps, p50, p99) = drive(&server, total, &mut rng);
        let snap = server.metrics.snapshot();
        t.row(&[
            name.into(),
            total.to_string(),
            format!("{qps:.0}"),
            format!("{p50:.0}"),
            format!("{p99:.0}"),
            format!("{:.1}", snap.mean_batch),
        ]);
        server.shutdown();
    }

    // PJRT backend (needs artifacts)
    let spec = AttnKernelSpec { kind: "hfa".into(), head_dim: D, seq_len: N, batch: 16 };
    let artifacts = hfa::artifacts_dir();
    if artifacts.join("hlo").join(spec.file_name()).is_file() {
        let kv = Arc::new(KvStore::new(N, D, 4));
        kv.put("bench", k.clone(), v.clone())?;
        let factories = vec![
            PjrtBackend::factory(artifacts.clone(), spec.clone()),
            PjrtBackend::factory(artifacts.clone(), spec),
        ];
        let server = Server::start(&coord_cfg, kv, factories)?;
        let (qps, p50, p99) = drive(&server, total, &mut rng);
        let snap = server.metrics.snapshot();
        t.row(&[
            "pjrt H-FA kernel".into(),
            total.to_string(),
            format!("{qps:.0}"),
            format!("{p50:.0}"),
            format!("{p99:.0}"),
            format!("{:.1}", snap.mean_batch),
        ]);
        server.shutdown();
    } else {
        eprintln!("(skipping PJRT backend row: artifacts missing)");
    }
    t.emit("e2e_throughput");

    // raw accelerator batch compute (no coordinator) for overhead attribution
    let mut accel = Accelerator::new(Arith::Hfa, accel_cfg.clone());
    accel.load_kv(k.clone(), v.clone())?;
    let q = Mat::from_vec(16, D, rng.normal_vec(16 * D));
    let stats = bench(2, 20, Duration::from_secs(10), || {
        let _ = accel.compute_batch(&q).unwrap();
    });
    println!(
        "raw sim-accelerator compute_batch(16 queries): mean {:.2} ms (functional model wall time; modelled silicon time: {:.1} us)",
        stats.mean_ms(),
        accel.compute_batch(&q)?.1.time_us(500.0)
    );

    // KV-preparation amortization (EXPERIMENTS.md §Perf): per-call
    // conversion (the seed serving behaviour) vs prepared-KV reuse
    let kb = k.round_bf16();
    let vb = v.round_bf16();
    let per_call = bench(2, 20, Duration::from_secs(10), || {
        let _ = hfa::attention::hfa::attention(&q, &kb, &vb, None, None, &mut None);
    });
    let prepared = hfa::attention::PreparedKv::new(kb.clone(), vb.clone());
    let reused = bench(2, 20, Duration::from_secs(10), || {
        let _ = prepared.attention(&q, None, None);
    });
    println!(
        "attention(16 queries, N={N}, d={D}): per-call V->LNS {:.2} ms, prepared-KV reuse {:.2} ms ({:.2}x)",
        per_call.mean_ms(),
        reused.mean_ms(),
        per_call.mean_ns / reused.mean_ns.max(1.0)
    );

    // decode loop (EXPERIMENTS.md §Decode): prefill once, then STEPS x
    // (one-row KV write + one attend).  "append" uses Server::append
    // (convert only the new row); "re-put" rebuilds the whole session per
    // step — the only option before the append path existed.
    let steps: usize = std::env::var("HFA_BENCH_DECODE_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
        .min(N / 2);
    let prefill = N - steps;
    // NOTE on fairness: both arms time the full step (KV write + attend)
    // via wall clock, which is symmetric; per-request latency percentiles
    // are NOT comparable across arms (the re-put arm's write bypasses the
    // server and its metrics), so the table reports steps/s only.
    let mut dt = Table::new(
        "Decode loop — prefill once, then append+attend per token, N=1024, d=64",
        &[
            "KV write path",
            "prefill",
            "steps",
            "steps/s",
            "step mean us",
            "V rows converted",
            "KV MiB copied",
        ],
    );
    for (name, use_append) in [("chunked append", true), ("full re-put (seed)", false)] {
        let kv = Arc::new(KvStore::new(N, D, 4));
        kv.put("dec", k.rows_slice(0, prefill), v.rows_slice(0, prefill))?;
        let factories = (0..coord_cfg.workers)
            .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg.clone()))
            .collect();
        let server = Server::start(&coord_cfg, kv.clone(), factories)?;
        let conv0 = hfa::attention::hfa::value_conversion_count();
        let copy0 = hfa::attention::prepared::kv_copy_bytes();
        let t0 = Instant::now();
        for s in 0..steps {
            let at = prefill + s;
            if use_append {
                let ack = server.append(
                    "dec",
                    k.rows_slice(at, at + 1),
                    v.rows_slice(at, at + 1),
                )?;
                assert!(ack.ok(), "{:?}", ack.output);
            } else {
                kv.put("dec", k.rows_slice(0, at + 1), v.rows_slice(0, at + 1))?;
            }
            let r = server.call("dec", rng.normal_vec(D))?;
            assert!(r.ok(), "{:?}", r.output);
        }
        let wall = t0.elapsed().as_secs_f64();
        let converted = hfa::attention::hfa::value_conversion_count() - conv0;
        let copied = hfa::attention::prepared::kv_copy_bytes() - copy0;
        dt.row(&[
            name.into(),
            prefill.to_string(),
            steps.to_string(),
            format!("{:.0}", steps as f64 / wall),
            format!("{:.0}", wall / steps as f64 * 1e6),
            converted.to_string(),
            format!("{:.2}", copied as f64 / (1024.0 * 1024.0)),
        ]);
        server.shutdown();
    }
    dt.emit("decode_loop");
    Ok(())
}

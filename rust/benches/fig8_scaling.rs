//! Fig. 8: normalized execution time (a) and normalized area (b) of the
//! H-FA accelerator as the number of parallel KV sub-blocks grows
//! (d=64, N=1024 tokens, datapath + SRAM).

use hfa::benchlib::Table;
use hfa::config::AcceleratorConfig;
use hfa::hw::cost::{report, Arith};
use hfa::hw::pipeline::{simulate, LatencyModel};

fn main() {
    let lat = LatencyModel::for_head_dim(64);
    let base_cycles = simulate(64, 1024, 1, 1, 1, lat).cycles as f64;
    let base_cfg = AcceleratorConfig {
        head_dim: 64,
        seq_len: 1024,
        kv_blocks: 1,
        parallel_queries: 1,
        freq_mhz: 500.0,
    };
    let base_r = report(Arith::Hfa, &base_cfg, 1);
    let base_area = base_r.total_area_mm2();
    let base_dp = base_r.datapath_area_mm2;

    let mut t = Table::new(
        "Fig. 8 analog — H-FA normalized exec time & area vs parallel KV blocks (d=64, N=1024)",
        &["p", "cycles", "norm. time", "speedup", "area mm^2", "norm. area", "norm. dp area"],
    );
    for p in [1usize, 2, 4, 8] {
        let s = simulate(64, 1024, p, 1, 1, lat);
        let cfg = AcceleratorConfig { kv_blocks: p, ..base_cfg.clone() };
        let r = report(Arith::Hfa, &cfg, 1);
        t.row(&[
            p.to_string(),
            s.cycles.to_string(),
            format!("{:.3}", s.cycles as f64 / base_cycles),
            format!("{:.2}x", base_cycles / s.cycles as f64),
            format!("{:.3}", r.total_area_mm2()),
            format!("{:.2}", r.total_area_mm2() / base_area),
            format!("{:.2}", r.datapath_area_mm2 / base_dp),
        ]);
    }
    t.emit("fig8_scaling");
    let s8 = simulate(64, 1024, 8, 1, 1, lat);
    let r8 = report(Arith::Hfa, &AcceleratorConfig { kv_blocks: 8, ..base_cfg }, 1);
    println!(
        "speedup at p=8: {:.2}x (paper: ~6x); datapath area at p=8: {:.1}x of p=1 (paper Fig. 8b: ~10x)",
        base_cycles / s8.cycles as f64,
        r8.datapath_area_mm2 / base_dp
    );
}

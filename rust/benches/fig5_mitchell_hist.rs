//! Fig. 5: distribution of inputs to Mitchell's approximation recorded
//! over real eval traffic through the H-FA datapath, with the absolute
//! error curve E(x) = |log2(1+x) - x|.

use hfa::arith::mitchell::MitchellHistogram;
use hfa::benchlib::Table;
use hfa::evalsuite::score::evaluate_file;
use hfa::evalsuite::tasks::list_eval_files;
use hfa::model::{AttnSelect, Transformer};

fn main() -> anyhow::Result<()> {
    let artifacts = hfa::artifacts_dir();
    let model = Transformer::load(&artifacts.join("models/s1"))?;
    let files = list_eval_files(&artifacts.join("eval"))?;
    let lim: usize =
        std::env::var("HFA_EVAL_LIMIT").ok().and_then(|s| s.parse().ok()).unwrap_or(10);

    let mut hist = MitchellHistogram::new(20);
    for (_, _, path) in files.iter().take(5) {
        let _ = evaluate_file(&model, path, AttnSelect::Hfa, lim, &mut Some(&mut hist))?;
    }

    let mut t = Table::new(
        &format!("Fig. 5 analog — Mitchell input distribution ({} samples)", hist.total),
        &["x (bin center)", "density", "E(x)", "histogram"],
    );
    let max_d = hist.rows().iter().map(|r| r.1).fold(0.0, f64::max).max(1e-12);
    for (x, dens, err) in hist.rows() {
        let bar = "#".repeat(((dens / max_d) * 40.0).round() as usize);
        t.row(&[format!("{x:.3}"), format!("{dens:.4}"), format!("{err:.4}"), bar]);
    }
    t.emit("fig5_mitchell_hist");
    println!(
        "mass below 0.1: {:.1}%   below 0.5: {:.1}%   max E(x) = 0.0861 at x = 0.4427",
        100.0 * hist.mass_below(0.1),
        100.0 * hist.mass_below(0.5)
    );
    Ok(())
}

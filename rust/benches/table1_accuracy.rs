//! Tables I & II: task accuracy of the tiny LMs with FA-2 vs H-FA
//! attention (substitute for MMLU / multi-benchmark LLM study — see
//! DESIGN.md §5/§6).
//!
//! Table I analog: the 20 (family, variant) tasks on the s1 model.
//! Table II analog: per-family mean accuracy for all three model sizes.

use std::collections::BTreeMap;

use hfa::benchlib::Table;
use hfa::evalsuite::score::evaluate_file;
use hfa::evalsuite::tasks::list_eval_files;
use hfa::model::{AttnSelect, Transformer};

fn limit() -> usize {
    std::env::var("HFA_EVAL_LIMIT").ok().and_then(|s| s.parse().ok()).unwrap_or(100)
}

fn main() -> anyhow::Result<()> {
    let artifacts = hfa::artifacts_dir();
    let eval_dir = artifacts.join("eval");
    let files = list_eval_files(&eval_dir)?;
    anyhow::ensure!(!files.is_empty(), "no eval task files — run `make artifacts`");
    let lim = limit();

    // ---- Table I analog: per-task accuracy on s1 ------------------------
    let s1 = Transformer::load(&artifacts.join("models/s1"))?;
    let mut t1 = Table::new(
        &format!("Table I analog — s1 task accuracy (%), H-FA vs FA-2 ({lim} instances/task)"),
        &["task", "H-FA", "FA-2", "delta"],
    );
    let mut diffs = Vec::new();
    for (fam, var, path) in &files {
        let fa2 = evaluate_file(&s1, path, AttnSelect::Fa2, lim, &mut None)?;
        let hfa_acc = evaluate_file(&s1, path, AttnSelect::Hfa, lim, &mut None)?;
        let d = hfa_acc.pct() - fa2.pct();
        diffs.push(d);
        t1.row(&[
            format!("{fam}_{var}"),
            format!("{:.0}", hfa_acc.pct()),
            format!("{:.0}", fa2.pct()),
            format!("{d:+.0}"),
        ]);
    }
    t1.emit("table1_accuracy");
    let mean_abs: f64 = diffs.iter().map(|d| d.abs()).sum::<f64>() / diffs.len() as f64;
    println!("mean |accuracy delta| = {mean_abs:.1} pts (paper: below 5 in the majority of tasks)");

    // ---- Table II analog: per-family means for 3 sizes -------------------
    let mut t2 = Table::new(
        "Table II analog — per-family mean accuracy (%), three model sizes",
        &["model", "impl", "copy_last", "induction", "assoc", "maxsym", "modsum"],
    );
    for size in ["s0", "s1", "s2"] {
        let dir = artifacts.join("models").join(size);
        if !dir.join("weights.bin").is_file() {
            eprintln!("skipping {size}: weights missing");
            continue;
        }
        let model = Transformer::load(&dir)?;
        for (imp_name, imp) in [("FA-2", AttnSelect::Fa2), ("H-FA", AttnSelect::Hfa)] {
            let mut fam_acc: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
            for (fam, _var, path) in &files {
                let acc = evaluate_file(&model, path, imp, lim, &mut None)?;
                fam_acc
                    .entry(match fam.as_str() {
                        "copy_last" => "copy_last",
                        "induction" => "induction",
                        "assoc" => "assoc",
                        "maxsym" => "maxsym",
                        _ => "modsum",
                    })
                    .or_default()
                    .push(acc.pct());
            }
            let mean = |f: &str| {
                let v = &fam_acc[f];
                format!("{:.0}", v.iter().sum::<f64>() / v.len() as f64)
            };
            t2.row(&[
                size.to_string(),
                imp_name.to_string(),
                mean("copy_last"),
                mean("induction"),
                mean("assoc"),
                mean("maxsym"),
                mean("modsum"),
            ]);
        }
    }
    t2.emit("table2_accuracy");
    Ok(())
}

//! Table IV: comparison of the two proposed H-FA configurations with
//! published state-of-the-art attention accelerators.  SoTA rows are the
//! paper's published numbers (reprinted); the H-FA rows are regenerated
//! from our cost model + cycle simulator.

use hfa::benchlib::Table;
use hfa::config::AcceleratorConfig;
use hfa::hw::cost::{report, report::throughput_tops, Arith};

fn main() {
    let mut t = Table::new(
        "Table IV analog — comparison with SoTA designs",
        &["design", "process", "area mm^2", "freq MHz", "power W", "precision",
          "TOPS", "TOPS/W", "TOPS/mm^2"],
    );
    // published rows (from the paper, for context)
    for row in [
        ["Keller et al. [9]", "5nm", "0.153", "152", "-", "INT4/INT8", "3.6/1.8", "91.1/39.1", "23.53/11.67"],
        ["MECLA [11]", "28nm", "22.02", "1000", "2.87", "INT8", "14", "7.08", "0.64"],
        ["FACT [19]", "28nm", "6.03", "500", "0.337", "INT8", "1.02", "4.39", "0.17"],
        ["Kim et al. [12]", "28nm", "20.25", "50", "-", "INT8", "3.41", "22.9", "0.17"],
        ["Moon et al. [15]", "28nm", "7.29", "20", "0.002-0.237", "AQ 1-8b", "0.52", "8.94", "0.07"],
        ["Chen et al. [16]", "28nm", "0.636", "500", "0.108", "MXINT4/8", "0.256", "2.37", "0.40"],
        ["COSA plus [14]", "16nm FPGA", "-", "200", "30.3", "INT8", "1.44", "0.05", "-"],
        ["TSAcc [18]", "28nm", "8.6", "500", "3.1", "FP32", "2.05", "0.66", "0.24"],
    ] {
        t.row(&row.map(String::from));
    }

    // our two configurations, regenerated from the model
    for (name, nq) in [("HFA-1-4 (ours, modelled)", 1usize), ("HFA-4-4 (ours, modelled)", 4)] {
        let cfg = AcceleratorConfig {
            head_dim: 64,
            seq_len: 1024,
            kv_blocks: 4,
            parallel_queries: nq,
            freq_mhz: 500.0,
        };
        let r = report(Arith::Hfa, &cfg, 64);
        let (bf16_tops, fix_tops) = throughput_tops(&cfg, Arith::Hfa);
        let total_tops = bf16_tops + fix_tops;
        let power_w = r.total_power_mw() / 1000.0;
        t.row(&[
            name.to_string(),
            "28nm".into(),
            format!("{:.2}", r.total_area_mm2()),
            "500".into(),
            format!("{power_w:.2}"),
            "BF16&FIX16".into(),
            format!("{bf16_tops:.2}+{fix_tops:.2}"),
            format!("{:.2}", total_tops / power_w),
            format!("{:.2}", total_tops / r.total_area_mm2()),
        ]);
    }
    t.emit("table4_sota");
    println!("(paper HFA-1-4: 1.14 mm^2, 0.22 W, 0.256+0.91 TOPS, 5.41 TOPS/W, 1.02 TOPS/mm^2)");
    println!("(paper HFA-4-4: 3.34 mm^2, 0.62 W, 1.64+5.84 TOPS, 7.48 TOPS/W, 1.40 TOPS/mm^2)");
}

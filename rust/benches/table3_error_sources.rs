//! Table III: contribution (%) of the three H-FA approximation sources —
//! fixed-point quantization, Mitchell's approximation, the PWL 2^-x — to
//! the total logit error, measured on three (model, benchmark) pairs by
//! disabling one source at a time (exactly the paper's methodology).

use hfa::attention::hfa::EmuConfig;
use hfa::benchlib::Table;
use hfa::evalsuite::score::mean_logit_error;
use hfa::model::{AttnSelect, Transformer};

fn main() -> anyhow::Result<()> {
    let artifacts = hfa::artifacts_dir();
    let pairs = [
        ("s0", "maxsym_4.txt"),
        ("s1", "assoc_2.txt"),
        ("s2", "copy_last_4.txt"),
    ];
    let lim: usize =
        std::env::var("HFA_EVAL_LIMIT").ok().and_then(|s| s.parse().ok()).unwrap_or(8);

    let mut t = Table::new(
        "Table III analog — absolute error contribution (%) per source",
        &["model/benchmark", "BF16-to-FIX16", "Mitchell", "PWL 2^-x", "total |dlogit|"],
    );
    for (size, bench) in pairs {
        let dir = artifacts.join("models").join(size);
        if !dir.join("weights.bin").is_file() {
            eprintln!("skipping {size}: weights missing");
            continue;
        }
        let model = Transformer::load(&dir)?;
        let file = artifacts.join("eval").join(bench);
        let all = EmuConfig::all_on();
        let e_all = mean_logit_error(&model, &file, AttnSelect::HfaEmu(all), lim)?;
        let e_noq = mean_logit_error(
            &model, &file, AttnSelect::HfaEmu(EmuConfig { quant: false, ..all }), lim)?;
        let e_nom = mean_logit_error(
            &model, &file, AttnSelect::HfaEmu(EmuConfig { mitchell: false, ..all }), lim)?;
        let e_nop = mean_logit_error(
            &model, &file, AttnSelect::HfaEmu(EmuConfig { pwl: false, ..all }), lim)?;

        // error removed by disabling each source, normalized to 100%
        let c = [
            (e_all - e_noq).max(0.0),
            (e_all - e_nom).max(0.0),
            (e_all - e_nop).max(0.0),
        ];
        let sum: f64 = c.iter().sum::<f64>().max(1e-12);
        t.row(&[
            format!("{size}/{}", bench.trim_end_matches(".txt")),
            format!("{:.1}", 100.0 * c[0] / sum),
            format!("{:.1}", 100.0 * c[1] / sum),
            format!("{:.1}", 100.0 * c[2] / sum),
            format!("{e_all:.4}"),
        ]);
    }
    t.emit("table3_error_sources");
    println!("(paper: Mitchell > 90%, others < 10% each)");
    Ok(())
}

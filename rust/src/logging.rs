//! Tiny leveled logger (the `log` facade alone has no emitter offline).

use std::time::Instant;

// Always-std atomics (`counter`): a `static` initializer needs const `new`,
// which loom's types do not provide, and the log level is not a protocol
// under verification.
use crate::sync::counter::{AtomicU8, Ordering};

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    // not the FromStr trait: infallible, defaults to Info
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }
}

pub fn set_level(l: Level) {
    // ordering: Relaxed — the level is an isolated knob; no other memory
    // is published through it.
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn init_from_env() {
    if let Ok(v) = std::env::var("HFA_LOG") {
        set_level(Level::from_str(&v));
    }
}

pub fn enabled(l: Level) -> bool {
    // ordering: Relaxed — see `set_level`; a stale read only mis-gates a
    // log line.
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

fn t0() -> Instant {
    use crate::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments) {
    if enabled(l) {
        let dt = t0().elapsed().as_secs_f64();
        eprintln!("[{dt:9.3}s {l:?} {target}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnlog {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debuglog {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_and_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert_eq!(Level::from_str("TRACE"), Level::Trace);
        assert_eq!(Level::from_str("bogus"), Level::Info);
    }
}

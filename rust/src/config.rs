//! Typed configuration for the accelerator, coordinator and launcher.
//!
//! Values resolve in order: built-in defaults < config file (`key=value`
//! lines) < environment (`HFA_*`) < CLI `--key value`.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::cli::Args;

/// Accelerator geometry (paper Section VI-C defaults: N=1024 tokens in
/// four 256-row KV sub-blocks, BF16, 500 MHz).
#[derive(Clone, Debug, PartialEq)]
pub struct AcceleratorConfig {
    /// Head dimension d (paper sweeps 32/64/128).
    pub head_dim: usize,
    /// Max sequence length held in the KV SRAM buffers.
    pub seq_len: usize,
    /// Parallel KV sub-blocks p (block-FAUs per query).
    pub kv_blocks: usize,
    /// Query vectors processed in parallel (datapath replication).
    pub parallel_queries: usize,
    /// Clock frequency in MHz.
    pub freq_mhz: f64,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        AcceleratorConfig {
            head_dim: 64,
            seq_len: 1024,
            kv_blocks: 4,
            parallel_queries: 1,
            freq_mhz: 500.0,
        }
    }
}

/// Coordinator / serving configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct CoordinatorConfig {
    /// Max queries per formed batch (one FAU datapath pass).
    pub max_batch: usize,
    /// Max total requests one cross-session super-batch dispatch may
    /// carry (window-expired per-session groups are fused up to this
    /// cap; clamped to at least `max_batch`).
    pub max_total_batch: usize,
    /// Batch-forming window in microseconds.
    pub batch_window_us: u64,
    /// Worker threads executing batches.
    pub workers: usize,
    /// Bounded queue depth before backpressure rejects.
    pub queue_depth: usize,
    /// Default per-request deadline in microseconds from submit.  Past
    /// it the request is shed with `ServeError::TimedOut` (batcher at
    /// group close, workers before dispatch) instead of computing an
    /// answer nobody is waiting for.
    pub request_timeout_us: u64,
    /// Admission gate: maximum requests in flight (accepted but not yet
    /// answered) before `submit` rejects with `ServeError::Overloaded`.
    pub max_pending_requests: usize,
    /// Bounded retries for backend faults classified transient
    /// (`TransientFault`); permanent faults are never retried.
    pub max_retries: u32,
    /// Base backoff between transient-fault retries in microseconds
    /// (doubles per attempt).
    pub retry_backoff_us: u64,
    /// Pool-wide budget of worker respawns after backend panics: while
    /// it lasts a panicked worker rebuilds its backend in place instead
    /// of shrinking the pool toward zero.
    pub worker_respawn_budget: u32,
    /// Continuous scheduler: max tokens (append rows + queries) one
    /// prefill admission dispatch may carry.  `0` = unlimited.
    pub max_batch_prefill_tokens: usize,
    /// Continuous scheduler: max total resident tokens (KV rows of all
    /// slot sessions plus the tokens being admitted) the running batch
    /// may hold; under pressure idle slots are retired LRU before an
    /// admission is deferred.  `0` = unlimited.
    pub max_batch_total_tokens: usize,
    /// Continuous scheduler: decode keeps priority until the waiting
    /// queue reaches `ceil(waiting_served_ratio * running_slots)` groups
    /// (TGI's `waiting_served_ratio`); then decode pauses one iteration
    /// to admit prefills.  An empty running batch always admits.
    pub waiting_served_ratio: f64,
    /// Starvation override: a waiting group older than this many decode
    /// iterations is admitted even below the ratio threshold.
    pub max_waiting_iters: u64,
    /// Grace period in microseconds added past a request's deadline when
    /// the caller blocks for its response (`Server::call` / `append`, and
    /// the ingress terminal-frame waits): the serving loop sheds expired
    /// work itself, so the terminal response normally lands within the
    /// deadline — the grace only bounds how long a caller waits for that
    /// shed to be delivered before synthesizing `TimedOut` locally.
    pub response_grace_us: u64,
    /// Streaming ingress: max concurrently accepted connections; past it
    /// new connections get a terminal `Overloaded` frame and are closed.
    pub ingress_max_connections: usize,
    /// Streaming ingress: max wire requests in flight across all
    /// connections (each holds its gate slot from admission to terminal
    /// frame), layered above the server's own `max_pending_requests`.
    pub ingress_max_requests: usize,
    /// Streaming ingress: bounded per-connection write queue, in frames.
    /// A full queue blocks that session's decode routing (backpressure);
    /// the stall budget below bounds how long.
    pub ingress_write_queue: usize,
    /// Streaming ingress: slow-consumer stall budget in microseconds — a
    /// session whose write queue stays full this long is shed with
    /// `ServeError::Cancelled` and its KV evicted, so one laggard can
    /// never wedge the iteration loop or strand KV bytes.
    pub ingress_stall_budget_us: u64,
    /// Streaming ingress: listener (accept) thread-pool size.
    pub ingress_acceptors: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 16,
            max_total_batch: 256,
            batch_window_us: 200,
            workers: 2,
            queue_depth: 256,
            request_timeout_us: 5_000_000,
            max_pending_requests: 4096,
            max_retries: 2,
            retry_backoff_us: 100,
            worker_respawn_budget: 4,
            max_batch_prefill_tokens: 0,
            max_batch_total_tokens: 0,
            waiting_served_ratio: 1.2,
            max_waiting_iters: 4,
            response_grace_us: 100_000,
            ingress_max_connections: 256,
            ingress_max_requests: 1024,
            ingress_write_queue: 64,
            ingress_stall_budget_us: 2_000_000,
            ingress_acceptors: 2,
        }
    }
}

/// Full resolved configuration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    pub accel: AcceleratorConfig,
    pub coord: CoordinatorConfig,
}

fn parse_kv_file(path: &Path) -> Result<BTreeMap<String, String>> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading config {}", path.display()))?;
    let mut map = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((k, v)) = line.split_once('=') {
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
    }
    Ok(map)
}

impl Config {
    /// Resolve from optional file + env + CLI args.
    pub fn resolve(file: Option<&Path>, args: &Args) -> Result<Config> {
        let mut map = BTreeMap::new();
        if let Some(p) = file {
            map.extend(parse_kv_file(p)?);
        }
        for (k, v) in std::env::vars() {
            if let Some(stripped) = k.strip_prefix("HFA_CFG_") {
                map.insert(stripped.to_ascii_lowercase(), v);
            }
        }
        for (k, v) in &args.options {
            map.insert(k.replace('-', "_"), v.clone());
        }

        let mut cfg = Config::default();
        let get_usize = |map: &BTreeMap<String, String>, k: &str, d: usize| -> Result<usize> {
            match map.get(k) {
                None => Ok(d),
                Some(v) => v.parse().with_context(|| format!("config {k}={v:?}")),
            }
        };
        cfg.accel.head_dim = get_usize(&map, "head_dim", cfg.accel.head_dim)?;
        cfg.accel.seq_len = get_usize(&map, "seq_len", cfg.accel.seq_len)?;
        cfg.accel.kv_blocks = get_usize(&map, "kv_blocks", cfg.accel.kv_blocks)?;
        cfg.accel.parallel_queries =
            get_usize(&map, "parallel_queries", cfg.accel.parallel_queries)?;
        if let Some(v) = map.get("freq_mhz") {
            cfg.accel.freq_mhz = v.parse().context("freq_mhz")?;
        }
        cfg.coord.max_batch = get_usize(&map, "max_batch", cfg.coord.max_batch)?;
        cfg.coord.max_total_batch =
            get_usize(&map, "max_total_batch", cfg.coord.max_total_batch)?;
        cfg.coord.workers = get_usize(&map, "workers", cfg.coord.workers)?;
        cfg.coord.queue_depth = get_usize(&map, "queue_depth", cfg.coord.queue_depth)?;
        if let Some(v) = map.get("batch_window_us") {
            cfg.coord.batch_window_us = v.parse().context("batch_window_us")?;
        }
        if let Some(v) = map.get("request_timeout_us") {
            cfg.coord.request_timeout_us = v.parse().context("request_timeout_us")?;
        }
        cfg.coord.max_pending_requests =
            get_usize(&map, "max_pending_requests", cfg.coord.max_pending_requests)?;
        if let Some(v) = map.get("max_retries") {
            cfg.coord.max_retries = v.parse().context("max_retries")?;
        }
        if let Some(v) = map.get("retry_backoff_us") {
            cfg.coord.retry_backoff_us = v.parse().context("retry_backoff_us")?;
        }
        if let Some(v) = map.get("worker_respawn_budget") {
            cfg.coord.worker_respawn_budget = v.parse().context("worker_respawn_budget")?;
        }
        cfg.coord.max_batch_prefill_tokens =
            get_usize(&map, "max_batch_prefill_tokens", cfg.coord.max_batch_prefill_tokens)?;
        cfg.coord.max_batch_total_tokens =
            get_usize(&map, "max_batch_total_tokens", cfg.coord.max_batch_total_tokens)?;
        if let Some(v) = map.get("waiting_served_ratio") {
            cfg.coord.waiting_served_ratio = v.parse().context("waiting_served_ratio")?;
        }
        if let Some(v) = map.get("max_waiting_iters") {
            cfg.coord.max_waiting_iters = v.parse().context("max_waiting_iters")?;
        }
        if let Some(v) = map.get("response_grace_us") {
            cfg.coord.response_grace_us = v.parse().context("response_grace_us")?;
        }
        cfg.coord.ingress_max_connections =
            get_usize(&map, "ingress_max_connections", cfg.coord.ingress_max_connections)?;
        cfg.coord.ingress_max_requests =
            get_usize(&map, "ingress_max_requests", cfg.coord.ingress_max_requests)?;
        cfg.coord.ingress_write_queue =
            get_usize(&map, "ingress_write_queue", cfg.coord.ingress_write_queue)?;
        if let Some(v) = map.get("ingress_stall_budget_us") {
            cfg.coord.ingress_stall_budget_us = v.parse().context("ingress_stall_budget_us")?;
        }
        cfg.coord.ingress_acceptors =
            get_usize(&map, "ingress_acceptors", cfg.coord.ingress_acceptors)?;

        anyhow::ensure!(
            cfg.accel.seq_len % cfg.accel.kv_blocks == 0,
            "seq_len must be divisible by kv_blocks"
        );
        // zero/negative/NaN would make the scheduler's prefill-due need
        // clamp to 1 and silently defeat decode priority (a prefill
        // admitted on every iteration with any waiting group)
        anyhow::ensure!(
            cfg.coord.waiting_served_ratio.is_finite() && cfg.coord.waiting_served_ratio > 0.0,
            "waiting_served_ratio must be finite and > 0, got {}",
            cfg.coord.waiting_served_ratio
        );
        // a zero grace would synthesize TimedOut the instant a deadline
        // passes, racing the serving loop's own shed-and-deliver path
        anyhow::ensure!(
            cfg.coord.response_grace_us > 0,
            "response_grace_us must be > 0, got {}",
            cfg.coord.response_grace_us
        );
        // zero-sized ingress resources wedge rather than shed: no
        // connection could ever be accepted / no frame ever queued, and a
        // zero stall budget sheds every consumer on its first full queue
        anyhow::ensure!(
            cfg.coord.ingress_max_connections > 0,
            "ingress_max_connections must be > 0"
        );
        anyhow::ensure!(cfg.coord.ingress_max_requests > 0, "ingress_max_requests must be > 0");
        anyhow::ensure!(cfg.coord.ingress_write_queue > 0, "ingress_write_queue must be > 0");
        anyhow::ensure!(
            cfg.coord.ingress_stall_budget_us > 0,
            "ingress_stall_budget_us must be > 0"
        );
        anyhow::ensure!(cfg.coord.ingress_acceptors > 0, "ingress_acceptors must be > 0");
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn defaults_match_paper_setup() {
        let c = AcceleratorConfig::default();
        assert_eq!(c.seq_len, 1024);
        assert_eq!(c.kv_blocks, 4);
        assert_eq!(c.freq_mhz, 500.0);
    }

    #[test]
    fn cli_overrides_file() {
        let dir = std::env::temp_dir().join("hfa_cfg_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.txt");
        let mut f = fs::File::create(&p).unwrap();
        writeln!(f, "head_dim=32\nkv_blocks=8").unwrap();
        let args = Args::parse(["--head-dim".into(), "128".into()]);
        let c = Config::resolve(Some(&p), &args).unwrap();
        assert_eq!(c.accel.head_dim, 128); // CLI wins
        assert_eq!(c.accel.kv_blocks, 8); // file applies
    }

    #[test]
    fn robustness_knobs_resolve() {
        let args = Args::parse([
            "--request-timeout-us".into(),
            "2500".into(),
            "--max-pending-requests".into(),
            "9".into(),
            "--max-retries".into(),
            "5".into(),
            "--retry-backoff-us".into(),
            "777".into(),
            "--worker-respawn-budget".into(),
            "3".into(),
        ]);
        let c = Config::resolve(None, &args).unwrap();
        assert_eq!(c.coord.request_timeout_us, 2500);
        assert_eq!(c.coord.max_pending_requests, 9);
        assert_eq!(c.coord.max_retries, 5);
        assert_eq!(c.coord.retry_backoff_us, 777);
        assert_eq!(c.coord.worker_respawn_budget, 3);
        // defaults survive when unset
        let c = Config::resolve(None, &Args::parse(Vec::<String>::new())).unwrap();
        assert_eq!(c.coord, CoordinatorConfig::default());
    }

    #[test]
    fn continuous_batching_knobs_resolve() {
        let args = Args::parse([
            "--max-batch-prefill-tokens".into(),
            "4096".into(),
            "--max-batch-total-tokens".into(),
            "16384".into(),
            "--waiting-served-ratio".into(),
            "0.3".into(),
            "--max-waiting-iters".into(),
            "20".into(),
        ]);
        let c = Config::resolve(None, &args).unwrap();
        assert_eq!(c.coord.max_batch_prefill_tokens, 4096);
        assert_eq!(c.coord.max_batch_total_tokens, 16384);
        assert_eq!(c.coord.waiting_served_ratio, 0.3);
        assert_eq!(c.coord.max_waiting_iters, 20);
        // defaults: budgets unlimited, TGI-like ratio, bounded starvation
        let c = Config::resolve(None, &Args::parse(Vec::<String>::new())).unwrap();
        assert_eq!(c.coord.max_batch_prefill_tokens, 0);
        assert_eq!(c.coord.max_batch_total_tokens, 0);
        assert_eq!(c.coord.waiting_served_ratio, 1.2);
        assert_eq!(c.coord.max_waiting_iters, 4);
    }

    #[test]
    fn streaming_ingress_knobs_resolve_and_validate() {
        let args = Args::parse([
            "--response-grace-us".into(),
            "250000".into(),
            "--ingress-max-connections".into(),
            "33".into(),
            "--ingress-max-requests".into(),
            "77".into(),
            "--ingress-write-queue".into(),
            "8".into(),
            "--ingress-stall-budget-us".into(),
            "500000".into(),
            "--ingress-acceptors".into(),
            "4".into(),
        ]);
        let c = Config::resolve(None, &args).unwrap();
        assert_eq!(c.coord.response_grace_us, 250_000);
        assert_eq!(c.coord.ingress_max_connections, 33);
        assert_eq!(c.coord.ingress_max_requests, 77);
        assert_eq!(c.coord.ingress_write_queue, 8);
        assert_eq!(c.coord.ingress_stall_budget_us, 500_000);
        assert_eq!(c.coord.ingress_acceptors, 4);
        // defaults survive when unset
        let c = Config::resolve(None, &Args::parse(Vec::<String>::new())).unwrap();
        assert_eq!(c.coord.response_grace_us, 100_000);
        assert_eq!(c.coord.ingress_write_queue, 64);
        // zero is rejected for every ingress knob and the grace
        for knob in [
            "--response-grace-us",
            "--ingress-max-connections",
            "--ingress-max-requests",
            "--ingress-write-queue",
            "--ingress-stall-budget-us",
            "--ingress-acceptors",
        ] {
            let args = Args::parse([knob.into(), "0".into()]);
            assert!(Config::resolve(None, &args).is_err(), "{knob}=0 must be rejected");
        }
    }

    #[test]
    fn rejects_bad_geometry() {
        let args = Args::parse(["--seq-len".into(), "100".into(), "--kv-blocks".into(), "3".into()]);
        assert!(Config::resolve(None, &args).is_err());
    }

    #[test]
    fn rejects_nonpositive_or_nonfinite_waiting_served_ratio() {
        for bad in ["0", "-1.5", "NaN", "inf"] {
            let args = Args::parse(["--waiting-served-ratio".into(), bad.into()]);
            assert!(
                Config::resolve(None, &args).is_err(),
                "waiting_served_ratio={bad} must be rejected"
            );
        }
        let args = Args::parse(["--waiting-served-ratio".into(), "0.01".into()]);
        assert!(Config::resolve(None, &args).is_ok(), "small positive ratio is valid");
    }
}

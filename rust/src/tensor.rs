//! Minimal row-major f32 matrix used by the golden models, the native
//! transformer and the hardware simulator.  (No external linear-algebra
//! crates are available offline; attention working sets are small.)

/// Row-major `rows x cols` f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self (r x k) * rhs (k x c)` -> `(r x c)`, f32 accumulate.
    pub fn matmul(&self, rhs: &Mat) -> Mat {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = out.row_mut(i);
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = rhs.row(kk);
                for (j, &b) in brow.iter().enumerate() {
                    orow[j] += a * b;
                }
            }
        }
        out
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self.at(c, r))
    }

    /// Slice of rows [lo, hi).
    pub fn rows_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat::from_vec(hi - lo, self.cols, self.data[lo * self.cols..hi * self.cols].to_vec())
    }

    /// Round every element through BF16 (hardware input convention).
    pub fn round_bf16(&self) -> Mat {
        let data = self.data.iter().map(|&x| crate::Bf16::from_f32(x).to_f32()).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative RMS error vs. a reference.
    pub fn rel_rms(&self, reference: &Mat) -> f64 {
        let num: f64 = self
            .data
            .iter()
            .zip(&reference.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        let den: f64 = reference.data.iter().map(|&b| (b as f64).powi(2)).sum();
        (num / den.max(1e-300)).sqrt()
    }
}

/// Sequential dot product (definition order matters for cross-checking
/// against hardware which accumulates in stream order).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let i = Mat::from_fn(2, 2, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 5, |r, c| (r * 5 + c) as f32);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn rows_slice_picks_rows() {
        let a = Mat::from_fn(4, 2, |r, _| r as f32);
        let s = a.rows_slice(1, 3);
        assert_eq!(s.data, vec![1., 1., 2., 2.]);
    }

    #[test]
    fn rel_rms_zero_for_identical() {
        let a = Mat::from_fn(2, 2, |r, c| (r + c) as f32 + 1.0);
        assert!(a.rel_rms(&a) < 1e-12);
    }
}

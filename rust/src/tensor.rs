//! Minimal row-major f32 matrix used by the golden models, the native
//! transformer and the hardware simulator.  (No external linear-algebra
//! crates are available offline; attention working sets are small.)

/// Row-major `rows x cols` f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    /// An empty (0-row) matrix whose storage is preallocated for
    /// `row_capacity` rows, so growing it row-by-row up to that capacity
    /// never reallocates — the backing store of a fixed-capacity KV chunk.
    pub fn with_row_capacity(row_capacity: usize, cols: usize) -> Mat {
        Mat { rows: 0, cols, data: Vec::with_capacity(row_capacity * cols) }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self (r x k) * rhs (k x c)` -> `(r x c)`, f32 accumulate.
    ///
    /// Blocked transposed-RHS kernel: the RHS is transposed once so every
    /// output element is a unit-stride [`dot_f32`] over two contiguous
    /// rows (the same sequential accumulation order as the definition,
    /// so results match the element-wise `dot_f32` oracle exactly).
    pub fn matmul(&self, rhs: &Mat) -> Mat {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        self.matmul_t(&rhs.t())
    }

    /// `self (r x k) * rhs_t^T` where `rhs_t (c x k)` is the RHS **already
    /// transposed** — the kernel behind [`Mat::matmul`], exposed so
    /// callers that hold a transposed operand (e.g. the weight-tied LM
    /// head, where `tok_emb` *is* `W_head^T`) skip the per-call transpose
    /// copy.  Bit-identical to `matmul(&rhs_t.t())`: same `dot_f32` over
    /// the same contiguous rows in the same order.
    pub fn matmul_t(&self, rhs_t: &Mat) -> Mat {
        assert_eq!(self.cols, rhs_t.cols, "matmul_t shape mismatch");
        let mut out = Mat::zeros(self.rows, rhs_t.rows);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = out.row_mut(i);
            for (j, o) in orow.iter_mut().enumerate() {
                *o = dot_f32(arow, rhs_t.row(j));
            }
        }
        out
    }

    /// Transpose (cache-blocked copy).
    pub fn t(&self) -> Mat {
        const TILE: usize = 32;
        let mut out = Mat::zeros(self.cols, self.rows);
        for r0 in (0..self.rows).step_by(TILE) {
            for c0 in (0..self.cols).step_by(TILE) {
                for r in r0..(r0 + TILE).min(self.rows) {
                    for c in c0..(c0 + TILE).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Slice of rows [lo, hi).
    pub fn rows_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat::from_vec(hi - lo, self.cols, self.data[lo * self.cols..hi * self.cols].to_vec())
    }

    /// Copy of columns [lo, hi) (row-wise memcpy) — per-head Q/K/V slicing.
    pub fn cols_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.cols);
        let w = hi - lo;
        let mut out = Mat::zeros(self.rows, w);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[lo..hi]);
        }
        out
    }

    /// Grow capacity geometrically (at least doubling) when `extra` more
    /// elements would not fit.  `Vec` already doubles on its own growth
    /// path, but a cloned `Vec` (e.g. a copy-on-write KV cache) starts at
    /// exact capacity — without this, a per-token append loop over a
    /// clone degenerates to one realloc + full memcpy per token (O(T^2)
    /// bytes over a decode).  Explicit here so the invariant is pinned
    /// by tests rather than inherited from `Vec` internals.
    fn reserve_amortized(&mut self, extra: usize) {
        let need = self.data.len() + extra;
        if need > self.data.capacity() {
            let target = need.max(self.data.capacity() * 2);
            self.data.reserve_exact(target - self.data.len());
        }
    }

    /// Append one row (`row.len() == cols`) below the existing rows.
    /// Amortized O(cols): capacity grows geometrically.
    pub fn append_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols, "append_row width mismatch");
        self.reserve_amortized(row.len());
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Append the rows of `rows` (same column count) below the existing
    /// rows — the decode-time KV growth primitive.  Amortized O(new rows);
    /// resident rows are never moved element-wise (at most one realloc
    /// memcpy of the flat storage, geometrically amortized).
    pub fn append_rows(&mut self, rows: &Mat) {
        assert_eq!(rows.cols, self.cols, "append_rows column mismatch");
        self.reserve_amortized(rows.data.len());
        self.data.extend_from_slice(&rows.data);
        self.rows += rows.rows;
    }

    /// Round every element through BF16 (hardware input convention).
    pub fn round_bf16(&self) -> Mat {
        let data = self.data.iter().map(|&x| crate::Bf16::from_f32(x).to_f32()).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative RMS error vs. a reference.
    pub fn rel_rms(&self, reference: &Mat) -> f64 {
        let num: f64 = self
            .data
            .iter()
            .zip(&reference.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        let den: f64 = reference.data.iter().map(|&b| (b as f64).powi(2)).sum();
        (num / den.max(1e-300)).sqrt()
    }
}

/// Sequential dot product (definition order matters for cross-checking
/// against hardware which accumulates in stream order).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let i = Mat::from_fn(2, 2, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 5, |r, c| (r * 5 + c) as f32);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn transpose_blocked_matches_definition() {
        // shapes straddling the tile size in both dimensions
        for (r, c) in [(1, 1), (7, 3), (32, 32), (33, 31), (70, 5), (2, 65)] {
            let a = Mat::from_fn(r, c, |i, j| (i * 131 + j * 17) as f32 * 0.25 - 3.0);
            let t = a.t();
            assert_eq!((t.rows, t.cols), (c, r));
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t.at(j, i), a.at(i, j), "({i},{j}) of {r}x{c}");
                }
            }
        }
    }

    /// Definition-order reference: the seed's naive triple loop.
    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.cols, b.rows);
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for kk in 0..a.cols {
                for j in 0..b.cols {
                    let x = out.at(i, j) + a.at(i, kk) * b.at(kk, j);
                    out.set(i, j, x);
                }
            }
        }
        out
    }

    #[test]
    fn matmul_kernel_matches_naive_reference_bitwise() {
        // same accumulation order -> bit-identical f32 sums
        let mut seed = 0x9e3779b9u32;
        let mut next = move || {
            seed = seed.wrapping_mul(1664525).wrapping_add(1013904223);
            ((seed >> 8) as f32 / (1 << 24) as f32) * 4.0 - 2.0
        };
        for (r, k, c) in [(1, 1, 1), (3, 5, 2), (8, 8, 8), (13, 33, 7), (31, 4, 17)] {
            let a = Mat::from_fn(r, k, |_, _| next());
            let b = Mat::from_fn(k, c, |_, _| next());
            let fast = a.matmul(&b);
            let slow = matmul_naive(&a, &b);
            assert_eq!(fast.data, slow.data, "{r}x{k}x{c}");
        }
    }

    #[test]
    fn matmul_t_bitwise_equals_matmul() {
        let a = Mat::from_fn(5, 11, |r, c| ((r * 11 + c) as f32).sin());
        let b = Mat::from_fn(11, 7, |r, c| ((r * 7 + c) as f32).cos());
        assert_eq!(a.matmul(&b).data, a.matmul_t(&b.t()).data);
    }

    #[test]
    fn matmul_consistent_with_dot_f32() {
        let a = Mat::from_fn(6, 19, |r, c| ((r * 19 + c) as f32).sin());
        let b = Mat::from_fn(19, 9, |r, c| ((r * 9 + c) as f32).cos());
        let o = a.matmul(&b);
        let bt = b.t();
        for i in 0..6 {
            for j in 0..9 {
                assert_eq!(o.at(i, j), dot_f32(a.row(i), bt.row(j)), "({i},{j})");
            }
        }
    }

    #[test]
    fn matmul_with_zero_rows_matches_reference() {
        // zeros exercised the seed kernel's skip path; the new kernel must
        // produce the same sums
        let mut a = Mat::from_fn(4, 6, |r, c| (r + c) as f32 - 3.0);
        for c in 0..6 {
            a.set(2, c, 0.0);
        }
        let b = Mat::from_fn(6, 5, |r, c| (r * 5 + c) as f32 * 0.5 - 7.0);
        assert_eq!(a.matmul(&b).data, matmul_naive(&a, &b).data);
        assert_eq!(a.matmul(&b).row(2).to_vec(), vec![0.0f32; 5]);
    }

    #[test]
    fn cols_slice_picks_columns() {
        let a = Mat::from_fn(3, 6, |r, c| (r * 10 + c) as f32);
        let s = a.cols_slice(2, 5);
        assert_eq!((s.rows, s.cols), (3, 3));
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(s.at(r, c), a.at(r, 2 + c));
            }
        }
        let full = a.cols_slice(0, 6);
        assert_eq!(full, a);
    }

    #[test]
    fn rows_slice_picks_rows() {
        let a = Mat::from_fn(4, 2, |r, _| r as f32);
        let s = a.rows_slice(1, 3);
        assert_eq!(s.data, vec![1., 1., 2., 2.]);
    }

    #[test]
    fn append_rows_extends_in_place() {
        let mut a = Mat::from_fn(2, 3, |r, c| (r * 3 + c) as f32);
        let b = Mat::from_fn(2, 3, |r, c| 100.0 + (r * 3 + c) as f32);
        a.append_rows(&b);
        assert_eq!((a.rows, a.cols), (4, 3));
        assert_eq!(a.row(1), &[3.0, 4.0, 5.0][..]);
        assert_eq!(a.row(2), &[100.0, 101.0, 102.0][..]);
        assert_eq!(a.row(3), &[103.0, 104.0, 105.0][..]);
        // appending zero rows is a no-op
        a.append_rows(&Mat::zeros(0, 3));
        assert_eq!(a.rows, 4);
        // prefix + appended suffix == the full matrix built at once
        let full = Mat::from_fn(5, 2, |r, c| (r * 2 + c) as f32 * 0.5);
        let mut grown = full.rows_slice(0, 2);
        grown.append_rows(&full.rows_slice(2, 5));
        assert_eq!(grown, full);
    }

    #[test]
    fn append_row_matches_append_rows() {
        let mut by_row = Mat::with_row_capacity(4, 3);
        let mut by_mat = Mat::zeros(0, 3);
        let src = Mat::from_fn(5, 3, |r, c| (r * 3 + c) as f32);
        for r in 0..5 {
            by_row.append_row(src.row(r));
        }
        by_mat.append_rows(&src);
        assert_eq!(by_row, by_mat);
        assert_eq!(by_row, src);
    }

    #[test]
    fn append_growth_is_geometric_even_after_exact_capacity_clone() {
        // a cloned Vec starts at exact capacity; T single-row appends
        // must still trigger only O(log T) reallocations, not T
        let src = Mat::from_fn(1, 8, |_, c| c as f32);
        let mut m = Mat::from_fn(100, 8, |r, c| (r * 8 + c) as f32).clone();
        let mut caps = std::collections::BTreeSet::new();
        for _ in 0..1000 {
            m.append_rows(&src);
            caps.insert(m.data.capacity());
        }
        assert_eq!(m.rows, 1100);
        assert!(
            caps.len() <= 8,
            "capacity changed {} times over 1000 single-row appends — growth is not geometric",
            caps.len()
        );
    }

    #[test]
    fn with_row_capacity_appends_without_realloc() {
        let mut m = Mat::with_row_capacity(64, 4);
        let cap0 = m.data.capacity();
        let row = [1.0f32, 2.0, 3.0, 4.0];
        for _ in 0..64 {
            m.append_row(&row);
        }
        assert_eq!(m.rows, 64);
        assert_eq!(m.data.capacity(), cap0, "preallocated chunk must not realloc");
    }

    #[test]
    fn rel_rms_zero_for_identical() {
        let a = Mat::from_fn(2, 2, |r, c| (r + c) as f32 + 1.0);
        assert!(a.rel_rms(&a) < 1e-12);
    }
}

//! In-repo property-testing micro-framework (crates.io `proptest` is not
//! available offline — DESIGN.md §9).
//!
//! Deterministic xorshift PRNG + a `check` runner that reports the first
//! failing case with its seed and iteration so failures are reproducible.

/// Deterministic xorshift64* generator.
#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng(seed.max(1))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Uniform i32 in [lo, hi).
    #[inline]
    pub fn int_in(&mut self, lo: i32, hi: i32) -> i32 {
        lo + (self.below((hi - lo) as u64) as i32)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn unit_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.unit_f32() * (hi - lo)
    }

    /// Approximately standard normal (sum of 12 uniforms - 6).
    pub fn normal_f32(&mut self) -> f32 {
        let mut s = 0.0f32;
        for _ in 0..12 {
            s += self.unit_f32();
        }
        s - 6.0
    }

    /// Vec of approx-normal f32.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

/// Run `f` on `iters` generated cases; panic with seed/iteration context on
/// the first failure (returning `Err(msg)` from the property).
pub fn check<G, T, F>(name: &str, seed: u64, iters: usize, mut gen: G, mut f: F)
where
    G: FnMut(&mut Rng) -> T,
    F: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let mut rng = Rng::new(seed);
    for i in 0..iters {
        let case = gen(&mut rng);
        if let Err(msg) = f(&case) {
            panic!(
                "property '{name}' failed at iteration {i} (seed {seed}): {msg}\ncase: {case:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn unit_f32_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.unit_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_has_reasonable_moments() {
        let mut r = Rng::new(1);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal_f32()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn check_runs_all_iters() {
        let mut count = 0;
        check("counter", 3, 50, |r| r.int_in(0, 10), |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_panics_on_failure() {
        check("fails", 3, 50, |r| r.int_in(0, 10), |&x| {
            if x < 9 { Ok(()) } else { Err("too big".into()) }
        });
    }
}

//! Native tiny-LM inference engine: loads the weights trained at artifact
//! build time (`artifacts/models/<size>/`) and runs the transformer
//! forward in f32 with a pluggable attention implementation — the
//! instrumentable path behind the Table I/II/III accuracy study and the
//! Fig. 5 histogram (the PJRT full-model artifacts cross-check it).

pub mod config;
pub mod transformer;
pub mod weights;

pub use config::ModelConfig;
pub use transformer::{AttnSelect, Decoder, Transformer};
pub use weights::Weights;

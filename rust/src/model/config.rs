//! Model configuration file (`config.txt` written by `model.py`).

use std::path::Path;

use anyhow::{Context, Result};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub n_layer: usize,
    pub seq_len: usize,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_head
    }

    pub fn load(path: &Path) -> Result<ModelConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading model config {}", path.display()))?;
        let mut name = String::new();
        let (mut vocab, mut d_model, mut n_head, mut n_layer, mut seq_len) = (0, 0, 0, 0, 0);
        for line in text.lines() {
            let Some((k, v)) = line.split_once('=') else { continue };
            match k.trim() {
                "name" => name = v.trim().to_string(),
                "vocab" => vocab = v.trim().parse()?,
                "d_model" => d_model = v.trim().parse()?,
                "n_head" => n_head = v.trim().parse()?,
                "n_layer" => n_layer = v.trim().parse()?,
                "seq_len" => seq_len = v.trim().parse()?,
                _ => {}
            }
        }
        anyhow::ensure!(d_model > 0 && n_head > 0 && n_layer > 0, "incomplete config");
        anyhow::ensure!(d_model % n_head == 0, "d_model must divide n_head");
        Ok(ModelConfig { name, vocab, d_model, n_head, n_layer, seq_len })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn parses_config_file() {
        let dir = std::env::temp_dir().join("hfa_model_cfg");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("config.txt");
        let mut f = std::fs::File::create(&p).unwrap();
        writeln!(f, "name=s1\nvocab=64\nd_model=64\nn_head=2\nn_layer=2\nseq_len=128").unwrap();
        let c = ModelConfig::load(&p).unwrap();
        assert_eq!(c.name, "s1");
        assert_eq!(c.d_head(), 32);
    }

    #[test]
    fn rejects_incomplete() {
        let dir = std::env::temp_dir().join("hfa_model_cfg2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("config.txt");
        std::fs::write(&p, "name=x\n").unwrap();
        assert!(ModelConfig::load(&p).is_err());
    }
}

//! Weight loading: flat little-endian f32 `weights.bin` + line-based
//! `manifest.txt` (`name|shape|offset|count`) written by `model.py`.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::Mat;

/// All named parameters of one model.
pub struct Weights {
    tensors: HashMap<String, (Vec<usize>, Vec<f32>)>,
}

impl Weights {
    pub fn load(dir: &Path) -> Result<Weights> {
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let raw = std::fs::read(dir.join("weights.bin"))
            .with_context(|| format!("reading weights.bin in {}", dir.display()))?;
        anyhow::ensure!(raw.len() % 4 == 0, "weights.bin not a multiple of 4 bytes");
        let flat: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();

        let mut tensors = HashMap::new();
        for line in manifest.lines() {
            if line.starts_with('#') || line.trim().is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split('|').collect();
            if parts.len() != 4 {
                bail!("bad manifest line {line:?}");
            }
            let name = parts[0].to_string();
            let shape: Vec<usize> = parts[1]
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().unwrap())
                .collect();
            let offset: usize = parts[2].parse()?;
            let count: usize = parts[3].parse()?;
            anyhow::ensure!(offset + count <= flat.len(), "manifest overruns weights.bin");
            anyhow::ensure!(
                shape.iter().product::<usize>() == count,
                "shape/count mismatch for {name}"
            );
            tensors.insert(name, (shape, flat[offset..offset + count].to_vec()));
        }
        Ok(Weights { tensors })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.tensors.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Fetch a 2-D tensor as a Mat.
    pub fn mat(&self, name: &str) -> Result<Mat> {
        let (shape, data) = self
            .tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing weight {name}"))?;
        anyhow::ensure!(shape.len() == 2, "{name} is not 2-D (shape {shape:?})");
        Ok(Mat::from_vec(shape[0], shape[1], data.clone()))
    }

    /// Fetch a 1-D tensor.
    pub fn vec(&self, name: &str) -> Result<Vec<f32>> {
        let (shape, data) = self
            .tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing weight {name}"))?;
        anyhow::ensure!(shape.len() == 1, "{name} is not 1-D (shape {shape:?})");
        Ok(data.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn loads_manifest_and_bin() {
        let dir = std::env::temp_dir().join("hfa_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let data: Vec<f32> = (0..10).map(|x| x as f32).collect();
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("weights.bin"), bytes).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        writeln!(f, "# header\na|2,3|0|6\nb|4|6|4").unwrap();
        let w = Weights::load(&dir).unwrap();
        assert_eq!(w.mat("a").unwrap().at(1, 2), 5.0);
        assert_eq!(w.vec("b").unwrap(), vec![6.0, 7.0, 8.0, 9.0]);
        assert!(w.mat("missing").is_err());
        assert!(w.vec("a").is_err());
    }
}

//! The native transformer forward: pre-LN GPT blocks with pluggable
//! attention — `exact` (training parity), `fa2` (BF16 FlashAttention-2),
//! `hfa` (the bit-exact log-domain datapath), or the functional H-FA
//! emulation with per-approximation ablation switches (Table III) and an
//! optional Mitchell-input histogram (Fig. 5).
//!
//! Mirrors `python/compile/model.py` (same LN epsilon, tanh-approximated
//! GELU, weight-tied head); the PJRT full-model artifacts cross-check the
//! numerics in `rust/tests/model_eval.rs`.

use std::path::Path;

use anyhow::Result;

use crate::arith::mitchell::MitchellHistogram;
use crate::attention::{exact, fa2, hfa, PreparedKv};
use crate::tensor::Mat;

use super::config::ModelConfig;
use super::weights::Weights;

/// Attention implementation selector (including ablations).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AttnSelect {
    Exact,
    Fa2,
    Hfa,
    /// Functional H-FA with ablation switches (Table III).
    HfaEmu(hfa::EmuConfig),
}

impl AttnSelect {
    pub fn from_str(s: &str) -> Result<AttnSelect> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "exact" => AttnSelect::Exact,
            "fa2" => AttnSelect::Fa2,
            "hfa" => AttnSelect::Hfa,
            "hfa-emu" => AttnSelect::HfaEmu(hfa::EmuConfig::all_on()),
            "hfa-noquant" => {
                AttnSelect::HfaEmu(hfa::EmuConfig { quant: false, ..hfa::EmuConfig::all_on() })
            }
            "hfa-nomitchell" => {
                AttnSelect::HfaEmu(hfa::EmuConfig { mitchell: false, ..hfa::EmuConfig::all_on() })
            }
            "hfa-nopwl" => {
                AttnSelect::HfaEmu(hfa::EmuConfig { pwl: false, ..hfa::EmuConfig::all_on() })
            }
            other => anyhow::bail!("unknown attention selector {other:?}"),
        })
    }

    pub fn name(self) -> String {
        match self {
            AttnSelect::Exact => "exact".into(),
            AttnSelect::Fa2 => "fa2".into(),
            AttnSelect::Hfa => "hfa".into(),
            AttnSelect::HfaEmu(c) => format!(
                "hfa-emu(q={},m={},p={})",
                c.quant as u8, c.mitchell as u8, c.pwl as u8
            ),
        }
    }
}

/// A loaded model ready for inference.
pub struct Transformer {
    pub cfg: ModelConfig,
    w: Weights,
}

impl Transformer {
    pub fn load(dir: &Path) -> Result<Transformer> {
        let cfg = ModelConfig::load(&dir.join("config.txt"))?;
        let w = Weights::load(dir)?;
        Ok(Transformer { cfg, w })
    }

    /// Forward one sequence: `tokens` -> logits `(T, V)`.
    /// `hist` collects Mitchell inputs when attention is an H-FA variant.
    pub fn forward(
        &self,
        tokens: &[i32],
        attn: AttnSelect,
        hist: &mut Option<&mut MitchellHistogram>,
    ) -> Result<Mat> {
        let t = tokens.len();
        anyhow::ensure!(t <= self.cfg.seq_len, "sequence too long");
        let d = self.cfg.d_model;

        let tok_emb = self.w.mat("tok_emb")?;
        let pos_emb = self.w.mat("pos_emb")?;
        let mut x = Mat::zeros(t, d);
        for (i, &tok) in tokens.iter().enumerate() {
            anyhow::ensure!((tok as usize) < self.cfg.vocab, "token {tok} out of vocab");
            for j in 0..d {
                x.set(i, j, tok_emb.at(tok as usize, j) + pos_emb.at(i, j));
            }
        }

        // causal mask rows, built once and shared by every layer and head
        let mask = causal_mask(t);

        for l in 0..self.cfg.n_layer {
            let pfx = format!("l{l}");
            let ln1 = layer_norm(&x, &self.w.vec(&format!("{pfx}.ln1_g"))?,
                                 &self.w.vec(&format!("{pfx}.ln1_b"))?);
            let a = self.attention(&ln1, l, attn, &mask, hist)?;
            add_inplace(&mut x, &a);

            let ln2 = layer_norm(&x, &self.w.vec(&format!("{pfx}.ln2_g"))?,
                                 &self.w.vec(&format!("{pfx}.ln2_b"))?);
            let mut h = ln2.matmul(&self.w.mat(&format!("{pfx}.w1"))?);
            let b1 = self.w.vec(&format!("{pfx}.b1"))?;
            for r in 0..h.rows {
                for c in 0..h.cols {
                    h.set(r, c, gelu(h.at(r, c) + b1[c]));
                }
            }
            let mut m = h.matmul(&self.w.mat(&format!("{pfx}.w2"))?);
            let b2 = self.w.vec(&format!("{pfx}.b2"))?;
            for r in 0..m.rows {
                for c in 0..m.cols {
                    let v = m.at(r, c) + b2[c];
                    m.set(r, c, v);
                }
            }
            add_inplace(&mut x, &m);
        }

        let xf = layer_norm(&x, &self.w.vec("lnf_g")?, &self.w.vec("lnf_b")?);
        Ok(xf.matmul(&tok_emb.t())) // weight-tied head
    }

    fn attention(
        &self,
        x: &Mat,
        layer: usize,
        attn: AttnSelect,
        mask: &[bool],
        hist: &mut Option<&mut MitchellHistogram>,
    ) -> Result<Mat> {
        let t = x.rows;
        let (h, dh) = (self.cfg.n_head, self.cfg.d_head());
        let pfx = format!("l{layer}");
        let q_all = x.matmul(&self.w.mat(&format!("{pfx}.wq"))?);
        let k_all = x.matmul(&self.w.mat(&format!("{pfx}.wk"))?);
        let v_all = x.matmul(&self.w.mat(&format!("{pfx}.wv"))?);

        let mut merged = Mat::zeros(t, self.cfg.d_model);
        for head in 0..h {
            // contiguous row-wise head slices (memcpy, not per-element)
            let q = q_all.cols_slice(head * dh, (head + 1) * dh);
            let k = k_all.cols_slice(head * dh, (head + 1) * dh);
            let v = v_all.cols_slice(head * dh, (head + 1) * dh);
            let o = match attn {
                AttnSelect::Exact => exact::attention(&q, &k, &v, None, Some(mask)),
                AttnSelect::Fa2 => {
                    // the BF16 hardware path rounds operands on ingress
                    fa2::attention(&q.round_bf16(), &k.round_bf16(), &v.round_bf16(),
                                   None, Some(mask)).round_bf16()
                }
                AttnSelect::Hfa => {
                    if hist.is_some() {
                        hfa::attention(&q.round_bf16(), &k.round_bf16(), &v.round_bf16(),
                                       None, Some(mask), hist)
                    } else {
                        // prepared per-head KV: convert V once, reuse the
                        // resident lanes for every query row of this pass
                        let kv = PreparedKv::new(k.round_bf16(), v.round_bf16());
                        kv.attention(&q.round_bf16(), None, Some(mask))
                    }
                }
                AttnSelect::HfaEmu(cfg) => hfa::attention_emu_masked(
                    &q.round_bf16(), &k.round_bf16(), &v.round_bf16(), cfg, None, Some(mask)),
            };
            for r in 0..t {
                merged.row_mut(r)[head * dh..(head + 1) * dh].copy_from_slice(o.row(r));
            }
        }
        Ok(merged.matmul(&self.w.mat(&format!("{pfx}.wo"))?))
    }
}

/// Causal mask rows for a `t`-token sequence (true = attend).
fn causal_mask(t: usize) -> Vec<bool> {
    let mut mask = vec![false; t * t];
    for i in 0..t {
        for j in 0..=i {
            mask[i * t + j] = true;
        }
    }
    mask
}

fn layer_norm(x: &Mat, g: &[f32], b: &[f32]) -> Mat {
    let mut out = Mat::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let mu: f32 = row.iter().sum::<f32>() / row.len() as f32;
        let var: f32 = row.iter().map(|v| (v - mu).powi(2)).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for c in 0..x.cols {
            out.set(r, c, (row[c] - mu) * inv * g[c] + b[c]);
        }
    }
    out
}

/// tanh-approximated GELU (jax.nn.gelu default).
fn gelu(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

fn add_inplace(x: &mut Mat, y: &Mat) {
    for (a, b) in x.data.iter_mut().zip(&y.data) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_reference_points() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        assert!(gelu(10.0) > 9.99);
    }

    #[test]
    fn layer_norm_normalizes() {
        let x = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let out = layer_norm(&x, &[1.0; 4], &[0.0; 4]);
        let mean: f32 = out.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 = out.row(0).iter().map(|v| (v - mean).powi(2)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn attn_select_parses_all_variants() {
        for s in ["exact", "fa2", "hfa", "hfa-emu", "hfa-noquant", "hfa-nomitchell", "hfa-nopwl"] {
            assert!(AttnSelect::from_str(s).is_ok(), "{s}");
        }
        assert!(AttnSelect::from_str("bogus").is_err());
    }
}

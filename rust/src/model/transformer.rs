//! The native transformer forward: pre-LN GPT blocks with pluggable
//! attention — `exact` (training parity), `fa2` (BF16 FlashAttention-2),
//! `hfa` (the bit-exact log-domain datapath), or the functional H-FA
//! emulation with per-approximation ablation switches (Table III) and an
//! optional Mitchell-input histogram (Fig. 5).
//!
//! Mirrors `python/compile/model.py` (same LN epsilon, tanh-approximated
//! GELU, weight-tied head); the PJRT full-model artifacts cross-check the
//! numerics in `rust/tests/model_eval.rs`.

use std::path::Path;

use anyhow::Result;

use crate::arith::mitchell::MitchellHistogram;
use crate::attention::{exact, fa2, hfa, PreparedKv};
use crate::tensor::Mat;

use super::config::ModelConfig;
use super::weights::Weights;

/// Attention implementation selector (including ablations).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AttnSelect {
    Exact,
    Fa2,
    Hfa,
    /// Functional H-FA with ablation switches (Table III).
    HfaEmu(hfa::EmuConfig),
}

impl AttnSelect {
    // not the FromStr trait: this is a CLI selector with anyhow errors
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Result<AttnSelect> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "exact" => AttnSelect::Exact,
            "fa2" => AttnSelect::Fa2,
            "hfa" => AttnSelect::Hfa,
            "hfa-emu" => AttnSelect::HfaEmu(hfa::EmuConfig::all_on()),
            "hfa-noquant" => {
                AttnSelect::HfaEmu(hfa::EmuConfig { quant: false, ..hfa::EmuConfig::all_on() })
            }
            "hfa-nomitchell" => {
                AttnSelect::HfaEmu(hfa::EmuConfig { mitchell: false, ..hfa::EmuConfig::all_on() })
            }
            "hfa-nopwl" => {
                AttnSelect::HfaEmu(hfa::EmuConfig { pwl: false, ..hfa::EmuConfig::all_on() })
            }
            other => anyhow::bail!("unknown attention selector {other:?}"),
        })
    }

    pub fn name(self) -> String {
        match self {
            AttnSelect::Exact => "exact".into(),
            AttnSelect::Fa2 => "fa2".into(),
            AttnSelect::Hfa => "hfa".into(),
            AttnSelect::HfaEmu(c) => format!(
                "hfa-emu(q={},m={},p={})",
                c.quant as u8, c.mitchell as u8, c.pwl as u8
            ),
        }
    }
}

/// A loaded model ready for inference.
pub struct Transformer {
    pub cfg: ModelConfig,
    w: Weights,
}

impl Transformer {
    pub fn load(dir: &Path) -> Result<Transformer> {
        let cfg = ModelConfig::load(&dir.join("config.txt"))?;
        let w = Weights::load(dir)?;
        Ok(Transformer { cfg, w })
    }

    /// Forward one sequence: `tokens` -> logits `(T, V)`.
    /// `hist` collects Mitchell inputs when attention is an H-FA variant.
    pub fn forward(
        &self,
        tokens: &[i32],
        attn: AttnSelect,
        hist: &mut Option<&mut MitchellHistogram>,
    ) -> Result<Mat> {
        let t = tokens.len();
        anyhow::ensure!(t <= self.cfg.seq_len, "sequence too long");
        let d = self.cfg.d_model;

        let tok_emb = self.w.mat("tok_emb")?;
        let pos_emb = self.w.mat("pos_emb")?;
        let mut x = Mat::zeros(t, d);
        for (i, &tok) in tokens.iter().enumerate() {
            anyhow::ensure!((tok as usize) < self.cfg.vocab, "token {tok} out of vocab");
            for j in 0..d {
                x.set(i, j, tok_emb.at(tok as usize, j) + pos_emb.at(i, j));
            }
        }

        // causal mask rows, built once and shared by every layer and head
        let mask = causal_mask(t);

        for l in 0..self.cfg.n_layer {
            let pfx = format!("l{l}");
            let ln1 = layer_norm(&x, &self.w.vec(&format!("{pfx}.ln1_g"))?,
                                 &self.w.vec(&format!("{pfx}.ln1_b"))?);
            let a = self.attention(&ln1, l, attn, &mask, hist)?;
            add_inplace(&mut x, &a);

            let ln2 = layer_norm(&x, &self.w.vec(&format!("{pfx}.ln2_g"))?,
                                 &self.w.vec(&format!("{pfx}.ln2_b"))?);
            let mut h = ln2.matmul(&self.w.mat(&format!("{pfx}.w1"))?);
            let b1 = self.w.vec(&format!("{pfx}.b1"))?;
            for r in 0..h.rows {
                for c in 0..h.cols {
                    h.set(r, c, gelu(h.at(r, c) + b1[c]));
                }
            }
            let mut m = h.matmul(&self.w.mat(&format!("{pfx}.w2"))?);
            let b2 = self.w.vec(&format!("{pfx}.b2"))?;
            for r in 0..m.rows {
                for c in 0..m.cols {
                    let v = m.at(r, c) + b2[c];
                    m.set(r, c, v);
                }
            }
            add_inplace(&mut x, &m);
        }

        let xf = layer_norm(&x, &self.w.vec("lnf_g")?, &self.w.vec("lnf_b")?);
        // weight-tied head: tok_emb is W_head^T already, no transpose copy
        Ok(xf.matmul_t(&tok_emb))
    }

    /// Start an autoregressive decode session: per-layer, per-head KV
    /// caches that grow by one row per [`Decoder::step`]
    /// (`PreparedKv::append`), so the V linear->log conversion cost
    /// tracks new tokens only — never the resident prefix.  Supports
    /// `exact`, `fa2` and `hfa` attention; step-`t` logits are
    /// bit-identical to row `t` of a full [`Transformer::forward`] over
    /// the same token prefix (causal row `t` attends keys `0..=t`, which
    /// is exactly the grown cache, and every per-row op — LayerNorm,
    /// matmul, GELU — is row-independent).  Pinned by
    /// `rust/tests/decode_parity.rs`.
    pub fn decoder(&self, attn: AttnSelect) -> Result<Decoder<'_>> {
        anyhow::ensure!(
            !matches!(attn, AttnSelect::HfaEmu(_)),
            "decode mode does not support the hfa-emu ablation variants"
        );
        let dh = self.cfg.d_head();
        // fetch every weight tensor once: Weights::mat/vec return owned
        // copies, and a decode loop must not re-copy unchanged weights on
        // every token
        let layers: Vec<LayerWeights> = (0..self.cfg.n_layer)
            .map(|l| {
                let p = format!("l{l}");
                Ok(LayerWeights {
                    ln1_g: self.w.vec(&format!("{p}.ln1_g"))?,
                    ln1_b: self.w.vec(&format!("{p}.ln1_b"))?,
                    wq: self.w.mat(&format!("{p}.wq"))?,
                    wk: self.w.mat(&format!("{p}.wk"))?,
                    wv: self.w.mat(&format!("{p}.wv"))?,
                    wo: self.w.mat(&format!("{p}.wo"))?,
                    ln2_g: self.w.vec(&format!("{p}.ln2_g"))?,
                    ln2_b: self.w.vec(&format!("{p}.ln2_b"))?,
                    w1: self.w.mat(&format!("{p}.w1"))?,
                    b1: self.w.vec(&format!("{p}.b1"))?,
                    w2: self.w.mat(&format!("{p}.w2"))?,
                    b2: self.w.vec(&format!("{p}.b2"))?,
                })
            })
            .collect::<Result<_>>()?;
        let caches: Vec<Vec<HeadCache>> = (0..self.cfg.n_layer)
            .map(|_| (0..self.cfg.n_head).map(|_| HeadCache::new(attn, dh)).collect())
            .collect();
        Ok(Decoder {
            model: self,
            attn,
            tok_emb: self.w.mat("tok_emb")?,
            pos_emb: self.w.mat("pos_emb")?,
            lnf_g: self.w.vec("lnf_g")?,
            lnf_b: self.w.vec("lnf_b")?,
            layers,
            caches,
            pos: 0,
        })
    }

    fn attention(
        &self,
        x: &Mat,
        layer: usize,
        attn: AttnSelect,
        mask: &[bool],
        hist: &mut Option<&mut MitchellHistogram>,
    ) -> Result<Mat> {
        let t = x.rows;
        let (h, dh) = (self.cfg.n_head, self.cfg.d_head());
        let pfx = format!("l{layer}");
        let q_all = x.matmul(&self.w.mat(&format!("{pfx}.wq"))?);
        let k_all = x.matmul(&self.w.mat(&format!("{pfx}.wk"))?);
        let v_all = x.matmul(&self.w.mat(&format!("{pfx}.wv"))?);

        let mut merged = Mat::zeros(t, self.cfg.d_model);
        for head in 0..h {
            // contiguous row-wise head slices (memcpy, not per-element)
            let q = q_all.cols_slice(head * dh, (head + 1) * dh);
            let k = k_all.cols_slice(head * dh, (head + 1) * dh);
            let v = v_all.cols_slice(head * dh, (head + 1) * dh);
            let o = match attn {
                AttnSelect::Exact => exact::attention(&q, &k, &v, None, Some(mask)),
                AttnSelect::Fa2 => {
                    // the BF16 hardware path rounds operands on ingress
                    fa2::attention(&q.round_bf16(), &k.round_bf16(), &v.round_bf16(),
                                   None, Some(mask)).round_bf16()
                }
                AttnSelect::Hfa => {
                    if hist.is_some() {
                        hfa::attention(&q.round_bf16(), &k.round_bf16(), &v.round_bf16(),
                                       None, Some(mask), hist)
                    } else {
                        // prepared per-head KV: convert V once, reuse the
                        // resident lanes for every query row of this pass
                        let kv = PreparedKv::new(k.round_bf16(), v.round_bf16());
                        kv.attention(&q.round_bf16(), None, Some(mask))
                    }
                }
                AttnSelect::HfaEmu(cfg) => hfa::attention_emu_masked(
                    &q.round_bf16(), &k.round_bf16(), &v.round_bf16(), cfg, None, Some(mask)),
            };
            for r in 0..t {
                merged.row_mut(r)[head * dh..(head + 1) * dh].copy_from_slice(o.row(r));
            }
        }
        Ok(merged.matmul(&self.w.mat(&format!("{pfx}.wo"))?))
    }
}

/// One layer's weight tensors, fetched once per decode session (the
/// `Weights` accessors return owned copies — too expensive per token).
struct LayerWeights {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: Mat,
    wk: Mat,
    wv: Mat,
    wo: Mat,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    w1: Mat,
    b1: Vec<f32>,
    w2: Mat,
    b2: Vec<f32>,
}

/// One attention head's growing KV cache.  Only the H-FA variant keeps
/// the log-domain prepared form; exact/fa2 decode attends over raw
/// matrices and must not pay (or count) V->LNS conversions.  The
/// prepared cache is uniquely owned by the decoder, so its chunked
/// `PreparedKv::append` writes the tail chunk in place — one row's
/// conversion and one row's memcpy per step, never the resident prefix
/// (raw caches get the same amortization from `Mat::append_rows`'s
/// geometric growth).
enum HeadCache {
    Raw { k: Mat, v: Mat },
    Prepared(PreparedKv),
}

impl HeadCache {
    fn new(attn: AttnSelect, dh: usize) -> HeadCache {
        match attn {
            AttnSelect::Hfa => {
                HeadCache::Prepared(PreparedKv::new(Mat::zeros(0, dh), Mat::zeros(0, dh)))
            }
            _ => HeadCache::Raw { k: Mat::zeros(0, dh), v: Mat::zeros(0, dh) },
        }
    }
}

/// An autoregressive decode session over a loaded model: feed one token
/// at a time, get that position's logits back.  KV state lives in
/// `caches[layer][head]` and grows append-only — the serving-side analogue
/// of the coordinator's `KvStore::append` path.
pub struct Decoder<'a> {
    model: &'a Transformer,
    attn: AttnSelect,
    tok_emb: Mat,
    pos_emb: Mat,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    layers: Vec<LayerWeights>,
    /// `caches[layer][head]`: grown one row per step.
    caches: Vec<Vec<HeadCache>>,
    pos: usize,
}

impl Decoder<'_> {
    /// Sequence position the next token will occupy.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Feed one token; returns its logits row `(1, V)`.
    pub fn step(&mut self, token: i32) -> Result<Mat> {
        let cfg = &self.model.cfg;
        let t = self.pos;
        anyhow::ensure!(t < cfg.seq_len, "sequence too long");
        anyhow::ensure!(
            token >= 0 && (token as usize) < cfg.vocab,
            "token {token} out of vocab"
        );
        let d = cfg.d_model;
        let n_layer = cfg.n_layer;
        let mut x = Mat::zeros(1, d);
        for j in 0..d {
            x.set(0, j, self.tok_emb.at(token as usize, j) + self.pos_emb.at(t, j));
        }

        for l in 0..n_layer {
            let ln1 = layer_norm(&x, &self.layers[l].ln1_g, &self.layers[l].ln1_b);
            let a = self.attention_step(&ln1, l);
            add_inplace(&mut x, &a);

            let lw = &self.layers[l];
            let ln2 = layer_norm(&x, &lw.ln2_g, &lw.ln2_b);
            let mut h = ln2.matmul(&lw.w1);
            for c in 0..h.cols {
                h.set(0, c, gelu(h.at(0, c) + lw.b1[c]));
            }
            let mut mm = h.matmul(&lw.w2);
            for c in 0..mm.cols {
                let v = mm.at(0, c) + lw.b2[c];
                mm.set(0, c, v);
            }
            add_inplace(&mut x, &mm);
        }

        let xf = layer_norm(&x, &self.lnf_g, &self.lnf_b);
        self.pos += 1;
        // weight-tied head: tok_emb is W_head^T already, no transpose copy
        Ok(xf.matmul_t(&self.tok_emb))
    }

    /// One decode step's attention for one layer: project q/k/v for the
    /// new row, grow each head's cache, attend over it.  No mask is
    /// needed — the causal row `t` attends exactly the `t+1` resident
    /// rows, in the same key order as the full forward pass.
    fn attention_step(&mut self, x: &Mat, layer: usize) -> Mat {
        let cfg = &self.model.cfg;
        let (heads, dh) = (cfg.n_head, cfg.d_head());
        let d_model = cfg.d_model;
        let lw = &self.layers[layer];
        let q_all = x.matmul(&lw.wq);
        let k_all = x.matmul(&lw.wk);
        let v_all = x.matmul(&lw.wv);

        let mut merged = Mat::zeros(1, d_model);
        for head in 0..heads {
            let q = q_all.cols_slice(head * dh, (head + 1) * dh);
            let k = k_all.cols_slice(head * dh, (head + 1) * dh);
            let v = v_all.cols_slice(head * dh, (head + 1) * dh);
            let o = match (self.attn, &mut self.caches[layer][head]) {
                (AttnSelect::Exact, HeadCache::Raw { k: ck, v: cv }) => {
                    ck.append_rows(&k);
                    cv.append_rows(&v);
                    exact::attention(&q, ck, cv, None, None)
                }
                (AttnSelect::Fa2, HeadCache::Raw { k: ck, v: cv }) => {
                    // the BF16 hardware path rounds operands on ingress
                    ck.append_rows(&k.round_bf16());
                    cv.append_rows(&v.round_bf16());
                    fa2::attention(&q.round_bf16(), ck, cv, None, None).round_bf16()
                }
                (AttnSelect::Hfa, HeadCache::Prepared(kv)) => {
                    // resident log-domain lanes: only this step's row is
                    // converted, the prefix is reused as-is
                    kv.append(&k.round_bf16(), &v.round_bf16());
                    kv.attention(&q.round_bf16(), None, None)
                }
                // HfaEmu is rejected in decoder(); cache kind always
                // matches the variant it was built for
                _ => unreachable!("decoder cache/attention variant mismatch"),
            };
            merged.row_mut(0)[head * dh..(head + 1) * dh].copy_from_slice(o.row(0));
        }
        merged.matmul(&self.layers[layer].wo)
    }
}

/// Causal mask rows for a `t`-token sequence (true = attend).
fn causal_mask(t: usize) -> Vec<bool> {
    let mut mask = vec![false; t * t];
    for i in 0..t {
        for j in 0..=i {
            mask[i * t + j] = true;
        }
    }
    mask
}

fn layer_norm(x: &Mat, g: &[f32], b: &[f32]) -> Mat {
    let mut out = Mat::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let mu: f32 = row.iter().sum::<f32>() / row.len() as f32;
        let var: f32 = row.iter().map(|v| (v - mu).powi(2)).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for c in 0..x.cols {
            out.set(r, c, (row[c] - mu) * inv * g[c] + b[c]);
        }
    }
    out
}

/// tanh-approximated GELU (jax.nn.gelu default).
fn gelu(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

fn add_inplace(x: &mut Mat, y: &Mat) {
    for (a, b) in x.data.iter_mut().zip(&y.data) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_reference_points() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        assert!(gelu(10.0) > 9.99);
    }

    #[test]
    fn layer_norm_normalizes() {
        let x = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let out = layer_norm(&x, &[1.0; 4], &[0.0; 4]);
        let mean: f32 = out.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 = out.row(0).iter().map(|v| (v - mean).powi(2)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn attn_select_parses_all_variants() {
        for s in ["exact", "fa2", "hfa", "hfa-emu", "hfa-noquant", "hfa-nomitchell", "hfa-nopwl"] {
            assert!(AttnSelect::from_str(s).is_ok(), "{s}");
        }
        assert!(AttnSelect::from_str("bogus").is_err());
    }
}

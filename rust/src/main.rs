//! `hfa` — launcher CLI for the H-FA accelerator system.
//!
//! Subcommands:
//!   info                         list artifacts, models and kernels
//!   simulate [--head-dim D] [--kv-blocks P] [--seq-len N] [--arith hfa|fa2]
//!                                cycle simulation + cost report
//!   eval --size s1 --impl hfa [--limit K] [--task FILE]
//!                                task-accuracy evaluation (native engine)
//!   serve [--impl hfa|fa2] [--requests N] [--workers W] [--pjrt]
//!                                run the serving coordinator on a workload
//!   serve --listen ADDR [--smoke N] [--steps S]
//!                                framed-socket streaming front end
//!                                (`--smoke N` runs N scripted loopback
//!                                streaming clients, then drains and
//!                                exits; without it, Enter drains)
//!   validate-bench [FILE]        check a BENCH_*.json trajectory file
//!                                against the benchlib row schema
//!                                (default: BENCH_serving.json)
//!   reproduce --exp table1|table3|fig5|fig6|fig7|fig8|table4|e2e
//!                                how to regenerate each paper table/figure

use anyhow::Result;
use hfa::cli::Args;
use hfa::config::{AcceleratorConfig, Config, CoordinatorConfig};
use hfa::hw::cost::{compare, report, Arith};
use hfa::hw::pipeline::{simulate, LatencyModel};

fn main() {
    hfa::logging::init_from_env();
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "info" => info(),
        "simulate" => cmd_simulate(args),
        "eval" => cmd_eval(args),
        "serve" => cmd_serve(args),
        "validate-bench" => cmd_validate_bench(args),
        "reproduce" => cmd_reproduce(args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "hfa — hybrid float/log FlashAttention accelerator (paper reproduction)\n\n\
         usage: hfa <info|simulate|eval|serve|validate-bench|reproduce> [options]\n\n\
         see the module docs in rust/src/main.rs and README.md"
    );
}

fn info() -> Result<()> {
    let dir = hfa::artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    match hfa::runtime::ArtifactRegistry::open(&dir) {
        Err(e) => println!("  (no artifacts: {e})"),
        Ok(reg) => {
            println!("attention kernels:");
            for s in reg.list_attention_kernels()? {
                println!("  {:4} d={:3} N={:4} B={}", s.kind, s.head_dim, s.seq_len, s.batch);
            }
            println!("models:");
            for (size, imp) in reg.list_models()? {
                println!("  model_{size}_{imp}");
            }
        }
    }
    for size in ["s0", "s1", "s2"] {
        let mdir = dir.join("models").join(size);
        if mdir.join("weights.bin").is_file() {
            let cfg = hfa::model::ModelConfig::load(&mdir.join("config.txt"))?;
            println!(
                "native weights {size}: d_model={} heads={} layers={} seq={}",
                cfg.d_model, cfg.n_head, cfg.n_layer, cfg.seq_len
            );
        }
    }
    Ok(())
}

fn accel_cfg(args: &Args) -> Result<AcceleratorConfig> {
    Ok(Config::resolve(None, args)?.accel)
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = accel_cfg(args)?;
    let arith = match args.get_or("arith", "hfa") {
        "fa2" => Arith::Fa2,
        _ => Arith::Hfa,
    };
    let queries = args.get_usize("queries", 16)?;
    let lat = LatencyModel::for_head_dim(cfg.head_dim);
    let stats = simulate(cfg.head_dim, cfg.seq_len, cfg.kv_blocks, cfg.parallel_queries,
                         queries, lat);
    println!(
        "{} d={} N={} p={} nq={} | {} queries: {} cycles = {:.2} us @ {} MHz",
        arith.name(), cfg.head_dim, cfg.seq_len, cfg.kv_blocks, cfg.parallel_queries,
        queries, stats.cycles, stats.time_us(cfg.freq_mhz), cfg.freq_mhz
    );
    println!(
        "  pipeline fill latency: {} cycles (paper: 19/20/21 for d=32/64/128)",
        lat.total()
    );
    println!(
        "  utilization: FAU {:.0}%  ACC {:.0}%  DIV {:.0}%  | SRAM {:.1} words/cycle",
        100.0 * stats.fau_utilization(),
        100.0 * stats.acc_utilization(),
        100.0 * stats.div_utilization(),
        stats.sram_words_per_cycle()
    );
    let r = report(arith, &cfg, queries);
    println!(
        "  cost: datapath {:.3} mm^2 + SRAM {:.3} mm^2, power {:.0} mW",
        r.datapath_area_mm2, r.sram_area_mm2, r.total_power_mw()
    );
    let (fa2, hfa_r, area_s, power_s) = compare(&cfg, queries);
    println!(
        "  H-FA vs FA-2: area {:.3} vs {:.3} mm^2 ({area_s:.1}% less), power {:.0} vs {:.0} mW ({power_s:.1}% less)",
        hfa_r.total_area_mm2(), fa2.total_area_mm2(),
        hfa_r.total_power_mw(), fa2.total_power_mw()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let size = args.get_or("size", "s1");
    let imp = hfa::model::AttnSelect::from_str(args.get_or("impl", "hfa"))?;
    let limit = args.get_usize("limit", 50)?;
    let model = hfa::model::Transformer::load(&hfa::artifacts_dir().join("models").join(size))?;
    let eval_dir = hfa::artifacts_dir().join("eval");
    let files: Vec<_> = match args.get("task") {
        Some(f) => vec![("task".to_string(), 0u32, eval_dir.join(f))],
        None => hfa::evalsuite::tasks::list_eval_files(&eval_dir)?,
    };
    let mut total_c = 0;
    let mut total_n = 0;
    for (fam, var, path) in files {
        let acc = hfa::evalsuite::score::evaluate_file(&model, &path, imp, limit, &mut None)?;
        println!("{fam}_{var}: {:.0}%  ({}/{})", acc.pct(), acc.correct, acc.total);
        total_c += acc.correct;
        total_n += acc.total;
    }
    println!(
        "overall {} {}: {:.1}%",
        size,
        imp.name(),
        100.0 * total_c as f64 / total_n.max(1) as f64
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use hfa::coordinator::{KvStore, PjrtBackend, Server, SimBackend};
    use hfa::proptest::Rng;
    use hfa::sync::Arc;

    let cfg = Config::resolve(None, args)?;
    if let Some(addr) = args.get("listen") {
        return serve_socket(args, &cfg, addr);
    }
    let requests = args.get_usize("requests", 256)?;
    let arith = match args.get_or("impl", "hfa") {
        "fa2" => Arith::Fa2,
        _ => Arith::Hfa,
    };
    let d = cfg.accel.head_dim;
    let n = cfg.accel.seq_len;
    let mut rng = Rng::new(7);
    let kv = Arc::new(KvStore::new(n, d, 4));
    kv.put("demo", hfa::Mat::from_vec(n, d, rng.normal_vec(n * d)),
           hfa::Mat::from_vec(n, d, rng.normal_vec(n * d)))?;

    let coord = CoordinatorConfig { workers: cfg.coord.workers, ..cfg.coord.clone() };
    let factories: Vec<hfa::coordinator::BackendFactory> = if args.flag("pjrt") {
        let spec = hfa::runtime::AttnKernelSpec {
            kind: if arith == Arith::Hfa { "hfa".into() } else { "fa2".into() },
            head_dim: d,
            seq_len: n,
            batch: 16,
        };
        (0..coord.workers)
            .map(|_| PjrtBackend::factory(hfa::artifacts_dir(), spec.clone()))
            .collect()
    } else {
        (0..coord.workers).map(|_| SimBackend::factory(arith, cfg.accel.clone())).collect()
    };
    let server = Server::start(&coord, kv, factories)?;

    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|_| loop {
            match server.submit("demo", rng.normal_vec(d)) {
                Ok(rx) => break rx,
                Err(_) => hfa::sync::thread::sleep(std::time::Duration::from_micros(50)),
            }
        })
        .collect();
    for rx in rxs {
        let r = rx.recv()?;
        anyhow::ensure!(r.ok(), "request failed: {:?}", r.output);
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics.snapshot();
    println!(
        "served {requests} requests in {wall:.3}s = {:.0} QPS | p50 {:.0} us p99 {:.0} us | mean batch {:.1} | rejected {}",
        requests as f64 / wall, snap.p50_us, snap.p99_us, snap.mean_batch, snap.rejected
    );
    server.shutdown();
    Ok(())
}

/// Framed-socket streaming mode: bind the ingress on `--listen ADDR`
/// (":0" picks an ephemeral port).  `--smoke N` runs N concurrent
/// scripted loopback clients — prefill, an S-step token stream, goodbye
/// — then drains and exits non-zero unless the drain was clean; it is
/// the CI streaming smoke.  Without `--smoke`, serves until Enter.
fn serve_socket(args: &Args, cfg: &Config, addr: &str) -> Result<()> {
    use hfa::coordinator::{Client, Ingress, KvStore, Server, SimBackend, StreamEvent, StreamStep};
    use hfa::proptest::Rng;
    use hfa::sync::Arc;

    let arith = match args.get_or("impl", "hfa") {
        "fa2" => Arith::Fa2,
        _ => Arith::Hfa,
    };
    let smoke = args.get_usize("smoke", 0)?;
    let steps = args.get_usize("steps", 8)?;
    let d = cfg.accel.head_dim;
    let n = cfg.accel.seq_len;
    let coord = cfg.coord.clone();
    let kv = Arc::new(KvStore::new(n, d, smoke.max(4)));
    let factories: Vec<hfa::coordinator::BackendFactory> =
        (0..coord.workers).map(|_| SimBackend::factory(arith, cfg.accel.clone())).collect();
    let server = Server::start(&coord, kv, factories)?;
    let ing = Ingress::bind(addr, server, &coord)?;
    let local = ing.local_addr();
    let metrics = ing.metrics();
    println!("listening on {local} (head_dim {d}, seq_len {n}, {} workers)", coord.workers);

    if smoke == 0 {
        println!("press Enter to drain");
        let mut line = String::new();
        let _ = std::io::stdin().read_line(&mut line);
    } else {
        let t0 = std::time::Instant::now();
        let clients: Vec<_> = (0..smoke)
            .map(|i| {
                hfa::sync::thread::spawn(move || -> Result<()> {
                    let mut rng = Rng::new(0xC11 + i as u64);
                    let mut cl = Client::connect(&local)?;
                    let sess = format!("smoke-{i}");
                    let rows = 4.min(n);
                    cl.put(
                        &sess,
                        hfa::Mat::from_vec(rows, d, rng.normal_vec(rows * d)),
                        hfa::Mat::from_vec(rows, d, rng.normal_vec(rows * d)),
                    )?;
                    let plan: Vec<StreamStep> = (0..steps)
                        .map(|_| StreamStep {
                            k: hfa::Mat::from_vec(1, d, rng.normal_vec(d)),
                            v: hfa::Mat::from_vec(1, d, rng.normal_vec(d)),
                            q: rng.normal_vec(d),
                        })
                        .collect();
                    let events = cl.stream(&sess, plan)?;
                    let tokens =
                        events.iter().filter(|e| matches!(e, StreamEvent::Token { .. })).count();
                    anyhow::ensure!(tokens == steps, "{sess}: {tokens}/{steps} tokens");
                    anyhow::ensure!(
                        matches!(events.last(), Some(StreamEvent::End { .. })),
                        "{sess}: stream did not end cleanly: {:?}",
                        events.last()
                    );
                    cl.goodbye()?;
                    Ok(())
                })
            })
            .collect();
        for c in clients {
            c.join().map_err(|_| anyhow::anyhow!("smoke client panicked"))??;
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "smoke: {smoke} clients x {steps} streamed tokens in {wall:.3}s = {:.0} tokens/s",
            (smoke * steps) as f64 / wall
        );
    }

    let report = ing.drain(std::time::Duration::from_secs(30));
    let snap = metrics.snapshot();
    println!("{report}");
    println!(
        "streams {} tokens {} | first-token p50/p99 {:.0}/{:.0} us | inter-token p50/p99 {:.0}/{:.0} us | shed {} disconnects {}",
        snap.streams_opened,
        snap.stream_tokens,
        snap.first_token_p50_us,
        snap.first_token_p99_us,
        snap.inter_token_p50_us,
        snap.inter_token_p99_us,
        snap.slow_consumer_shed,
        snap.disconnects
    );
    anyhow::ensure!(report.clean(), "drain was not clean: {report}");
    Ok(())
}

/// Validate a machine-readable perf trajectory file against the benchlib
/// row schema (`{bench, shape, ns_per_step, kv_bytes_copied}`) — the CI
/// gate that keeps `BENCH_serving.json` toolable as rows accumulate.
fn cmd_validate_bench(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("BENCH_serving.json");
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
    let rows = hfa::benchlib::validate_bench_schema(&text)
        .map_err(|e| anyhow::anyhow!("{path}: schema violation: {e}"))?;
    println!("{path}: ok ({rows} bench rows)");
    Ok(())
}

fn cmd_reproduce(args: &Args) -> Result<()> {
    let exp = args.get_or("exp", "all");
    let mapping = [
        ("table1", "cargo bench --bench table1_accuracy   # Tables I and II"),
        ("table2", "cargo bench --bench table1_accuracy   # emits Table II too"),
        ("table3", "cargo bench --bench table3_error_sources"),
        ("table4", "cargo bench --bench table4_sota"),
        ("fig5", "cargo bench --bench fig5_mitchell_hist"),
        ("fig6", "cargo bench --bench fig7_area_power    # includes Fig. 6 breakdown"),
        ("fig7", "cargo bench --bench fig7_area_power"),
        ("fig8", "cargo bench --bench fig8_scaling"),
        ("e2e", "cargo bench --bench e2e_throughput"),
    ];
    for (k, v) in mapping {
        if exp == "all" || exp == k {
            println!("{k:7} -> {v}");
        }
    }
    Ok(())
}

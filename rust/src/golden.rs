//! Golden-vector file parsing: replays `artifacts/golden/*.txt` dumped by
//! `python/compile/goldens.py` to pin the rust arithmetic to the python
//! spec bit-for-bit (DESIGN.md §3).

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Parse a whitespace-separated table of i64, skipping `#` comments.
pub fn parse_rows(path: &Path) -> Result<Vec<Vec<i64>>> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading golden file {}", path.display()))?;
    let mut rows = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let row: Result<Vec<i64>, _> = line.split_whitespace().map(str::parse).collect();
        rows.push(row.with_context(|| format!("parsing line {line:?}"))?);
    }
    Ok(rows)
}

/// A whole-attention golden case (`attn_case_*.txt`).
#[derive(Debug, Clone)]
pub struct AttnCase {
    pub b: usize,
    pub n: usize,
    pub d: usize,
    pub num_blocks: usize,
    /// (B, d) f32
    pub q: Vec<f32>,
    /// (N, d) f32
    pub k: Vec<f32>,
    /// (N, d) f32
    pub v: Vec<f32>,
    /// (B, N) f32 — scores as computed by numpy (pins association order)
    pub scores: Vec<f32>,
    /// (B, d) expected H-FA output, raw bf16 bits
    pub out_bf16: Vec<u16>,
    /// (B, d) FA-2 reference output, f32
    pub fa2_f32: Vec<f32>,
}

fn f32_from_bits_list(vals: &[i64]) -> Vec<f32> {
    vals.iter().map(|&b| f32::from_bits(b as u32)).collect()
}

pub fn parse_attn_case(path: &Path) -> Result<AttnCase> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading attention case {}", path.display()))?;
    let mut lines = text.lines();
    let header: Vec<usize> = lines
        .next()
        .context("empty golden attention case")?
        .split_whitespace()
        .map(|t| t.parse().unwrap())
        .collect();
    let (b, n, d, num_blocks) = (header[0], header[1], header[2], header[3]);
    let mut case = AttnCase {
        b,
        n,
        d,
        num_blocks,
        q: vec![],
        k: vec![],
        v: vec![],
        scores: vec![],
        out_bf16: vec![],
        fa2_f32: vec![],
    };
    for line in lines {
        let Some((name, rest)) = line.split_once(':') else { continue };
        let vals: Vec<i64> = rest.split_whitespace().map(|t| t.parse().unwrap()).collect();
        match name.trim() {
            "q" => case.q = f32_from_bits_list(&vals),
            "k" => case.k = f32_from_bits_list(&vals),
            "v" => case.v = f32_from_bits_list(&vals),
            "scores" => case.scores = f32_from_bits_list(&vals),
            "out_bf16" => case.out_bf16 = vals.iter().map(|&x| x as u16).collect(),
            "fa2_f32" => case.fa2_f32 = f32_from_bits_list(&vals),
            other => bail!("unknown section {other:?} in {}", path.display()),
        }
    }
    if case.q.len() != b * d || case.k.len() != n * d || case.scores.len() != b * n {
        bail!("golden case {} has inconsistent shapes", path.display());
    }
    Ok(case)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn parse_rows_skips_comments() {
        let dir = std::env::temp_dir().join("hfa_golden_test");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rows.txt");
        let mut f = fs::File::create(&p).unwrap();
        writeln!(f, "# comment\n1 2 3\n\n4 5 6").unwrap();
        let rows = parse_rows(&p).unwrap();
        assert_eq!(rows, vec![vec![1, 2, 3], vec![4, 5, 6]]);
    }
}

//! Execution backends the workers drive: the simulated accelerator
//! (golden-model arithmetic + cycle timing) or a PJRT-compiled HLO kernel.

use std::sync::Arc;

use anyhow::Result;

use crate::attention::PreparedKv;
use crate::coordinator::kvstore::KvEntry;
use crate::hw::Accelerator;
use crate::runtime::LoadedExecutable;
use crate::Mat;

/// Factory constructing a backend *on the worker's own thread* — required
/// because PJRT executables are not `Send` (the xla crate wraps them in
/// `Rc`); each worker owns a thread-local client + executable.
pub type BackendFactory = Box<dyn FnOnce() -> Result<Box<dyn Backend>> + Send>;

/// Something that can compute a batch of attention queries against a KV
/// set.  `compute` receives the session's resident [`KvEntry`] (raw BF16
/// matrices plus the prepared log-domain form) and the query batch;
/// backends may cache per-session state internally.
pub trait Backend {
    fn head_dim(&self) -> usize;
    fn seq_len(&self) -> usize;
    /// Preferred maximum batch (the batcher's cap).
    fn max_batch(&self) -> usize;
    fn compute(&mut self, kv: &KvEntry, q: &Mat) -> Result<Mat>;
    fn name(&self) -> String;
}

/// Backend running the RTL-equivalent simulated accelerator.
pub struct SimBackend {
    accel: Accelerator,
    loaded_session: Option<usize>, // ptr identity of the prepared KV
    pub total_cycles: u64,
}

impl SimBackend {
    pub fn new(accel: Accelerator) -> SimBackend {
        SimBackend { accel, loaded_session: None, total_cycles: 0 }
    }
}

impl Backend for SimBackend {
    fn head_dim(&self) -> usize {
        self.accel.cfg.head_dim
    }

    fn seq_len(&self) -> usize {
        self.accel.cfg.seq_len
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn compute(&mut self, kv: &KvEntry, q: &Mat) -> Result<Mat> {
        // swap in the session's prepared buffers only when they changed
        // (models the preloaded-SRAM assumption; Arc pointer identity is
        // the cache key — ABA-safe because the accelerator retains the
        // loaded Arc).  No copy, no rounding, no V->LNS reconversion —
        // the store prepared everything once at `put()`.  The batch
        // itself runs on the query-tiled two-axis grid inside
        // `Accelerator::compute_batch` (attention::kernel), so even a
        // single-query decode batch parallelizes across the session's
        // resident KV blocks; the cycle model is unaffected.
        let key = Arc::as_ptr(kv.prepared()) as usize;
        if self.loaded_session != Some(key) {
            self.accel.load_prepared(kv.prepared().clone())?;
            self.loaded_session = Some(key);
        }
        let (out, stats) = self.accel.compute_batch(q)?;
        self.total_cycles += stats.cycles;
        Ok(out)
    }

    fn name(&self) -> String {
        format!("sim-{}", self.accel.arith.name())
    }
}

/// Backend running an AOT-compiled PJRT attention kernel.  The kernel has
/// a fixed batch dimension; smaller batches are padded and sliced.  The
/// kernel wants dense contiguous K/V operands, so the session's chunked
/// prepared form is materialized once per session swap and cached by
/// `Arc` identity (same policy as `SimBackend`'s loaded-session cache).
pub struct PjrtBackend {
    exe: Arc<LoadedExecutable>,
    head_dim: usize,
    seq_len: usize,
    batch: usize,
    /// The loaded session's prepared set and its dense K/V planes.  The
    /// `Arc` is retained so pointer-identity comparison is ABA-safe (a
    /// freed session's address can never be reused while we hold it) —
    /// same policy as `SimBackend`/`Accelerator::load_prepared`.
    loaded: Option<(Arc<PreparedKv>, Mat, Mat)>,
}

impl PjrtBackend {
    pub fn new(
        exe: Arc<LoadedExecutable>,
        head_dim: usize,
        seq_len: usize,
        batch: usize,
    ) -> PjrtBackend {
        PjrtBackend { exe, head_dim, seq_len, batch, loaded: None }
    }

    /// Factory that loads the kernel on the worker thread (its own PJRT
    /// client, since executables are not Send).
    pub fn factory(
        artifacts_dir: std::path::PathBuf,
        spec: crate::runtime::AttnKernelSpec,
    ) -> BackendFactory {
        Box::new(move || {
            let reg = crate::runtime::ArtifactRegistry::open(&artifacts_dir)?;
            let exe = reg.attention_kernel(&spec)?;
            Ok(Box::new(PjrtBackend::new(exe, spec.head_dim, spec.seq_len, spec.batch))
                as Box<dyn Backend>)
        })
    }
}

impl SimBackend {
    /// Factory for a simulated-accelerator backend.
    pub fn factory(
        arith: crate::hw::Arith,
        cfg: crate::config::AcceleratorConfig,
    ) -> BackendFactory {
        Box::new(move || Ok(Box::new(SimBackend::new(Accelerator::new(arith, cfg))) as _))
    }
}

impl Backend for PjrtBackend {
    fn head_dim(&self) -> usize {
        self.head_dim
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn compute(&mut self, kv: &KvEntry, q: &Mat) -> Result<Mat> {
        anyhow::ensure!(q.rows <= self.batch, "batch {} exceeds kernel {}", q.rows, self.batch);
        let prepared = kv.prepared();
        // the AOT kernel has a *static* (seq_len, head_dim) K/V shape: a
        // short-prefill or mid-decode session (KvStore allows any
        // residency up to capacity) cannot be shipped to it
        anyhow::ensure!(
            prepared.n() == self.seq_len && prepared.d() == self.head_dim,
            "session KV {}x{} does not match the compiled kernel's static {}x{} \
             (partial/decode sessions need a sim backend or a matching kernel)",
            prepared.n(),
            prepared.d(),
            self.seq_len,
            self.head_dim
        );
        // materialize the chunked session into the kernel's dense layout
        // once per swap (retained-Arc identity — same caching as
        // SimBackend, which keeps the loaded Arc inside the accelerator)
        let stale = match &self.loaded {
            Some((p, _, _)) => !Arc::ptr_eq(p, prepared),
            None => true,
        };
        if stale {
            self.loaded = Some((prepared.clone(), prepared.k_mat(), prepared.v_mat()));
        }
        let (_, dense_k, dense_v) = self.loaded.as_ref().expect("just loaded");
        // pad to the kernel's static batch
        let mut padded = Mat::zeros(self.batch, self.head_dim);
        padded.data[..q.data.len()].copy_from_slice(&q.data);
        let out = self.exe.run_attention(&padded, dense_k, dense_v)?;
        Ok(out.rows_slice(0, q.rows))
    }

    fn name(&self) -> String {
        format!("pjrt-{}", self.exe.name)
    }
}

/// Convenience for tests and examples: wrap raw matrices the way the KV
/// store would (BF16 rounding + one-time preparation).
pub fn prepare_entry(k: Mat, v: Mat) -> KvEntry {
    KvEntry::new(k.round_bf16(), v.round_bf16())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::hw::Arith;
    use crate::proptest::Rng;

    fn hfa_backend() -> SimBackend {
        let cfg = AcceleratorConfig {
            head_dim: 8,
            seq_len: 32,
            kv_blocks: 4,
            parallel_queries: 1,
            freq_mhz: 500.0,
        };
        SimBackend::new(Accelerator::new(Arith::Hfa, cfg))
    }

    #[test]
    fn sim_backend_caches_kv_by_identity() {
        let mut be = hfa_backend();
        let mut rng = Rng::new(3);
        let entry = prepare_entry(
            Mat::from_vec(32, 8, rng.normal_vec(256)),
            Mat::from_vec(32, 8, rng.normal_vec(256)),
        );
        let q = Mat::from_vec(2, 8, rng.normal_vec(16));
        let o1 = be.compute(&entry, &q).unwrap();
        let o2 = be.compute(&entry, &q).unwrap();
        assert_eq!(o1.data, o2.data);
        assert!(be.total_cycles > 0);
    }

    #[test]
    fn sim_backend_swaps_sessions_correctly() {
        let mut be = hfa_backend();
        let mut rng = Rng::new(5);
        let e1 = prepare_entry(
            Mat::from_vec(32, 8, rng.normal_vec(256)),
            Mat::from_vec(32, 8, rng.normal_vec(256)),
        );
        let e2 = prepare_entry(
            Mat::from_vec(32, 8, rng.normal_vec(256)),
            Mat::from_vec(32, 8, rng.normal_vec(256)),
        );
        let q = Mat::from_vec(1, 8, rng.normal_vec(8));
        let o1 = be.compute(&e1, &q).unwrap();
        let o2 = be.compute(&e2, &q).unwrap();
        let o1_again = be.compute(&e1, &q).unwrap();
        assert_ne!(o1.data, o2.data, "different sessions must differ");
        assert_eq!(o1.data, o1_again.data, "session swap must be lossless");
    }
}

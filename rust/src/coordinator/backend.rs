//! Execution backends the workers drive: the simulated accelerator
//! (golden-model arithmetic + cycle timing) or a PJRT-compiled HLO kernel.
//!
//! The worker-facing entry point is **plan-based**
//! ([`Backend::compute_plan`]): one `(session KV, packed queries)` pair
//! per session of a fused cross-session super-batch, answered in one
//! dispatch.  [`Backend::compute`] is the single-session convenience
//! wrapper over it.

use crate::sync::Arc;

use anyhow::Result;

use crate::attention::PreparedKv;
use crate::coordinator::kvstore::KvEntry;
use crate::hw::Accelerator;
use crate::runtime::LoadedExecutable;
use crate::Mat;

/// Factory constructing a backend *on the worker's own thread* — required
/// because PJRT executables are not `Send` (the xla crate wraps them in
/// `Rc`); each worker owns a thread-local client + executable.  `Fn`
/// (not `FnOnce`) so the worker watchdog can rebuild a backend in place
/// after a panic instead of letting the pool shrink.
pub type BackendFactory = Box<dyn Fn() -> Result<Box<dyn Backend>> + Send>;

/// Marker error a backend attaches (via `anyhow::Error::new`) to faults
/// that are worth retrying — a dropped device heartbeat, a transient
/// queue-full, an injected chaos fault.  The serving loop downcasts for
/// it and retries with backoff up to `CoordinatorConfig::max_retries`;
/// any other error is treated as permanent and fails the request at
/// once.
#[derive(Debug, Clone)]
pub struct TransientFault(pub String);

impl std::fmt::Display for TransientFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "transient backend fault: {}", self.0)
    }
}

impl std::error::Error for TransientFault {}

/// Something that can compute batches of attention queries against
/// session KV sets.  `compute_plan` receives one entry per session of a
/// fused dispatch — each the session's resident [`KvEntry`] (raw BF16
/// matrices plus the prepared log-domain form) and its packed query
/// batch — and returns one output matrix per entry, in plan order.
/// Backends may cache per-session state internally; outputs must be
/// independent of what else shares the plan (bit-identical to serving
/// each session alone).
pub trait Backend {
    fn head_dim(&self) -> usize;
    fn seq_len(&self) -> usize;
    /// Preferred maximum per-session batch (the batcher's per-session cap).
    fn max_batch(&self) -> usize;
    /// Fused multi-session dispatch: one output `Mat` per plan entry.
    fn compute_plan(&mut self, plan: &[(&KvEntry, &Mat)]) -> Result<Vec<Mat>>;
    /// Single-session convenience wrapper over [`Backend::compute_plan`].
    fn compute(&mut self, kv: &KvEntry, q: &Mat) -> Result<Mat> {
        let mut outs = self.compute_plan(&[(kv, q)])?;
        let n = outs.len();
        // pop-then-check: a non-conforming backend becomes an error,
        // never a panic on a serve path
        match outs.pop() {
            Some(out) if n == 1 => Ok(out),
            _ => anyhow::bail!("backend returned {n} outputs for 1 entry"),
        }
    }
    fn name(&self) -> String;
}

/// How many sessions' prepared buffers a backend keeps loaded at once —
/// a small set of preloaded SRAM banks ([`SimBackend`]) or materialized
/// dense planes ([`PjrtBackend`]) instead of the old single slot, which
/// thrashed on every cross-session alternation.
const LOADED_SESSIONS: usize = 8;

/// Refresh the slot matching `hit` in a most-recently-used-first vector,
/// returning whether it was resident.  On a miss the caller inserts its
/// fresh entry at the front and truncates to [`LOADED_SESSIONS`].
fn lru_promote<T>(slots: &mut Vec<T>, hit: impl Fn(&T) -> bool) -> bool {
    match slots.iter().position(hit) {
        Some(pos) => {
            let entry = slots.remove(pos);
            slots.insert(0, entry);
            true
        }
        None => false,
    }
}

/// Backend running the RTL-equivalent simulated accelerator.
pub struct SimBackend {
    accel: Accelerator,
    /// Small LRU of loaded prepared sets, most recently used first.
    /// Retaining the `Arc`s keeps pointer identity ABA-safe (a freed
    /// session's address can never be reused while held here).
    loaded: Vec<Arc<PreparedKv>>,
    pub total_cycles: u64,
    /// Sessions swapped into the modelled SRAM (LRU misses) — the
    /// figure the multi-slot cache exists to shrink.
    pub session_loads: u64,
}

impl SimBackend {
    pub fn new(accel: Accelerator) -> SimBackend {
        SimBackend { accel, loaded: Vec::new(), total_cycles: 0, session_loads: 0 }
    }

    /// Mark a session's prepared set loaded (no copy, no rounding, no
    /// V->LNS reconversion — the store prepared everything once at
    /// `put()`): an LRU hit refreshes its slot, a miss evicts the
    /// least-recently-used Arc and counts a load.
    fn touch_loaded(&mut self, kv: &Arc<PreparedKv>) {
        if lru_promote(&mut self.loaded, |p| Arc::ptr_eq(p, kv)) {
            return;
        }
        self.session_loads += 1;
        self.loaded.insert(0, kv.clone());
        self.loaded.truncate(LOADED_SESSIONS);
    }

    /// Prepared sets currently resident in the loaded-session cache.
    pub fn loaded_sessions(&self) -> usize {
        self.loaded.len()
    }
}

impl Backend for SimBackend {
    fn head_dim(&self) -> usize {
        self.accel.cfg.head_dim
    }

    fn seq_len(&self) -> usize {
        self.accel.cfg.seq_len
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn compute_plan(&mut self, plan: &[(&KvEntry, &Mat)]) -> Result<Vec<Mat>> {
        // swap in whichever sessions are not already resident (Arc
        // pointer identity is the cache key), then run the whole
        // super-batch as one ragged cross-session grid dispatch: every
        // (session x query-tile x KV-block) cell fans out through one
        // pool pass inside `Accelerator::compute_plan`, while the cycle
        // model prices the sessions as sequential sub-launches.
        for (kv, _) in plan {
            self.touch_loaded(kv.prepared());
        }
        let accel_plan: Vec<(&Arc<PreparedKv>, &Mat)> =
            plan.iter().map(|&(kv, q)| (kv.prepared(), q)).collect();
        let (outs, stats) = self.accel.compute_plan(&accel_plan)?;
        self.total_cycles += stats.cycles;
        Ok(outs)
    }

    fn name(&self) -> String {
        format!("sim-{}", self.accel.arith.name())
    }
}

/// Backend running an AOT-compiled PJRT attention kernel.  The kernel has
/// a fixed batch dimension; smaller batches are padded and sliced.  The
/// kernel wants dense contiguous K/V operands, so each session's chunked
/// prepared form is materialized once and cached by `Arc` identity in a
/// small LRU (the static kernel cannot fuse sessions, so a plan runs as
/// per-session kernel launches).
pub struct PjrtBackend {
    exe: Arc<LoadedExecutable>,
    head_dim: usize,
    seq_len: usize,
    batch: usize,
    /// Loaded sessions' prepared sets and their dense K/V planes, most
    /// recently used first.  The `Arc` is retained so pointer-identity
    /// comparison is ABA-safe (a freed session's address can never be
    /// reused while we hold it) — same policy as [`SimBackend`].
    loaded: Vec<(Arc<PreparedKv>, Mat, Mat)>,
}

impl PjrtBackend {
    pub fn new(
        exe: Arc<LoadedExecutable>,
        head_dim: usize,
        seq_len: usize,
        batch: usize,
    ) -> PjrtBackend {
        PjrtBackend { exe, head_dim, seq_len, batch, loaded: Vec::new() }
    }

    /// Factory that loads the kernel on the worker thread (its own PJRT
    /// client, since executables are not Send).
    pub fn factory(
        artifacts_dir: std::path::PathBuf,
        spec: crate::runtime::AttnKernelSpec,
    ) -> BackendFactory {
        Box::new(move || {
            let reg = crate::runtime::ArtifactRegistry::open(&artifacts_dir)?;
            let exe = reg.attention_kernel(&spec)?;
            Ok(Box::new(PjrtBackend::new(exe, spec.head_dim, spec.seq_len, spec.batch))
                as Box<dyn Backend>)
        })
    }

    /// One session's kernel launch (pad to the static batch, slice back).
    fn compute_one(&mut self, kv: &KvEntry, q: &Mat) -> Result<Mat> {
        anyhow::ensure!(q.rows <= self.batch, "batch {} exceeds kernel {}", q.rows, self.batch);
        let prepared = kv.prepared();
        // the AOT kernel has a *static* (seq_len, head_dim) K/V shape: a
        // short-prefill or mid-decode session (KvStore allows any
        // residency up to capacity) cannot be shipped to it
        anyhow::ensure!(
            prepared.n() == self.seq_len && prepared.d() == self.head_dim,
            "session KV {}x{} does not match the compiled kernel's static {}x{} \
             (partial/decode sessions need a sim backend or a matching kernel)",
            prepared.n(),
            prepared.d(),
            self.seq_len,
            self.head_dim
        );
        // materialize the chunked session into the kernel's dense layout
        // on first use (retained-Arc identity), refreshing its LRU slot
        if !lru_promote(&mut self.loaded, |(p, _, _)| Arc::ptr_eq(p, prepared)) {
            self.loaded.insert(0, (prepared.clone(), prepared.k_mat(), prepared.v_mat()));
            self.loaded.truncate(LOADED_SESSIONS);
        }
        let (_, dense_k, dense_v) = &self.loaded[0];
        // pad to the kernel's static batch
        let mut padded = Mat::zeros(self.batch, self.head_dim);
        padded.data[..q.data.len()].copy_from_slice(&q.data);
        let out = self.exe.run_attention(&padded, dense_k, dense_v)?;
        Ok(out.rows_slice(0, q.rows))
    }
}

impl SimBackend {
    /// Factory for a simulated-accelerator backend.
    pub fn factory(
        arith: crate::hw::Arith,
        cfg: crate::config::AcceleratorConfig,
    ) -> BackendFactory {
        Box::new(move || {
            Ok(Box::new(SimBackend::new(Accelerator::new(arith, cfg.clone()))) as _)
        })
    }
}

impl Backend for PjrtBackend {
    fn head_dim(&self) -> usize {
        self.head_dim
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn compute_plan(&mut self, plan: &[(&KvEntry, &Mat)]) -> Result<Vec<Mat>> {
        plan.iter().map(|&(kv, q)| self.compute_one(kv, q)).collect()
    }

    fn name(&self) -> String {
        format!("pjrt-{}", self.exe.name)
    }
}

/// Convenience for tests and examples: wrap raw matrices the way the KV
/// store would (BF16 rounding + one-time preparation).
pub fn prepare_entry(k: Mat, v: Mat) -> KvEntry {
    KvEntry::new(k.round_bf16(), v.round_bf16())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::hw::Arith;
    use crate::proptest::Rng;

    fn hfa_backend() -> SimBackend {
        let cfg = AcceleratorConfig {
            head_dim: 8,
            seq_len: 32,
            kv_blocks: 4,
            parallel_queries: 1,
            freq_mhz: 500.0,
        };
        SimBackend::new(Accelerator::new(Arith::Hfa, cfg))
    }

    fn rand_entry(rng: &mut Rng, n: usize) -> KvEntry {
        prepare_entry(
            Mat::from_vec(n, 8, rng.normal_vec(n * 8)),
            Mat::from_vec(n, 8, rng.normal_vec(n * 8)),
        )
    }

    #[test]
    fn sim_backend_caches_kv_by_identity() {
        let mut be = hfa_backend();
        let mut rng = Rng::new(3);
        let entry = rand_entry(&mut rng, 32);
        let q = Mat::from_vec(2, 8, rng.normal_vec(16));
        let o1 = be.compute(&entry, &q).unwrap();
        let o2 = be.compute(&entry, &q).unwrap();
        assert_eq!(o1.data, o2.data);
        assert!(be.total_cycles > 0);
        assert_eq!(be.session_loads, 1, "second compute must hit the loaded cache");
    }

    #[test]
    fn sim_backend_lru_keeps_alternating_sessions_resident() {
        // the single-slot seed reloaded on every cross-session
        // alternation; the LRU must absorb a working set up to its cap
        let mut be = hfa_backend();
        let mut rng = Rng::new(5);
        let e1 = rand_entry(&mut rng, 32);
        let e2 = rand_entry(&mut rng, 32);
        let q = Mat::from_vec(1, 8, rng.normal_vec(8));
        let o1 = be.compute(&e1, &q).unwrap();
        let o2 = be.compute(&e2, &q).unwrap();
        let o1_again = be.compute(&e1, &q).unwrap();
        assert_ne!(o1.data, o2.data, "different sessions must differ");
        assert_eq!(o1.data, o1_again.data, "session swap must be lossless");
        assert_eq!(be.session_loads, 2, "alternation within the LRU must not reload");
        assert_eq!(be.loaded_sessions(), 2);
        // blow past the cap: the oldest falls out and reloads on return
        let extras: Vec<KvEntry> =
            (0..LOADED_SESSIONS).map(|_| rand_entry(&mut rng, 32)).collect();
        for e in &extras {
            be.compute(e, &q).unwrap();
        }
        assert_eq!(be.loaded_sessions(), LOADED_SESSIONS);
        let loads_before = be.session_loads;
        be.compute(&e1, &q).unwrap();
        assert_eq!(be.session_loads, loads_before + 1, "evicted session must reload");
    }

    #[test]
    fn sim_backend_plan_bit_identical_to_solo_serving() {
        // the acceptance property at the backend layer: a fused plan
        // spanning sessions must equal serving each session alone,
        // bitwise, whatever the plan composition
        let mut rng = Rng::new(11);
        let entries: Vec<KvEntry> =
            [32usize, 9, 17].iter().map(|&n| rand_entry(&mut rng, n)).collect();
        let queries: Vec<Mat> = [1usize, 3, 2]
            .iter()
            .map(|&b| Mat::from_vec(b, 8, rng.normal_vec(b * 8)))
            .collect();
        let mut fused_be = hfa_backend();
        let plan: Vec<(&KvEntry, &Mat)> = entries.iter().zip(&queries).collect();
        let fused = fused_be.compute_plan(&plan).unwrap();
        assert_eq!(fused.len(), 3);
        for ((entry, q), fused_out) in plan.iter().zip(&fused) {
            let mut solo_be = hfa_backend();
            let want = solo_be.compute(entry, q).unwrap();
            assert_eq!(fused_out.data, want.data, "fused plan entry diverged from solo");
        }
        // one dispatch loaded all three sessions
        assert_eq!(fused_be.session_loads, 3);
        assert_eq!(fused_be.loaded_sessions(), 3);
    }
}

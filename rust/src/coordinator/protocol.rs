//! The coordinator's hand-rolled concurrency protocols, extracted into
//! one loom-checkable module: the bounded dispatch queue
//! ([`BatchQueue`]), the session cancellation registry
//! ([`CancelRegistry`]), the panic-safe pin guard ([`PinGuard`]), the
//! in-flight admission gate ([`try_admit`]/[`release`]), and the
//! continuous-batching iteration gate ([`IterGate`]/[`IterToken`]).
//!
//! Everything here is built exclusively from the [`crate::sync`] facade,
//! so under `RUSTFLAGS="--cfg loom"` the loom suite
//! (`rust/tests/loom_models.rs`) model-checks these exact
//! implementations — not simplified replicas — across every
//! bounded-preemption interleaving.
//!
//! # Lock order
//!
//! When more than one of the coordinator's locks must be held, they are
//! acquired in this fixed order (enforced textually by
//! `cargo run -p xtask -- lint`):
//!
//! 1. `KvStore` (the store's slot-table mutex, via `pin`/`unpin`/`get`/
//!    `put`/`append`/`evict`),
//! 2. `Metrics` (the latency reservoir mutex, via `observe_latency`/
//!    `snapshot`),
//! 3. dispatch/pool queues ([`BatchQueue`], the worker pool's task
//!    queue).
//!
//! In practice no path in the crate nests them at all — each acquisition
//! is self-contained — and the linter keeps it that way: acquiring a
//! lower-numbered lock while holding a higher-numbered one is the
//! reversal that would let a future refactor deadlock against the
//! existing order.

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{Arc, Condvar, Mutex};

use super::kvstore::KvStore;

/// Bounded dispatch queue between a producer (the batcher) and a fixed
/// pool of consumers (the workers).
///
/// Replaces the former `Arc<Mutex<Receiver<Batch>>>`, whose lock was
/// held **across the blocking `recv()`**: idle workers serialized on the
/// mutex (one waiting inside `recv`, the rest queued on the lock) and
/// shutdown could only wake them strictly one at a time.  Here the lock
/// guards only the deque — waiting happens on the condvar with the lock
/// released, so any number of workers park and wake independently.
///
/// Generic over the item so the loom suite can model-check the protocol
/// on small payloads; the server instantiates `BatchQueue<Batch>`.
pub struct BatchQueue<T> {
    cap: usize,
    inner: Mutex<BatchQueueInner<T>>,
    /// Wakes workers: work available or queue closed.
    available: Condvar,
    /// Wakes the batcher: space freed or a worker died.
    space: Condvar,
}

struct BatchQueueInner<T> {
    queue: VecDeque<T>,
    /// The producer is still feeding the queue.
    open: bool,
    /// Live worker threads (kept honest by the server's `WorkerExit`
    /// guard, panic-safe).
    workers: usize,
}

impl<T> BatchQueue<T> {
    pub fn new(cap: usize, workers: usize) -> BatchQueue<T> {
        BatchQueue {
            cap: cap.max(1),
            inner: Mutex::new(BatchQueueInner {
                queue: VecDeque::new(),
                open: true,
                workers,
            }),
            available: Condvar::new(),
            space: Condvar::new(),
        }
    }

    /// Block until there is room, then enqueue.  `Err(item)` when every
    /// worker is gone — the dispatch would hang its callers forever.
    pub fn push(&self, b: T) -> Result<(), T> {
        let mut g = self.inner.lock();
        while g.queue.len() >= self.cap && g.workers > 0 {
            g = self.space.wait(g);
        }
        if g.workers == 0 {
            return Err(b);
        }
        g.queue.push_back(b);
        drop(g);
        self.available.notify_one();
        Ok(())
    }

    /// Consumer side: block for the next item; `None` once the queue is
    /// closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock();
        loop {
            if let Some(b) = g.queue.pop_front() {
                drop(g);
                self.space.notify_one();
                return Some(b);
            }
            if !g.open {
                return None;
            }
            g = self.available.wait(g);
        }
    }

    /// Producer exit: no more items will arrive; wake every idle worker.
    pub fn close(&self) {
        let mut g = self.inner.lock();
        g.open = false;
        drop(g);
        self.available.notify_all();
    }

    /// One worker is gone (normal exit, failed init, or panic).  The
    /// last worker out hands back whatever is still queued so the caller
    /// can fail those requests explicitly.
    pub fn worker_exited(&self) -> Vec<T> {
        let mut g = self.inner.lock();
        g.workers = g.workers.saturating_sub(1);
        let residue: Vec<T> =
            if g.workers == 0 { g.queue.drain(..).collect() } else { Vec::new() };
        drop(g);
        self.space.notify_all();
        residue
    }
}

/// Why a [`WriteQueue::push`] did not enqueue; the item comes back so
/// the caller can substitute a terminal frame or count the loss.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// The queue stayed full past the caller's stall budget — the
    /// consumer is too slow and the slow-consumer policy applies.
    Stalled(T),
    /// The queue was closed (connection torn down); nothing will drain
    /// it again.
    Closed(T),
}

/// Bounded per-connection write queue between the stream drivers
/// (producers) and the connection's single writer thread (consumer).
///
/// The bound is the backpressure: a full queue blocks the producing
/// stream — and with it that session's decode routing — up to the
/// caller's stall budget, after which [`PushError::Stalled`] hands the
/// frame back and the slow-consumer policy (cancel + evict) takes over.
/// Terminal frames bypass the bound ([`WriteQueue::push_unbounded`])
/// so the exactly-one-terminal-frame contract survives a full queue:
/// shedding must never have to *drop* another request's terminal frame
/// to say "you were shed".
pub struct WriteQueue<T> {
    cap: usize,
    inner: Mutex<WriteQueueInner<T>>,
    /// Wakes the writer thread: frame available or queue closed.
    available: Condvar,
    /// Wakes producers: space freed or queue closed.
    space: Condvar,
}

struct WriteQueueInner<T> {
    queue: VecDeque<T>,
    open: bool,
}

impl<T> WriteQueue<T> {
    pub fn new(cap: usize) -> WriteQueue<T> {
        WriteQueue {
            cap: cap.max(1),
            inner: Mutex::new(WriteQueueInner { queue: VecDeque::new(), open: true }),
            available: Condvar::new(),
            space: Condvar::new(),
        }
    }

    /// Enqueue `item`, blocking while the queue is full for at most
    /// `stall`.  `Stalled` hands the item back once the budget is spent
    /// with the queue still full; `Closed` once the queue is closed.
    pub fn push(&self, item: T, stall: Duration) -> Result<(), PushError<T>> {
        let deadline = Instant::now() + stall;
        let mut g = self.inner.lock();
        while g.open && g.queue.len() >= self.cap {
            let now = Instant::now();
            if now >= deadline {
                return Err(PushError::Stalled(item));
            }
            let (guard, _timed_out) = self.space.wait_timeout(g, deadline - now);
            g = guard;
        }
        if !g.open {
            return Err(PushError::Closed(item));
        }
        g.queue.push_back(item);
        drop(g);
        self.available.notify_one();
        Ok(())
    }

    /// Enqueue past the bound, never stalling — reserved for terminal
    /// frames (one per request, so the overshoot is bounded by the
    /// requests in flight on the connection).  Only a closed queue
    /// refuses.
    pub fn push_unbounded(&self, item: T) -> Result<(), PushError<T>> {
        let mut g = self.inner.lock();
        if !g.open {
            return Err(PushError::Closed(item));
        }
        g.queue.push_back(item);
        drop(g);
        self.available.notify_one();
        Ok(())
    }

    /// Consumer side: block for the next frame; `None` once the queue is
    /// closed **and** drained (a graceful close flushes the backlog).
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock();
        loop {
            if let Some(item) = g.queue.pop_front() {
                drop(g);
                self.space.notify_one();
                return Some(item);
            }
            if !g.open {
                return None;
            }
            g = self.available.wait(g);
        }
    }

    /// Graceful close: no more frames will be accepted, but the writer
    /// still drains what is queued (push the terminal frames *before*
    /// closing).  Wakes every parked producer and the writer.
    pub fn close(&self) {
        let mut g = self.inner.lock();
        g.open = false;
        drop(g);
        self.available.notify_all();
        self.space.notify_all();
    }

    /// Abortive close for a dead connection: close *and* discard the
    /// backlog (nothing can be delivered), returning how many frames
    /// were dropped so the caller can count the delivery losses.
    pub fn abort(&self) -> usize {
        let mut g = self.inner.lock();
        g.open = false;
        let dropped = g.queue.len();
        g.queue.clear();
        drop(g);
        self.available.notify_all();
        self.space.notify_all();
        dropped
    }

    /// Queued frame count (diagnostics/tests).
    pub fn len(&self) -> usize {
        self.inner.lock().queue.len()
    }

    /// Whether the queue is empty (clippy pairing for [`WriteQueue::len`]).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Session-level cancellation marks: session -> instant of the cancel.
/// A request is cancelled iff its session was cancelled *at or after*
/// its arrival, so traffic submitted after a cancel is served normally —
/// the mark never has to be removed to reopen the session.
#[derive(Default)]
pub struct CancelRegistry {
    inner: Mutex<HashMap<String, Instant>>,
}

impl CancelRegistry {
    /// Mark `session` cancelled as of now.
    pub fn cancel(&self, session: &str) {
        self.cancel_at(session, Instant::now());
    }

    /// Mark `session` cancelled as of `at` (split out so unit and loom
    /// tests can pin timestamps instead of racing the clock).
    pub fn cancel_at(&self, session: &str, at: Instant) {
        let mut g = self.inner.lock();
        if g.len() >= 1024 {
            // bound the registry: marks older than any plausible queue
            // residency are dead weight (queued requests outlive them
            // only past their own deadline, where TimedOut sheds them)
            g.retain(|_, t| at.duration_since(*t) < Duration::from_secs(30));
        }
        g.insert(session.to_string(), at);
    }

    /// Was `session` cancelled at or after `arrived`?  Inclusive on
    /// purpose: a cancel and a submit carrying the *same* timestamp must
    /// shed the request — the cancel covers everything already in the
    /// pipeline at its instant.
    pub fn cancelled_since(&self, session: &str, arrived: Instant) -> bool {
        self.inner.lock().get(session).is_some_and(|t| *t >= arrived)
    }
}

/// Hard admission gate: atomically claim one slot of an at-most-`max`
/// in-flight budget tracked by `gauge`.  Increment-then-check: the slot
/// is claimed *before* the bound is tested and rolled back on rejection,
/// so two racing admitters can never both slip under the cap the way a
/// check-then-increment gate lets them (each reads `max - 1`, both
/// admit, gauge lands at `max + 1`).  Returns whether the caller owns a
/// slot; a `true` must eventually be paired with exactly one
/// [`release`].
pub fn try_admit(gauge: &AtomicU64, max: u64) -> bool {
    // ordering: SeqCst — the gauge synchronizes the admission gate with
    // drain()'s `draining`-flag store and zero-poll (one total order
    // across both), and the claim must be visible before the request is
    // handed over (a served request's decrement racing ahead of this
    // increment would underflow the gauge and wedge the gate)
    let prev = gauge.fetch_add(1, Ordering::SeqCst);
    if prev >= max {
        // ordering: SeqCst — pairs with the claim above; the rollback
        // must join the same total order the drain zero-poll reads
        gauge.fetch_sub(1, Ordering::SeqCst);
        return false;
    }
    true
}

/// Release one admission slot claimed by a successful [`try_admit`]
/// (called at terminal response delivery, or on an ingress hand-over
/// failure).
pub fn release(gauge: &AtomicU64) {
    // ordering: SeqCst — same total order as try_admit's claim, so
    // drain()'s `inflight == 0` poll cannot observe zero while a claimed
    // request is still unserved
    gauge.fetch_sub(1, Ordering::SeqCst);
}

/// Which scheduling lane formed a dispatch.  The continuous scheduler
/// keeps at most one `Prefill` and one `Decode` dispatch in flight at a
/// time (the TGI-style iteration model: the running batch advances one
/// step, then is reassembled); `Formed` marks ungated dispatches from
/// the legacy window/cap/barrier front-end and the drain path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchKind {
    /// Window/cap/barrier-closed batch, not iteration-gated.
    Formed,
    /// Waiting groups entering residency (one prefill step).
    Prefill,
    /// One decode iteration over resident slots.
    Decode,
}

/// Per-lane iteration gate: at most one `Prefill` and one `Decode`
/// dispatch may be in flight at once.  The scheduler loop is the only
/// claimer (single-threaded), so `claim` never races another claim; the
/// flags exist so *workers* finishing a dispatch (via [`IterToken`]
/// drop, on every path including panic unwind) reopen the lane and the
/// loop can observe completion without joining the worker.
#[derive(Default)]
pub struct IterGate {
    prefill: AtomicBool,
    decode: AtomicBool,
}

impl IterGate {
    pub fn new() -> IterGate {
        IterGate { prefill: AtomicBool::new(false), decode: AtomicBool::new(false) }
    }

    fn slot(&self, kind: BatchKind) -> Option<&AtomicBool> {
        match kind {
            BatchKind::Formed => None,
            BatchKind::Prefill => Some(&self.prefill),
            BatchKind::Decode => Some(&self.decode),
        }
    }

    /// Claim the lane for one dispatch.  `Formed` is ungated and always
    /// claims.  A `true` must be paired with exactly one
    /// [`IterGate::finish`] (normally via [`IterToken`] drop).
    pub fn claim(&self, kind: BatchKind) -> bool {
        match self.slot(kind) {
            None => true,
            // ordering: SeqCst — the claim joins one total order with
            // finish() so the single-threaded scheduler loop can never
            // observe a lane both free (inflight() false) and still
            // claimed by an unretired dispatch
            Some(flag) => {
                flag.compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst).is_ok()
            }
        }
    }

    /// Reopen the lane: the dispatch claimed for `kind` is fully retired
    /// (served, shed, or failed).  No-op for `Formed`.
    pub fn finish(&self, kind: BatchKind) {
        if let Some(flag) = self.slot(kind) {
            // ordering: SeqCst — pairs with claim(); the store must be
            // visible before any wake the finisher sends, or the loop
            // could wake, read the lane as busy, and park again
            flag.store(false, Ordering::SeqCst);
        }
    }

    /// Is a dispatch of `kind` still in flight?
    pub fn inflight(&self, kind: BatchKind) -> bool {
        match self.slot(kind) {
            None => false,
            // ordering: SeqCst — same total order as claim/finish
            Some(flag) => flag.load(Ordering::SeqCst),
        }
    }
}

/// Completion token attached to an iteration-gated dispatch: dropping it
/// — on delivery, shed, worker panic unwind, dead-pool hand-back, any
/// path — reopens the dispatch's gate lane and fires the best-effort
/// wake `nudge` (the scheduler loop's backstop `recv_timeout` covers a
/// lost nudge).  Finish-then-nudge order matters: the woken loop must
/// observe the lane already free.
pub struct IterToken {
    gate: Arc<IterGate>,
    kind: BatchKind,
    nudge: Option<Box<dyn Fn() + Send>>,
}

impl IterToken {
    pub fn new(
        gate: Arc<IterGate>,
        kind: BatchKind,
        nudge: Option<Box<dyn Fn() + Send>>,
    ) -> IterToken {
        IterToken { gate, kind, nudge }
    }
}

impl Drop for IterToken {
    fn drop(&mut self) {
        self.gate.finish(self.kind);
        if let Some(nudge) = &self.nudge {
            nudge();
        }
    }
}

/// Releases one session group's not-yet-released pins on drop, so a
/// panic anywhere in the serve path (e.g. a crashing backend) cannot
/// leak pins — a leaked pin would make the session permanently
/// unevictable under the byte budget.  One guard per session group of a
/// super-batch; the happy path releases each pin explicitly
/// ([`PinGuard::release_one`]) *before* the response is sent, so by the
/// time a caller observes its response the session is evictable again.
pub struct PinGuard<'a> {
    kv: &'a KvStore,
    session: String,
    remaining: usize,
}

impl<'a> PinGuard<'a> {
    /// Guard `remaining` pins of `session` held in `kv`.
    pub fn new(kv: &'a KvStore, session: String, remaining: usize) -> PinGuard<'a> {
        PinGuard { kv, session, remaining }
    }

    /// Release one guarded pin now (the happy path, before the reply is
    /// sent); the guard's drop covers whatever was not released.
    pub fn release_one(&mut self) {
        if self.remaining > 0 {
            self.remaining -= 1;
            self.kv.unpin(&self.session);
        }
    }
}

impl Drop for PinGuard<'_> {
    fn drop(&mut self) {
        for _ in 0..self.remaining {
            self.kv.unpin(&self.session);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::thread;
    use crate::sync::Arc;
    use crate::Mat;

    #[test]
    fn batch_queue_roundtrip_and_close() {
        let q: BatchQueue<u32> = BatchQueue::new(2, 1);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn push_fails_once_all_workers_exited() {
        let q: BatchQueue<u32> = BatchQueue::new(4, 1);
        q.push(7).unwrap();
        let residue = q.worker_exited();
        assert_eq!(residue, vec![7], "last worker out hands the queue back");
        assert_eq!(q.push(8), Err(8), "push to a dead pool is refused");
    }

    #[test]
    fn cancel_with_equal_timestamp_sheds_the_request() {
        // cancel-then-immediate-resubmit where both carry the *same*
        // Instant: the inclusive `>=` must shed the in-pipeline request
        // (the cancel covers its instant), while anything arriving even
        // one tick later is served normally
        let reg = CancelRegistry::default();
        let t = Instant::now();
        reg.cancel_at("s", t);
        assert!(reg.cancelled_since("s", t), "equal timestamps: cancelled");
        assert!(
            !reg.cancelled_since("s", t + Duration::from_nanos(1)),
            "a later resubmit reopens the session with no unmark needed"
        );
    }

    #[test]
    fn cancel_of_unknown_session_is_inert() {
        let reg = CancelRegistry::default();
        reg.cancel("ghost");
        assert!(!reg.cancelled_since("other", Instant::now() - Duration::from_secs(1)));
        // re-cancelling and re-checking the same unknown-to-the-server
        // session stays consistent: only "ghost" itself is marked
        assert!(reg.cancelled_since("ghost", Instant::now() - Duration::from_secs(1)));
    }

    #[test]
    fn cancel_registry_sweeps_stale_marks_at_capacity() {
        let reg = CancelRegistry::default();
        let old = Instant::now() - Duration::from_secs(60);
        for i in 0..1024 {
            reg.cancel_at(&format!("old-{i}"), old);
        }
        // the 1025th insert triggers the retention sweep; stale marks go
        reg.cancel("fresh");
        assert!(reg.cancelled_since("fresh", old));
        assert!(!reg.cancelled_since("old-0", old), "stale mark swept");
    }

    #[test]
    fn admission_gate_claims_and_rolls_back() {
        let gauge = AtomicU64::new(0);
        assert!(try_admit(&gauge, 2));
        assert!(try_admit(&gauge, 2));
        assert!(!try_admit(&gauge, 2), "cap reached");
        // ordering: SeqCst — test-side read of the gauge's total order
        assert_eq!(gauge.load(Ordering::SeqCst), 2, "rejection rolled back its claim");
        release(&gauge);
        assert!(try_admit(&gauge, 2), "released slot is reclaimable");
    }

    #[test]
    fn admission_gate_is_a_hard_cap_under_contention() {
        let gauge = Arc::new(AtomicU64::new(0));
        let peak = Arc::new(AtomicU64::new(0));
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let gauge = gauge.clone();
                let peak = peak.clone();
                thread::spawn(move || {
                    for _ in 0..200 {
                        if try_admit(&gauge, 3) {
                            // ordering: SeqCst — the admitted count and
                            // its peak tracking must observe the same
                            // total order as the gate itself
                            let now = gauge.load(Ordering::SeqCst);
                            peak.fetch_max(now, Ordering::SeqCst);
                            release(&gauge);
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        // ordering: SeqCst — post-join reads of the gate's total order
        assert_eq!(gauge.load(Ordering::SeqCst), 0, "every claim released");
        assert!(peak.load(Ordering::SeqCst) <= 3, "cap never overrun");
    }

    #[test]
    fn iter_gate_serializes_each_lane_independently() {
        let gate = IterGate::new();
        assert!(!gate.inflight(BatchKind::Decode));
        assert!(gate.claim(BatchKind::Decode), "free lane claims");
        assert!(!gate.claim(BatchKind::Decode), "lane busy until finished");
        assert!(gate.claim(BatchKind::Prefill), "lanes are independent");
        assert!(gate.inflight(BatchKind::Decode) && gate.inflight(BatchKind::Prefill));
        gate.finish(BatchKind::Decode);
        assert!(!gate.inflight(BatchKind::Decode));
        assert!(gate.inflight(BatchKind::Prefill), "finishing one lane leaves the other");
        assert!(gate.claim(BatchKind::Decode), "finished lane reclaims");
        // Formed dispatches are ungated: always claimable, never in flight
        assert!(gate.claim(BatchKind::Formed));
        assert!(gate.claim(BatchKind::Formed));
        assert!(!gate.inflight(BatchKind::Formed));
        gate.finish(BatchKind::Formed); // no-op
    }

    #[test]
    fn iter_token_drop_reopens_lane_then_nudges() {
        let gate = Arc::new(IterGate::new());
        let nudged = Arc::new(AtomicU64::new(0));
        assert!(gate.claim(BatchKind::Prefill));
        let token = {
            let (gate2, nudged) = (gate.clone(), nudged.clone());
            IterToken::new(
                gate.clone(),
                BatchKind::Prefill,
                Some(Box::new(move || {
                    assert!(
                        !gate2.inflight(BatchKind::Prefill),
                        "nudge must observe the lane already reopened"
                    );
                    // ordering: SeqCst — test-side tally in the gate's order
                    nudged.fetch_add(1, Ordering::SeqCst);
                })),
            )
        };
        assert!(gate.inflight(BatchKind::Prefill), "token held: lane busy");
        drop(token);
        assert!(!gate.inflight(BatchKind::Prefill), "drop reopened the lane");
        // ordering: SeqCst — post-drop read of the tally
        assert_eq!(nudged.load(Ordering::SeqCst), 1, "nudge fired exactly once");
        // a token without a nudge still reopens its lane
        assert!(gate.claim(BatchKind::Decode));
        drop(IterToken::new(gate.clone(), BatchKind::Decode, None));
        assert!(!gate.inflight(BatchKind::Decode));
    }

    #[test]
    fn write_queue_bounds_producers_and_flushes_on_graceful_close() {
        let q: WriteQueue<u32> = WriteQueue::new(2);
        q.push(1, Duration::from_secs(1)).unwrap();
        q.push(2, Duration::from_secs(1)).unwrap();
        // full queue + tiny stall budget: the push hands the frame back
        let t0 = Instant::now();
        assert_eq!(q.push(3, Duration::from_millis(20)), Err(PushError::Stalled(3)));
        assert!(t0.elapsed() >= Duration::from_millis(20), "stall budget is honoured");
        // terminal frames bypass the bound
        q.push_unbounded(99).unwrap();
        assert_eq!(q.len(), 3);
        // graceful close still drains the backlog in order
        q.close();
        assert_eq!(q.push(4, Duration::from_secs(1)), Err(PushError::Closed(4)));
        assert_eq!(q.push_unbounded(5), Err(PushError::Closed(5)));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(99));
        assert_eq!(q.pop(), None, "closed and drained");
    }

    #[test]
    fn write_queue_push_wakes_when_the_writer_frees_space() {
        let q: Arc<WriteQueue<u32>> = Arc::new(WriteQueue::new(1));
        q.push(1, Duration::from_secs(1)).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push(2, Duration::from_secs(30)));
        // the producer parks on the full queue; popping frees its slot
        thread::sleep(Duration::from_millis(10));
        assert_eq!(q.pop(), Some(1));
        h.join().expect("producer exits").expect("freed slot admits the parked push");
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn write_queue_abort_discards_the_backlog_and_reports_it() {
        let q: WriteQueue<u32> = WriteQueue::new(8);
        q.push(1, Duration::from_secs(1)).unwrap();
        q.push(2, Duration::from_secs(1)).unwrap();
        assert_eq!(q.abort(), 2, "both undelivered frames are counted");
        assert_eq!(q.pop(), None, "nothing to drain after an abort");
        assert_eq!(q.push(3, Duration::from_secs(1)), Err(PushError::Closed(3)));
        assert!(q.is_empty());
    }

    #[test]
    fn pin_guard_releases_remainder_on_drop() {
        let kv = KvStore::new(4, 2, 4);
        kv.put("s", Mat::zeros(4, 2), Mat::zeros(4, 2)).unwrap();
        assert!(kv.pin("s"));
        assert!(kv.pin("s"));
        {
            let mut g = PinGuard::new(&kv, "s".into(), 2);
            g.release_one();
            assert_eq!(kv.pinned_sessions(), 1, "one pin still guarded");
            // guard dropped here with one pin unreleased (panic analogue)
        }
        assert_eq!(kv.pinned_sessions(), 0, "drop released the remainder");
    }
}

//! The serving coordinator — the L3 system a deployment would run around
//! the accelerator: bounded ingress with backpressure, a dynamic batcher
//! (vLLM-router-style), session-keyed KV buffer management, worker threads
//! owning execution backends (simulated accelerator or PJRT executable),
//! and metrics.
//!
//! Built on std threads + channels (tokio is unavailable offline —
//! DESIGN.md §9); the architecture is the same: one ingress queue, a
//! batch-forming stage, N workers, per-request completion channels.

pub mod batcher;
pub mod backend;
pub mod kvstore;
pub mod metrics;
pub mod request;
pub mod server;

pub use backend::{prepare_entry, Backend, BackendFactory, PjrtBackend, SimBackend};
pub use kvstore::{KvEntry, KvStore};
pub use metrics::Metrics;
pub use request::{AttentionRequest, AttentionResponse};
pub use server::Server;

//! The serving coordinator — the L3 system a deployment would run around
//! the accelerator: bounded ingress with backpressure, a **continuous
//! scheduler** (TGI-style iteration-level batching over a slot table of
//! resident decode sessions, with the window/barrier batcher surviving
//! as the group-assembly front-end), session-keyed KV buffer
//! management, worker threads owning plan-based execution backends
//! (simulated accelerator or PJRT executable), and metrics.
//!
//! Built on std threads + channels (tokio is unavailable offline —
//! DESIGN.md §9); the architecture is the same: one ingress queue, a
//! scheduling stage, N workers, per-request completion channels.
//! A dispatch may span many sessions ([`batcher::Batch`]); the worker
//! answers all of them through one [`backend::Backend::compute_plan`]
//! call whose outputs are bit-identical to serving each session alone.
//!
//! ## Continuous batching
//!
//! A session's *first* traffic takes the classic path: the
//! [`batcher::Batcher`] forms its per-session group inside the batching
//! window, and the closed group enters the [`scheduler::Scheduler`]'s
//! waiting queue.  Admission (a `Prefill` dispatch, governed by
//! `max_batch_prefill_tokens` / `max_batch_total_tokens` /
//! `waiting_served_ratio` / `max_waiting_iters`) makes the session a
//! **resident slot**; from then on its decode traffic is routed
//! straight into the slot and served by per-iteration `Decode`
//! dispatches assembled from all resident slots — an N-token decode
//! costs one batcher admission instead of N round-trips, and a long
//! prefill never stalls resident sessions' token cadence.  Sessions
//! join and leave the running batch between iterations (cancellation
//! and handle drops retire slots at the next boundary); outputs stay
//! bit-identical to solo serving (`rust/tests/continuous_batching.rs`).
//!
//! ## Decode/append protocol
//!
//! Autoregressive serving interleaves two request kinds per session
//! ([`request::Payload`]): `Query` (attend over the resident KV) and
//! `Append` (make the decode step's new K/V rows resident).  An append
//! is a per-session barrier: the batcher closes the session's pending
//! queries and ships them with the append *last*, so a worker serves
//! queries against the pre-append KV and then applies the write —
//! arrival order is execution order within a batch.  Across batches,
//! ordering is what the client enforces by waiting for the append
//! acknowledgement before submitting the next query (the natural shape
//! of a decode loop: `append(k_t, v_t)` -> `call(q_t)`).  The write
//! itself is [`KvStore::append`]: only the new rows are BF16-rounded
//! and log-converted; resident rows are never touched, so per-step cost
//! tracks the new tokens, not the sequence length.
//!
//! ## Robustness
//!
//! Every request carries an absolute deadline and every terminal
//! outcome is a typed [`request::ServeError`].  Admission is bounded
//! ([`Overloaded`](request::ServeError::Overloaded) past
//! `max_pending_requests`), expired requests are shed at group-close
//! and re-checked at dispatch ([`TimedOut`](request::ServeError::TimedOut)),
//! sessions can be cancelled mid-flight ([`Server::cancel`]), transient
//! backend faults ([`backend::TransientFault`]) are retried with
//! backoff, a watchdog respawns panicked worker backends within a
//! budget, and [`Server::drain`] stops admissions and serves what is in
//! flight until a deadline, reporting what it served / force-failed /
//! evicted ([`server::DrainReport`]).  The [`chaos`] module provides a
//! seeded fault-injection wrapper used by the soak tests to prove all
//! of it.
//!
//! ## Streaming ingress
//!
//! [`ingress`] puts a framed-socket front end over the server: a
//! length-prefixed binary protocol (hand-rolled, no new deps — see
//! `rust/EXPERIMENTS.md` §Streaming for the wire format), door
//! validation that maps shape/geometry rejections and every
//! [`request::ServeError`] 1:1 onto typed error frames, per-connection
//! reader/driver/writer threads, and per-token streaming: each decode
//! step's output is pushed as its own frame when the scheduler's decode
//! iteration completes, not buffered until the stream ends.  Writes go
//! through a bounded [`protocol::WriteQueue`] — a slow consumer first
//! blocks its own stream's routing, then past the configured stall
//! budget is shed with [`Cancelled`](request::ServeError::Cancelled)
//! and its session's KV evicted, so one stalled client never perturbs
//! other sessions' token cadence.  [`ingress::Ingress::drain`] closes
//! the door, lets in-flight streams finish their terminal frames, and
//! hands the remainder to [`Server::drain`].
//!
//! ## Verification
//!
//! The hand-rolled protocols (dispatch queue, cancellation registry,
//! pin guard, admission gate) live in [`protocol`], built on the
//! [`crate::sync`] facade so the loom suite (`tests/loom_models.rs`,
//! `RUSTFLAGS="--cfg loom"`) model-checks the exact shipped
//! implementations; `cargo run -p xtask -- lint` enforces the facade,
//! the no-unwrap rule on serve paths, per-site atomic-ordering comments,
//! and the KvStore → Metrics → queue lock order (see
//! `rust/EXPERIMENTS.md` §Verification).

pub mod batcher;
pub mod backend;
pub mod chaos;
pub mod ingress;
pub mod kvstore;
pub mod metrics;
pub mod protocol;
pub mod request;
pub mod scheduler;
pub mod server;

pub use backend::{prepare_entry, Backend, BackendFactory, PjrtBackend, SimBackend, TransientFault};
pub use chaos::{ChaosBackend, ChaosConfig, ConnChaos, ConnFate};
pub use ingress::{Client, Frame, Ingress, IngressDrainReport, StreamEvent, StreamStep};
pub use kvstore::{KvEntry, KvStore};
pub use metrics::Metrics;
pub use protocol::{PushError, WriteQueue};
pub use request::{AttentionRequest, AttentionResponse, Payload, ServeError};
pub use scheduler::{Scheduler, SchedulerCfg};
pub use server::{DrainReport, ResponseHandle, Server};

//! Continuous-batching scheduler: a **resident running batch** whose
//! decode sessions stay in flight across iterations while new work joins
//! and leaves between steps (the TGI `router/src/infer.rs` iteration
//! model, adapted to this crate's thread-per-stage serving loop).
//!
//! The window/barrier [`super::batcher::Batcher`] survives only as the
//! group-assembly front-end: it forms per-session groups exactly as
//! before, but closed groups no longer dispatch directly — they enter
//! the scheduler's **waiting queue**, and admission moves them into the
//! **slot table** of resident sessions.  From then on the session's
//! decode traffic is routed straight into its slot
//! ([`Scheduler::route`]) and served by iteration-assembled `Decode`
//! dispatches: an N-token decode pays **one** batcher admission, not N.
//!
//! Two independent dispatch lanes (serialized per lane by
//! [`IterGate`], at most one dispatch of each kind in flight):
//!
//! * **Prefill** ([`BatchKind::Prefill`]): waiting groups entering
//!   residency, packed under `max_batch_prefill_tokens` and the
//!   running-batch `max_batch_total_tokens` budget.  Admission is
//!   deferred while decode has priority — until the waiting queue
//!   reaches `ceil(waiting_served_ratio × running)` groups or the front
//!   group has aged `max_waiting_iters` decode iterations (the TGI
//!   starvation override) — so a long prefill never steals the token
//!   cadence of resident sessions, and a starved prefill still lands.
//! * **Decode** ([`BatchKind::Decode`]): one iteration's ragged
//!   multi-session grid, assembled from resident slots in rotation
//!   order (round-robin fairness), up to `max_batch` requests per slot
//!   and `max_total_batch` total.  Dispatched through the same
//!   `compute_plan` / fused-grid path as before — outputs are
//!   bit-identical to solo serving (pinned by
//!   `rust/tests/continuous_batching.rs`).
//!
//! **Ordering.**  Within a session, arrival order is execution order
//! (the append-barrier contract).  The scheduler preserves it by
//! construction: a session's requests flow through exactly one channel
//! at a time — while the session has batcher-pending or waiting-queue
//! state, new arrivals keep flowing through the batcher behind it
//! ([`Scheduler::route`] refuses them); only a quiescent resident slot
//! accepts direct routing.  Slots admitted by a prefill are excluded
//! from decode assembly until that prefill's gate lane reopens, so a
//! session is never split across concurrently-executing dispatches.
//!
//! **Residency is routing state, not a KV pin.**  Slots hold *no* idle
//! pins — per-request pins work exactly as before (taken at ingress for
//! resident sessions, released at delivery), so an idle resident slot
//! leaves `KvStore::pinned_sessions() == 0` and the byte-budget LRU
//! free to evict cold sessions.  Cancellation retires the slot at the
//! next iteration boundary and `KvStore::evict` frees the bytes
//! immediately (in-flight computes hold `Arc` snapshots).
//!
//! **Prefix sharing changes none of this.**  A pin (or a slot) covers
//! one *session*; the chunks under it may be shared with siblings or
//! forked children, but chunk lifetime is the store's refcount
//! registry's problem — evicting a pinned-out cold parent frees only
//! bytes no other resident session references, and a forked child
//! enters the slot table exactly like any other session the first time
//! a request routes to it (`Server::fork` touches only the KV store;
//! there is no scheduler-side fork state to reconcile).
//!
//! **Deadlines.**  Queued requests can sit past their deadline while
//! parked — a waiting group deferred by the total-token budget against a
//! persistently busy running batch never reaches a dispatch-side shed
//! point.  The scheduler therefore maintains a lower bound on the
//! earliest queued deadline ([`Scheduler::next_request_deadline`]); the
//! serving loop folds it into its wake timer and sweeps expired or
//! cancelled requests out via [`Scheduler::remove_matching`] on every
//! timed wake (not only on a cancel nudge), so a deferred request always
//! gets its terminal `TimedOut` response and releases its ingress pin.
//!
//! The scheduler itself is single-threaded state owned by the serving
//! loop — no internal locks; every method is a plain call, which keeps
//! the whole policy synchronously unit-testable.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use crate::sync::atomic::Ordering;
use crate::sync::Arc;

use super::batcher::{Batch, SessionBatch};
use super::kvstore::KvStore;
use super::metrics::Metrics;
use super::protocol::{BatchKind, IterGate};
use super::request::AttentionRequest;

/// Slot-table bound: beyond this many resident sessions, admitting a new
/// one first retires the least-recently-active *idle* slot, so the table
/// cannot grow without bound under session-churn traffic (busy slots are
/// never retired; in-flight work is already bounded by admission).
const MAX_SLOTS: usize = 1024;

/// Scheduler policy knobs (resolved from
/// [`crate::config::CoordinatorConfig`]).
#[derive(Clone, Debug)]
pub struct SchedulerCfg {
    /// Max requests one slot contributes per decode iteration.
    pub max_batch: usize,
    /// Max total requests per assembled dispatch (either lane).
    pub max_total_batch: usize,
    /// Max tokens one prefill dispatch may admit (0 = unlimited).
    pub max_batch_prefill_tokens: usize,
    /// Max resident tokens of the running batch (0 = unlimited).
    pub max_batch_total_tokens: usize,
    /// Decode priority: prefill waits until `waiting >= ceil(ratio *
    /// running)` (an empty running batch always admits).
    pub waiting_served_ratio: f64,
    /// Starvation override: admit once the front waiting group has aged
    /// this many decode iterations regardless of the ratio.
    pub max_waiting_iters: u64,
}

impl Default for SchedulerCfg {
    fn default() -> SchedulerCfg {
        SchedulerCfg {
            max_batch: 16,
            max_total_batch: 256,
            max_batch_prefill_tokens: 0,
            max_batch_total_tokens: 0,
            waiting_served_ratio: 1.2,
            max_waiting_iters: 4,
        }
    }
}

/// One resident decode session's scheduling state (no KV pin — see the
/// module docs).
struct Slot {
    /// Requests routed directly into the slot, arrival order.
    pending: Vec<AttentionRequest>,
    /// Last admission/routing/assembly touch — the idle-retirement LRU
    /// stamp.
    last_active: Instant,
    /// Previous decode iteration that carried this slot's work; the
    /// distance to the next one is the inter-token gap span.
    last_decode_at: Option<Instant>,
    /// Admitted by a prefill dispatch that has not retired yet: excluded
    /// from decode assembly until the prefill lane reopens, so one
    /// session never runs in two concurrent dispatches.
    in_prefill: bool,
    /// Contributed requests to the decode dispatch currently in flight
    /// (set at assembly, cleared when the decode lane reopens).  Such a
    /// slot looks idle — its pending drained into the dispatch — but
    /// retiring it would let the session's next request re-admit through
    /// the independent prefill lane and run concurrently with the
    /// still-executing decode, so `retire_idle_lru` must skip it.
    in_decode: bool,
}

/// A closed front-end group parked for admission.
struct WaitingGroup {
    group: SessionBatch,
    /// Token charge against `max_batch_prefill_tokens`.
    prefill_tokens: usize,
    /// Decode-iteration stamp at enqueue (starvation aging).
    enqueued_iter: u64,
}

/// The continuous scheduler: slot table + waiting queue + admission
/// policy.  Owned (unshared) by the serving loop; see the module docs.
pub struct Scheduler {
    cfg: SchedulerCfg,
    slots: HashMap<String, Slot>,
    /// Round-robin order over resident slots (each session appears at
    /// most once; entries for retired slots are dropped lazily).
    rotation: VecDeque<String>,
    waiting: VecDeque<WaitingGroup>,
    /// Decode iterations assembled so far (waiting-group aging clock).
    iter: u64,
    /// Lower bound on the earliest deadline across all queued requests
    /// (waiting groups + slot pendings): tightened on every insert,
    /// recomputed exactly by [`Scheduler::remove_matching`] (the sweep
    /// the serving loop schedules at this instant).  A stale-low bound
    /// only costs one spurious sweep; it is never later than the true
    /// minimum, so a parked request can never outlive its deadline
    /// unobserved — even when token-budget admission defers it
    /// indefinitely.
    min_deadline: Option<Instant>,
    kv: Arc<KvStore>,
    metrics: Arc<Metrics>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerCfg, kv: Arc<KvStore>, metrics: Arc<Metrics>) -> Scheduler {
        Scheduler {
            cfg: SchedulerCfg {
                max_batch: cfg.max_batch.max(1),
                max_total_batch: cfg.max_total_batch.max(cfg.max_batch.max(1)),
                ..cfg
            },
            slots: HashMap::new(),
            rotation: VecDeque::new(),
            waiting: VecDeque::new(),
            iter: 0,
            min_deadline: None,
            kv,
            metrics,
        }
    }

    /// Earliest deadline across queued requests (waiting + slots), as a
    /// lower bound (see the `min_deadline` field docs).  The serving
    /// loop folds this into its wake timer and runs
    /// [`Scheduler::remove_matching`] with the shed verdict once it
    /// passes, so deferred/parked requests still expire on time.
    pub fn next_request_deadline(&self) -> Option<Instant> {
        self.min_deadline
    }

    fn note_deadline(&mut self, d: Instant) {
        self.min_deadline = Some(self.min_deadline.map_or(d, |m| m.min(d)));
    }

    /// Recompute `min_deadline` exactly from the remaining queued
    /// requests (O(pending); called only at sweep points, not per
    /// message).
    fn refresh_deadline(&mut self) {
        self.min_deadline = self
            .waiting
            .iter()
            .flat_map(|w| w.group.requests.iter())
            .chain(self.slots.values().flat_map(|s| s.pending.iter()))
            .map(|r| r.deadline)
            .min();
    }

    /// Does `session` hold a resident slot?
    pub fn is_resident(&self, session: &str) -> bool {
        self.slots.contains_key(session)
    }

    fn waiting_has(&self, session: &str) -> bool {
        self.waiting.iter().any(|w| w.group.session == session)
    }

    /// Try to route a request straight into its resident slot, bypassing
    /// the batcher.  Returns the request back when it must take the
    /// front-end path instead: session not resident, or the session
    /// still has earlier traffic in flight through the front end
    /// (`front_end_pending`, i.e. batcher-pending, or a waiting group) —
    /// routing around it would reorder the session's arrival order.
    pub fn route(
        &mut self,
        req: AttentionRequest,
        now: Instant,
        front_end_pending: bool,
    ) -> Option<AttentionRequest> {
        if front_end_pending || self.waiting_has(&req.session) {
            return Some(req);
        }
        let deadline = req.deadline;
        match self.slots.get_mut(&req.session) {
            Some(slot) => {
                slot.pending.push(req);
                slot.last_active = now;
                // ordering: Relaxed — statistical counter
                self.metrics.slot_hits.fetch_add(1, Ordering::Relaxed);
            }
            None => return Some(req),
        }
        self.note_deadline(deadline);
        None
    }

    /// Park a front-end-closed batch's groups for admission.  A group
    /// whose session is resident with no waiting-queue state ahead of it
    /// extends the slot directly (order-safe: later arrivals were
    /// refused direct routing while this group was forming).
    pub fn enqueue_closed(&mut self, batch: Batch, now: Instant) {
        for g in batch.groups {
            if let Some(d) = g.requests.iter().map(|r| r.deadline).min() {
                self.note_deadline(d);
            }
            let resident_and_clear =
                self.slots.contains_key(&g.session) && !self.waiting_has(&g.session);
            if resident_and_clear {
                if let Some(slot) = self.slots.get_mut(&g.session) {
                    // ordering: Relaxed — statistical counter
                    self.metrics.slot_hits.fetch_add(g.requests.len() as u64, Ordering::Relaxed);
                    slot.pending.extend(g.requests);
                    slot.last_active = now;
                    continue;
                }
            }
            let prefill_tokens = g.requests.iter().map(AttentionRequest::token_cost).sum();
            self.waiting.push_back(WaitingGroup {
                group: g,
                prefill_tokens,
                enqueued_iter: self.iter,
            });
        }
    }

    /// Assemble this iteration's dispatches: at most one `Prefill` and
    /// one `Decode` batch, only for lanes the gate reports free.  The
    /// caller (the serving loop, the gate's only claimer) claims the
    /// lane and attaches the [`super::protocol::IterToken`] before
    /// emitting each returned batch.
    pub fn dispatch(&mut self, now: Instant, gate: &IterGate) -> Vec<Batch> {
        let prefill_free = !gate.inflight(BatchKind::Prefill);
        if prefill_free {
            // iteration boundary: the previously admitted prefill (if
            // any) has fully retired, so its slots become decodable
            for slot in self.slots.values_mut() {
                slot.in_prefill = false;
            }
        }
        if !gate.inflight(BatchKind::Decode) {
            // the previous decode dispatch (if any) has fully retired:
            // its slots become genuinely idle (retirable) again.
            // Cleared before prefill assembly so admission's LRU
            // retirement sees accurate flags.
            for slot in self.slots.values_mut() {
                slot.in_decode = false;
            }
        }
        let mut out = Vec::new();
        if prefill_free && self.prefill_due() {
            if let Some(b) = self.assemble_prefill(now) {
                out.push(b);
            }
        }
        if !gate.inflight(BatchKind::Decode) {
            if let Some(b) = self.assemble_decode(now) {
                out.push(b);
            }
        }
        out
    }

    /// Decode-priority gate: is it time to pause decode and admit?
    fn prefill_due(&self) -> bool {
        if self.waiting.is_empty() {
            return false;
        }
        let running =
            self.slots.values().filter(|s| !s.pending.is_empty() || s.in_prefill).count();
        if running == 0 {
            return true;
        }
        let need = (self.cfg.waiting_served_ratio * running as f64).ceil().max(1.0) as usize;
        if self.waiting.len() >= need {
            return true;
        }
        self.waiting
            .front()
            .is_some_and(|w| self.iter.saturating_sub(w.enqueued_iter) >= self.cfg.max_waiting_iters)
    }

    /// Pack waiting groups (FIFO) under the prefill-token, total-token
    /// and total-request budgets into one `Prefill` dispatch, admitting
    /// their sessions into the slot table.
    fn assemble_prefill(&mut self, now: Instant) -> Option<Batch> {
        let mut groups: Vec<SessionBatch> = Vec::new();
        let mut tokens = 0usize;
        let mut reqs = 0usize;
        loop {
            let Some(front) = self.waiting.front() else { break };
            let t = front.prefill_tokens;
            let n = front.group.requests.len();
            if !groups.is_empty() {
                if self.cfg.max_batch_prefill_tokens > 0
                    && tokens + t > self.cfg.max_batch_prefill_tokens
                {
                    break; // budget full; the rest waits for the next admission
                }
                if reqs + n > self.cfg.max_total_batch {
                    break;
                }
            }
            let session = front.group.session.clone();
            if !self.admit_total_tokens(&session, t) {
                // running batch is token-full and nothing idle to
                // retire: head-of-line waits for decode to drain
                break;
            }
            let Some(w) = self.waiting.pop_front() else { break };
            tokens += t;
            reqs += n;
            self.admit_slot(&session, now);
            match groups.iter_mut().find(|g| g.session == session) {
                // two waiting groups of one session admitted together
                // merge FIFO — arrival order is preserved
                Some(g) => g.requests.extend(w.group.requests),
                None => groups.push(w.group),
            }
        }
        if groups.is_empty() {
            return None;
        }
        // ordering: Relaxed — statistical counter
        self.metrics.prefill_iters.fetch_add(1, Ordering::Relaxed);
        Some(Batch { groups, kind: BatchKind::Prefill, done: None })
    }

    /// Running-batch token budget: can `incoming_tokens` for `session`
    /// join?  Retires least-recently-active *idle* slots to make room;
    /// refuses (group stays waiting) when only busy slots remain.
    fn admit_total_tokens(&mut self, session: &str, incoming_tokens: usize) -> bool {
        if self.cfg.max_batch_total_tokens == 0 {
            return true;
        }
        loop {
            let resident: usize = self
                .slots
                .keys()
                .map(|s| self.kv.session_rows(s).unwrap_or(0))
                .sum();
            let incoming_resident = if self.slots.contains_key(session) {
                0 // already counted in the resident sum
            } else {
                self.kv.session_rows(session).unwrap_or(0)
            };
            if resident + incoming_resident + incoming_tokens <= self.cfg.max_batch_total_tokens {
                return true;
            }
            if !self.retire_idle_lru(Some(session)) {
                return false;
            }
        }
    }

    /// Retire the least-recently-active idle slot (no pending work, not
    /// mid-prefill, not feeding the in-flight decode dispatch),
    /// excluding `keep`.  Returns whether one was retired.
    fn retire_idle_lru(&mut self, keep: Option<&str>) -> bool {
        let victim = self
            .slots
            .iter()
            .filter(|(name, s)| {
                s.pending.is_empty()
                    && !s.in_prefill
                    && !s.in_decode
                    && keep != Some(name.as_str())
            })
            .min_by_key(|(_, s)| s.last_active)
            .map(|(name, _)| name.clone());
        match victim {
            Some(name) => {
                self.slots.remove(&name);
                true
            }
            None => false,
        }
    }

    /// Create (or re-touch) the slot for an admitted session, marked
    /// `in_prefill` until the admitting dispatch retires.
    fn admit_slot(&mut self, session: &str, now: Instant) {
        if let Some(slot) = self.slots.get_mut(session) {
            slot.in_prefill = true;
            slot.last_active = now;
            return;
        }
        if self.slots.len() >= MAX_SLOTS {
            // bound the table; if nothing is idle the table grows past
            // the soft cap (in-flight work is bounded by admission)
            self.retire_idle_lru(None);
        }
        self.rotation.retain(|s| s != session); // drop any stale entry
        self.rotation.push_back(session.to_string());
        self.slots.insert(
            session.to_string(),
            Slot {
                pending: Vec::new(),
                last_active: now,
                last_decode_at: None,
                in_prefill: true,
                in_decode: false,
            },
        );
        // ordering: Relaxed — statistical counter (the acceptance test
        // reads it after joining the serving threads)
        self.metrics.batcher_admissions.fetch_add(1, Ordering::Relaxed);
    }

    /// Assemble one decode iteration: resident slots with pending work,
    /// rotation (round-robin) order, `max_batch` per slot, capped at
    /// `max_total_batch` total.
    fn assemble_decode(&mut self, now: Instant) -> Option<Batch> {
        let mut groups: Vec<SessionBatch> = Vec::new();
        let mut total = 0usize;
        let mut capped = false;
        let rot_len = self.rotation.len();
        for _ in 0..rot_len {
            if total >= self.cfg.max_total_batch {
                capped = true;
                break;
            }
            let Some(session) = self.rotation.pop_front() else { break };
            if !self.slots.contains_key(&session) {
                continue; // stale entry for a retired slot: drop it
            }
            self.rotation.push_back(session.clone());
            let max_batch = self.cfg.max_batch;
            let room = self.cfg.max_total_batch - total;
            let Some(slot) = self.slots.get_mut(&session) else { continue };
            if slot.in_prefill || slot.pending.is_empty() {
                continue;
            }
            let take = slot.pending.len().min(max_batch).min(room);
            let requests: Vec<AttentionRequest> = slot.pending.drain(..take).collect();
            slot.in_decode = true;
            if let Some(prev) = slot.last_decode_at {
                self.metrics.observe_decode_gap(now.duration_since(prev).as_secs_f64() * 1e6);
            }
            slot.last_decode_at = Some(now);
            slot.last_active = now;
            total += requests.len();
            groups.push(SessionBatch { session, requests });
        }
        if capped {
            // the early break already left the first unserved slot at
            // the rotation front for the next iteration
        } else if let Some(front) = self.rotation.pop_front() {
            // full scan: advance the start by one so a slot capped at
            // `max_batch` cannot permanently shadow the slots behind it
            self.rotation.push_back(front);
        }
        if groups.is_empty() {
            return None;
        }
        self.iter += 1;
        // ordering: Relaxed — statistical counter
        self.metrics.decode_iters.fetch_add(1, Ordering::Relaxed);
        Some(Batch { groups, kind: BatchKind::Decode, done: None })
    }

    /// Remove every queued request matched by `pred` from the waiting
    /// queue and the slot table — the cancellation / deadline sweep.
    /// Emptied waiting groups are dropped (their admission never
    /// happens); emptied slots stay resident (routing state).
    pub fn remove_matching(
        &mut self,
        mut pred: impl FnMut(&AttentionRequest) -> bool,
    ) -> Vec<AttentionRequest> {
        let mut removed = Vec::new();
        let mut sieve = |reqs: &mut Vec<AttentionRequest>| {
            let mut kept = Vec::with_capacity(reqs.len());
            for r in reqs.drain(..) {
                if pred(&r) {
                    removed.push(r);
                } else {
                    kept.push(r);
                }
            }
            *reqs = kept;
        };
        for w in self.waiting.iter_mut() {
            sieve(&mut w.group.requests);
            w.prefill_tokens =
                w.group.requests.iter().map(AttentionRequest::token_cost).sum();
        }
        self.waiting.retain(|w| !w.group.requests.is_empty());
        for slot in self.slots.values_mut() {
            sieve(&mut slot.pending);
        }
        // sweep point: re-tighten the deadline bound exactly (dispatch
        // assembly can leave it stale-low, which schedules one spurious
        // sweep — corrected here)
        self.refresh_deadline();
        removed
    }

    /// Evict a session's resident slot (cancellation path: the serving
    /// loop calls this at the iteration boundary where it processes the
    /// cancel).  Returns the slot's still-pending requests for the
    /// caller to fail; a dispatch already in flight is unaffected (it
    /// holds its own KV snapshot).
    pub fn retire(&mut self, session: &str) -> Vec<AttentionRequest> {
        self.rotation.retain(|s| s != session);
        let pending = self.slots.remove(session).map(|s| s.pending).unwrap_or_default();
        if !pending.is_empty() {
            self.refresh_deadline();
        }
        pending
    }

    /// Flush everything for shutdown: waiting groups and slot pendings
    /// packed into ungated `Formed` batches (the drain path serves or
    /// sheds them; residency ends).  Every retired slot is tallied into
    /// `sessions_evicted` so [`crate::coordinator::DrainReport`] can
    /// account for the residencies the teardown released.
    pub fn drain_all(&mut self) -> Vec<Batch> {
        // ordering: Relaxed — statistical counter; the drain reads it
        // after joining the serving threads
        self.metrics.sessions_evicted.fetch_add(self.slots.len() as u64, Ordering::Relaxed);
        let mut groups: Vec<SessionBatch> = Vec::new();
        for w in self.waiting.drain(..) {
            groups.push(w.group);
        }
        let mut slots: Vec<(String, Slot)> = self.slots.drain().collect();
        slots.sort_by_key(|(_, s)| s.last_active);
        for (session, slot) in slots {
            if !slot.pending.is_empty() {
                groups.push(SessionBatch { session, requests: slot.pending });
            }
        }
        self.rotation.clear();
        self.min_deadline = None;
        let mut out: Vec<Batch> = Vec::new();
        let mut cur: Vec<SessionBatch> = Vec::new();
        let mut cur_total = 0usize;
        for g in groups {
            if !cur.is_empty() && cur_total + g.requests.len() > self.cfg.max_total_batch {
                out.push(Batch::formed(std::mem::take(&mut cur)));
                cur_total = 0;
            }
            cur_total += g.requests.len();
            cur.push(g);
        }
        if !cur.is_empty() {
            out.push(Batch::formed(cur));
        }
        out
    }

    /// Is there any queued work (waiting groups or slot pendings)?
    pub fn has_backlog(&self) -> bool {
        !self.waiting.is_empty() || self.slots.values().any(|s| !s.pending.is_empty())
    }

    /// Resident slot count (diagnostics/tests).
    pub fn resident_slots(&self) -> usize {
        self.slots.len()
    }

    /// Waiting (unadmitted) group count (diagnostics/tests).
    pub fn waiting_groups(&self) -> usize {
        self.waiting.len()
    }

    /// Queued requests across waiting groups and slots.
    pub fn pending_requests(&self) -> usize {
        self.waiting.iter().map(|w| w.group.requests.len()).sum::<usize>()
            + self.slots.values().map(|s| s.pending.len()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Payload;
    use crate::sync::atomic::AtomicBool;
    use crate::sync::mpsc::channel;
    use crate::Mat;
    use std::time::Duration;

    fn req(id: u64, session: &str) -> AttentionRequest {
        let (tx, _rx) = channel();
        let now = Instant::now();
        AttentionRequest {
            id,
            session: session.into(),
            payload: Payload::Query(vec![0.0; 4]),
            arrived: now,
            deadline: now + Duration::from_secs(300),
            pinned: false,
            cancelled: Arc::new(AtomicBool::new(false)),
            reply: tx,
        }
    }

    fn append_req(id: u64, session: &str, rows: usize) -> AttentionRequest {
        let (tx, _rx) = channel();
        let now = Instant::now();
        AttentionRequest {
            id,
            session: session.into(),
            payload: Payload::Append {
                k_rows: Mat::zeros(rows, 4),
                v_rows: Mat::zeros(rows, 4),
            },
            arrived: now,
            deadline: now + Duration::from_secs(300),
            pinned: false,
            cancelled: Arc::new(AtomicBool::new(false)),
            reply: tx,
        }
    }

    fn sched(cfg: SchedulerCfg) -> Scheduler {
        Scheduler::new(cfg, Arc::new(KvStore::new(64, 4, 8)), Arc::new(Metrics::new()))
    }

    fn sched_with_kv(cfg: SchedulerCfg, kv: Arc<KvStore>) -> Scheduler {
        Scheduler::new(cfg, kv, Arc::new(Metrics::new()))
    }

    /// Park `group` (one session, these requests) in the waiting queue.
    fn park(s: &mut Scheduler, session: &str, reqs: Vec<AttentionRequest>) {
        s.enqueue_closed(
            Batch::formed(vec![SessionBatch { session: session.into(), requests: reqs }]),
            Instant::now(),
        );
    }

    fn ids(b: &Batch) -> Vec<u64> {
        b.groups.iter().flat_map(|g| g.requests.iter().map(|r| r.id)).collect()
    }

    #[test]
    fn empty_running_batch_admits_immediately_as_one_prefill() {
        let mut s = sched(SchedulerCfg::default());
        let gate = IterGate::new();
        for i in 0..8u64 {
            park(&mut s, &format!("sess-{i}"), vec![req(i, &format!("sess-{i}"))]);
        }
        let batches = s.dispatch(Instant::now(), &gate);
        assert_eq!(batches.len(), 1, "all waiting groups admit as ONE prefill dispatch");
        assert_eq!(batches[0].kind, BatchKind::Prefill);
        assert_eq!(batches[0].groups.len(), 8);
        assert_eq!(s.resident_slots(), 8);
        assert_eq!(s.waiting_groups(), 0);
        // a second dispatch with nothing pending assembles nothing
        assert!(s.dispatch(Instant::now(), &gate).is_empty());
    }

    #[test]
    fn routed_decode_traffic_never_reenters_admission() {
        let mut s = sched(SchedulerCfg::default());
        let gate = IterGate::new();
        let now = Instant::now();
        park(&mut s, "s", vec![append_req(0, "s", 1)]);
        let admitted = s.dispatch(now, &gate);
        assert_eq!(admitted.len(), 1);
        // prefill retired (gate never claimed in this test): decode next
        for i in 1..=10u64 {
            let r = req(i, "s");
            assert!(s.route(r, now, false).is_none(), "resident slot takes the request");
            let batches = s.dispatch(now, &gate);
            assert_eq!(batches.len(), 1);
            assert_eq!(batches[0].kind, BatchKind::Decode);
            assert_eq!(ids(&batches[0]), vec![i]);
        }
        // ordering: Relaxed — test-side counter reads
        assert_eq!(s.metrics.batcher_admissions.load(Ordering::Relaxed), 1);
        assert_eq!(s.metrics.slot_hits.load(Ordering::Relaxed), 10);
        assert_eq!(s.metrics.decode_iters.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn route_refuses_nonresident_and_order_hazards() {
        let mut s = sched(SchedulerCfg::default());
        let now = Instant::now();
        assert!(s.route(req(1, "s"), now, false).is_some(), "not resident: front end");
        park(&mut s, "s", vec![req(1, "s")]);
        // waiting state ahead: direct routing would reorder
        assert!(s.route(req(2, "s"), now, false).is_some());
        let gate = IterGate::new();
        s.dispatch(now, &gate);
        assert!(s.is_resident("s"));
        // front-end pending (batcher) ahead: still refused
        assert!(s.route(req(3, "s"), now, true).is_some());
        assert!(s.route(req(4, "s"), now, false).is_none(), "quiescent slot routes");
    }

    #[test]
    fn prefill_token_budget_splits_admissions() {
        let mut s = sched(SchedulerCfg { max_batch_prefill_tokens: 4, ..SchedulerCfg::default() });
        let gate = IterGate::new();
        // three groups of 3 tokens each (append of 2 rows + 1 query)
        for i in 0..3u64 {
            let sess = format!("s{i}");
            park(&mut s, &sess, vec![append_req(10 * i, &sess, 2), req(10 * i + 1, &sess)]);
        }
        let first = s.dispatch(Instant::now(), &gate);
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].groups.len(), 1, "3 + 3 > 4: one group per admission");
        assert_eq!(s.waiting_groups(), 2);
        let second = s.dispatch(Instant::now(), &gate);
        assert_eq!(second[0].groups[0].session, "s1", "FIFO admission order");
        // an oversized lone group still admits alone (never wedges)
        let mut s = sched(SchedulerCfg { max_batch_prefill_tokens: 2, ..SchedulerCfg::default() });
        park(&mut s, "big", vec![append_req(0, "big", 8)]);
        let b = s.dispatch(Instant::now(), &gate);
        assert_eq!(b.len(), 1, "head-of-line oversized group admits alone");
        assert_eq!(s.waiting_groups(), 0);
    }

    #[test]
    fn decode_keeps_priority_until_ratio_then_starvation_override() {
        let mut s = sched(SchedulerCfg {
            waiting_served_ratio: 2.0,
            max_waiting_iters: 3,
            ..SchedulerCfg::default()
        });
        let gate = IterGate::new();
        let now = Instant::now();
        park(&mut s, "resident", vec![req(0, "resident")]);
        s.dispatch(now, &gate); // admit: slot resident
        // keep the resident slot busy, then park one waiting group:
        // 1 < ceil(2.0 * 1) = 2, so decode keeps priority
        assert!(s.route(req(1, "resident"), now, false).is_none());
        park(&mut s, "newbie", vec![req(100, "newbie")]);
        let batches = s.dispatch(now, &gate);
        assert_eq!(batches.len(), 1, "below the ratio: decode only");
        assert_eq!(batches[0].kind, BatchKind::Decode);
        assert_eq!(s.waiting_groups(), 1);
        // two more decode iterations age the waiting group to the
        // starvation override (enqueued at iter 1; admitted at iter 4)
        for _ in 0..2 {
            let now = Instant::now();
            assert!(s.route(req(2, "resident"), now, false).is_none());
            let batches = s.dispatch(now, &gate);
            assert_eq!(batches.len(), 1);
            assert_eq!(batches[0].kind, BatchKind::Decode);
        }
        assert!(s.route(req(3, "resident"), now, false).is_none());
        let batches = s.dispatch(Instant::now(), &gate);
        assert_eq!(batches.len(), 2, "starved prefill admitted alongside decode");
        assert_eq!(batches[0].kind, BatchKind::Prefill);
        assert_eq!(batches[0].groups[0].session, "newbie");
        assert_eq!(batches[1].kind, BatchKind::Decode);
        // a second waiting group reaches the ratio threshold directly
        assert!(s.route(req(4, "resident"), now, false).is_none());
        park(&mut s, "w1", vec![req(101, "w1")]);
        park(&mut s, "w2", vec![req(102, "w2")]);
        park(&mut s, "w3", vec![req(103, "w3")]);
        let batches = s.dispatch(Instant::now(), &gate);
        // running = 2 busy slots? "newbie" has no pending; running is
        // "resident" (+ any in_prefill) — 3 >= ceil(2.0 * running)
        assert_eq!(batches[0].kind, BatchKind::Prefill, "ratio reached: prefill admitted");
        assert_eq!(batches[0].groups.len(), 3);
    }

    #[test]
    fn total_token_budget_retires_idle_slots_then_defers() {
        let kv = Arc::new(KvStore::new(64, 4, 16));
        kv.put("idle", Mat::zeros(4, 4), Mat::zeros(4, 4)).unwrap();
        kv.put("busy", Mat::zeros(4, 4), Mat::zeros(4, 4)).unwrap();
        kv.put("new", Mat::zeros(9, 4), Mat::zeros(9, 4)).unwrap();
        let mut s = sched_with_kv(
            SchedulerCfg { max_batch_total_tokens: 16, ..SchedulerCfg::default() },
            kv,
        );
        let gate = IterGate::new();
        let now = Instant::now();
        park(&mut s, "idle", vec![req(0, "idle")]);
        park(&mut s, "busy", vec![req(1, "busy")]);
        let first = s.dispatch(now, &gate);
        assert_eq!(first[0].groups.len(), 2, "4 + 4 + 2 query tokens fit the budget");
        assert_eq!(s.resident_slots(), 2);
        // keep "busy" busy; admitting "new" (9 resident + 1 query) needs
        // 8 + 10 > 16 — the idle slot must be retired to make room.
        // running=1, waiting=1 < ceil(1.2*1)=2: age past max_waiting_iters
        assert!(s.route(req(2, "busy"), now, false).is_none());
        park(&mut s, "new", vec![req(100, "new")]);
        for _ in 0..4 {
            assert!(s.route(req(3, "busy"), Instant::now(), false).is_none());
            s.dispatch(Instant::now(), &gate);
        }
        assert!(s.route(req(4, "busy"), Instant::now(), false).is_none());
        let batches = s.dispatch(Instant::now(), &gate);
        assert_eq!(batches[0].kind, BatchKind::Prefill);
        assert!(!s.is_resident("idle"), "idle slot retired to fund the admission");
        assert!(s.is_resident("busy") && s.is_resident("new"));
        // now the running batch holds 4 (busy) + 9 (new) = 13 tokens; a
        // 9-token group cannot fit and nothing is idle — it must defer
        park(&mut s, "x", vec![append_req(200, "x", 9)]);
        for _ in 0..6 {
            assert!(s.route(req(5, "busy"), Instant::now(), false).is_none());
            assert!(s.route(req(6, "new"), Instant::now(), false).is_none());
            let batches = s.dispatch(Instant::now(), &gate);
            assert!(
                batches.iter().all(|b| b.kind == BatchKind::Decode),
                "token-full running batch defers admission even past aging"
            );
        }
        assert_eq!(s.waiting_groups(), 1, "the group stays waiting");
    }

    #[test]
    fn decode_assembly_is_round_robin_and_caps_per_slot() {
        let mut s = sched(SchedulerCfg { max_batch: 2, max_total_batch: 3, ..Default::default() });
        let gate = IterGate::new();
        let now = Instant::now();
        park(&mut s, "a", vec![req(0, "a")]);
        park(&mut s, "b", vec![req(1, "b")]);
        s.dispatch(now, &gate); // admit both
        // a: 3 pending, b: 2 pending; per-slot cap 2, total cap 3
        for i in 0..3u64 {
            assert!(s.route(req(10 + i, "a"), now, false).is_none());
        }
        for i in 0..2u64 {
            assert!(s.route(req(20 + i, "b"), now, false).is_none());
        }
        let first = s.dispatch(now, &gate);
        assert_eq!(first.len(), 1);
        let d = &first[0];
        assert_eq!(d.kind, BatchKind::Decode);
        assert_eq!(d.groups.len(), 2, "both slots served in one iteration");
        assert_eq!(ids(d), vec![10, 11, 20], "2 from a (cap), 1 from b (total cap)");
        let second = s.dispatch(now, &gate);
        // rotation moved on: b first this time
        assert_eq!(ids(&second[0]), vec![21, 12], "round-robin starts at b's remainder");
        assert!(!s.has_backlog());
    }

    #[test]
    fn gate_lanes_serialize_dispatches() {
        // ratio 0.5: one waiting group against one running slot is
        // already past the admission threshold
        let mut s = sched(SchedulerCfg { waiting_served_ratio: 0.5, ..Default::default() });
        let gate = IterGate::new();
        let now = Instant::now();
        park(&mut s, "s", vec![req(0, "s")]);
        let batches = s.dispatch(now, &gate);
        assert_eq!(batches[0].kind, BatchKind::Prefill);
        assert!(gate.claim(BatchKind::Prefill), "loop claims the lane before emit");
        // while the prefill is in flight its slot must not decode, and
        // no second prefill may assemble
        park(&mut s, "t", vec![req(1, "t")]);
        assert!(s.route(req(2, "s"), now, true).is_some(), "front end busy: refused");
        park(&mut s, "s", vec![req(2, "s")]);
        let during = s.dispatch(now, &gate);
        assert!(during.is_empty(), "in-flight prefill: slot excluded, lane busy");
        gate.finish(BatchKind::Prefill);
        let after = s.dispatch(now, &gate);
        assert_eq!(after.len(), 2, "lane reopened: next prefill + decode iteration");
        assert_eq!(after[0].kind, BatchKind::Prefill);
        assert_eq!(after[1].kind, BatchKind::Decode);
        assert_eq!(ids(&after[1]), vec![2], "the retired prefill's slot decodes now");
        // decode lane serializes identically
        assert!(gate.claim(BatchKind::Decode));
        assert!(s.route(req(3, "s"), now, false).is_none());
        assert!(s.dispatch(now, &gate).iter().all(|b| b.kind != BatchKind::Decode));
        gate.finish(BatchKind::Decode);
        gate.finish(BatchKind::Prefill);
        let b = s.dispatch(now, &gate);
        assert!(b.iter().any(|b| b.kind == BatchKind::Decode));
    }

    #[test]
    fn remove_matching_sweeps_waiting_and_slots() {
        let mut s = sched(SchedulerCfg::default());
        let gate = IterGate::new();
        let now = Instant::now();
        park(&mut s, "live", vec![req(0, "live")]);
        s.dispatch(now, &gate);
        assert!(s.route(req(1, "live"), now, false).is_none());
        assert!(s.route(req(2, "live"), now, false).is_none());
        park(&mut s, "doomed", vec![req(3, "doomed"), req(4, "doomed")]);
        park(&mut s, "mixed", vec![req(5, "mixed"), req(6, "mixed")]);
        let removed = s.remove_matching(|r| r.session == "doomed" || r.id == 5 || r.id == 1);
        let mut got: Vec<u64> = removed.iter().map(|r| r.id).collect();
        got.sort_unstable();
        assert_eq!(got, vec![1, 3, 4, 5]);
        assert_eq!(s.waiting_groups(), 1, "emptied waiting group dropped");
        assert_eq!(s.pending_requests(), 2, "survivors: slot req 2 + waiting req 6");
        assert!(s.is_resident("live"), "drained slot stays resident");
        // retire evicts the slot and hands back its pending
        let left = s.retire("live");
        assert_eq!(left.len(), 1);
        assert_eq!(left[0].id, 2);
        assert!(!s.is_resident("live"));
        assert!(s.retire("live").is_empty(), "double retire is inert");
    }

    #[test]
    fn drain_all_flushes_waiting_and_slots_as_formed_batches() {
        let mut s = sched(SchedulerCfg { max_total_batch: 2, max_batch: 2, ..Default::default() });
        let gate = IterGate::new();
        let now = Instant::now();
        park(&mut s, "a", vec![req(0, "a")]);
        s.dispatch(now, &gate);
        assert!(s.route(req(1, "a"), now, false).is_none());
        park(&mut s, "w", vec![req(2, "w"), req(3, "w")]);
        let batches = s.drain_all();
        assert_eq!(batches.iter().map(|b| b.groups.len()).sum::<usize>(), 2);
        assert_eq!(
            batches.iter().flat_map(ids).count(),
            3,
            "every queued request is flushed exactly once"
        );
        assert!(batches.iter().all(|b| b.kind == BatchKind::Formed && b.done.is_none()));
        assert_eq!(s.resident_slots(), 0);
        assert_eq!(s.waiting_groups(), 0);
        assert!(!s.has_backlog());
        assert_eq!(
            s.metrics.sessions_evicted.load(Ordering::Relaxed),
            1,
            "the one resident slot retired by the flush is tallied"
        );
    }

    #[test]
    fn slot_feeding_inflight_decode_is_not_retired_by_token_budget() {
        let kv = Arc::new(KvStore::new(64, 4, 16));
        kv.put("a", Mat::zeros(4, 4), Mat::zeros(4, 4)).unwrap();
        kv.put("b", Mat::zeros(9, 4), Mat::zeros(9, 4)).unwrap();
        let mut s = sched_with_kv(
            SchedulerCfg { max_batch_total_tokens: 12, ..SchedulerCfg::default() },
            kv,
        );
        let gate = IterGate::new();
        let now = Instant::now();
        park(&mut s, "a", vec![req(0, "a")]);
        s.dispatch(now, &gate); // prefill admits "a" (4 resident + 1 query <= 12)
        // drain a's next request into a decode dispatch kept in flight
        assert!(s.route(req(1, "a"), now, false).is_none());
        let d = s.dispatch(now, &gate);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].kind, BatchKind::Decode);
        assert!(gate.claim(BatchKind::Decode), "decode dispatch in flight");
        // "b" (9 resident + 1 query) cannot fit beside a's 4 resident
        // tokens; a's pending is drained but its work is mid-flight, so
        // a must NOT be retired to fund the admission — "b" defers.
        // (Retiring it would let a's next request re-admit through the
        // prefill lane concurrently with the running decode.)
        park(&mut s, "b", vec![req(2, "b")]);
        let during = s.dispatch(Instant::now(), &gate);
        assert!(during.iter().all(|b| b.kind != BatchKind::Prefill), "admission deferred");
        assert!(s.is_resident("a"), "slot feeding the in-flight decode must survive");
        assert_eq!(s.waiting_groups(), 1);
        // once the decode retires, the genuinely idle slot funds it
        gate.finish(BatchKind::Decode);
        let after = s.dispatch(Instant::now(), &gate);
        assert_eq!(after[0].kind, BatchKind::Prefill);
        assert!(!s.is_resident("a"), "idle slot retired once its dispatch completed");
        assert!(s.is_resident("b"));
    }

    #[test]
    fn deadline_bound_tracks_queued_requests_and_refreshes_after_sweep() {
        let mut s = sched(SchedulerCfg::default());
        let gate = IterGate::new();
        let now = Instant::now();
        assert!(s.next_request_deadline().is_none());
        let r0 = req(0, "w");
        let d0 = r0.deadline;
        park(&mut s, "w", vec![r0]);
        assert_eq!(s.next_request_deadline(), Some(d0), "waiting group sets the bound");
        s.dispatch(now, &gate); // admits "w" (bound may stay stale-low)
        // a routed request with an earlier deadline tightens the bound
        let mut r1 = req(1, "w");
        r1.deadline = now + Duration::from_millis(5);
        let d1 = r1.deadline;
        assert!(s.route(r1, now, false).is_none());
        assert_eq!(s.next_request_deadline(), Some(d1));
        // the sweep removes the expired request and re-tightens exactly
        let later = now + Duration::from_millis(10);
        let removed = s.remove_matching(|r| r.expired(later));
        assert_eq!(removed.len(), 1);
        assert_eq!(removed[0].id, 1);
        assert!(s.next_request_deadline().is_none(), "no queued work: bound cleared");
    }

    #[test]
    fn merged_same_session_waiting_groups_admit_in_fifo_order() {
        let mut s = sched(SchedulerCfg::default());
        let gate = IterGate::new();
        park(&mut s, "s", vec![req(1, "s"), append_req(2, "s", 1)]);
        park(&mut s, "s", vec![req(3, "s")]);
        let batches = s.dispatch(Instant::now(), &gate);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].groups.len(), 1, "same session merges into one group");
        assert_eq!(ids(&batches[0]), vec![1, 2, 3], "FIFO = arrival order preserved");
    }
}

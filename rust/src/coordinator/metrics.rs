//! Serving metrics: counters + latency reservoir with percentiles.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Default)]
pub struct Metrics {
    pub accepted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    latencies_us: Mutex<Vec<f64>>,
}

/// A point-in-time metrics summary.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn observe_latency(&self, us: f64) {
        self.latencies_us.lock().unwrap().push(us);
    }

    pub fn snapshot(&self) -> Snapshot {
        let mut lat = self.latencies_us.lock().unwrap().clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |q: f64| {
            if lat.is_empty() {
                0.0
            } else {
                lat[((lat.len() as f64 - 1.0) * q) as usize]
            }
        };
        let batches = self.batches.load(Ordering::Relaxed);
        Snapshot {
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 {
                0.0
            } else {
                self.batched_requests.load(Ordering::Relaxed) as f64 / batches as f64
            },
            p50_us: pick(0.5),
            p99_us: pick(0.99),
            mean_us: if lat.is_empty() { 0.0 } else { lat.iter().sum::<f64>() / lat.len() as f64 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_latency(i as f64);
        }
        m.batches.store(10, Ordering::Relaxed);
        m.batched_requests.store(100, Ordering::Relaxed);
        let s = m.snapshot();
        assert!(s.p50_us >= 49.0 && s.p50_us <= 52.0);
        assert!(s.p99_us >= 98.0);
        assert_eq!(s.mean_batch, 10.0);
    }
}

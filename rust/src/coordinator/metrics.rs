//! Serving metrics: counters + a **bounded** latency reservoir with
//! percentiles.
//!
//! The seed kept every observed latency in an unbounded `Vec` — under
//! sustained load it grew forever and `snapshot()` cloned + sorted the
//! whole history under the lock.  The reservoir is fixed-size (Vitter's
//! Algorithm R with a fixed-seed xorshift, so replacement is
//! deterministic for a given arrival order): memory is O(cap), the
//! per-observation cost is O(1), and `snapshot()` sorts at most `cap`
//! samples *outside* the lock.  Mean latency stays exact over every
//! observation (running sum/count); percentiles are reservoir estimates
//! that are exact until the reservoir first fills.

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::Mutex;

use super::request::ServeError;

/// Fixed reservoir capacity: big enough for tight tail estimates
/// (standard error of a quantile ~ sqrt(q(1-q)/cap) < 1.6% at p50),
/// small enough that a snapshot sort is microseconds.
const RESERVOIR_CAP: usize = 4096;

/// Bounded latency reservoir (Algorithm R, deterministic xorshift64*).
struct Reservoir {
    samples: Vec<f64>,
    /// Total observations ever (not just resident samples).
    seen: u64,
    /// Exact running sum over every observation.
    sum: f64,
    rng: u64,
}

impl Default for Reservoir {
    fn default() -> Reservoir {
        Reservoir {
            samples: Vec::new(),
            seen: 0,
            sum: 0.0,
            rng: 0x9E37_79B9_7F4A_7C15,
        }
    }
}

impl Reservoir {
    fn next_u64(&mut self) -> u64 {
        // xorshift64* — fixed seed, so identical observation sequences
        // produce identical reservoirs (pinned by tests)
        let mut x = self.rng;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn observe(&mut self, us: f64) {
        self.seen += 1;
        self.sum += us;
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(us);
        } else {
            let j = self.next_u64() % self.seen;
            if (j as usize) < RESERVOIR_CAP {
                self.samples[j as usize] = us;
            }
        }
    }
}

pub struct Metrics {
    pub accepted: AtomicU64,
    pub rejected: AtomicU64,
    /// Queries answered successfully (appends are counted separately —
    /// a decode loop must not double its completion rate or dilute the
    /// attention-latency percentiles with near-zero-compute write acks).
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    /// KV append writes applied successfully.
    pub appends: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Session groups carried by dispatched batches (a cross-session
    /// super-batch counts each of its sessions); `/ batches` is the
    /// fan-out fusion factor the two-level batcher exists to raise.
    pub batched_sessions: AtomicU64,
    /// Requests currently in flight: accepted at ingress but not yet
    /// delivered a terminal response.  Gauge, not a counter — the
    /// admission gate (`max_pending_requests`) reads it, and drain waits
    /// for it to reach zero.
    pub inflight: AtomicU64,
    /// Requests shed before dispatch (deadline expired or session
    /// cancelled while queued) — work the serving loop declined to do.
    pub shed: AtomicU64,
    /// Per-outcome failure tallies (each also counts under `failed`).
    pub timed_out: AtomicU64,
    pub cancelled: AtomicU64,
    pub overloaded: AtomicU64,
    pub backend_failed: AtomicU64,
    pub kv_admission_failed: AtomicU64,
    pub shutdown_failed: AtomicU64,
    /// Re-dispatch attempts after transient backend faults.
    pub retries: AtomicU64,
    /// Workers whose backend was rebuilt in place after a panic.
    pub worker_respawns: AtomicU64,
    /// Terminal responses whose reply receiver was already dropped (the
    /// caller went away — the implicit cancellation the server detects
    /// at delivery time).
    pub delivery_lost: AtomicU64,
    /// Sessions admitted into scheduler residency through the batcher
    /// front-end (slot creations).  A continuous decode loop pays this
    /// once per session, not once per token — the structural win the
    /// acceptance test pins (`batcher_admissions == 1` for an N-token
    /// decode).
    pub batcher_admissions: AtomicU64,
    /// Requests routed straight into a resident slot, bypassing the
    /// window/barrier batcher entirely.
    pub slot_hits: AtomicU64,
    /// Prefill dispatches assembled by the continuous scheduler.
    pub prefill_iters: AtomicU64,
    /// Decode iterations assembled by the continuous scheduler.
    pub decode_iters: AtomicU64,
    /// Streaming ingress: connections accepted past the connection gate.
    pub conns_accepted: AtomicU64,
    /// Streaming ingress: connections refused at the door (gate full or
    /// handshake rejected).
    pub conns_rejected: AtomicU64,
    /// Streaming ingress: client disconnects observed mid-session (the
    /// wire analogue of a dropped `ResponseHandle`).
    pub disconnects: AtomicU64,
    /// Streaming ingress: token streams opened (one per `Stream` frame).
    pub streams_opened: AtomicU64,
    /// Streaming ingress: token frames delivered into write queues.
    pub stream_tokens: AtomicU64,
    /// Streaming ingress: sessions shed for exhausting their slow-consumer
    /// stall budget (each also cancels + evicts the session's KV).
    pub slow_consumer_shed: AtomicU64,
    /// Sessions whose KV was evicted by cancellation or drain teardown.
    pub sessions_evicted: AtomicU64,
    /// KV bytes resident fleet-wide (gauge; each unique chunk charged
    /// once no matter how many sessions reference it).
    pub kv_resident_bytes: AtomicU64,
    /// KV bytes referenced by two or more resident sessions (gauge; the
    /// portion of `kv_resident_bytes` the prefix cache deduplicated).
    pub kv_shared_bytes: AtomicU64,
    /// Sessions currently resident in the KV store (gauge).
    pub kv_resident_sessions: AtomicU64,
    /// Full prefix chunks resolved to an already-resident `Arc<KvChunk>`
    /// at put/fork instead of being rebuilt + LNS-converted.  Counted
    /// only after the session is admitted and installed, so a failed
    /// admission contributes nothing (same discipline as
    /// `batched_sessions`).
    pub kv_dedup_hits: AtomicU64,
    latencies_us: Mutex<Reservoir>,
    /// Ingress -> dispatch span (time queued in the batcher, the waiting
    /// queue, or a resident slot before a worker picked the request up).
    queue_wait_us: Mutex<Reservoir>,
    /// Wall time of prefill dispatches (admission to completion).
    prefill_us: Mutex<Reservoir>,
    /// Inter-token decode gap: per-slot time between consecutive decode
    /// iterations that carried the slot's work — the token cadence whose
    /// p99 the continuous scheduler exists to bound.
    decode_gap_us: Mutex<Reservoir>,
    /// Streaming ingress: stream-open to first token frame queued.
    first_token_us: Mutex<Reservoir>,
    /// Streaming ingress: gap between consecutive token frames of one
    /// stream — the client-visible cadence (decode gap + delivery).
    inter_token_us: Mutex<Reservoir>,
}

/// A point-in-time metrics summary.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub appends: u64,
    pub batches: u64,
    pub mean_batch: f64,
    /// Mean sessions fused per dispatched batch (1.0 when every dispatch
    /// is single-session).
    pub mean_sessions: f64,
    pub inflight: u64,
    pub shed: u64,
    pub timed_out: u64,
    pub cancelled: u64,
    pub overloaded: u64,
    pub backend_failed: u64,
    pub kv_admission_failed: u64,
    pub shutdown_failed: u64,
    pub retries: u64,
    pub worker_respawns: u64,
    pub delivery_lost: u64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub batcher_admissions: u64,
    pub slot_hits: u64,
    pub prefill_iters: u64,
    pub decode_iters: u64,
    pub queue_wait_p50_us: f64,
    pub queue_wait_p99_us: f64,
    pub prefill_p50_us: f64,
    pub prefill_p99_us: f64,
    pub decode_gap_p50_us: f64,
    pub decode_gap_p99_us: f64,
    pub conns_accepted: u64,
    pub conns_rejected: u64,
    pub disconnects: u64,
    pub streams_opened: u64,
    pub stream_tokens: u64,
    pub slow_consumer_shed: u64,
    pub sessions_evicted: u64,
    pub kv_resident_bytes: u64,
    pub kv_shared_bytes: u64,
    pub kv_resident_sessions: u64,
    pub kv_dedup_hits: u64,
    /// Mean resident KV bytes charged per resident session — with
    /// prefix sharing this drops below a solo session's footprint,
    /// which is the sessions-per-box lever the radix cache exists for.
    pub kv_mean_session_bytes: u64,
    pub first_token_p50_us: f64,
    pub first_token_p99_us: f64,
    pub inter_token_p50_us: f64,
    pub inter_token_p99_us: f64,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    /// Explicit construction (not `derive(Default)`): the facade's loom
    /// atomics do not implement `Default`, and spelling out every field
    /// keeps the struct constructible under `--cfg loom`.
    pub fn new() -> Metrics {
        let z = AtomicU64::new;
        Metrics {
            accepted: z(0),
            rejected: z(0),
            completed: z(0),
            failed: z(0),
            appends: z(0),
            batches: z(0),
            batched_requests: z(0),
            batched_sessions: z(0),
            inflight: z(0),
            shed: z(0),
            timed_out: z(0),
            cancelled: z(0),
            overloaded: z(0),
            backend_failed: z(0),
            kv_admission_failed: z(0),
            shutdown_failed: z(0),
            retries: z(0),
            worker_respawns: z(0),
            delivery_lost: z(0),
            batcher_admissions: z(0),
            slot_hits: z(0),
            prefill_iters: z(0),
            decode_iters: z(0),
            conns_accepted: z(0),
            conns_rejected: z(0),
            disconnects: z(0),
            streams_opened: z(0),
            stream_tokens: z(0),
            slow_consumer_shed: z(0),
            sessions_evicted: z(0),
            kv_resident_bytes: z(0),
            kv_shared_bytes: z(0),
            kv_resident_sessions: z(0),
            kv_dedup_hits: z(0),
            latencies_us: Mutex::new(Reservoir::default()),
            queue_wait_us: Mutex::new(Reservoir::default()),
            prefill_us: Mutex::new(Reservoir::default()),
            decode_gap_us: Mutex::new(Reservoir::default()),
            first_token_us: Mutex::new(Reservoir::default()),
            inter_token_us: Mutex::new(Reservoir::default()),
        }
    }

    pub fn observe_latency(&self, us: f64) {
        self.latencies_us.lock().observe(us);
    }

    /// Record one request's queue-wait span (ingress to worker pickup).
    pub fn observe_queue_wait(&self, us: f64) {
        self.queue_wait_us.lock().observe(us);
    }

    /// Record one prefill dispatch's wall time.
    pub fn observe_prefill(&self, us: f64) {
        self.prefill_us.lock().observe(us);
    }

    /// Record one slot's inter-token decode gap.
    pub fn observe_decode_gap(&self, us: f64) {
        self.decode_gap_us.lock().observe(us);
    }

    /// Record one stream's open-to-first-token span.
    pub fn observe_first_token(&self, us: f64) {
        self.first_token_us.lock().observe(us);
    }

    /// Record one stream's gap between consecutive token frames.
    pub fn observe_inter_token(&self, us: f64) {
        self.inter_token_us.lock().observe(us);
    }

    /// Count one failed terminal response: the aggregate `failed` plus
    /// the per-outcome tally for the error's variant.
    pub fn record_failure(&self, err: &ServeError) {
        // ordering: Relaxed — statistical counters; readers that need a
        // consistent view (tests, snapshots after shutdown) get their
        // happens-before from joining the serving threads first
        self.failed.fetch_add(1, Ordering::Relaxed);
        let tally = match err {
            ServeError::TimedOut => &self.timed_out,
            ServeError::Overloaded => &self.overloaded,
            ServeError::Cancelled => &self.cancelled,
            ServeError::BackendFailed { .. } => &self.backend_failed,
            ServeError::Shutdown(_) => &self.shutdown_failed,
            ServeError::KvAdmission(_) => &self.kv_admission_failed,
        };
        // ordering: Relaxed — same statistical-counter rationale as above
        tally.fetch_add(1, Ordering::Relaxed);
    }

    /// Latency samples currently resident (bounded by the reservoir cap).
    pub fn latency_samples(&self) -> usize {
        self.latencies_us.lock().samples.len()
    }

    /// Sorted copy of one span reservoir's samples (bounded copy under
    /// its lock, sort outside; each reservoir mutex is taken alone).
    fn sorted_samples(r: &Mutex<Reservoir>) -> Vec<f64> {
        let mut v = {
            let g = r.lock();
            g.samples.clone()
        };
        // total_cmp: latencies are finite by construction, but a NaN that
        // ever slipped in must not panic the metrics endpoint
        v.sort_by(f64::total_cmp);
        v
    }

    pub fn snapshot(&self) -> Snapshot {
        // bounded copy under the lock; the sort happens outside it
        let (mut lat, seen, sum) = {
            let g = self.latencies_us.lock();
            (g.samples.clone(), g.seen, g.sum)
        };
        lat.sort_by(f64::total_cmp);
        let queue_wait = Metrics::sorted_samples(&self.queue_wait_us);
        let prefill = Metrics::sorted_samples(&self.prefill_us);
        let decode_gap = Metrics::sorted_samples(&self.decode_gap_us);
        let first_token = Metrics::sorted_samples(&self.first_token_us);
        let inter_token = Metrics::sorted_samples(&self.inter_token_us);
        // nearest-rank (ceil) percentile: the q-quantile is the smallest
        // sample with at least ceil(q * n) samples <= it.  The previous
        // `((n - 1) * q) as usize` truncated the rank, biasing tail
        // percentiles low at small sample counts — at n = 2 it returned
        // the *minimum* as p99, and at n = 4 the 3rd-smallest instead of
        // the max, collapsing p99 toward p50 exactly where the reservoir
        // is sparsest.
        let rank = |sorted: &[f64], q: f64| {
            if sorted.is_empty() {
                0.0
            } else {
                let rank = (sorted.len() as f64 * q).ceil() as usize;
                sorted[rank.clamp(1, sorted.len()) - 1]
            }
        };
        let pick = |q: f64| rank(&lat, q);
        // ordering: Relaxed — a snapshot is an advisory point-in-time
        // read of independent statistical counters, not a synchronization
        // point; callers needing exact totals join the serving threads
        // first (shutdown/drain), which supplies the happens-before edge
        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        let batches = ld(&self.batches);
        Snapshot {
            accepted: ld(&self.accepted),
            rejected: ld(&self.rejected),
            completed: ld(&self.completed),
            failed: ld(&self.failed),
            appends: ld(&self.appends),
            batches,
            mean_batch: if batches == 0 {
                0.0
            } else {
                ld(&self.batched_requests) as f64 / batches as f64
            },
            mean_sessions: if batches == 0 {
                0.0
            } else {
                ld(&self.batched_sessions) as f64 / batches as f64
            },
            inflight: ld(&self.inflight),
            shed: ld(&self.shed),
            timed_out: ld(&self.timed_out),
            cancelled: ld(&self.cancelled),
            overloaded: ld(&self.overloaded),
            backend_failed: ld(&self.backend_failed),
            kv_admission_failed: ld(&self.kv_admission_failed),
            shutdown_failed: ld(&self.shutdown_failed),
            retries: ld(&self.retries),
            worker_respawns: ld(&self.worker_respawns),
            delivery_lost: ld(&self.delivery_lost),
            p50_us: pick(0.5),
            p99_us: pick(0.99),
            mean_us: if seen == 0 { 0.0 } else { sum / seen as f64 },
            batcher_admissions: ld(&self.batcher_admissions),
            slot_hits: ld(&self.slot_hits),
            prefill_iters: ld(&self.prefill_iters),
            decode_iters: ld(&self.decode_iters),
            queue_wait_p50_us: rank(&queue_wait, 0.5),
            queue_wait_p99_us: rank(&queue_wait, 0.99),
            prefill_p50_us: rank(&prefill, 0.5),
            prefill_p99_us: rank(&prefill, 0.99),
            decode_gap_p50_us: rank(&decode_gap, 0.5),
            decode_gap_p99_us: rank(&decode_gap, 0.99),
            conns_accepted: ld(&self.conns_accepted),
            conns_rejected: ld(&self.conns_rejected),
            disconnects: ld(&self.disconnects),
            streams_opened: ld(&self.streams_opened),
            stream_tokens: ld(&self.stream_tokens),
            slow_consumer_shed: ld(&self.slow_consumer_shed),
            sessions_evicted: ld(&self.sessions_evicted),
            kv_resident_bytes: ld(&self.kv_resident_bytes),
            kv_shared_bytes: ld(&self.kv_shared_bytes),
            kv_resident_sessions: ld(&self.kv_resident_sessions),
            kv_dedup_hits: ld(&self.kv_dedup_hits),
            kv_mean_session_bytes: ld(&self.kv_resident_bytes)
                / ld(&self.kv_resident_sessions).max(1),
            first_token_p50_us: rank(&first_token, 0.5),
            first_token_p99_us: rank(&first_token, 0.99),
            inter_token_p50_us: rank(&inter_token, 0.5),
            inter_token_p99_us: rank(&inter_token, 0.99),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_latency(i as f64);
        }
        m.batches.store(10, Ordering::Relaxed);
        m.batched_requests.store(100, Ordering::Relaxed);
        m.batched_sessions.store(30, Ordering::Relaxed);
        let s = m.snapshot();
        assert!(s.p50_us >= 49.0 && s.p50_us <= 52.0);
        assert!(s.p99_us >= 98.0);
        assert_eq!(s.mean_batch, 10.0);
        assert_eq!(s.mean_sessions, 3.0);
        assert!((s.mean_us - 50.5).abs() < 1e-9);
    }

    // Nearest-rank (ceil) selection at small and exact sample counts —
    // the truncating `((n-1) * q) as usize` rank biased p99 low and
    // collapsed it onto p50 below ~100 samples.
    #[test]
    fn percentiles_use_nearest_rank_ceil_selection() {
        // n = 1: every percentile is the lone sample
        let m = Metrics::new();
        m.observe_latency(42.0);
        let s = m.snapshot();
        assert_eq!(s.p50_us, 42.0);
        assert_eq!(s.p99_us, 42.0);

        // n = 2: p50 is the lower sample (rank ceil(1.0) = 1), p99 the
        // upper (rank ceil(1.98) = 2) — the truncating rank returned the
        // lower sample for *both*
        let m = Metrics::new();
        m.observe_latency(10.0);
        m.observe_latency(20.0);
        let s = m.snapshot();
        assert_eq!(s.p50_us, 10.0);
        assert_eq!(s.p99_us, 20.0);

        // n = 4: p50 = 2nd-smallest, p99 = max (truncation gave the 3rd)
        let m = Metrics::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            m.observe_latency(x);
        }
        let s = m.snapshot();
        assert_eq!(s.p50_us, 2.0);
        assert_eq!(s.p99_us, 4.0);

        // n = 100 over 1..=100: exact nearest-rank values — p50 = 50
        // (rank 50), p99 = 99 (rank 99)
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_latency(i as f64);
        }
        let s = m.snapshot();
        assert_eq!(s.p50_us, 50.0);
        assert_eq!(s.p99_us, 99.0);

        // empty reservoir still reports zeros
        let s = Metrics::new().snapshot();
        assert_eq!(s.p50_us, 0.0);
        assert_eq!(s.p99_us, 0.0);
    }

    #[test]
    fn latency_spans_are_recorded_and_summarized_separately() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_queue_wait(i as f64); // 1..=100
            m.observe_prefill(10.0 * i as f64); // 10..=1000
            m.observe_decode_gap(0.5 * i as f64); // 0.5..=50
        }
        // ordering: Relaxed — statistical counters, test-side writes
        m.batcher_admissions.fetch_add(1, Ordering::Relaxed);
        m.slot_hits.fetch_add(7, Ordering::Relaxed);
        m.prefill_iters.fetch_add(2, Ordering::Relaxed);
        m.decode_iters.fetch_add(4, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.queue_wait_p50_us, 50.0);
        assert_eq!(s.queue_wait_p99_us, 99.0);
        assert_eq!(s.prefill_p50_us, 500.0);
        assert_eq!(s.prefill_p99_us, 990.0);
        assert_eq!(s.decode_gap_p50_us, 25.0);
        assert_eq!(s.decode_gap_p99_us, 49.5);
        assert_eq!((s.batcher_admissions, s.slot_hits), (1, 7));
        assert_eq!((s.prefill_iters, s.decode_iters), (2, 4));
        // the spans never leak into the end-to-end latency reservoir
        assert_eq!(m.latency_samples(), 0);
        assert_eq!(s.p50_us, 0.0);
    }

    #[test]
    fn streaming_spans_and_counters_are_summarized_separately() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_first_token(2.0 * i as f64); // 2..=200
            m.observe_inter_token(i as f64); // 1..=100
        }
        // ordering: Relaxed — statistical counters, test-side writes
        m.conns_accepted.fetch_add(3, Ordering::Relaxed);
        m.conns_rejected.fetch_add(1, Ordering::Relaxed);
        m.disconnects.fetch_add(2, Ordering::Relaxed);
        m.streams_opened.fetch_add(5, Ordering::Relaxed);
        m.stream_tokens.fetch_add(40, Ordering::Relaxed);
        m.slow_consumer_shed.fetch_add(1, Ordering::Relaxed);
        m.sessions_evicted.fetch_add(6, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.first_token_p50_us, 100.0);
        assert_eq!(s.first_token_p99_us, 198.0);
        assert_eq!(s.inter_token_p50_us, 50.0);
        assert_eq!(s.inter_token_p99_us, 99.0);
        assert_eq!((s.conns_accepted, s.conns_rejected, s.disconnects), (3, 1, 2));
        assert_eq!((s.streams_opened, s.stream_tokens), (5, 40));
        assert_eq!((s.slow_consumer_shed, s.sessions_evicted), (1, 6));
        // the streaming spans never leak into the end-to-end reservoir
        assert_eq!(m.latency_samples(), 0);
        assert_eq!(s.p50_us, 0.0);
    }

    #[test]
    fn kv_sharing_gauges_summarize_in_snapshot() {
        let m = Metrics::new();
        // ordering: Relaxed — statistical counters, test-side writes
        m.kv_resident_bytes.store(9_000, Ordering::Relaxed);
        m.kv_shared_bytes.store(6_000, Ordering::Relaxed);
        m.kv_resident_sessions.store(3, Ordering::Relaxed);
        m.kv_dedup_hits.fetch_add(5, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.kv_resident_bytes, 9_000);
        assert_eq!(s.kv_shared_bytes, 6_000);
        assert_eq!(s.kv_resident_sessions, 3);
        assert_eq!(s.kv_dedup_hits, 5);
        assert_eq!(s.kv_mean_session_bytes, 3_000);
        // empty fleet: mean guards the zero-session divide
        let s = Metrics::new().snapshot();
        assert_eq!(s.kv_mean_session_bytes, 0);
    }

    #[test]
    fn per_outcome_tallies_track_failure_variants() {
        let m = Metrics::new();
        m.record_failure(&ServeError::TimedOut);
        m.record_failure(&ServeError::TimedOut);
        m.record_failure(&ServeError::Cancelled);
        m.record_failure(&ServeError::backend("boom"));
        m.record_failure(&ServeError::Shutdown("drain".into()));
        m.record_failure(&ServeError::KvAdmission("unknown".into()));
        let s = m.snapshot();
        assert_eq!(s.failed, 6, "every outcome also counts in the aggregate");
        assert_eq!(s.timed_out, 2);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.backend_failed, 1);
        assert_eq!(s.shutdown_failed, 1);
        assert_eq!(s.kv_admission_failed, 1);
        assert_eq!(s.overloaded, 0);
    }

    #[test]
    fn reservoir_stays_bounded_under_sustained_load() {
        let m = Metrics::new();
        const TOTAL: usize = 100_000;
        for i in 0..TOTAL {
            m.observe_latency(i as f64);
        }
        assert!(
            m.latency_samples() <= RESERVOIR_CAP,
            "reservoir grew past its cap: {}",
            m.latency_samples()
        );
        let s = m.snapshot();
        // exact mean over all observations, not just resident samples
        assert!((s.mean_us - (TOTAL as f64 - 1.0) / 2.0).abs() < 1e-6);
        // percentile estimates track the uniform ramp
        assert!(s.p50_us > 0.4 * TOTAL as f64 && s.p50_us < 0.6 * TOTAL as f64, "p50 {}", s.p50_us);
        assert!(s.p99_us > 0.95 * TOTAL as f64, "p99 {}", s.p99_us);
    }

    #[test]
    fn replacement_is_deterministic() {
        let a = Metrics::new();
        let b = Metrics::new();
        for i in 0..20_000u64 {
            let us = ((i * 2_654_435_761) % 10_000) as f64;
            a.observe_latency(us);
            b.observe_latency(us);
        }
        let (sa, sb) = (a.snapshot(), b.snapshot());
        assert_eq!(sa.p50_us, sb.p50_us);
        assert_eq!(sa.p99_us, sb.p99_us);
        assert_eq!(sa.mean_us, sb.mean_us);
    }
}

//! Session-keyed KV buffer manager.
//!
//! Models the accelerator's on-chip KV SRAM: a bounded number of resident
//! sessions (each one `seq_len x d` K and V), LRU eviction when capacity
//! is exceeded — the coordinator-level counterpart of the paper's
//! "KV sub-blocks preloaded into local buffers" assumption (Section III-B).
//!
//! Each resident entry carries an [`Arc<PreparedKv>`] built **once** at
//! `put()`: V's linear->log conversion is paid at session load, never per
//! batch (pinned by `rust/tests/kv_prepare_once.rs`).  The LRU is a
//! generation counter — `get()` is one HashMap probe and a u64 bump under
//! the lock, with no list walks or key clones on the request path.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::attention::prepared::PreparedKv;
use crate::Mat;

/// One resident session's KV data.  A single `Arc<PreparedKv>` is the
/// whole state: it owns the raw BF16-rounded matrices (PJRT backends
/// ship those to the kernel) *and* the prepared log-domain lanes the
/// simulated accelerator executes against — so the raw and prepared
/// views can never disagree.
#[derive(Clone)]
pub struct KvEntry {
    prepared: Arc<PreparedKv>,
}

impl KvEntry {
    /// Build an entry (and its prepared form) from owned matrices.
    /// No rounding is applied — callers own the ingress convention.
    pub fn new(k: Mat, v: Mat) -> KvEntry {
        KvEntry { prepared: Arc::new(PreparedKv::new(k, v)) }
    }

    pub fn prepared(&self) -> &Arc<PreparedKv> {
        &self.prepared
    }

    pub fn k(&self) -> &Mat {
        self.prepared.k()
    }

    pub fn v(&self) -> &Mat {
        self.prepared.v()
    }
}

struct Slot {
    entry: KvEntry,
    /// Generation stamp of the last touch; smallest = LRU victim.
    last_used: u64,
}

struct Inner {
    capacity: usize,
    entries: HashMap<String, Slot>,
    /// Monotonic access generation counter.
    tick: u64,
    evictions: u64,
}

impl Inner {
    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    fn evict_to_capacity(&mut self) {
        while self.entries.len() > self.capacity {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(name, _)| name.clone());
            match victim {
                Some(name) => {
                    self.entries.remove(&name);
                    self.evictions += 1;
                }
                None => break,
            }
        }
    }
}

/// Thread-safe KV session store with generation-counter LRU eviction.
pub struct KvStore {
    seq_len: usize,
    head_dim: usize,
    inner: Mutex<Inner>,
}

impl KvStore {
    /// `capacity`: max resident sessions (SRAM budget / per-session bytes).
    pub fn new(seq_len: usize, head_dim: usize, capacity: usize) -> KvStore {
        KvStore {
            seq_len,
            head_dim,
            inner: Mutex::new(Inner {
                capacity: capacity.max(1),
                entries: HashMap::new(),
                tick: 0,
                evictions: 0,
            }),
        }
    }

    /// Bytes one session occupies (BF16 K + V).
    pub fn session_bytes(&self) -> usize {
        2 * self.seq_len * self.head_dim * 2
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Insert (or replace) a session's KV matrices.  The BF16 rounding and
    /// the one-time V->LNS preparation happen *outside* the lock.
    pub fn put(&self, session: &str, k: Mat, v: Mat) -> Result<()> {
        if k.rows != self.seq_len || k.cols != self.head_dim {
            bail!(
                "K shape {}x{} != store geometry {}x{}",
                k.rows, k.cols, self.seq_len, self.head_dim
            );
        }
        if v.rows != k.rows || v.cols != k.cols {
            bail!("V shape mismatch");
        }
        let entry = KvEntry::new(k.round_bf16(), v.round_bf16());
        let mut g = self.inner.lock().unwrap();
        let stamp = g.next_tick();
        g.entries.insert(session.to_string(), Slot { entry, last_used: stamp });
        g.evict_to_capacity();
        Ok(())
    }

    /// Fetch a session, refreshing its LRU stamp (O(1) under the lock).
    pub fn get(&self, session: &str) -> Option<KvEntry> {
        let mut g = self.inner.lock().unwrap();
        let stamp = g.next_tick();
        let slot = g.entries.get_mut(session)?;
        slot.last_used = stamp;
        Some(slot.entry.clone())
    }

    pub fn resident(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(n: usize, d: usize, fill: f32) -> (Mat, Mat) {
        (Mat::from_fn(n, d, |_, _| fill), Mat::from_fn(n, d, |_, _| -fill))
    }

    #[test]
    fn put_get_roundtrip() {
        let store = KvStore::new(16, 8, 2);
        let (k, v) = kv(16, 8, 1.0);
        store.put("a", k, v).unwrap();
        let e = store.get("a").unwrap();
        assert_eq!(e.k().at(0, 0), 1.0);
        assert_eq!(e.v().at(0, 0), -1.0);
        // the raw accessors alias the prepared form's own matrices
        assert!(std::ptr::eq(e.k(), e.prepared().k()));
        assert!(std::ptr::eq(e.v(), e.prepared().v()));
        assert_eq!(e.prepared().n(), 16);
    }

    #[test]
    fn rejects_wrong_geometry() {
        let store = KvStore::new(16, 8, 2);
        let (k, v) = kv(8, 8, 1.0);
        assert!(store.put("a", k, v).is_err());
    }

    #[test]
    fn lru_evicts_oldest() {
        let store = KvStore::new(4, 4, 2);
        for (i, name) in ["a", "b", "c"].iter().enumerate() {
            let (k, v) = kv(4, 4, i as f32);
            store.put(name, k, v).unwrap();
        }
        assert_eq!(store.resident(), 2);
        assert!(store.get("a").is_none(), "oldest should be evicted");
        assert!(store.get("b").is_some());
        assert_eq!(store.evictions(), 1);
    }

    #[test]
    fn get_refreshes_lru() {
        let store = KvStore::new(4, 4, 2);
        let (k, v) = kv(4, 4, 0.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        store.put("b", k.clone(), v.clone()).unwrap();
        store.get("a"); // refresh a
        store.put("c", k, v).unwrap(); // evicts b, not a
        assert!(store.get("a").is_some());
        assert!(store.get("b").is_none());
    }

    #[test]
    fn replacing_a_session_refreshes_it() {
        let store = KvStore::new(4, 4, 2);
        let (k, v) = kv(4, 4, 0.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        store.put("b", k.clone(), v.clone()).unwrap();
        store.put("a", k.clone(), v.clone()).unwrap(); // re-put refreshes a
        store.put("c", k, v).unwrap(); // evicts b
        assert!(store.get("a").is_some());
        assert!(store.get("b").is_none());
        assert!(store.get("c").is_some());
    }

    #[test]
    fn session_bytes_matches_bf16_kv() {
        let store = KvStore::new(1024, 64, 1);
        assert_eq!(store.session_bytes(), 2 * 1024 * 64 * 2);
    }

    #[test]
    fn concurrent_gets_and_puts_stay_consistent() {
        // request-path contention: many readers refreshing LRU stamps
        // while writers insert/evict.  The store must never exceed
        // capacity and never hand out a torn entry — every session name
        // encodes its fill value, so any `Some` result is verifiable.
        let store = Arc::new(KvStore::new(8, 4, 3));
        let fill = |s: usize| s as f32 + 1.0;
        let mut handles = Vec::new();
        for t in 0..6usize {
            let store = store.clone();
            handles.push(std::thread::spawn(move || {
                let mut hits = 0u64;
                for i in 0..500usize {
                    let s = (t + i) % 5;
                    if t < 2 {
                        let (k, v) = kv(8, 4, fill(s));
                        store.put(&format!("sess-{s}"), k, v).unwrap();
                    }
                    if let Some(e) = store.get(&format!("sess-{s}")) {
                        assert_eq!(e.k().at(0, 0), fill(s), "torn entry for sess-{s}");
                        assert_eq!(e.v().at(0, 0), -fill(s));
                        assert_eq!(e.prepared().n(), 8);
                        hits += 1;
                    }
                    assert!(store.resident() <= 3);
                }
                hits
            }));
        }
        let hits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(hits > 0, "at least some gets must land on resident sessions");
        assert!(store.resident() <= 3, "resident {} > capacity", store.resident());
    }
}

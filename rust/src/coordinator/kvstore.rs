//! Session-keyed KV buffer manager.
//!
//! Models the accelerator's on-chip KV SRAM: a bounded number of resident
//! sessions (each one `seq_len x d` K and V), LRU eviction when capacity
//! is exceeded — the coordinator-level counterpart of the paper's
//! "KV sub-blocks preloaded into local buffers" assumption (Section III-B).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::Mat;

/// One resident session's KV data.
#[derive(Clone)]
pub struct KvEntry {
    pub k: Arc<Mat>,
    pub v: Arc<Mat>,
}

struct Inner {
    capacity: usize,
    entries: HashMap<String, KvEntry>,
    /// LRU order, most recent last.
    lru: Vec<String>,
    evictions: u64,
}

/// Thread-safe KV session store with LRU eviction.
pub struct KvStore {
    seq_len: usize,
    head_dim: usize,
    inner: Mutex<Inner>,
}

impl KvStore {
    /// `capacity`: max resident sessions (SRAM budget / per-session bytes).
    pub fn new(seq_len: usize, head_dim: usize, capacity: usize) -> KvStore {
        KvStore {
            seq_len,
            head_dim,
            inner: Mutex::new(Inner {
                capacity: capacity.max(1),
                entries: HashMap::new(),
                lru: Vec::new(),
                evictions: 0,
            }),
        }
    }

    /// Bytes one session occupies (BF16 K + V).
    pub fn session_bytes(&self) -> usize {
        2 * self.seq_len * self.head_dim * 2
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Insert (or replace) a session's KV matrices.
    pub fn put(&self, session: &str, k: Mat, v: Mat) -> Result<()> {
        if k.rows != self.seq_len || k.cols != self.head_dim {
            bail!(
                "K shape {}x{} != store geometry {}x{}",
                k.rows, k.cols, self.seq_len, self.head_dim
            );
        }
        if v.rows != k.rows || v.cols != k.cols {
            bail!("V shape mismatch");
        }
        let mut g = self.inner.lock().unwrap();
        g.lru.retain(|s| s != session);
        g.lru.push(session.to_string());
        g.entries.insert(
            session.to_string(),
            KvEntry { k: Arc::new(k.round_bf16()), v: Arc::new(v.round_bf16()) },
        );
        while g.entries.len() > g.capacity {
            let victim = g.lru.remove(0);
            g.entries.remove(&victim);
            g.evictions += 1;
        }
        Ok(())
    }

    /// Fetch a session, refreshing its LRU position.
    pub fn get(&self, session: &str) -> Option<KvEntry> {
        let mut g = self.inner.lock().unwrap();
        if g.entries.contains_key(session) {
            g.lru.retain(|s| s != session);
            g.lru.push(session.to_string());
        }
        g.entries.get(session).cloned()
    }

    pub fn resident(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(n: usize, d: usize, fill: f32) -> (Mat, Mat) {
        (Mat::from_fn(n, d, |_, _| fill), Mat::from_fn(n, d, |_, _| -fill))
    }

    #[test]
    fn put_get_roundtrip() {
        let store = KvStore::new(16, 8, 2);
        let (k, v) = kv(16, 8, 1.0);
        store.put("a", k, v).unwrap();
        let e = store.get("a").unwrap();
        assert_eq!(e.k.at(0, 0), 1.0);
        assert_eq!(e.v.at(0, 0), -1.0);
    }

    #[test]
    fn rejects_wrong_geometry() {
        let store = KvStore::new(16, 8, 2);
        let (k, v) = kv(8, 8, 1.0);
        assert!(store.put("a", k, v).is_err());
    }

    #[test]
    fn lru_evicts_oldest() {
        let store = KvStore::new(4, 4, 2);
        for (i, name) in ["a", "b", "c"].iter().enumerate() {
            let (k, v) = kv(4, 4, i as f32);
            store.put(name, k, v).unwrap();
        }
        assert_eq!(store.resident(), 2);
        assert!(store.get("a").is_none(), "oldest should be evicted");
        assert!(store.get("b").is_some());
        assert_eq!(store.evictions(), 1);
    }

    #[test]
    fn get_refreshes_lru() {
        let store = KvStore::new(4, 4, 2);
        let (k, v) = kv(4, 4, 0.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        store.put("b", k.clone(), v.clone()).unwrap();
        store.get("a"); // refresh a
        store.put("c", k, v).unwrap(); // evicts b, not a
        assert!(store.get("a").is_some());
        assert!(store.get("b").is_none());
    }

    #[test]
    fn session_bytes_matches_bf16_kv() {
        let store = KvStore::new(1024, 64, 1);
        assert_eq!(store.session_bytes(), 2 * 1024 * 64 * 2);
    }
}

//! Session-keyed KV buffer manager.
//!
//! Models the accelerator's on-chip KV SRAM: resident sessions are
//! bounded by a **byte budget** (not a session count), LRU-evicted when
//! the budget is exceeded — the coordinator-level counterpart of the
//! paper's "KV sub-blocks preloaded into local buffers" assumption
//! (Section III-B).  A session's charge is its prepared form's
//! chunk-granular plane bytes ([`PreparedKv::resident_bytes`]), so many
//! short-prefill decode sessions fit where one full session would; the
//! charge grows as appends land.
//!
//! Admission is explicit: a `put`/`append` that cannot fit inside the
//! budget even after evicting every unpinned session **fails** instead
//! of silently dropping someone else's resident state; the error
//! surfaces through `Server::submit_append` acknowledgements and
//! `KvStore::put` results.
//!
//! Sessions with in-flight work are **pinned** ([`KvStore::pin`] at
//! enqueue, [`KvStore::unpin`] at delivery): a pinned session is never
//! an eviction victim, so a query queued in the batcher can no longer
//! race an eviction into a spurious "unknown session" failure (pinned by
//! `rust/tests/byte_budget.rs`).
//!
//! Each resident entry carries an [`Arc<PreparedKv>`] built **once** at
//! `put()`: V's linear->log conversion is paid at session load, never per
//! batch (pinned by `rust/tests/kv_prepare_once.rs`).  The LRU is a
//! generation counter — `get()` is one HashMap probe and a u64 bump under
//! the lock, with no list walks or key clones on the request path.
//!
//! Autoregressive decode grows a session one (or a few) rows per step via
//! [`KvStore::append`]: the new rows are BF16-rounded and linear->log
//! converted, then a fresh `Arc<PreparedKv>` built from the old one is
//! swapped in.  The prepared form is a table of `Arc`-shared fixed-size
//! chunks, so the swap-in copies only the chunk table and the
//! partially-filled tail chunk — per-step memory traffic tracks the
//! appended rows, not the sequence length (pinned by
//! `rust/tests/decode_append.rs` and `rust/tests/append_traffic.rs`).
//! `seq_len` is the maximum a session may grow to; `put()` accepts any
//! prefill length up to it.

use std::collections::HashMap;
use crate::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::attention::prepared::{row_bytes, PreparedKv};
use crate::Mat;

/// One resident session's KV data.  A single `Arc<PreparedKv>` is the
/// whole state: it owns the raw BF16-rounded matrices (PJRT backends
/// materialize those for the kernel) *and* the prepared log-domain lanes
/// the simulated accelerator executes against — so the raw and prepared
/// views can never disagree.
#[derive(Clone)]
pub struct KvEntry {
    prepared: Arc<PreparedKv>,
}

impl KvEntry {
    /// Build an entry (and its prepared form) from owned matrices.
    /// No rounding is applied — callers own the ingress convention.
    pub fn new(k: Mat, v: Mat) -> KvEntry {
        KvEntry { prepared: Arc::new(PreparedKv::new(k, v)) }
    }

    pub fn prepared(&self) -> &Arc<PreparedKv> {
        &self.prepared
    }
}

struct Slot {
    entry: KvEntry,
    /// Generation stamp of the last touch; smallest = LRU victim.
    last_used: u64,
    /// Byte charge of this session against the store budget.
    bytes: usize,
    /// Outstanding in-flight references; a pinned slot is never evicted.
    pins: u32,
}

struct Inner {
    budget_bytes: usize,
    used_bytes: usize,
    entries: HashMap<String, Slot>,
    /// Monotonic access generation counter.
    tick: u64,
    evictions: u64,
}

impl Inner {
    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Make room for `new_bytes` to be charged to `session` (whose
    /// current charge, if resident, is about to be released): evict
    /// unpinned LRU victims — never `session` itself — until the budget
    /// holds, or fail if only pinned sessions remain.  Call *before*
    /// applying the insert/replace so a rejected write leaves the store
    /// untouched.
    fn admit(&mut self, session: &str, new_bytes: usize) -> Result<()> {
        if new_bytes > self.budget_bytes {
            bail!(
                "session {session:?} needs {new_bytes} B, exceeding the whole KV byte budget \
                 ({} B)",
                self.budget_bytes
            );
        }
        loop {
            let replaced = self.entries.get(session).map(|s| s.bytes).unwrap_or(0);
            if self.used_bytes - replaced + new_bytes <= self.budget_bytes {
                return Ok(());
            }
            let victim = self
                .entries
                .iter()
                .filter(|(name, slot)| slot.pins == 0 && name.as_str() != session)
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(name, _)| name.clone());
            match victim {
                Some(name) => {
                    // the victim was selected from the live map above,
                    // but tolerate a phantom miss instead of panicking
                    // a serve path holding the store lock
                    if let Some(gone) = self.entries.remove(&name) {
                        self.used_bytes -= gone.bytes;
                        self.evictions += 1;
                    }
                }
                None => bail!(
                    "KV byte budget exhausted admitting {session:?} ({new_bytes} B): \
                     {} of {} B used and every other resident session is pinned",
                    self.used_bytes - replaced,
                    self.budget_bytes
                ),
            }
        }
    }

    /// Charge `bytes` to `session`, replacing its entry (pins and any
    /// prior charge carry over correctly).
    fn install(&mut self, session: &str, entry: KvEntry, bytes: usize) {
        let stamp = self.next_tick();
        match self.entries.get_mut(session) {
            Some(slot) => {
                self.used_bytes = self.used_bytes - slot.bytes + bytes;
                slot.entry = entry;
                slot.bytes = bytes;
                slot.last_used = stamp;
            }
            None => {
                self.used_bytes += bytes;
                self.entries.insert(
                    session.to_string(),
                    Slot { entry, last_used: stamp, bytes, pins: 0 },
                );
            }
        }
    }
}

/// Thread-safe KV session store with byte-budget LRU eviction and
/// in-flight pinning.
pub struct KvStore {
    seq_len: usize,
    head_dim: usize,
    inner: Mutex<Inner>,
}

impl KvStore {
    /// Budget expressed in sessions: room for `capacity` *full*
    /// (`seq_len`-row) sessions' prepared bytes.  Shorter sessions
    /// charge less, so more of them fit — eviction is by bytes, not
    /// count.
    pub fn new(seq_len: usize, head_dim: usize, capacity: usize) -> KvStore {
        let full = seq_len.max(1) * row_bytes(head_dim, head_dim);
        KvStore::with_byte_budget(seq_len, head_dim, capacity.max(1) * full)
    }

    /// Budget expressed directly in bytes of prepared KV planes.
    pub fn with_byte_budget(seq_len: usize, head_dim: usize, budget_bytes: usize) -> KvStore {
        KvStore {
            seq_len,
            head_dim,
            inner: Mutex::new(Inner {
                budget_bytes: budget_bytes.max(1),
                used_bytes: 0,
                entries: HashMap::new(),
                tick: 0,
                evictions: 0,
            }),
        }
    }

    /// Modelled SRAM bytes of one full session (BF16 K + V) — the
    /// hardware-facing figure; the eviction budget accounts the host
    /// prepared-plane bytes instead (see [`KvStore::budget_bytes`]).
    pub fn session_bytes(&self) -> usize {
        2 * self.seq_len * self.head_dim * 2
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Insert (or replace) a session's KV matrices.  The prefill may be
    /// any length `1..=seq_len` (a decode session grows the rest via
    /// [`KvStore::append`]).  The BF16 rounding and the one-time V->LNS
    /// preparation happen *outside* the lock.  Fails (without touching
    /// the store) when the session cannot fit inside the byte budget
    /// after evicting every unpinned resident session.
    pub fn put(&self, session: &str, k: Mat, v: Mat) -> Result<()> {
        if !(1..=self.seq_len).contains(&k.rows) || k.cols != self.head_dim {
            bail!(
                "K shape {}x{} incompatible with store geometry (up to {})x{}",
                k.rows, k.cols, self.seq_len, self.head_dim
            );
        }
        if v.rows != k.rows || v.cols != k.cols {
            bail!("V shape mismatch");
        }
        let entry = KvEntry::new(k.round_bf16(), v.round_bf16());
        let bytes = entry.prepared.resident_bytes();
        let mut g = self.inner.lock();
        g.admit(session, bytes)?;
        g.install(session, entry, bytes);
        Ok(())
    }

    /// Append decode-step rows to a resident session: BF16-round the new
    /// rows, convert **only them** to the log domain, and swap in a new
    /// [`Arc<PreparedKv>`] built from the old one (copy-on-write at chunk
    /// granularity — filled chunks stay shared, only the tail chunk and
    /// the chunk table are copied).  In-flight batches holding the old
    /// `Arc` keep computing against the pre-append snapshot; requests
    /// arriving after this returns see the grown KV.  Refreshes the
    /// session's LRU stamp, and fails — leaving the session untouched —
    /// when the grown charge cannot fit inside the byte budget after
    /// evicting every unpinned *other* session.
    ///
    /// The tail-chunk copy and the per-row conversion run **outside**
    /// the store lock (other sessions' `get`/`put` are never stalled
    /// behind a decode session); the swap-in re-checks by `Arc` identity
    /// that the session was not concurrently replaced and retries
    /// against the new base if it was.
    pub fn append(&self, session: &str, k_rows: Mat, v_rows: Mat) -> Result<()> {
        if k_rows.cols != self.head_dim || v_rows.cols != self.head_dim {
            bail!(
                "append dims {}x{} / {}x{} != head dim {}",
                k_rows.rows, k_rows.cols, v_rows.rows, v_rows.cols, self.head_dim
            );
        }
        if k_rows.rows != v_rows.rows {
            bail!("K/V append row count mismatch");
        }
        if k_rows.rows == 0 {
            bail!("empty append");
        }
        let kb = k_rows.round_bf16();
        let vb = v_rows.round_bf16();
        loop {
            // snapshot the base under the lock (an Arc clone); the LRU
            // stamp is refreshed only on the successful swap-in, so a
            // rejected (e.g. over-capacity) append does not count as use
            let base = {
                let g = self.inner.lock();
                match g.entries.get(session) {
                    Some(slot) => slot.entry.prepared.clone(),
                    None => bail!("unknown session {session:?}"),
                }
            };
            if base.n() + kb.rows > self.seq_len {
                bail!(
                    "append overflows session capacity: {} + {} > {}",
                    base.n(), kb.rows, self.seq_len
                );
            }
            // rebuild outside the lock
            let next = Arc::new(base.appended(&kb, &vb));
            let bytes = next.resident_bytes();
            // swap in, unless the session was replaced meanwhile (a
            // concurrent put/append won the race) — then retry on the
            // new base so no write is ever silently dropped
            let mut g = self.inner.lock();
            match g.entries.get(session) {
                Some(slot) if Arc::ptr_eq(&slot.entry.prepared, &base) => {}
                Some(_) => continue,
                None => bail!("unknown session {session:?}"),
            }
            g.admit(session, bytes)?;
            g.install(session, KvEntry { prepared: next }, bytes);
            return Ok(());
        }
    }

    /// Fetch a session, refreshing its LRU stamp (O(1) under the lock).
    pub fn get(&self, session: &str) -> Option<KvEntry> {
        let mut g = self.inner.lock();
        let stamp = g.next_tick();
        let slot = g.entries.get_mut(session)?;
        slot.last_used = stamp;
        Some(slot.entry.clone())
    }

    /// Mark a session as having in-flight work: refreshes its LRU stamp
    /// and excludes it from eviction until the matching [`KvStore::unpin`].
    /// Returns `false` (no pin taken) when the session is not resident.
    pub fn pin(&self, session: &str) -> bool {
        let mut g = self.inner.lock();
        let stamp = g.next_tick();
        match g.entries.get_mut(session) {
            Some(slot) => {
                slot.pins += 1;
                slot.last_used = stamp;
                true
            }
            None => false,
        }
    }

    /// Release one in-flight pin (the session becomes evictable again
    /// once its pin count reaches zero).  A no-op for unknown sessions.
    pub fn unpin(&self, session: &str) {
        let mut g = self.inner.lock();
        if let Some(slot) = g.entries.get_mut(session) {
            slot.pins = slot.pins.saturating_sub(1);
        }
    }

    /// Forcibly remove a session regardless of pins — the cancellation
    /// path, where a dead client must free its bytes mid-decode without
    /// waiting for its queued requests to drain.  Safe because in-flight
    /// computes hold `Arc<PreparedKv>` snapshots and a late `unpin` on a
    /// gone session is a no-op.  (If the *same* session is re-`put`
    /// before the cancelled requests are failed, their stale unpins can
    /// release the fresh slot's pins early — callers cancelling with
    /// eviction should treat the session name as dead.)  Returns the
    /// freed bytes, or `None` when the session was not resident.
    pub fn evict(&self, session: &str) -> Option<usize> {
        let mut g = self.inner.lock();
        let slot = g.entries.remove(session)?;
        g.used_bytes -= slot.bytes;
        g.evictions += 1;
        Some(slot.bytes)
    }

    /// Is the session resident?  (No LRU refresh — diagnostics only.)
    pub fn contains(&self, session: &str) -> bool {
        self.inner.lock().entries.contains_key(session)
    }

    /// Byte charge of one resident session (diagnostics only).
    pub fn session_resident_bytes(&self, session: &str) -> Option<usize> {
        self.inner.lock().entries.get(session).map(|s| s.bytes)
    }

    /// Resident KV rows (tokens) of one session, or `None` when it is
    /// not resident.  No LRU refresh — the continuous scheduler's
    /// token-budget accounting must not count as a use.
    pub fn session_rows(&self, session: &str) -> Option<usize> {
        self.inner.lock().entries.get(session).map(|s| s.entry.prepared.n())
    }

    pub fn resident(&self) -> usize {
        self.inner.lock().entries.len()
    }

    /// Sessions currently holding at least one in-flight pin
    /// (diagnostics: a steady-state serving loop must return this to 0 —
    /// a leak here makes sessions permanently unevictable).
    pub fn pinned_sessions(&self) -> usize {
        self.inner.lock().entries.values().filter(|s| s.pins > 0).count()
    }

    /// Total byte charge of all resident sessions.
    pub fn used_bytes(&self) -> usize {
        self.inner.lock().used_bytes
    }

    /// The eviction budget, in prepared-plane bytes.
    pub fn budget_bytes(&self) -> usize {
        self.inner.lock().budget_bytes
    }

    pub fn evictions(&self) -> u64 {
        self.inner.lock().evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(n: usize, d: usize, fill: f32) -> (Mat, Mat) {
        (Mat::from_fn(n, d, |_, _| fill), Mat::from_fn(n, d, |_, _| -fill))
    }

    #[test]
    fn put_get_roundtrip() {
        let store = KvStore::new(16, 8, 2);
        let (k, v) = kv(16, 8, 1.0);
        store.put("a", k, v).unwrap();
        let e = store.get("a").unwrap();
        assert_eq!(e.prepared().k_row(0)[0], 1.0);
        assert_eq!(e.prepared().v_row(0)[0], -1.0);
        assert_eq!(e.prepared().n(), 16);
        assert_eq!(store.used_bytes(), 16 * row_bytes(8, 8));
        assert_eq!(store.session_resident_bytes("a"), Some(16 * row_bytes(8, 8)));
    }

    #[test]
    fn evict_removes_even_pinned_sessions_and_frees_bytes() {
        let store = KvStore::new(16, 8, 2);
        let (k, v) = kv(16, 8, 1.0);
        store.put("a", k, v).unwrap();
        assert!(store.pin("a"));
        // pinned sessions resist LRU eviction but not forced eviction
        let freed = store.evict("a").expect("resident session evicts");
        assert_eq!(freed, 16 * row_bytes(8, 8));
        assert!(!store.contains("a"));
        assert_eq!(store.used_bytes(), 0);
        assert_eq!(store.evictions(), 1);
        assert_eq!(store.pinned_sessions(), 0);
        // the in-flight holder's late unpin is a harmless no-op
        store.unpin("a");
        assert!(store.evict("a").is_none(), "double evict reports not-resident");
    }

    #[test]
    fn rejects_wrong_geometry() {
        let store = KvStore::new(16, 8, 2);
        let (k, v) = kv(16, 4, 1.0); // wrong head dim
        assert!(store.put("a", k, v).is_err());
        let (k, v) = kv(32, 8, 1.0); // over capacity
        assert!(store.put("a", k, v).is_err());
        let (k, v) = kv(0, 8, 1.0); // empty prefill
        assert!(store.put("a", k, v).is_err());
        let (k, v) = kv(8, 8, 1.0); // short prefill is fine (decode grows it)
        assert!(store.put("a", k, v).is_ok());
        assert_eq!(store.get("a").unwrap().prepared().n(), 8);
    }

    #[test]
    fn append_grows_resident_session_matching_full_put() {
        let store = KvStore::new(16, 4, 2);
        let full_k = Mat::from_fn(10, 4, |r, c| (r * 4 + c) as f32 * 0.25 - 1.0);
        let full_v = Mat::from_fn(10, 4, |r, c| 1.0 - (r * 4 + c) as f32 * 0.125);
        store.put("s", full_k.rows_slice(0, 6), full_v.rows_slice(0, 6)).unwrap();
        store.append("s", full_k.rows_slice(6, 7), full_v.rows_slice(6, 7)).unwrap();
        store.append("s", full_k.rows_slice(7, 10), full_v.rows_slice(7, 10)).unwrap();
        let grown = store.get("s").unwrap();
        let reference = KvStore::new(16, 4, 2);
        reference.put("s", full_k, full_v).unwrap();
        let full = reference.get("s").unwrap();
        assert_eq!(grown.prepared().n(), 10);
        assert_eq!(grown.prepared().k_mat().data, full.prepared().k_mat().data);
        assert_eq!(grown.prepared().v_mat().data, full.prepared().v_mat().data);
        assert_eq!(grown.prepared().v_lns_mat(), full.prepared().v_lns_mat());
        assert_eq!(grown.prepared().blocks(), full.prepared().blocks());
        // the byte charge followed the growth
        assert_eq!(store.session_resident_bytes("s"), Some(10 * row_bytes(4, 4)));
    }

    #[test]
    fn append_error_paths() {
        let store = KvStore::new(8, 4, 2);
        let (k, v) = kv(6, 4, 1.0);
        store.put("s", k, v).unwrap();
        let (k1, v1) = kv(1, 4, 2.0);
        assert!(store.append("missing", k1.clone(), v1.clone()).is_err(), "unknown session");
        let (kw, vw) = kv(1, 3, 2.0);
        assert!(store.append("s", kw, vw).is_err(), "wrong head dim");
        let (k0, v0) = kv(0, 4, 2.0);
        assert!(store.append("s", k0, v0).is_err(), "empty append");
        let (k3, v3) = kv(3, 4, 2.0);
        assert!(store.append("s", k3, v3).is_err(), "overflows capacity 8");
        // failed appends must leave the session untouched
        assert_eq!(store.get("s").unwrap().prepared().n(), 6);
        assert!(store.append("s", k1, v1).is_ok());
        assert_eq!(store.get("s").unwrap().prepared().n(), 7);
    }

    #[test]
    fn session_rows_reports_growth_without_refreshing_lru() {
        let store = KvStore::new(8, 4, 2); // budget: two full 8-row sessions
        let (k, v) = kv(6, 4, 0.0);
        store.put("a", k, v).unwrap();
        let (kf, vf) = kv(8, 4, 0.0);
        store.put("b", kf.clone(), vf.clone()).unwrap();
        assert_eq!(store.session_rows("a"), Some(6));
        assert_eq!(store.session_rows("missing"), None);
        let (k1, v1) = kv(1, 4, 1.0);
        store.append("a", k1, v1).unwrap();
        assert_eq!(store.session_rows("a"), Some(7), "row count tracks appends");
        store.get("a"); // make "a" most recently *used*
        // probe "b" last: were the probe an LRU touch, "b" would now be
        // the most recent and "a" the victim below
        assert_eq!(store.session_rows("b"), Some(8));
        store.put("c", kf, vf).unwrap(); // over budget: evicts the true LRU
        assert!(store.contains("a"));
        assert!(!store.contains("b"), "session_rows must not refresh LRU");
    }

    #[test]
    fn append_refreshes_lru() {
        let store = KvStore::new(8, 4, 2); // budget: two full 8-row sessions
        let (k, v) = kv(6, 4, 0.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        let (kf, vf) = kv(8, 4, 0.0);
        store.put("b", kf.clone(), vf.clone()).unwrap();
        let (k1, v1) = kv(1, 4, 1.0);
        store.append("a", k1, v1).unwrap(); // refresh a (now 7 rows)
        store.put("c", kf, vf).unwrap(); // 7+8+8 > 16 rows: evicts b, not a
        assert!(store.contains("a"));
        assert!(!store.contains("b"));
    }

    #[test]
    fn inflight_snapshot_survives_append() {
        // a batch holding the old Arc keeps the pre-append view
        let store = KvStore::new(8, 4, 1);
        let (k, v) = kv(4, 4, 1.0);
        store.put("s", k, v).unwrap();
        let snapshot = store.get("s").unwrap();
        let (k1, v1) = kv(2, 4, 3.0);
        store.append("s", k1, v1).unwrap();
        assert_eq!(snapshot.prepared().n(), 4, "in-flight entry must be immutable");
        assert_eq!(store.get("s").unwrap().prepared().n(), 6);
    }

    #[test]
    fn lru_evicts_oldest() {
        let store = KvStore::new(4, 4, 2);
        for (i, name) in ["a", "b", "c"].iter().enumerate() {
            let (k, v) = kv(4, 4, i as f32);
            store.put(name, k, v).unwrap();
        }
        assert_eq!(store.resident(), 2);
        assert!(store.get("a").is_none(), "oldest should be evicted");
        assert!(store.get("b").is_some());
        assert_eq!(store.evictions(), 1);
    }

    #[test]
    fn byte_budget_packs_short_sessions_where_count_lru_would_evict() {
        // the budget holds two *full* 16-row sessions; four 8-row decode
        // prefills fit simultaneously (the old count-based store would
        // have started evicting at the third)
        let store = KvStore::new(16, 4, 2);
        for name in ["a", "b", "c", "d"] {
            let (k, v) = kv(8, 4, 1.0);
            store.put(name, k, v).unwrap();
        }
        assert_eq!(store.resident(), 4, "byte budget must pack partial sessions");
        assert_eq!(store.evictions(), 0);
        assert_eq!(store.used_bytes(), 4 * 8 * row_bytes(4, 4));
        // a fifth spills the budget: exactly one eviction (the LRU)
        let (k, v) = kv(8, 4, 1.0);
        store.put("e", k, v).unwrap();
        assert_eq!(store.evictions(), 1);
        assert!(!store.contains("a"));
        assert!(store.contains("e"));
    }

    #[test]
    fn oversized_session_is_rejected_not_silently_evicting_everyone() {
        let store = KvStore::with_byte_budget(32, 4, 10 * row_bytes(4, 4));
        let (k, v) = kv(8, 4, 1.0);
        store.put("resident", k, v).unwrap();
        let (k, v) = kv(16, 4, 2.0); // 16 rows > 10-row budget
        let err = store.put("huge", k, v).unwrap_err();
        assert!(err.to_string().contains("byte budget"), "{err}");
        assert!(store.contains("resident"), "rejected put must not evict anyone");
        assert_eq!(store.evictions(), 0);
    }

    #[test]
    fn pinned_sessions_are_never_evicted() {
        let store = KvStore::new(4, 4, 2); // budget: two full sessions
        let (k, v) = kv(4, 4, 1.0);
        store.put("pinned", k.clone(), v.clone()).unwrap();
        assert!(store.pin("pinned"));
        store.put("other", k.clone(), v.clone()).unwrap();
        // a third full session must evict "other" (LRU among unpinned),
        // even though "pinned" is older by stamp without the pin refresh
        store.get("other"); // make "other" the most recently used
        store.put("third", k.clone(), v.clone()).unwrap();
        assert!(store.contains("pinned"), "pinned session evicted");
        assert!(!store.contains("other"), "unpinned LRU should have been the victim");
        // once every other session is pinned, admission fails loudly
        assert!(store.pin("third"));
        let err = store.put("fourth", k.clone(), v.clone()).unwrap_err();
        assert!(err.to_string().contains("pinned"), "{err}");
        // unpinning makes room again
        store.unpin("third");
        store.put("fourth", k, v).unwrap();
        assert!(!store.contains("third"));
        assert!(store.contains("pinned"));
        // balanced unpin on the survivor
        store.unpin("pinned");
        assert!(!store.pin("missing"), "pin of a non-resident session takes no pin");
    }

    #[test]
    fn append_budget_overflow_fails_cleanly_when_others_pinned() {
        // budget: 8 rows total; "grow" at 4 rows, "pinned" at 4 rows
        let store = KvStore::with_byte_budget(8, 4, 8 * row_bytes(4, 4));
        let (k, v) = kv(4, 4, 1.0);
        store.put("grow", k.clone(), v.clone()).unwrap();
        store.put("pinned", k, v).unwrap();
        assert!(store.pin("pinned"));
        let (k1, v1) = kv(1, 4, 2.0);
        let err = store.append("grow", k1.clone(), v1.clone()).unwrap_err();
        assert!(err.to_string().contains("pinned"), "{err}");
        assert_eq!(store.get("grow").unwrap().prepared().n(), 4, "failed append must not apply");
        // releasing the pin lets the same append evict and land
        store.unpin("pinned");
        store.append("grow", k1, v1).unwrap();
        assert_eq!(store.get("grow").unwrap().prepared().n(), 5);
        assert!(!store.contains("pinned"));
    }

    #[test]
    fn get_refreshes_lru() {
        let store = KvStore::new(4, 4, 2);
        let (k, v) = kv(4, 4, 0.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        store.put("b", k.clone(), v.clone()).unwrap();
        store.get("a"); // refresh a
        store.put("c", k, v).unwrap(); // evicts b, not a
        assert!(store.get("a").is_some());
        assert!(store.get("b").is_none());
    }

    #[test]
    fn replacing_a_session_refreshes_it() {
        let store = KvStore::new(4, 4, 2);
        let (k, v) = kv(4, 4, 0.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        store.put("b", k.clone(), v.clone()).unwrap();
        store.put("a", k.clone(), v.clone()).unwrap(); // re-put refreshes a
        store.put("c", k, v).unwrap(); // evicts b
        assert!(store.get("a").is_some());
        assert!(store.get("b").is_none());
        assert!(store.get("c").is_some());
    }

    #[test]
    fn replacing_a_session_releases_its_old_charge() {
        let store = KvStore::new(16, 4, 2);
        let (k, v) = kv(16, 4, 1.0);
        store.put("a", k, v).unwrap();
        assert_eq!(store.used_bytes(), 16 * row_bytes(4, 4));
        let (k, v) = kv(2, 4, 1.0);
        store.put("a", k, v).unwrap(); // shrinks
        assert_eq!(store.used_bytes(), 2 * row_bytes(4, 4));
        assert_eq!(store.resident(), 1);
    }

    #[test]
    fn session_bytes_matches_bf16_kv() {
        let store = KvStore::new(1024, 64, 1);
        assert_eq!(store.session_bytes(), 2 * 1024 * 64 * 2);
    }

    #[test]
    fn concurrent_gets_and_puts_stay_consistent() {
        // request-path contention: many readers refreshing LRU stamps
        // while writers insert/evict.  The store must never exceed
        // its byte budget and never hand out a torn entry — every session
        // name encodes its fill value, so any `Some` result is verifiable.
        let store = Arc::new(KvStore::new(8, 4, 3));
        let budget = store.budget_bytes();
        let fill = |s: usize| s as f32 + 1.0;
        let mut handles = Vec::new();
        for t in 0..6usize {
            let store = store.clone();
            handles.push(crate::sync::thread::spawn(move || {
                let mut hits = 0u64;
                for i in 0..500usize {
                    let s = (t + i) % 5;
                    if t < 2 {
                        let (k, v) = kv(8, 4, fill(s));
                        store.put(&format!("sess-{s}"), k, v).unwrap();
                    }
                    if let Some(e) = store.get(&format!("sess-{s}")) {
                        assert_eq!(e.prepared().k_row(0)[0], fill(s), "torn entry for sess-{s}");
                        assert_eq!(e.prepared().v_row(0)[0], -fill(s));
                        assert_eq!(e.prepared().n(), 8);
                        hits += 1;
                    }
                    assert!(store.used_bytes() <= budget);
                }
                hits
            }));
        }
        let hits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(hits > 0, "at least some gets must land on resident sessions");
        assert!(store.resident() <= 3, "resident {} sessions exceed budget", store.resident());
    }
}

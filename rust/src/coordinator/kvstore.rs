//! Session-keyed KV buffer manager.
//!
//! Models the accelerator's on-chip KV SRAM: a bounded number of resident
//! sessions (each one `seq_len x d` K and V), LRU eviction when capacity
//! is exceeded — the coordinator-level counterpart of the paper's
//! "KV sub-blocks preloaded into local buffers" assumption (Section III-B).
//!
//! Each resident entry carries an [`Arc<PreparedKv>`] built **once** at
//! `put()`: V's linear->log conversion is paid at session load, never per
//! batch (pinned by `rust/tests/kv_prepare_once.rs`).  The LRU is a
//! generation counter — `get()` is one HashMap probe and a u64 bump under
//! the lock, with no list walks or key clones on the request path.
//!
//! Autoregressive decode grows a session one (or a few) rows per step via
//! [`KvStore::append`]: the new rows are BF16-rounded and linear->log
//! converted, then a fresh `Arc<PreparedKv>` built from the old one is
//! swapped in — resident rows are never re-rounded or re-converted, so
//! per-step cost tracks the appended rows, not the sequence length
//! (pinned by `rust/tests/decode_append.rs`).  `seq_len` is the maximum a
//! session may grow to; `put()` accepts any prefill length up to it.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::attention::prepared::PreparedKv;
use crate::Mat;

/// One resident session's KV data.  A single `Arc<PreparedKv>` is the
/// whole state: it owns the raw BF16-rounded matrices (PJRT backends
/// ship those to the kernel) *and* the prepared log-domain lanes the
/// simulated accelerator executes against — so the raw and prepared
/// views can never disagree.
#[derive(Clone)]
pub struct KvEntry {
    prepared: Arc<PreparedKv>,
}

impl KvEntry {
    /// Build an entry (and its prepared form) from owned matrices.
    /// No rounding is applied — callers own the ingress convention.
    pub fn new(k: Mat, v: Mat) -> KvEntry {
        KvEntry { prepared: Arc::new(PreparedKv::new(k, v)) }
    }

    pub fn prepared(&self) -> &Arc<PreparedKv> {
        &self.prepared
    }

    pub fn k(&self) -> &Mat {
        self.prepared.k()
    }

    pub fn v(&self) -> &Mat {
        self.prepared.v()
    }
}

struct Slot {
    entry: KvEntry,
    /// Generation stamp of the last touch; smallest = LRU victim.
    last_used: u64,
}

struct Inner {
    capacity: usize,
    entries: HashMap<String, Slot>,
    /// Monotonic access generation counter.
    tick: u64,
    evictions: u64,
}

impl Inner {
    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    fn evict_to_capacity(&mut self) {
        while self.entries.len() > self.capacity {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(name, _)| name.clone());
            match victim {
                Some(name) => {
                    self.entries.remove(&name);
                    self.evictions += 1;
                }
                None => break,
            }
        }
    }
}

/// Thread-safe KV session store with generation-counter LRU eviction.
pub struct KvStore {
    seq_len: usize,
    head_dim: usize,
    inner: Mutex<Inner>,
}

impl KvStore {
    /// `capacity`: max resident sessions (SRAM budget / per-session bytes).
    pub fn new(seq_len: usize, head_dim: usize, capacity: usize) -> KvStore {
        KvStore {
            seq_len,
            head_dim,
            inner: Mutex::new(Inner {
                capacity: capacity.max(1),
                entries: HashMap::new(),
                tick: 0,
                evictions: 0,
            }),
        }
    }

    /// Bytes one session occupies (BF16 K + V).
    pub fn session_bytes(&self) -> usize {
        2 * self.seq_len * self.head_dim * 2
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Insert (or replace) a session's KV matrices.  The prefill may be
    /// any length `1..=seq_len` (a decode session grows the rest via
    /// [`KvStore::append`]).  The BF16 rounding and the one-time V->LNS
    /// preparation happen *outside* the lock.
    pub fn put(&self, session: &str, k: Mat, v: Mat) -> Result<()> {
        if !(1..=self.seq_len).contains(&k.rows) || k.cols != self.head_dim {
            bail!(
                "K shape {}x{} incompatible with store geometry (up to {})x{}",
                k.rows, k.cols, self.seq_len, self.head_dim
            );
        }
        if v.rows != k.rows || v.cols != k.cols {
            bail!("V shape mismatch");
        }
        let entry = KvEntry::new(k.round_bf16(), v.round_bf16());
        let mut g = self.inner.lock().unwrap();
        let stamp = g.next_tick();
        g.entries.insert(session.to_string(), Slot { entry, last_used: stamp });
        g.evict_to_capacity();
        Ok(())
    }

    /// Append decode-step rows to a resident session: BF16-round the new
    /// rows, convert **only them** to the log domain, and swap in a new
    /// [`Arc<PreparedKv>`] built from the old one (copy-on-write — the
    /// resident rows are memcpy'd, never re-rounded or re-converted).
    /// In-flight batches holding the old `Arc` keep computing against the
    /// pre-append snapshot; requests arriving after this returns see the
    /// grown KV.  Refreshes the session's LRU stamp.
    ///
    /// The O(resident) plane copy and the per-row conversion run
    /// **outside** the store lock (other sessions' `get`/`put` are never
    /// stalled behind a long decode session); the swap-in re-checks by
    /// `Arc` identity that the session was not concurrently replaced and
    /// retries against the new base if it was.
    pub fn append(&self, session: &str, k_rows: Mat, v_rows: Mat) -> Result<()> {
        if k_rows.cols != self.head_dim || v_rows.cols != self.head_dim {
            bail!(
                "append dims {}x{} / {}x{} != head dim {}",
                k_rows.rows, k_rows.cols, v_rows.rows, v_rows.cols, self.head_dim
            );
        }
        if k_rows.rows != v_rows.rows {
            bail!("K/V append row count mismatch");
        }
        if k_rows.rows == 0 {
            bail!("empty append");
        }
        let kb = k_rows.round_bf16();
        let vb = v_rows.round_bf16();
        loop {
            // snapshot the base under the lock (an Arc clone); the LRU
            // stamp is refreshed only on the successful swap-in, so a
            // rejected (e.g. over-capacity) append does not count as use
            let base = {
                let g = self.inner.lock().unwrap();
                match g.entries.get(session) {
                    Some(slot) => slot.entry.prepared.clone(),
                    None => bail!("unknown session {session:?}"),
                }
            };
            if base.n() + kb.rows > self.seq_len {
                bail!(
                    "append overflows session capacity: {} + {} > {}",
                    base.n(), kb.rows, self.seq_len
                );
            }
            // rebuild outside the lock
            let next = Arc::new(base.appended(&kb, &vb));
            // swap in, unless the session was replaced meanwhile (a
            // concurrent put/append won the race) — then retry on the
            // new base so no write is ever silently dropped
            let mut g = self.inner.lock().unwrap();
            let stamp = g.next_tick();
            let slot = match g.entries.get_mut(session) {
                Some(slot) => slot,
                None => bail!("unknown session {session:?}"),
            };
            if Arc::ptr_eq(&slot.entry.prepared, &base) {
                slot.entry = KvEntry { prepared: next };
                slot.last_used = stamp;
                return Ok(());
            }
        }
    }

    /// Fetch a session, refreshing its LRU stamp (O(1) under the lock).
    pub fn get(&self, session: &str) -> Option<KvEntry> {
        let mut g = self.inner.lock().unwrap();
        let stamp = g.next_tick();
        let slot = g.entries.get_mut(session)?;
        slot.last_used = stamp;
        Some(slot.entry.clone())
    }

    pub fn resident(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(n: usize, d: usize, fill: f32) -> (Mat, Mat) {
        (Mat::from_fn(n, d, |_, _| fill), Mat::from_fn(n, d, |_, _| -fill))
    }

    #[test]
    fn put_get_roundtrip() {
        let store = KvStore::new(16, 8, 2);
        let (k, v) = kv(16, 8, 1.0);
        store.put("a", k, v).unwrap();
        let e = store.get("a").unwrap();
        assert_eq!(e.k().at(0, 0), 1.0);
        assert_eq!(e.v().at(0, 0), -1.0);
        // the raw accessors alias the prepared form's own matrices
        assert!(std::ptr::eq(e.k(), e.prepared().k()));
        assert!(std::ptr::eq(e.v(), e.prepared().v()));
        assert_eq!(e.prepared().n(), 16);
    }

    #[test]
    fn rejects_wrong_geometry() {
        let store = KvStore::new(16, 8, 2);
        let (k, v) = kv(16, 4, 1.0); // wrong head dim
        assert!(store.put("a", k, v).is_err());
        let (k, v) = kv(32, 8, 1.0); // over capacity
        assert!(store.put("a", k, v).is_err());
        let (k, v) = kv(0, 8, 1.0); // empty prefill
        assert!(store.put("a", k, v).is_err());
        let (k, v) = kv(8, 8, 1.0); // short prefill is fine (decode grows it)
        assert!(store.put("a", k, v).is_ok());
        assert_eq!(store.get("a").unwrap().prepared().n(), 8);
    }

    #[test]
    fn append_grows_resident_session_matching_full_put() {
        let store = KvStore::new(16, 4, 2);
        let full_k = Mat::from_fn(10, 4, |r, c| (r * 4 + c) as f32 * 0.25 - 1.0);
        let full_v = Mat::from_fn(10, 4, |r, c| 1.0 - (r * 4 + c) as f32 * 0.125);
        store.put("s", full_k.rows_slice(0, 6), full_v.rows_slice(0, 6)).unwrap();
        store.append("s", full_k.rows_slice(6, 7), full_v.rows_slice(6, 7)).unwrap();
        store.append("s", full_k.rows_slice(7, 10), full_v.rows_slice(7, 10)).unwrap();
        let grown = store.get("s").unwrap();
        let reference = KvStore::new(16, 4, 2);
        reference.put("s", full_k, full_v).unwrap();
        let full = reference.get("s").unwrap();
        assert_eq!(grown.prepared().n(), 10);
        assert_eq!(grown.k().data, full.k().data);
        assert_eq!(grown.v().data, full.v().data);
        assert_eq!(grown.prepared().v_lns(), full.prepared().v_lns());
        assert_eq!(grown.prepared().blocks(), full.prepared().blocks());
    }

    #[test]
    fn append_error_paths() {
        let store = KvStore::new(8, 4, 2);
        let (k, v) = kv(6, 4, 1.0);
        store.put("s", k, v).unwrap();
        let (k1, v1) = kv(1, 4, 2.0);
        assert!(store.append("missing", k1.clone(), v1.clone()).is_err(), "unknown session");
        let (kw, vw) = kv(1, 3, 2.0);
        assert!(store.append("s", kw, vw).is_err(), "wrong head dim");
        let (k0, v0) = kv(0, 4, 2.0);
        assert!(store.append("s", k0, v0).is_err(), "empty append");
        let (k3, v3) = kv(3, 4, 2.0);
        assert!(store.append("s", k3, v3).is_err(), "overflows capacity 8");
        // failed appends must leave the session untouched
        assert_eq!(store.get("s").unwrap().prepared().n(), 6);
        assert!(store.append("s", k1, v1).is_ok());
        assert_eq!(store.get("s").unwrap().prepared().n(), 7);
    }

    #[test]
    fn append_refreshes_lru() {
        let store = KvStore::new(4, 4, 2);
        let (k, v) = kv(2, 4, 0.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        store.put("b", k.clone(), v.clone()).unwrap();
        let (k1, v1) = kv(1, 4, 1.0);
        store.append("a", k1, v1).unwrap(); // refresh a
        store.put("c", k, v).unwrap(); // evicts b, not a
        assert!(store.get("a").is_some());
        assert!(store.get("b").is_none());
    }

    #[test]
    fn inflight_snapshot_survives_append() {
        // a batch holding the old Arc keeps the pre-append view
        let store = KvStore::new(8, 4, 1);
        let (k, v) = kv(4, 4, 1.0);
        store.put("s", k, v).unwrap();
        let snapshot = store.get("s").unwrap();
        let (k1, v1) = kv(2, 4, 3.0);
        store.append("s", k1, v1).unwrap();
        assert_eq!(snapshot.prepared().n(), 4, "in-flight entry must be immutable");
        assert_eq!(store.get("s").unwrap().prepared().n(), 6);
    }

    #[test]
    fn lru_evicts_oldest() {
        let store = KvStore::new(4, 4, 2);
        for (i, name) in ["a", "b", "c"].iter().enumerate() {
            let (k, v) = kv(4, 4, i as f32);
            store.put(name, k, v).unwrap();
        }
        assert_eq!(store.resident(), 2);
        assert!(store.get("a").is_none(), "oldest should be evicted");
        assert!(store.get("b").is_some());
        assert_eq!(store.evictions(), 1);
    }

    #[test]
    fn get_refreshes_lru() {
        let store = KvStore::new(4, 4, 2);
        let (k, v) = kv(4, 4, 0.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        store.put("b", k.clone(), v.clone()).unwrap();
        store.get("a"); // refresh a
        store.put("c", k, v).unwrap(); // evicts b, not a
        assert!(store.get("a").is_some());
        assert!(store.get("b").is_none());
    }

    #[test]
    fn replacing_a_session_refreshes_it() {
        let store = KvStore::new(4, 4, 2);
        let (k, v) = kv(4, 4, 0.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        store.put("b", k.clone(), v.clone()).unwrap();
        store.put("a", k.clone(), v.clone()).unwrap(); // re-put refreshes a
        store.put("c", k, v).unwrap(); // evicts b
        assert!(store.get("a").is_some());
        assert!(store.get("b").is_none());
        assert!(store.get("c").is_some());
    }

    #[test]
    fn session_bytes_matches_bf16_kv() {
        let store = KvStore::new(1024, 64, 1);
        assert_eq!(store.session_bytes(), 2 * 1024 * 64 * 2);
    }

    #[test]
    fn concurrent_gets_and_puts_stay_consistent() {
        // request-path contention: many readers refreshing LRU stamps
        // while writers insert/evict.  The store must never exceed
        // capacity and never hand out a torn entry — every session name
        // encodes its fill value, so any `Some` result is verifiable.
        let store = Arc::new(KvStore::new(8, 4, 3));
        let fill = |s: usize| s as f32 + 1.0;
        let mut handles = Vec::new();
        for t in 0..6usize {
            let store = store.clone();
            handles.push(std::thread::spawn(move || {
                let mut hits = 0u64;
                for i in 0..500usize {
                    let s = (t + i) % 5;
                    if t < 2 {
                        let (k, v) = kv(8, 4, fill(s));
                        store.put(&format!("sess-{s}"), k, v).unwrap();
                    }
                    if let Some(e) = store.get(&format!("sess-{s}")) {
                        assert_eq!(e.k().at(0, 0), fill(s), "torn entry for sess-{s}");
                        assert_eq!(e.v().at(0, 0), -fill(s));
                        assert_eq!(e.prepared().n(), 8);
                        hits += 1;
                    }
                    assert!(store.resident() <= 3);
                }
                hits
            }));
        }
        let hits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(hits > 0, "at least some gets must land on resident sessions");
        assert!(store.resident() <= 3, "resident {} > capacity", store.resident());
    }
}

//! Session-keyed KV buffer manager.
//!
//! Models the accelerator's on-chip KV SRAM: resident sessions are
//! bounded by a **byte budget** (not a session count), LRU-evicted when
//! the budget is exceeded — the coordinator-level counterpart of the
//! paper's "KV sub-blocks preloaded into local buffers" assumption
//! (Section III-B).  A session's charge is its prepared form's
//! chunk-granular plane bytes ([`PreparedKv::resident_bytes`]), so many
//! short-prefill decode sessions fit where one full session would; the
//! charge grows as appends land.
//!
//! Admission is explicit: a `put`/`append` that cannot fit inside the
//! budget even after evicting every unpinned session **fails** instead
//! of silently dropping someone else's resident state; the error
//! surfaces through `Server::submit_append` acknowledgements and
//! `KvStore::put` results.
//!
//! Sessions with in-flight work are **pinned** ([`KvStore::pin`] at
//! enqueue, [`KvStore::unpin`] at delivery): a pinned session is never
//! an eviction victim, so a query queued in the batcher can no longer
//! race an eviction into a spurious "unknown session" failure (pinned by
//! `rust/tests/byte_budget.rs`).
//!
//! Each resident entry carries an [`Arc<PreparedKv>`] built **once** at
//! `put()`: V's linear->log conversion is paid at session load, never per
//! batch (pinned by `rust/tests/kv_prepare_once.rs`).  The LRU is a
//! generation counter — `get()` is one HashMap probe and a u64 bump under
//! the lock, with no list walks or key clones on the request path.
//!
//! Autoregressive decode grows a session one (or a few) rows per step via
//! [`KvStore::append`]: the new rows are BF16-rounded and linear->log
//! converted, then a fresh `Arc<PreparedKv>` built from the old one is
//! swapped in.  The prepared form is a table of `Arc`-shared fixed-size
//! chunks, so the swap-in copies only the chunk table and the
//! partially-filled tail chunk — per-step memory traffic tracks the
//! appended rows, not the sequence length (pinned by
//! `rust/tests/decode_append.rs` and `rust/tests/append_traffic.rs`).
//! `seq_len` is the maximum a session may grow to; `put()` accepts any
//! prefill length up to it.
//!
//! ## Cross-session prefix sharing (the paged radix cache)
//!
//! Chunks are already append-stable and `Arc`-shared *within* a session;
//! the store exploits that *across* sessions too.  A radix **prefix
//! index** keys every full (capacity-aligned) chunk by the chain of
//! content hashes leading to it ([`chain_root`] -> [`chain_link`] over
//! [`chunk_row_hash`] values), so a `put` whose rounded rows repeat a
//! resident prefix resolves those chunks to the existing `Arc<KvChunk>`s
//! *before* any LNS conversion happens — a fleet of S sessions sharing a
//! P-row prompt stores and converts the prefix once, not S times
//! (pinned by `rust/tests/prefix_sharing.rs`).  Hashes are lookup keys
//! only: every resolved chunk is byte-verified against the rounded
//! source rows before it is installed ([`KvChunk::matches_rows`]), so a
//! hash collision can never alias one session's chunk into another's
//! table.  [`KvStore::fork`] goes
//! further: the child session's chunk table is a copy of the parent's
//! (every chunk shared, tail included), and the first append to either
//! branch copy-on-writes only that branch's tail chunk.
//!
//! Byte accounting is **refcount-aware**: a registry keyed on chunk
//! pointer identity charges each unique chunk once fleet-wide
//! (`used_bytes` is the sum over *unique* resident chunks), admission
//! credits dedup hits (a fully-shared put or fork admits at near-zero
//! cost), and eviction releases references — bytes are freed only when
//! the last resident session referencing a chunk goes, so no eviction
//! path can free a chunk another resident session still streams.
//! Deduped and forked sessions serve the exact same chunk objects the
//! grid already streams, so every output stays bit-identical to solo
//! serving by construction.

use std::collections::{HashMap, HashSet};
use crate::sync::atomic::Ordering;
use crate::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Result};

use crate::attention::prepared::{
    chain_link, chain_root, chunk_row_hash, row_bytes, KvChunk, PreparedKv, DEFAULT_BLOCK_ROWS,
};
use crate::Mat;

use super::metrics::Metrics;

/// One resident session's KV data.  A single `Arc<PreparedKv>` is the
/// whole state: it owns the raw BF16-rounded matrices (PJRT backends
/// materialize those for the kernel) *and* the prepared log-domain lanes
/// the simulated accelerator executes against — so the raw and prepared
/// views can never disagree.
#[derive(Clone)]
pub struct KvEntry {
    prepared: Arc<PreparedKv>,
}

impl KvEntry {
    /// Build an entry (and its prepared form) from owned matrices.
    /// No rounding is applied — callers own the ingress convention.
    pub fn new(k: Mat, v: Mat) -> KvEntry {
        KvEntry { prepared: Arc::new(PreparedKv::new(k, v)) }
    }

    pub fn prepared(&self) -> &Arc<PreparedKv> {
        &self.prepared
    }
}

struct Slot {
    entry: KvEntry,
    /// Generation stamp of the last touch; smallest = LRU victim.
    last_used: u64,
    /// Byte charge of this session against the store budget.
    bytes: usize,
    /// Outstanding in-flight references; a pinned slot is never evicted.
    pins: u32,
}

/// Fleet-wide registry record of one resident chunk: how many session
/// tables reference it, its byte charge (charged once however many
/// sessions share it), and the prefix-index links resolving to it
/// (removed eagerly when the last reference drops, so the index never
/// holds a chunk no resident session references).
struct ChunkRef {
    bytes: usize,
    refs: u32,
    links: Vec<u64>,
}

/// Registry key: chunk pointer identity.  Valid because a registered
/// chunk is kept alive by the referencing entries (the `Arc` cannot be
/// dropped — and its address reused — while its refcount here is
/// nonzero), and the copy-on-write append path never mutates a chunk
/// whose `Arc` has other holders in place.
fn chunk_key(c: &Arc<KvChunk>) -> usize {
    Arc::as_ptr(c) as usize
}

struct Inner {
    budget_bytes: usize,
    /// Bytes of *unique* resident chunks: each chunk charged once
    /// fleet-wide, however many sessions' tables share it.
    used_bytes: usize,
    /// Bytes of chunks referenced by two or more resident sessions.
    shared_bytes: usize,
    entries: HashMap<String, Slot>,
    /// Refcount registry over every chunk referenced by a resident
    /// entry, keyed by pointer identity ([`chunk_key`]).
    chunk_refs: HashMap<usize, ChunkRef>,
    /// Radix prefix index: hash-chain link ([`chain_root`] +
    /// [`chain_link`]) of each registered full chunk -> that chunk.
    /// Values are always registry-live (eager cleanup on last unref).
    prefix_index: HashMap<u64, Arc<KvChunk>>,
    /// Monotonic access generation counter.
    tick: u64,
    evictions: u64,
}

impl Inner {
    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Take one reference per chunk of `prepared`, charging bytes only
    /// for chunks not already resident (the dedup credit).
    fn ref_chunks(&mut self, prepared: &PreparedKv) {
        for c in prepared.chunks() {
            match self.chunk_refs.get_mut(&chunk_key(c)) {
                Some(cr) => {
                    cr.refs += 1;
                    if cr.refs == 2 {
                        self.shared_bytes += cr.bytes;
                    }
                }
                None => {
                    let bytes = c.bytes();
                    self.used_bytes += bytes;
                    self.chunk_refs
                        .insert(chunk_key(c), ChunkRef { bytes, refs: 1, links: Vec::new() });
                }
            }
        }
    }

    /// Drop one reference per chunk of `prepared`.  A chunk reaching
    /// zero references is uncharged and its prefix-index links removed;
    /// a chunk another resident session still references frees nothing.
    /// Returns the bytes actually freed.
    fn unref_chunks(&mut self, prepared: &PreparedKv) -> usize {
        let mut freed = 0;
        for c in prepared.chunks() {
            let key = chunk_key(c);
            let gone = match self.chunk_refs.get_mut(&key) {
                Some(cr) => {
                    cr.refs = cr.refs.saturating_sub(1);
                    if cr.refs == 1 {
                        self.shared_bytes -= cr.bytes;
                    }
                    cr.refs == 0
                }
                None => false,
            };
            if gone {
                if let Some(cr) = self.chunk_refs.remove(&key) {
                    freed += cr.bytes;
                    self.used_bytes -= cr.bytes;
                    for link in cr.links {
                        if self.prefix_index.get(&link).is_some_and(|ix| Arc::ptr_eq(ix, c)) {
                            self.prefix_index.remove(&link);
                        }
                    }
                }
            }
        }
        freed
    }

    /// Byte movement of swapping `session`'s entry (if any) for `next`:
    /// `(added, freed)`.  `added` counts next's unique chunks that would
    /// not be resident once the old entry releases — dedup hits and
    /// fork-shared chunks cost nothing; `freed` counts old chunks no
    /// *other* session references.  Chunks shared between old and new
    /// (an append's filled prefix) appear in both terms and cancel.
    fn swap_delta(&self, session: &str, next: &PreparedKv) -> (usize, usize) {
        let mut old_counts: HashMap<usize, u32> = HashMap::new();
        if let Some(slot) = self.entries.get(session) {
            for c in slot.entry.prepared.chunks() {
                *old_counts.entry(chunk_key(c)).or_insert(0) += 1;
            }
        }
        let mut freed = 0;
        for (key, &n) in &old_counts {
            if let Some(cr) = self.chunk_refs.get(key) {
                if cr.refs <= n {
                    freed += cr.bytes;
                }
            }
        }
        let mut added = 0;
        let mut seen: HashSet<usize> = HashSet::new();
        for c in next.chunks() {
            let key = chunk_key(c);
            if !seen.insert(key) {
                continue; // charged once per unique chunk
            }
            let refs = self.chunk_refs.get(&key).map(|cr| cr.refs).unwrap_or(0);
            let surviving = refs.saturating_sub(old_counts.get(&key).copied().unwrap_or(0));
            if surviving == 0 {
                added += c.bytes();
            }
        }
        (added, freed)
    }

    /// Make room to swap `session`'s entry for `next`: evict unpinned
    /// LRU victims — never `session` itself — until the budget holds the
    /// refcount-aware delta ([`Inner::swap_delta`]), or fail if only
    /// pinned sessions remain.  The delta is recomputed after every
    /// eviction: evicting a victim that shared chunks with `next` grows
    /// the bytes this install must newly charge.  Call *before* applying
    /// the swap so a rejected write never lands — though evictions
    /// performed while trying to make room persist even when admission
    /// ultimately fails, so callers must republish gauges on the error
    /// path too.
    fn admit_swap(&mut self, session: &str, next: &PreparedKv) -> Result<()> {
        loop {
            let (added, freed) = self.swap_delta(session, next);
            if added > self.budget_bytes {
                bail!(
                    "session {session:?} needs {added} B, exceeding the whole KV byte budget \
                     ({} B)",
                    self.budget_bytes
                );
            }
            if self.used_bytes - freed + added <= self.budget_bytes {
                return Ok(());
            }
            let victim = self
                .entries
                .iter()
                .filter(|(name, slot)| slot.pins == 0 && name.as_str() != session)
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(name, _)| name.clone());
            match victim {
                Some(name) => {
                    // the victim was selected from the live map above,
                    // but tolerate a phantom miss instead of panicking
                    // a serve path holding the store lock
                    if let Some(gone) = self.entries.remove(&name) {
                        self.unref_chunks(&gone.entry.prepared);
                        self.evictions += 1;
                    }
                }
                None => bail!(
                    "KV byte budget exhausted admitting {session:?} ({added} B): \
                     {} of {} B used and every other resident session is pinned",
                    self.used_bytes - freed,
                    self.budget_bytes
                ),
            }
        }
    }

    /// Swap in `session`'s entry, releasing the old one's chunk
    /// references and taking the new one's (pins carry over; the byte
    /// movement is exactly the [`Inner::swap_delta`] the caller
    /// admitted).
    fn install(&mut self, session: &str, entry: KvEntry) {
        let stamp = self.next_tick();
        if let Some(slot) = self.entries.get(session) {
            let old = Arc::clone(&slot.entry.prepared);
            self.unref_chunks(&old);
        }
        self.ref_chunks(&entry.prepared);
        let bytes = entry.prepared.resident_bytes();
        match self.entries.get_mut(session) {
            Some(slot) => {
                slot.entry = entry;
                slot.bytes = bytes;
                slot.last_used = stamp;
            }
            None => {
                self.entries.insert(
                    session.to_string(),
                    Slot { entry, last_used: stamp, bytes, pins: 0 },
                );
            }
        }
    }

    /// Resolve a chain of full-chunk content hashes against the prefix
    /// index.  The chain stops at the first miss — a deeper link can
    /// only exist if every link before it was registered by the same
    /// prefix — and the returned vector is padded with `None` to
    /// `hashes.len()` so it indexes 1:1 with the put's full chunks.
    fn resolve_prefix(&self, root: u64, hashes: &[u64]) -> Vec<Option<Arc<KvChunk>>> {
        let mut out = Vec::with_capacity(hashes.len());
        let mut link = root;
        for &h in hashes {
            link = chain_link(link, h);
            match self.prefix_index.get(&link) {
                Some(c) => out.push(Some(Arc::clone(c))),
                None => break,
            }
        }
        out.resize(hashes.len(), None);
        out
    }

    /// Register `prepared`'s full prefix chunks under their chain links
    /// (after install, so every indexed chunk is registry-live).  An
    /// existing live mapping is kept — the first registration is
    /// canonical; a racing duplicate build simply goes unindexed and is
    /// freed with its session.
    fn index_prefix(&mut self, root: u64, hashes: &[u64], prepared: &PreparedKv) {
        let mut link = root;
        for (i, &h) in hashes.iter().enumerate() {
            link = chain_link(link, h);
            let c = &prepared.chunks()[i];
            let occupied = self
                .prefix_index
                .get(&link)
                .is_some_and(|ix| self.chunk_refs.contains_key(&chunk_key(ix)));
            if !occupied {
                if let Some(cr) = self.chunk_refs.get_mut(&chunk_key(c)) {
                    if !cr.links.contains(&link) {
                        cr.links.push(link);
                    }
                    self.prefix_index.insert(link, Arc::clone(c));
                }
            }
        }
    }
}

/// Thread-safe KV session store with byte-budget LRU eviction,
/// in-flight pinning, and cross-session prefix sharing (see the module
/// docs' radix-cache section).
pub struct KvStore {
    seq_len: usize,
    head_dim: usize,
    inner: Mutex<Inner>,
    /// Attached metrics sink ([`KvStore::attach_metrics`]); gauge
    /// publication is atomics-only, so no lock is ever taken through
    /// this (the KvStore -> Metrics -> queue lock order of
    /// `coordinator/protocol.rs` stays un-nested).
    metrics: OnceLock<Arc<Metrics>>,
}

impl KvStore {
    /// Budget expressed in sessions: room for `capacity` *full*
    /// (`seq_len`-row) sessions' prepared bytes.  Shorter sessions
    /// charge less, so more of them fit — eviction is by bytes, not
    /// count.
    pub fn new(seq_len: usize, head_dim: usize, capacity: usize) -> KvStore {
        let full = seq_len.max(1) * row_bytes(head_dim, head_dim);
        KvStore::with_byte_budget(seq_len, head_dim, capacity.max(1) * full)
    }

    /// Budget expressed directly in bytes of prepared KV planes.
    pub fn with_byte_budget(seq_len: usize, head_dim: usize, budget_bytes: usize) -> KvStore {
        KvStore {
            seq_len,
            head_dim,
            inner: Mutex::new(Inner {
                budget_bytes: budget_bytes.max(1),
                used_bytes: 0,
                shared_bytes: 0,
                entries: HashMap::new(),
                chunk_refs: HashMap::new(),
                prefix_index: HashMap::new(),
                tick: 0,
                evictions: 0,
            }),
            metrics: OnceLock::new(),
        }
    }

    /// Attach a metrics sink: the store publishes its byte/sharing
    /// gauges (`kv_resident_bytes`, `kv_shared_bytes`,
    /// `kv_resident_sessions`) and the `kv_dedup_hits` counter after
    /// every state change.  Publication is atomics-only — no Metrics
    /// lock is taken, even with the store lock held.  The `kv_dedup_hits`
    /// counter moves only on a successful admit+install (the rollback
    /// discipline of `batched_sessions`: a rejected operation never
    /// counts a hit); the byte/session gauges are republished even when
    /// admission fails, because evictions performed while trying to
    /// make room persist and must show in the snapshot immediately.
    /// Idempotent; the first attach wins.
    pub fn attach_metrics(&self, metrics: Arc<Metrics>) {
        let _ = self.metrics.set(metrics);
    }

    /// Publish the store's gauges into the attached [`Metrics`] sink.
    /// Call with the `Inner` guard still held so the published figures
    /// are a consistent cut of store state.
    fn publish(&self, g: &Inner, dedup_hits: u64) {
        let Some(m) = self.metrics.get() else { return };
        // ordering: Relaxed — telemetry gauges/counters only; snapshot
        // readers do not synchronize store state through them.
        m.kv_resident_bytes.store(g.used_bytes as u64, Ordering::Relaxed);
        m.kv_shared_bytes.store(g.shared_bytes as u64, Ordering::Relaxed);
        m.kv_resident_sessions.store(g.entries.len() as u64, Ordering::Relaxed);
        if dedup_hits > 0 {
            m.kv_dedup_hits.fetch_add(dedup_hits, Ordering::Relaxed);
        }
    }

    /// Modelled SRAM bytes of one full session (BF16 K + V) — the
    /// hardware-facing figure; the eviction budget accounts the host
    /// prepared-plane bytes instead (see [`KvStore::budget_bytes`]).
    pub fn session_bytes(&self) -> usize {
        2 * self.seq_len * self.head_dim * 2
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Insert (or replace) a session's KV matrices.  The prefill may be
    /// any length `1..=seq_len` (a decode session grows the rest via
    /// [`KvStore::append`]).  The BF16 rounding and the one-time V->LNS
    /// preparation happen *outside* the lock.  Fails — leaving the
    /// session itself untouched, though evictions performed while
    /// trying to make room persist — when the session cannot fit inside
    /// the byte budget after evicting every unpinned resident session.
    ///
    /// Full (capacity-aligned) prefix chunks of the rounded rows are
    /// first resolved against the radix prefix index: a hit whose
    /// stored planes byte-match the rounded source rows (the
    /// [`KvChunk::matches_rows`] install gate — hashes are lookup keys,
    /// never trusted for content) installs the already-resident
    /// `Arc<KvChunk>` verbatim — no copy, no LNS conversion, near-zero
    /// byte charge — so both `value_to_lns` work and `used_bytes` scale
    /// with *unique* rows fleet-wide, not sessions x rows (pinned by
    /// `rust/tests/prefix_sharing.rs`).
    pub fn put(&self, session: &str, k: Mat, v: Mat) -> Result<()> {
        if !(1..=self.seq_len).contains(&k.rows) || k.cols != self.head_dim {
            bail!(
                "K shape {}x{} incompatible with store geometry (up to {})x{}",
                k.rows, k.cols, self.seq_len, self.head_dim
            );
        }
        if v.rows != k.rows || v.cols != k.cols {
            bail!("V shape mismatch");
        }
        let k = k.round_bf16();
        let v = v.round_bf16();
        // hash the full prefix chunks of the *rounded* rows (chunk
        // planes hold exactly these bits), then resolve them under a
        // brief lock before building anything; the hits are only
        // candidates — with_shared_chunks byte-verifies each one
        // against the rounded rows before installing it, so a hash
        // collision can never alias another session's chunk
        let block_rows = DEFAULT_BLOCK_ROWS;
        let root = chain_root(k.cols, v.cols, block_rows);
        let hashes: Vec<u64> = (0..k.rows / block_rows)
            .map(|c| chunk_row_hash(&k, &v, c * block_rows, (c + 1) * block_rows))
            .collect();
        let hits = if hashes.is_empty() {
            Vec::new()
        } else {
            self.inner.lock().resolve_prefix(root, &hashes)
        };
        // build outside the lock: only missed chunks and the ragged
        // tail convert and copy (two sessions racing the same new
        // prefix may both build it — benign: one registration wins the
        // index and the loser's copy is freed with its session)
        let prepared = PreparedKv::with_shared_chunks(&k, &v, block_rows, |c, _| {
            hits.get(c).cloned().flatten()
        });
        // count hits the verify gate actually installed, not resolver
        // candidates (a byte-mismatched candidate builds fresh)
        let dedup_hits = prepared
            .chunks()
            .iter()
            .zip(&hits)
            .filter(|&(c, h)| h.as_ref().is_some_and(|hc| Arc::ptr_eq(c, hc)))
            .count() as u64;
        let entry = KvEntry { prepared: Arc::new(prepared) };
        let installed = Arc::clone(&entry.prepared);
        let mut g = self.inner.lock();
        if let Err(e) = g.admit_swap(session, &entry.prepared) {
            // evictions performed while trying to make room persist:
            // refresh the gauges so a failed admission never leaves
            // them stale until the next successful operation
            self.publish(&g, 0);
            return Err(e);
        }
        g.install(session, entry);
        g.index_prefix(root, &hashes, &installed);
        self.publish(&g, dedup_hits);
        Ok(())
    }

    /// Fork `parent` into a new resident session `child` whose chunk
    /// table copy-on-writes from the shared ancestor: the child
    /// references the exact same `Arc<KvChunk>`s (tail included), so it
    /// admits at zero added bytes, converts nothing, and serves
    /// bit-identical outputs — beam/parallel sampling over a common
    /// prefix is free until the branches diverge.  The first append to
    /// either branch copies only that branch's tail chunk
    /// ([`PreparedKv::append`]'s copy-on-write), charging only the
    /// delta bytes.  Fails when `parent` is not resident or `child`
    /// already is (forking over a live session would silently drop its
    /// state).  Counts as a use of `parent` (LRU refresh) — but only
    /// once validation passes, so a rejected fork leaves eviction
    /// order untouched.
    pub fn fork(&self, parent: &str, child: &str) -> Result<()> {
        if parent.is_empty() || child.is_empty() {
            bail!("fork: empty session name");
        }
        if parent == child {
            bail!("fork: parent and child must be distinct sessions");
        }
        let mut g = self.inner.lock();
        // validate the child before touching the parent's LRU stamp:
        // a rejected fork must not mutate eviction order
        if g.entries.contains_key(child) {
            bail!("fork: session {child:?} is already resident");
        }
        let stamp = g.next_tick();
        let base = match g.entries.get_mut(parent) {
            Some(slot) => {
                slot.last_used = stamp;
                Arc::clone(&slot.entry.prepared)
            }
            None => bail!("fork: unknown parent session {parent:?}"),
        };
        let shared = base.chunks().len() as u64;
        // a table copy, not a plane copy: one Arc pointer per chunk
        let entry = KvEntry { prepared: Arc::new((*base).clone()) };
        if let Err(e) = g.admit_swap(child, &entry.prepared) {
            // see put(): evictions from the failed admission persist
            self.publish(&g, 0);
            return Err(e);
        }
        g.install(child, entry);
        self.publish(&g, shared);
        Ok(())
    }

    /// Append decode-step rows to a resident session: BF16-round the new
    /// rows, convert **only them** to the log domain, and swap in a new
    /// [`Arc<PreparedKv>`] built from the old one (copy-on-write at chunk
    /// granularity — filled chunks stay shared, only the tail chunk and
    /// the chunk table are copied).  In-flight batches holding the old
    /// `Arc` keep computing against the pre-append snapshot; requests
    /// arriving after this returns see the grown KV.  Refreshes the
    /// session's LRU stamp, and fails — leaving the session untouched —
    /// when the grown charge cannot fit inside the byte budget after
    /// evicting every unpinned *other* session.
    ///
    /// The tail-chunk copy and the per-row conversion run **outside**
    /// the store lock (other sessions' `get`/`put` are never stalled
    /// behind a decode session); the swap-in re-checks by `Arc` identity
    /// that the session was not concurrently replaced and retries
    /// against the new base if it was.
    ///
    /// When the session's tail chunk is shared — a forked branch, or a
    /// sibling that deduped the same full prefix — exactly that chunk is
    /// copied on write, and the refcount-aware swap charges only the
    /// delta bytes: the shared prefix stays charged once fleet-wide,
    /// the branch's new private tail is charged to this session, and
    /// the ancestor's tail stays charged as long as any other session
    /// references it (`kv_copy_bytes` counts the CoW'd tail plus the
    /// appended rows, pinned by `rust/tests/append_traffic.rs`).
    pub fn append(&self, session: &str, k_rows: Mat, v_rows: Mat) -> Result<()> {
        if k_rows.cols != self.head_dim || v_rows.cols != self.head_dim {
            bail!(
                "append dims {}x{} / {}x{} != head dim {}",
                k_rows.rows, k_rows.cols, v_rows.rows, v_rows.cols, self.head_dim
            );
        }
        if k_rows.rows != v_rows.rows {
            bail!("K/V append row count mismatch");
        }
        if k_rows.rows == 0 {
            bail!("empty append");
        }
        let kb = k_rows.round_bf16();
        let vb = v_rows.round_bf16();
        loop {
            // snapshot the base under the lock (an Arc clone); the LRU
            // stamp is refreshed only on the successful swap-in, so a
            // rejected (e.g. over-capacity) append does not count as use
            let base = {
                let g = self.inner.lock();
                match g.entries.get(session) {
                    Some(slot) => slot.entry.prepared.clone(),
                    None => bail!("unknown session {session:?}"),
                }
            };
            if base.n() + kb.rows > self.seq_len {
                bail!(
                    "append overflows session capacity: {} + {} > {}",
                    base.n(), kb.rows, self.seq_len
                );
            }
            // rebuild outside the lock
            let next = Arc::new(base.appended(&kb, &vb));
            // swap in, unless the session was replaced meanwhile (a
            // concurrent put/append won the race) — then retry on the
            // new base so no write is ever silently dropped
            let mut g = self.inner.lock();
            match g.entries.get(session) {
                Some(slot) if Arc::ptr_eq(&slot.entry.prepared, &base) => {}
                Some(_) => continue,
                None => bail!("unknown session {session:?}"),
            }
            if let Err(e) = g.admit_swap(session, &next) {
                // see put(): evictions from the failed admission persist
                self.publish(&g, 0);
                return Err(e);
            }
            g.install(session, KvEntry { prepared: next });
            self.publish(&g, 0);
            return Ok(());
        }
    }

    /// Fetch a session, refreshing its LRU stamp (O(1) under the lock).
    pub fn get(&self, session: &str) -> Option<KvEntry> {
        let mut g = self.inner.lock();
        let stamp = g.next_tick();
        let slot = g.entries.get_mut(session)?;
        slot.last_used = stamp;
        Some(slot.entry.clone())
    }

    /// Mark a session as having in-flight work: refreshes its LRU stamp
    /// and excludes it from eviction until the matching [`KvStore::unpin`].
    /// Returns `false` (no pin taken) when the session is not resident.
    pub fn pin(&self, session: &str) -> bool {
        let mut g = self.inner.lock();
        let stamp = g.next_tick();
        match g.entries.get_mut(session) {
            Some(slot) => {
                slot.pins += 1;
                slot.last_used = stamp;
                true
            }
            None => false,
        }
    }

    /// Release one in-flight pin (the session becomes evictable again
    /// once its pin count reaches zero).  A no-op for unknown sessions.
    pub fn unpin(&self, session: &str) {
        let mut g = self.inner.lock();
        if let Some(slot) = g.entries.get_mut(session) {
            slot.pins = slot.pins.saturating_sub(1);
        }
    }

    /// Forcibly remove a session regardless of pins — the cancellation
    /// path, where a dead client must free its bytes mid-decode without
    /// waiting for its queued requests to drain.  Safe because in-flight
    /// computes hold `Arc<PreparedKv>` snapshots and a late `unpin` on a
    /// gone session is a no-op.  (If the *same* session is re-`put`
    /// before the cancelled requests are failed, their stale unpins can
    /// release the fresh slot's pins early — callers cancelling with
    /// eviction should treat the session name as dead.)  Returns the
    /// bytes actually freed, or `None` when the session was not
    /// resident.  Freed means *uniquely held*: chunks another resident
    /// session still references (a forked branch, a deduped sibling)
    /// stay charged and alive — evicting a fork parent frees only its
    /// unshared bytes.
    pub fn evict(&self, session: &str) -> Option<usize> {
        let mut g = self.inner.lock();
        let slot = g.entries.remove(session)?;
        let freed = g.unref_chunks(&slot.entry.prepared);
        g.evictions += 1;
        self.publish(&g, 0);
        Some(freed)
    }

    /// Is the session resident?  (No LRU refresh — diagnostics only.)
    pub fn contains(&self, session: &str) -> bool {
        self.inner.lock().entries.contains_key(session)
    }

    /// Bytes of prepared planes one resident session *references*
    /// (diagnostics only).  Under sharing this can exceed the session's
    /// marginal charge: a chunk referenced by many sessions shows in
    /// each of their footprints but in [`KvStore::used_bytes`] once.
    pub fn session_resident_bytes(&self, session: &str) -> Option<usize> {
        self.inner.lock().entries.get(session).map(|s| s.bytes)
    }

    /// Resident KV rows (tokens) of one session, or `None` when it is
    /// not resident.  No LRU refresh — the continuous scheduler's
    /// token-budget accounting must not count as a use.
    pub fn session_rows(&self, session: &str) -> Option<usize> {
        self.inner.lock().entries.get(session).map(|s| s.entry.prepared.n())
    }

    pub fn resident(&self) -> usize {
        self.inner.lock().entries.len()
    }

    /// Sessions currently holding at least one in-flight pin
    /// (diagnostics: a steady-state serving loop must return this to 0 —
    /// a leak here makes sessions permanently unevictable).
    pub fn pinned_sessions(&self) -> usize {
        self.inner.lock().entries.values().filter(|s| s.pins > 0).count()
    }

    /// Total byte charge of all resident sessions — the sum over
    /// **unique** resident chunks, each charged once however many
    /// sessions share it.
    pub fn used_bytes(&self) -> usize {
        self.inner.lock().used_bytes
    }

    /// Bytes of chunks currently referenced by two or more resident
    /// sessions (the fleet's dedup/fork savings are
    /// `sum(session_resident_bytes) - used_bytes`; this gauge is the
    /// shared portion counted once).
    pub fn shared_bytes(&self) -> usize {
        self.inner.lock().shared_bytes
    }

    /// Unique chunks in the refcount registry (diagnostics: returns to
    /// 0 when the store drains; a leak here means an unref was missed).
    pub fn registered_chunks(&self) -> usize {
        self.inner.lock().chunk_refs.len()
    }

    /// Live entries in the radix prefix index (diagnostics; always
    /// bounded by registered full chunks — entries are removed eagerly
    /// when their chunk's last reference drops).
    pub fn indexed_prefixes(&self) -> usize {
        self.inner.lock().prefix_index.len()
    }

    /// The eviction budget, in prepared-plane bytes.
    pub fn budget_bytes(&self) -> usize {
        self.inner.lock().budget_bytes
    }

    pub fn evictions(&self) -> u64 {
        self.inner.lock().evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(n: usize, d: usize, fill: f32) -> (Mat, Mat) {
        (Mat::from_fn(n, d, |_, _| fill), Mat::from_fn(n, d, |_, _| -fill))
    }

    #[test]
    fn put_get_roundtrip() {
        let store = KvStore::new(16, 8, 2);
        let (k, v) = kv(16, 8, 1.0);
        store.put("a", k, v).unwrap();
        let e = store.get("a").unwrap();
        assert_eq!(e.prepared().k_row(0)[0], 1.0);
        assert_eq!(e.prepared().v_row(0)[0], -1.0);
        assert_eq!(e.prepared().n(), 16);
        assert_eq!(store.used_bytes(), 16 * row_bytes(8, 8));
        assert_eq!(store.session_resident_bytes("a"), Some(16 * row_bytes(8, 8)));
    }

    #[test]
    fn evict_removes_even_pinned_sessions_and_frees_bytes() {
        let store = KvStore::new(16, 8, 2);
        let (k, v) = kv(16, 8, 1.0);
        store.put("a", k, v).unwrap();
        assert!(store.pin("a"));
        // pinned sessions resist LRU eviction but not forced eviction
        let freed = store.evict("a").expect("resident session evicts");
        assert_eq!(freed, 16 * row_bytes(8, 8));
        assert!(!store.contains("a"));
        assert_eq!(store.used_bytes(), 0);
        assert_eq!(store.evictions(), 1);
        assert_eq!(store.pinned_sessions(), 0);
        // the in-flight holder's late unpin is a harmless no-op
        store.unpin("a");
        assert!(store.evict("a").is_none(), "double evict reports not-resident");
    }

    #[test]
    fn rejects_wrong_geometry() {
        let store = KvStore::new(16, 8, 2);
        let (k, v) = kv(16, 4, 1.0); // wrong head dim
        assert!(store.put("a", k, v).is_err());
        let (k, v) = kv(32, 8, 1.0); // over capacity
        assert!(store.put("a", k, v).is_err());
        let (k, v) = kv(0, 8, 1.0); // empty prefill
        assert!(store.put("a", k, v).is_err());
        let (k, v) = kv(8, 8, 1.0); // short prefill is fine (decode grows it)
        assert!(store.put("a", k, v).is_ok());
        assert_eq!(store.get("a").unwrap().prepared().n(), 8);
    }

    #[test]
    fn append_grows_resident_session_matching_full_put() {
        let store = KvStore::new(16, 4, 2);
        let full_k = Mat::from_fn(10, 4, |r, c| (r * 4 + c) as f32 * 0.25 - 1.0);
        let full_v = Mat::from_fn(10, 4, |r, c| 1.0 - (r * 4 + c) as f32 * 0.125);
        store.put("s", full_k.rows_slice(0, 6), full_v.rows_slice(0, 6)).unwrap();
        store.append("s", full_k.rows_slice(6, 7), full_v.rows_slice(6, 7)).unwrap();
        store.append("s", full_k.rows_slice(7, 10), full_v.rows_slice(7, 10)).unwrap();
        let grown = store.get("s").unwrap();
        let reference = KvStore::new(16, 4, 2);
        reference.put("s", full_k, full_v).unwrap();
        let full = reference.get("s").unwrap();
        assert_eq!(grown.prepared().n(), 10);
        assert_eq!(grown.prepared().k_mat().data, full.prepared().k_mat().data);
        assert_eq!(grown.prepared().v_mat().data, full.prepared().v_mat().data);
        assert_eq!(grown.prepared().v_lns_mat(), full.prepared().v_lns_mat());
        assert_eq!(grown.prepared().blocks(), full.prepared().blocks());
        // the byte charge followed the growth
        assert_eq!(store.session_resident_bytes("s"), Some(10 * row_bytes(4, 4)));
    }

    #[test]
    fn append_error_paths() {
        let store = KvStore::new(8, 4, 2);
        let (k, v) = kv(6, 4, 1.0);
        store.put("s", k, v).unwrap();
        let (k1, v1) = kv(1, 4, 2.0);
        assert!(store.append("missing", k1.clone(), v1.clone()).is_err(), "unknown session");
        let (kw, vw) = kv(1, 3, 2.0);
        assert!(store.append("s", kw, vw).is_err(), "wrong head dim");
        let (k0, v0) = kv(0, 4, 2.0);
        assert!(store.append("s", k0, v0).is_err(), "empty append");
        let (k3, v3) = kv(3, 4, 2.0);
        assert!(store.append("s", k3, v3).is_err(), "overflows capacity 8");
        // failed appends must leave the session untouched
        assert_eq!(store.get("s").unwrap().prepared().n(), 6);
        assert!(store.append("s", k1, v1).is_ok());
        assert_eq!(store.get("s").unwrap().prepared().n(), 7);
    }

    #[test]
    fn session_rows_reports_growth_without_refreshing_lru() {
        let store = KvStore::new(8, 4, 2); // budget: two full 8-row sessions
        let (k, v) = kv(6, 4, 0.0);
        store.put("a", k, v).unwrap();
        let (kf, vf) = kv(8, 4, 0.0);
        store.put("b", kf.clone(), vf.clone()).unwrap();
        assert_eq!(store.session_rows("a"), Some(6));
        assert_eq!(store.session_rows("missing"), None);
        let (k1, v1) = kv(1, 4, 1.0);
        store.append("a", k1, v1).unwrap();
        assert_eq!(store.session_rows("a"), Some(7), "row count tracks appends");
        store.get("a"); // make "a" most recently *used*
        // probe "b" last: were the probe an LRU touch, "b" would now be
        // the most recent and "a" the victim below
        assert_eq!(store.session_rows("b"), Some(8));
        store.put("c", kf, vf).unwrap(); // over budget: evicts the true LRU
        assert!(store.contains("a"));
        assert!(!store.contains("b"), "session_rows must not refresh LRU");
    }

    #[test]
    fn append_refreshes_lru() {
        let store = KvStore::new(8, 4, 2); // budget: two full 8-row sessions
        let (k, v) = kv(6, 4, 0.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        let (kf, vf) = kv(8, 4, 0.0);
        store.put("b", kf.clone(), vf.clone()).unwrap();
        let (k1, v1) = kv(1, 4, 1.0);
        store.append("a", k1, v1).unwrap(); // refresh a (now 7 rows)
        store.put("c", kf, vf).unwrap(); // 7+8+8 > 16 rows: evicts b, not a
        assert!(store.contains("a"));
        assert!(!store.contains("b"));
    }

    #[test]
    fn inflight_snapshot_survives_append() {
        // a batch holding the old Arc keeps the pre-append view
        let store = KvStore::new(8, 4, 1);
        let (k, v) = kv(4, 4, 1.0);
        store.put("s", k, v).unwrap();
        let snapshot = store.get("s").unwrap();
        let (k1, v1) = kv(2, 4, 3.0);
        store.append("s", k1, v1).unwrap();
        assert_eq!(snapshot.prepared().n(), 4, "in-flight entry must be immutable");
        assert_eq!(store.get("s").unwrap().prepared().n(), 6);
    }

    #[test]
    fn lru_evicts_oldest() {
        let store = KvStore::new(4, 4, 2);
        for (i, name) in ["a", "b", "c"].iter().enumerate() {
            let (k, v) = kv(4, 4, i as f32);
            store.put(name, k, v).unwrap();
        }
        assert_eq!(store.resident(), 2);
        assert!(store.get("a").is_none(), "oldest should be evicted");
        assert!(store.get("b").is_some());
        assert_eq!(store.evictions(), 1);
    }

    #[test]
    fn byte_budget_packs_short_sessions_where_count_lru_would_evict() {
        // the budget holds two *full* 16-row sessions; four 8-row decode
        // prefills fit simultaneously (the old count-based store would
        // have started evicting at the third)
        let store = KvStore::new(16, 4, 2);
        for name in ["a", "b", "c", "d"] {
            let (k, v) = kv(8, 4, 1.0);
            store.put(name, k, v).unwrap();
        }
        assert_eq!(store.resident(), 4, "byte budget must pack partial sessions");
        assert_eq!(store.evictions(), 0);
        assert_eq!(store.used_bytes(), 4 * 8 * row_bytes(4, 4));
        // a fifth spills the budget: exactly one eviction (the LRU)
        let (k, v) = kv(8, 4, 1.0);
        store.put("e", k, v).unwrap();
        assert_eq!(store.evictions(), 1);
        assert!(!store.contains("a"));
        assert!(store.contains("e"));
    }

    #[test]
    fn oversized_session_is_rejected_not_silently_evicting_everyone() {
        let store = KvStore::with_byte_budget(32, 4, 10 * row_bytes(4, 4));
        let (k, v) = kv(8, 4, 1.0);
        store.put("resident", k, v).unwrap();
        let (k, v) = kv(16, 4, 2.0); // 16 rows > 10-row budget
        let err = store.put("huge", k, v).unwrap_err();
        assert!(err.to_string().contains("byte budget"), "{err}");
        assert!(store.contains("resident"), "rejected put must not evict anyone");
        assert_eq!(store.evictions(), 0);
    }

    #[test]
    fn pinned_sessions_are_never_evicted() {
        let store = KvStore::new(4, 4, 2); // budget: two full sessions
        let (k, v) = kv(4, 4, 1.0);
        store.put("pinned", k.clone(), v.clone()).unwrap();
        assert!(store.pin("pinned"));
        store.put("other", k.clone(), v.clone()).unwrap();
        // a third full session must evict "other" (LRU among unpinned),
        // even though "pinned" is older by stamp without the pin refresh
        store.get("other"); // make "other" the most recently used
        store.put("third", k.clone(), v.clone()).unwrap();
        assert!(store.contains("pinned"), "pinned session evicted");
        assert!(!store.contains("other"), "unpinned LRU should have been the victim");
        // once every other session is pinned, admission fails loudly
        assert!(store.pin("third"));
        let err = store.put("fourth", k.clone(), v.clone()).unwrap_err();
        assert!(err.to_string().contains("pinned"), "{err}");
        // unpinning makes room again
        store.unpin("third");
        store.put("fourth", k, v).unwrap();
        assert!(!store.contains("third"));
        assert!(store.contains("pinned"));
        // balanced unpin on the survivor
        store.unpin("pinned");
        assert!(!store.pin("missing"), "pin of a non-resident session takes no pin");
    }

    #[test]
    fn append_budget_overflow_fails_cleanly_when_others_pinned() {
        // budget: 8 rows total; "grow" at 4 rows, "pinned" at 4 rows
        let store = KvStore::with_byte_budget(8, 4, 8 * row_bytes(4, 4));
        let (k, v) = kv(4, 4, 1.0);
        store.put("grow", k.clone(), v.clone()).unwrap();
        store.put("pinned", k, v).unwrap();
        assert!(store.pin("pinned"));
        let (k1, v1) = kv(1, 4, 2.0);
        let err = store.append("grow", k1.clone(), v1.clone()).unwrap_err();
        assert!(err.to_string().contains("pinned"), "{err}");
        assert_eq!(store.get("grow").unwrap().prepared().n(), 4, "failed append must not apply");
        // releasing the pin lets the same append evict and land
        store.unpin("pinned");
        store.append("grow", k1, v1).unwrap();
        assert_eq!(store.get("grow").unwrap().prepared().n(), 5);
        assert!(!store.contains("pinned"));
    }

    #[test]
    fn get_refreshes_lru() {
        let store = KvStore::new(4, 4, 2);
        let (k, v) = kv(4, 4, 0.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        store.put("b", k.clone(), v.clone()).unwrap();
        store.get("a"); // refresh a
        store.put("c", k, v).unwrap(); // evicts b, not a
        assert!(store.get("a").is_some());
        assert!(store.get("b").is_none());
    }

    #[test]
    fn replacing_a_session_refreshes_it() {
        let store = KvStore::new(4, 4, 2);
        let (k, v) = kv(4, 4, 0.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        store.put("b", k.clone(), v.clone()).unwrap();
        store.put("a", k.clone(), v.clone()).unwrap(); // re-put refreshes a
        store.put("c", k, v).unwrap(); // evicts b
        assert!(store.get("a").is_some());
        assert!(store.get("b").is_none());
        assert!(store.get("c").is_some());
    }

    #[test]
    fn replacing_a_session_releases_its_old_charge() {
        let store = KvStore::new(16, 4, 2);
        let (k, v) = kv(16, 4, 1.0);
        store.put("a", k, v).unwrap();
        assert_eq!(store.used_bytes(), 16 * row_bytes(4, 4));
        let (k, v) = kv(2, 4, 1.0);
        store.put("a", k, v).unwrap(); // shrinks
        assert_eq!(store.used_bytes(), 2 * row_bytes(4, 4));
        assert_eq!(store.resident(), 1);
    }

    #[test]
    fn session_bytes_matches_bf16_kv() {
        let store = KvStore::new(1024, 64, 1);
        assert_eq!(store.session_bytes(), 2 * 1024 * 64 * 2);
    }

    #[test]
    fn concurrent_gets_and_puts_stay_consistent() {
        // request-path contention: many readers refreshing LRU stamps
        // while writers insert/evict.  The store must never exceed
        // its byte budget and never hand out a torn entry — every session
        // name encodes its fill value, so any `Some` result is verifiable.
        let store = Arc::new(KvStore::new(8, 4, 3));
        let budget = store.budget_bytes();
        let fill = |s: usize| s as f32 + 1.0;
        let mut handles = Vec::new();
        for t in 0..6usize {
            let store = store.clone();
            handles.push(crate::sync::thread::spawn(move || {
                let mut hits = 0u64;
                for i in 0..500usize {
                    let s = (t + i) % 5;
                    if t < 2 {
                        let (k, v) = kv(8, 4, fill(s));
                        store.put(&format!("sess-{s}"), k, v).unwrap();
                    }
                    if let Some(e) = store.get(&format!("sess-{s}")) {
                        assert_eq!(e.prepared().k_row(0)[0], fill(s), "torn entry for sess-{s}");
                        assert_eq!(e.prepared().v_row(0)[0], -fill(s));
                        assert_eq!(e.prepared().n(), 8);
                        hits += 1;
                    }
                    assert!(store.used_bytes() <= budget);
                }
                hits
            }));
        }
        let hits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(hits > 0, "at least some gets must land on resident sessions");
        assert!(store.resident() <= 3, "resident {} sessions exceed budget", store.resident());
    }

    // -- prefix sharing / fork ------------------------------------------
    // (exact conversion/copy-counter equations live in
    // `rust/tests/prefix_sharing.rs` and `rust/tests/append_traffic.rs`,
    // whose binaries own the process-wide counters)

    fn prefix_val(r: usize, c: usize) -> f32 {
        ((r * 4 + c) % 97) as f32 * 0.0625 - 3.0
    }

    /// 520 rows = two full DEFAULT_BLOCK_ROWS chunks + an 8-row tail;
    /// the prefix is shared, the tail is `fill`-specific.
    fn prefixed_kv(fill: f32) -> (Mat, Mat) {
        (
            Mat::from_fn(520, 4, |r, c| if r < 512 { prefix_val(r, c) } else { fill }),
            Mat::from_fn(520, 4, |r, c| if r < 512 { -prefix_val(r, c) } else { -fill }),
        )
    }

    #[test]
    fn put_dedups_shared_full_prefix_chunks() {
        let store = KvStore::new(600, 4, 4);
        let rb = row_bytes(4, 4);
        let (k1, v1) = prefixed_kv(1.0);
        store.put("s1", k1, v1).unwrap();
        assert_eq!(store.used_bytes(), 520 * rb);
        assert_eq!(store.shared_bytes(), 0);
        assert_eq!(store.indexed_prefixes(), 2, "both full chunks registered");
        let (k2, v2) = prefixed_kv(2.0);
        store.put("s2", k2, v2).unwrap();
        // the 512-row prefix (two full chunks) is stored once; only the
        // 8-row tails are per-session
        assert_eq!(store.used_bytes(), 520 * rb + 8 * rb);
        assert_eq!(store.shared_bytes(), 512 * rb);
        assert_eq!(store.session_resident_bytes("s2"), Some(520 * rb));
        let a = store.get("s1").unwrap();
        let b = store.get("s2").unwrap();
        assert!(Arc::ptr_eq(&a.prepared().chunks()[0], &b.prepared().chunks()[0]));
        assert!(Arc::ptr_eq(&a.prepared().chunks()[1], &b.prepared().chunks()[1]));
        assert!(!Arc::ptr_eq(&a.prepared().chunks()[2], &b.prepared().chunks()[2]));
        // reads resolve through the shared chunks bit-for-bit
        assert_eq!(b.prepared().k_row(100), a.prepared().k_row(100));
        assert_eq!(b.prepared().k_row(515)[3], 2.0);
        // evicting one sibling frees only its tail; the last one frees
        // the prefix too, and the index entries die with their chunks
        assert_eq!(store.evict("s1"), Some(8 * rb));
        assert_eq!(store.used_bytes(), 520 * rb);
        assert_eq!(store.shared_bytes(), 0);
        assert_eq!(store.evict("s2"), Some(520 * rb));
        assert_eq!(store.used_bytes(), 0);
        assert_eq!(store.registered_chunks(), 0);
        assert_eq!(store.indexed_prefixes(), 0);
    }

    #[test]
    fn prefix_chain_stops_at_first_divergence() {
        // radix semantics: a chunk resolves only when the entire prefix
        // before it matched — equal content behind a divergent first
        // chunk must NOT alias
        let store = KvStore::new(600, 4, 4);
        let rb = row_bytes(4, 4);
        let (k1, v1) = prefixed_kv(1.0);
        store.put("s1", k1, v1).unwrap();
        let (mut k3, v3) = prefixed_kv(3.0);
        for i in 0..256 * 4 {
            k3.data[i] = 7.0; // divergent first chunk, identical second
        }
        store.put("s3", k3, v3).unwrap();
        assert_eq!(store.used_bytes(), 520 * rb + 520 * rb, "no cross-prefix aliasing");
        let a = store.get("s1").unwrap();
        let b = store.get("s3").unwrap();
        assert!(!Arc::ptr_eq(&a.prepared().chunks()[1], &b.prepared().chunks()[1]));
    }

    #[test]
    fn fork_shares_every_chunk_and_cow_append_diverges() {
        let store = KvStore::new(16, 4, 4);
        let rb = row_bytes(4, 4);
        let (k, v) = kv(10, 4, 1.0);
        store.put("parent", k, v).unwrap();
        store.fork("parent", "child").unwrap();
        assert_eq!(store.resident(), 2);
        assert_eq!(store.used_bytes(), 10 * rb, "a pure fork adds zero bytes");
        assert_eq!(store.shared_bytes(), 10 * rb);
        let p = store.get("parent").unwrap();
        let c = store.get("child").unwrap();
        assert!(Arc::ptr_eq(&p.prepared().chunks()[0], &c.prepared().chunks()[0]));
        assert_eq!(p.prepared().k_mat().data, c.prepared().k_mat().data);
        // the child's first append copy-on-writes exactly the shared
        // tail and charges only the child's new private chunk
        let (k1, v1) = kv(1, 4, 2.0);
        store.append("child", k1, v1).unwrap();
        assert_eq!(store.used_bytes(), 10 * rb + 11 * rb);
        assert_eq!(store.shared_bytes(), 0);
        assert_eq!(store.get("parent").unwrap().prepared().n(), 10, "parent untouched");
        assert_eq!(store.get("child").unwrap().prepared().n(), 11);
        // evicting the parent frees only its now-unshared chunk
        assert_eq!(store.evict("parent"), Some(10 * rb));
        assert_eq!(store.used_bytes(), 11 * rb);
        assert_eq!(store.registered_chunks(), 1);
    }

    #[test]
    fn fork_error_paths_and_zero_cost_admission() {
        let store = KvStore::new(8, 4, 2);
        let (k, v) = kv(4, 4, 1.0);
        store.put("p", k.clone(), v.clone()).unwrap();
        assert!(store.fork("missing", "c").is_err(), "unknown parent");
        assert!(store.fork("p", "p").is_err(), "self fork");
        assert!(store.fork("p", "").is_err(), "empty child");
        store.put("other", k, v).unwrap();
        assert!(store.fork("p", "other").is_err(), "child already resident");
        assert_eq!(store.resident(), 2, "failed forks leave the store untouched");
        store.fork("p", "c").unwrap();
        store.fork("c", "grandchild").unwrap();
        assert_eq!(store.resident(), 4);
        // forks admit at zero added bytes: nothing was evicted even
        // though four sessions now share a two-full-session budget
        assert_eq!(store.evictions(), 0);
        assert_eq!(store.used_bytes(), 8 * row_bytes(4, 4));
    }

    #[test]
    fn failed_fork_does_not_refresh_parent_lru() {
        let store = KvStore::new(4, 4, 2); // budget: two full sessions
        let (k, v) = kv(4, 4, 1.0);
        store.put("a", k.clone(), v.clone()).unwrap();
        store.put("b", k.clone(), v.clone()).unwrap();
        // "a" is LRU; a rejected fork (child already resident) must not
        // count as a use of the parent
        assert!(store.fork("a", "b").is_err());
        store.put("c", k, v).unwrap(); // evicts the true LRU
        assert!(!store.contains("a"), "failed fork must not refresh the parent's stamp");
        assert!(store.contains("b"));
    }

    #[test]
    fn failed_admission_evictions_still_publish_gauges() {
        // budget: 8 rows; "old" (4 rows, unpinned) + "pinned" (4 rows)
        let store = KvStore::with_byte_budget(16, 4, 8 * row_bytes(4, 4));
        let m = Arc::new(Metrics::new());
        store.attach_metrics(Arc::clone(&m));
        let (k, v) = kv(4, 4, 1.0);
        store.put("old", k.clone(), v.clone()).unwrap();
        store.put("pinned", k, v).unwrap();
        assert!(store.pin("pinned"));
        // 8 new rows fit the budget alone but not beside the pinned 4:
        // admission evicts "old", then fails on the pinned remainder —
        // the eviction persists and the gauges must say so immediately
        let (kb, vb) = kv(8, 4, 2.0);
        assert!(store.put("big", kb, vb).is_err());
        assert!(!store.contains("old"), "eviction from the failed admission persists");
        let snap = m.snapshot();
        assert_eq!(snap.kv_resident_sessions, 1, "gauge must reflect the eviction");
        assert_eq!(snap.kv_resident_bytes, (4 * row_bytes(4, 4)) as u64);
        store.unpin("pinned");
    }

    #[test]
    fn attached_metrics_track_sharing_gauges() {
        let store = KvStore::new(16, 4, 4);
        let rb = row_bytes(4, 4);
        let m = Arc::new(Metrics::new());
        store.attach_metrics(Arc::clone(&m));
        let (k, v) = kv(10, 4, 1.0);
        store.put("p", k, v).unwrap();
        store.fork("p", "c").unwrap();
        let snap = m.snapshot();
        assert_eq!(snap.kv_shared_bytes, (10 * rb) as u64);
        assert_eq!(snap.kv_dedup_hits, 1, "the fork shared one chunk");
        assert_eq!(snap.kv_mean_session_bytes, (10 * rb / 2) as u64);
        store.evict("c");
        let snap = m.snapshot();
        assert_eq!(snap.kv_shared_bytes, 0);
        assert_eq!(snap.kv_mean_session_bytes, (10 * rb) as u64);
    }
}

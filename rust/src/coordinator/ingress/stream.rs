//! Stream execution: drives one `Stream` request's decode steps against
//! the server, pushing each step's output as its own `Token` frame when
//! the scheduler's decode iteration completes — never buffering the
//! stream to the end — and enforcing the slow-consumer policy.
//!
//! ## Per-step protocol
//!
//! Each [`StreamStep`] is one decode step: `server.append(k, v)` makes
//! the step's rows resident (the per-session barrier orders it against
//! the step's query), then `server.call(q)` attends over the grown KV.
//! Both are the same blocking entry points an in-process client uses,
//! so streamed outputs are bit-identical to the solo path by
//! construction — the wire adds framing, not arithmetic.
//!
//! ## Slow-consumer policy
//!
//! The token push goes through the connection's bounded
//! [`WriteQueue`] with the configured stall budget
//! (`ingress_stall_budget_us`).  While the queue is full the *push
//! blocks* — which blocks this stream's next decode step, which stops
//! the session's slot from being fed: backpressure reaches the
//! scheduler without touching any other session's cadence.  Once the
//! budget is spent with the queue still full, the stream is shed:
//! `slow_consumer_shed` is counted, the session is cancelled with its
//! KV evicted ([`Server::cancel`] with `evict_kv = true`), and the
//! terminal `Error { code: Cancelled }` frame is pushed past the bound
//! ([`WriteQueue::push_unbounded`]) so the exactly-one-terminal
//! contract holds even against a full queue.
//!
//! ## Termination
//!
//! Exactly one terminal frame per stream: `End` after the last token,
//! or `Error` on the first failure (door rejections are refused before
//! this module runs).  A disconnect observed at a step boundary cancels
//! the session mid-decode and evicts its KV; no terminal frame is owed
//! to a peer that is gone (the write queue is aborted by then anyway).

use std::collections::HashSet;
use std::time::{Duration, Instant};

use super::super::metrics::Metrics;
use super::super::protocol::{PushError, WriteQueue};
use super::super::request::ServeError;
use super::super::server::Server;
use super::frame::{Frame, StreamStep};
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::Mutex;

/// Everything a stream needs from its connection.
pub(super) struct StreamCtx<'a> {
    pub server: &'a Server,
    /// The connection's bounded write queue (shared with the writer
    /// thread and any terminal pushed by the driver).
    pub out: &'a WriteQueue<Frame>,
    /// Stall budget for bounded token pushes (`ingress_stall_budget_us`).
    pub stall: Duration,
    /// Request ids cancelled over the wire (fed by the reader thread,
    /// checked at every step boundary).
    pub cancels: &'a Mutex<HashSet<u64>>,
    /// Set by the reader on EOF / torn frame: the peer is gone.
    pub dead: &'a AtomicBool,
}

impl StreamCtx<'_> {
    fn metrics(&self) -> &Metrics {
        &self.server.metrics
    }

    /// Step-boundary shed check: a disconnected peer or a wire `Cancel`
    /// ends the stream *now*, cancelling the session and evicting its
    /// KV so an abandoned decode never holds memory.  Returns `true`
    /// when the stream must stop (the cancel path has already pushed
    /// its terminal frame; the disconnect path owes none).
    fn shed_if_abandoned(&self, id: u64, session: &str) -> bool {
        // ordering: Relaxed — advisory disconnect flag; a stale read
        // only delays the shed to the next step boundary
        if self.dead.load(Ordering::Relaxed) {
            self.server.cancel(session, true);
            return true;
        }
        let cancelled = self.cancels.lock().contains(&id);
        if cancelled {
            self.server.cancel(session, true);
            let _ = self.out.push_unbounded(Frame::serve_error(id, &ServeError::Cancelled));
            return true;
        }
        false
    }

    /// Deliver a terminal `Error` frame (unbounded: terminal frames are
    /// never dropped for backpressure — one per request bounds the
    /// overshoot).  A `Closed` refusal means the connection died; the
    /// session is cancelled so its KV cannot leak.
    fn fail(&self, id: u64, session: &str, frame: Frame) {
        if self.out.push_unbounded(frame).is_err() {
            self.server.cancel(session, true);
        }
    }
}

/// Map a submit-path rejection (an `anyhow::Error` wrapping a
/// [`ServeError`], or a validation message) onto its wire frame.
pub(super) fn error_frame(id: u64, err: &anyhow::Error) -> Frame {
    match err.downcast_ref::<ServeError>() {
        Some(e) => Frame::serve_error(id, e),
        None => Frame::invalid(id, err.to_string()),
    }
}

/// Execute one `Stream` request to its single terminal frame.
pub(super) fn run_stream(ctx: &StreamCtx<'_>, id: u64, session: &str, steps: Vec<StreamStep>) {
    // ordering: Relaxed — statistical counter
    ctx.metrics().streams_opened.fetch_add(1, Ordering::Relaxed);
    let total = steps.len() as u32;
    let t0 = Instant::now();
    let mut last_token: Option<Instant> = None;
    for (step, s) in steps.into_iter().enumerate() {
        if ctx.shed_if_abandoned(id, session) {
            return;
        }
        // the decode step's write half: rows resident before the query
        match ctx.server.append(session, s.k, s.v) {
            Ok(resp) => {
                if let Err(se) = resp.output {
                    ctx.fail(id, session, Frame::serve_error(id, &se));
                    return;
                }
            }
            Err(e) => {
                ctx.fail(id, session, error_frame(id, &e));
                return;
            }
        }
        if ctx.shed_if_abandoned(id, session) {
            return;
        }
        let out = match ctx.server.call(session, s.q) {
            Ok(resp) => match resp.output {
                Ok(v) => v,
                Err(se) => {
                    ctx.fail(id, session, Frame::serve_error(id, &se));
                    return;
                }
            },
            Err(e) => {
                ctx.fail(id, session, error_frame(id, &e));
                return;
            }
        };
        // stream the step's output as its own frame now — the decode
        // iteration just completed; nothing is buffered to stream end
        match ctx.out.push(Frame::Token { id, step: step as u32, out }, ctx.stall) {
            Ok(()) => {}
            Err(PushError::Stalled(_)) => {
                // slow-consumer policy: the queue stayed full past the
                // stall budget — shed this stream, free its KV, and say
                // so with the one terminal frame
                // ordering: Relaxed — statistical counter
                ctx.metrics().slow_consumer_shed.fetch_add(1, Ordering::Relaxed);
                ctx.server.cancel(session, true);
                ctx.fail(id, session, Frame::serve_error(id, &ServeError::Cancelled));
                return;
            }
            Err(PushError::Closed(_)) => {
                // the connection died under us: nothing is deliverable;
                // free the session's KV and stop
                ctx.server.cancel(session, true);
                return;
            }
        }
        // latency spans: first-token from stream start, inter-token
        // between consecutive deliveries into the write queue
        let now = Instant::now();
        match last_token {
            None => ctx.metrics().observe_first_token(now.duration_since(t0).as_secs_f64() * 1e6),
            Some(prev) => {
                ctx.metrics().observe_inter_token(now.duration_since(prev).as_secs_f64() * 1e6)
            }
        }
        last_token = Some(now);
        // ordering: Relaxed — statistical counter
        ctx.metrics().stream_tokens.fetch_add(1, Ordering::Relaxed);
    }
    ctx.fail(id, session, Frame::End { id, steps: total });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, CoordinatorConfig};
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::kvstore::KvStore;
    use crate::hw::Arith;
    use crate::sync::{thread, Arc};
    use crate::Mat;

    fn accel(head_dim: usize) -> AcceleratorConfig {
        AcceleratorConfig { head_dim, seq_len: 32, kv_blocks: 4, parallel_queries: 1, freq_mhz: 500.0 }
    }

    fn server() -> Server {
        let cfg = CoordinatorConfig { workers: 1, ..CoordinatorConfig::default() };
        let kv = Arc::new(KvStore::new(32, 8, 8));
        Server::start(&cfg, kv, vec![SimBackend::factory(Arith::Hfa, accel(8))]).unwrap()
    }

    fn steps(n: usize, dim: usize) -> Vec<StreamStep> {
        (0..n)
            .map(|i| StreamStep {
                k: Mat::from_vec(1, dim, vec![0.1 * (i + 1) as f32; dim]),
                v: Mat::from_vec(1, dim, vec![0.2 * (i + 1) as f32; dim]),
                q: vec![0.3; dim],
            })
            .collect()
    }

    #[test]
    fn stream_delivers_every_token_then_exactly_one_end() {
        let srv = server();
        srv.kv.put("s", Mat::zeros(2, 8), Mat::zeros(2, 8)).unwrap();
        let out = WriteQueue::new(64);
        let cancels = Mutex::new(HashSet::new());
        let dead = AtomicBool::new(false);
        let ctx = StreamCtx {
            server: &srv,
            out: &out,
            stall: Duration::from_secs(5),
            cancels: &cancels,
            dead: &dead,
        };
        run_stream(&ctx, 42, "s", steps(4, 8));
        out.close();
        let mut tokens = 0;
        let mut terminals = 0;
        while let Some(f) = out.pop() {
            match f {
                Frame::Token { id, step, ref out } => {
                    assert_eq!(id, 42);
                    assert_eq!(step, tokens);
                    assert_eq!(out.len(), 8);
                    tokens += 1;
                }
                Frame::End { id, steps } => {
                    assert_eq!((id, steps), (42, 4));
                    terminals += 1;
                }
                other => panic!("unexpected frame {other:?}"),
            }
        }
        assert_eq!(tokens, 4, "one Token frame per decode step");
        assert_eq!(terminals, 1, "exactly one terminal frame");
        let snap = srv.metrics.snapshot();
        assert_eq!(snap.streams_opened, 1);
        assert_eq!(snap.stream_tokens, 4);
        assert!(snap.first_token_p99_us > 0.0, "first-token span observed");
        srv.shutdown();
    }

    #[test]
    fn stalled_consumer_is_shed_with_kv_evicted_and_one_terminal() {
        let srv = server();
        srv.kv.put("slow", Mat::zeros(2, 8), Mat::zeros(2, 8)).unwrap();
        let out = WriteQueue::new(1); // nobody pops: fills after 1 frame
        let cancels = Mutex::new(HashSet::new());
        let dead = AtomicBool::new(false);
        let ctx = StreamCtx {
            server: &srv,
            out: &out,
            stall: Duration::from_millis(30),
            cancels: &cancels,
            dead: &dead,
        };
        run_stream(&ctx, 7, "slow", steps(6, 8));
        assert_eq!(srv.metrics.slow_consumer_shed.load(Ordering::Relaxed), 1);
        assert!(srv.kv.session_rows("slow").is_none(), "shed stream's KV must be evicted");
        out.close();
        let mut terminals = Vec::new();
        let mut tokens = 0;
        while let Some(f) = out.pop() {
            match f {
                Frame::Token { .. } => tokens += 1,
                Frame::Error { code, .. } => terminals.push(code),
                other => panic!("unexpected frame {other:?}"),
            }
        }
        assert_eq!(tokens, 1, "the queue held one token when the consumer stalled");
        assert_eq!(
            terminals,
            vec![ServeError::Cancelled.wire_code()],
            "exactly one terminal, and it is the Cancelled error"
        );
        srv.shutdown();
    }

    #[test]
    fn wire_cancel_and_disconnect_stop_the_stream_at_a_step_boundary() {
        // wire cancel: one terminal Cancelled error frame
        let srv = server();
        srv.kv.put("c", Mat::zeros(2, 8), Mat::zeros(2, 8)).unwrap();
        let out = WriteQueue::new(64);
        let cancels = Mutex::new(HashSet::from([9u64]));
        let dead = AtomicBool::new(false);
        let ctx = StreamCtx {
            server: &srv,
            out: &out,
            stall: Duration::from_secs(1),
            cancels: &cancels,
            dead: &dead,
        };
        run_stream(&ctx, 9, "c", steps(3, 8));
        out.close();
        let frames: Vec<Frame> = std::iter::from_fn(|| out.pop()).collect();
        assert_eq!(frames.len(), 1, "cancelled before step 0: terminal only");
        assert!(
            matches!(frames[0], Frame::Error { id: 9, code, .. }
                if code == ServeError::Cancelled.wire_code()),
            "terminal must be the Cancelled error: {frames:?}"
        );
        assert!(srv.kv.session_rows("c").is_none(), "cancel evicts the KV");
        srv.shutdown();

        // disconnect: no terminal owed, KV freed
        let srv2 = server();
        srv2.kv.put("d", Mat::zeros(2, 8), Mat::zeros(2, 8)).unwrap();
        let out2 = WriteQueue::new(64);
        let cancels2 = Mutex::new(HashSet::new());
        let dead2 = AtomicBool::new(true);
        let ctx2 = StreamCtx {
            server: &srv2,
            out: &out2,
            stall: Duration::from_secs(1),
            cancels: &cancels2,
            dead: &dead2,
        };
        run_stream(&ctx2, 10, "d", steps(3, 8));
        assert!(out2.is_empty(), "a dead peer is owed no frames");
        assert!(srv2.kv.session_rows("d").is_none(), "disconnect mid-decode evicts the KV");
        srv2.shutdown();
    }

    #[test]
    fn stalled_stream_does_not_delay_another_sessions_cadence() {
        // the isolation claim of the slow-consumer policy: a stalled
        // stream blocks only its *own* routing — another session's
        // stream completes every step while the stalled one is still
        // parked inside its stall budget, and only the stalled one is
        // shed.  Deterministic at the write-queue layer (PR-8 style):
        // the budget (3 s) dwarfs the healthy stream's full runtime.
        let srv = Arc::new(server());
        srv.kv.put("slow", Mat::zeros(2, 8), Mat::zeros(2, 8)).unwrap();
        srv.kv.put("fast", Mat::zeros(2, 8), Mat::zeros(2, 8)).unwrap();
        let stall = Duration::from_secs(3);

        // stream A: queue of 1 that nobody pops — parks at its second
        // token until the budget sheds it
        let slow_out = Arc::new(WriteQueue::new(1));
        let srv_a = Arc::clone(&srv);
        let slow_out_a = Arc::clone(&slow_out);
        let a = thread::spawn(move || {
            let cancels = Mutex::new(HashSet::new());
            let dead = AtomicBool::new(false);
            let ctx = StreamCtx {
                server: &srv_a,
                out: &slow_out_a,
                stall,
                cancels: &cancels,
                dead: &dead,
            };
            run_stream(&ctx, 1, "slow", steps(6, 8));
        });

        // stream B: actively drained — must run to End while A is parked
        let fast_out = Arc::new(WriteQueue::new(1));
        let fast_out_d = Arc::clone(&fast_out);
        let drainer = thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(f) = fast_out_d.pop() {
                got.push(f);
            }
            got
        });
        let cancels = Mutex::new(HashSet::new());
        let dead = AtomicBool::new(false);
        let ctx = StreamCtx {
            server: &srv,
            out: &fast_out,
            stall,
            cancels: &cancels,
            dead: &dead,
        };
        let t0 = Instant::now();
        run_stream(&ctx, 2, "fast", steps(6, 8));
        let fast_elapsed = t0.elapsed();
        fast_out.close();
        let got = drainer.join().unwrap();

        // B finished whole while A was still inside its stall window
        assert!(
            fast_elapsed < stall,
            "healthy stream took {fast_elapsed:?} — it must not wait on the stalled one"
        );
        assert_eq!(
            srv.metrics.slow_consumer_shed.load(Ordering::Relaxed),
            0,
            "the stalled stream must still be parked when the healthy one finishes"
        );
        let tokens = got.iter().filter(|f| matches!(f, Frame::Token { .. })).count();
        let ends = got.iter().filter(|f| matches!(f, Frame::End { .. })).count();
        assert_eq!((tokens, ends), (6, 1), "every healthy token + exactly one End: {got:?}");

        // then the budget runs out: only the stalled session is shed
        a.join().unwrap();
        assert_eq!(srv.metrics.slow_consumer_shed.load(Ordering::Relaxed), 1);
        assert!(srv.kv.session_rows("slow").is_none(), "shed stream's KV is evicted");
        assert!(srv.kv.session_rows("fast").is_some(), "healthy stream's KV is untouched");
        match Arc::try_unwrap(srv) {
            Ok(s) => s.shutdown(),
            Err(_) => panic!("server Arc must be unique after the joins"),
        }
    }

    #[test]
    fn blocked_stream_resumes_when_the_writer_catches_up() {
        let srv = server();
        srv.kv.put("r", Mat::zeros(2, 8), Mat::zeros(2, 8)).unwrap();
        let out = Arc::new(WriteQueue::new(1));
        let cancels = Mutex::new(HashSet::new());
        let dead = AtomicBool::new(false);
        // slow consumer that still beats the generous stall budget
        let out2 = Arc::clone(&out);
        let drainer = thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(f) = out2.pop() {
                thread::sleep(Duration::from_millis(5));
                got.push(f);
            }
            got
        });
        let ctx = StreamCtx {
            server: &srv,
            out: &out,
            stall: Duration::from_secs(10),
            cancels: &cancels,
            dead: &dead,
        };
        run_stream(&ctx, 11, "r", steps(5, 8));
        out.close();
        let got = drainer.join().unwrap();
        let tokens = got.iter().filter(|f| matches!(f, Frame::Token { .. })).count();
        let ends = got.iter().filter(|f| matches!(f, Frame::End { .. })).count();
        assert_eq!((tokens, ends), (5, 1), "backpressure blocks, then every frame lands");
        assert_eq!(srv.metrics.slow_consumer_shed.load(Ordering::Relaxed), 0);
        srv.shutdown();
    }
}

//! Per-connection lifecycle: one reader, one driver, one writer.
//!
//! * **reader** (the connection's own thread) — performs the handshake,
//!   then decodes frames off the socket with a short read timeout
//!   ([`TICK`]) so it notices shutdown/drain between frames.  `Cancel`
//!   frames land in the shared cancel set immediately (they must take
//!   effect while the driver is mid-stream); work frames are forwarded
//!   to the driver's channel.  EOF or a torn frame is the disconnect
//!   signal: the `dead` flag stops the active stream at its next step
//!   boundary, which cancels the session and frees its KV.
//! * **driver** — executes work frames strictly in order (the wire is a
//!   per-connection program: `Put`, then `Append`/`Query`/`Stream`
//!   against what is resident).  It owns the door: shape/geometry
//!   validation (typed `Error { code: 0 }` frames), the wire-request
//!   gate (`ingress_max_requests`, layered over the server's own
//!   admission control), and the drain refusal (`Error { code:
//!   Shutdown }` for work arriving after admissions closed).
//! * **writer** — drains the bounded [`WriteQueue`] to the socket.  Any
//!   write error means the connection is beyond resync: the queue is
//!   aborted and `dead` is raised.
//!
//! The threads share no locks beyond the write queue and the cancel
//! set; teardown is by flags + channel closure, so every thread exits
//! within one tick of any terminal condition and the connection thread
//! can join all of them deterministically.

use std::collections::HashSet;
use std::net::{Shutdown, TcpStream};
use std::time::{Duration, Instant};

use super::super::protocol::{self, WriteQueue};
use super::super::request::ServeError;
use super::super::server::Server;
use super::frame::{self, Frame, ReadOutcome, FORK_WIRE_VERSION, MIN_WIRE_VERSION, WIRE_VERSION};
use super::stream::{error_frame, run_stream, StreamCtx};
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::mpsc::{channel, RecvTimeoutError};
use crate::sync::{thread, Arc, Mutex};

/// Reader/driver tick: the socket read timeout, and therefore the
/// cadence at which parked loops notice stop/drain/teardown flags.
pub(super) const TICK: Duration = Duration::from_millis(50);

/// Patience for the opening `Hello` before the connection is refused.
const HANDSHAKE_PATIENCE: Duration = Duration::from_secs(5);

/// Per-frame socket write bound: a peer that stops reading cannot park
/// the writer forever (the drain join depends on it).  A timed-out
/// write may be partial — beyond resync — so it tears the connection.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Ingress-wide state shared by every connection (and the acceptors).
pub(super) struct Shared {
    pub server: Arc<Server>,
    /// Hard stop: acceptors, readers and idle drivers exit at their
    /// next tick.
    pub stop: AtomicBool,
    /// Soft drain: work frames are refused with a wire `Shutdown`
    /// error; idle connections are told `Bye` and closed.
    pub draining: AtomicBool,
    /// Wire-request gate (`ingress_max_requests`): requests admitted
    /// past the door across all connections, held for a stream's whole
    /// lifetime.  Layered over the server's own `max_pending_requests`.
    pub active_requests: AtomicU64,
    /// Connection gate (`ingress_max_connections`), claimed by the
    /// acceptor and released when the connection thread exits.
    pub active_conns: AtomicU64,
    pub knobs: Knobs,
}

/// The ingress knobs a connection needs (resolved from
/// `CoordinatorConfig` at bind).
pub(super) struct Knobs {
    pub max_requests: u64,
    pub write_queue: usize,
    pub stall_budget: Duration,
}

/// Serve one accepted connection to completion.  Called on the
/// connection's own thread; joins its writer/driver before returning,
/// so `Ingress::drain` can join connection threads and know the whole
/// cell is gone.
pub(super) fn run_conn(sock: TcpStream, shared: Arc<Shared>) {
    if sock.set_read_timeout(Some(TICK)).is_err() {
        return;
    }
    let write_half = match sock.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let out = Arc::new(WriteQueue::new(shared.knobs.write_queue));
    let dead = Arc::new(AtomicBool::new(false));
    let writer = {
        let out = Arc::clone(&out);
        let dead = Arc::clone(&dead);
        thread::spawn(move || writer_loop(write_half, &out, &dead))
    };

    let mut sock = sock;
    if let Some(version) = handshake(&mut sock, &shared, &out) {
        serve_frames(&mut sock, &shared, &out, &dead, version);
    }
    // graceful close flushes whatever is queued (terminals, Bye);
    // abortive paths already emptied it
    out.close();
    let _ = writer.join();
    let _ = sock.shutdown(Shutdown::Both);
}

/// Expect `Hello`, answer `HelloAck` with the negotiated version and
/// the KV geometry the door validates against.  Anything else is a
/// `Bye` + refusal.  Returns the negotiated version on success — the
/// connection's dialect, which the door enforces per-frame.
fn handshake(sock: &mut TcpStream, shared: &Shared, out: &WriteQueue<Frame>) -> Option<u32> {
    let deadline = Instant::now() + HANDSHAKE_PATIENCE;
    let stop = || {
        // ordering: Relaxed — advisory shutdown flag; a stale read only
        // delays the refusal one tick
        shared.stop.load(Ordering::Relaxed) || Instant::now() >= deadline
    };
    let refused = |detail: String| {
        let _ = out.push_unbounded(Frame::Bye { detail });
        None
    };
    match frame::read_frame(sock, &stop) {
        Ok(ReadOutcome::Frame(Frame::Hello { version })) => {
            if !(MIN_WIRE_VERSION..=WIRE_VERSION).contains(&version) {
                return refused(format!(
                    "version mismatch: client speaks {version}, server speaks \
                     {MIN_WIRE_VERSION}..={WIRE_VERSION}"
                ));
            }
            // echo the *client's* version: every frame a vN client can
            // send is encoded identically in vN+, so the server simply
            // speaks the client's dialect (frames newer than it — e.g.
            // Fork on a v1 connection — are refused at the door)
            let ack = Frame::HelloAck {
                version,
                head_dim: shared.server.head_dim() as u32,
                seq_len: shared.server.kv.seq_len() as u32,
            };
            out.push_unbounded(ack).ok().map(|_| version)
        }
        Ok(ReadOutcome::Frame(f)) => {
            refused(format!("handshake violation: expected Hello, got {}", frame_name(&f)))
        }
        Ok(ReadOutcome::Eof) | Err(_) => None,
        Ok(ReadOutcome::Stopped) => refused("handshake timed out or server stopping".into()),
    }
}

/// The post-handshake reader loop plus driver thread (see module docs).
fn serve_frames(
    sock: &mut TcpStream,
    shared: &Arc<Shared>,
    out: &Arc<WriteQueue<Frame>>,
    dead: &Arc<AtomicBool>,
    wire_version: u32,
) {
    let cancels: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));
    // raised by the driver once it has said `Bye`: the reader exits at
    // its next tick instead of waiting for client EOF
    let closing = Arc::new(AtomicBool::new(false));
    let (tx, rx) = channel::<Frame>();
    let driver = {
        let shared = Arc::clone(shared);
        let out = Arc::clone(out);
        let cancels = Arc::clone(&cancels);
        let dead = Arc::clone(dead);
        let closing = Arc::clone(&closing);
        thread::spawn(move || {
            driver_loop(&shared, &out, &rx, &cancels, &dead, &closing, wire_version);
        })
    };

    let stop = {
        let shared = Arc::clone(shared);
        let closing = Arc::clone(&closing);
        let dead = Arc::clone(dead);
        move || {
            // ordering: Relaxed — advisory teardown flags; a stale read
            // only delays the reader's exit one tick
            shared.stop.load(Ordering::Relaxed)
                || closing.load(Ordering::Relaxed)
                || dead.load(Ordering::Relaxed)
        }
    };

    let mut disconnected = false;
    loop {
        match frame::read_frame(sock, &stop) {
            Ok(ReadOutcome::Frame(f)) => match f {
                // cancels bypass the driver queue: they must take
                // effect while the driver is mid-stream
                Frame::Cancel { id } => {
                    cancels.lock().insert(id);
                }
                Frame::Goodbye => {
                    let _ = tx.send(Frame::Goodbye);
                    break;
                }
                work @ (Frame::Put { .. }
                | Frame::Query { .. }
                | Frame::Append { .. }
                | Frame::Stream { .. }
                | Frame::Fork { .. }) => {
                    if tx.send(work).is_err() {
                        break; // driver gone (drain Bye raced the send)
                    }
                }
                other => {
                    // a server->client tag or a second Hello: the peer
                    // is off-protocol; say why and hang up
                    let _ = out.push_unbounded(Frame::Bye {
                        detail: format!("protocol violation: unexpected {}", frame_name(&other)),
                    });
                    break;
                }
            },
            Ok(ReadOutcome::Eof) => {
                disconnected = true;
                break;
            }
            Ok(ReadOutcome::Stopped) => break,
            Err(_) => {
                // torn frame or socket error: same as a disconnect
                disconnected = true;
                break;
            }
        }
    }
    if disconnected {
        // ordering: Relaxed — advisory teardown flag (the active stream
        // sheds at its next step boundary and frees the session's KV)
        dead.store(true, Ordering::Relaxed);
        // ordering: Relaxed — statistical counter
        shared.server.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
    }
    drop(tx); // driver finishes its backlog, then exits
    let _ = driver.join();
}

/// Sequential executor for the connection's work frames.
fn driver_loop(
    shared: &Shared,
    out: &WriteQueue<Frame>,
    rx: &crate::sync::mpsc::Receiver<Frame>,
    cancels: &Mutex<HashSet<u64>>,
    dead: &AtomicBool,
    closing: &AtomicBool,
    wire_version: u32,
) {
    loop {
        match rx.recv_timeout(TICK) {
            Ok(Frame::Goodbye) => {
                let _ = out.push_unbounded(Frame::Bye { detail: "goodbye".into() });
                break;
            }
            Ok(work) => exec(shared, out, cancels, dead, wire_version, work),
            Err(RecvTimeoutError::Timeout) => {
                // ordering: Relaxed — advisory flags checked each tick
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
                // ordering: Relaxed — see above
                if shared.draining.load(Ordering::Relaxed) {
                    // idle under drain: explicit terminal farewell
                    let _ = out.push_unbounded(Frame::Bye { detail: "server draining".into() });
                    break;
                }
            }
            Err(RecvTimeoutError::Disconnected) => break, // reader exited
        }
    }
    // ordering: Relaxed — advisory flag; the reader exits at its next tick
    closing.store(true, Ordering::Relaxed);
    out.close();
}

/// Execute one admitted work frame to its single terminal frame.
fn exec(
    shared: &Shared,
    out: &WriteQueue<Frame>,
    cancels: &Mutex<HashSet<u64>>,
    dead: &AtomicBool,
    wire_version: u32,
    f: Frame,
) {
    let id = match f.id() {
        Some(id) => id,
        None => return,
    };
    // work arriving after admissions closed is refused, typed
    // ordering: Relaxed — advisory drain flag; Server::enqueue re-checks
    // with SeqCst, this refusal is just the earlier, cheaper door
    if shared.draining.load(Ordering::Relaxed) {
        let shutdown = ServeError::Shutdown("server draining: admissions closed".into());
        let _ = out.push_unbounded(Frame::serve_error(id, &shutdown));
        return;
    }
    // the wire-request gate: concurrent requests across all connections
    if !protocol::try_admit(&shared.active_requests, shared.knobs.max_requests) {
        let _ = out.push_unbounded(Frame::serve_error(id, &ServeError::Overloaded));
        return;
    }
    if let Err(detail) = door_check(&shared.server, &f, wire_version) {
        let _ = out.push_unbounded(Frame::invalid(id, detail));
        protocol::release(&shared.active_requests);
        return;
    }
    match f {
        Frame::Put { id, session, k, v } => {
            let reply = match shared.server.kv.put(&session, k, v) {
                Ok(()) => Frame::Ack { id },
                Err(e) => Frame::serve_error(id, &ServeError::KvAdmission(e.to_string())),
            };
            let _ = out.push_unbounded(reply);
        }
        Frame::Query { id, session, q } => {
            let reply = match shared.server.call(&session, q) {
                Ok(resp) => match resp.output {
                    Ok(outv) => Frame::Output { id, out: outv },
                    Err(se) => Frame::serve_error(id, &se),
                },
                Err(e) => error_frame(id, &e),
            };
            let _ = out.push_unbounded(reply);
        }
        Frame::Append { id, session, k, v } => {
            let reply = match shared.server.append(&session, k, v) {
                Ok(resp) => match resp.output {
                    Ok(_) => Frame::Ack { id },
                    Err(se) => Frame::serve_error(id, &se),
                },
                Err(e) => error_frame(id, &e),
            };
            let _ = out.push_unbounded(reply);
        }
        Frame::Fork { id, parent, child } => {
            // a direct store operation like Put: no backend dispatch,
            // admission failures surface as typed KvAdmission errors
            let reply = match shared.server.fork(&parent, &child) {
                Ok(()) => Frame::Ack { id },
                Err(e) => Frame::serve_error(id, &ServeError::KvAdmission(e.to_string())),
            };
            let _ = out.push_unbounded(reply);
        }
        Frame::Stream { id, session, steps } => {
            let ctx = StreamCtx {
                server: &shared.server,
                out,
                stall: shared.knobs.stall_budget,
                cancels,
                dead,
            };
            run_stream(&ctx, id, &session, steps);
        }
        _ => {}
    }
    protocol::release(&shared.active_requests);
}

/// Door validation: shape/geometry/length checks against the server's
/// KV geometry, plus dialect enforcement (frames newer than the
/// connection's negotiated wire version are refused), all answered with
/// a typed `Error { code: 0 }` before any server resource is touched.
fn door_check(server: &Server, f: &Frame, wire_version: u32) -> Result<(), String> {
    let hd = server.head_dim();
    let seq = server.kv.seq_len();
    let check_session = |s: &str| -> Result<(), String> {
        if s.is_empty() {
            return Err("session name must be non-empty".into());
        }
        Ok(())
    };
    let check_kv = |k: &crate::Mat, v: &crate::Mat| -> Result<(), String> {
        if k.cols != hd || v.cols != hd {
            return Err(format!("K/V dims {}x{} / {}x{} != head_dim {hd}", k.rows, k.cols, v.rows, v.cols));
        }
        if k.rows != v.rows || k.rows == 0 {
            return Err("K/V row counts must match and be non-zero".into());
        }
        if k.rows > seq {
            return Err(format!("{} rows exceed seq_len {seq}", k.rows));
        }
        Ok(())
    };
    let check_q = |q: &[f32]| -> Result<(), String> {
        if q.len() != hd {
            return Err(format!("query dim {} != head_dim {hd}", q.len()));
        }
        Ok(())
    };
    match f {
        Frame::Put { session, k, v, .. } | Frame::Append { session, k, v, .. } => {
            check_session(session)?;
            check_kv(k, v)
        }
        Frame::Query { session, q, .. } => {
            check_session(session)?;
            check_q(q)
        }
        Frame::Fork { parent, child, .. } => {
            // "a v1 client never sends Fork" is an enforced invariant,
            // not a convention: the negotiated dialect gates the frame
            if wire_version < FORK_WIRE_VERSION {
                return Err(format!(
                    "Fork requires wire v{FORK_WIRE_VERSION}+; this connection negotiated \
                     v{wire_version}"
                ));
            }
            check_session(parent)?;
            check_session(child)?;
            if parent == child {
                return Err("fork parent and child must be distinct sessions".into());
            }
            Ok(())
        }
        Frame::Stream { session, steps, .. } => {
            check_session(session)?;
            if steps.is_empty() {
                return Err("stream must carry at least one step".into());
            }
            for (i, s) in steps.iter().enumerate() {
                check_kv(&s.k, &s.v).map_err(|e| format!("step {i}: {e}"))?;
                check_q(&s.q).map_err(|e| format!("step {i}: {e}"))?;
            }
            Ok(())
        }
        _ => Ok(()),
    }
}

/// Writer: drain the queue to the socket until it closes (graceful
/// paths flush the backlog) or a write fails (abort — nothing can be
/// delivered past a partial write).
fn writer_loop(mut sock: TcpStream, out: &WriteQueue<Frame>, dead: &AtomicBool) {
    let _ = sock.set_write_timeout(Some(WRITE_TIMEOUT));
    while let Some(f) = out.pop() {
        if frame::write_frame(&mut sock, &f).is_err() {
            // ordering: Relaxed — advisory teardown flag (streams shed
            // at their next step boundary)
            dead.store(true, Ordering::Relaxed);
            out.abort();
            break;
        }
    }
    let _ = sock.shutdown(Shutdown::Write);
}

/// Short human-readable frame kind (for `Bye` details — never the
/// payload, which may be megabytes of KV).
fn frame_name(f: &Frame) -> &'static str {
    match f {
        Frame::Hello { .. } => "Hello",
        Frame::Put { .. } => "Put",
        Frame::Query { .. } => "Query",
        Frame::Append { .. } => "Append",
        Frame::Stream { .. } => "Stream",
        Frame::Fork { .. } => "Fork",
        Frame::Cancel { .. } => "Cancel",
        Frame::Goodbye => "Goodbye",
        Frame::HelloAck { .. } => "HelloAck",
        Frame::Ack { .. } => "Ack",
        Frame::Output { .. } => "Output",
        Frame::Token { .. } => "Token",
        Frame::End { .. } => "End",
        Frame::Error { .. } => "Error",
        Frame::Bye { .. } => "Bye",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, CoordinatorConfig};
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::ingress::frame::StreamStep;
    use crate::coordinator::kvstore::KvStore;
    use crate::hw::Arith;
    use crate::Mat;
    use std::net::TcpListener;

    fn accel(head_dim: usize) -> AcceleratorConfig {
        AcceleratorConfig { head_dim, seq_len: 32, kv_blocks: 4, parallel_queries: 1, freq_mhz: 500.0 }
    }

    fn shared() -> Arc<Shared> {
        let cfg = CoordinatorConfig { workers: 1, ..CoordinatorConfig::default() };
        let kv = Arc::new(KvStore::new(32, 8, 8));
        let server = Server::start(&cfg, kv, vec![SimBackend::factory(Arith::Hfa, accel(8))])
            .expect("server starts");
        Arc::new(Shared {
            server: Arc::new(server),
            stop: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            active_requests: AtomicU64::new(0),
            active_conns: AtomicU64::new(0),
            knobs: Knobs {
                max_requests: 64,
                write_queue: 16,
                stall_budget: Duration::from_secs(2),
            },
        })
    }

    /// Spin up one served connection; returns the client socket and the
    /// conn thread handle.
    fn one_conn(sh: &Arc<Shared>) -> (TcpStream, thread::JoinHandle<()>) {
        let l = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = l.local_addr().expect("addr");
        let sh2 = Arc::clone(sh);
        let h = thread::spawn(move || {
            let (sock, _) = l.accept().expect("accept");
            run_conn(sock, sh2);
        });
        let client = TcpStream::connect(addr).expect("connect");
        (client, h)
    }

    fn send(c: &mut TcpStream, f: &Frame) {
        frame::write_frame(c, f).expect("client write");
    }

    fn recv(c: &mut TcpStream) -> Frame {
        match frame::read_frame(c, &|| false).expect("client read") {
            ReadOutcome::Frame(f) => f,
            other => panic!("expected frame, got {other:?}"),
        }
    }

    #[test]
    fn handshake_then_put_query_append_roundtrip() {
        let sh = shared();
        let (mut c, h) = one_conn(&sh);
        send(&mut c, &Frame::Hello { version: WIRE_VERSION });
        match recv(&mut c) {
            Frame::HelloAck { version, head_dim, seq_len } => {
                assert_eq!((version, head_dim, seq_len), (WIRE_VERSION, 8, 32));
            }
            other => panic!("expected HelloAck, got {other:?}"),
        }
        let k = Mat::from_vec(2, 8, (0..16).map(|i| i as f32 * 0.1).collect());
        send(&mut c, &Frame::Put { id: 1, session: "s".into(), k: k.clone(), v: k.clone() });
        assert_eq!(recv(&mut c), Frame::Ack { id: 1 });
        send(&mut c, &Frame::Query { id: 2, session: "s".into(), q: vec![0.5; 8] });
        match recv(&mut c) {
            Frame::Output { id, out } => {
                assert_eq!(id, 2);
                assert_eq!(out.len(), 8);
            }
            other => panic!("expected Output, got {other:?}"),
        }
        let row = Mat::from_vec(1, 8, vec![0.25; 8]);
        send(&mut c, &Frame::Append { id: 3, session: "s".into(), k: row.clone(), v: row });
        assert_eq!(recv(&mut c), Frame::Ack { id: 3 });
        send(&mut c, &Frame::Goodbye);
        assert!(matches!(recv(&mut c), Frame::Bye { .. }));
        h.join().expect("conn thread exits");
        match Arc::try_unwrap(sh) {
            Ok(s) => match Arc::try_unwrap(s.server) {
                Ok(srv) => srv.shutdown(),
                Err(_) => panic!("server Arc must be unique after the conn joined"),
            },
            Err(_) => panic!("shared Arc must be unique after the conn joined"),
        }
    }

    #[test]
    fn door_rejects_bad_shapes_with_code_zero_and_keeps_serving() {
        let sh = shared();
        let (mut c, h) = one_conn(&sh);
        send(&mut c, &Frame::Hello { version: WIRE_VERSION });
        let _ = recv(&mut c);
        // wrong query dim
        send(&mut c, &Frame::Query { id: 1, session: "s".into(), q: vec![0.5; 3] });
        match recv(&mut c) {
            Frame::Error { id, code, ref detail, .. } => {
                assert_eq!((id, code), (1, frame::CODE_INVALID));
                assert!(detail.contains("head_dim"), "{detail}");
            }
            other => panic!("expected Error, got {other:?}"),
        }
        // mismatched K/V rows
        send(&mut c, &Frame::Put {
            id: 2,
            session: "s".into(),
            k: Mat::zeros(2, 8),
            v: Mat::zeros(3, 8),
        });
        assert!(matches!(recv(&mut c), Frame::Error { id: 2, code: 0, .. }));
        // empty session name
        send(&mut c, &Frame::Query { id: 3, session: String::new(), q: vec![0.0; 8] });
        assert!(matches!(recv(&mut c), Frame::Error { id: 3, code: 0, .. }));
        // rows past seq_len
        send(&mut c, &Frame::Put {
            id: 4,
            session: "s".into(),
            k: Mat::zeros(33, 8),
            v: Mat::zeros(33, 8),
        });
        assert!(matches!(recv(&mut c), Frame::Error { id: 4, code: 0, .. }));
        // an empty stream
        send(&mut c, &Frame::Stream { id: 5, session: "s".into(), steps: vec![] });
        assert!(matches!(recv(&mut c), Frame::Error { id: 5, code: 0, .. }));
        // the door is stateless: a valid request still lands
        send(&mut c, &Frame::Put { id: 6, session: "s".into(), k: Mat::zeros(2, 8), v: Mat::zeros(2, 8) });
        assert_eq!(recv(&mut c), Frame::Ack { id: 6 });
        // gate must be fully released after rejections
        // ordering: Relaxed — quiesced single-threaded readback
        assert_eq!(sh.active_requests.load(Ordering::Relaxed), 0);
        send(&mut c, &Frame::Goodbye);
        let _ = recv(&mut c);
        h.join().expect("conn thread exits");
    }

    #[test]
    fn fork_over_the_wire_shares_and_serves_the_child() {
        let sh = shared();
        let (mut c, h) = one_conn(&sh);
        send(&mut c, &Frame::Hello { version: WIRE_VERSION });
        let _ = recv(&mut c);
        let k = Mat::from_vec(2, 8, (0..16).map(|i| i as f32 * 0.125).collect());
        send(&mut c, &Frame::Put { id: 1, session: "base".into(), k: k.clone(), v: k.clone() });
        assert_eq!(recv(&mut c), Frame::Ack { id: 1 });
        send(&mut c, &Frame::Fork { id: 2, parent: "base".into(), child: "beam".into() });
        assert_eq!(recv(&mut c), Frame::Ack { id: 2 });
        // the forked child stores zero new bytes and answers queries
        assert_eq!(sh.server.kv.used_bytes(), sh.server.kv.shared_bytes());
        send(&mut c, &Frame::Query { id: 3, session: "beam".into(), q: vec![0.5; 8] });
        let beam_out = match recv(&mut c) {
            Frame::Output { id, out } => {
                assert_eq!(id, 3);
                out
            }
            other => panic!("expected Output, got {other:?}"),
        };
        send(&mut c, &Frame::Query { id: 4, session: "base".into(), q: vec![0.5; 8] });
        match recv(&mut c) {
            Frame::Output { out, .. } => assert_eq!(out, beam_out, "fork is bit-identical"),
            other => panic!("expected Output, got {other:?}"),
        }
        // door rejections: self-fork and empty child
        send(&mut c, &Frame::Fork { id: 5, parent: "base".into(), child: "base".into() });
        assert!(matches!(recv(&mut c), Frame::Error { id: 5, code: 0, .. }));
        send(&mut c, &Frame::Fork { id: 6, parent: "base".into(), child: String::new() });
        assert!(matches!(recv(&mut c), Frame::Error { id: 6, code: 0, .. }));
        // unknown parent passes the door but fails typed in the store
        send(&mut c, &Frame::Fork { id: 7, parent: "nope".into(), child: "x".into() });
        match recv(&mut c) {
            Frame::Error { id, code, ref detail, .. } => {
                assert_eq!((id, code), (7, ServeError::KvAdmission(String::new()).wire_code()));
                assert!(detail.contains("unknown parent"), "{detail}");
            }
            other => panic!("expected Error, got {other:?}"),
        }
        send(&mut c, &Frame::Goodbye);
        let _ = recv(&mut c);
        h.join().expect("conn thread exits");
    }

    #[test]
    fn v1_clients_still_handshake_and_serve() {
        let sh = shared();
        let (mut c, h) = one_conn(&sh);
        send(&mut c, &Frame::Hello { version: MIN_WIRE_VERSION });
        match recv(&mut c) {
            Frame::HelloAck { version, .. } => {
                assert_eq!(version, MIN_WIRE_VERSION, "the ack echoes the client's dialect");
            }
            other => panic!("expected HelloAck, got {other:?}"),
        }
        // a v1 workload is served unchanged
        send(&mut c, &Frame::Put { id: 1, session: "s".into(), k: Mat::zeros(2, 8), v: Mat::zeros(2, 8) });
        assert_eq!(recv(&mut c), Frame::Ack { id: 1 });
        send(&mut c, &Frame::Goodbye);
        let _ = recv(&mut c);
        h.join().expect("conn thread exits");
    }

    #[test]
    fn v1_connections_cannot_fork() {
        let sh = shared();
        sh.server.kv.put("base", Mat::zeros(2, 8), Mat::zeros(2, 8)).expect("put");
        let (mut c, h) = one_conn(&sh);
        send(&mut c, &Frame::Hello { version: MIN_WIRE_VERSION });
        let _ = recv(&mut c);
        // the negotiated dialect is enforced per-frame: a v1 connection
        // sending the v2-only Fork gets a typed door refusal
        send(&mut c, &Frame::Fork { id: 1, parent: "base".into(), child: "beam".into() });
        match recv(&mut c) {
            Frame::Error { id, code, ref detail, .. } => {
                assert_eq!((id, code), (1, frame::CODE_INVALID));
                assert!(detail.contains("negotiated v1"), "{detail}");
            }
            other => panic!("expected Error, got {other:?}"),
        }
        assert!(!sh.server.kv.contains("beam"), "refused fork must not create the child");
        // the refusal is per-frame, not connection-fatal
        send(&mut c, &Frame::Put { id: 2, session: "s".into(), k: Mat::zeros(2, 8), v: Mat::zeros(2, 8) });
        assert_eq!(recv(&mut c), Frame::Ack { id: 2 });
        // gate fully released after the rejection
        // ordering: Relaxed — quiesced single-threaded readback
        assert_eq!(sh.active_requests.load(Ordering::Relaxed), 0);
        send(&mut c, &Frame::Goodbye);
        let _ = recv(&mut c);
        h.join().expect("conn thread exits");
    }

    #[test]
    fn handshake_violations_get_a_bye() {
        // version mismatch
        let sh = shared();
        let (mut c, h) = one_conn(&sh);
        send(&mut c, &Frame::Hello { version: 999 });
        match recv(&mut c) {
            Frame::Bye { detail } => assert!(detail.contains("version mismatch"), "{detail}"),
            other => panic!("expected Bye, got {other:?}"),
        }
        h.join().expect("conn thread exits");

        // first frame is not Hello
        let (mut c2, h2) = one_conn(&sh);
        send(&mut c2, &Frame::Ack { id: 1 });
        match recv(&mut c2) {
            Frame::Bye { detail } => assert!(detail.contains("expected Hello"), "{detail}"),
            other => panic!("expected Bye, got {other:?}"),
        }
        h2.join().expect("conn thread exits");
    }

    #[test]
    fn unknown_wire_error_codes_do_not_round_trip_but_door_codes_do() {
        // a door rejection decodes client-side as "no ServeError" (code 0)
        let f = Frame::invalid(9, "query dim 3 != head_dim 8");
        match f {
            Frame::Error { code, transient, ref detail, .. } => {
                assert_eq!(ServeError::from_wire(code, transient, detail), None);
            }
            other => panic!("expected Error, got {other:?}"),
        }
    }

    #[test]
    fn disconnect_mid_stream_is_counted_and_frees_the_session() {
        let sh = shared();
        sh.server.kv.put("d", Mat::zeros(2, 8), Mat::zeros(2, 8)).expect("put");
        let (mut c, h) = one_conn(&sh);
        send(&mut c, &Frame::Hello { version: WIRE_VERSION });
        let _ = recv(&mut c);
        // a long stream, then vanish after the first token
        let steps: Vec<StreamStep> = (0..64)
            .map(|_| StreamStep {
                k: Mat::from_vec(1, 8, vec![0.1; 8]),
                v: Mat::from_vec(1, 8, vec![0.1; 8]),
                q: vec![0.5; 8],
            })
            .collect();
        send(&mut c, &Frame::Stream { id: 1, session: "d".into(), steps });
        let first = recv(&mut c);
        assert!(matches!(first, Frame::Token { id: 1, step: 0, .. }), "{first:?}");
        drop(c); // disconnect with 63 steps outstanding
        h.join().expect("conn thread exits");
        // ordering: Relaxed — quiesced readback after the join
        assert_eq!(sh.server.metrics.disconnects.load(Ordering::Relaxed), 1);
        assert!(
            sh.server.kv.session_rows("d").is_none(),
            "disconnect mid-decode must evict the session's KV"
        );
        // ordering: Relaxed — quiesced readback after the join
        assert_eq!(sh.active_requests.load(Ordering::Relaxed), 0, "gate released");
    }
}

//! Wire codec for the streaming ingress: length-prefixed binary frames
//! over a byte stream (hand-rolled — no serialization deps offline,
//! matching the repo's JSON-by-hand stance in `benchlib`).
//!
//! ## Framing
//!
//! Every frame is `u32 LE body length | body`, where the body is
//! `u8 tag | tag-specific fields` and the length counts the body only.
//! Bodies are capped at [`MAX_FRAME`] so a corrupt or hostile length
//! prefix cannot make the reader allocate unboundedly.  Field encoding:
//!
//! * integers — little-endian fixed width (`u16`/`u32`/`u64`)
//! * strings — `u16 LE byte length | UTF-8 bytes`
//! * f32 vectors — `u32 LE count | count * f32 LE`
//! * matrices — `u32 LE rows | u32 LE cols | rows*cols * f32 LE`
//!
//! ## Reading
//!
//! [`read_frame`] distinguishes the three ways a socket read ends:
//! a complete frame, a clean EOF **at a frame boundary** (the peer
//! closed after a whole frame — [`ReadOutcome::Eof`]), and a torn frame
//! (EOF with a length prefix or body half-read — an
//! [`io::ErrorKind::UnexpectedEof`] error, because data was lost).
//! Timeout errors (`WouldBlock`/`TimedOut`) never lose bytes: the
//! partial frame is accumulated across retries inside the call, and the
//! caller-supplied `stop` predicate is polled at each timeout tick so a
//! reader parked on an idle socket can still notice shutdown.

use std::io::{self, Read, Write};

use super::super::request::ServeError;
use crate::Mat;

/// Protocol version carried by `Hello`/`HelloAck`; bumped on any wire
/// change.  v2 added the `Fork` frame (cross-session KV prefix
/// sharing).  The handshake negotiates: the server accepts any client
/// in [`MIN_WIRE_VERSION`]`..=`[`WIRE_VERSION`] and echoes the
/// *client's* version in `HelloAck`, so a v1 client keeps working
/// unchanged; anything outside the range is refused at the handshake.
/// The negotiated version is an enforced invariant, not a convention:
/// the door rejects frames newer than the connection's dialect (a v1
/// connection sending `Fork` gets a typed per-frame refusal).
pub const WIRE_VERSION: u32 = 2;

/// Oldest client version the server still speaks (every v1 frame is
/// encoded identically in v2 — the bump is purely additive).
pub const MIN_WIRE_VERSION: u32 = 1;

/// First wire version that carries `Fork`; the door refuses the frame
/// on connections that negotiated anything older.
pub const FORK_WIRE_VERSION: u32 = 2;

/// Upper bound on a frame body (16 MiB) — large enough for a full
/// `Put` of any geometry this repo benchmarks, small enough that a
/// corrupt length prefix cannot OOM the reader.
pub const MAX_FRAME: usize = 16 << 20;

/// Wire error code for protocol-level rejections decided at the door
/// (malformed or shape-invalid requests that never became a
/// [`ServeError`]); serving errors use [`ServeError::wire_code`] (1..=6).
pub const CODE_INVALID: u8 = 0;

// Client -> server tags.
const T_HELLO: u8 = 0x01;
const T_PUT: u8 = 0x02;
const T_QUERY: u8 = 0x03;
const T_APPEND: u8 = 0x04;
const T_STREAM: u8 = 0x05;
const T_CANCEL: u8 = 0x06;
const T_GOODBYE: u8 = 0x07;
const T_FORK: u8 = 0x08; // wire v2+
// Server -> client tags (high bit set).
const T_HELLO_ACK: u8 = 0x81;
const T_ACK: u8 = 0x82;
const T_OUTPUT: u8 = 0x83;
const T_TOKEN: u8 = 0x84;
const T_END: u8 = 0x85;
const T_ERROR: u8 = 0x86;
const T_BYE: u8 = 0x87;

/// One decode step of a [`Frame::Stream`]: the step's new K/V rows and
/// the query to attend with once they are resident — the wire image of
/// the decode loop's `append(k_t, v_t); call(q_t)` pair.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamStep {
    pub k: Mat,
    pub v: Mat,
    pub q: Vec<f32>,
}

/// Every frame of the ingress protocol (both directions).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    // -- client -> server --
    /// Handshake opener; must be the first frame on a connection.
    Hello { version: u32 },
    /// Install a session's prefill KV (server replies `Ack` / `Error`).
    Put { id: u64, session: String, k: Mat, v: Mat },
    /// One attention query (server replies `Output` / `Error`).
    Query { id: u64, session: String, q: Vec<f32> },
    /// One decode-step KV append (server replies `Ack` / `Error`).
    Append { id: u64, session: String, k: Mat, v: Mat },
    /// A whole decode stream: the server executes the steps in order
    /// and pushes a `Token` frame per step as the scheduler's decode
    /// iteration completes, then exactly one terminal `End` / `Error`.
    Stream { id: u64, session: String, steps: Vec<StreamStep> },
    /// Cancel an in-flight request by id (streams shed at the next
    /// step boundary with `Error { code: Cancelled }`).
    Cancel { id: u64 },
    /// Fork `child` from resident session `parent` (wire v2+): the
    /// child copy-on-writes the parent's KV chunk table — zero bytes
    /// copied at fork time (server replies `Ack` / `Error`).
    Fork { id: u64, parent: String, child: String },
    /// Graceful close: the server flushes replies and answers `Bye`.
    Goodbye,

    // -- server -> client --
    /// Handshake reply: negotiated version plus the KV geometry the
    /// door validates against.
    HelloAck { version: u32, head_dim: u32, seq_len: u32 },
    /// Terminal success for `Put` / `Append`.
    Ack { id: u64 },
    /// Terminal success for `Query`: the attention output.
    Output { id: u64, out: Vec<f32> },
    /// One streamed decode step's output (non-terminal).
    Token { id: u64, step: u32, out: Vec<f32> },
    /// Stream completed: all `steps` tokens were delivered (terminal).
    End { id: u64, steps: u32 },
    /// Terminal failure; `code` is [`ServeError::wire_code`] or
    /// [`CODE_INVALID`] for door rejections, `detail` is human-readable.
    Error { id: u64, code: u8, transient: bool, detail: String },
    /// Connection-level farewell (drain, handshake refusal, protocol
    /// violation); the server closes after sending it.
    Bye { detail: String },
}

impl Frame {
    /// Whether this frame ends a request (exactly one of these is
    /// delivered per accepted request — the invariant the soak asserts).
    pub fn is_terminal(&self) -> bool {
        matches!(self, Frame::Ack { .. } | Frame::Output { .. } | Frame::End { .. } | Frame::Error { .. })
    }

    /// The request id this frame belongs to, if any.
    pub fn id(&self) -> Option<u64> {
        match self {
            Frame::Put { id, .. }
            | Frame::Query { id, .. }
            | Frame::Append { id, .. }
            | Frame::Stream { id, .. }
            | Frame::Fork { id, .. }
            | Frame::Cancel { id }
            | Frame::Ack { id }
            | Frame::Output { id, .. }
            | Frame::Token { id, .. }
            | Frame::End { id, .. }
            | Frame::Error { id, .. } => Some(*id),
            _ => None,
        }
    }

    /// An `Error` frame carrying a [`ServeError`]'s wire code + detail.
    pub fn serve_error(id: u64, e: &ServeError) -> Frame {
        Frame::Error { id, code: e.wire_code(), transient: e.is_transient(), detail: e.to_string() }
    }

    /// An `Error` frame for a door rejection ([`CODE_INVALID`]).
    pub fn invalid(id: u64, detail: impl Into<String>) -> Frame {
        Frame::Error { id, code: CODE_INVALID, transient: false, detail: detail.into() }
    }

    fn encode_body(&self, b: &mut Vec<u8>) {
        match self {
            Frame::Hello { version } => {
                b.push(T_HELLO);
                put_u32(b, *version);
            }
            Frame::Put { id, session, k, v } => {
                b.push(T_PUT);
                put_u64(b, *id);
                put_str(b, session);
                put_mat(b, k);
                put_mat(b, v);
            }
            Frame::Query { id, session, q } => {
                b.push(T_QUERY);
                put_u64(b, *id);
                put_str(b, session);
                put_f32s(b, q);
            }
            Frame::Append { id, session, k, v } => {
                b.push(T_APPEND);
                put_u64(b, *id);
                put_str(b, session);
                put_mat(b, k);
                put_mat(b, v);
            }
            Frame::Stream { id, session, steps } => {
                b.push(T_STREAM);
                put_u64(b, *id);
                put_str(b, session);
                put_u32(b, steps.len() as u32);
                for s in steps {
                    put_mat(b, &s.k);
                    put_mat(b, &s.v);
                    put_f32s(b, &s.q);
                }
            }
            Frame::Fork { id, parent, child } => {
                b.push(T_FORK);
                put_u64(b, *id);
                put_str(b, parent);
                put_str(b, child);
            }
            Frame::Cancel { id } => {
                b.push(T_CANCEL);
                put_u64(b, *id);
            }
            Frame::Goodbye => b.push(T_GOODBYE),
            Frame::HelloAck { version, head_dim, seq_len } => {
                b.push(T_HELLO_ACK);
                put_u32(b, *version);
                put_u32(b, *head_dim);
                put_u32(b, *seq_len);
            }
            Frame::Ack { id } => {
                b.push(T_ACK);
                put_u64(b, *id);
            }
            Frame::Output { id, out } => {
                b.push(T_OUTPUT);
                put_u64(b, *id);
                put_f32s(b, out);
            }
            Frame::Token { id, step, out } => {
                b.push(T_TOKEN);
                put_u64(b, *id);
                put_u32(b, *step);
                put_f32s(b, out);
            }
            Frame::End { id, steps } => {
                b.push(T_END);
                put_u64(b, *id);
                put_u32(b, *steps);
            }
            Frame::Error { id, code, transient, detail } => {
                b.push(T_ERROR);
                put_u64(b, *id);
                b.push(*code);
                b.push(u8::from(*transient));
                put_str(b, detail);
            }
            Frame::Bye { detail } => {
                b.push(T_BYE);
                put_str(b, detail);
            }
        }
    }

    fn decode_body(body: &[u8]) -> io::Result<Frame> {
        let mut c = Cur { b: body, pos: 0 };
        let tag = c.u8()?;
        let f = match tag {
            T_HELLO => Frame::Hello { version: c.u32()? },
            T_PUT => Frame::Put { id: c.u64()?, session: c.str()?, k: c.mat()?, v: c.mat()? },
            T_QUERY => Frame::Query { id: c.u64()?, session: c.str()?, q: c.f32s()? },
            T_APPEND => Frame::Append { id: c.u64()?, session: c.str()?, k: c.mat()?, v: c.mat()? },
            T_STREAM => {
                let id = c.u64()?;
                let session = c.str()?;
                let n = c.u32()? as usize;
                let mut steps = Vec::new();
                for _ in 0..n {
                    steps.push(StreamStep { k: c.mat()?, v: c.mat()?, q: c.f32s()? });
                }
                Frame::Stream { id, session, steps }
            }
            T_FORK => Frame::Fork { id: c.u64()?, parent: c.str()?, child: c.str()? },
            T_CANCEL => Frame::Cancel { id: c.u64()? },
            T_GOODBYE => Frame::Goodbye,
            T_HELLO_ACK => {
                Frame::HelloAck { version: c.u32()?, head_dim: c.u32()?, seq_len: c.u32()? }
            }
            T_ACK => Frame::Ack { id: c.u64()? },
            T_OUTPUT => Frame::Output { id: c.u64()?, out: c.f32s()? },
            T_TOKEN => Frame::Token { id: c.u64()?, step: c.u32()?, out: c.f32s()? },
            T_END => Frame::End { id: c.u64()?, steps: c.u32()? },
            T_ERROR => Frame::Error {
                id: c.u64()?,
                code: c.u8()?,
                transient: c.u8()? != 0,
                detail: c.str()?,
            },
            T_BYE => Frame::Bye { detail: c.str()? },
            t => return Err(bad(format!("unknown frame tag 0x{t:02x}"))),
        };
        if c.pos != body.len() {
            return Err(bad(format!("{} trailing bytes after frame body", body.len() - c.pos)));
        }
        Ok(f)
    }
}

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    let n = bytes.len().min(u16::MAX as usize);
    put_u16(b, n as u16);
    b.extend_from_slice(&bytes[..n]);
}

fn put_f32s(b: &mut Vec<u8>, v: &[f32]) {
    put_u32(b, v.len() as u32);
    for x in v {
        b.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_mat(b: &mut Vec<u8>, m: &Mat) {
    put_u32(b, m.rows as u32);
    put_u32(b, m.cols as u32);
    for x in &m.data {
        b.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked body cursor: every read validates the remaining
/// length before touching the slice, so a malformed frame decodes to a
/// typed `InvalidData` error instead of a panic.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Cur<'_> {
    fn take(&mut self, n: usize) -> io::Result<&[u8]> {
        if self.b.len() - self.pos < n {
            return Err(bad(format!(
                "frame truncated: need {n} bytes, {} remain",
                self.b.len() - self.pos
            )));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> io::Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> io::Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> io::Result<u64> {
        let s = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_le_bytes(a))
    }

    fn str(&mut self) -> io::Result<String> {
        let n = self.u16()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| bad("string field is not UTF-8".into()))
    }

    fn f32s(&mut self) -> io::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        // length check before the allocation: the count field must be
        // covered by bytes actually present in the (MAX_FRAME-capped) body
        let s = self.take(n.checked_mul(4).ok_or_else(|| bad("f32 count overflow".into()))?)?;
        Ok(s.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn mat(&mut self) -> io::Result<Mat> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let n = rows.checked_mul(cols).ok_or_else(|| bad("matrix shape overflow".into()))?;
        let s = self.take(n.checked_mul(4).ok_or_else(|| bad("matrix size overflow".into()))?)?;
        let data: Vec<f32> =
            s.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
        Ok(Mat { rows, cols, data })
    }
}

/// How a [`read_frame`] call ended.
#[derive(Debug)]
pub enum ReadOutcome {
    /// A complete frame was decoded.
    Frame(Frame),
    /// Clean EOF at a frame boundary: the peer closed the stream with
    /// no partial frame in flight.
    Eof,
    /// The `stop` predicate fired at a read-timeout tick (shutdown).
    Stopped,
}

/// Write one frame: `u32 LE length | body`.  Any I/O error means the
/// connection is unusable (a partial write cannot be resynchronized);
/// the caller tears the connection down.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> io::Result<()> {
    let mut body = Vec::new();
    f.encode_body(&mut body);
    if body.len() > MAX_FRAME {
        return Err(bad(format!("frame body {} exceeds MAX_FRAME {MAX_FRAME}", body.len())));
    }
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(&body)
}

/// Read one frame, accumulating across read-timeout ticks (so a socket
/// read timeout loses no bytes) and polling `stop` at each tick.
///
/// * clean EOF before any byte of the length prefix → [`ReadOutcome::Eof`]
/// * EOF mid-prefix or mid-body → `UnexpectedEof` ("torn frame")
/// * `stop()` true at a timeout tick → [`ReadOutcome::Stopped`]
pub fn read_frame(r: &mut impl Read, stop: &dyn Fn() -> bool) -> io::Result<ReadOutcome> {
    let mut prefix = [0u8; 4];
    match read_full(r, &mut prefix, true, stop)? {
        Progress::Done => {}
        Progress::Eof => return Ok(ReadOutcome::Eof),
        Progress::Stopped => return Ok(ReadOutcome::Stopped),
    }
    let len = u32::from_le_bytes(prefix) as usize;
    if len > MAX_FRAME {
        return Err(bad(format!("frame length {len} exceeds MAX_FRAME {MAX_FRAME}")));
    }
    let mut body = vec![0u8; len];
    match read_full(r, &mut body, false, stop)? {
        Progress::Done => Frame::decode_body(&body).map(ReadOutcome::Frame),
        // a length prefix was consumed: EOF here lost data
        Progress::Eof => Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "torn frame: peer closed mid-body",
        )),
        Progress::Stopped => Ok(ReadOutcome::Stopped),
    }
}

enum Progress {
    Done,
    Eof,
    Stopped,
}

/// Fill `buf` completely, retrying across `WouldBlock`/`TimedOut`
/// (socket read-timeout ticks) without losing partial progress.
/// `eof_ok_at_start` permits a clean EOF only before the first byte.
fn read_full(
    r: &mut impl Read,
    buf: &mut [u8],
    eof_ok_at_start: bool,
    stop: &dyn Fn() -> bool,
) -> io::Result<Progress> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 && eof_ok_at_start {
                    return Ok(Progress::Eof);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "torn frame: peer closed mid-frame",
                ));
            }
            Ok(n) => filled += n,
            Err(e) => match e.kind() {
                io::ErrorKind::Interrupted => {}
                io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
                    if stop() {
                        return Ok(Progress::Stopped);
                    }
                }
                _ => return Err(e),
            },
        }
    }
    Ok(Progress::Done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        let mut r = Cursor::new(buf);
        match read_frame(&mut r, &|| false).unwrap() {
            ReadOutcome::Frame(back) => assert_eq!(back, f),
            other => panic!("expected a frame, got {other:?}"),
        }
        // and a clean EOF right at the boundary
        assert!(matches!(read_frame(&mut r, &|| false).unwrap(), ReadOutcome::Eof));
    }

    #[test]
    fn every_frame_kind_roundtrips() {
        let m = Mat::from_vec(2, 3, vec![1.0, -2.5, 3.25, 0.0, f32::MIN_POSITIVE, 7.5]);
        roundtrip(Frame::Hello { version: WIRE_VERSION });
        roundtrip(Frame::Put { id: 7, session: "sess".into(), k: m.clone(), v: m.clone() });
        roundtrip(Frame::Query { id: 8, session: "s2".into(), q: vec![0.5, -0.5] });
        roundtrip(Frame::Append { id: 9, session: "s3".into(), k: m.clone(), v: m.clone() });
        roundtrip(Frame::Stream {
            id: 10,
            session: "s4".into(),
            steps: vec![
                StreamStep { k: m.clone(), v: m.clone(), q: vec![1.0, 2.0] },
                StreamStep { k: m.clone(), v: m.clone(), q: vec![3.0] },
            ],
        });
        roundtrip(Frame::Fork { id: 17, parent: "base".into(), child: "beam-0".into() });
        roundtrip(Frame::Cancel { id: 11 });
        roundtrip(Frame::Goodbye);
        roundtrip(Frame::HelloAck { version: 1, head_dim: 8, seq_len: 32 });
        roundtrip(Frame::Ack { id: 12 });
        roundtrip(Frame::Output { id: 13, out: vec![1.0; 8] });
        roundtrip(Frame::Token { id: 14, step: 3, out: vec![-1.0; 4] });
        roundtrip(Frame::End { id: 15, steps: 16 });
        roundtrip(Frame::Error {
            id: 16,
            code: 3,
            transient: true,
            detail: "session cancelled".into(),
        });
        roundtrip(Frame::Bye { detail: "drain".into() });
    }

    #[test]
    fn serve_errors_cross_the_wire_typed() {
        let e = ServeError::BackendFailed { reason: "device lost".into(), transient: true };
        let f = Frame::serve_error(21, &e);
        match f {
            Frame::Error { id, code, transient, ref detail } => {
                assert_eq!((id, code, transient), (21, 4, true));
                assert_eq!(
                    ServeError::from_wire(code, transient, detail),
                    Some(ServeError::BackendFailed { reason: detail.clone(), transient: true })
                );
            }
            other => panic!("expected Error, got {other:?}"),
        }
        assert!(matches!(Frame::invalid(1, "bad shape"),
            Frame::Error { code: CODE_INVALID, .. }));
    }

    #[test]
    fn terminal_classification_matches_the_protocol() {
        assert!(Frame::Ack { id: 1 }.is_terminal());
        assert!(Frame::Output { id: 1, out: vec![] }.is_terminal());
        assert!(Frame::End { id: 1, steps: 2 }.is_terminal());
        assert!(Frame::invalid(1, "x").is_terminal());
        assert!(!Frame::Token { id: 1, step: 0, out: vec![] }.is_terminal());
        assert!(!Frame::Bye { detail: String::new() }.is_terminal());
        assert_eq!(Frame::Cancel { id: 9 }.id(), Some(9));
        assert_eq!(Frame::Goodbye.id(), None);
    }

    #[test]
    fn torn_and_oversized_frames_are_typed_errors() {
        // torn mid-body: write a frame, truncate the bytes
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Ack { id: 5 }).unwrap();
        let torn = &buf[..buf.len() - 3];
        let err = match read_frame(&mut Cursor::new(torn.to_vec()), &|| false) {
            Err(e) => e,
            Ok(o) => panic!("torn frame must error, got {o:?}"),
        };
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);

        // torn mid-prefix
        let err2 = match read_frame(&mut Cursor::new(vec![1u8, 0]), &|| false) {
            Err(e) => e,
            Ok(o) => panic!("torn prefix must error, got {o:?}"),
        };
        assert_eq!(err2.kind(), io::ErrorKind::UnexpectedEof);

        // hostile length prefix
        let huge = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        let err3 = match read_frame(&mut Cursor::new(huge), &|| false) {
            Err(e) => e,
            Ok(o) => panic!("oversized frame must error, got {o:?}"),
        };
        assert_eq!(err3.kind(), io::ErrorKind::InvalidData);

        // unknown tag
        let mut bad_tag = Vec::new();
        bad_tag.extend_from_slice(&1u32.to_le_bytes());
        bad_tag.push(0x7f);
        assert!(read_frame(&mut Cursor::new(bad_tag), &|| false).is_err());

        // trailing garbage after a valid body
        let mut trailing = Vec::new();
        trailing.extend_from_slice(&10u32.to_le_bytes());
        trailing.push(super::T_GOODBYE);
        trailing.extend_from_slice(&[0u8; 9]);
        assert!(read_frame(&mut Cursor::new(trailing), &|| false).is_err());
    }

    #[test]
    fn truncated_count_fields_cannot_allocate_past_the_body() {
        // a Query whose f32 count claims more data than the body holds
        let mut body = vec![T_QUERY];
        body.extend_from_slice(&1u64.to_le_bytes());
        body.extend_from_slice(&1u16.to_le_bytes());
        body.push(b's');
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd count
        let mut buf = Vec::new();
        buf.extend_from_slice(&(body.len() as u32).to_le_bytes());
        buf.extend_from_slice(&body);
        let err = match read_frame(&mut Cursor::new(buf), &|| false) {
            Err(e) => e,
            Ok(o) => panic!("must reject, got {o:?}"),
        };
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }
}

//! Streaming socket front end for the coordinator: a framed,
//! length-prefixed binary protocol ([`frame`]) served by a listener
//! pool, with per-connection reader/driver/writer threads ([`conn`]),
//! per-token output streaming with bounded write queues and the
//! slow-consumer shedding policy ([`stream`]), and a drain that
//! integrates with [`Server::drain`].
//!
//! ## Lifecycle
//!
//! [`Ingress::bind`] takes **ownership** of the [`Server`]: the ingress
//! is the server's front door, and connection threads share it through
//! one `Arc` that [`Ingress::drain`] reclaims after every thread has
//! joined — so the server's own drain (which consumes it) always runs
//! exactly once, after the last socket is quiet.
//!
//! Drain sequencing:
//!
//! 1. raise `draining` — acceptors exit, drivers refuse new work with
//!    typed wire `Shutdown` errors, idle connections get `Bye`;
//! 2. give in-flight streams the drain deadline to reach their
//!    terminal frames (each decode step is still served and streamed);
//! 3. past the deadline, force the stragglers: readers are stopped and
//!    their sockets shut down, which sheds active streams at the next
//!    step boundary (cancelling their sessions and freeing KV);
//! 4. join everything, reclaim the server, and run [`Server::drain`]
//!    with whatever budget remains.
//!
//! The combined outcome is an [`IngressDrainReport`].
//!
//! ## Gates
//!
//! Two admission gates, both built on [`protocol::try_admit`]: a
//! connection gate (`ingress_max_connections`, claimed at accept) and a
//! wire-request gate (`ingress_max_requests`, claimed at the door and
//! held for a stream's entire lifetime).  Both sit *in front of* the
//! server's own `max_pending_requests` admission control — refusals are
//! typed `Overloaded` wire errors, never silent drops.

pub mod conn;
pub mod frame;
pub mod stream;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use self::conn::{Knobs, Shared};
use self::frame::ReadOutcome;
pub use self::frame::{Frame, StreamStep, CODE_INVALID, MAX_FRAME, MIN_WIRE_VERSION, WIRE_VERSION};
use super::protocol;
use super::request::ServeError;
use super::server::{DrainReport, Server};
use crate::config::CoordinatorConfig;
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::thread::{self, JoinHandle};
use crate::sync::{Arc, Mutex};

/// Accept-poll cadence (the listener is non-blocking so acceptors can
/// notice shutdown without a wakeup connection).
const ACCEPT_TICK: Duration = Duration::from_millis(10);

/// One accepted connection being tracked for drain: the socket clone
/// lets a force-teardown unblock the reader/writer from outside.
struct ConnCell {
    sock: TcpStream,
    handle: JoinHandle<()>,
}

/// The framed-socket front end.  See the module docs for the lifecycle.
pub struct Ingress {
    shared: Arc<Shared>,
    addr: SocketAddr,
    acceptors: Vec<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<ConnCell>>>,
}

impl Ingress {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving connections against `server`, which the ingress
    /// now owns.  Knobs come from the same [`CoordinatorConfig`] that
    /// started the server (`ingress_*`, validated > 0 at resolve).
    pub fn bind(addr: &str, server: Server, cfg: &CoordinatorConfig) -> Result<Ingress> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("ingress: cannot bind {addr}"))?;
        listener.set_nonblocking(true).context("ingress: set_nonblocking")?;
        let local = listener.local_addr().context("ingress: local_addr")?;
        let shared = Arc::new(Shared {
            server: Arc::new(server),
            stop: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            active_requests: AtomicU64::new(0),
            active_conns: AtomicU64::new(0),
            knobs: Knobs {
                max_requests: cfg.ingress_max_requests as u64,
                write_queue: cfg.ingress_write_queue,
                stall_budget: Duration::from_micros(cfg.ingress_stall_budget_us.max(1)),
            },
        });
        let conns: Arc<Mutex<Vec<ConnCell>>> = Arc::new(Mutex::new(Vec::new()));
        let max_conns = cfg.ingress_max_connections as u64;
        let mut acceptors = Vec::new();
        for _ in 0..cfg.ingress_acceptors.max(1) {
            let l = listener.try_clone().context("ingress: clone listener")?;
            let shared = Arc::clone(&shared);
            let conns = Arc::clone(&conns);
            acceptors.push(thread::spawn(move || accept_loop(&l, &shared, &conns, max_conns)));
        }
        Ok(Ingress { shared, addr: local, acceptors, conns })
    }

    /// The bound address (resolves the ephemeral port of `":0"` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The served [`Server`]'s metrics (live view for tests/benches).
    pub fn metrics(&self) -> Arc<super::metrics::Metrics> {
        Arc::clone(&self.shared.server.metrics)
    }

    /// Graceful shutdown: close the door, let in-flight streams finish
    /// their terminal frames within `timeout`, force the stragglers,
    /// then run [`Server::drain`] on the reclaimed server with the
    /// remaining budget.  See the module docs for the exact sequencing.
    pub fn drain(self, timeout: Duration) -> IngressDrainReport {
        let deadline = Instant::now() + timeout;
        // 1. close the door: acceptors exit, drivers refuse new work and
        //    Bye idle connections at their next tick
        // ordering: Relaxed — advisory flag polled every tick; the
        // server's own SeqCst draining flag is the authoritative gate
        self.shared.draining.store(true, Ordering::Relaxed);
        for a in self.acceptors {
            let _ = a.join();
        }
        // 2. grace: in-flight connections wind down on their own
        let mut graceful = 0u64;
        loop {
            let pending = {
                let g = self.conns.lock();
                g.iter().filter(|c| !c.handle.is_finished()).count()
            };
            if pending == 0 || Instant::now() >= deadline {
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        // 3. force the stragglers: stop readers, shut their sockets so
        //    blocked reads/writes return, streams shed at the next step
        //    boundary (cancel + evict)
        // ordering: Relaxed — advisory stop flag polled every tick
        self.shared.stop.store(true, Ordering::Relaxed);
        let mut forced = 0u64;
        let cells: Vec<ConnCell> = {
            let mut g = self.conns.lock();
            g.drain(..).collect()
        };
        for cell in &cells {
            if cell.handle.is_finished() {
                graceful += 1;
            } else {
                forced += 1;
                let _ = cell.sock.shutdown(std::net::Shutdown::Both);
            }
        }
        for cell in cells {
            let _ = cell.handle.join();
        }
        // 4. reclaim the server (every thread that held it has joined)
        //    and drain it with whatever budget remains
        let server = match Arc::try_unwrap(self.shared) {
            Ok(shared) => match Arc::try_unwrap(shared.server) {
                Ok(server) => Some(server),
                Err(_) => None,
            },
            Err(_) => None,
        };
        let report = match server {
            Some(server) => {
                let remaining = deadline.saturating_duration_since(Instant::now());
                IngressDrainReport {
                    graceful_conns: graceful,
                    forced_conns: forced,
                    server: server.drain(remaining),
                }
            }
            None => {
                // a thread leaked its Arc — should be impossible after
                // the joins above; report it instead of panicking
                crate::warnlog!(
                    "coordinator::ingress",
                    "drain could not reclaim the server: an Arc is still held"
                );
                IngressDrainReport {
                    graceful_conns: graceful,
                    forced_conns: forced,
                    server: DrainReport { clean: false, served: 0, force_failed: 0, sessions_evicted: 0 },
                }
            }
        };
        if report.clean() {
            crate::info!("coordinator::ingress", "{report}");
        } else {
            crate::warnlog!("coordinator::ingress", "{report}");
        }
        report
    }
}

/// Combined outcome of an [`Ingress::drain`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IngressDrainReport {
    /// Connections that wound down (terminal frames + `Bye`) within the
    /// drain deadline.
    pub graceful_conns: u64,
    /// Connections force-shutdown past it (their active streams were
    /// shed with cancel + evict).
    pub forced_conns: u64,
    /// The reclaimed server's own drain outcome.
    pub server: DrainReport,
}

impl IngressDrainReport {
    /// Fully graceful: no forced connections and a clean server drain.
    pub fn clean(&self) -> bool {
        self.forced_conns == 0 && self.server.clean
    }
}

impl std::fmt::Display for IngressDrainReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ingress drain: {} graceful conns, {} forced; {}",
            self.graceful_conns, self.forced_conns, self.server
        )
    }
}

/// Listener-pool body: non-blocking accepts on a shared listener, the
/// connection gate, and conn-thread spawning.
fn accept_loop(
    listener: &TcpListener,
    shared: &Arc<Shared>,
    conns: &Arc<Mutex<Vec<ConnCell>>>,
    max_conns: u64,
) {
    loop {
        // ordering: Relaxed — advisory flags polled every tick
        if shared.stop.load(Ordering::Relaxed) || shared.draining.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((sock, _peer)) => {
                if !protocol::try_admit(&shared.active_conns, max_conns) {
                    // ordering: Relaxed — statistical counter
                    shared.server.metrics.conns_rejected.fetch_add(1, Ordering::Relaxed);
                    // best-effort typed refusal before the close; the
                    // short drain read afterwards keeps an already-sent
                    // Hello from turning the close into an RST that
                    // would discard the Bye on the peer's side
                    let mut s = sock;
                    let _ = s.set_write_timeout(Some(Duration::from_millis(250)));
                    let _ = frame::write_frame(
                        &mut s,
                        &Frame::Bye { detail: "connection limit reached".into() },
                    );
                    let _ = s.shutdown(std::net::Shutdown::Write);
                    let _ = s.set_read_timeout(Some(Duration::from_millis(250)));
                    let mut sink = [0u8; 256];
                    let _ = std::io::Read::read(&mut s, &mut sink);
                    continue;
                }
                // ordering: Relaxed — statistical counter
                shared.server.metrics.conns_accepted.fetch_add(1, Ordering::Relaxed);
                let track = match sock.try_clone() {
                    Ok(c) => c,
                    Err(_) => {
                        protocol::release(&shared.active_conns);
                        continue;
                    }
                };
                let shared2 = Arc::clone(shared);
                let handle = thread::spawn(move || {
                    conn::run_conn(sock, Arc::clone(&shared2));
                    protocol::release(&shared2.active_conns);
                });
                let mut g = conns.lock();
                // reap finished cells so a long-lived ingress does not
                // accumulate handles without bound
                g.retain(|c| !c.handle.is_finished());
                g.push(ConnCell { sock: track, handle });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(ACCEPT_TICK);
            }
            Err(_) => {
                // transient accept error (EMFILE, aborted connection):
                // back off a tick and keep listening
                thread::sleep(ACCEPT_TICK);
            }
        }
    }
}

/// One event of a streamed request, as the client sees it.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamEvent {
    /// One decode step's output (non-terminal).
    Token { step: u32, out: Vec<f32> },
    /// The stream's terminal success.
    End { steps: u32 },
    /// The stream's terminal failure; `err` is the decoded
    /// [`ServeError`] when the code carried one (door rejections with
    /// code 0 decode to `None`).
    Failed { err: Option<ServeError>, detail: String },
}

impl StreamEvent {
    pub fn is_terminal(&self) -> bool {
        !matches!(self, StreamEvent::Token { .. })
    }
}

/// Minimal blocking client for the wire protocol — the scripted side of
/// the loopback tests, the CI smoke, and the `serve` CLI demo.  One
/// request at a time (the protocol itself allows pipelining; this
/// helper does not).
pub struct Client {
    sock: TcpStream,
    next_id: u64,
    head_dim: usize,
    seq_len: usize,
}

impl Client {
    /// Connect and handshake.
    pub fn connect(addr: &SocketAddr) -> Result<Client> {
        let mut sock = TcpStream::connect(addr)
            .with_context(|| format!("client: cannot connect {addr}"))?;
        frame::write_frame(&mut sock, &Frame::Hello { version: WIRE_VERSION })
            .context("client: hello")?;
        match read_one(&mut sock)? {
            Frame::HelloAck { version, head_dim, seq_len } => {
                // a well-behaved server echoes our own version back;
                // anything in our supported range is still acceptable
                anyhow::ensure!(
                    (MIN_WIRE_VERSION..=WIRE_VERSION).contains(&version),
                    "client: server speaks wire version {version}, not \
                     {MIN_WIRE_VERSION}..={WIRE_VERSION}"
                );
                Ok(Client {
                    sock,
                    next_id: 1,
                    head_dim: head_dim as usize,
                    seq_len: seq_len as usize,
                })
            }
            Frame::Bye { detail } => anyhow::bail!("client: refused at handshake: {detail}"),
            other => anyhow::bail!("client: expected HelloAck, got {other:?}"),
        }
    }

    /// The geometry the server validates against (from the handshake).
    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn alloc_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Install a session's prefill KV.
    pub fn put(&mut self, session: &str, k: crate::Mat, v: crate::Mat) -> Result<()> {
        let id = self.alloc_id();
        frame::write_frame(
            &mut self.sock,
            &Frame::Put { id, session: session.to_string(), k, v },
        )?;
        match read_one(&mut self.sock)? {
            Frame::Ack { id: rid } if rid == id => Ok(()),
            Frame::Error { code, transient, detail, .. } => {
                Err(wire_error(code, transient, &detail))
            }
            other => anyhow::bail!("client: expected Ack for put, got {other:?}"),
        }
    }

    /// Fork `child` from resident session `parent` (wire v2): the child
    /// shares the parent's KV chunks server-side, so this costs one
    /// tiny frame instead of re-sending the whole prefix.
    pub fn fork(&mut self, parent: &str, child: &str) -> Result<()> {
        let id = self.alloc_id();
        frame::write_frame(
            &mut self.sock,
            &Frame::Fork { id, parent: parent.to_string(), child: child.to_string() },
        )?;
        match read_one(&mut self.sock)? {
            Frame::Ack { id: rid } if rid == id => Ok(()),
            Frame::Error { code, transient, detail, .. } => {
                Err(wire_error(code, transient, &detail))
            }
            other => anyhow::bail!("client: expected Ack for fork, got {other:?}"),
        }
    }

    /// One attention query; the output vector on success.
    pub fn query(&mut self, session: &str, q: Vec<f32>) -> Result<Vec<f32>> {
        let id = self.alloc_id();
        frame::write_frame(
            &mut self.sock,
            &Frame::Query { id, session: session.to_string(), q },
        )?;
        match read_one(&mut self.sock)? {
            Frame::Output { id: rid, out } if rid == id => Ok(out),
            Frame::Error { code, transient, detail, .. } => {
                Err(wire_error(code, transient, &detail))
            }
            other => anyhow::bail!("client: expected Output, got {other:?}"),
        }
    }

    /// One decode-step KV append.
    pub fn append(&mut self, session: &str, k: crate::Mat, v: crate::Mat) -> Result<()> {
        let id = self.alloc_id();
        frame::write_frame(
            &mut self.sock,
            &Frame::Append { id, session: session.to_string(), k, v },
        )?;
        match read_one(&mut self.sock)? {
            Frame::Ack { id: rid } if rid == id => Ok(()),
            Frame::Error { code, transient, detail, .. } => {
                Err(wire_error(code, transient, &detail))
            }
            other => anyhow::bail!("client: expected Ack for append, got {other:?}"),
        }
    }

    /// Open a stream; returns its request id.  Pair with
    /// [`Client::next_event`] (or use [`Client::stream`] to collect).
    pub fn start_stream(&mut self, session: &str, steps: Vec<StreamStep>) -> Result<u64> {
        let id = self.alloc_id();
        frame::write_frame(
            &mut self.sock,
            &Frame::Stream { id, session: session.to_string(), steps },
        )?;
        Ok(id)
    }

    /// Read the next event of the open stream (blocking).
    pub fn next_event(&mut self) -> Result<StreamEvent> {
        match read_one(&mut self.sock)? {
            Frame::Token { step, out, .. } => Ok(StreamEvent::Token { step, out }),
            Frame::End { steps, .. } => Ok(StreamEvent::End { steps }),
            Frame::Error { code, transient, detail, .. } => Ok(StreamEvent::Failed {
                err: ServeError::from_wire(code, transient, &detail),
                detail,
            }),
            other => anyhow::bail!("client: unexpected frame mid-stream: {other:?}"),
        }
    }

    /// Run a whole stream, collecting every event through the terminal.
    pub fn stream(&mut self, session: &str, steps: Vec<StreamStep>) -> Result<Vec<StreamEvent>> {
        self.start_stream(session, steps)?;
        let mut events = Vec::new();
        loop {
            let ev = self.next_event()?;
            let terminal = ev.is_terminal();
            events.push(ev);
            if terminal {
                return Ok(events);
            }
        }
    }

    /// Cancel an in-flight request by id (fire-and-forget; the server
    /// answers with the request's terminal `Error { Cancelled }`).
    pub fn cancel(&mut self, id: u64) -> Result<()> {
        frame::write_frame(&mut self.sock, &Frame::Cancel { id })?;
        Ok(())
    }

    /// Graceful close: `Goodbye`, wait for `Bye`.
    pub fn goodbye(mut self) -> Result<String> {
        frame::write_frame(&mut self.sock, &Frame::Goodbye)?;
        loop {
            match read_one(&mut self.sock)? {
                Frame::Bye { detail } => return Ok(detail),
                // late frames of finished requests may still flush
                _ => {}
            }
        }
    }

    /// The raw socket (tests use it to simulate stalls/disconnects).
    pub fn socket(&self) -> &TcpStream {
        &self.sock
    }
}

/// Blocking single-frame read for the client side; EOF is an error here
/// (the client always expects an answer).
fn read_one(sock: &mut TcpStream) -> Result<Frame> {
    match frame::read_frame(sock, &|| false)? {
        ReadOutcome::Frame(f) => Ok(f),
        ReadOutcome::Eof => anyhow::bail!("client: server closed the connection"),
        ReadOutcome::Stopped => anyhow::bail!("client: read interrupted"),
    }
}

/// Decode a wire `Error` frame into the typed [`ServeError`] when it
/// carries one (so `downcast_ref::<ServeError>()` works on the client
/// side exactly like on the in-process API).
fn wire_error(code: u8, transient: bool, detail: &str) -> anyhow::Error {
    match ServeError::from_wire(code, transient, detail) {
        Some(e) => anyhow::Error::new(e),
        None => anyhow::anyhow!("refused at the door: {detail}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::kvstore::KvStore;
    use crate::hw::Arith;
    use crate::Mat;

    fn cfg() -> CoordinatorConfig {
        CoordinatorConfig { workers: 1, ..CoordinatorConfig::default() }
    }

    fn accel(head_dim: usize) -> AcceleratorConfig {
        AcceleratorConfig { head_dim, seq_len: 32, kv_blocks: 4, parallel_queries: 1, freq_mhz: 500.0 }
    }

    fn ingress(c: &CoordinatorConfig) -> Ingress {
        let kv = Arc::new(KvStore::new(32, 8, 8));
        let server = Server::start(c, kv, vec![SimBackend::factory(Arith::Hfa, accel(8))])
            .expect("server starts");
        Ingress::bind("127.0.0.1:0", server, c).expect("ingress binds")
    }

    #[test]
    fn end_to_end_decode_loop_over_the_socket() {
        let c = cfg();
        let ing = ingress(&c);
        let metrics = ing.metrics();
        let mut cl = Client::connect(&ing.local_addr()).expect("connect");
        assert_eq!((cl.head_dim(), cl.seq_len()), (8, 32));
        cl.put("s", Mat::zeros(2, 8), Mat::zeros(2, 8)).expect("put");
        let steps: Vec<StreamStep> = (0..3)
            .map(|i| StreamStep {
                k: Mat::from_vec(1, 8, vec![0.1 * (i + 1) as f32; 8]),
                v: Mat::from_vec(1, 8, vec![0.2 * (i + 1) as f32; 8]),
                q: vec![0.5; 8],
            })
            .collect();
        let events = cl.stream("s", steps).expect("stream");
        let tokens = events.iter().filter(|e| matches!(e, StreamEvent::Token { .. })).count();
        assert_eq!(tokens, 3);
        assert_eq!(*events.last().expect("terminal"), StreamEvent::End { steps: 3 });
        cl.goodbye().expect("goodbye");
        let report = ing.drain(Duration::from_secs(10));
        assert!(report.clean(), "{report}");
        assert_eq!(report.forced_conns, 0);
        let snap = metrics.snapshot();
        assert_eq!(snap.conns_accepted, 1);
        assert_eq!(snap.streams_opened, 1);
        assert_eq!(snap.stream_tokens, 3);
    }

    #[test]
    fn connection_gate_refuses_the_overflow_with_a_typed_bye() {
        let c = CoordinatorConfig { ingress_max_connections: 1, ..cfg() };
        let ing = ingress(&c);
        let metrics = ing.metrics();
        let _held = Client::connect(&ing.local_addr()).expect("first connect");
        // the second connection is refused before the handshake (the
        // exact error shape depends on whether the Bye outraces the
        // close on this host, so only the refusal itself is asserted)
        assert!(Client::connect(&ing.local_addr()).is_err(), "second connection must be refused");
        assert_eq!(metrics.conns_rejected.load(Ordering::Relaxed), 1);
        let report = ing.drain(Duration::from_secs(5));
        assert!(report.server.clean, "{report}");
    }

    #[test]
    fn drain_refuses_new_work_and_byes_idle_connections() {
        let c = cfg();
        let ing = ingress(&c);
        let cl = Client::connect(&ing.local_addr()).expect("connect");
        let report = ing.drain(Duration::from_secs(10));
        assert!(report.clean(), "idle conn must wind down gracefully: {report}");
        assert_eq!(report.graceful_conns, 1);
        // the idle client was told Bye
        let mut sock = cl.sock;
        match frame::read_frame(&mut sock, &|| false).expect("read") {
            ReadOutcome::Frame(Frame::Bye { detail }) => {
                assert!(detail.contains("draining"), "{detail}")
            }
            other => panic!("expected Bye, got {other:?}"),
        }
    }
}

//! Dynamic batcher: groups queued requests by KV session into batches of
//! up to `max_batch`, closing a batch when full or when the forming
//! window expires — the standard continuous-batching front half.
//!
//! Decode-step KV appends ([`Payload::Append`]) are sequencing barriers:
//! an append closes the session's pending queries immediately and ships
//! them in one batch with the append last, so the worker serves the
//! queries against the pre-append KV and then applies the write.  The
//! forming window of a session always counts from its *first* pending
//! request — later sub-cap pushes and append traffic must not reset it.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::request::AttentionRequest;

/// A formed batch: all requests share one KV session, in arrival order
/// (any append is last).
pub struct Batch {
    pub session: String,
    pub requests: Vec<AttentionRequest>,
}

/// Incremental batch former.  Feed it requests; `push` returns batches
/// that hit the size cap (or were closed by an append barrier), and
/// `close_expired` collects the window-expired remainder on ticks.
pub struct Batcher {
    max_batch: usize,
    window: Duration,
    pending: HashMap<String, (Instant, Vec<AttentionRequest>)>,
}

impl Batcher {
    pub fn new(max_batch: usize, window: Duration) -> Batcher {
        Batcher { max_batch: max_batch.max(1), window, pending: HashMap::new() }
    }

    /// Add a request; returns a closed batch when the session hit the
    /// cap or the request is an append barrier.  O(1) either way: the
    /// just-filled session's entry is removed directly — no scan over
    /// other sessions' pending state — and the hot sub-cap path clones
    /// no session key at all (a clone is paid only on a session's first
    /// pending request and on batch close).
    pub fn push(&mut self, req: AttentionRequest) -> Option<Batch> {
        if req.is_append() {
            // barrier: flush this session's pending queries together
            // with the append (queries first — they predate the write)
            let session = req.session.clone();
            let mut requests =
                self.pending.remove(&session).map(|(_, reqs)| reqs).unwrap_or_default();
            requests.push(req);
            return Some(Batch { session, requests });
        }
        let mut close_key: Option<String> = None;
        if let Some((_, reqs)) = self.pending.get_mut(&req.session) {
            if reqs.len() + 1 >= self.max_batch {
                close_key = Some(req.session.clone());
            }
            reqs.push(req);
        } else if self.max_batch == 1 {
            let session = req.session.clone();
            return Some(Batch { session, requests: vec![req] });
        } else {
            self.pending.insert(req.session.clone(), (Instant::now(), vec![req]));
        }
        if let Some(session) = close_key {
            let (_, requests) = self.pending.remove(&session)?;
            return Some(Batch { session, requests });
        }
        None
    }

    /// Collect every batch whose forming window has expired.
    pub fn close_expired(&mut self, now: Instant) -> Vec<Batch> {
        let window = self.window;
        let mut closed = Vec::new();
        self.pending.retain(|session, (t0, requests)| {
            if now.duration_since(*t0) >= window {
                closed.push(Batch {
                    session: session.clone(),
                    requests: std::mem::take(requests),
                });
                false
            } else {
                true
            }
        });
        closed
    }

    /// Flush everything (shutdown path).
    pub fn drain(&mut self) -> Vec<Batch> {
        self.pending
            .drain()
            .map(|(session, (_, requests))| Batch { session, requests })
            .collect()
    }

    pub fn pending_requests(&self) -> usize {
        self.pending.values().map(|(_, v)| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Payload;
    use crate::Mat;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn req(id: u64, session: &str) -> AttentionRequest {
        let (tx, _rx) = channel();
        AttentionRequest {
            id,
            session: session.into(),
            payload: Payload::Query(vec![0.0; 4]),
            arrived: Instant::now(),
            pinned: false,
            reply: tx,
        }
    }

    fn append_req(id: u64, session: &str) -> AttentionRequest {
        let (tx, _rx) = channel();
        AttentionRequest {
            id,
            session: session.into(),
            payload: Payload::Append { k_rows: Mat::zeros(1, 4), v_rows: Mat::zeros(1, 4) },
            arrived: Instant::now(),
            pinned: false,
            reply: tx,
        }
    }

    #[test]
    fn batch_closes_at_cap() {
        let mut b = Batcher::new(3, Duration::from_secs(10));
        assert!(b.push(req(1, "s")).is_none());
        assert!(b.push(req(2, "s")).is_none());
        let batch = b.push(req(3, "s")).expect("full batch");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn sessions_batch_independently() {
        let mut b = Batcher::new(2, Duration::from_secs(10));
        assert!(b.push(req(1, "a")).is_none());
        assert!(b.push(req(2, "b")).is_none());
        let batch = b.push(req(3, "a")).expect("session a full");
        assert_eq!(batch.session, "a");
        assert_eq!(b.pending_requests(), 1);
    }

    #[test]
    fn window_expiry_closes_partial_batches() {
        let mut b = Batcher::new(100, Duration::from_millis(0));
        b.push(req(1, "s"));
        let closed = b.close_expired(Instant::now());
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].requests.len(), 1);
    }

    #[test]
    fn unexpired_batches_stay_pending() {
        let mut b = Batcher::new(100, Duration::from_secs(60));
        b.push(req(1, "s"));
        assert!(b.close_expired(Instant::now()).is_empty());
        assert_eq!(b.pending_requests(), 1);
    }

    #[test]
    fn drain_flushes_all() {
        let mut b = Batcher::new(100, Duration::from_secs(60));
        b.push(req(1, "a"));
        b.push(req(2, "b"));
        let all = b.drain();
        assert_eq!(all.len(), 2);
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn append_closes_pending_queries_in_arrival_order() {
        let mut b = Batcher::new(100, Duration::from_secs(60));
        assert!(b.push(req(1, "s")).is_none());
        assert!(b.push(req(2, "s")).is_none());
        let batch = b.push(append_req(3, "s")).expect("append must close immediately");
        assert_eq!(batch.session, "s");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(
            batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![1, 2, 3],
            "queries first, append last"
        );
        assert!(batch.requests[2].is_append());
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn append_with_no_pending_ships_alone_and_leaves_others() {
        let mut b = Batcher::new(100, Duration::from_secs(60));
        assert!(b.push(req(1, "other")).is_none());
        let batch = b.push(append_req(2, "s")).expect("lone append closes");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(b.pending_requests(), 1, "other session's pending untouched");
    }

    // Guards the `or_insert_with(Instant::now)` stamp: a session under
    // continuous sub-cap traffic must still close `window` after its
    // *first* pending request — later pushes and append traffic on other
    // sessions must not push the deadline out.
    #[test]
    fn window_counts_from_first_pending_request_under_continuous_traffic() {
        let window = Duration::from_millis(200);
        let mut b = Batcher::new(100, window);
        b.push(req(0, "s"));
        let t0 = Instant::now(); // >= the batch's forming stamp
        for i in 1..5u64 {
            // sub-cap traffic keeps arriving; probing before the window
            // must not close, and the new pushes must not reset the clock
            assert!(b.close_expired(t0 + window / 4).is_empty(), "closed early at push {i}");
            b.push(req(i, "s"));
            // append traffic on an unrelated session touches the batcher
            // without disturbing "s"
            let other = b.push(append_req(100 + i, "other"));
            assert!(other.is_some());
        }
        let closed = b.close_expired(t0 + window);
        assert_eq!(closed.len(), 1, "batch must close at window from the first request");
        assert_eq!(closed[0].requests.len(), 5);
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn window_restarts_after_append_barrier_flush() {
        let window = Duration::from_millis(200);
        let mut b = Batcher::new(100, window);
        b.push(req(1, "s"));
        let t0 = Instant::now();
        b.push(append_req(2, "s")).expect("barrier flush");
        // new traffic after the flush starts a fresh window: the old
        // deadline must not apply to it
        b.push(req(3, "s"));
        let t1 = Instant::now();
        assert!(
            b.close_expired(t0 + window / 2).is_empty(),
            "fresh batch must not inherit the flushed batch's deadline"
        );
        let closed = b.close_expired(t1 + window);
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].requests[0].id, 3);
    }
}

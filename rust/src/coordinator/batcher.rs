//! Dynamic batcher: groups queued requests by KV session into batches of
//! up to `max_batch`, closing a batch when full or when the forming
//! window expires — the standard continuous-batching front half.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::request::AttentionRequest;

/// A formed batch: all requests share one KV session.
pub struct Batch {
    pub session: String,
    pub requests: Vec<AttentionRequest>,
}

/// Incremental batch former.  Feed it requests; poll `close_ready` for
/// batches that hit the size cap, and `close_expired` on ticks.
pub struct Batcher {
    max_batch: usize,
    window: Duration,
    pending: HashMap<String, (Instant, Vec<AttentionRequest>)>,
}

impl Batcher {
    pub fn new(max_batch: usize, window: Duration) -> Batcher {
        Batcher { max_batch: max_batch.max(1), window, pending: HashMap::new() }
    }

    /// Add a request; returns a full batch if the session hit the cap.
    pub fn push(&mut self, req: AttentionRequest) -> Option<Batch> {
        let entry = self
            .pending
            .entry(req.session.clone())
            .or_insert_with(|| (Instant::now(), Vec::new()));
        entry.1.push(req);
        if entry.1.len() >= self.max_batch {
            let session = self
                .pending
                .iter()
                .find(|(_, (_, v))| v.len() >= self.max_batch)
                .map(|(k, _)| k.clone())
                .unwrap();
            let (_, reqs) = self.pending.remove(&session).unwrap();
            return Some(Batch { session, requests: reqs });
        }
        None
    }

    /// Collect every batch whose forming window has expired.
    pub fn close_expired(&mut self, now: Instant) -> Vec<Batch> {
        let expired: Vec<String> = self
            .pending
            .iter()
            .filter(|(_, (t0, _))| now.duration_since(*t0) >= self.window)
            .map(|(k, _)| k.clone())
            .collect();
        expired
            .into_iter()
            .map(|session| {
                let (_, requests) = self.pending.remove(&session).unwrap();
                Batch { session, requests }
            })
            .collect()
    }

    /// Flush everything (shutdown path).
    pub fn drain(&mut self) -> Vec<Batch> {
        self.pending
            .drain()
            .map(|(session, (_, requests))| Batch { session, requests })
            .collect()
    }

    pub fn pending_requests(&self) -> usize {
        self.pending.values().map(|(_, v)| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn req(id: u64, session: &str) -> AttentionRequest {
        let (tx, _rx) = channel();
        AttentionRequest {
            id,
            session: session.into(),
            query: vec![0.0; 4],
            arrived: Instant::now(),
            reply: tx,
        }
    }

    #[test]
    fn batch_closes_at_cap() {
        let mut b = Batcher::new(3, Duration::from_secs(10));
        assert!(b.push(req(1, "s")).is_none());
        assert!(b.push(req(2, "s")).is_none());
        let batch = b.push(req(3, "s")).expect("full batch");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn sessions_batch_independently() {
        let mut b = Batcher::new(2, Duration::from_secs(10));
        assert!(b.push(req(1, "a")).is_none());
        assert!(b.push(req(2, "b")).is_none());
        let batch = b.push(req(3, "a")).expect("session a full");
        assert_eq!(batch.session, "a");
        assert_eq!(b.pending_requests(), 1);
    }

    #[test]
    fn window_expiry_closes_partial_batches() {
        let mut b = Batcher::new(100, Duration::from_millis(0));
        b.push(req(1, "s"));
        let closed = b.close_expired(Instant::now());
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].requests.len(), 1);
    }

    #[test]
    fn unexpired_batches_stay_pending() {
        let mut b = Batcher::new(100, Duration::from_secs(60));
        b.push(req(1, "s"));
        assert!(b.close_expired(Instant::now()).is_empty());
        assert_eq!(b.pending_requests(), 1);
    }

    #[test]
    fn drain_flushes_all() {
        let mut b = Batcher::new(100, Duration::from_secs(60));
        b.push(req(1, "a"));
        b.push(req(2, "b"));
        let all = b.drain();
        assert_eq!(all.len(), 2);
        assert_eq!(b.pending_requests(), 0);
    }
}

//! Dynamic batcher: groups queued requests by KV session into per-session
//! groups of up to `max_batch`, then packs closed groups into
//! **cross-session super-batches** — the batch former of a deployment
//! whose traffic is millions of sessions with one in-flight query each,
//! where single-session batching degenerates to batch-size-1 dispatches.
//!
//! Two levels:
//!
//! * **Per-session groups** keep the original semantics exactly: the
//!   forming window counts from the session's *first* pending request
//!   (later sub-cap pushes and other sessions' traffic never reset it),
//!   a group closes when it hits the per-session cap, and a decode-step
//!   KV append ([`Payload::Append`]) is a sequencing barrier that closes
//!   the session's pending queries immediately (queries first, append
//!   last) — appends barrier **only their own session**.
//! * **Super-batches** ([`Batch`]): a cap- or barrier-closed group ships
//!   immediately (latency priority — it never waits for other sessions),
//!   while window-expired groups are packed together, oldest deadline
//!   first, into super-batches capped by `max_total` total requests.
//!   One super-batch is one worker dispatch: N idle sessions' expired
//!   singleton groups become one fused launch instead of N.
//!
//! [`Batcher::next_deadline`] exposes the earliest pending group's expiry
//! so the serving loop can sleep exactly until it instead of polling on a
//! fixed tick (which closed idle partial batches up to ~2x late).

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use super::protocol::{BatchKind, IterToken};
use super::request::AttentionRequest;

/// One session's slice of a super-batch: requests in arrival order (any
/// append is last), all against the same KV session.
pub struct SessionBatch {
    pub session: String,
    pub requests: Vec<AttentionRequest>,
}

/// A formed dispatch: one or more per-session groups served in a single
/// worker pass.  Sessions within a super-batch are distinct (the batcher
/// keys pending groups by session), ordered oldest deadline first.
pub struct Batch {
    pub groups: Vec<SessionBatch>,
    /// Which scheduling lane formed this dispatch (the batcher always
    /// emits `Formed`; the continuous scheduler re-tags its admissions
    /// as `Prefill` and its iteration assemblies as `Decode`).
    pub kind: BatchKind,
    /// Iteration completion token for gated dispatches: dropped when the
    /// batch is fully retired — served, shed, or failed, on every path
    /// including worker panic unwind — reopening the scheduler's lane.
    pub done: Option<IterToken>,
}

impl Batch {
    /// An ungated dispatch (window/cap/barrier front-end, drain path).
    pub fn formed(groups: Vec<SessionBatch>) -> Batch {
        Batch { groups, kind: BatchKind::Formed, done: None }
    }

    fn single(session: String, requests: Vec<AttentionRequest>) -> Batch {
        Batch::formed(vec![SessionBatch { session, requests }])
    }

    /// Total requests across every session group.
    pub fn total_requests(&self) -> usize {
        self.groups.iter().map(|g| g.requests.len()).sum()
    }

    /// Session groups fused into this dispatch.
    pub fn sessions(&self) -> usize {
        self.groups.len()
    }
}

/// Incremental batch former.  Feed it requests; `push` returns dispatches
/// that hit the per-session cap (or were closed by an append barrier),
/// and `close_expired` packs the window-expired remainder into
/// cross-session super-batches.
pub struct Batcher {
    max_batch: usize,
    /// Total-request cap of one packed super-batch.
    max_total: usize,
    window: Duration,
    pending: HashMap<String, (Instant, Vec<AttentionRequest>)>,
    /// FIFO of `(forming stamp, session)` — stamps come from a monotonic
    /// clock at group creation, so the deque is sorted by construction
    /// and the front is always the earliest candidate deadline in O(1)
    /// (no per-message scan over every pending session).  Entries whose
    /// group has since closed (or re-formed under a newer stamp) are
    /// stale and popped lazily; each group creation adds exactly one
    /// entry, so the lazy pops amortize to O(1) per group.
    forming: VecDeque<(Instant, String)>,
}

impl Batcher {
    pub fn new(max_batch: usize, max_total: usize, window: Duration) -> Batcher {
        let max_batch = max_batch.max(1);
        Batcher {
            max_batch,
            max_total: max_total.max(max_batch),
            window,
            pending: HashMap::new(),
            forming: VecDeque::new(),
        }
    }

    /// Add a request; returns a closed dispatch when the session hit the
    /// cap or the request is an append barrier.  O(1) either way: the
    /// just-filled session's entry is removed directly — no scan over
    /// other sessions' pending state — and the hot sub-cap path clones
    /// no session key at all (a clone is paid only on a session's first
    /// pending request and on batch close).  Cap/barrier closes ship
    /// alone (they never wait on other sessions); cross-session packing
    /// happens on the expiry path, where groups are already past their
    /// latency deadline.
    pub fn push(&mut self, req: AttentionRequest) -> Option<Batch> {
        if req.is_append() {
            // barrier: flush this session's pending queries together
            // with the append (queries first — they predate the write)
            let session = req.session.clone();
            let mut requests =
                self.pending.remove(&session).map(|(_, reqs)| reqs).unwrap_or_default();
            requests.push(req);
            return Some(Batch::single(session, requests));
        }
        let mut close_key: Option<String> = None;
        if let Some((_, reqs)) = self.pending.get_mut(&req.session) {
            if reqs.len() + 1 >= self.max_batch {
                close_key = Some(req.session.clone());
            }
            reqs.push(req);
        } else if self.max_batch == 1 {
            let session = req.session.clone();
            return Some(Batch::single(session, vec![req]));
        } else {
            let t0 = Instant::now();
            self.forming.push_back((t0, req.session.clone()));
            self.pending.insert(req.session.clone(), (t0, vec![req]));
        }
        if let Some(session) = close_key {
            let (_, requests) = self.pending.remove(&session)?;
            return Some(Batch::single(session, requests));
        }
        None
    }

    /// The earliest pending group's window expiry, if any group is
    /// forming — the exact instant the serving loop should wake to sweep
    /// (no fixed-tick polling, no late closes).  Amortized O(1): reads
    /// the front of the sorted `forming` deque, lazily discarding stale
    /// entries for groups that have since closed.
    pub fn next_deadline(&mut self) -> Option<Instant> {
        loop {
            let front = match self.forming.front() {
                None => return None,
                Some((t0, session)) => match self.pending.get(session) {
                    // live entry: its stamp still matches the group's
                    Some((cur, _)) if cur == t0 => Some(*t0 + self.window),
                    _ => None,
                },
            };
            match front {
                Some(deadline) => return Some(deadline),
                None => {
                    self.forming.pop_front();
                }
            }
        }
    }

    /// Collect every group whose forming window has expired, packed into
    /// cross-session super-batches: oldest deadline first, each dispatch
    /// capped at `max_total` total requests.
    pub fn close_expired(&mut self, now: Instant) -> Vec<Batch> {
        let window = self.window;
        let mut expired: Vec<(Instant, SessionBatch)> = Vec::new();
        self.pending.retain(|session, (t0, requests)| {
            if now.duration_since(*t0) >= window {
                expired.push((
                    *t0,
                    SessionBatch { session: session.clone(), requests: std::mem::take(requests) },
                ));
                false
            } else {
                true
            }
        });
        expired.sort_by_key(|(t0, _)| *t0);
        self.pack(expired.into_iter().map(|(_, g)| g))
    }

    /// Flush everything (shutdown path), packed like the expiry sweep.
    pub fn drain(&mut self) -> Vec<Batch> {
        self.forming.clear();
        let mut groups: Vec<(Instant, SessionBatch)> = self
            .pending
            .drain()
            .map(|(session, (t0, requests))| (t0, SessionBatch { session, requests }))
            .collect();
        groups.sort_by_key(|(t0, _)| *t0);
        self.pack(groups.into_iter().map(|(_, g)| g))
    }

    /// Greedily pack ordered groups into super-batches of at most
    /// `max_total` total requests (a group is never split; an oversized
    /// group ships as its own dispatch).
    fn pack(&self, groups: impl Iterator<Item = SessionBatch>) -> Vec<Batch> {
        let mut out: Vec<Batch> = Vec::new();
        let mut cur: Vec<SessionBatch> = Vec::new();
        let mut cur_total = 0usize;
        for g in groups {
            if !cur.is_empty() && cur_total + g.requests.len() > self.max_total {
                out.push(Batch::formed(std::mem::take(&mut cur)));
                cur_total = 0;
            }
            cur_total += g.requests.len();
            cur.push(g);
        }
        if !cur.is_empty() {
            out.push(Batch::formed(cur));
        }
        out
    }

    /// Remove every pending request matched by `pred` — the
    /// cancellation / deadline sweep.  Surviving groups keep their
    /// forming stamp (a partially-drained group still closes at its
    /// original window); groups left empty are dropped, their `forming`
    /// entries going stale and popped lazily by [`Batcher::next_deadline`].
    pub fn remove_matching(
        &mut self,
        mut pred: impl FnMut(&AttentionRequest) -> bool,
    ) -> Vec<AttentionRequest> {
        let mut removed = Vec::new();
        self.pending.retain(|_, (_, reqs)| {
            let mut kept = Vec::with_capacity(reqs.len());
            for r in reqs.drain(..) {
                if pred(&r) {
                    removed.push(r);
                } else {
                    kept.push(r);
                }
            }
            *reqs = kept;
            !reqs.is_empty()
        });
        removed
    }

    pub fn pending_requests(&self) -> usize {
        self.pending.values().map(|(_, v)| v.len()).sum()
    }

    /// Whether `session` has a group still forming.  The continuous
    /// scheduler must not route around a forming group (arrival order
    /// would break), so its slot routing checks this first.
    pub fn has_pending_session(&self, session: &str) -> bool {
        self.pending.contains_key(session)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Payload;
    use crate::Mat;
    use crate::sync::atomic::AtomicBool;
    use crate::sync::mpsc::channel;
    use crate::sync::Arc;
    use std::time::Instant;

    fn req(id: u64, session: &str) -> AttentionRequest {
        let (tx, _rx) = channel();
        let now = Instant::now();
        AttentionRequest {
            id,
            session: session.into(),
            payload: Payload::Query(vec![0.0; 4]),
            arrived: now,
            deadline: now + Duration::from_secs(300),
            pinned: false,
            cancelled: Arc::new(AtomicBool::new(false)),
            reply: tx,
        }
    }

    fn append_req(id: u64, session: &str) -> AttentionRequest {
        let (tx, _rx) = channel();
        let now = Instant::now();
        AttentionRequest {
            id,
            session: session.into(),
            payload: Payload::Append { k_rows: Mat::zeros(1, 4), v_rows: Mat::zeros(1, 4) },
            arrived: now,
            deadline: now + Duration::from_secs(300),
            pinned: false,
            cancelled: Arc::new(AtomicBool::new(false)),
            reply: tx,
        }
    }

    /// The lone group of a dispatch expected to be single-session.
    fn only(batch: &Batch) -> &SessionBatch {
        assert_eq!(batch.groups.len(), 1, "expected a single-session dispatch");
        &batch.groups[0]
    }

    #[test]
    fn batch_closes_at_cap() {
        let mut b = Batcher::new(3, 64, Duration::from_secs(10));
        assert!(b.push(req(1, "s")).is_none());
        assert!(b.push(req(2, "s")).is_none());
        let batch = b.push(req(3, "s")).expect("full batch");
        assert_eq!(batch.total_requests(), 3);
        assert_eq!(only(&batch).requests.len(), 3);
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn sessions_batch_independently() {
        let mut b = Batcher::new(2, 64, Duration::from_secs(10));
        assert!(b.push(req(1, "a")).is_none());
        assert!(b.push(req(2, "b")).is_none());
        let batch = b.push(req(3, "a")).expect("session a full");
        assert_eq!(only(&batch).session, "a");
        assert_eq!(b.pending_requests(), 1);
    }

    #[test]
    fn window_expiry_closes_partial_batches() {
        let mut b = Batcher::new(100, 64, Duration::from_millis(0));
        b.push(req(1, "s"));
        let closed = b.close_expired(Instant::now());
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].total_requests(), 1);
    }

    #[test]
    fn unexpired_batches_stay_pending() {
        let mut b = Batcher::new(100, 64, Duration::from_secs(60));
        b.push(req(1, "s"));
        assert!(b.close_expired(Instant::now()).is_empty());
        assert_eq!(b.pending_requests(), 1);
    }

    #[test]
    fn drain_flushes_all_into_one_super_batch() {
        let mut b = Batcher::new(100, 64, Duration::from_secs(60));
        b.push(req(1, "a"));
        b.push(req(2, "b"));
        let all = b.drain();
        assert_eq!(all.len(), 1, "two sub-cap groups pack into one dispatch");
        assert_eq!(all[0].sessions(), 2);
        assert_eq!(all[0].total_requests(), 2);
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn append_closes_pending_queries_in_arrival_order() {
        let mut b = Batcher::new(100, 64, Duration::from_secs(60));
        assert!(b.push(req(1, "s")).is_none());
        assert!(b.push(req(2, "s")).is_none());
        let batch = b.push(append_req(3, "s")).expect("append must close immediately");
        let g = only(&batch);
        assert_eq!(g.session, "s");
        assert_eq!(g.requests.len(), 3);
        assert_eq!(
            g.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![1, 2, 3],
            "queries first, append last"
        );
        assert!(g.requests[2].is_append());
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn append_with_no_pending_ships_alone_and_leaves_others() {
        let mut b = Batcher::new(100, 64, Duration::from_secs(60));
        assert!(b.push(req(1, "other")).is_none());
        let batch = b.push(append_req(2, "s")).expect("lone append closes");
        assert_eq!(batch.total_requests(), 1);
        assert_eq!(only(&batch).session, "s");
        assert_eq!(b.pending_requests(), 1, "other session's pending untouched");
    }

    // Guards the forming stamp: a session under continuous sub-cap
    // traffic must still close `window` after its *first* pending
    // request — later pushes and append traffic on other sessions must
    // not push the deadline out.
    #[test]
    fn window_counts_from_first_pending_request_under_continuous_traffic() {
        let window = Duration::from_millis(200);
        let mut b = Batcher::new(100, 64, window);
        b.push(req(0, "s"));
        let t0 = Instant::now(); // >= the batch's forming stamp
        for i in 1..5u64 {
            // sub-cap traffic keeps arriving; probing before the window
            // must not close, and the new pushes must not reset the clock
            assert!(b.close_expired(t0 + window / 4).is_empty(), "closed early at push {i}");
            b.push(req(i, "s"));
            // append traffic on an unrelated session touches the batcher
            // without disturbing "s"
            let other = b.push(append_req(100 + i, "other"));
            assert!(other.is_some());
        }
        let closed = b.close_expired(t0 + window);
        assert_eq!(closed.len(), 1, "batch must close at window from the first request");
        assert_eq!(closed[0].total_requests(), 5);
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn window_restarts_after_append_barrier_flush() {
        let window = Duration::from_millis(200);
        let mut b = Batcher::new(100, 64, window);
        b.push(req(1, "s"));
        let t0 = Instant::now();
        b.push(append_req(2, "s")).expect("barrier flush");
        // new traffic after the flush starts a fresh window: the old
        // deadline must not apply to it
        b.push(req(3, "s"));
        let t1 = Instant::now();
        assert!(
            b.close_expired(t0 + window / 2).is_empty(),
            "fresh batch must not inherit the flushed batch's deadline"
        );
        let closed = b.close_expired(t1 + window);
        assert_eq!(closed.len(), 1);
        assert_eq!(only(&closed[0]).requests[0].id, 3);
    }

    #[test]
    fn expired_groups_fuse_into_super_batches_oldest_first() {
        // 64 sessions x 1 pending query each (the high-fan-out serving
        // regime): one sweep packs them into ceil(64/max_total)
        // dispatches, ordered by forming deadline
        let mut b = Batcher::new(16, 24, Duration::from_millis(0));
        for s in 0..64u64 {
            assert!(b.push(req(s, &format!("sess-{s}"))).is_none());
            // distinct forming stamps: Instant::now() is monotonic but
            // may tick coarsely; ordering assertions below only need
            // non-decreasing ids per dispatch, which holds either way
        }
        let batches = b.close_expired(Instant::now() + Duration::from_millis(1));
        assert_eq!(batches.len(), 3, "64 singleton groups at cap 24 -> 3 dispatches");
        assert_eq!(batches.iter().map(Batch::total_requests).sum::<usize>(), 64);
        assert_eq!(batches[0].sessions(), 24);
        assert_eq!(batches[1].sessions(), 24);
        assert_eq!(batches[2].sessions(), 16);
        // every session appears exactly once across the dispatches
        let mut seen: Vec<&str> =
            batches.iter().flat_map(|b| b.groups.iter().map(|g| g.session.as_str())).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 64);
        assert_eq!(b.pending_requests(), 0);
    }

    #[test]
    fn super_batch_never_splits_a_group() {
        // groups of 3 at total cap 4: each dispatch carries exactly one
        // group (3 + 3 > 4), never a fragment
        let mut b = Batcher::new(8, 4, Duration::from_millis(0));
        for s in 0..3 {
            for i in 0..3u64 {
                assert!(b.push(req(s * 10 + i, &format!("g{s}"))).is_none());
            }
        }
        let batches = b.close_expired(Instant::now() + Duration::from_millis(1));
        assert_eq!(batches.len(), 3);
        for batch in &batches {
            assert_eq!(batch.sessions(), 1);
            assert_eq!(batch.total_requests(), 3);
        }
    }

    #[test]
    fn remove_matching_drains_a_session_and_leaves_others_forming() {
        let mut b = Batcher::new(100, 64, Duration::from_secs(60));
        b.push(req(1, "doomed"));
        b.push(req(2, "doomed"));
        b.push(req(3, "live"));
        let removed = b.remove_matching(|r| r.session == "doomed");
        assert_eq!(removed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(b.pending_requests(), 1, "unrelated session untouched");
        // the survivor's forming window is intact: a sweep well before
        // its window closes nothing, and its deadline is still exposed
        assert!(b.close_expired(Instant::now()).is_empty());
        assert!(b.next_deadline().is_some());
        // a partially-drained group survives with its remainder
        b.push(req(4, "live"));
        let removed = b.remove_matching(|r| r.id == 3);
        assert_eq!(removed.len(), 1);
        let all = b.drain();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].total_requests(), 1);
        assert_eq!(all[0].groups[0].requests[0].id, 4);
        // nothing pending: the sweep is a cheap no-op
        assert!(b.remove_matching(|_| true).is_empty());
    }

    #[test]
    fn next_deadline_tracks_earliest_group() {
        let window = Duration::from_millis(500);
        let mut b = Batcher::new(100, 64, window);
        assert!(b.next_deadline().is_none(), "idle batcher has no deadline");
        b.push(req(1, "a"));
        let first = b.next_deadline().expect("deadline after first push");
        // a later session must not move the earliest deadline forward
        crate::sync::thread::sleep(Duration::from_millis(5));
        b.push(req(2, "b"));
        let still = b.next_deadline().expect("deadline with two groups");
        assert_eq!(still, first, "earliest deadline must stay the oldest group's");
        // closing the oldest group advances the deadline to the next one
        let closed = b.push(append_req(3, "a")).expect("barrier closes group a");
        assert_eq!(only(&closed).session, "a");
        let next = b.next_deadline().expect("b still pending");
        assert!(next > first, "deadline must advance to session b's window");
        assert!(b.close_expired(Instant::now()).is_empty());
        b.drain();
        assert!(b.next_deadline().is_none());
    }
}

//! Fault-injection harness: a [`ChaosBackend`] wraps any [`Backend`]
//! with a deterministic, seeded fault plan — compute errors, worker
//! panics, artificial latency, and transient faults that succeed when
//! retried — so the soak tests can prove the serving loop degrades
//! gracefully (explicit error responses, no leaked pins, no lost
//! workers while the respawn budget lasts) instead of hoping.
//!
//! Fault decisions are **content-keyed**, not call-sequence-keyed: each
//! plan entry hashes its session length and packed query bits together
//! with the seed, and that hash alone decides panic/fault/transient.
//! The same request therefore draws the same fate no matter how the
//! batcher composed its dispatch or which worker served it — a chaos
//! run is reproducible under scheduling jitter, and a retry of a
//! *permanent* fault deterministically fails again rather than flaking
//! into success.  Transient faults are armed with a countdown
//! ([`ChaosConfig::transient_failures`]); a retry replaying the same
//! content decrements it and succeeds when it reaches zero, modelling a
//! device fault that clears.

use std::collections::HashMap;
use std::time::Duration;

use anyhow::Result;

use super::backend::{Backend, BackendFactory, TransientFault};
use super::kvstore::KvEntry;
use crate::Mat;

/// Knobs of one seeded fault plan.  Rates are probabilities in [0, 1]
/// evaluated per plan entry from the entry's content hash; the bands are
/// disjoint (panic is drawn first, then fault).
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Seed mixed into every content hash: two runs with the same seed
    /// and the same request contents inject identical faults.
    pub seed: u64,
    /// Probability that a plan entry panics the dispatch (a crashed
    /// device thread) — exercises the worker watchdog.
    pub panic_rate: f64,
    /// Probability that a plan entry fails the plan with an error.
    pub fault_rate: f64,
    /// Fraction of faults that are transient ([`TransientFault`], the
    /// serving loop retries them) rather than permanent.
    pub transient_ratio: f64,
    /// How many times a transient fault fails before the same content
    /// succeeds — retries beyond this count recover.
    pub transient_failures: u32,
    /// Fixed artificial latency added to every dispatch.
    pub latency: Duration,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            seed: 0x5EED,
            panic_rate: 0.0,
            fault_rate: 0.0,
            transient_ratio: 0.5,
            transient_failures: 1,
            latency: Duration::ZERO,
        }
    }
}

/// SplitMix64 finalizer: turns an accumulated hash into a well-mixed
/// 64-bit value (same construction as the deterministic RNGs elsewhere
/// in the repo).
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// FNV-1a accumulation step.
fn fnv(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(0x0000_0100_0000_01B3)
}

/// A fault-injecting wrapper around a real backend.
pub struct ChaosBackend {
    inner: Box<dyn Backend>,
    cfg: ChaosConfig,
    /// Countdown of remaining failures per armed transient fault, keyed
    /// by content hash — a retry replays identical content, finds its
    /// key here, and recovers once the countdown hits zero.
    armed: HashMap<u64, u32>,
    /// Faults injected so far (transient and permanent; diagnostics).
    pub injected_faults: u64,
    /// Panics injected so far (counted just before unwinding).
    pub injected_panics: u64,
}

impl ChaosBackend {
    pub fn new(cfg: ChaosConfig, inner: Box<dyn Backend>) -> ChaosBackend {
        ChaosBackend { inner, cfg, armed: HashMap::new(), injected_faults: 0, injected_panics: 0 }
    }

    /// Wrap a backend factory so every (re)spawned worker backend gets
    /// the same seeded fault plan — including watchdog respawns, which
    /// rebuild through the same factory.
    pub fn wrap_factory(cfg: ChaosConfig, inner: BackendFactory) -> BackendFactory {
        Box::new(move || {
            let be = inner()?;
            Ok(Box::new(ChaosBackend::new(cfg.clone(), be)) as Box<dyn Backend>)
        })
    }

    /// Content hash of one plan entry: seed + session length + packed
    /// query bits.  Identical content (a retry) hashes identically.
    fn entry_key(&self, entry: &KvEntry, q: &Mat) -> u64 {
        let mut h = fnv(self.cfg.seed, 0x6368_616F_73); // "chaos"
        h = fnv(h, entry.prepared().n() as u64);
        h = fnv(h, q.rows as u64);
        h = fnv(h, q.cols as u64);
        for &x in &q.data {
            h = fnv(h, u64::from(x.to_bits()));
        }
        splitmix(h)
    }

    /// Map a mixed key to a uniform draw in [0, 1).
    fn unit(key: u64) -> f64 {
        (key >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

impl Backend for ChaosBackend {
    fn head_dim(&self) -> usize {
        self.inner.head_dim()
    }

    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn compute_plan(&mut self, plan: &[(&KvEntry, &Mat)]) -> Result<Vec<Mat>> {
        if !self.cfg.latency.is_zero() {
            crate::sync::thread::sleep(self.cfg.latency);
        }
        for &(entry, q) in plan {
            let key = self.entry_key(entry, q);
            // armed transient fault: count the replay down to recovery
            if let Some(remaining) = self.armed.get_mut(&key) {
                if *remaining > 0 {
                    *remaining -= 1;
                    self.injected_faults += 1;
                    return Err(anyhow::Error::new(TransientFault(format!(
                        "chaos: injected transient fault (key {key:#018x})"
                    ))));
                }
                self.armed.remove(&key);
                continue; // recovered — serve this entry normally
            }
            let u = Self::unit(key);
            if u < self.cfg.panic_rate {
                self.injected_panics += 1;
                panic!("chaos: injected backend panic (key {key:#018x})");
            }
            let f = u - self.cfg.panic_rate;
            if f < self.cfg.fault_rate {
                self.injected_faults += 1;
                if f < self.cfg.fault_rate * self.cfg.transient_ratio {
                    self.armed.insert(key, self.cfg.transient_failures.saturating_sub(1));
                    return Err(anyhow::Error::new(TransientFault(format!(
                        "chaos: injected transient fault (key {key:#018x})"
                    ))));
                }
                anyhow::bail!("chaos: injected permanent fault (key {key:#018x})");
            }
        }
        self.inner.compute_plan(plan)
    }

    fn name(&self) -> String {
        format!("chaos({})", self.inner.name())
    }
}

/// What a chaos-driven client does to its connection mid-stream — the
/// connection-level counterpart of [`ChaosBackend`], used by the
/// streaming soak (`rust/tests/streaming_ingress.rs`) to script client
/// misbehavior deterministically.  Fates are drawn per connection key
/// (same seed + same key = same fate), so a soak failure replays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnFate {
    /// Behave: read every token, take the terminal frame, say goodbye.
    Healthy,
    /// Drop the socket after reading this many tokens — a mid-stream
    /// disconnect the server must answer by cancelling the stream and
    /// freeing its KV.
    DisconnectAfter(u32),
    /// Stop reading just before this token until the server sheds the
    /// connection as a slow consumer (stall budget exceeded).
    StallBefore(u32),
    /// Send a deliberately torn frame (a length prefix promising more
    /// bytes than follow, then close) — exercises the reader's
    /// torn-frame handling.
    TornFrame,
}

/// Seeded plan of connection-level faults.  Rates are probabilities in
/// [0, 1] drawn per connection key; the bands are disjoint and drawn in
/// order (disconnect, then stall, then torn).
#[derive(Debug, Clone)]
pub struct ConnChaos {
    /// Seed mixed into every key hash.
    pub seed: u64,
    /// Probability a connection disconnects mid-stream.
    pub disconnect_rate: f64,
    /// Probability a connection stalls its reads until shed.
    pub stall_rate: f64,
    /// Probability a connection sends a torn frame and drops.
    pub torn_rate: f64,
    /// Upper bound (exclusive, min 1) for the token index drawn into
    /// [`ConnFate::DisconnectAfter`] / [`ConnFate::StallBefore`].
    pub max_step: u32,
}

impl Default for ConnChaos {
    fn default() -> ConnChaos {
        ConnChaos { seed: 0x5EED, disconnect_rate: 0.0, stall_rate: 0.0, torn_rate: 0.0, max_step: 4 }
    }
}

impl ConnChaos {
    /// The fate of the connection identified by `conn_key` (typically
    /// its session name).  Pure: same seed + same key, same fate.
    pub fn fate(&self, conn_key: &str) -> ConnFate {
        let mut h = fnv(self.seed, 0x636F_6E6E); // "conn"
        for b in conn_key.bytes() {
            h = fnv(h, u64::from(b));
        }
        let key = splitmix(h);
        let u = ChaosBackend::unit(key);
        let step = (splitmix(key) % u64::from(self.max_step.max(1))) as u32;
        if u < self.disconnect_rate {
            return ConnFate::DisconnectAfter(step);
        }
        let u = u - self.disconnect_rate;
        if u < self.stall_rate {
            return ConnFate::StallBefore(step);
        }
        if u - self.stall_rate < self.torn_rate {
            return ConnFate::TornFrame;
        }
        ConnFate::Healthy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::coordinator::backend::{prepare_entry, SimBackend};
    use crate::hw::{Accelerator, Arith};
    use crate::proptest::Rng;

    fn sim() -> Box<dyn Backend> {
        let cfg = AcceleratorConfig {
            head_dim: 8,
            seq_len: 32,
            kv_blocks: 4,
            parallel_queries: 1,
            freq_mhz: 500.0,
        };
        Box::new(SimBackend::new(Accelerator::new(Arith::Hfa, cfg)))
    }

    fn entry_and_query(rng: &mut Rng) -> (KvEntry, Mat) {
        let e = prepare_entry(
            Mat::from_vec(32, 8, rng.normal_vec(256)),
            Mat::from_vec(32, 8, rng.normal_vec(256)),
        );
        let q = Mat::from_vec(1, 8, rng.normal_vec(8));
        (e, q)
    }

    #[test]
    fn zero_rates_are_a_transparent_passthrough() {
        let mut chaos = ChaosBackend::new(ChaosConfig::default(), sim());
        let mut plain = sim();
        let mut rng = Rng::new(7);
        let (e, q) = entry_and_query(&mut rng);
        let a = chaos.compute_plan(&[(&e, &q)]).unwrap();
        let b = plain.compute_plan(&[(&e, &q)]).unwrap();
        assert_eq!(a[0].data, b[0].data, "inactive chaos must not perturb outputs");
        assert_eq!(chaos.injected_faults, 0);
        assert!(chaos.name().starts_with("chaos("));
    }

    #[test]
    fn fault_decisions_are_content_keyed_and_reproducible() {
        let cfg = ChaosConfig { seed: 99, fault_rate: 0.5, transient_ratio: 0.0, ..ChaosConfig::default() };
        let mut a = ChaosBackend::new(cfg.clone(), sim());
        let mut b = ChaosBackend::new(cfg.clone(), sim());
        let mut rng = Rng::new(11);
        let cases: Vec<_> = (0..24).map(|_| entry_and_query(&mut rng)).collect();
        let mut faulted = 0;
        for (e, q) in &cases {
            let ra = a.compute_plan(&[(e, q)]).is_err();
            let rb = b.compute_plan(&[(e, q)]).is_err();
            assert_eq!(ra, rb, "same seed + same content must draw the same fate");
            // permanent faults must stay failed on retry, not flake
            assert_eq!(a.compute_plan(&[(e, q)]).is_err(), ra);
            faulted += ra as usize;
        }
        assert!(faulted > 0 && faulted < cases.len(), "rate 0.5 must fault some, not all");
        // a different seed redraws fates
        let mut c =
            ChaosBackend::new(ChaosConfig { seed: 100, ..cfg }, sim());
        let redrawn = cases
            .iter()
            .filter(|(e, q)| c.compute_plan(&[(e, q)]).is_err())
            .count();
        assert_ne!(redrawn, 0);
    }

    #[test]
    fn transient_faults_recover_after_their_countdown() {
        let cfg = ChaosConfig {
            fault_rate: 1.0,
            transient_ratio: 1.0,
            transient_failures: 2,
            ..ChaosConfig::default()
        };
        let mut be = ChaosBackend::new(cfg, sim());
        let mut rng = Rng::new(21);
        let (e, q) = entry_and_query(&mut rng);
        for attempt in 0..2 {
            let err = be.compute_plan(&[(&e, &q)]).expect_err("armed fault must fail");
            assert!(
                err.downcast_ref::<TransientFault>().is_some(),
                "attempt {attempt}: fault must be marked transient: {err}"
            );
        }
        let out = be.compute_plan(&[(&e, &q)]).expect("third attempt recovers");
        assert_eq!(out.len(), 1);
        assert_eq!(be.injected_faults, 2);
    }

    #[test]
    fn permanent_faults_are_not_marked_transient() {
        let cfg =
            ChaosConfig { fault_rate: 1.0, transient_ratio: 0.0, ..ChaosConfig::default() };
        let mut be = ChaosBackend::new(cfg, sim());
        let mut rng = Rng::new(31);
        let (e, q) = entry_and_query(&mut rng);
        let err = be.compute_plan(&[(&e, &q)]).expect_err("rate 1.0 always faults");
        assert!(err.downcast_ref::<TransientFault>().is_none());
        assert!(err.to_string().contains("permanent"));
    }

    #[test]
    fn panic_rate_one_panics_every_dispatch() {
        let cfg = ChaosConfig { panic_rate: 1.0, ..ChaosConfig::default() };
        let mut be = ChaosBackend::new(cfg, sim());
        let mut rng = Rng::new(41);
        let (e, q) = entry_and_query(&mut rng);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = be.compute_plan(&[(&e, &q)]);
        }));
        assert!(caught.is_err(), "panic_rate 1.0 must panic the dispatch");
    }

    #[test]
    fn conn_fates_are_key_deterministic_and_band_disjoint() {
        let plan = ConnChaos {
            seed: 7,
            disconnect_rate: 0.25,
            stall_rate: 0.25,
            torn_rate: 0.25,
            max_step: 6,
        };
        let mut tally = [0usize; 4];
        for i in 0..64 {
            let key = format!("sess-{i}");
            let fate = plan.fate(&key);
            assert_eq!(fate, plan.fate(&key), "same seed + key must redraw the same fate");
            match fate {
                ConnFate::Healthy => tally[0] += 1,
                ConnFate::DisconnectAfter(s) => {
                    assert!(s < 6);
                    tally[1] += 1;
                }
                ConnFate::StallBefore(s) => {
                    assert!(s < 6);
                    tally[2] += 1;
                }
                ConnFate::TornFrame => tally[3] += 1,
            }
        }
        assert!(tally.iter().all(|&n| n > 0), "every band must be drawn at 0.25 each: {tally:?}");
        // a different seed redraws at least one fate
        let reseeded = ConnChaos { seed: 8, ..plan.clone() };
        assert!(
            (0..64).any(|i| reseeded.fate(&format!("sess-{i}")) != plan.fate(&format!("sess-{i}"))),
            "reseeding must change some fates"
        );
        // zero rates are all-healthy
        let calm = ConnChaos::default();
        assert!((0..16).all(|i| calm.fate(&format!("sess-{i}")) == ConnFate::Healthy));
    }

    #[test]
    fn wrapped_factory_builds_fresh_chaos_backends() {
        let accel = AcceleratorConfig {
            head_dim: 8,
            seq_len: 32,
            kv_blocks: 4,
            parallel_queries: 1,
            freq_mhz: 500.0,
        };
        let factory = ChaosBackend::wrap_factory(
            ChaosConfig::default(),
            SimBackend::factory(Arith::Hfa, accel),
        );
        // callable repeatedly — the watchdog respawn path needs `Fn`
        let a = factory().unwrap();
        let b = factory().unwrap();
        assert_eq!(a.name(), b.name());
        assert_eq!(a.head_dim(), 8);
    }
}

//! Request/response types flowing through the coordinator.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::Mat;

/// What a request asks the serving loop to do.
#[derive(Debug)]
pub enum Payload {
    /// Attend over the session's resident KV with this query
    /// (length = head_dim).
    Query(Vec<f32>),
    /// Append decode-step K/V rows to the session before any later
    /// request of the same session is served (the autoregressive
    /// write half of a decode step).
    Append { k_rows: Mat, v_rows: Mat },
}

/// One request against a named KV session.
#[derive(Debug)]
pub struct AttentionRequest {
    pub id: u64,
    /// Session whose KV buffers to attend over / append to.
    pub session: String,
    pub payload: Payload,
    pub arrived: Instant,
    /// Whether ingress took a [`crate::coordinator::KvStore::pin`] on the
    /// session for this request (it was resident at submit time).  The
    /// pin keeps the session from being evicted while the request is
    /// queued; whoever delivers the response releases it.
    pub pinned: bool,
    /// Completion channel.
    pub reply: Sender<AttentionResponse>,
}

impl AttentionRequest {
    pub fn is_append(&self) -> bool {
        matches!(self.payload, Payload::Append { .. })
    }
}

/// The served result.
#[derive(Debug, Clone)]
pub struct AttentionResponse {
    pub id: u64,
    /// Attention output vector, or an error message.  Append
    /// acknowledgements carry an empty vector.
    pub output: Result<Vec<f32>, String>,
    /// Wall time from ingress to completion.
    pub latency_us: f64,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

impl AttentionResponse {
    pub fn ok(&self) -> bool {
        self.output.is_ok()
    }
}

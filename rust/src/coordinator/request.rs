//! Request/response types flowing through the coordinator.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// One attention query against a named KV session.
#[derive(Debug)]
pub struct AttentionRequest {
    pub id: u64,
    /// Session whose KV buffers to attend over.
    pub session: String,
    /// The query vector (length = head_dim).
    pub query: Vec<f32>,
    pub arrived: Instant,
    /// Completion channel.
    pub reply: Sender<AttentionResponse>,
}

/// The served result.
#[derive(Debug, Clone)]
pub struct AttentionResponse {
    pub id: u64,
    /// Attention output vector, or an error message.
    pub output: Result<Vec<f32>, String>,
    /// Wall time from ingress to completion.
    pub latency_us: f64,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

impl AttentionResponse {
    pub fn ok(&self) -> bool {
        self.output.is_ok()
    }
}

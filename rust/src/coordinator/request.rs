//! Request/response types flowing through the coordinator.

use std::fmt;
use crate::sync::atomic::AtomicBool;
use crate::sync::mpsc::Sender;
use crate::sync::Arc;
use std::time::Instant;

use crate::Mat;

/// Why the serving loop could not (or chose not to) answer a request.
///
/// Carried in [`AttentionResponse::output`] so clients and tests match on
/// variants instead of error-message substrings; [`fmt::Display`] keeps
/// the human-readable detail.  Submit-path rejections (`Overloaded`,
/// `Shutdown`) are returned as an [`anyhow::Error`] wrapping the same
/// variant — downcast with `err.downcast_ref::<ServeError>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request's deadline expired before it was served; the batcher
    /// sheds expired requests at group close and workers re-check before
    /// dispatch, so no compute is spent on an answer nobody awaits.
    TimedOut,
    /// Admission control rejected the request at submit: the in-flight
    /// cap (`max_pending_requests`) was reached or the bounded ingress
    /// queue was full (backpressure).
    Overloaded,
    /// The session was cancelled ([`crate::coordinator::Server::cancel`])
    /// while this request was queued.
    Cancelled,
    /// The backend failed to compute the dispatch (plan error, shape
    /// disagreement, or a panic).  `transient` marks faults the backend
    /// classified as retryable; the serving loop retries those with
    /// backoff before giving up, so a delivered transient error means
    /// the retry budget was exhausted too.
    BackendFailed { reason: String, transient: bool },
    /// Serving stopped before the request could run (shutdown, drain
    /// deadline expiry, or every worker gone).
    Shutdown(String),
    /// The KV store refused the operation: unknown session, geometry
    /// mismatch, or byte-budget admission failure.
    KvAdmission(String),
}

impl ServeError {
    /// A permanent (non-transient) backend failure.
    pub fn backend(reason: impl Into<String>) -> ServeError {
        ServeError::BackendFailed { reason: reason.into(), transient: false }
    }

    /// Whether a retry might have succeeded (transient backend faults).
    pub fn is_transient(&self) -> bool {
        matches!(self, ServeError::BackendFailed { transient: true, .. })
    }

    /// Stable wire code for the streaming ingress's `Error` frames —
    /// a 1:1 mapping over the variants (code `0` is reserved for
    /// protocol-level rejections that never were a `ServeError`, e.g. a
    /// malformed or shape-invalid request refused at the door).
    pub fn wire_code(&self) -> u8 {
        match self {
            ServeError::TimedOut => 1,
            ServeError::Overloaded => 2,
            ServeError::Cancelled => 3,
            ServeError::BackendFailed { .. } => 4,
            ServeError::Shutdown(_) => 5,
            ServeError::KvAdmission(_) => 6,
        }
    }

    /// Inverse of [`ServeError::wire_code`] for the client-side decoder.
    /// `detail` repopulates the variants that carry a reason; stateless
    /// variants ignore it.  Unknown codes (0 included) have no variant —
    /// `None` tells the client to surface the raw frame instead.
    pub fn from_wire(code: u8, transient: bool, detail: &str) -> Option<ServeError> {
        match code {
            1 => Some(ServeError::TimedOut),
            2 => Some(ServeError::Overloaded),
            3 => Some(ServeError::Cancelled),
            4 => Some(ServeError::BackendFailed { reason: detail.to_string(), transient }),
            5 => Some(ServeError::Shutdown(detail.to_string())),
            6 => Some(ServeError::KvAdmission(detail.to_string())),
            _ => None,
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::TimedOut => write!(f, "request deadline expired before serving"),
            ServeError::Overloaded => {
                write!(f, "admission control rejected the request (server overloaded)")
            }
            ServeError::Cancelled => write!(f, "session cancelled while the request was queued"),
            ServeError::BackendFailed { reason, transient: false } => {
                write!(f, "backend failed: {reason}")
            }
            ServeError::BackendFailed { reason, transient: true } => {
                write!(f, "backend failed (transient, retries exhausted): {reason}")
            }
            ServeError::Shutdown(reason) => write!(f, "{reason}"),
            ServeError::KvAdmission(reason) => write!(f, "{reason}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a request asks the serving loop to do.
#[derive(Debug)]
pub enum Payload {
    /// Attend over the session's resident KV with this query
    /// (length = head_dim).
    Query(Vec<f32>),
    /// Append decode-step K/V rows to the session before any later
    /// request of the same session is served (the autoregressive
    /// write half of a decode step).
    Append { k_rows: Mat, v_rows: Mat },
}

/// One request against a named KV session.
#[derive(Debug)]
pub struct AttentionRequest {
    pub id: u64,
    /// Session whose KV buffers to attend over / append to.
    pub session: String,
    pub payload: Payload,
    pub arrived: Instant,
    /// Absolute deadline: past it the request is shed with
    /// [`ServeError::TimedOut`] instead of served.  Defaults to
    /// `arrived + CoordinatorConfig::request_timeout_us`.
    pub deadline: Instant,
    /// Whether ingress took a [`crate::coordinator::KvStore::pin`] on the
    /// session for this request (it was resident at submit time).  The
    /// pin keeps the session from being evicted while the request is
    /// queued; whoever delivers the response releases it.
    pub pinned: bool,
    /// Per-request cancellation flag, shared with the caller's
    /// [`crate::coordinator::server::ResponseHandle`]: dropping the
    /// handle before a terminal response sets it, and every shed point
    /// checks it so abandoned requests are failed fast instead of
    /// computed into a dead channel.
    pub cancelled: Arc<AtomicBool>,
    /// Completion channel.
    pub reply: Sender<AttentionResponse>,
}

impl AttentionRequest {
    pub fn is_append(&self) -> bool {
        matches!(self.payload, Payload::Append { .. })
    }

    /// Whether the deadline has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        now >= self.deadline
    }

    /// Token charge against the scheduler's batch budgets
    /// (`max_batch_prefill_tokens` / `max_batch_total_tokens`): an
    /// append makes `k_rows.rows` new tokens resident, a query attends
    /// for one output token.
    pub fn token_cost(&self) -> usize {
        match &self.payload {
            Payload::Query(_) => 1,
            Payload::Append { k_rows, .. } => k_rows.rows.max(1),
        }
    }
}

/// The served result.
#[derive(Debug, Clone)]
pub struct AttentionResponse {
    pub id: u64,
    /// Attention output vector, or the typed serving error.  Append
    /// acknowledgements carry an empty vector.
    pub output: Result<Vec<f32>, ServeError>,
    /// Wall time from ingress to completion.
    pub latency_us: f64,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

impl AttentionResponse {
    pub fn ok(&self) -> bool {
        self.output.is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_error_display_carries_detail() {
        let e = ServeError::BackendFailed { reason: "device lost".into(), transient: true };
        assert!(e.to_string().contains("device lost"));
        assert!(e.to_string().contains("transient"));
        assert!(e.is_transient());
        assert!(!ServeError::backend("boom").is_transient());
        assert!(ServeError::KvAdmission("unknown session \"x\"".into())
            .to_string()
            .contains("unknown session"));
    }

    #[test]
    fn wire_codes_roundtrip_every_variant() {
        let variants = [
            ServeError::TimedOut,
            ServeError::Overloaded,
            ServeError::Cancelled,
            ServeError::BackendFailed { reason: "device lost".into(), transient: true },
            ServeError::backend("boom"),
            ServeError::Shutdown("server draining".into()),
            ServeError::KvAdmission("unknown session".into()),
        ];
        for e in &variants {
            let code = e.wire_code();
            assert!(code >= 1, "0 is reserved for protocol-level rejection");
            let detail = match e {
                ServeError::BackendFailed { reason, .. } => reason.clone(),
                ServeError::Shutdown(r) | ServeError::KvAdmission(r) => r.clone(),
                _ => String::new(),
            };
            let back = ServeError::from_wire(code, e.is_transient(), &detail)
                .unwrap_or_else(|| panic!("code {code} must decode"));
            assert_eq!(&back, e, "wire code {code} must roundtrip");
        }
        // distinct variants map to distinct codes (1:1)
        let mut codes: Vec<u8> = variants.iter().map(ServeError::wire_code).collect();
        codes.dedup(); // the two BackendFailed entries share one code
        codes.sort_unstable();
        codes.dedup();
        assert_eq!(codes.len(), 6);
        assert_eq!(ServeError::from_wire(0, false, "bad shape"), None);
        assert_eq!(ServeError::from_wire(200, false, ""), None);
    }

    #[test]
    fn serve_error_downcasts_from_anyhow() {
        let err = anyhow::Error::new(ServeError::Overloaded);
        assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::Overloaded));
    }

    #[test]
    fn token_cost_charges_append_rows_and_one_per_query() {
        let mk = |payload| {
            let (tx, _rx) = crate::sync::mpsc::channel();
            let now = Instant::now();
            AttentionRequest {
                id: 0,
                session: "s".into(),
                payload,
                arrived: now,
                deadline: now,
                pinned: false,
                cancelled: Arc::new(AtomicBool::new(false)),
                reply: tx,
            }
        };
        assert_eq!(mk(Payload::Query(vec![0.0; 4])).token_cost(), 1);
        let app = Payload::Append { k_rows: Mat::zeros(3, 4), v_rows: Mat::zeros(3, 4) };
        assert_eq!(mk(app).token_cost(), 3);
    }
}

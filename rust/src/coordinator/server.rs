//! The serving loop: bounded ingress -> batcher thread -> worker threads
//! owning backends -> per-request reply channels.
//!
//! Requests are either attention queries or decode-step KV appends
//! ([`Payload`]); an append acts as a per-session barrier in the batcher,
//! so a batch is served in arrival order — queries first (against the
//! pre-append KV), then the append.  Clients interleave
//! `append`/`call` to run an autoregressive decode loop whose KV
//! conversion cost tracks the new tokens only.
//!
//! Ingress **pins** the request's session in the KV store
//! (`KvStore::pin`), and the pin is released when the response is
//! delivered — so a session with queries queued in the batcher can no
//! longer be LRU-evicted out from under them into spurious "unknown
//! session" failures.  KV admission-control failures (byte budget
//! exceeded, capacity overflow) surface as error responses on the
//! submitting channel.
//!
//! `start` fails fast: if any backend factory errors on its worker
//! thread, the failure is propagated out instead of silently serving
//! with fewer (possibly zero) workers.
//!
//! Shutdown is cooperative: dropping the `Server` closes the ingress,
//! drains in-flight batches and joins all threads.  Requests that can no
//! longer be served — queued behind the shutdown message, or formed into
//! a batch when every worker is gone — receive an **explicit error
//! response** instead of a silently dropped reply channel.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::backend::{Backend, BackendFactory};
use super::batcher::{Batch, Batcher};
use super::kvstore::KvStore;
use super::metrics::Metrics;
use super::request::{AttentionRequest, AttentionResponse, Payload};
use crate::config::CoordinatorConfig;
use crate::Mat;

enum Msg {
    Req(AttentionRequest),
    Shutdown,
}

/// A running coordinator instance.
pub struct Server {
    ingress: SyncSender<Msg>,
    threads: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    pub kv: Arc<KvStore>,
    head_dim: usize,
    /// The batcher hands the ingress receiver back here on exit, so
    /// shutdown can drain requests that raced into the queue after the
    /// batcher's final sweep (see [`Server::shutdown`]).
    ingress_rx: Arc<Mutex<Option<Receiver<Msg>>>>,
}

impl Server {
    /// Start the coordinator with one worker thread per backend factory
    /// (each backend is constructed on its own worker thread — PJRT
    /// executables are thread-local).  Returns an error if **any**
    /// factory fails, after tearing the partially-started instance back
    /// down: a server that silently came up with fewer workers than
    /// configured (or none, hanging every request) was a debugging trap.
    pub fn start(
        cfg: &CoordinatorConfig,
        kv: Arc<KvStore>,
        factories: Vec<BackendFactory>,
    ) -> Result<Server> {
        anyhow::ensure!(!factories.is_empty(), "need at least one backend");
        let head_dim = kv.head_dim();
        let metrics = Arc::new(Metrics::new());
        let (in_tx, in_rx) = sync_channel::<Msg>(cfg.queue_depth);
        let (batch_tx, batch_rx) = sync_channel::<Batch>(cfg.queue_depth);
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        // batcher thread
        let window = Duration::from_micros(cfg.batch_window_us);
        let max_batch = cfg.max_batch;
        let m = metrics.clone();
        let kv_batcher = kv.clone();
        let ingress_rx: Arc<Mutex<Option<Receiver<Msg>>>> = Arc::new(Mutex::new(None));
        let rx_back = ingress_rx.clone();
        let batcher_handle = std::thread::Builder::new()
            .name("hfa-batcher".into())
            .spawn(move || batcher_loop(in_rx, batch_tx, max_batch, window, m, kv_batcher, rx_back))?;

        // worker threads; each reports its backend-init outcome before
        // entering the serve loop
        let worker_count = factories.len();
        let (init_tx, init_rx) = channel::<std::result::Result<(), String>>();
        let mut threads = vec![batcher_handle];
        for (i, factory) in factories.into_iter().enumerate() {
            let rx = batch_rx.clone();
            let kv = kv.clone();
            let m = metrics.clone();
            let init_tx = init_tx.clone();
            let h = std::thread::Builder::new()
                .name(format!("hfa-worker-{i}"))
                .spawn(move || match factory() {
                    Ok(mut be) => {
                        let _ = init_tx.send(Ok(()));
                        // release the handshake sender before serving, so
                        // start()'s recv() can observe a disconnect (not
                        // hang) if some *other* worker dies without
                        // reporting (e.g. a panicking factory)
                        drop(init_tx);
                        worker_loop(&mut *be, rx, kv, m)
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(format!("hfa-worker-{i}: {e}")));
                    }
                })?;
            threads.push(h);
        }
        drop(init_tx);

        let mut failures = Vec::new();
        for _ in 0..worker_count {
            match init_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => failures.push(e),
                Err(_) => failures.push("worker exited before reporting init".into()),
            }
        }
        if !failures.is_empty() {
            // tear down: stop the batcher (its exit drops batch_tx, which
            // disconnects any workers that did come up), then join all
            let _ = in_tx.send(Msg::Shutdown);
            for h in threads {
                let _ = h.join();
            }
            anyhow::bail!("backend init failed: {}", failures.join("; "));
        }

        Ok(Server {
            ingress: in_tx,
            threads,
            next_id: AtomicU64::new(1),
            metrics,
            kv,
            head_dim,
            ingress_rx,
        })
    }

    /// Submit one query; returns the reply receiver, or an error when the
    /// ingress queue is full (backpressure).
    pub fn submit(
        &self,
        session: &str,
        query: Vec<f32>,
    ) -> Result<std::sync::mpsc::Receiver<AttentionResponse>> {
        anyhow::ensure!(
            query.len() == self.head_dim,
            "query dim {} != head dim {}",
            query.len(),
            self.head_dim
        );
        self.enqueue(session, Payload::Query(query))
    }

    /// Submit a decode-step KV append; the acknowledgement (empty output
    /// vector) arrives once the rows are resident.  Within the batch the
    /// barrier closes, pending queries are served against the pre-append
    /// KV; queries submitted after the acknowledgement see the grown KV.
    /// Across *separate* batches no inter-worker ordering is imposed —
    /// a decode client serializes by waiting for each response before
    /// the next submit (see the module docs' decode protocol).
    pub fn submit_append(
        &self,
        session: &str,
        k_rows: Mat,
        v_rows: Mat,
    ) -> Result<std::sync::mpsc::Receiver<AttentionResponse>> {
        anyhow::ensure!(
            k_rows.cols == self.head_dim && v_rows.cols == self.head_dim,
            "append dims {}x{} / {}x{} != head dim {}",
            k_rows.rows,
            k_rows.cols,
            v_rows.rows,
            v_rows.cols,
            self.head_dim
        );
        anyhow::ensure!(
            k_rows.rows == v_rows.rows && k_rows.rows > 0,
            "K/V append row counts must match and be non-zero"
        );
        self.enqueue(session, Payload::Append { k_rows, v_rows })
    }

    fn enqueue(
        &self,
        session: &str,
        payload: Payload,
    ) -> Result<std::sync::mpsc::Receiver<AttentionResponse>> {
        let (tx, rx) = channel();
        // pin the session so the LRU cannot evict it while this request
        // sits in the batcher (released at delivery); a not-yet-resident
        // session takes no pin and fails at serve time as before
        let pinned = self.kv.pin(session);
        let req = AttentionRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            session: session.to_string(),
            payload,
            arrived: Instant::now(),
            pinned,
            reply: tx,
        };
        match self.ingress.try_send(Msg::Req(req)) {
            Ok(()) => {
                self.metrics.accepted.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                if pinned {
                    self.kv.unpin(session);
                }
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("ingress queue full (backpressure)")
            }
            Err(TrySendError::Disconnected(_)) => {
                if pinned {
                    self.kv.unpin(session);
                }
                anyhow::bail!("server stopped")
            }
        }
    }

    /// Submit and wait.
    pub fn call(&self, session: &str, query: Vec<f32>) -> Result<AttentionResponse> {
        let rx = self.submit(session, query)?;
        Ok(rx.recv()?)
    }

    /// Submit a KV append and wait for the acknowledgement.
    pub fn append(&self, session: &str, k_rows: Mat, v_rows: Mat) -> Result<AttentionResponse> {
        let rx = self.submit_append(session, k_rows, v_rows)?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        let _ = self.ingress.send(Msg::Shutdown);
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
        // authoritative residue drain: after the join no submit can race
        // (shutdown/drop hold the Server exclusively and the threads are
        // gone), so any request still sitting in the ingress queue gets
        // an explicit error — and its session pin released — instead of
        // a silently dropped reply channel
        let rx = self.ingress_rx.lock().unwrap().take();
        if let Some(rx) = rx {
            loop {
                match rx.try_recv() {
                    Ok(Msg::Req(req)) => {
                        fail_request(req, SHUTDOWN_ERROR, &self.kv, &self.metrics)
                    }
                    Ok(Msg::Shutdown) => {}
                    Err(_) => break,
                }
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if !self.threads.is_empty() {
            self.shutdown_inner();
        }
    }
}

/// Error delivered to requests the serving loop can no longer execute.
const SHUTDOWN_ERROR: &str = "server shutting down: request dropped before serving";
const WORKERS_GONE_ERROR: &str = "no workers available (server shutting down?)";

fn batcher_loop(
    in_rx: Receiver<Msg>,
    batch_tx: SyncSender<Batch>,
    max_batch: usize,
    window: Duration,
    metrics: Arc<Metrics>,
    kv: Arc<KvStore>,
    rx_back: Arc<Mutex<Option<Receiver<Msg>>>>,
) {
    let mut batcher = Batcher::new(max_batch, window);
    let tick = window.max(Duration::from_micros(50));
    loop {
        match in_rx.recv_timeout(tick) {
            Ok(Msg::Req(req)) => {
                if let Some(b) = batcher.push(req) {
                    emit(&batch_tx, b, &metrics, &kv);
                }
            }
            Ok(Msg::Shutdown) => {
                // requests that raced into the queue behind the shutdown
                // message would otherwise be dropped with a dead reply
                // channel — deliver an explicit error instead
                loop {
                    match in_rx.try_recv() {
                        Ok(Msg::Req(req)) => fail_request(req, SHUTDOWN_ERROR, &kv, &metrics),
                        Ok(Msg::Shutdown) => {}
                        Err(_) => break,
                    }
                }
                break;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        for b in batcher.close_expired(Instant::now()) {
            emit(&batch_tx, b, &metrics, &kv);
        }
    }
    for b in batcher.drain() {
        emit(&batch_tx, b, &metrics, &kv);
    }
    // hand the ingress receiver back to the Server: a submit can race
    // its request into the queue between our final sweep above and this
    // thread's exit, and shutdown drains those authoritatively after
    // joining us (the window where a message is truly unreachable is
    // thereby closed)
    *rx_back.lock().unwrap() = Some(in_rx);
    // dropping batch_tx disconnects the workers
}

fn emit(tx: &SyncSender<Batch>, b: Batch, metrics: &Metrics, kv: &KvStore) {
    let n = b.requests.len() as u64;
    match tx.send(b) {
        Ok(()) => {
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            metrics.batched_requests.fetch_add(n, Ordering::Relaxed);
        }
        // every worker is gone (all exited/panicked): the batch would
        // hang its callers forever — deliver explicit errors instead
        Err(std::sync::mpsc::SendError(b)) => {
            for req in b.requests {
                fail_request(req, WORKERS_GONE_ERROR, kv, metrics);
            }
        }
    }
}

/// Deliver an explicit error response for a request that will never be
/// served, releasing its session pin.
fn fail_request(req: AttentionRequest, msg: &str, kv: &KvStore, metrics: &Metrics) {
    let AttentionRequest { id, session, arrived, pinned, reply, .. } = req;
    if pinned {
        kv.unpin(&session);
    }
    metrics.failed.fetch_add(1, Ordering::Relaxed);
    let latency_us = arrived.elapsed().as_secs_f64() * 1e6;
    let _ = reply.send(AttentionResponse {
        id,
        output: Err(msg.to_string()),
        latency_us,
        batch_size: 0,
    });
}

fn worker_loop(
    be: &mut dyn Backend,
    rx: Arc<Mutex<Receiver<Batch>>>,
    kv: Arc<KvStore>,
    metrics: Arc<Metrics>,
) {
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(b) => b,
                Err(_) => break, // batcher gone
            }
        };
        serve_batch(be, batch, &kv, &metrics);
    }
}

/// A query waiting to be flushed: `(id, query, arrived, pinned, reply)`.
type PendingQuery = (u64, Vec<f32>, Instant, bool, Sender<AttentionResponse>);

/// Releases a batch's not-yet-released session pins on drop, so a panic
/// anywhere in the serve path (e.g. a crashing backend) cannot leak
/// pins — a leaked pin would make the session permanently unevictable
/// under the byte budget.  The happy path releases each pin explicitly
/// ([`PinGuard::release_one`]) *before* the response is sent, so by the
/// time a caller observes its response the session is evictable again.
struct PinGuard<'a> {
    kv: &'a KvStore,
    session: &'a str,
    remaining: usize,
}

impl PinGuard<'_> {
    fn release_one(&mut self) {
        if self.remaining > 0 {
            self.remaining -= 1;
            self.kv.unpin(self.session);
        }
    }
}

impl Drop for PinGuard<'_> {
    fn drop(&mut self) {
        for _ in 0..self.remaining {
            self.kv.unpin(self.session);
        }
    }
}

/// Serve one batch in arrival order: contiguous runs of queries are
/// computed together against the session's current KV; an append flushes
/// the run ahead of it, then applies the write.  Configuration errors
/// (backend/store geometry disagreements) become error responses, never
/// worker panics.  Every response releases its ingress pin (before the
/// reply is sent; panic-safe via [`PinGuard`]).
fn serve_batch(be: &mut dyn Backend, batch: Batch, kv: &KvStore, metrics: &Metrics) {
    let n = batch.requests.len();
    let mut pins = PinGuard {
        kv,
        session: &batch.session,
        remaining: batch.requests.iter().filter(|r| r.pinned).count(),
    };
    if be.head_dim() != kv.head_dim() {
        let msg = format!(
            "backend head_dim {} != KV store head_dim {}",
            be.head_dim(),
            kv.head_dim()
        );
        for req in batch.requests {
            let AttentionRequest { id, arrived, pinned, reply, .. } = req;
            if pinned {
                pins.release_one();
            }
            deliver(id, arrived, reply, Err(msg.clone()), n, metrics);
        }
        return;
    }
    let mut run: Vec<PendingQuery> = Vec::new();
    for req in batch.requests {
        let AttentionRequest { id, payload, arrived, pinned, reply, .. } = req;
        match payload {
            Payload::Query(q) => run.push((id, q, arrived, pinned, reply)),
            Payload::Append { k_rows, v_rows } => {
                flush_queries(be, &batch.session, std::mem::take(&mut run), kv, &mut pins, metrics, n);
                let output = kv
                    .append(&batch.session, k_rows, v_rows)
                    .map(|()| Vec::new())
                    .map_err(|e| e.to_string());
                if pinned {
                    pins.release_one();
                }
                deliver_append(id, arrived, reply, output, n, metrics);
            }
        }
    }
    flush_queries(be, &batch.session, run, kv, &mut pins, metrics, n);
}

fn flush_queries(
    be: &mut dyn Backend,
    session: &str,
    run: Vec<PendingQuery>,
    kv: &KvStore,
    pins: &mut PinGuard<'_>,
    metrics: &Metrics,
    batch_size: usize,
) {
    if run.is_empty() {
        return;
    }
    let d = be.head_dim();
    let result: std::result::Result<Mat, String> = if let Some(entry) = kv.get(session) {
        if run.iter().any(|(_, q, _, _, _)| q.len() != d) {
            Err(format!("query dim mismatch (expected {d})"))
        } else {
            let mut q = Mat::zeros(run.len(), d);
            for (i, (_, qv, _, _, _)) in run.iter().enumerate() {
                q.row_mut(i).copy_from_slice(qv);
            }
            be.compute(&entry, &q).map_err(|e| e.to_string())
        }
    } else {
        Err(format!("unknown session {session:?}"))
    };
    for (i, (id, _, arrived, pinned, reply)) in run.into_iter().enumerate() {
        let output = match &result {
            Ok(mat) => Ok(mat.row(i).to_vec()),
            Err(e) => Err(e.clone()),
        };
        if pinned {
            pins.release_one();
        }
        deliver(id, arrived, reply, output, batch_size, metrics);
    }
}

fn deliver(
    id: u64,
    arrived: Instant,
    reply: Sender<AttentionResponse>,
    output: std::result::Result<Vec<f32>, String>,
    batch_size: usize,
    metrics: &Metrics,
) {
    let latency_us = arrived.elapsed().as_secs_f64() * 1e6;
    if output.is_ok() {
        metrics.completed.fetch_add(1, Ordering::Relaxed);
    } else {
        metrics.failed.fetch_add(1, Ordering::Relaxed);
    }
    metrics.observe_latency(latency_us);
    let _ = reply.send(AttentionResponse { id, output, latency_us, batch_size });
}

/// Acknowledge a KV append.  Counted under `Metrics::appends`, not
/// `completed`, and excluded from the latency reservoir: the percentiles
/// measure attention serving, and near-zero-compute write acks would
/// dilute them (a decode loop would otherwise also double-count its
/// completion rate).
fn deliver_append(
    id: u64,
    arrived: Instant,
    reply: Sender<AttentionResponse>,
    output: std::result::Result<Vec<f32>, String>,
    batch_size: usize,
    metrics: &Metrics,
) {
    let latency_us = arrived.elapsed().as_secs_f64() * 1e6;
    if output.is_ok() {
        metrics.appends.fetch_add(1, Ordering::Relaxed);
    } else {
        metrics.failed.fetch_add(1, Ordering::Relaxed);
    }
    let _ = reply.send(AttentionResponse { id, output, latency_us, batch_size });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::coordinator::backend::SimBackend;
    use crate::hw::Arith;
    use crate::proptest::Rng;

    fn accel_cfg(head_dim: usize) -> AcceleratorConfig {
        AcceleratorConfig {
            head_dim,
            seq_len: 32,
            kv_blocks: 4,
            parallel_queries: 1,
            freq_mhz: 500.0,
        }
    }

    fn test_server(workers: usize) -> (Server, Mat, Mat) {
        let coord_cfg = CoordinatorConfig {
            max_batch: 4,
            batch_window_us: 200,
            workers,
            queue_depth: 64,
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(1);
        let k = Mat::from_vec(32, 8, rng.normal_vec(256));
        let v = Mat::from_vec(32, 8, rng.normal_vec(256));
        kv.put("sess", k.clone(), v.clone()).unwrap();
        let factories: Vec<_> = (0..workers)
            .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg(8)))
            .collect();
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        (srv, k.round_bf16(), v.round_bf16())
    }

    #[test]
    fn serves_single_request_correctly() {
        let (srv, k, v) = test_server(1);
        let mut rng = Rng::new(2);
        let qv = rng.normal_vec(8);
        let resp = srv.call("sess", qv.clone()).unwrap();
        assert!(resp.ok(), "{:?}", resp.output);
        // must equal the golden model directly (the accelerator rounds
        // incoming queries to BF16, so the golden call gets rounded q)
        let q = Mat::from_vec(1, 8, qv).round_bf16();
        let golden =
            crate::attention::hfa::attention_blocked(&q, &k, &v, 4, None, &mut None);
        assert_eq!(resp.output.unwrap(), golden.row(0).to_vec());
        srv.shutdown();
    }

    #[test]
    fn unknown_session_fails_cleanly() {
        let (srv, _, _) = test_server(1);
        let resp = srv.call("nope", vec![0.0; 8]).unwrap();
        assert!(!resp.ok());
        assert_eq!(srv.metrics.snapshot().failed, 1);
        srv.shutdown();
    }

    #[test]
    fn wrong_dim_rejected_at_submit() {
        let (srv, _, _) = test_server(1);
        assert!(srv.submit("sess", vec![0.0; 5]).is_err());
        assert!(srv.submit_append("sess", Mat::zeros(1, 5), Mat::zeros(1, 5)).is_err());
        assert!(srv.submit_append("sess", Mat::zeros(0, 8), Mat::zeros(0, 8)).is_err());
        assert!(srv.submit_append("sess", Mat::zeros(2, 8), Mat::zeros(1, 8)).is_err());
        srv.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let (srv, _, _) = test_server(2);
        let mut rng = Rng::new(3);
        let rxs: Vec<_> =
            (0..32).map(|_| srv.submit("sess", rng.normal_vec(8)).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.ok());
        }
        let snap = srv.metrics.snapshot();
        assert_eq!(snap.completed, 32);
        assert!(snap.mean_batch > 1.0, "batching never kicked in: {snap:?}");
        srv.shutdown();
    }

    #[test]
    fn responses_match_request_order_independence() {
        // interleave two sessions; every response must use its session's KV
        let (srv, k, v) = test_server(2);
        let mut rng = Rng::new(5);
        let k2 = Mat::from_vec(32, 8, rng.normal_vec(256));
        let v2 = Mat::from_vec(32, 8, rng.normal_vec(256));
        srv.kv.put("sess2", k2.clone(), v2.clone()).unwrap();
        let q1 = rng.normal_vec(8);
        let q2 = rng.normal_vec(8);
        let r1 = srv.call("sess", q1.clone()).unwrap().output.unwrap();
        let r2 = srv.call("sess2", q2.clone()).unwrap().output.unwrap();
        let g1 = crate::attention::hfa::attention_blocked(
            &Mat::from_vec(1, 8, q1).round_bf16(), &k, &v, 4, None, &mut None);
        let g2 = crate::attention::hfa::attention_blocked(
            &Mat::from_vec(1, 8, q2).round_bf16(), &k2.round_bf16(), &v2.round_bf16(), 4,
            None, &mut None);
        assert_eq!(r1, g1.row(0).to_vec());
        assert_eq!(r2, g2.row(0).to_vec());
        srv.shutdown();
    }

    #[test]
    fn start_fails_when_any_backend_init_fails() {
        let coord_cfg = CoordinatorConfig {
            max_batch: 4,
            batch_window_us: 100,
            workers: 2,
            queue_depth: 16,
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        // all factories failing
        let factories: Vec<BackendFactory> =
            (0..2).map(|_| Box::new(|| anyhow::bail!("no device")) as BackendFactory).collect();
        let err = Server::start(&coord_cfg, kv.clone(), factories)
            .err()
            .expect("start must propagate backend init failure");
        assert!(err.to_string().contains("backend init failed"), "{err}");
        // one good + one bad is still a failed start (no silent degraded mode)
        let factories: Vec<BackendFactory> = vec![
            SimBackend::factory(Arith::Hfa, accel_cfg(8)),
            Box::new(|| anyhow::bail!("no device")),
        ];
        assert!(Server::start(&coord_cfg, kv, factories).is_err());
    }

    #[test]
    fn head_dim_mismatch_fails_requests_without_killing_worker() {
        // store says d=8, backend says d=16: every request must get an
        // error response (the seed panicked the worker, hanging clients)
        let coord_cfg = CoordinatorConfig {
            max_batch: 4,
            batch_window_us: 100,
            workers: 1,
            queue_depth: 16,
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(7);
        kv.put("sess", Mat::from_vec(32, 8, rng.normal_vec(256)),
               Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
        let factories = vec![SimBackend::factory(Arith::Hfa, accel_cfg(16))];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        for _ in 0..2 {
            // two rounds: the worker must survive the first mismatch
            let resp = srv.call("sess", rng.normal_vec(8)).unwrap();
            assert!(!resp.ok());
            assert!(resp.output.unwrap_err().contains("head_dim"));
        }
        srv.shutdown();
    }

    /// Backend whose first compute panics its worker — models a crashed
    /// device thread.
    struct PanicBackend;

    impl crate::coordinator::backend::Backend for PanicBackend {
        fn head_dim(&self) -> usize {
            8
        }
        fn seq_len(&self) -> usize {
            32
        }
        fn max_batch(&self) -> usize {
            4
        }
        fn compute(
            &mut self,
            _kv: &crate::coordinator::kvstore::KvEntry,
            _q: &Mat,
        ) -> Result<Mat> {
            panic!("injected backend crash")
        }
        fn name(&self) -> String {
            "panic".into()
        }
    }

    #[test]
    fn dead_workers_yield_explicit_errors_not_hangs() {
        // regression: once every worker is gone, formed batches used to
        // be dropped on the floor — callers blocked on a reply channel
        // that would only error when the whole server was torn down
        let coord_cfg = CoordinatorConfig {
            max_batch: 1,
            batch_window_us: 100,
            workers: 1,
            queue_depth: 16,
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(13);
        kv.put(
            "sess",
            Mat::from_vec(32, 8, rng.normal_vec(256)),
            Mat::from_vec(32, 8, rng.normal_vec(256)),
        )
        .unwrap();
        let factories: Vec<BackendFactory> =
            vec![Box::new(|| Ok(Box::new(PanicBackend) as Box<dyn crate::coordinator::backend::Backend>))];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        // the first request crashes the only worker; its own reply
        // channel dies with the panic (recv error — still not a hang)
        assert!(srv.call("sess", rng.normal_vec(8)).is_err());
        // let the worker thread finish unwinding and drop its receiver
        std::thread::sleep(Duration::from_millis(200));
        // later requests must receive an explicit error response
        let resp = srv.call("sess", rng.normal_vec(8)).unwrap();
        assert!(!resp.ok());
        let msg = resp.output.unwrap_err();
        assert!(msg.contains("no workers"), "unexpected error text: {msg}");
        srv.shutdown();
    }

    #[test]
    fn append_then_attend_sees_grown_kv() {
        let coord_cfg = CoordinatorConfig {
            max_batch: 4,
            batch_window_us: 100,
            workers: 1,
            queue_depth: 64,
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(11);
        let k = Mat::from_vec(25, 8, rng.normal_vec(200));
        let v = Mat::from_vec(25, 8, rng.normal_vec(200));
        kv.put("dec", k.rows_slice(0, 24), v.rows_slice(0, 24)).unwrap();
        let factories = vec![SimBackend::factory(Arith::Hfa, accel_cfg(8))];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();

        let q1 = rng.normal_vec(8);
        let r1 = srv.call("dec", q1.clone()).unwrap().output.unwrap();
        let ack = srv.append("dec", k.rows_slice(24, 25), v.rows_slice(24, 25)).unwrap();
        assert!(ack.ok(), "{:?}", ack.output);
        assert!(ack.output.unwrap().is_empty());
        let q2 = rng.normal_vec(8);
        let r2 = srv.call("dec", q2.clone()).unwrap().output.unwrap();

        let (kb, vb) = (k.round_bf16(), v.round_bf16());
        let g1 = crate::attention::hfa::attention_blocked(
            &Mat::from_vec(1, 8, q1).round_bf16(),
            &kb.rows_slice(0, 24), &vb.rows_slice(0, 24), 4, None, &mut None);
        let g2 = crate::attention::hfa::attention_blocked(
            &Mat::from_vec(1, 8, q2).round_bf16(), &kb, &vb, 4, None, &mut None);
        assert_eq!(r1, g1.row(0).to_vec(), "pre-append attend uses the prefill KV");
        assert_eq!(r2, g2.row(0).to_vec(), "post-append attend must see the new row");

        // append acks are counted separately from query completions and
        // stay out of the latency reservoir
        let snap = srv.metrics.snapshot();
        assert_eq!(snap.appends, 1);
        assert_eq!(snap.completed, 2, "only the two attends count as completed");
        assert_eq!(srv.metrics.latency_samples(), 2, "append ack must not enter the reservoir");

        // append errors surface as responses, not hangs
        let bad = srv.append("missing", Mat::zeros(1, 8), Mat::zeros(1, 8)).unwrap();
        assert!(!bad.ok());
        assert_eq!(srv.metrics.snapshot().failed, 1);
        srv.shutdown();
    }
}

//! The serving loop: bounded ingress -> scheduler thread (continuous
//! batching) -> worker threads owning backends -> per-request reply
//! channels.
//!
//! Requests are either attention queries or decode-step KV appends
//! ([`Payload`]); an append acts as a per-session barrier in the batcher,
//! so a session's slice of a batch is served in arrival order — queries
//! first (against the pre-append KV), then the append.  Clients
//! interleave `append`/`call` to run an autoregressive decode loop whose
//! KV conversion cost tracks the new tokens only.
//!
//! Batches are **cross-session super-batches** ([`Batch`]): a dispatch
//! fuses many sessions' per-session groups, and the worker answers every
//! session's queries through a single plan-based backend call
//! ([`Backend::compute_plan`]) — the high-fan-out serving regime
//! (N sessions x 1 query) runs as one fused grid launch instead of N
//! single-query dispatches.  Fusion is a scheduling choice only:
//! outputs are bit-identical to serving each session alone, appends
//! barrier only their own session, and pins release per session.
//!
//! **Continuous batching** ([`scheduler_loop`], replacing the old
//! window/barrier-only batcher loop): the [`Batcher`] survives as the
//! group-assembly front-end for a session's *first* traffic, but closed
//! groups no longer dispatch directly — they enter the
//! [`Scheduler`]'s waiting queue, and a `Prefill` admission makes the
//! session a resident slot.  From then on its decode traffic is routed
//! straight into the slot (no batcher round-trip: an N-token decode
//! costs one admission) and served by per-iteration `Decode` dispatches
//! assembled from every resident slot — the TGI iteration model, where
//! sessions join and leave the running batch between iterations.
//! Prefill and decode are separate gate lanes ([`IterGate`], at most
//! one in-flight dispatch per lane, serialized by an [`IterToken`] the
//! worker drops at completion), so a long prefill never stalls resident
//! sessions' token cadence.  Cancellation ([`Server::cancel`], dropped
//! handles) retires the session's slot at the next iteration boundary.
//!
//! Ingress **pins** the request's session in the KV store
//! (`KvStore::pin`), and the pin is released when the response is
//! delivered — so a session with queries queued in the batcher can no
//! longer be LRU-evicted out from under them into spurious "unknown
//! session" failures.  KV admission-control failures (byte budget
//! exceeded, capacity overflow) surface as error responses on the
//! submitting channel.
//!
//! The batcher sleeps exactly until the earliest pending group's window
//! expiry ([`Batcher::next_deadline`]) instead of polling a fixed tick —
//! an idle partial batch closes on time, not up to ~2x its window late.
//! Workers take batches from a condvar-guarded queue ([`BatchQueue`])
//! rather than a mutex-wrapped channel receiver, so an idle worker never
//! blocks another behind a held lock (and shutdown wakes all of them at
//! once).
//!
//! `start` fails fast: if any backend factory errors on its worker
//! thread, the failure is propagated out instead of silently serving
//! with fewer (possibly zero) workers.
//!
//! Shutdown is cooperative: dropping the `Server` closes the ingress,
//! drains in-flight batches and joins all threads.  Requests that can no
//! longer be served — queued behind the shutdown message, or formed into
//! a batch when every worker is gone — receive an **explicit error
//! response** instead of a silently dropped reply channel.
//!
//! **Robustness** (see `coordinator::chaos` for the fault-injection side):
//! every request carries an absolute deadline and admission is bounded —
//! submit rejects with [`ServeError::Overloaded`] past
//! `max_pending_requests`.  Requests that expire, get cancelled
//! ([`Server::cancel`] or a dropped [`ResponseHandle`]), or outlive a
//! drain deadline are *shed* at well-defined points (batcher group close,
//! the cancel nudge, worker pre-dispatch) with a typed [`ServeError`]
//! instead of being computed or silently dropped.  Transient backend
//! faults are retried with exponential backoff up to `max_retries`; a
//! panicked worker backend is rebuilt in place while the pool-wide
//! `worker_respawn_budget` lasts.  [`Server::drain`] closes admissions,
//! serves what is in flight until a deadline, then fails the remainder
//! explicitly.  None of this touches kernel outputs: served responses
//! stay bit-identical to the unfused, fault-free path.

use std::cell::Cell;
use std::fmt;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use crate::sync::mpsc::{channel, sync_channel, Receiver, RecvError, RecvTimeoutError, Sender, SyncSender, TryRecvError, TrySendError};
use crate::sync::thread::JoinHandle;
use crate::sync::{thread, Arc, Mutex};

use super::backend::{Backend, BackendFactory, TransientFault};
use super::batcher::{Batch, Batcher};
use super::kvstore::{KvEntry, KvStore};
use super::metrics::Metrics;
use super::protocol::{self, BatchKind, BatchQueue, CancelRegistry, IterGate, IterToken, PinGuard};
use super::request::{AttentionRequest, AttentionResponse, Payload, ServeError};
use super::scheduler::{Scheduler, SchedulerCfg};
use crate::config::CoordinatorConfig;
use crate::Mat;

enum Msg {
    Req(AttentionRequest),
    /// Nudge: a session was cancelled — sweep the batcher's pending
    /// groups now instead of waiting for the next close.  Best-effort
    /// (sent with `try_send`): if the ingress is full, the batcher is
    /// busy and will shed the cancelled requests at group close anyway.
    Cancel(String),
    /// Wake-only nudge from a dropping [`IterToken`]: an iteration's
    /// dispatch retired and its gate lane reopened, so the scheduler
    /// should reassemble now instead of sleeping out its timeout.
    /// Best-effort (`try_send`); a gated-backlog poll in the loop covers
    /// the lost-nudge case.
    IterDone,
    Shutdown,
}

/// Shared robustness state threaded through the batcher and the workers:
/// where shed decisions (deadline, cancel, drain), retry policy and the
/// respawn budget live.
struct ServeCtx {
    kv: Arc<KvStore>,
    metrics: Arc<Metrics>,
    cancels: CancelRegistry,
    /// Admissions closed ([`Server::drain`] in progress).
    draining: AtomicBool,
    /// Drain deadline expired: shed everything still queued with an
    /// explicit `Shutdown` error instead of serving it.
    shed_all: AtomicBool,
    /// Remaining pool-wide worker respawns after backend panics.
    respawn_budget: AtomicU32,
    /// Bounded retries for transient backend faults.
    max_retries: u32,
    /// Base backoff between retries (doubles per attempt).
    retry_backoff: Duration,
}

/// Reply handle for a submitted request, wrapping the completion
/// channel.  Exposes the channel's blocking receive API; **dropping the
/// handle before the terminal response marks the request cancelled**, so
/// the serving loop sheds it instead of computing an answer nobody will
/// read (a caller that gave up is an implicit [`Server::cancel`] scoped
/// to this one request).
pub struct ResponseHandle {
    rx: Receiver<AttentionResponse>,
    cancelled: Arc<AtomicBool>,
    done: Cell<bool>,
}

impl ResponseHandle {
    pub fn recv(&self) -> std::result::Result<AttentionResponse, RecvError> {
        let r = self.rx.recv();
        if r.is_ok() {
            self.done.set(true);
        }
        r
    }

    pub fn recv_timeout(
        &self,
        timeout: Duration,
    ) -> std::result::Result<AttentionResponse, RecvTimeoutError> {
        let r = self.rx.recv_timeout(timeout);
        if r.is_ok() {
            self.done.set(true);
        }
        r
    }

    pub fn try_recv(&self) -> std::result::Result<AttentionResponse, TryRecvError> {
        let r = self.rx.try_recv();
        if r.is_ok() {
            self.done.set(true);
        }
        r
    }
}

impl Drop for ResponseHandle {
    fn drop(&mut self) {
        if !self.done.get() {
            // ordering: Relaxed — a pure advisory flag with no data
            // published behind it; the serving loop's shed points only
            // need to see it eventually, and each re-checks right before
            // dispatch
            self.cancelled.store(true, Ordering::Relaxed);
        }
    }
}

/// A running coordinator instance.
pub struct Server {
    ingress: SyncSender<Msg>,
    threads: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    pub kv: Arc<KvStore>,
    head_dim: usize,
    /// Default per-request deadline from submit
    /// (`CoordinatorConfig::request_timeout_us`).
    request_timeout: Duration,
    /// Delivery grace past a request's deadline for blocking waits
    /// (`CoordinatorConfig::response_grace_us`, validated > 0): how long
    /// `call`/`append` — and the ingress's terminal-frame waits — allow
    /// the serving loop's own shed to deliver before synthesizing
    /// `TimedOut` locally.
    delivery_grace: Duration,
    /// Admission gate: max requests in flight before submit rejects.
    max_pending: usize,
    ctx: Arc<ServeCtx>,
    /// The batcher hands the ingress receiver back here on exit, so
    /// shutdown can drain requests that raced into the queue after the
    /// batcher's final sweep (see [`Server::shutdown`]).
    ingress_rx: Arc<Mutex<Option<Receiver<Msg>>>>,
}

impl Server {
    /// Start the coordinator with one worker thread per backend factory
    /// (each backend is constructed on its own worker thread — PJRT
    /// executables are thread-local).  Returns an error if **any**
    /// factory fails, after tearing the partially-started instance back
    /// down: a server that silently came up with fewer workers than
    /// configured (or none, hanging every request) was a debugging trap.
    pub fn start(
        cfg: &CoordinatorConfig,
        kv: Arc<KvStore>,
        factories: Vec<BackendFactory>,
    ) -> Result<Server> {
        anyhow::ensure!(!factories.is_empty(), "need at least one backend");
        let head_dim = kv.head_dim();
        let metrics = Arc::new(Metrics::new());
        // KV residency/sharing gauges publish through the same sink the
        // serving loop reports into (first server wins if the store is
        // ever shared across instances)
        kv.attach_metrics(metrics.clone());
        let (in_tx, in_rx) = sync_channel::<Msg>(cfg.queue_depth);
        let queue = Arc::new(BatchQueue::new(cfg.queue_depth, factories.len()));
        let ctx = Arc::new(ServeCtx {
            kv: kv.clone(),
            metrics: metrics.clone(),
            cancels: CancelRegistry::default(),
            draining: AtomicBool::new(false),
            shed_all: AtomicBool::new(false),
            respawn_budget: AtomicU32::new(cfg.worker_respawn_budget),
            max_retries: cfg.max_retries,
            retry_backoff: Duration::from_micros(cfg.retry_backoff_us),
        });

        // scheduler thread (continuous batching; the Batcher lives
        // inside it as the group-assembly front-end)
        let window = Duration::from_micros(cfg.batch_window_us);
        let sched_cfg = SchedulerCfg {
            max_batch: cfg.max_batch,
            max_total_batch: cfg.max_total_batch,
            max_batch_prefill_tokens: cfg.max_batch_prefill_tokens,
            max_batch_total_tokens: cfg.max_batch_total_tokens,
            waiting_served_ratio: cfg.waiting_served_ratio,
            max_waiting_iters: cfg.max_waiting_iters,
        };
        let bctx = ctx.clone();
        let bq = queue.clone();
        let loop_tx = in_tx.clone();
        let ingress_rx: Arc<Mutex<Option<Receiver<Msg>>>> = Arc::new(Mutex::new(None));
        let rx_back = ingress_rx.clone();
        let batcher_handle = thread::Builder::new().name("hfa-scheduler".into()).spawn(
            move || scheduler_loop(in_rx, loop_tx, bq, window, sched_cfg, bctx, rx_back),
        )?;

        // worker threads; each reports its backend-init outcome before
        // entering the serve loop
        let worker_count = factories.len();
        let (init_tx, init_rx) = channel::<std::result::Result<(), String>>();
        let mut threads = vec![batcher_handle];
        for (i, factory) in factories.into_iter().enumerate() {
            let queue = queue.clone();
            let wctx = ctx.clone();
            let init_tx = init_tx.clone();
            let h = thread::Builder::new().name(format!("hfa-worker-{i}")).spawn(
                move || {
                    // releases this worker's queue slot on any exit —
                    // return, failed init, or panic mid-batch — and the
                    // last worker out fails whatever batches remain
                    // queued instead of leaving their callers hanging
                    let _exit = WorkerExit { queue: &*queue, ctx: &*wctx };
                    match factory() {
                        Ok(be) => {
                            let _ = init_tx.send(Ok(()));
                            // release the handshake sender before
                            // serving, so start()'s recv() can observe a
                            // disconnect (not hang) if some *other*
                            // worker dies without reporting (e.g. a
                            // panicking factory)
                            drop(init_tx);
                            worker_loop(&factory, be, &queue, &wctx)
                        }
                        Err(e) => {
                            let _ = init_tx.send(Err(format!("hfa-worker-{i}: {e}")));
                        }
                    }
                },
            )?;
            threads.push(h);
        }
        drop(init_tx);

        let mut failures = Vec::new();
        for _ in 0..worker_count {
            match init_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => failures.push(e),
                Err(_) => failures.push("worker exited before reporting init".into()),
            }
        }
        if !failures.is_empty() {
            // tear down: stop the batcher (its exit closes the batch
            // queue, which releases any workers that did come up), then
            // join all
            let _ = in_tx.send(Msg::Shutdown);
            for h in threads {
                let _ = h.join();
            }
            anyhow::bail!("backend init failed: {}", failures.join("; "));
        }

        Ok(Server {
            ingress: in_tx,
            threads,
            next_id: AtomicU64::new(1),
            metrics,
            kv,
            head_dim,
            request_timeout: Duration::from_micros(cfg.request_timeout_us),
            delivery_grace: Duration::from_micros(cfg.response_grace_us.max(1)),
            max_pending: cfg.max_pending_requests.max(1),
            ctx,
            ingress_rx,
        })
    }

    fn validate_query(&self, query: &[f32]) -> Result<()> {
        anyhow::ensure!(
            query.len() == self.head_dim,
            "query dim {} != head dim {}",
            query.len(),
            self.head_dim
        );
        Ok(())
    }

    fn validate_append(&self, k_rows: &Mat, v_rows: &Mat) -> Result<()> {
        anyhow::ensure!(
            k_rows.cols == self.head_dim && v_rows.cols == self.head_dim,
            "append dims {}x{} / {}x{} != head dim {}",
            k_rows.rows,
            k_rows.cols,
            v_rows.rows,
            v_rows.cols,
            self.head_dim
        );
        anyhow::ensure!(
            k_rows.rows == v_rows.rows && k_rows.rows > 0,
            "K/V append row counts must match and be non-zero"
        );
        Ok(())
    }

    /// Submit one query with the default deadline
    /// (`request_timeout_us` from now); returns the reply handle, or an
    /// error when admission control rejects (`ServeError::Overloaded`
    /// past the in-flight cap or a full ingress queue,
    /// `ServeError::Shutdown` while draining — downcast to match).
    pub fn submit(&self, session: &str, query: Vec<f32>) -> Result<ResponseHandle> {
        self.submit_with_deadline(session, query, Instant::now() + self.request_timeout)
    }

    /// Submit one query that must be answered by `deadline`: past it the
    /// serving loop sheds the request with [`ServeError::TimedOut`]
    /// instead of computing an answer nobody awaits.
    pub fn submit_with_deadline(
        &self,
        session: &str,
        query: Vec<f32>,
        deadline: Instant,
    ) -> Result<ResponseHandle> {
        self.validate_query(&query)?;
        self.enqueue(session, Payload::Query(query), deadline).map(|(_, rx)| rx)
    }

    /// Submit a decode-step KV append; the acknowledgement (empty output
    /// vector) arrives once the rows are resident.  Within the batch the
    /// barrier closes, pending queries are served against the pre-append
    /// KV; queries submitted after the acknowledgement see the grown KV.
    /// Across *separate* batches no inter-worker ordering is imposed —
    /// a decode client serializes by waiting for each response before
    /// the next submit (see the module docs' decode protocol).
    pub fn submit_append(
        &self,
        session: &str,
        k_rows: Mat,
        v_rows: Mat,
    ) -> Result<ResponseHandle> {
        self.submit_append_with_deadline(
            session,
            k_rows,
            v_rows,
            Instant::now() + self.request_timeout,
        )
    }

    /// [`Server::submit_append`] with an explicit deadline.
    pub fn submit_append_with_deadline(
        &self,
        session: &str,
        k_rows: Mat,
        v_rows: Mat,
        deadline: Instant,
    ) -> Result<ResponseHandle> {
        self.validate_append(&k_rows, &v_rows)?;
        self.enqueue(session, Payload::Append { k_rows, v_rows }, deadline).map(|(_, rx)| rx)
    }

    fn enqueue(
        &self,
        session: &str,
        payload: Payload,
        deadline: Instant,
    ) -> Result<(u64, ResponseHandle)> {
        // ordering: SeqCst — pairs with drain()'s SeqCst store: once the
        // drain flag is set, no submit may slip a claim past the zero
        // poll (flag store, gauge claims and the poll share one total
        // order)
        if self.ctx.draining.load(Ordering::SeqCst) {
            // ordering: Relaxed — statistical counter, no data behind it
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(ServeError::Shutdown(DRAINING_ERROR.into())));
        }
        // admission gate: bound the requests in flight (accepted but not
        // yet answered) — past the cap, shedding at submit is cheaper
        // and more honest than queueing work that will time out anyway.
        // try_admit claims the slot *before* testing the bound (rolling
        // back on rejection), so racing submitters cannot both read
        // `max - 1` and overshoot the cap the way the former
        // check-then-increment gate could; the claim also lands before
        // the request is handed over, so a served request's decrement
        // can never race ahead of it and underflow the gauge
        if !protocol::try_admit(&self.metrics.inflight, self.max_pending as u64) {
            // ordering: Relaxed — statistical counter, no data behind it
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(ServeError::Overloaded));
        }
        let (tx, rx) = channel();
        // pin the session so the LRU cannot evict it while this request
        // sits in the batcher (released at delivery); a not-yet-resident
        // session takes no pin and fails at serve time as before
        let pinned = self.kv.pin(session);
        let cancelled = Arc::new(AtomicBool::new(false));
        // ordering: Relaxed — id allocation needs uniqueness only, no
        // happens-before with anything else
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = AttentionRequest {
            id,
            session: session.to_string(),
            payload,
            arrived: Instant::now(),
            deadline,
            pinned,
            cancelled: cancelled.clone(),
            reply: tx,
        };
        match self.ingress.try_send(Msg::Req(req)) {
            Ok(()) => {
                // ordering: Relaxed — statistical counter
                self.metrics.accepted.fetch_add(1, Ordering::Relaxed);
                Ok((id, ResponseHandle { rx, cancelled, done: Cell::new(false) }))
            }
            Err(TrySendError::Full(_)) => {
                protocol::release(&self.metrics.inflight);
                if pinned {
                    self.kv.unpin(session);
                }
                // ordering: Relaxed — statistical counter
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(anyhow::Error::new(ServeError::Overloaded)
                    .context("ingress queue full (backpressure)"))
            }
            Err(TrySendError::Disconnected(_)) => {
                protocol::release(&self.metrics.inflight);
                if pinned {
                    self.kv.unpin(session);
                }
                Err(anyhow::Error::new(ServeError::Shutdown("server stopped".into())))
            }
        }
    }

    /// Submit and wait.  Bounded: waits until the request deadline (plus
    /// the configured delivery grace, `response_grace_us`) and
    /// synthesizes a [`ServeError::TimedOut`] response if nothing
    /// arrived — a lost reply channel can never hang the caller.
    pub fn call(&self, session: &str, query: Vec<f32>) -> Result<AttentionResponse> {
        self.validate_query(&query)?;
        let t0 = Instant::now();
        let deadline = t0 + self.request_timeout;
        let (id, rx) = self.enqueue(session, Payload::Query(query), deadline)?;
        Ok(await_response(id, &rx, deadline, t0, self.delivery_grace))
    }

    /// Submit a KV append and wait for the acknowledgement (bounded by
    /// the deadline like [`Server::call`]).
    pub fn append(&self, session: &str, k_rows: Mat, v_rows: Mat) -> Result<AttentionResponse> {
        self.validate_append(&k_rows, &v_rows)?;
        let t0 = Instant::now();
        let deadline = t0 + self.request_timeout;
        let (id, rx) = self.enqueue(session, Payload::Append { k_rows, v_rows }, deadline)?;
        Ok(await_response(id, &rx, deadline, t0, self.delivery_grace))
    }

    /// Fork `child` from resident session `parent`: the child becomes a
    /// resident session whose chunk table aliases every parent chunk
    /// (zero bytes copied, zero rows re-converted), diverging lazily via
    /// the chunk-level copy-on-write that `append` already performs on
    /// shared tails — beam/parallel sampling for the price of a chunk
    /// table clone.  The fork is a direct store operation (no queue
    /// round-trip, same as `KvStore::put` from the ingress): it needs no
    /// backend work and must be visible to a submit racing in right
    /// after.  Refuses while draining, mirroring the admission gate.
    pub fn fork(&self, parent: &str, child: &str) -> Result<()> {
        // ordering: Relaxed — advisory drain flag, same as enqueue's gate
        anyhow::ensure!(
            !self.ctx.draining.load(Ordering::Relaxed),
            "server is draining"
        );
        self.kv.fork(parent, child)
    }

    /// The configured delivery grace (`response_grace_us`): the streaming
    /// ingress reuses it to bound its terminal-frame waits.
    pub fn delivery_grace(&self) -> Duration {
        self.delivery_grace
    }

    /// The default per-request deadline span (`request_timeout_us`).
    pub fn request_timeout(&self) -> Duration {
        self.request_timeout
    }

    /// The KV geometry this server validates requests against.
    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Cancel a session: every queued request of the session submitted
    /// before this call fails with [`ServeError::Cancelled`] and its pin
    /// is released immediately; `evict_kv` additionally drops the
    /// session's KV (freeing its bytes even while pinned — safe, since
    /// in-flight computes hold `Arc` snapshots).  A request already
    /// inside a formed batch is shed by the worker's pre-dispatch
    /// re-check; one already being computed is delivered normally (its
    /// receiver may be gone — counted as `delivery_lost`).  Requests
    /// submitted *after* the cancel are served normally.
    pub fn cancel(&self, session: &str, evict_kv: bool) {
        self.ctx.cancels.cancel(session);
        if evict_kv && self.kv.evict(session).is_some() {
            // ordering: Relaxed — statistical counter (drain reports its
            // delta after joining the serving threads)
            self.metrics.sessions_evicted.fetch_add(1, Ordering::Relaxed);
        }
        let _ = self.ingress.try_send(Msg::Cancel(session.to_string()));
    }

    /// Graceful drain: stop admissions, keep serving what is already in
    /// flight until `timeout` has elapsed, then fail the remainder with
    /// an explicit [`ServeError::Shutdown`] and tear the server down.
    /// Returns a [`DrainReport`]: `clean` when everything in flight
    /// completed before the deadline, plus the counts of requests served
    /// and force-failed during the drain and the sessions whose
    /// residency/KV was torn down.  Either way, every accepted request
    /// has received its terminal response by the time this returns.
    pub fn drain(mut self, timeout: Duration) -> DrainReport {
        // baseline for the report's deltas: everything terminal from
        // here on happened *during* the drain
        // ordering: Relaxed — statistical counters; the exact totals are
        // read again after the serving threads are joined
        let served0 = self.metrics.completed.load(Ordering::Relaxed)
            + self.metrics.appends.load(Ordering::Relaxed);
        let failed0 = self.metrics.failed.load(Ordering::Relaxed);
        let evicted0 = self.metrics.sessions_evicted.load(Ordering::Relaxed);
        // ordering: SeqCst — pairs with enqueue's SeqCst load: every
        // submit either observes the flag (and rejects) or its gauge
        // claim precedes the zero poll below in the single total order
        self.ctx.draining.store(true, Ordering::SeqCst);
        let deadline = Instant::now() + timeout;
        let clean = loop {
            // ordering: SeqCst — the zero poll must join the gate's
            // total order (protocol::try_admit/release); a Relaxed read
            // could see zero while an already-claimed request is still
            // unserved
            if self.metrics.inflight.load(Ordering::SeqCst) == 0 {
                break true;
            }
            if Instant::now() >= deadline {
                break false;
            }
            thread::sleep(Duration::from_millis(1));
        };
        if !clean {
            // past the deadline: the batcher's final sweep and the
            // workers' pre-dispatch checks shed everything still queued
            // ordering: SeqCst — must be visible to every worker's next
            // shed_batch check after this point; keeps the drain cutoff
            // in the same total order as the gauge it is racing
            self.ctx.shed_all.store(true, Ordering::SeqCst);
        }
        self.shutdown_inner();
        // the joins above supply the happens-before edge: these reads see
        // every terminal outcome the serving threads recorded
        // ordering: Relaxed — post-join reads of statistical counters
        let report = DrainReport {
            clean,
            served: (self.metrics.completed.load(Ordering::Relaxed)
                + self.metrics.appends.load(Ordering::Relaxed))
            .saturating_sub(served0),
            force_failed: self.metrics.failed.load(Ordering::Relaxed).saturating_sub(failed0),
            sessions_evicted: self
                .metrics
                .sessions_evicted
                .load(Ordering::Relaxed)
                .saturating_sub(evicted0),
        };
        if report.clean {
            crate::info!("coordinator::server", "{report}");
        } else {
            crate::warnlog!("coordinator::server", "{report}");
        }
        report
    }

    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        let _ = self.ingress.send(Msg::Shutdown);
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
        // authoritative residue drain: after the join no submit can race
        // (shutdown/drop hold the Server exclusively and the threads are
        // gone), so any request still sitting in the ingress queue gets
        // an explicit error — and its session pin released — instead of
        // a silently dropped reply channel
        let rx = self.ingress_rx.lock().take();
        if let Some(rx) = rx {
            loop {
                match rx.try_recv() {
                    Ok(Msg::Req(req)) => fail_request(
                        req,
                        ServeError::Shutdown(SHUTDOWN_ERROR.into()),
                        &self.kv,
                        &self.metrics,
                    ),
                    Ok(Msg::Cancel(_)) | Ok(Msg::IterDone) | Ok(Msg::Shutdown) => {}
                    Err(_) => break,
                }
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if !self.threads.is_empty() {
            self.shutdown_inner();
        }
    }
}

/// Error detail delivered to requests the serving loop can no longer
/// execute (each becomes the matching [`ServeError`] variant).
const SHUTDOWN_ERROR: &str = "server shutting down: request dropped before serving";
const WORKERS_GONE_ERROR: &str = "no workers available (server shutting down?)";
const BACKEND_PANIC_ERROR: &str = "backend panicked while serving this dispatch";
const DRAINING_ERROR: &str = "server draining: admissions closed";
const DRAIN_SHED_ERROR: &str = "drain deadline expired before this request was served";

/// Outcome of a [`Server::drain`]: whether it was clean plus the deltas
/// of terminal outcomes recorded across the drain call itself (requests
/// served to completion, requests force-failed past the deadline, and
/// sessions whose residency/KV was torn down — by cancels racing the
/// drain or by the scheduler retiring resident slots at teardown).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DrainReport {
    /// Everything in flight completed before the drain deadline.
    pub clean: bool,
    /// Queries completed + appends acknowledged during the drain.
    pub served: u64,
    /// Requests failed during the drain (deadline sheds, cancels, and
    /// the explicit [`ServeError::Shutdown`] force-fails past the
    /// drain deadline).
    pub force_failed: u64,
    /// Sessions evicted during the drain (KV freed, residency retired).
    pub sessions_evicted: u64,
}

impl fmt::Display for DrainReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "drain {}: served={} force_failed={} sessions_evicted={}",
            if self.clean { "clean" } else { "past deadline" },
            self.served,
            self.force_failed,
            self.sessions_evicted
        )
    }
}

/// Bounded wait for a submitted request's response: until its deadline
/// plus the configured delivery `grace`
/// ([`crate::config::CoordinatorConfig::response_grace_us`]).  A miss —
/// deadline passed with nothing delivered yet, or a lost reply channel —
/// synthesizes an explicit [`ServeError::TimedOut`] response instead of
/// hanging the caller.  (The in-pipeline request still receives its own
/// terminal response; with this handle dropped, that delivery counts as
/// `delivery_lost`.)
fn await_response(
    id: u64,
    rx: &ResponseHandle,
    deadline: Instant,
    t0: Instant,
    grace: Duration,
) -> AttentionResponse {
    let wait = (deadline + grace).saturating_duration_since(Instant::now());
    match rx.recv_timeout(wait) {
        Ok(resp) => resp,
        Err(_) => AttentionResponse {
            id,
            output: Err(ServeError::TimedOut),
            latency_us: t0.elapsed().as_secs_f64() * 1e6,
            batch_size: 0,
        },
    }
}

/// Shed verdict for one queued request, checked at group close and again
/// by the worker just before dispatch.
fn shed_verdict(req: &AttentionRequest, now: Instant, shed_all: bool, ctx: &ServeCtx) -> Option<ServeError> {
    if shed_all {
        Some(ServeError::Shutdown(DRAIN_SHED_ERROR.into()))
    // ordering: Relaxed — advisory drop-cancel flag (see ResponseHandle);
    // a stale read only delays the shed to the next check point
    } else if req.cancelled.load(Ordering::Relaxed)
        || ctx.cancels.cancelled_since(&req.session, req.arrived)
    {
        Some(ServeError::Cancelled)
    } else if req.expired(now) {
        Some(ServeError::TimedOut)
    } else {
        None
    }
}

/// Strip cancelled / deadline-expired / drain-shed requests out of a
/// batch, delivering their terminal errors immediately; returns the
/// batch if any requests survive.  Run twice per dispatch: by the
/// batcher at group close (before the dispatch is counted) and by the
/// worker right before serving (a batch can sit in the dispatch queue
/// past deadlines or cancels).
fn shed_batch(batch: Batch, ctx: &ServeCtx) -> Option<Batch> {
    let now = Instant::now();
    // ordering: SeqCst — pairs with drain()'s shed_all store (same total
    // order as the in-flight gauge the drain deadline races)
    let shed_all = ctx.shed_all.load(Ordering::SeqCst);
    let Batch { groups: old_groups, kind, done } = batch;
    let mut groups = Vec::with_capacity(old_groups.len());
    for mut g in old_groups {
        let mut kept = Vec::with_capacity(g.requests.len());
        for req in g.requests.drain(..) {
            match shed_verdict(&req, now, shed_all, ctx) {
                Some(err) => {
                    // ordering: Relaxed — statistical counter
                    ctx.metrics.shed.fetch_add(1, Ordering::Relaxed);
                    fail_request(req, err, &ctx.kv, &ctx.metrics);
                }
                None => kept.push(req),
            }
        }
        if !kept.is_empty() {
            g.requests = kept;
            groups.push(g);
        }
    }
    if groups.is_empty() {
        // `done` (if any) drops here, finishing its gate lane — a fully
        // shed iteration must reopen the lane like a served one
        None
    } else {
        Some(Batch { groups, kind, done })
    }
}

/// Panic-safe worker accounting: decrements the live-worker count on any
/// exit path and fails batches stranded behind the last worker.  (The
/// dispatch queue itself lives in [`super::protocol::BatchQueue`], where
/// the loom suite model-checks its park/wake/shutdown protocol.)
struct WorkerExit<'a> {
    queue: &'a BatchQueue<Batch>,
    ctx: &'a ServeCtx,
}

impl Drop for WorkerExit<'_> {
    fn drop(&mut self) {
        let metrics = &self.ctx.metrics;
        for batch in self.queue.worker_exited() {
            // emit() counted this dispatch when it was handed over; it
            // never served, so roll the structural counters back before
            // failing it (same invariant as emit()'s push-failure path —
            // `batches`/`mean_sessions` must count served dispatches)
            // ordering: Relaxed — statistical counters; the queue mutex
            // inside worker_exited() already ordered the handoff itself
            metrics.batches.fetch_sub(1, Ordering::Relaxed);
            metrics
                .batched_requests
                .fetch_sub(batch.total_requests() as u64, Ordering::Relaxed);
            metrics.batched_sessions.fetch_sub(batch.sessions() as u64, Ordering::Relaxed);
            fail_batch(
                batch,
                &ServeError::Shutdown(WORKERS_GONE_ERROR.into()),
                &self.ctx.kv,
                metrics,
            );
        }
    }
}

/// Closes the batch queue when the batcher thread exits — **including by
/// panic**, where leaving it open would park every idle worker on the
/// `available` condvar forever and hang shutdown's join.  (The replaced
/// channel design was implicitly panic-safe: unwinding dropped the
/// sender, disconnecting the workers' `recv()`.)
struct CloseOnExit<'a>(&'a BatchQueue<Batch>);

impl Drop for CloseOnExit<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// The continuous-batching scheduling loop (replaces the seed's pure
/// window/barrier `batcher_loop`).
///
/// A session's first traffic still forms per-session groups inside the
/// [`Batcher`]'s window, but closed groups are no longer dispatched
/// directly: they enter the [`Scheduler`]'s waiting queue, and a
/// `Prefill` admission makes the session a **resident slot**.  Resident
/// sessions' traffic is routed straight into their slots (no batcher
/// round-trip) and served by per-iteration `Decode` dispatches
/// assembled round-robin from every slot with work — the TGI iteration
/// model, where sessions join/leave the running batch at iteration
/// boundaries instead of the whole batch forming and retiring together.
///
/// Iteration pacing: each dispatch carries an [`IterToken`] holding its
/// gate lane ([`IterGate`]; prefill and decode are independent lanes,
/// so a long prefill never blocks decode cadence).  The worker drops
/// the token when the dispatch retires, which reopens the lane and
/// `try_send`s a wake-only [`Msg::IterDone`] nudge back into the
/// ingress; a bounded poll below covers a lost nudge (full channel).
#[allow(clippy::too_many_arguments)] // thread entry point: every collaborator is passed once
fn scheduler_loop(
    in_rx: Receiver<Msg>,
    in_tx: SyncSender<Msg>,
    queue: Arc<BatchQueue<Batch>>,
    window: Duration,
    sched_cfg: SchedulerCfg,
    ctx: Arc<ServeCtx>,
    rx_back: Arc<Mutex<Option<Receiver<Msg>>>>,
) {
    // dropped last (declared first): the queue closes after the final
    // drain below on a normal exit, and on any panic path too
    let _close = CloseOnExit(&queue);
    let mut batcher = Batcher::new(sched_cfg.max_batch, sched_cfg.max_total_batch, window);
    let mut scheduler = Scheduler::new(sched_cfg, ctx.kv.clone(), ctx.metrics.clone());
    let gate = Arc::new(IterGate::new());
    // Fusion slack: expiry sweeps run at `earliest deadline + window/4`
    // instead of per-group deadlines, so every group whose window lapses
    // inside one slack interval closes in the *same* sweep and packs
    // into one cross-session super-batch.  Worst-case close latency is
    // 1.25x the window (pinned < 1.5x by the close-latency regression
    // test) — the bounded price of fusing N idle sessions' singleton
    // groups into one dispatch instead of N deadline-ordered ones.
    let slack = window / 4;
    loop {
        // sleep until the earliest of: the earliest pending group's
        // sweep point, the earliest queued-request deadline inside the
        // scheduler (a waiting group deferred by the token budget never
        // reaches a dispatch-side shed point, so its expiry must wake
        // this loop), and — while a lane is in flight over a backlog — a
        // short poll bound in case the worker's IterDone nudge was lost
        // to a full ingress channel.  A fully idle loop blocks on the
        // channel with no timeout at all — no fixed-tick polling.
        let mut wake = batcher.next_deadline().map(|d| d + slack);
        if let Some(d) = scheduler.next_request_deadline() {
            wake = Some(wake.map_or(d, |w| w.min(d)));
        }
        if scheduler.has_backlog()
            && (gate.inflight(BatchKind::Prefill) || gate.inflight(BatchKind::Decode))
        {
            let poll = Instant::now() + Duration::from_micros(500);
            wake = Some(wake.map_or(poll, |w| w.min(poll)));
        }
        let msg = match wake {
            None => in_rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
            Some(at) => {
                let now = Instant::now();
                if at <= now {
                    Err(RecvTimeoutError::Timeout) // sweep immediately
                } else {
                    in_rx.recv_timeout(at - now)
                }
            }
        };
        match msg {
            Ok(Msg::Req(req)) => {
                // slot routing honors arrival order: while the session
                // has a group still forming (or parked in the waiting
                // queue), new traffic must follow it through the same
                // channel, so route() refuses and the batcher takes it
                let front_end_pending = batcher.has_pending_session(&req.session);
                if let Some(req) = scheduler.route(req, Instant::now(), front_end_pending) {
                    if let Some(b) = batcher.push(req) {
                        scheduler.enqueue_closed(b, Instant::now());
                    }
                }
            }
            Ok(Msg::IterDone) => {
                // wake-only: a dispatch retired and its lane reopened;
                // the dispatch pass below reassembles
            }
            Ok(Msg::Cancel(session)) => {
                // cancellation nudge: sweep the pending groups, waiting
                // queue and slot backlogs now so a cancelled session's
                // requests fail (and release their pins) immediately,
                // and retire the session's slot at this iteration
                // boundary — its residency ends here, not at drain
                let now = Instant::now();
                let mut shed: Vec<AttentionRequest> = batcher
                    .remove_matching(|r| shed_verdict(r, now, false, &ctx).is_some());
                shed.extend(
                    scheduler.remove_matching(|r| shed_verdict(r, now, false, &ctx).is_some()),
                );
                shed.extend(scheduler.retire(&session));
                for req in shed {
                    // the verdict is re-derived (same `now`, same ctx);
                    // the registry's retention sweep could in principle
                    // drop the mark between the two calls, so fall back
                    // to Cancelled (the only sweepable verdict) instead
                    // of panicking the scheduler — the request was
                    // already removed and must get its terminal response
                    let err = shed_verdict(&req, now, false, &ctx)
                        .unwrap_or(ServeError::Cancelled);
                    // ordering: Relaxed — statistical counter
                    ctx.metrics.shed.fetch_add(1, Ordering::Relaxed);
                    fail_request(req, err, &ctx.kv, &ctx.metrics);
                }
            }
            Ok(Msg::Shutdown) => {
                // requests that raced into the queue behind the shutdown
                // message would otherwise be dropped with a dead reply
                // channel — deliver an explicit error instead
                loop {
                    match in_rx.try_recv() {
                        Ok(Msg::Req(req)) => fail_request(
                            req,
                            ServeError::Shutdown(SHUTDOWN_ERROR.into()),
                            &ctx.kv,
                            &ctx.metrics,
                        ),
                        Ok(Msg::Cancel(_)) | Ok(Msg::IterDone) | Ok(Msg::Shutdown) => {}
                        Err(_) => break,
                    }
                }
                break;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        // sweep only at the slack-quantized boundary — not after every
        // message, which would close groups one by one as traffic
        // trickles past their deadlines and defeat the fusion
        if wake.is_some_and(|at| Instant::now() >= at) {
            let now = Instant::now();
            for b in batcher.close_expired(now) {
                scheduler.enqueue_closed(b, now);
            }
            // deadline sweep over the scheduler's own queues (waiting
            // groups + slot backlogs), gated on its deadline bound so
            // the O(pending) scan runs only when something can actually
            // have expired — NOT only on a Cancel nudge: a group parked
            // by token-budget deferral would otherwise hang past its
            // deadline with its pin held (remove_matching re-tightens
            // the bound, so a stale-low bound costs one empty pass)
            if scheduler.next_request_deadline().is_some_and(|d| now >= d) {
                for req in
                    scheduler.remove_matching(|r| shed_verdict(r, now, false, &ctx).is_some())
                {
                    // same re-derivation fallback rationale as the
                    // Cancel arm; here expiry is the usual verdict
                    let err = shed_verdict(&req, now, false, &ctx)
                        .unwrap_or(ServeError::TimedOut);
                    // ordering: Relaxed — statistical counter
                    ctx.metrics.shed.fetch_add(1, Ordering::Relaxed);
                    fail_request(req, err, &ctx.kv, &ctx.metrics);
                }
            }
        }
        // iteration dispatch: at most one batch per free gate lane.  The
        // token claims the lane before the handoff; its Drop (worker
        // side, on any path — served, shed, panic unwind, queue residue)
        // finishes the lane and nudges this loop to reassemble.
        for mut b in scheduler.dispatch(Instant::now(), &gate) {
            let kind = b.kind;
            if gate.claim(kind) {
                // this loop is the sole claimer, so the claim always
                // succeeds (dispatch() only assembles for free lanes);
                // `Formed` batches are ungated and skip the token
                let tx = in_tx.clone();
                b.done = Some(IterToken::new(
                    gate.clone(),
                    kind,
                    Some(Box::new(move || {
                        let _ = tx.try_send(Msg::IterDone);
                    })),
                ));
            }
            emit(&queue, b, &ctx);
        }
    }
    for b in batcher.drain() {
        emit(&queue, b, &ctx);
    }
    for b in scheduler.drain_all() {
        emit(&queue, b, &ctx);
    }
    // hand the ingress receiver back to the Server: a submit can race
    // its request into the queue between our final sweep above and this
    // thread's exit, and shutdown drains those authoritatively after
    // joining us (the window where a message is truly unreachable is
    // thereby closed)
    *rx_back.lock() = Some(in_rx);
    // `_close` drops here, closing the queue — workers exit once it drains
}

fn emit(queue: &BatchQueue<Batch>, b: Batch, ctx: &ServeCtx) {
    // group-close shed point: expired / cancelled / drain-shed requests
    // fail here instead of being dispatched (and are excluded from the
    // structural batch counters — they were never part of a dispatch)
    let Some(b) = shed_batch(b, ctx) else { return };
    let metrics = &ctx.metrics;
    // queue-wait span closes at dispatch handoff: time from submit to
    // the request leaving the scheduling stage (forming + waiting/slot
    // time), separate from the compute latency the serve path records
    let now = Instant::now();
    for g in &b.groups {
        for req in &g.requests {
            metrics.observe_queue_wait(now.duration_since(req.arrived).as_secs_f64() * 1e6);
        }
    }
    let requests = b.total_requests() as u64;
    let sessions = b.sessions() as u64;
    // count the dispatch *before* handing it over: a worker can pop,
    // serve and answer the batch before this thread runs again, and a
    // caller reading the metrics right after its response must already
    // see the dispatch
    // ordering: Relaxed — statistical counters; the program-order
    // count-before-push plus the queue mutex inside push() gives the
    // worker (and anyone it answers) a happens-before on these adds
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.batched_requests.fetch_add(requests, Ordering::Relaxed);
    metrics.batched_sessions.fetch_add(sessions, Ordering::Relaxed);
    if let Err(b) = queue.push(b) {
        // every worker is gone (all exited/panicked): the batch would
        // hang its callers forever — deliver explicit errors instead
        // ordering: Relaxed — rollback of the statistical counts above
        metrics.batches.fetch_sub(1, Ordering::Relaxed);
        metrics.batched_requests.fetch_sub(requests, Ordering::Relaxed);
        metrics.batched_sessions.fetch_sub(sessions, Ordering::Relaxed);
        fail_batch(b, &ServeError::Shutdown(WORKERS_GONE_ERROR.into()), &ctx.kv, metrics);
    }
}

/// Deliver an explicit error response to every request of a batch that
/// will never be served.
fn fail_batch(b: Batch, err: &ServeError, kv: &KvStore, metrics: &Metrics) {
    for group in b.groups {
        for req in group.requests {
            fail_request(req, err.clone(), kv, metrics);
        }
    }
}

/// Deliver an explicit error response for a request that will never be
/// served, releasing its session pin.  A terminal delivery: decrements
/// the in-flight gauge and records the per-outcome failure tally (but
/// not the latency reservoir — the request was never computed, and
/// shed/shutdown latencies would poison the serving percentiles).
fn fail_request(req: AttentionRequest, err: ServeError, kv: &KvStore, metrics: &Metrics) {
    let AttentionRequest { id, session, arrived, pinned, reply, .. } = req;
    if pinned {
        kv.unpin(&session);
    }
    metrics.record_failure(&err);
    // terminal delivery: give the admission slot back (same total order
    // as the gate — see protocol::release)
    protocol::release(&metrics.inflight);
    let latency_us = arrived.elapsed().as_secs_f64() * 1e6;
    let sent = reply.send(AttentionResponse { id, output: Err(err), latency_us, batch_size: 0 });
    if sent.is_err() {
        // ordering: Relaxed — statistical counter
        metrics.delivery_lost.fetch_add(1, Ordering::Relaxed);
    }
}

/// The worker's serve loop, wrapped in a watchdog: a backend panic
/// (crashed device thread) is caught after [`serve_batch`] has delivered
/// explicit errors for the whole dispatch, and — while the pool-wide
/// respawn budget lasts — the backend is rebuilt in place through the
/// same factory instead of letting the pool shrink toward zero.  Past
/// the budget the panic propagates and [`WorkerExit`] accounts the
/// death as before.
fn worker_loop(
    factory: &BackendFactory,
    mut be: Box<dyn Backend>,
    queue: &BatchQueue<Batch>,
    ctx: &ServeCtx,
) {
    while let Some(batch) = queue.pop() {
        // pre-dispatch shed point: the batch may have sat in the queue
        // past deadlines, cancels, or the drain cutoff
        let Some(mut batch) = shed_batch(batch, ctx) else { continue };
        // hold the iteration token on this frame, not inside the batch:
        // it must drop (reopening the gate lane and nudging the
        // scheduler) when the dispatch retires on *any* path — served,
        // panic unwind through catch_unwind, or respawn
        let kind = batch.kind;
        let _done = batch.done.take();
        let t0 = Instant::now();
        let caught = catch_unwind(AssertUnwindSafe(|| serve_batch(&mut *be, batch, ctx)));
        let Err(payload) = caught else {
            if kind == BatchKind::Prefill {
                ctx.metrics.observe_prefill(t0.elapsed().as_secs_f64() * 1e6);
            }
            continue;
        };
        // every request of the panicked dispatch already received its
        // explicit error (serve_batch guarantees that before re-raising).
        // CAS loop (not fetch_update) so the claim compiles against the
        // facade's loom atomics too; semantics are identical
        let claimed = loop {
            // ordering: SeqCst — pool-wide budget: concurrent panicking
            // workers must agree on exactly which claims succeeded
            let b = ctx.respawn_budget.load(Ordering::SeqCst);
            let Some(nb) = b.checked_sub(1) else { break false };
            // ordering: SeqCst — the winning CAS is the budget claim
            if ctx
                .respawn_budget
                .compare_exchange(b, nb, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                break true;
            }
        };
        if !claimed {
            resume_unwind(payload);
        }
        match factory() {
            Ok(fresh) => {
                // ordering: Relaxed — statistical counter
                ctx.metrics.worker_respawns.fetch_add(1, Ordering::Relaxed);
                be = fresh;
            }
            // a budget unit is consumed by the failed attempt; the
            // worker dies as it would have without a watchdog
            Err(_) => resume_unwind(payload),
        }
    }
}

/// A query waiting to be flushed: `(id, query, arrived, pinned, reply)`.
type PendingQuery = (u64, Vec<f32>, Instant, bool, Sender<AttentionResponse>);

/// One session group's request stream while a super-batch is served.
type GroupStream = (String, std::vec::IntoIter<AttentionRequest>);

/// One group's slice of a fused plan: `(group index, pending queries,
/// resolved KV entry, packed query rows)`.
type FusedRun = (usize, Vec<PendingQuery>, KvEntry, Mat);

/// Serve one super-batch.  Each session group runs in arrival order —
/// contiguous query runs, then the append that barriered them — while
/// *across* groups the leading query runs of every session are answered
/// by a **single fused** [`Backend::compute_plan`] dispatch (outputs are
/// bit-identical to serving each session alone, so the fusion is
/// invisible to callers).  Configuration errors (backend/store geometry
/// disagreements, unknown sessions) become error responses for the
/// affected group only, never worker panics.  Every response releases
/// its ingress pin (before the reply is sent; panic-safe via the
/// per-session [`PinGuard`]s).
fn serve_batch(be: &mut dyn Backend, batch: Batch, ctx: &ServeCtx) {
    let kv = &*ctx.kv;
    let metrics = &*ctx.metrics;
    let n = batch.total_requests();
    let mut guards: Vec<PinGuard> = batch
        .groups
        .iter()
        .map(|g| {
            // panic-safe pin accounting per session group; see
            // protocol::PinGuard for the release-before-reply invariant
            PinGuard::new(kv, g.session.clone(), g.requests.iter().filter(|r| r.pinned).count())
        })
        .collect();
    if be.head_dim() != kv.head_dim() {
        let err = ServeError::backend(format!(
            "backend head_dim {} != KV store head_dim {}",
            be.head_dim(),
            kv.head_dim()
        ));
        for (guard, group) in guards.iter_mut().zip(batch.groups) {
            for req in group.requests {
                let is_append = req.is_append();
                let AttentionRequest { id, arrived, pinned, reply, .. } = req;
                if pinned {
                    guard.release_one();
                }
                if is_append {
                    deliver_append(id, arrived, reply, Err(err.clone()), n, metrics);
                } else {
                    deliver(id, arrived, reply, Err(err.clone()), n, metrics);
                }
            }
        }
        return;
    }
    // per-group request streams; the batcher ships appends last within a
    // group, but the loop below handles any interleaving: it alternates
    // fused cross-session query phases with per-session append barriers
    // until every stream is exhausted
    let mut streams: Vec<GroupStream> = batch
        .groups
        .into_iter()
        .map(|g| (g.session, g.requests.into_iter()))
        .collect();
    let mut parked_append: Vec<Option<AttentionRequest>> =
        streams.iter().map(|_| None).collect();
    // a backend panic inside a phase still kills this worker, but every
    // request of the dispatch must first receive an explicit error:
    // flush_runs fails its in-flight fused runs itself, and the residue
    // pass below covers requests not yet drained from their streams
    // (parked appends included) before the panic is re-raised
    let caught = catch_unwind(AssertUnwindSafe(|| {
        serve_groups(be, &mut streams, &mut parked_append, ctx, &mut guards, n)
    }));
    if let Err(payload) = caught {
        for (gi, (_, stream)) in streams.iter_mut().enumerate() {
            let parked = parked_append[gi].take();
            for req in parked.into_iter().chain(stream.by_ref()) {
                let is_append = req.is_append();
                let AttentionRequest { id, arrived, pinned, reply, .. } = req;
                if pinned {
                    guards[gi].release_one();
                }
                let output = Err(ServeError::backend(BACKEND_PANIC_ERROR));
                if is_append {
                    deliver_append(id, arrived, reply, output, n, metrics);
                } else {
                    deliver(id, arrived, reply, output, n, metrics);
                }
            }
        }
        resume_unwind(payload);
    }
}

/// The phase loop of [`serve_batch`]: alternate fused cross-session
/// query dispatches with per-session append barriers until every
/// group's stream is exhausted.
fn serve_groups(
    be: &mut dyn Backend,
    streams: &mut [GroupStream],
    parked_append: &mut [Option<AttentionRequest>],
    ctx: &ServeCtx,
    guards: &mut [PinGuard<'_>],
    n: usize,
) {
    let metrics = &*ctx.metrics;
    loop {
        // phase 1: every group's next contiguous query run, fused into
        // one plan dispatch
        let mut runs: Vec<(usize, Vec<PendingQuery>)> = Vec::new();
        for (gi, (_, stream)) in streams.iter_mut().enumerate() {
            if parked_append[gi].is_some() {
                continue;
            }
            let mut run: Vec<PendingQuery> = Vec::new();
            for req in stream.by_ref() {
                if req.is_append() {
                    parked_append[gi] = Some(req);
                    break;
                }
                let AttentionRequest { id, payload, arrived, pinned, reply, .. } = req;
                if let Payload::Query(q) = payload {
                    run.push((id, q, arrived, pinned, reply));
                }
            }
            if !run.is_empty() {
                runs.push((gi, run));
            }
        }
        let had_queries = !runs.is_empty();
        if had_queries {
            flush_runs(be, streams, runs, ctx, guards, n);
        }
        // phase 2: apply each group's parked append barrier
        let mut had_appends = false;
        for (gi, slot) in parked_append.iter_mut().enumerate() {
            let Some(req) = slot.take() else { continue };
            had_appends = true;
            let AttentionRequest { id, payload, arrived, pinned, reply, .. } = req;
            let output = match payload {
                Payload::Append { k_rows, v_rows } => ctx
                    .kv
                    .append(&streams[gi].0, k_rows, v_rows)
                    .map(|()| Vec::new())
                    .map_err(|e| ServeError::KvAdmission(e.to_string())),
                Payload::Query(_) => unreachable!("parked request is an append"),
            };
            if pinned {
                guards[gi].release_one();
            }
            deliver_append(id, arrived, reply, output, n, metrics);
        }
        if !had_queries && !had_appends {
            break;
        }
    }
}

/// Answer one fused phase: every group's pending query run in a single
/// plan-based backend dispatch.  Groups whose session is missing or
/// whose queries are malformed fail individually; the rest fuse.
fn flush_runs(
    be: &mut dyn Backend,
    streams: &[GroupStream],
    runs: Vec<(usize, Vec<PendingQuery>)>,
    ctx: &ServeCtx,
    guards: &mut [PinGuard<'_>],
    batch_size: usize,
) {
    let metrics = &*ctx.metrics;
    let d = be.head_dim();
    let mut fused: Vec<FusedRun> = Vec::new();
    for (gi, run) in runs {
        let session = streams[gi].0.as_str();
        let Some(entry) = ctx.kv.get(session) else {
            let err = ServeError::KvAdmission(format!("unknown session {session:?}"));
            fail_run(run, &err, gi, guards, metrics, batch_size);
            continue;
        };
        if run.iter().any(|(_, q, _, _, _)| q.len() != d) {
            let err = ServeError::backend(format!("query dim mismatch (expected {d})"));
            fail_run(run, &err, gi, guards, metrics, batch_size);
            continue;
        }
        let mut q = Mat::zeros(run.len(), d);
        for (i, (_, qv, _, _, _)) in run.iter().enumerate() {
            q.row_mut(i).copy_from_slice(qv);
        }
        fused.push((gi, run, entry, q));
    }
    if fused.is_empty() {
        return;
    }
    let plan: Vec<(&KvEntry, &Mat)> = fused.iter().map(|(_, _, e, q)| (e, q)).collect();
    // a panicking backend (crashed device thread) still unwinds to the
    // worker watchdog — but the fused callers get an explicit error
    // response first instead of dead reply channels for every innocent
    // session that happened to share the dispatch
    let result = catch_unwind(AssertUnwindSafe(|| be.compute_plan(&plan)));
    let plan_len = plan.len();
    drop(plan);
    match result {
        Err(payload) => {
            let err = ServeError::backend(BACKEND_PANIC_ERROR);
            for (gi, run, _, _) in fused {
                fail_run(run, &err, gi, guards, metrics, batch_size);
            }
            resume_unwind(payload);
        }
        Ok(Ok(outs)) if outs.len() == plan_len => {
            for ((gi, run, _, _), out) in fused.into_iter().zip(outs) {
                deliver_run(run, &out, gi, guards, metrics, batch_size);
            }
        }
        Ok(Ok(outs)) => {
            let err = ServeError::backend(format!(
                "backend returned {} outputs for a {plan_len}-session plan",
                outs.len()
            ));
            for (gi, run, _, _) in fused {
                fail_run(run, &err, gi, guards, metrics, batch_size);
            }
        }
        // error isolation + retry: one bad session (e.g. a static-shape
        // PJRT kernel rejecting a mid-decode session, or an injected
        // fault) must not fail its dispatch neighbours — retry each
        // group as its own plan, with bounded backoff retries for faults
        // the backend marked transient, and deliver per-group results.
        // This matches pre-fusion behavior where every session was its
        // own dispatch; the aborted fused attempt costs at most the
        // entries before the first failure (both in-tree backends
        // validate eagerly / short-circuit at the first failing entry),
        // so the error path stays ~one pass
        Ok(Err(e)) => {
            if is_transient(&e) {
                // the per-session re-dispatch below is itself the first
                // retry of the transient fused failure
                // ordering: Relaxed — statistical counter
                metrics.retries.fetch_add(1, Ordering::Relaxed);
            }
            // index loop over take-able slots: a panic mid-retry must
            // still deliver explicit errors to the *remaining* runs
            // before unwinding to the watchdog — exactly-one-response
            // holds even when the retry pass itself crashes.  Each slot
            // is taken exactly once (here, or by the panic sweep below,
            // which only visits indices past the current one), so an
            // empty slot simply has nothing left to serve
            let mut slots: Vec<Option<FusedRun>> = fused.into_iter().map(Some).collect();
            for i in 0..slots.len() {
                let Some((gi, run, entry, q)) = slots[i].take() else { continue };
                let caught = catch_unwind(AssertUnwindSafe(|| {
                    compute_single_with_retry(&mut *be, &entry, &q, ctx)
                }));
                match caught {
                    Err(payload) => {
                        let err = ServeError::backend(BACKEND_PANIC_ERROR);
                        fail_run(run, &err, gi, guards, metrics, batch_size);
                        for slot in slots.iter_mut().skip(i + 1) {
                            if let Some((gj, runj, _, _)) = slot.take() {
                                fail_run(runj, &err, gj, guards, metrics, batch_size);
                            }
                        }
                        resume_unwind(payload);
                    }
                    Ok(Ok(out)) => deliver_run(run, &out, gi, guards, metrics, batch_size),
                    Ok(Err(err)) => fail_run(run, &err, gi, guards, metrics, batch_size),
                }
            }
        }
    }
}

/// Whether any error in the chain is a [`TransientFault`] marker.
fn is_transient(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.downcast_ref::<TransientFault>().is_some())
}

/// Serve one session's query run as its own single-entry plan, retrying
/// faults the backend marked transient with exponential backoff, up to
/// `max_retries` re-attempts.  Permanent faults are never retried.
fn compute_single_with_retry(
    be: &mut dyn Backend,
    entry: &KvEntry,
    q: &Mat,
    ctx: &ServeCtx,
) -> std::result::Result<Mat, ServeError> {
    let mut attempt = 0u32;
    loop {
        match be.compute_plan(&[(entry, q)]) {
            Ok(mut outs) => {
                let n = outs.len();
                // pop-then-check instead of indexing: a conforming
                // backend returns exactly one output, and a broken one
                // becomes an error response, never a worker panic
                match outs.pop() {
                    Some(out) if n == 1 => return Ok(out),
                    _ => {
                        return Err(ServeError::backend(format!(
                            "backend returned {n} outputs for a 1-session plan"
                        )))
                    }
                }
            }
            Err(e) => {
                let transient = is_transient(&e);
                if transient && attempt < ctx.max_retries {
                    attempt += 1;
                    // ordering: Relaxed — statistical counter
                    ctx.metrics.retries.fetch_add(1, Ordering::Relaxed);
                    let backoff = ctx.retry_backoff * (1u32 << (attempt - 1).min(10));
                    if !backoff.is_zero() {
                        thread::sleep(backoff);
                    }
                    continue;
                }
                return Err(ServeError::BackendFailed { reason: e.to_string(), transient });
            }
        }
    }
}

/// Deliver one group's fused-plan outputs row by row.
fn deliver_run(
    run: Vec<PendingQuery>,
    out: &Mat,
    gi: usize,
    guards: &mut [PinGuard<'_>],
    metrics: &Metrics,
    batch_size: usize,
) {
    for (i, (id, _, arrived, pinned, reply)) in run.into_iter().enumerate() {
        if pinned {
            guards[gi].release_one();
        }
        deliver(id, arrived, reply, Ok(out.row(i).to_vec()), batch_size, metrics);
    }
}

/// Deliver the same error to every query of one group's run.
fn fail_run(
    run: Vec<PendingQuery>,
    err: &ServeError,
    gi: usize,
    guards: &mut [PinGuard<'_>],
    metrics: &Metrics,
    batch_size: usize,
) {
    for (id, _, arrived, pinned, reply) in run {
        if pinned {
            guards[gi].release_one();
        }
        deliver(id, arrived, reply, Err(err.clone()), batch_size, metrics);
    }
}

fn deliver(
    id: u64,
    arrived: Instant,
    reply: Sender<AttentionResponse>,
    output: std::result::Result<Vec<f32>, ServeError>,
    batch_size: usize,
    metrics: &Metrics,
) {
    let latency_us = arrived.elapsed().as_secs_f64() * 1e6;
    match &output {
        Ok(_) => {
            // ordering: Relaxed — statistical counter
            metrics.completed.fetch_add(1, Ordering::Relaxed);
        }
        Err(e) => metrics.record_failure(e),
    }
    metrics.observe_latency(latency_us);
    // terminal delivery: give the admission slot back (same total order
    // as the gate — see protocol::release)
    protocol::release(&metrics.inflight);
    if reply
        .send(AttentionResponse { id, output, latency_us, batch_size })
        .is_err()
    {
        // ordering: Relaxed — statistical counter
        metrics.delivery_lost.fetch_add(1, Ordering::Relaxed);
    }
}

/// Acknowledge a KV append.  Counted under `Metrics::appends`, not
/// `completed`, and excluded from the latency reservoir: the percentiles
/// measure attention serving, and near-zero-compute write acks would
/// dilute them (a decode loop would otherwise also double-count its
/// completion rate).
fn deliver_append(
    id: u64,
    arrived: Instant,
    reply: Sender<AttentionResponse>,
    output: std::result::Result<Vec<f32>, ServeError>,
    batch_size: usize,
    metrics: &Metrics,
) {
    let latency_us = arrived.elapsed().as_secs_f64() * 1e6;
    match &output {
        Ok(_) => {
            // ordering: Relaxed — statistical counter
            metrics.appends.fetch_add(1, Ordering::Relaxed);
        }
        Err(e) => metrics.record_failure(e),
    }
    // terminal delivery: give the admission slot back (same total order
    // as the gate — see protocol::release)
    protocol::release(&metrics.inflight);
    if reply
        .send(AttentionResponse { id, output, latency_us, batch_size })
        .is_err()
    {
        // ordering: Relaxed — statistical counter
        metrics.delivery_lost.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::coordinator::backend::SimBackend;
    use crate::hw::Arith;
    use crate::proptest::Rng;

    fn accel_cfg(head_dim: usize) -> AcceleratorConfig {
        AcceleratorConfig {
            head_dim,
            seq_len: 32,
            kv_blocks: 4,
            parallel_queries: 1,
            freq_mhz: 500.0,
        }
    }

    fn test_server(workers: usize) -> (Server, Mat, Mat) {
        let coord_cfg = CoordinatorConfig {
            max_batch: 4,
            max_total_batch: 64,
            batch_window_us: 200,
            workers,
            queue_depth: 64,
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(1);
        let k = Mat::from_vec(32, 8, rng.normal_vec(256));
        let v = Mat::from_vec(32, 8, rng.normal_vec(256));
        kv.put("sess", k.clone(), v.clone()).unwrap();
        let factories: Vec<_> = (0..workers)
            .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg(8)))
            .collect();
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        (srv, k.round_bf16(), v.round_bf16())
    }

    #[test]
    fn serves_single_request_correctly() {
        let (srv, k, v) = test_server(1);
        let mut rng = Rng::new(2);
        let qv = rng.normal_vec(8);
        let resp = srv.call("sess", qv.clone()).unwrap();
        assert!(resp.ok(), "{:?}", resp.output);
        // must equal the golden model directly (the accelerator rounds
        // incoming queries to BF16, so the golden call gets rounded q)
        let q = Mat::from_vec(1, 8, qv).round_bf16();
        let golden =
            crate::attention::hfa::attention_blocked(&q, &k, &v, 4, None, &mut None);
        assert_eq!(resp.output.unwrap(), golden.row(0).to_vec());
        srv.shutdown();
    }

    #[test]
    fn unknown_session_fails_cleanly() {
        let (srv, _, _) = test_server(1);
        let resp = srv.call("nope", vec![0.0; 8]).unwrap();
        assert!(!resp.ok());
        assert_eq!(srv.metrics.snapshot().failed, 1);
        srv.shutdown();
    }

    #[test]
    fn wrong_dim_rejected_at_submit() {
        let (srv, _, _) = test_server(1);
        assert!(srv.submit("sess", vec![0.0; 5]).is_err());
        assert!(srv.submit_append("sess", Mat::zeros(1, 5), Mat::zeros(1, 5)).is_err());
        assert!(srv.submit_append("sess", Mat::zeros(0, 8), Mat::zeros(0, 8)).is_err());
        assert!(srv.submit_append("sess", Mat::zeros(2, 8), Mat::zeros(1, 8)).is_err());
        srv.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let (srv, _, _) = test_server(2);
        let mut rng = Rng::new(3);
        let rxs: Vec<_> =
            (0..32).map(|_| srv.submit("sess", rng.normal_vec(8)).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.ok());
        }
        let snap = srv.metrics.snapshot();
        assert_eq!(snap.completed, 32);
        assert!(snap.mean_batch > 1.0, "batching never kicked in: {snap:?}");
        srv.shutdown();
    }

    #[test]
    fn responses_match_request_order_independence() {
        // interleave two sessions; every response must use its session's KV
        let (srv, k, v) = test_server(2);
        let mut rng = Rng::new(5);
        let k2 = Mat::from_vec(32, 8, rng.normal_vec(256));
        let v2 = Mat::from_vec(32, 8, rng.normal_vec(256));
        srv.kv.put("sess2", k2.clone(), v2.clone()).unwrap();
        let q1 = rng.normal_vec(8);
        let q2 = rng.normal_vec(8);
        let r1 = srv.call("sess", q1.clone()).unwrap().output.unwrap();
        let r2 = srv.call("sess2", q2.clone()).unwrap().output.unwrap();
        let g1 = crate::attention::hfa::attention_blocked(
            &Mat::from_vec(1, 8, q1).round_bf16(), &k, &v, 4, None, &mut None);
        let g2 = crate::attention::hfa::attention_blocked(
            &Mat::from_vec(1, 8, q2).round_bf16(), &k2.round_bf16(), &v2.round_bf16(), 4,
            None, &mut None);
        assert_eq!(r1, g1.row(0).to_vec());
        assert_eq!(r2, g2.row(0).to_vec());
        srv.shutdown();
    }

    // The batcher must close an idle partial batch at its window, not at
    // the next fixed-tick sweep (the seed slept `max(window, 50us)`
    // between sweeps, so traffic landing just before a deadline pushed
    // the close up to ~2x the window out).
    #[test]
    fn partial_batch_closes_within_its_window_under_background_traffic() {
        let window_us = 200_000u64; // 200 ms: generous against CI jitter
        let coord_cfg = CoordinatorConfig {
            max_batch: 100,
            max_total_batch: 256,
            batch_window_us: window_us,
            workers: 1,
            queue_depth: 64,
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(71);
        kv.put("slow", Mat::from_vec(32, 8, rng.normal_vec(256)),
               Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
        kv.put("other", Mat::from_vec(32, 8, rng.normal_vec(256)),
               Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
        let factories = vec![SimBackend::factory(Arith::Hfa, accel_cfg(8))];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();

        let t0 = Instant::now();
        let rx = srv.submit("slow", rng.normal_vec(8)).unwrap();
        // background traffic on another session lands *just before* the
        // "slow" deadline — under fixed-tick sweeping this rescheduled
        // the next sweep a whole window later
        thread::sleep(Duration::from_micros(window_us * 3 / 5));
        let _rx2 = srv.submit("other", rng.normal_vec(8)).unwrap();
        let resp = rx.recv().unwrap();
        assert!(resp.ok(), "{:?}", resp.output);
        let elapsed = t0.elapsed();
        let window = Duration::from_micros(window_us);
        assert!(
            elapsed < window * 3 / 2,
            "partial batch closed {elapsed:?} after submit; want < 1.5x the {window:?} window"
        );
        srv.shutdown();
    }

    #[test]
    fn start_fails_when_any_backend_init_fails() {
        let coord_cfg = CoordinatorConfig {
            max_batch: 4,
            max_total_batch: 64,
            batch_window_us: 100,
            workers: 2,
            queue_depth: 16,
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        // all factories failing
        let factories: Vec<BackendFactory> =
            (0..2).map(|_| Box::new(|| anyhow::bail!("no device")) as BackendFactory).collect();
        let err = Server::start(&coord_cfg, kv.clone(), factories)
            .err()
            .expect("start must propagate backend init failure");
        assert!(err.to_string().contains("backend init failed"), "{err}");
        // one good + one bad is still a failed start (no silent degraded mode)
        let factories: Vec<BackendFactory> = vec![
            SimBackend::factory(Arith::Hfa, accel_cfg(8)),
            Box::new(|| anyhow::bail!("no device")),
        ];
        assert!(Server::start(&coord_cfg, kv, factories).is_err());
    }

    #[test]
    fn head_dim_mismatch_fails_requests_without_killing_worker() {
        // store says d=8, backend says d=16: every request must get an
        // error response (the seed panicked the worker, hanging clients)
        let coord_cfg = CoordinatorConfig {
            max_batch: 4,
            max_total_batch: 64,
            batch_window_us: 100,
            workers: 1,
            queue_depth: 16,
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(7);
        kv.put("sess", Mat::from_vec(32, 8, rng.normal_vec(256)),
               Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
        let factories = vec![SimBackend::factory(Arith::Hfa, accel_cfg(16))];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        for _ in 0..2 {
            // two rounds: the worker must survive the first mismatch
            let resp = srv.call("sess", rng.normal_vec(8)).unwrap();
            assert!(!resp.ok());
            assert!(resp.output.unwrap_err().to_string().contains("head_dim"));
        }
        srv.shutdown();
    }

    /// Backend whose first compute panics its worker — models a crashed
    /// device thread.
    struct PanicBackend;

    impl crate::coordinator::backend::Backend for PanicBackend {
        fn head_dim(&self) -> usize {
            8
        }
        fn seq_len(&self) -> usize {
            32
        }
        fn max_batch(&self) -> usize {
            4
        }
        fn compute_plan(
            &mut self,
            _plan: &[(&crate::coordinator::kvstore::KvEntry, &Mat)],
        ) -> Result<Vec<Mat>> {
            panic!("injected backend crash")
        }
        fn name(&self) -> String {
            "panic".into()
        }
    }

    #[test]
    fn dead_workers_yield_explicit_errors_not_hangs() {
        // regression: once every worker is gone, formed batches used to
        // be dropped on the floor — callers blocked on a reply channel
        // that would only error when the whole server was torn down
        let coord_cfg = CoordinatorConfig {
            max_batch: 1,
            max_total_batch: 64,
            batch_window_us: 100,
            workers: 1,
            queue_depth: 16,
            // a panicking backend must NOT be respawned here: this test
            // is about the explicit-error path once the pool is gone
            worker_respawn_budget: 0,
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(13);
        kv.put(
            "sess",
            Mat::from_vec(32, 8, rng.normal_vec(256)),
            Mat::from_vec(32, 8, rng.normal_vec(256)),
        )
        .unwrap();
        let factories: Vec<BackendFactory> =
            vec![Box::new(|| Ok(Box::new(PanicBackend) as Box<dyn crate::coordinator::backend::Backend>))];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        // the first request crashes the only worker, but its caller
        // still receives an explicit error response before the unwind
        // (fused neighbours of a crashing dispatch must not be left on
        // dead reply channels)
        let resp = srv.call("sess", rng.normal_vec(8)).unwrap();
        assert!(!resp.ok());
        assert!(
            resp.output.unwrap_err().to_string().contains("panicked"),
            "caller must learn the backend crashed"
        );
        // let the worker thread finish unwinding
        thread::sleep(Duration::from_millis(200));
        // later requests must receive an explicit error response
        let resp = srv.call("sess", rng.normal_vec(8)).unwrap();
        assert!(!resp.ok());
        let msg = resp.output.unwrap_err().to_string();
        assert!(msg.contains("no workers"), "unexpected error text: {msg}");
        srv.shutdown();
    }

    /// Backend that (like the static-shape PJRT kernel) can only serve
    /// full-length sessions, and whose `compute_plan` fails as a whole
    /// when any entry is short — the shape that used to take every
    /// fused neighbour down with it.
    struct StrictLenBackend;

    impl crate::coordinator::backend::Backend for StrictLenBackend {
        fn head_dim(&self) -> usize {
            8
        }
        fn seq_len(&self) -> usize {
            32
        }
        fn max_batch(&self) -> usize {
            8
        }
        fn compute_plan(
            &mut self,
            plan: &[(&crate::coordinator::kvstore::KvEntry, &Mat)],
        ) -> Result<Vec<Mat>> {
            plan.iter()
                .map(|&(kv, q)| {
                    anyhow::ensure!(
                        kv.prepared().n() == 32,
                        "short session rejected by static kernel"
                    );
                    Ok(Mat::from_fn(q.rows, 8, |_, _| 1.0))
                })
                .collect()
        }
        fn name(&self) -> String {
            "strict-len".into()
        }
    }

    #[test]
    fn fused_dispatch_isolates_per_session_backend_errors() {
        // one valid and one invalid session fused into a dispatch: the
        // invalid one must fail alone, the valid one must still be
        // served (pre-fusion each session was its own dispatch, so the
        // valid one always succeeded — fusion must not regress that)
        let coord_cfg = CoordinatorConfig {
            max_batch: 8,
            max_total_batch: 64,
            batch_window_us: 100_000, // generous window so the two fuse
            workers: 1,
            queue_depth: 16,
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(23);
        kv.put("full", Mat::from_vec(32, 8, rng.normal_vec(256)),
               Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
        kv.put("short", Mat::from_vec(16, 8, rng.normal_vec(128)),
               Mat::from_vec(16, 8, rng.normal_vec(128))).unwrap();
        let factories: Vec<BackendFactory> = vec![Box::new(|| {
            Ok(Box::new(StrictLenBackend) as Box<dyn crate::coordinator::backend::Backend>)
        })];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        let rx_full = srv.submit("full", rng.normal_vec(8)).unwrap();
        let rx_short = srv.submit("short", rng.normal_vec(8)).unwrap();
        let full = rx_full.recv().unwrap();
        let short = rx_short.recv().unwrap();
        assert!(full.ok(), "valid session must survive a neighbour's failure: {:?}", full.output);
        assert_eq!(full.output.unwrap(), vec![1.0; 8]);
        assert!(!short.ok(), "invalid session must fail alone");
        assert!(short.output.unwrap_err().to_string().contains("short session rejected"));
        srv.shutdown();
    }

    #[test]
    fn append_then_attend_sees_grown_kv() {
        let coord_cfg = CoordinatorConfig {
            max_batch: 4,
            max_total_batch: 64,
            batch_window_us: 100,
            workers: 1,
            queue_depth: 64,
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(11);
        let k = Mat::from_vec(25, 8, rng.normal_vec(200));
        let v = Mat::from_vec(25, 8, rng.normal_vec(200));
        kv.put("dec", k.rows_slice(0, 24), v.rows_slice(0, 24)).unwrap();
        let factories = vec![SimBackend::factory(Arith::Hfa, accel_cfg(8))];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();

        let q1 = rng.normal_vec(8);
        let r1 = srv.call("dec", q1.clone()).unwrap().output.unwrap();
        let ack = srv.append("dec", k.rows_slice(24, 25), v.rows_slice(24, 25)).unwrap();
        assert!(ack.ok(), "{:?}", ack.output);
        assert!(ack.output.unwrap().is_empty());
        let q2 = rng.normal_vec(8);
        let r2 = srv.call("dec", q2.clone()).unwrap().output.unwrap();

        let (kb, vb) = (k.round_bf16(), v.round_bf16());
        let g1 = crate::attention::hfa::attention_blocked(
            &Mat::from_vec(1, 8, q1).round_bf16(),
            &kb.rows_slice(0, 24), &vb.rows_slice(0, 24), 4, None, &mut None);
        let g2 = crate::attention::hfa::attention_blocked(
            &Mat::from_vec(1, 8, q2).round_bf16(), &kb, &vb, 4, None, &mut None);
        assert_eq!(r1, g1.row(0).to_vec(), "pre-append attend uses the prefill KV");
        assert_eq!(r2, g2.row(0).to_vec(), "post-append attend must see the new row");

        // append acks are counted separately from query completions and
        // stay out of the latency reservoir
        let snap = srv.metrics.snapshot();
        assert_eq!(snap.appends, 1);
        assert_eq!(snap.completed, 2, "only the two attends count as completed");
        assert_eq!(srv.metrics.latency_samples(), 2, "append ack must not enter the reservoir");

        // append errors surface as responses, not hangs
        let bad = srv.append("missing", Mat::zeros(1, 8), Mat::zeros(1, 8)).unwrap();
        assert!(!bad.ok());
        assert_eq!(srv.metrics.snapshot().failed, 1);
        srv.shutdown();
    }

    #[test]
    fn expired_requests_are_shed_with_timed_out() {
        let (srv, _, _) = test_server(1);
        let mut rng = Rng::new(31);
        // a deadline already in the past: the batcher must shed it at
        // group close without spending backend compute on it
        let rx = srv
            .submit_with_deadline("sess", rng.normal_vec(8), Instant::now())
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.output, Err(ServeError::TimedOut));
        // live traffic alongside the shed request is unaffected
        let live = srv.call("sess", rng.normal_vec(8)).unwrap();
        assert!(live.ok(), "{:?}", live.output);
        let snap = srv.metrics.snapshot();
        assert_eq!(snap.timed_out, 1);
        assert_eq!(snap.shed, 1);
        assert_eq!(srv.kv.pinned_sessions(), 0, "shed request must release its pin");
        srv.shutdown();
    }

    #[test]
    fn admission_gate_bounds_requests_in_flight() {
        let coord_cfg = CoordinatorConfig {
            max_batch: 4,
            max_total_batch: 64,
            // long window: the first request stays in flight while the
            // second hits the gate
            batch_window_us: 500_000,
            workers: 1,
            queue_depth: 16,
            max_pending_requests: 1,
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(37);
        kv.put("sess", Mat::from_vec(32, 8, rng.normal_vec(256)),
               Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
        let factories = vec![SimBackend::factory(Arith::Hfa, accel_cfg(8))];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        let rx = srv.submit("sess", rng.normal_vec(8)).unwrap();
        let err = srv.submit("sess", rng.normal_vec(8)).expect_err("gate must reject");
        assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::Overloaded));
        assert_eq!(srv.metrics.snapshot().rejected, 1);
        // once the in-flight request completes, capacity reopens
        assert!(rx.recv().unwrap().ok());
        let resp = srv.call("sess", rng.normal_vec(8)).unwrap();
        assert!(resp.ok(), "{:?}", resp.output);
        srv.shutdown();
    }

    #[test]
    fn cancel_sheds_queued_requests_and_releases_pins() {
        let coord_cfg = CoordinatorConfig {
            max_batch: 8,
            max_total_batch: 64,
            batch_window_us: 2_000_000, // long window: requests sit queued
            workers: 1,
            queue_depth: 16,
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(41);
        kv.put("sess", Mat::from_vec(32, 8, rng.normal_vec(256)),
               Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
        let factories = vec![SimBackend::factory(Arith::Hfa, accel_cfg(8))];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        let rx1 = srv.submit("sess", rng.normal_vec(8)).unwrap();
        let rx2 = srv.submit("sess", rng.normal_vec(8)).unwrap();
        srv.cancel("sess", false);
        assert_eq!(rx1.recv().unwrap().output, Err(ServeError::Cancelled));
        assert_eq!(rx2.recv().unwrap().output, Err(ServeError::Cancelled));
        assert_eq!(srv.kv.pinned_sessions(), 0, "cancel must release the pins");
        let snap = srv.metrics.snapshot();
        assert_eq!(snap.cancelled, 2);
        assert_eq!(snap.shed, 2);
        // the KV entry survives (evict_kv=false): new requests serve fine
        assert!(srv.kv.contains("sess"));
        let resp = srv.call("sess", rng.normal_vec(8)).unwrap();
        assert!(resp.ok(), "post-cancel traffic must serve: {:?}", resp.output);
        srv.shutdown();
    }

    #[test]
    fn dropped_response_handle_cancels_the_request() {
        let coord_cfg = CoordinatorConfig {
            max_batch: 8,
            max_total_batch: 64,
            batch_window_us: 100_000,
            workers: 1,
            queue_depth: 16,
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(43);
        kv.put("sess", Mat::from_vec(32, 8, rng.normal_vec(256)),
               Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
        let factories = vec![SimBackend::factory(Arith::Hfa, accel_cfg(8))];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        drop(srv.submit("sess", rng.normal_vec(8)).unwrap());
        // the abandoned request must reach a terminal outcome on its own
        // (shed as cancelled at a shed point, or — if it raced past them
        // all — delivered into the dropped channel)
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let snap = srv.metrics.snapshot();
            if snap.cancelled + snap.completed + snap.delivery_lost >= 1 {
                break;
            }
            assert!(Instant::now() < deadline, "abandoned request never terminal: {snap:?}");
            thread::sleep(Duration::from_millis(10));
        }
        let snap = srv.metrics.snapshot();
        assert_eq!(snap.inflight, 0, "in-flight gauge must return to zero");
        assert_eq!(srv.kv.pinned_sessions(), 0);
        srv.shutdown();
    }

    #[test]
    fn drain_serves_inflight_before_the_deadline() {
        let (srv, _, _) = test_server(1);
        let mut rng = Rng::new(47);
        let rx = srv.submit("sess", rng.normal_vec(8)).unwrap();
        let metrics = Arc::clone(&srv.metrics);
        let report = srv.drain(Duration::from_secs(10));
        assert!(report.clean, "drain must complete cleanly: {report}");
        assert_eq!(report.served, 1, "the in-flight query completed during the drain");
        assert_eq!(report.force_failed, 0, "a clean drain force-fails nothing");
        let resp = rx.recv().unwrap();
        assert!(resp.ok(), "in-flight request must be served through drain: {:?}", resp.output);
        assert_eq!(metrics.snapshot().inflight, 0);
    }

    #[test]
    fn drain_past_deadline_fails_the_remainder_explicitly() {
        let coord_cfg = CoordinatorConfig {
            max_batch: 8,
            max_total_batch: 64,
            batch_window_us: 10_000_000, // never closes on its own
            workers: 1,
            queue_depth: 16,
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(53);
        kv.put("sess", Mat::from_vec(32, 8, rng.normal_vec(256)),
               Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
        let factories = vec![SimBackend::factory(Arith::Hfa, accel_cfg(8))];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        let rx = srv.submit("sess", rng.normal_vec(8)).unwrap();
        let metrics = Arc::clone(&srv.metrics);
        let report = srv.drain(Duration::ZERO);
        assert!(!report.clean, "expired drain must report unclean: {report}");
        assert_eq!(report.force_failed, 1, "the shed remainder is counted");
        let resp = rx.recv().unwrap();
        assert!(
            matches!(resp.output, Err(ServeError::Shutdown(_))),
            "remainder must fail explicitly: {:?}",
            resp.output
        );
        assert_eq!(metrics.snapshot().inflight, 0);
    }

    #[test]
    fn panicked_worker_respawns_until_budget_exhausted() {
        let coord_cfg = CoordinatorConfig {
            max_batch: 1, // no fusion: each call is its own dispatch
            max_total_batch: 64,
            batch_window_us: 100,
            workers: 1,
            queue_depth: 16,
            worker_respawn_budget: 2,
            ..CoordinatorConfig::default()
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(61);
        kv.put("sess", Mat::from_vec(32, 8, rng.normal_vec(256)),
               Mat::from_vec(32, 8, rng.normal_vec(256))).unwrap();
        let factories: Vec<BackendFactory> = vec![Box::new(|| {
            Ok(Box::new(PanicBackend) as Box<dyn crate::coordinator::backend::Backend>)
        })];
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        // each dispatch panics; the watchdog rebuilds the backend twice,
        // so three requests in a row all get explicit backend errors
        // from a live worker
        for _ in 0..3 {
            let resp = srv.call("sess", rng.normal_vec(8)).unwrap();
            assert!(!resp.ok());
            assert!(resp.output.unwrap_err().to_string().contains("panicked"));
        }
        // let the third unwind finish killing the worker (budget spent)
        thread::sleep(Duration::from_millis(200));
        assert_eq!(srv.metrics.snapshot().worker_respawns, 2);
        let resp = srv.call("sess", rng.normal_vec(8)).unwrap();
        assert!(
            matches!(resp.output, Err(ServeError::Shutdown(_))),
            "past the budget the pool is gone: {:?}",
            resp.output
        );
        srv.shutdown();
    }
}

//! The serving loop: bounded ingress -> batcher thread -> worker threads
//! owning backends -> per-request reply channels.
//!
//! Shutdown is cooperative: dropping the `Server` closes the ingress,
//! drains in-flight batches and joins all threads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::backend::{Backend, BackendFactory};
use super::batcher::{Batch, Batcher};
use super::kvstore::KvStore;
use super::metrics::Metrics;
use super::request::{AttentionRequest, AttentionResponse};
use crate::config::CoordinatorConfig;
use crate::Mat;

enum Msg {
    Req(AttentionRequest),
    Shutdown,
}

/// A running coordinator instance.
pub struct Server {
    ingress: SyncSender<Msg>,
    threads: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    pub kv: Arc<KvStore>,
    head_dim: usize,
}

impl Server {
    /// Start the coordinator with one worker thread per backend factory
    /// (each backend is constructed on its own worker thread — PJRT
    /// executables are thread-local).
    pub fn start(
        cfg: &CoordinatorConfig,
        kv: Arc<KvStore>,
        factories: Vec<BackendFactory>,
    ) -> Result<Server> {
        anyhow::ensure!(!factories.is_empty(), "need at least one backend");
        let head_dim = kv.head_dim();
        let metrics = Arc::new(Metrics::new());
        let (in_tx, in_rx) = sync_channel::<Msg>(cfg.queue_depth);
        let (batch_tx, batch_rx) = sync_channel::<Batch>(cfg.queue_depth);
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        // batcher thread
        let window = Duration::from_micros(cfg.batch_window_us);
        let max_batch = cfg.max_batch;
        let m = metrics.clone();
        let batcher_handle = std::thread::Builder::new()
            .name("hfa-batcher".into())
            .spawn(move || batcher_loop(in_rx, batch_tx, max_batch, window, m))?;

        // worker threads
        let mut threads = vec![batcher_handle];
        for (i, factory) in factories.into_iter().enumerate() {
            let rx = batch_rx.clone();
            let kv = kv.clone();
            let m = metrics.clone();
            let h = std::thread::Builder::new()
                .name(format!("hfa-worker-{i}"))
                .spawn(move || match factory() {
                    Ok(mut be) => worker_loop(&mut *be, rx, kv, m),
                    Err(e) => eprintln!("hfa-worker-{i}: backend init failed: {e}"),
                })?;
            threads.push(h);
        }

        Ok(Server {
            ingress: in_tx,
            threads,
            next_id: AtomicU64::new(1),
            metrics,
            kv,
            head_dim,
        })
    }

    /// Submit one query; returns the reply receiver, or an error when the
    /// ingress queue is full (backpressure).
    pub fn submit(
        &self,
        session: &str,
        query: Vec<f32>,
    ) -> Result<std::sync::mpsc::Receiver<AttentionResponse>> {
        anyhow::ensure!(
            query.len() == self.head_dim,
            "query dim {} != head dim {}",
            query.len(),
            self.head_dim
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let req = AttentionRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            session: session.to_string(),
            query,
            arrived: Instant::now(),
            reply: tx,
        };
        match self.ingress.try_send(Msg::Req(req)) {
            Ok(()) => {
                self.metrics.accepted.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("ingress queue full (backpressure)")
            }
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("server stopped"),
        }
    }

    /// Submit and wait.
    pub fn call(&self, session: &str, query: Vec<f32>) -> Result<AttentionResponse> {
        let rx = self.submit(session, query)?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        let _ = self.ingress.send(Msg::Shutdown);
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if !self.threads.is_empty() {
            self.shutdown_inner();
        }
    }
}

fn batcher_loop(
    in_rx: Receiver<Msg>,
    batch_tx: SyncSender<Batch>,
    max_batch: usize,
    window: Duration,
    metrics: Arc<Metrics>,
) {
    let mut batcher = Batcher::new(max_batch, window);
    let tick = window.max(Duration::from_micros(50));
    loop {
        match in_rx.recv_timeout(tick) {
            Ok(Msg::Req(req)) => {
                if let Some(b) = batcher.push(req) {
                    emit(&batch_tx, b, &metrics);
                }
            }
            Ok(Msg::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        for b in batcher.close_expired(Instant::now()) {
            emit(&batch_tx, b, &metrics);
        }
    }
    for b in batcher.drain() {
        emit(&batch_tx, b, &metrics);
    }
    // dropping batch_tx disconnects the workers
}

fn emit(tx: &SyncSender<Batch>, b: Batch, metrics: &Metrics) {
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.batched_requests.fetch_add(b.requests.len() as u64, Ordering::Relaxed);
    let _ = tx.send(b);
}

fn worker_loop(
    be: &mut dyn Backend,
    rx: Arc<Mutex<Receiver<Batch>>>,
    kv: Arc<KvStore>,
    metrics: Arc<Metrics>,
) {
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(b) => b,
                Err(_) => break, // batcher gone
            }
        };
        serve_batch(be, batch, &kv, &metrics);
    }
}

fn serve_batch(be: &mut dyn Backend, batch: Batch, kv: &KvStore, metrics: &Metrics) {
    let n = batch.requests.len();
    let d = be.head_dim();
    let result: Result<Mat, String> = match kv.get(&batch.session) {
        None => Err(format!("unknown session {:?}", batch.session)),
        Some(entry) => {
            let mut q = Mat::zeros(n, d);
            for (i, r) in batch.requests.iter().enumerate() {
                q.row_mut(i).copy_from_slice(&r.query);
            }
            be.compute(&entry, &q).map_err(|e| e.to_string())
        }
    };
    for (i, req) in batch.requests.into_iter().enumerate() {
        let latency_us = req.arrived.elapsed().as_secs_f64() * 1e6;
        let output = match &result {
            Ok(mat) => Ok(mat.row(i).to_vec()),
            Err(e) => Err(e.clone()),
        };
        if output.is_ok() {
            metrics.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            metrics.failed.fetch_add(1, Ordering::Relaxed);
        }
        metrics.observe_latency(latency_us);
        let _ = req.reply.send(AttentionResponse {
            id: req.id,
            output,
            latency_us,
            batch_size: n,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::coordinator::backend::SimBackend;
    use crate::hw::Arith;
    use crate::proptest::Rng;

    fn test_server(workers: usize) -> (Server, Mat, Mat) {
        let accel_cfg = AcceleratorConfig {
            head_dim: 8,
            seq_len: 32,
            kv_blocks: 4,
            parallel_queries: 1,
            freq_mhz: 500.0,
        };
        let coord_cfg = CoordinatorConfig {
            max_batch: 4,
            batch_window_us: 200,
            workers,
            queue_depth: 64,
        };
        let kv = Arc::new(KvStore::new(32, 8, 4));
        let mut rng = Rng::new(1);
        let k = Mat::from_vec(32, 8, rng.normal_vec(256));
        let v = Mat::from_vec(32, 8, rng.normal_vec(256));
        kv.put("sess", k.clone(), v.clone()).unwrap();
        let factories: Vec<_> = (0..workers)
            .map(|_| SimBackend::factory(Arith::Hfa, accel_cfg.clone()))
            .collect();
        let srv = Server::start(&coord_cfg, kv, factories).unwrap();
        (srv, k.round_bf16(), v.round_bf16())
    }

    #[test]
    fn serves_single_request_correctly() {
        let (srv, k, v) = test_server(1);
        let mut rng = Rng::new(2);
        let qv = rng.normal_vec(8);
        let resp = srv.call("sess", qv.clone()).unwrap();
        assert!(resp.ok(), "{:?}", resp.output);
        // must equal the golden model directly (the accelerator rounds
        // incoming queries to BF16, so the golden call gets rounded q)
        let q = Mat::from_vec(1, 8, qv).round_bf16();
        let golden =
            crate::attention::hfa::attention_blocked(&q, &k, &v, 4, None, &mut None);
        assert_eq!(resp.output.unwrap(), golden.row(0).to_vec());
        srv.shutdown();
    }

    #[test]
    fn unknown_session_fails_cleanly() {
        let (srv, _, _) = test_server(1);
        let resp = srv.call("nope", vec![0.0; 8]).unwrap();
        assert!(!resp.ok());
        assert_eq!(srv.metrics.snapshot().failed, 1);
        srv.shutdown();
    }

    #[test]
    fn wrong_dim_rejected_at_submit() {
        let (srv, _, _) = test_server(1);
        assert!(srv.submit("sess", vec![0.0; 5]).is_err());
        srv.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let (srv, _, _) = test_server(2);
        let mut rng = Rng::new(3);
        let rxs: Vec<_> =
            (0..32).map(|_| srv.submit("sess", rng.normal_vec(8)).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.ok());
        }
        let snap = srv.metrics.snapshot();
        assert_eq!(snap.completed, 32);
        assert!(snap.mean_batch > 1.0, "batching never kicked in: {snap:?}");
        srv.shutdown();
    }

    #[test]
    fn responses_match_request_order_independence() {
        // interleave two sessions; every response must use its session's KV
        let (srv, k, v) = test_server(2);
        let mut rng = Rng::new(5);
        let k2 = Mat::from_vec(32, 8, rng.normal_vec(256));
        let v2 = Mat::from_vec(32, 8, rng.normal_vec(256));
        srv.kv.put("sess2", k2.clone(), v2.clone()).unwrap();
        let q1 = rng.normal_vec(8);
        let q2 = rng.normal_vec(8);
        let r1 = srv.call("sess", q1.clone()).unwrap().output.unwrap();
        let r2 = srv.call("sess2", q2.clone()).unwrap().output.unwrap();
        let g1 = crate::attention::hfa::attention_blocked(
            &Mat::from_vec(1, 8, q1).round_bf16(), &k, &v, 4, None, &mut None);
        let g2 = crate::attention::hfa::attention_blocked(
            &Mat::from_vec(1, 8, q2).round_bf16(), &k2.round_bf16(), &v2.round_bf16(), 4,
            None, &mut None);
        assert_eq!(r1, g1.row(0).to_vec());
        assert_eq!(r2, g2.row(0).to_vec());
        srv.shutdown();
    }
}

//! 28 nm standard-cell component library: per-operator area and energy.
//!
//! Substitute for the paper's Catapult-HLS + Cadence + PowerPro flow
//! (DESIGN.md §5).  Values are gate-level estimates at 28 nm / 0.9 V /
//! 500 MHz, calibrated so that (a) absolute magnitudes land near the
//! paper's reported design sizes (Table IV: H-FA-1-4 ~1.1 mm² with SRAM)
//! and (b) the *structural* FA-2 vs H-FA substitution — FP mul/div/exp
//! replaced by fixed-point add/sub/shift/LUT — reproduces the reported
//! savings shape (Fig. 6: ~36 % datapath at d=32; Fig. 7: >26 % with
//! SRAM included).  Both designs are composed from this same library, so
//! the comparison is apples-to-apples by construction.

/// One hardware operator class.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Op {
    /// BF16 multiplier (8x8 mantissa array + exponent add + normalize).
    Bf16Mul,
    /// BF16 adder (align barrel shifter + mantissa add + LZA + normalize).
    Bf16Add,
    /// BF16 comparator / max.
    Bf16Max,
    /// e^x evaluator in BF16 (shift-and-add power-of-two method, [31]).
    ExpUnit,
    /// BF16 divider (reciprocal LUT + Newton step + multiply).
    Bf16Div,
    /// 16-bit fixed-point adder/subtractor.
    FixAdd,
    /// 16-bit fixed-point comparator / max / abs-diff support.
    FixCmp,
    /// 16-bit barrel shifter (the `>> p` of Eq. 19).
    Shifter,
    /// PWL segment LUT (8 x 21 bit coefficients + decode mux).
    PwlLut,
    /// PWL slope multiplier (4 x 14 bit).
    PwlMul,
    /// Score-difference quantizer (clamp + constant multiply by log2 e).
    QuantUnit,
    /// 16-bit pipeline register.
    Reg16,
    /// 32-bit pipeline register (f32/score path).
    Reg32,
    /// Per-lane control / muxing overhead (ready-valid, enables).
    CtrlLane,
    /// Per-unit control FSM + flow control (fixed per FAU/ACC/DIV block).
    CtrlBlock,
}

/// Area in um^2 and switching energy in pJ per operation at 28 nm.
#[derive(Clone, Copy, Debug)]
pub struct CostEntry {
    pub area_um2: f64,
    pub energy_pj: f64,
}

/// The calibrated 28 nm library.
pub fn lib(op: Op) -> CostEntry {
    use Op::*;
    let (area_um2, energy_pj) = match op {
        Bf16Mul => (640.0, 1.20),
        Bf16Add => (590.0, 0.95),
        Bf16Max => (95.0, 0.10),
        ExpUnit => (980.0, 2.30),
        Bf16Div => (2150.0, 5.20),
        FixAdd => (76.0, 0.13),
        FixCmp => (66.0, 0.09),
        Shifter => (140.0, 0.18),
        PwlLut => (205.0, 0.22),
        PwlMul => (185.0, 0.31),
        QuantUnit => (150.0, 0.22),
        Reg16 => (50.0, 0.06),
        Reg32 => (92.0, 0.11),
        CtrlLane => (110.0, 0.09),
        CtrlBlock => (2600.0, 1.20),
    };
    CostEntry { area_um2, energy_pj }
}

/// Leakage power as a fraction of dynamic at full activity — used to add
/// an area-proportional static term (28 nm HVT-dominated mix).
pub const LEAKAGE_UW_PER_MM2: f64 = 6_000.0; // 6 mW per mm^2

/// An inventory of operator counts (a composed datapath block).
#[derive(Clone, Debug, Default)]
pub struct Inventory {
    counts: std::collections::BTreeMap<Op, u64>,
}

impl Inventory {
    pub fn new() -> Inventory {
        Inventory::default()
    }

    pub fn add(&mut self, op: Op, n: u64) -> &mut Self {
        *self.counts.entry(op).or_insert(0) += n;
        self
    }

    pub fn count(&self, op: Op) -> u64 {
        self.counts.get(&op).copied().unwrap_or(0)
    }

    pub fn merge(&mut self, other: &Inventory) {
        for (&op, &n) in &other.counts {
            self.add(op, n);
        }
    }

    pub fn scaled(&self, factor: u64) -> Inventory {
        let mut out = Inventory::new();
        for (&op, &n) in &self.counts {
            out.add(op, n * factor);
        }
        out
    }

    /// Total silicon area in mm^2.
    pub fn area_mm2(&self) -> f64 {
        self.counts
            .iter()
            .map(|(&op, &n)| lib(op).area_um2 * n as f64)
            .sum::<f64>()
            / 1e6
    }

    /// Dynamic power in mW given per-op activity (average toggles per
    /// cycle per instance, 0..=1) and clock frequency.
    pub fn dynamic_power_mw(&self, activity: f64, freq_mhz: f64) -> f64 {
        let pj_per_cycle: f64 = self
            .counts
            .iter()
            .map(|(&op, &n)| lib(op).energy_pj * n as f64 * activity)
            .sum();
        // pJ/cycle * cycles/s = pJ/s; 1e6 Hz per MHz; 1e-9 mW per pJ/s
        pj_per_cycle * freq_mhz * 1e6 * 1e-9
    }

    /// Leakage power in mW (area-proportional).
    pub fn leakage_mw(&self) -> f64 {
        self.area_mm2() * LEAKAGE_UW_PER_MM2 / 1000.0
    }

    /// Total power at the given activity.
    pub fn power_mw(&self, activity: f64, freq_mhz: f64) -> f64 {
        self.dynamic_power_mw(activity, freq_mhz) + self.leakage_mw()
    }

    pub fn iter(&self) -> impl Iterator<Item = (Op, u64)> + '_ {
        self.counts.iter().map(|(&op, &n)| (op, n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp_ops_cost_more_than_fixed() {
        assert!(lib(Op::Bf16Mul).area_um2 > 5.0 * lib(Op::FixAdd).area_um2);
        assert!(lib(Op::Bf16Div).area_um2 > 10.0 * lib(Op::FixAdd).area_um2);
        assert!(lib(Op::ExpUnit).energy_pj > 5.0 * lib(Op::FixAdd).energy_pj);
    }

    #[test]
    fn inventory_accumulates_and_scales() {
        let mut inv = Inventory::new();
        inv.add(Op::Bf16Mul, 32).add(Op::Bf16Add, 31).add(Op::Bf16Mul, 32);
        assert_eq!(inv.count(Op::Bf16Mul), 64);
        let x4 = inv.scaled(4);
        assert_eq!(x4.count(Op::Bf16Add), 124);
        assert!((x4.area_mm2() - 4.0 * inv.area_mm2()).abs() < 1e-12);
    }

    #[test]
    fn power_scales_with_activity_and_freq() {
        let mut inv = Inventory::new();
        inv.add(Op::Bf16Mul, 100);
        let p1 = inv.dynamic_power_mw(1.0, 500.0);
        let p2 = inv.dynamic_power_mw(0.5, 500.0);
        let p3 = inv.dynamic_power_mw(1.0, 1000.0);
        assert!((p1 - 2.0 * p2).abs() < 1e-9);
        assert!((p3 - 2.0 * p1).abs() < 1e-9);
        // 100 bf16 muls at full tilt, 500 MHz: 1.2pJ*100*500e6 = 60 mW
        assert!((p1 - 60.0).abs() < 1.0);
    }
}

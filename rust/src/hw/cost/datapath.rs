//! Structural composition of the two accelerator datapaths (Figs. 1-4):
//! operator inventories for the FA-2 (all-float) and H-FA (hybrid
//! float/log) FAU, ACC and final-division blocks.
//!
//! Fidelity notes (mapping figure -> inventory):
//! * Both designs share the identical BF16 **dot-product unit** (d mults +
//!   an adder tree + the 1/sqrt(d) scale; multi-operand addition per [51]).
//! * FA-2 'sum acc' (Fig. 1): two exponential units (`e^{m-m'}`,
//!   `e^{s-m'}`), FP multiply + add for `l`, FP max.
//! * FA-2 'output acc': per output lane two FP multiplies (`o*alpha`,
//!   `beta*v`) and one FP add.
//! * FA-2 DIV: one BF16 divider per output lane.
//! * H-FA FAU (Fig. 3): dot product unchanged; **two quantizers + two
//!   constant shifters per FAU** (west side of Fig. 3); per *lane* (d+1
//!   lanes: ell + d outputs): two fixed adds (A, B), abs-diff compare,
//!   PWL LUT + slope mult + barrel shift, one fixed add (max +- r), sign
//!   mux — all fixed point.  Value conversion is a bias-subtract per lane.
//! * H-FA ACC (Fig. 4): FP max + two quantizers, then the same per-lane
//!   LNS adder; **no conversions** to/from linear.
//! * H-FA LogDiv: per lane one fixed subtract + the log->float conversion
//!   (bias add + saturation mux).
//! * Pipeline registers and per-block control are charged to BOTH designs
//!   (identical streaming pattern, identical latency — Section VI-C).

use super::components::{Inventory, Op};

/// Which arithmetic the datapath uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arith {
    Fa2,
    Hfa,
}

impl Arith {
    pub fn name(self) -> &'static str {
        match self {
            Arith::Fa2 => "FA-2",
            Arith::Hfa => "H-FA",
        }
    }
}

/// Shared BF16 dot-product unit (d multipliers + (d-1)-adder tree + scale).
pub fn dot_unit(d: usize) -> Inventory {
    let mut inv = Inventory::new();
    inv.add(Op::Bf16Mul, d as u64 + 1) // +1 for the 1/sqrt(d) scale
        .add(Op::Bf16Add, d as u64 - 1)
        // operand + pipeline registers across the adder tree stages
        .add(Op::Reg16, 2 * d as u64)
        .add(Op::Reg32, (d.ilog2() as u64 + 1) * 2);
    inv
}

/// One FAU (serves one query against one KV sub-block stream).
pub fn fau(arith: Arith, d: usize) -> Inventory {
    let lanes = d as u64 + 1; // ell + d output lanes
    let mut inv = dot_unit(d);
    inv.add(Op::Bf16Max, 1); // running max m_i
    inv.add(Op::CtrlBlock, 1);
    match arith {
        Arith::Fa2 => {
            // sum acc: 2 exp + l*alpha + (+ beta)
            inv.add(Op::ExpUnit, 2).add(Op::Bf16Mul, 1).add(Op::Bf16Add, 1);
            // output acc: per lane o*alpha + beta*v + add
            inv.add(Op::Bf16Mul, 2 * d as u64).add(Op::Bf16Add, d as u64);
            // state registers: m, l, o[d] in bf16
            inv.add(Op::Reg16, d as u64 + 2);
            inv.add(Op::CtrlLane, d as u64);
        }
        Arith::Hfa => {
            // two quantizers + constant shifters (west side, Fig. 3)
            inv.add(Op::QuantUnit, 2).add(Op::Shifter, 2);
            // value conversion: bias subtract per lane
            inv.add(Op::FixAdd, lanes);
            // per-lane LNS adder: A/B adds, |A-B|, PWL, shift, +-r, sign
            inv.add(Op::FixAdd, 3 * lanes) // A, B, max +- r
                .add(Op::FixCmp, 2 * lanes) // max select + abs-diff sign
                .add(Op::PwlLut, lanes)
                .add(Op::PwlMul, lanes)
                .add(Op::Shifter, lanes);
            // state + inter-stage pipeline registers: m (bf16), sign +
            // log per lane carried across the 4-stage LNS adder
            inv.add(Op::Reg16, 3 * lanes + 1);
            inv.add(Op::CtrlLane, lanes);
        }
    }
    inv
}

/// One ACC merge block (combines two partial triplets; Fig. 2 cascade).
pub fn acc_block(arith: Arith, d: usize) -> Inventory {
    let lanes = d as u64 + 1;
    let mut inv = Inventory::new();
    inv.add(Op::Bf16Max, 1).add(Op::CtrlBlock, 1);
    match arith {
        Arith::Fa2 => {
            inv.add(Op::ExpUnit, 2);
            // per lane: o_A*e_A + o_B*e_B
            inv.add(Op::Bf16Mul, 2 * lanes).add(Op::Bf16Add, lanes);
            inv.add(Op::Reg16, lanes + 1);
            inv.add(Op::CtrlLane, lanes);
        }
        Arith::Hfa => {
            inv.add(Op::QuantUnit, 2).add(Op::Shifter, 2);
            inv.add(Op::FixAdd, 3 * lanes)
                .add(Op::FixCmp, 2 * lanes)
                .add(Op::PwlLut, lanes)
                .add(Op::PwlMul, lanes)
                .add(Op::Shifter, lanes);
            inv.add(Op::Reg16, 3 * lanes + 1);
            inv.add(Op::CtrlLane, lanes);
        }
    }
    inv
}

/// The final division block (one per query datapath).
pub fn div_block(arith: Arith, d: usize) -> Inventory {
    let mut inv = Inventory::new();
    inv.add(Op::CtrlBlock, 1);
    match arith {
        Arith::Fa2 => {
            inv.add(Op::Bf16Div, d as u64);
            inv.add(Op::Reg16, d as u64);
        }
        Arith::Hfa => {
            // LogDiv: fixed subtract per lane + log->float conversion
            // (bias add + saturation mux, Section V-B)
            inv.add(Op::FixAdd, 2 * d as u64) // subtract + bias add
                .add(Op::FixCmp, d as u64) // saturation detect
                .add(Op::Reg16, d as u64);
        }
    }
    inv
}

/// Whole accelerator datapath: `p` block-FAUs + `p` ACC units (the paper's
/// Fig. 6 layout instantiates one ACC per block row) + final division,
/// replicated for `nq` parallel query datapaths.
pub fn accelerator(arith: Arith, d: usize, p: usize, nq: usize) -> Inventory {
    let mut inv = Inventory::new();
    let mut per_query = Inventory::new();
    per_query.merge(&fau(arith, d).scaled(p as u64));
    per_query.merge(&acc_block(arith, d).scaled(p as u64));
    per_query.merge(&div_block(arith, d));
    inv.merge(&per_query.scaled(nq as u64));
    inv
}

/// Per-block area breakdown rows for the Fig. 6 substitute.
pub fn breakdown(arith: Arith, d: usize, p: usize) -> Vec<(String, f64)> {
    vec![
        (format!("dot-product x{p}"), dot_unit(d).scaled(p as u64).area_mm2()),
        (
            format!("{} accum x{p}", arith.name()),
            {
                let mut f = fau(arith, d);
                // subtract the shared dot unit to isolate the accumulator
                let dot = dot_unit(d);
                let mut acc_area = f.area_mm2() - dot.area_mm2();
                if acc_area < 0.0 {
                    acc_area = 0.0;
                }
                f = Inventory::new();
                let _ = f;
                acc_area * p as f64
            },
        ),
        (format!("ACC x{p}"), acc_block(arith, d).scaled(p as u64).area_mm2()),
        (
            if arith == Arith::Hfa { "LogDiv".into() } else { "DIV".into() },
            div_block(arith, d).area_mm2(),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hfa_fau_smaller_than_fa2() {
        for d in [32, 64, 128] {
            let a_fa2 = fau(Arith::Fa2, d).area_mm2();
            let a_hfa = fau(Arith::Hfa, d).area_mm2();
            assert!(a_hfa < a_fa2, "d={d}: {a_hfa} vs {a_fa2}");
        }
    }

    #[test]
    fn logdiv_much_smaller_than_div() {
        let div = div_block(Arith::Fa2, 32).area_mm2();
        let logdiv = div_block(Arith::Hfa, 32).area_mm2();
        assert!(logdiv < 0.25 * div, "{logdiv} vs {div}");
    }

    #[test]
    fn dot_unit_identical_across_designs() {
        // the score path stays in floating point in both designs
        let fa2 = fau(Arith::Fa2, 64);
        let hfa = fau(Arith::Hfa, 64);
        assert_eq!(fa2.count(Op::Bf16Mul) >= 65, true);
        assert_eq!(hfa.count(Op::Bf16Mul), 65); // only the dot unit's
    }

    #[test]
    fn datapath_savings_in_paper_range() {
        // Fig. 6: 36.1% datapath savings at d=32, p=4; Fig. 7 reports
        // >26% once SRAM is included.  The structural model must land in
        // the right regime (30-45% datapath-only).
        for d in [32, 64, 128] {
            let fa2 = accelerator(Arith::Fa2, d, 4, 1).area_mm2();
            let hfa = accelerator(Arith::Hfa, d, 4, 1).area_mm2();
            let savings = 1.0 - hfa / fa2;
            assert!(
                (0.28..0.50).contains(&savings),
                "d={d}: datapath savings {savings:.3} out of expected range"
            );
        }
    }

    #[test]
    fn accelerator_scales_with_replication() {
        let one = accelerator(Arith::Hfa, 64, 4, 1).area_mm2();
        let four = accelerator(Arith::Hfa, 64, 4, 4).area_mm2();
        assert!((four - 4.0 * one).abs() < 1e-9);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let rows = breakdown(Arith::Hfa, 32, 4);
        let sum: f64 = rows.iter().map(|(_, a)| a).sum();
        let total = accelerator(Arith::Hfa, 32, 4, 1).area_mm2();
        assert!((sum - total).abs() / total < 0.02, "{sum} vs {total}");
    }
}

//! Analytical SRAM model for the KV buffers — the Cacti-6.0 +
//! Accelergy-hwcomponents role in the paper's Section VI-C, with a
//! DeepScale-style node conversion (the paper models at 22 nm and scales
//! up to 28 nm; see `scaling.rs`).
//!
//! The model is the standard bank-structured fit: area = bank overhead +
//! bit-cell array / array-efficiency; read energy grows with sqrt(capacity)
//! (wordline/bitline length).  Constants are calibrated to public Cacti
//! numbers for small (64 kB - 1 MB) 22 nm SRAM macros.

use super::scaling::{area_scale, energy_scale, Node};

/// 22 nm SRAM bit-cell area (um^2) — 6T high-density cell.
const BITCELL_UM2_22: f64 = 0.065;
/// Array efficiency (cell area / macro area) for small macros.
const ARRAY_EFF: f64 = 0.55;
/// Fixed per-bank periphery area (um^2, 22 nm): decoders, sense amps, IO.
const BANK_OVERHEAD_UM2_22: f64 = 9_000.0;
/// Read energy fit at 22 nm: E(pJ/access) = A + B * sqrt(kB)  (64-bit word)
const READ_E_A_PJ: f64 = 1.8;
const READ_E_B_PJ: f64 = 0.55;
/// Static leakage per MB at 22 nm (mW).
const LEAK_MW_PER_MB_22: f64 = 18.0;

/// A KV SRAM buffer subsystem.
#[derive(Clone, Copy, Debug)]
pub struct SramConfig {
    /// Total capacity in bytes (K + V for all sub-blocks).
    pub capacity_bytes: u64,
    /// Number of independently addressed banks (one per KV sub-block per
    /// K/V matrix keeps all block-FAUs streaming concurrently).
    pub banks: u32,
    /// Word width in bits (one value element per access lane).
    pub word_bits: u32,
    /// Target technology node.
    pub node: Node,
}

impl SramConfig {
    /// KV buffers for the paper's accelerator: K and V matrices of
    /// `seq_len x d` BF16, split into `p` sub-blocks each, at `node`.
    pub fn kv_buffers(seq_len: usize, d: usize, p: usize, node: Node) -> SramConfig {
        SramConfig {
            capacity_bytes: (2 * seq_len * d * 2) as u64, // K+V, 2B/elem
            banks: (2 * p) as u32,
            word_bits: 16,
            node,
        }
    }

    /// Macro area in mm^2 at the configured node.
    pub fn area_mm2(&self) -> f64 {
        let bits = self.capacity_bytes as f64 * 8.0;
        let cell = bits * BITCELL_UM2_22 / ARRAY_EFF;
        let periph = self.banks as f64 * BANK_OVERHEAD_UM2_22;
        (cell + periph) / 1e6 * area_scale(Node::N22, self.node)
    }

    /// Energy per word read, pJ, at the configured node.
    pub fn read_energy_pj(&self) -> f64 {
        let kb_per_bank = self.capacity_bytes as f64 / 1024.0 / self.banks as f64;
        let e22 = (READ_E_A_PJ + READ_E_B_PJ * kb_per_bank.sqrt())
            * (self.word_bits as f64 / 64.0);
        e22 * energy_scale(Node::N22, self.node)
    }

    /// Leakage power in mW.
    pub fn leakage_mw(&self) -> f64 {
        let mb = self.capacity_bytes as f64 / (1024.0 * 1024.0);
        mb * LEAK_MW_PER_MB_22 * energy_scale(Node::N22, self.node)
    }

    /// Average power in mW given an access rate (words/cycle across all
    /// banks) at `freq_mhz`.
    pub fn power_mw(&self, words_per_cycle: f64, freq_mhz: f64) -> f64 {
        let dyn_mw = self.read_energy_pj() * words_per_cycle * freq_mhz * 1e6 * 1e-9;
        dyn_mw + self.leakage_mw()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_kv_buffer_magnitude() {
        // d=64, N=1024: 256 kB at 28 nm should land in the 0.2-0.6 mm^2
        // range (Cacti-class small macro)
        let s = SramConfig::kv_buffers(1024, 64, 4, Node::N28);
        assert_eq!(s.capacity_bytes, 256 * 1024);
        let a = s.area_mm2();
        assert!((0.15..0.8).contains(&a), "area {a}");
    }

    #[test]
    fn area_monotone_in_capacity() {
        let small = SramConfig::kv_buffers(256, 32, 4, Node::N28).area_mm2();
        let big = SramConfig::kv_buffers(1024, 128, 4, Node::N28).area_mm2();
        assert!(big > 4.0 * small, "{big} vs {small}");
    }

    #[test]
    fn node_scaling_shrinks_at_smaller_node() {
        let at28 = SramConfig::kv_buffers(1024, 64, 4, Node::N28).area_mm2();
        let at22 = SramConfig::kv_buffers(1024, 64, 4, Node::N22).area_mm2();
        assert!(at22 < at28);
    }

    #[test]
    fn read_energy_reasonable() {
        let s = SramConfig::kv_buffers(1024, 64, 4, Node::N28);
        let e = s.read_energy_pj();
        assert!((0.2..5.0).contains(&e), "read energy {e} pJ");
    }

    #[test]
    fn power_scales_with_access_rate() {
        let s = SramConfig::kv_buffers(1024, 64, 4, Node::N28);
        let p1 = s.power_mw(8.0, 500.0);
        let p2 = s.power_mw(16.0, 500.0);
        assert!(p2 > p1);
        assert!(p1 > s.leakage_mw());
    }
}

//! Technology-node scaling — the DeepScaleTool role in the paper's flow
//! (SRAM modelled at 22 nm, scaled to the 28 nm design node).
//!
//! Factors follow the published DeepScale/Stillmaker-Baas style dense
//! scaling tables: area scales with the square of the feature-size-like
//! dimension per node step; energy scales a bit slower in the deep
//! submicron era.

/// Supported nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Node {
    N45,
    N32,
    N28,
    N22,
    N16,
    N7,
}

impl Node {
    // not the FromStr trait: this is a CLI selector with anyhow errors
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> anyhow::Result<Node> {
        Ok(match s {
            "45" | "45nm" => Node::N45,
            "32" | "32nm" => Node::N32,
            "28" | "28nm" => Node::N28,
            "22" | "22nm" => Node::N22,
            "16" | "16nm" => Node::N16,
            "7" | "7nm" => Node::N7,
            other => anyhow::bail!("unknown node {other:?}"),
        })
    }

    /// Relative dense-logic area per gate, normalized to 28 nm = 1.0.
    fn area_factor(self) -> f64 {
        match self {
            Node::N45 => 2.58,
            Node::N32 => 1.31,
            Node::N28 => 1.00,
            Node::N22 => 0.62,
            Node::N16 => 0.34,
            Node::N7 => 0.092,
        }
    }

    /// Relative switching energy per op, normalized to 28 nm = 1.0.
    fn energy_factor(self) -> f64 {
        match self {
            Node::N45 => 2.10,
            Node::N32 => 1.25,
            Node::N28 => 1.00,
            Node::N22 => 0.75,
            Node::N16 => 0.48,
            Node::N7 => 0.21,
        }
    }
}

/// Multiply an area measured at `from` to express it at `to`.
pub fn area_scale(from: Node, to: Node) -> f64 {
    to.area_factor() / from.area_factor()
}

/// Multiply an energy measured at `from` to express it at `to`.
pub fn energy_scale(from: Node, to: Node) -> f64 {
    to.energy_factor() / from.energy_factor()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_scaling() {
        assert_eq!(area_scale(Node::N28, Node::N28), 1.0);
        assert_eq!(energy_scale(Node::N22, Node::N22), 1.0);
    }

    #[test]
    fn upscaling_22_to_28_grows() {
        // the paper's direction: Cacti @22nm -> 28nm design node
        assert!(area_scale(Node::N22, Node::N28) > 1.3);
        assert!(energy_scale(Node::N22, Node::N28) > 1.2);
    }

    #[test]
    fn scaling_is_multiplicative() {
        let via22 = area_scale(Node::N45, Node::N22) * area_scale(Node::N22, Node::N7);
        let direct = area_scale(Node::N45, Node::N7);
        assert!((via22 - direct).abs() < 1e-12);
    }

    #[test]
    fn parse_round_trip() {
        assert_eq!(Node::from_str("28nm").unwrap(), Node::N28);
        assert!(Node::from_str("13nm").is_err());
    }
}

//! Whole-design cost reports: datapath + SRAM area and activity-based
//! power for a configured accelerator — the numbers behind Figs. 6/7/8(b)
//! and Table IV.

use crate::config::AcceleratorConfig;
use crate::hw::cost::components::Inventory;
use crate::hw::cost::datapath::{acc_block, accelerator, div_block, fau, Arith};
use crate::hw::cost::scaling::Node;
use crate::hw::cost::sram::SramConfig;
use crate::hw::pipeline::{simulate, LatencyModel};

/// Wide SRAM row accesses amortize per-word energy (one 1024-bit row read
/// instead of 64 independent word reads) — effective per-word factor.
pub const WIDE_ACCESS_FACTOR: f64 = 0.25;

/// Average switching-activity derate for datapath dynamic power.  The
/// paper reports power "measured during inference on various benchmarks"
/// (PowerPro on real vectors); real operand streams toggle a fraction of
/// the worst-case bits per cycle.
pub const ACTIVITY_DERATE: f64 = 0.30;

/// Cost summary of one design point.
#[derive(Clone, Debug)]
pub struct CostReport {
    pub arith: Arith,
    pub d: usize,
    pub p: usize,
    pub nq: usize,
    pub datapath_area_mm2: f64,
    pub sram_area_mm2: f64,
    pub datapath_power_mw: f64,
    pub sram_power_mw: f64,
}

impl CostReport {
    pub fn total_area_mm2(&self) -> f64 {
        self.datapath_area_mm2 + self.sram_area_mm2
    }

    pub fn total_power_mw(&self) -> f64 {
        self.datapath_power_mw + self.sram_power_mw
    }
}

/// Build the cost report for a design point, with activity factors taken
/// from the cycle simulator under a steady stream of `batch` queries.
pub fn report(arith: Arith, cfg: &AcceleratorConfig, batch: usize) -> CostReport {
    let (d, p, nq) = (cfg.head_dim, cfg.kv_blocks, cfg.parallel_queries);
    let lat = LatencyModel::for_head_dim(d);
    let stats = simulate(d, cfg.seq_len, p, nq, batch.max(1), lat);

    // datapath split into block types so each gets its own activity
    let fau_inv = fau(arith, d).scaled((p * nq) as u64);
    let acc_inv = acc_block(arith, d).scaled((p * nq) as u64);
    let div_inv = div_block(arith, d).scaled(nq as u64);

    let total_inv = accelerator(arith, d, p, nq);
    let datapath_area = total_inv.area_mm2();

    let dp_power = fau_inv.power_mw(stats.fau_utilization() * ACTIVITY_DERATE, cfg.freq_mhz)
        + acc_inv.power_mw(stats.acc_utilization() * ACTIVITY_DERATE, cfg.freq_mhz)
        + div_inv.power_mw(stats.div_utilization() * ACTIVITY_DERATE, cfg.freq_mhz);

    let sram = SramConfig::kv_buffers(cfg.seq_len, d, p, Node::N28);
    let sram_power = sram.power_mw(
        stats.sram_words_per_cycle() * WIDE_ACCESS_FACTOR,
        cfg.freq_mhz,
    );

    CostReport {
        arith,
        d,
        p,
        nq,
        datapath_area_mm2: datapath_area,
        sram_area_mm2: sram.area_mm2(),
        datapath_power_mw: dp_power,
        sram_power_mw: sram_power,
    }
}

/// The Fig. 7 comparison rows: (FA-2 report, H-FA report, area savings %,
/// power savings %) for one head-dimension point.
pub fn compare(cfg: &AcceleratorConfig, batch: usize) -> (CostReport, CostReport, f64, f64) {
    let fa2 = report(Arith::Fa2, cfg, batch);
    let hfa = report(Arith::Hfa, cfg, batch);
    let area_savings = 100.0 * (1.0 - hfa.total_area_mm2() / fa2.total_area_mm2());
    let power_savings = 100.0 * (1.0 - hfa.total_power_mw() / fa2.total_power_mw());
    (fa2, hfa, area_savings, power_savings)
}

/// Throughput in TOPS for Table IV: ops counted per the paper's
/// convention (MAC = 2 ops) over the attention computation, split by
/// domain (BF16 score path, FIX16 log-domain accumulation path).
pub fn throughput_tops(cfg: &AcceleratorConfig, arith: Arith) -> (f64, f64) {
    let (d, p, nq) = (cfg.head_dim as f64, cfg.kv_blocks as f64, cfg.parallel_queries as f64);
    // per cycle: p*nq FAUs each consume one key row
    let bf16_ops_per_cycle = p * nq * (2.0 * d + 4.0); // dot MACs + max/exp path
    let fix_ops_per_cycle = match arith {
        Arith::Fa2 => 0.0,
        // per lane: ~7 fixed ops (2 shifts-adds A/B, cmp, pwl mul-add, shift, final add)
        Arith::Hfa => p * nq * (d + 1.0) * 7.0,
    };
    let cycles_per_sec = cfg.freq_mhz * 1e6;
    (
        bf16_ops_per_cycle * cycles_per_sec / 1e12,
        fix_ops_per_cycle * cycles_per_sec / 1e12,
    )
}

/// Extra per-component rows (Fig. 6-style breakdown table).
pub fn breakdown_table(arith: Arith, d: usize, p: usize) -> Vec<(String, f64)> {
    crate::hw::cost::datapath::breakdown(arith, d, p)
}

/// Utility: inventory of the whole design (for diagnostics).
pub fn full_inventory(arith: Arith, cfg: &AcceleratorConfig) -> Inventory {
    accelerator(arith, cfg.head_dim, cfg.kv_blocks, cfg.parallel_queries)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(d: usize, p: usize, nq: usize) -> AcceleratorConfig {
        AcceleratorConfig {
            head_dim: d,
            seq_len: 1024,
            kv_blocks: p,
            parallel_queries: nq,
            freq_mhz: 500.0,
        }
    }

    #[test]
    fn fig7_savings_in_paper_band() {
        // paper: area savings 22.5%-27% (26.5% avg), power ~23.4% avg,
        // across d in {32, 64, 128} with SRAM included
        for d in [32usize, 64, 128] {
            let (_, _, area_s, power_s) = compare(&cfg(d, 4, 1), 64);
            assert!(
                (15.0..40.0).contains(&area_s),
                "d={d} area savings {area_s:.1}% outside plausible band"
            );
            assert!(
                (12.0..40.0).contains(&power_s),
                "d={d} power savings {power_s:.1}% outside plausible band"
            );
        }
    }

    #[test]
    fn sram_identical_across_designs() {
        let (fa2, hfa, _, _) = compare(&cfg(64, 4, 1), 64);
        assert_eq!(fa2.sram_area_mm2, hfa.sram_area_mm2);
    }

    #[test]
    fn table4_magnitudes() {
        // H-FA-1-4 (d=64): paper reports 1.14 mm^2, 0.22 W total
        let r = report(Arith::Hfa, &cfg(64, 4, 1), 64);
        let area = r.total_area_mm2();
        let power_w = r.total_power_mw() / 1000.0;
        assert!((0.4..2.5).contains(&area), "area {area} mm^2");
        assert!((0.05..0.7).contains(&power_w), "power {power_w} W");
    }

    #[test]
    fn replication_scales_datapath_not_sram() {
        let r1 = report(Arith::Hfa, &cfg(64, 4, 1), 64);
        let r4 = report(Arith::Hfa, &cfg(64, 4, 4), 64);
        assert!((r4.datapath_area_mm2 / r1.datapath_area_mm2 - 4.0).abs() < 0.01);
        assert_eq!(r1.sram_area_mm2, r4.sram_area_mm2);
    }

    #[test]
    fn throughput_counts_fixed_ops_only_for_hfa() {
        let (bf_fa2, fix_fa2) = throughput_tops(&cfg(64, 4, 1), Arith::Fa2);
        let (bf_hfa, fix_hfa) = throughput_tops(&cfg(64, 4, 1), Arith::Hfa);
        assert_eq!(bf_fa2, bf_hfa);
        assert_eq!(fix_fa2, 0.0);
        assert!(fix_hfa > 0.0);
        // paper Table IV HFA-1-4: 0.256 TOPS BF16 + 0.91 TOPS FIX16
        assert!((0.1..0.6).contains(&bf_hfa), "bf16 {bf_hfa}");
        assert!((0.4..2.0).contains(&fix_hfa), "fix {fix_hfa}");
    }
}

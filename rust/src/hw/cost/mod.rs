//! 28 nm area/power cost model (the paper's Section VI-C evaluation flow
//! rebuilt as an analytical model — see DESIGN.md §5 for the substitution
//! argument).

pub mod components;
pub mod datapath;
pub mod report;
pub mod scaling;
pub mod sram;

pub use datapath::Arith;
pub use report::{compare, report, CostReport};

//! Hardware model of the parallel FlashAttention accelerator (paper
//! Sections III & V, Figs. 1-4):
//!
//! * [`pipeline`] — cycle-level timing: FAU streaming at II=1, the
//!   ready/valid ACC cascade, DIV/LogDiv, query-round pipelining.  The
//!   paper's 19/20/21-cycle latency points are asserted in tests.
//! * [`accelerator`] — RTL-equivalent functional model (bit-exact golden
//!   arithmetic) joined with the timing model and cost accounting.
//! * [`cost`] — the 28 nm area/power component library, KV-SRAM model and
//!   node-scaling helpers that regenerate Figs. 6/7/8(b) and Table IV.

pub mod accelerator;
pub mod cost;
pub mod pipeline;

pub use accelerator::Accelerator;
pub use cost::Arith;
pub use pipeline::{simulate, CycleStats, LatencyModel};
